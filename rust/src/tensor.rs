//! Host-side tensor type used between the PJRT runtime and the coordinator.
//!
//! Everything on the coordinator hot path (KV rows, score vectors, hidden
//! states) is an f32 `HostTensor`; token ids / lengths are `HostTensorI32`.
//! Row-major, shape-checked on construction.

#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct HostTensorI32 {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl HostTensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} vs {} elements",
            data.len()
        );
        HostTensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        HostTensor { shape, data: vec![0.0; n] }
    }

    /// Zero-element placeholder for `*_into` scratch buffers (note: a
    /// scalar has an empty *shape* but one element; this has neither).
    pub fn empty() -> Self {
        HostTensor { shape: vec![0], data: Vec::new() }
    }

    pub fn scalar(v: f32) -> Self {
        HostTensor { shape: vec![], data: vec![v] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Stride (in elements) of axis `d`.
    pub fn stride(&self, d: usize) -> usize {
        self.shape[d + 1..].iter().product()
    }

    /// Borrow row `i` along the leading axis.
    pub fn row(&self, i: usize) -> &[f32] {
        let s = self.stride(0).max(1);
        let s0 = self.shape.first().copied().unwrap_or(1);
        assert!(i < s0, "row {i} out of {s0}");
        &self.data[i * s..(i + 1) * s]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let s = self.stride(0).max(1);
        &mut self.data[i * s..(i + 1) * s]
    }

    /// Borrow sub-tensor at `[i, j]` of a >=2-d tensor.
    pub fn row2(&self, i: usize, j: usize) -> &[f32] {
        let s1 = self.stride(1).max(1);
        let base = i * self.stride(0) + j * s1;
        &self.data[base..base + s1]
    }

    /// Reinterpret with a new shape (same element count).
    pub fn reshaped(mut self, shape: Vec<usize>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape;
        self
    }

    pub fn argmax(&self) -> usize {
        self.data
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
}

impl HostTensorI32 {
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensorI32 { shape, data }
    }

    pub fn scalar(v: i32) -> Self {
        HostTensorI32 { shape: vec![], data: vec![v] }
    }

    /// Zero-element placeholder for `*_into` scratch buffers.
    pub fn empty() -> Self {
        HostTensorI32 { shape: vec![0], data: Vec::new() }
    }

    pub fn from_usizes(shape: Vec<usize>, xs: &[usize]) -> Self {
        Self::new(shape, xs.iter().map(|&x| x as i32).collect())
    }
}

/// L2 distance between two equal-length slices (Fig. 3 metric).
pub fn l2_distance(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| ((x - y) as f64).powi(2))
        .sum::<f64>()
        .sqrt()
}

/// Normalized L2 distance ||a-b|| / ||a|| (the paper's Fig. 3 y-axis).
pub fn normalized_l2(a: &[f32], b: &[f32]) -> f64 {
    let norm = a.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
    if norm == 0.0 {
        0.0
    } else {
        l2_distance(a, b) / norm
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_and_strides() {
        let t = HostTensor::new(
            vec![2, 3, 2],
            (0..12).map(|x| x as f32).collect(),
        );
        assert_eq!(t.stride(0), 6);
        assert_eq!(t.stride(1), 2);
        assert_eq!(t.row(1), &[6., 7., 8., 9., 10., 11.]);
        assert_eq!(t.row2(1, 2), &[10., 11.]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        HostTensor::new(vec![2, 2], vec![0.0; 3]);
    }

    #[test]
    fn argmax_works() {
        let t = HostTensor::new(vec![4], vec![0.1, 3.0, -1.0, 2.0]);
        assert_eq!(t.argmax(), 1);
    }

    #[test]
    fn distances() {
        assert_eq!(l2_distance(&[0.0, 3.0], &[4.0, 0.0]), 5.0);
        assert!((normalized_l2(&[3.0, 4.0], &[3.0, 4.0])).abs() < 1e-12);
    }
}
