//! Observability: request-lifecycle tracing, phase-level timing, and
//! the metrics export plane.
//!
//! The serving stack records typed [`trace::Event`]s into a bounded
//! ring ([`trace::TraceRecorder`], embedded in every
//! [`crate::metrics::Metrics`] registry) at each lifecycle transition —
//! submit, quota-defer, prefill start/end, admit, per-N decode steps,
//! compact, preempt, swap-out, resume, finish, reject — and snapshots
//! the last few events of a request into a flight-recorder
//! [`trace::Incident`] whenever something anomalous happens (reject,
//! swap refusal, recompute resume, quota denial).
//!
//! The [`export`] module renders the registry and the ring for external
//! consumers: Prometheus text exposition, a JSON snapshot that
//! round-trips through [`crate::util::json::Value`], and Chrome
//! trace-event JSON for timeline viewers.
//!
//! Tracing is off by default and costs one relaxed atomic load per
//! would-be event; the decode scratch path stays allocation-free either
//! way (events are `Copy` records written into a pre-allocated ring).
//! See `docs/observability.md` for the event schema and phase taxonomy.

#![warn(missing_docs)]

pub mod export;
pub mod trace;

pub use export::{
    chrome_trace, flight_text, json_snapshot, prometheus_text,
    write_chrome_trace, write_json_snapshot, write_prometheus,
};
pub use trace::{
    validate_lifecycle, Event, EventKind, Incident, IncidentKind,
    ResumeMode, TraceRecorder, NO_LANE,
};

use std::path::PathBuf;

/// Observability knobs on [`crate::coordinator::server::ServerConfig`].
///
/// Everything defaults to off: `trace_events == 0` leaves the recorder
/// disabled (the hot path pays one atomic load per would-be event) and
/// `None` paths skip all file output.
#[derive(Debug, Clone, Default)]
pub struct ObsConfig {
    /// Ring-buffer capacity in events; `0` disables tracing entirely.
    pub trace_events: usize,
    /// Dump the event ring as Chrome trace-event JSON here on shutdown.
    pub trace_out: Option<PathBuf>,
    /// Write the JSON metrics snapshot here periodically and on
    /// shutdown; a Prometheus text sibling with extension `.prom` is
    /// written next to it.
    pub metrics_out: Option<PathBuf>,
    /// Export `metrics_out` every this many serve-loop iterations
    /// (`0` means only on shutdown).
    pub export_every: usize,
}
