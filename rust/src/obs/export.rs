//! Export plane for the metrics registry and the lifecycle trace:
//!
//!  * [`prometheus_text`] — Prometheus text exposition (counters,
//!    gauges, and histograms with cumulative `_bucket{le=...}` rows);
//!  * [`json_snapshot`] — one JSON document with every counter, gauge,
//!    histogram (count/sum/min/max/percentiles/non-empty buckets), and
//!    the flight-recorder incidents; round-trips through
//!    [`crate::util::json::Value::parse`];
//!  * [`chrome_trace`] — the trace ring rendered as Chrome trace-event
//!    JSON (open in Perfetto / `chrome://tracing`): one track per lane
//!    plus a queue/parked track, spans per lifecycle phase, instants
//!    for compactions, swap-outs, deferrals, and rejects.
//!
//! All renderers read point-in-time copies ([`crate::metrics::Metrics::
//! snapshot`], [`TraceRecorder::snapshot`]) — they never hold the
//! registry lock while formatting.

use std::collections::BTreeMap;
use std::path::Path;

use crate::metrics::{Histogram, Metrics};
use crate::obs::trace::{Event, EventKind, ResumeMode, TraceRecorder};
use crate::util::json::Value;

/// Prometheus text exposition of every series in the registry.
/// Histograms emit cumulative `_bucket{le="..."}` rows for non-empty
/// buckets plus the `+Inf` catch-all, `_sum`, and `_count`.
pub fn prometheus_text(m: &Metrics) -> String {
    let snap = m.snapshot();
    let mut out = String::new();
    for (k, v) in &snap.counters {
        out.push_str(&format!("# TYPE {k} counter\n{k} {v}\n"));
    }
    for (k, v) in &snap.gauges {
        out.push_str(&format!("# TYPE {k} gauge\n{k} {v}\n"));
    }
    for (k, h) in &snap.histograms {
        out.push_str(&format!("# TYPE {k} histogram\n"));
        let mut cum = 0u64;
        for (i, &c) in h.bucket_counts().iter().enumerate() {
            if c == 0 {
                continue;
            }
            cum += c;
            let le = Histogram::upper_bound(i);
            if le.is_finite() {
                out.push_str(&format!("{k}_bucket{{le=\"{le}\"}} {cum}\n"));
            }
        }
        out.push_str(&format!(
            "{k}_bucket{{le=\"+Inf\"}} {}\n{k}_sum {}\n{k}_count {}\n",
            h.count(),
            h.total(),
            h.count()
        ));
    }
    out
}

fn num(v: f64) -> Value {
    Value::Num(v)
}

fn hist_json(h: &Histogram) -> Value {
    let mut o = BTreeMap::new();
    o.insert("count".into(), num(h.count() as f64));
    o.insert("sum".into(), num(h.total()));
    o.insert("min".into(), num(h.min()));
    o.insert("max".into(), num(h.max()));
    o.insert("mean".into(), num(h.mean()));
    o.insert("p50".into(), num(h.p(50.0)));
    o.insert("p95".into(), num(h.p(95.0)));
    o.insert("p99".into(), num(h.p(99.0)));
    let buckets: Vec<Value> = h
        .bucket_counts()
        .iter()
        .enumerate()
        .filter(|(_, &c)| c > 0)
        .map(|(i, &c)| {
            let mut b = BTreeMap::new();
            let le = Histogram::upper_bound(i);
            b.insert(
                "le".into(),
                if le.is_finite() {
                    num(le)
                } else {
                    Value::Str("+Inf".into())
                },
            );
            b.insert("n".into(), num(c as f64));
            Value::Obj(b)
        })
        .collect();
    o.insert("buckets".into(), Value::Arr(buckets));
    Value::Obj(o)
}

fn event_json(e: &Event) -> Value {
    let mut o = BTreeMap::new();
    o.insert("ts".into(), num(e.ts));
    o.insert("req".into(), num(e.req as f64));
    o.insert("tenant".into(), num(e.tenant.0 as f64));
    o.insert("lane".into(), num(e.lane as f64));
    o.insert("kind".into(), Value::Str(format!("{:?}", e.kind)));
    Value::Obj(o)
}

/// JSON snapshot of the full registry: counters, gauges, histograms
/// (with non-empty buckets), trace-ring stats, and the flight-recorder
/// incidents. The output parses back with [`Value::parse`]; the
/// round-trip is pinned by `tests/obs.rs`.
pub fn json_snapshot(m: &Metrics) -> Value {
    let snap = m.snapshot();
    let mut root = BTreeMap::new();
    root.insert(
        "counters".into(),
        Value::Obj(
            snap.counters
                .iter()
                .map(|(k, &v)| (k.clone(), num(v as f64)))
                .collect(),
        ),
    );
    root.insert(
        "gauges".into(),
        Value::Obj(
            snap.gauges
                .iter()
                .map(|(k, &v)| (k.clone(), num(v)))
                .collect(),
        ),
    );
    root.insert(
        "histograms".into(),
        Value::Obj(
            snap.histograms
                .iter()
                .map(|(k, h)| (k.clone(), hist_json(h)))
                .collect(),
        ),
    );
    let tr = m.tracer();
    let mut trace = BTreeMap::new();
    trace.insert("enabled".into(), Value::Bool(tr.is_enabled()));
    trace.insert("events".into(), num(tr.len() as f64));
    trace.insert("dropped".into(), num(tr.dropped() as f64));
    let incidents: Vec<Value> = tr
        .incidents()
        .iter()
        .map(|inc| {
            let mut o = BTreeMap::new();
            o.insert("kind".into(), Value::Str(format!("{:?}", inc.kind)));
            o.insert("req".into(), num(inc.req as f64));
            o.insert("tenant".into(), num(inc.tenant.0 as f64));
            o.insert("ts".into(), num(inc.ts));
            o.insert(
                "history".into(),
                Value::Arr(inc.history.iter().map(event_json).collect()),
            );
            Value::Obj(o)
        })
        .collect();
    trace.insert("incidents".into(), Value::Arr(incidents));
    root.insert("trace".into(), Value::Obj(trace));
    Value::Obj(root)
}

/// Write the JSON snapshot to `path`.
pub fn write_json_snapshot(m: &Metrics, path: &Path) -> std::io::Result<()> {
    std::fs::write(path, json_snapshot(m).to_string())
}

/// Write the Prometheus text exposition to `path`.
pub fn write_prometheus(m: &Metrics, path: &Path) -> std::io::Result<()> {
    std::fs::write(path, prometheus_text(m))
}

/// Track id of the queue/parked lifecycle phases (lanes use `lane + 1`).
const TID_QUEUE: i64 = 0;

fn chrome_event(
    name: &str,
    ph: &str,
    ts_us: f64,
    dur_us: Option<f64>,
    tid: i64,
    e: &Event,
) -> Value {
    let mut o = BTreeMap::new();
    o.insert("name".into(), Value::Str(name.into()));
    o.insert("cat".into(), Value::Str("lifecycle".into()));
    o.insert("ph".into(), Value::Str(ph.into()));
    o.insert("ts".into(), num(ts_us));
    if let Some(d) = dur_us {
        o.insert("dur".into(), num(d));
    }
    if ph == "i" {
        // instant scope: thread-local tick
        o.insert("s".into(), Value::Str("t".into()));
    }
    o.insert("pid".into(), num(1.0));
    o.insert("tid".into(), num(tid as f64));
    let mut args = BTreeMap::new();
    args.insert("req".into(), num(e.req as f64));
    args.insert("tenant".into(), num(e.tenant.0 as f64));
    args.insert("detail".into(), Value::Str(format!("{:?}", e.kind)));
    o.insert("args".into(), Value::Obj(args));
    Value::Obj(o)
}

fn thread_meta(tid: i64, name: &str) -> Value {
    let mut o = BTreeMap::new();
    o.insert("name".into(), Value::Str("thread_name".into()));
    o.insert("ph".into(), Value::Str("M".into()));
    o.insert("pid".into(), num(1.0));
    o.insert("tid".into(), num(tid as f64));
    let mut args = BTreeMap::new();
    args.insert("name".into(), Value::Str(name.into()));
    o.insert("args".into(), Value::Obj(args));
    Value::Obj(o)
}

/// Render the trace ring as Chrome trace-event JSON (the
/// `{"traceEvents": [...]}` object format): per request, the lifecycle
/// phases become `X` (complete) spans — `queued` (submit → prefill or
/// admit), `prefill`, `decode` (admit/swap-resume → preempt/finish),
/// `preempted` (preempt → resume/reject) — placed on one track per lane
/// (`tid = lane + 1`) with queue-side phases on track 0; compactions,
/// swap-outs, deferrals, decode-step samples, and rejects are instants.
/// A span still open when the ring was snapshotted is closed at the
/// request's last event.
pub fn chrome_trace(rec: &TraceRecorder) -> String {
    let events = rec.snapshot();
    let mut by_req: BTreeMap<u64, Vec<Event>> = BTreeMap::new();
    for e in events {
        by_req.entry(e.req).or_default().push(e);
    }
    let us = |ts: f64| ts * 1e6;
    let mut tids = std::collections::BTreeSet::new();
    tids.insert(TID_QUEUE);
    let mut out: Vec<Value> = Vec::new();
    for evs in by_req.values() {
        // one open span at a time per request: (phase name, start, tid)
        let mut open: Option<(&'static str, f64, i64)> = None;
        let last_ts = evs.last().map(|e| e.ts).unwrap_or(0.0);
        for e in evs {
            let lane_tid = if e.lane >= 0 {
                e.lane as i64 + 1
            } else {
                TID_QUEUE
            };
            tids.insert(lane_tid);
            let mut close = |open: &mut Option<(&'static str, f64, i64)>,
                             out: &mut Vec<Value>,
                             end: f64| {
                if let Some((name, t0, tid)) = open.take() {
                    out.push(chrome_event(
                        name,
                        "X",
                        us(t0),
                        Some(us((end - t0).max(0.0))),
                        tid,
                        e,
                    ));
                }
            };
            match &e.kind {
                EventKind::Submit { .. } => {
                    open = Some(("queued", e.ts, TID_QUEUE));
                }
                EventKind::PrefillStart { .. } => {
                    close(&mut open, &mut out, e.ts);
                    open = Some(("prefill", e.ts, TID_QUEUE));
                }
                EventKind::PrefillEnd { .. } => {
                    close(&mut open, &mut out, e.ts);
                }
                EventKind::Admit { .. } => {
                    close(&mut open, &mut out, e.ts);
                    open = Some(("decode", e.ts, lane_tid));
                }
                EventKind::Resume { mode } => {
                    close(&mut open, &mut out, e.ts);
                    if *mode == ResumeMode::Swap {
                        open = Some(("decode", e.ts, lane_tid));
                    }
                    // recompute resume: the prefill span follows
                }
                EventKind::Preempt { .. } => {
                    close(&mut open, &mut out, e.ts);
                    open = Some(("preempted", e.ts, TID_QUEUE));
                }
                EventKind::Finish { .. } => {
                    close(&mut open, &mut out, e.ts);
                }
                EventKind::Reject => {
                    close(&mut open, &mut out, e.ts);
                    out.push(chrome_event(
                        "reject", "i", us(e.ts), None, lane_tid, e,
                    ));
                }
                EventKind::DecodeStep { .. } => {
                    out.push(chrome_event(
                        "decode_step",
                        "i",
                        us(e.ts),
                        None,
                        lane_tid,
                        e,
                    ));
                }
                EventKind::PrefillChunk { .. } => {
                    out.push(chrome_event(
                        "prefill_chunk",
                        "i",
                        us(e.ts),
                        None,
                        TID_QUEUE,
                        e,
                    ));
                }
                EventKind::Compact => {
                    out.push(chrome_event(
                        "compact", "i", us(e.ts), None, lane_tid, e,
                    ));
                }
                EventKind::SwapOut { .. } => {
                    out.push(chrome_event(
                        "swap_out", "i", us(e.ts), None, TID_QUEUE, e,
                    ));
                }
                EventKind::QuotaDefer | EventKind::AdmitDeferred => {
                    out.push(chrome_event(
                        "admit_deferred",
                        "i",
                        us(e.ts),
                        None,
                        TID_QUEUE,
                        e,
                    ));
                }
            }
        }
        // close any span the snapshot caught mid-phase
        if let Some((name, t0, tid)) = open.take() {
            let e = evs.last().expect("open span implies events");
            out.push(chrome_event(
                name,
                "X",
                us(t0),
                Some(us((last_ts - t0).max(0.0))),
                tid,
                e,
            ));
        }
    }
    let mut meta: Vec<Value> = tids
        .into_iter()
        .map(|tid| {
            let name = if tid == TID_QUEUE {
                "queue/parked".to_string()
            } else {
                format!("lane {}", tid - 1)
            };
            thread_meta(tid, &name)
        })
        .collect();
    meta.extend(out);
    let mut root = BTreeMap::new();
    root.insert("traceEvents".into(), Value::Arr(meta));
    root.insert("displayTimeUnit".into(), Value::Str("ms".into()));
    Value::Obj(root).to_string()
}

/// Write the Chrome trace to `path`.
pub fn write_chrome_trace(
    rec: &TraceRecorder,
    path: &Path,
) -> std::io::Result<()> {
    std::fs::write(path, chrome_trace(rec))
}

/// Human-readable flight-recorder report: one block per incident with
/// the request's last trace events. Empty string when no incidents were
/// filed (or tracing is off).
pub fn flight_text(rec: &TraceRecorder) -> String {
    let incidents = rec.incidents();
    if incidents.is_empty() {
        return String::new();
    }
    let mut out = String::new();
    for inc in &incidents {
        out.push_str(&format!(
            "incident {:?} req={} tenant={} at +{:.6}s\n",
            inc.kind, inc.req, inc.tenant, inc.ts
        ));
        for e in &inc.history {
            out.push_str(&format!(
                "  +{:.6}s lane={} {:?}\n",
                e.ts, e.lane, e.kind
            ));
        }
    }
    out
}
