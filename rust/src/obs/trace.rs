//! Request-lifecycle trace recorder: a bounded ring buffer of typed,
//! fixed-size [`Event`] records plus a flight recorder that snapshots a
//! request's recent history on anomalies.
//!
//! Design constraints, in order:
//!
//!  1. **Free when off.** [`TraceRecorder::record`] is a single relaxed
//!     atomic load + branch when tracing is disabled — no lock, no clock
//!     read, no allocation. The decode hot loop records through this
//!     path every step, so "off" must cost nothing measurable.
//!  2. **Allocation-free when on.** `Event` is `Copy` with no heap
//!     payload, and the ring is pre-allocated to its full capacity at
//!     [`TraceRecorder::enable`] time; recording into it never
//!     allocates. Only the *flight recorder* (anomalies: rejects,
//!     swap refusals, recompute resumes, quota blocks) clones history,
//!     and anomalies are rare by construction.
//!  3. **Bounded.** The ring overwrites oldest-first and counts what it
//!     dropped; the incident list keeps the newest
//!     [`MAX_INCIDENTS`] entries.
//!
//! The recorder does not interpret events — [`validate_lifecycle`]
//! checks one request's stream against the serving state machine
//! (submit ≤ prefill ≤ admit ≤ decode ≤ finish, preempt/resume properly
//! nested), and `obs::export` renders streams as Chrome trace JSON.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::coordinator::paging::TenantId;

/// Lane value for events recorded while the request holds no store slot
/// (queued, parked, rejected before admission).
pub const NO_LANE: i32 = -1;

/// Events the flight recorder snapshots per incident (the "last K").
pub const FLIGHT_EVENTS: usize = 32;

/// Newest incidents retained by the flight recorder.
pub const MAX_INCIDENTS: usize = 32;

/// How a preempted lane will come back: restored bit-identical from the
/// host swap arena, or by re-running the policy prefill over
/// `prompt ++ generated` (the expensive fallback).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResumeMode {
    /// Blocks restored from the host swap arena (zero policy work).
    Swap,
    /// Re-prefill of `prompt ++ generated` (paid-for work re-done).
    Recompute,
}

/// One lifecycle transition with its typed payload. Every variant is
/// fixed-size and heap-free so [`Event`] stays `Copy`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EventKind {
    /// Request entered the scheduler queue.
    Submit {
        /// Prompt length in tokens.
        prompt_tokens: u32,
    },
    /// The admission gate skipped this request while its tenant was over
    /// quota (fair scanning stepped past it; it stays queued).
    QuotaDefer,
    /// Admission attempt deferred: the pool (or the swap-in) was
    /// momentarily too full; the request retries after decode frees
    /// blocks.
    AdmitDeferred,
    /// Policy prefill started (runs to the TSP layer, then selects).
    PrefillStart {
        /// Tokens fed to the prefill (`prompt ++ generated` on a
        /// recompute-resume).
        tokens: u32,
    },
    /// Policy prefill finished; the TSP-selected KV is materialized.
    PrefillEnd {
        /// Largest per-layer KV length retained after selection.
        kept_rows: u32,
    },
    /// One chunk of a chunked (resumable) prefill completed; the request
    /// stays in the prefilling state and decode rounds may interleave
    /// before the next chunk.
    PrefillChunk {
        /// Zero-based index of the completed chunk.
        index: u32,
        /// Valid tokens the chunk processed.
        tokens: u32,
    },
    /// The store accepted the request's cache into a lane.
    Admit {
        /// Pool blocks the lane holds right after admission.
        blocks_held: u32,
    },
    /// Sampled decode progress (recorded every N steps, not every step).
    DecodeStep {
        /// Absolute decode position of the lane.
        step: u32,
        /// Tokens generated so far.
        tokens_out: u32,
    },
    /// Block-granular compaction fired on this lane under pool pressure.
    Compact,
    /// Lane preempted under pool pressure; `mode` says how it will
    /// resume.
    Preempt {
        /// Resume path the preemption set up.
        mode: ResumeMode,
        /// Tokens generated before the preemption.
        generated: u32,
    },
    /// The preempted lane's KV was serialized to the host swap arena.
    SwapOut {
        /// Host bytes the swap entry occupies.
        bytes: u64,
    },
    /// A parked request came back (swap restore enters decode directly;
    /// recompute goes back through prefill).
    Resume {
        /// How the request resumed.
        mode: ResumeMode,
    },
    /// Request retired successfully; its lane was released.
    Finish {
        /// Tokens in the final response.
        tokens_out: u32,
    },
    /// Request failed permanently (cannot fit, prompt too long, prefill
    /// error).
    Reject,
}

/// One trace record. Fixed-size and `Copy` so recording into the
/// pre-allocated ring performs no heap allocation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event {
    /// Seconds since the recorder was enabled (monotonic clock).
    pub ts: f64,
    /// Request id.
    pub req: u64,
    /// Tenant the request is served under.
    pub tenant: TenantId,
    /// Store slot the request occupied when recorded, or [`NO_LANE`].
    pub lane: i32,
    /// The transition and its payload.
    pub kind: EventKind,
}

/// Anomaly class the flight recorder files an [`Incident`] under.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IncidentKind {
    /// Request rejected permanently.
    Reject,
    /// Preemption wanted to swap but the budget (or config) refused;
    /// the lane fell back to recompute-resume.
    SwapRefused,
    /// A prefill re-ran for a request that already paid for one.
    RecomputeResume,
    /// Admission skipped the request while its tenant was over quota.
    QuotaBlocked,
}

/// A flight-recorder report: the anomaly plus the request's last
/// [`FLIGHT_EVENTS`] trace events at the moment it happened.
#[derive(Debug, Clone)]
pub struct Incident {
    /// Anomaly class.
    pub kind: IncidentKind,
    /// Request the anomaly happened to.
    pub req: u64,
    /// Tenant of that request.
    pub tenant: TenantId,
    /// Seconds since the recorder was enabled.
    pub ts: f64,
    /// The request's recent events, oldest first.
    pub history: Vec<Event>,
}

#[derive(Debug)]
struct Ring {
    epoch: Instant,
    cap: usize,
    buf: Vec<Event>,
    /// Index of the oldest event once the ring has wrapped.
    head: usize,
    /// Events overwritten after the ring filled.
    dropped: u64,
    incidents: Vec<Incident>,
}

impl Ring {
    fn push(&mut self, ev: Event) {
        if self.buf.len() < self.cap {
            self.buf.push(ev);
        } else {
            self.buf[self.head] = ev;
            self.head = (self.head + 1) % self.cap;
            self.dropped += 1;
        }
    }

    /// Events oldest → newest.
    fn ordered(&self) -> impl Iterator<Item = &Event> {
        self.buf[self.head..].iter().chain(self.buf[..self.head].iter())
    }
}

/// Bounded ring buffer of lifecycle [`Event`]s plus the incident list.
/// Embedded in [`crate::metrics::Metrics`] so every function that
/// already takes a metrics handle can record events without a signature
/// change; disabled (and free) by default.
#[derive(Debug)]
pub struct TraceRecorder {
    enabled: AtomicBool,
    inner: Mutex<Ring>,
}

impl Default for TraceRecorder {
    fn default() -> Self {
        TraceRecorder {
            enabled: AtomicBool::new(false),
            inner: Mutex::new(Ring {
                epoch: Instant::now(),
                cap: 0,
                buf: Vec::new(),
                head: 0,
                dropped: 0,
                incidents: Vec::new(),
            }),
        }
    }
}

impl TraceRecorder {
    /// Turn tracing on with a ring of `capacity` events, pre-allocated
    /// here so [`TraceRecorder::record`] never allocates. Resets the
    /// clock epoch and any previously recorded events; `capacity == 0`
    /// leaves tracing off.
    pub fn enable(&self, capacity: usize) {
        let mut g = self.inner.lock().unwrap();
        g.epoch = Instant::now();
        g.cap = capacity;
        g.buf = Vec::with_capacity(capacity);
        g.head = 0;
        g.dropped = 0;
        g.incidents = Vec::new();
        drop(g);
        self.enabled.store(capacity > 0, Ordering::Release);
    }

    /// Whether [`TraceRecorder::record`] currently stores events. Callers
    /// use this to skip *payload computation* (e.g. a swap-bytes delta);
    /// `record` itself performs the same check.
    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Record one lifecycle transition. A relaxed load + branch when
    /// tracing is off; a lock + ring write (no allocation) when on.
    pub fn record(&self, req: u64, tenant: TenantId, lane: i32, kind: EventKind) {
        if !self.is_enabled() {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        let ts = g.epoch.elapsed().as_secs_f64();
        g.push(Event { ts, req, tenant, lane, kind });
    }

    /// Events currently in the ring (oldest first, ≤ capacity).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().buf.len()
    }

    /// Whether the ring holds no events.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events overwritten since the ring filled.
    pub fn dropped(&self) -> u64 {
        self.inner.lock().unwrap().dropped
    }

    /// Copy of the ring, oldest → newest.
    pub fn snapshot(&self) -> Vec<Event> {
        self.inner.lock().unwrap().ordered().copied().collect()
    }

    /// The last `k` events recorded for request `req`, oldest first.
    pub fn events_for(&self, req: u64, k: usize) -> Vec<Event> {
        let g = self.inner.lock().unwrap();
        let mut out: Vec<Event> =
            g.ordered().filter(|e| e.req == req).copied().collect();
        if out.len() > k {
            out.drain(..out.len() - k);
        }
        out
    }

    /// Flight-recorder hook: file an incident carrying the request's
    /// last [`FLIGHT_EVENTS`] events. No-op when tracing is off; keeps
    /// the newest [`MAX_INCIDENTS`] incidents. A repeat of the newest
    /// incident's `(kind, req)` is absorbed — a quota-blocked request is
    /// re-judged every admission scan, and one report per episode is
    /// what a human wants to read.
    pub fn incident(&self, kind: IncidentKind, req: u64, tenant: TenantId) {
        if !self.is_enabled() {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        if g.incidents.last().is_some_and(|l| l.kind == kind && l.req == req)
        {
            return;
        }
        let ts = g.epoch.elapsed().as_secs_f64();
        let mut history: Vec<Event> =
            g.ordered().filter(|e| e.req == req).copied().collect();
        if history.len() > FLIGHT_EVENTS {
            history.drain(..history.len() - FLIGHT_EVENTS);
        }
        if g.incidents.len() >= MAX_INCIDENTS {
            g.incidents.remove(0);
        }
        g.incidents.push(Incident { kind, req, tenant, ts, history });
    }

    /// Incidents filed so far (oldest first, ≤ [`MAX_INCIDENTS`]).
    pub fn incidents(&self) -> Vec<Incident> {
        self.inner.lock().unwrap().incidents.clone()
    }
}

/// Serving-lifecycle state for [`validate_lifecycle`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum LifeState {
    Start,
    Queued,
    Prefilling,
    Active,
    Parked,
    Done,
}

/// Check one request's event stream (as returned by
/// [`TraceRecorder::events_for`]) against the serving lifecycle
/// invariant:
///
///  * timestamps are non-decreasing;
///  * the stream starts with `Submit` and transitions follow the state
///    machine `Queued → (Prefilling →) Active → Done`, with
///    `Preempt`/`Resume` properly nested: a `Preempt` parks the request
///    and only a `Resume` (swap → straight back to decode, recompute →
///    back through prefill) or a `Reject` may follow for it;
///  * decode steps happen only while admitted, swap-outs only while
///    parked, and nothing follows `Finish`/`Reject`.
///
/// Returns `Err(description)` naming the first offending event.
pub fn validate_lifecycle(events: &[Event]) -> Result<(), String> {
    use EventKind as K;
    use LifeState as S;
    let mut state = S::Start;
    let mut last_ts = f64::NEG_INFINITY;
    for (i, ev) in events.iter().enumerate() {
        if ev.ts < last_ts {
            return Err(format!(
                "event {i} ({:?}) goes back in time: {} < {}",
                ev.kind, ev.ts, last_ts
            ));
        }
        last_ts = ev.ts;
        let bad = |state: S| {
            Err(format!(
                "event {i} ({:?}) illegal in state {state:?} for req {}",
                ev.kind, ev.req
            ))
        };
        state = match (state, &ev.kind) {
            (S::Start, K::Submit { .. }) => S::Queued,
            (S::Queued, K::QuotaDefer | K::AdmitDeferred) => S::Queued,
            (S::Parked, K::QuotaDefer | K::AdmitDeferred) => S::Parked,
            (S::Queued, K::PrefillStart { .. }) => S::Prefilling,
            (S::Prefilling, K::PrefillChunk { .. }) => S::Prefilling,
            // A chunking lane can be parked between chunks (it resumes
            // from the completed-chunk boundary — recompute-mode resume,
            // but with zero chunks re-run).
            (S::Prefilling, K::Preempt { .. }) => S::Parked,
            (S::Prefilling, K::PrefillEnd { .. }) => S::Queued,
            (S::Queued, K::Admit { .. }) => S::Active,
            (S::Active, K::DecodeStep { .. } | K::Compact) => S::Active,
            (S::Active, K::Preempt { .. }) => S::Parked,
            (S::Parked, K::SwapOut { .. }) => S::Parked,
            (S::Parked, K::Resume { mode: ResumeMode::Swap }) => S::Active,
            (S::Parked, K::Resume { mode: ResumeMode::Recompute }) => {
                S::Queued
            }
            (S::Active, K::Finish { .. }) => S::Done,
            (S::Queued | S::Prefilling | S::Parked, K::Reject) => S::Done,
            (s, _) => return bad(s),
        };
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(ts: f64, req: u64, kind: EventKind) -> Event {
        Event { ts, req, tenant: TenantId::DEFAULT, lane: NO_LANE, kind }
    }

    #[test]
    fn disabled_recorder_stores_nothing() {
        let tr = TraceRecorder::default();
        tr.record(1, TenantId::DEFAULT, NO_LANE, EventKind::Reject);
        tr.incident(IncidentKind::Reject, 1, TenantId::DEFAULT);
        assert!(tr.is_empty());
        assert!(tr.incidents().is_empty());
        assert!(!tr.is_enabled());
    }

    #[test]
    fn ring_wraps_oldest_first() {
        let tr = TraceRecorder::default();
        tr.enable(4);
        for i in 0..10u64 {
            tr.record(i, TenantId::DEFAULT, NO_LANE, EventKind::Reject);
        }
        let snap = tr.snapshot();
        assert_eq!(snap.len(), 4);
        assert_eq!(tr.dropped(), 6);
        let ids: Vec<u64> = snap.iter().map(|e| e.req).collect();
        assert_eq!(ids, vec![6, 7, 8, 9]);
        assert!(snap.windows(2).all(|w| w[0].ts <= w[1].ts));
    }

    #[test]
    fn events_for_keeps_last_k() {
        let tr = TraceRecorder::default();
        tr.enable(64);
        for i in 0..8u32 {
            tr.record(
                7,
                TenantId::DEFAULT,
                NO_LANE,
                EventKind::DecodeStep { step: i, tokens_out: i },
            );
            tr.record(9, TenantId::DEFAULT, NO_LANE, EventKind::QuotaDefer);
        }
        let evs = tr.events_for(7, 3);
        assert_eq!(evs.len(), 3);
        assert!(evs.iter().all(|e| e.req == 7));
        assert!(matches!(
            evs[2].kind,
            EventKind::DecodeStep { step: 7, .. }
        ));
    }

    #[test]
    fn lifecycle_accepts_preempt_resume_nesting() {
        use EventKind as K;
        let evs = vec![
            ev(0.0, 1, K::Submit { prompt_tokens: 8 }),
            ev(0.1, 1, K::PrefillStart { tokens: 8 }),
            ev(0.2, 1, K::PrefillEnd { kept_rows: 8 }),
            ev(0.3, 1, K::Admit { blocks_held: 4 }),
            ev(0.4, 1, K::DecodeStep { step: 9, tokens_out: 1 }),
            ev(0.5, 1, K::Preempt { mode: ResumeMode::Swap, generated: 1 }),
            ev(0.5, 1, K::SwapOut { bytes: 1024 }),
            ev(0.6, 1, K::AdmitDeferred),
            ev(0.7, 1, K::Resume { mode: ResumeMode::Swap }),
            ev(0.8, 1, K::DecodeStep { step: 10, tokens_out: 2 }),
            ev(0.9, 1, K::Finish { tokens_out: 3 }),
        ];
        validate_lifecycle(&evs).unwrap();
    }

    #[test]
    fn lifecycle_accepts_chunked_prefill_with_midway_park() {
        use EventKind as K;
        let evs = vec![
            ev(0.0, 1, K::Submit { prompt_tokens: 20 }),
            ev(0.1, 1, K::PrefillStart { tokens: 20 }),
            ev(0.2, 1, K::PrefillChunk { index: 0, tokens: 8 }),
            // parked between chunks to yield to a resuming lane
            ev(0.3, 1, K::Preempt { mode: ResumeMode::Recompute, generated: 0 }),
            ev(0.4, 1, K::Resume { mode: ResumeMode::Recompute }),
            ev(0.5, 1, K::PrefillStart { tokens: 20 }),
            ev(0.6, 1, K::PrefillChunk { index: 1, tokens: 8 }),
            ev(0.7, 1, K::PrefillChunk { index: 2, tokens: 4 }),
            ev(0.8, 1, K::PrefillEnd { kept_rows: 8 }),
            ev(0.9, 1, K::Admit { blocks_held: 4 }),
            ev(1.0, 1, K::Finish { tokens_out: 2 }),
        ];
        validate_lifecycle(&evs).unwrap();
        // a chunk may not arrive before PrefillStart
        let evs = vec![
            ev(0.0, 1, K::Submit { prompt_tokens: 20 }),
            ev(0.1, 1, K::PrefillChunk { index: 0, tokens: 8 }),
        ];
        assert!(validate_lifecycle(&evs).is_err());
    }

    #[test]
    fn lifecycle_rejects_disorder() {
        use EventKind as K;
        // decode before admission
        let evs = vec![
            ev(0.0, 1, K::Submit { prompt_tokens: 8 }),
            ev(0.1, 1, K::DecodeStep { step: 1, tokens_out: 1 }),
        ];
        assert!(validate_lifecycle(&evs).is_err());
        // resume without a preemption
        let evs = vec![
            ev(0.0, 1, K::Submit { prompt_tokens: 8 }),
            ev(0.1, 1, K::Resume { mode: ResumeMode::Swap }),
        ];
        assert!(validate_lifecycle(&evs).is_err());
        // time goes backwards
        let evs = vec![
            ev(1.0, 1, K::Submit { prompt_tokens: 8 }),
            ev(0.5, 1, K::PrefillStart { tokens: 8 }),
        ];
        assert!(validate_lifecycle(&evs).is_err());
    }
}
