//! Evaluation: answer scoring, suite runners, and paper-style reports.

pub mod runner;
pub mod report;

/// Character-level F1 between generated and reference answer bytes —
/// the analog of LongBench's token-F1 for our byte-level tasks.
pub fn char_f1(pred: &[u8], truth: &[u8]) -> f64 {
    if pred.is_empty() || truth.is_empty() {
        return if pred == truth { 1.0 } else { 0.0 };
    }
    let mut truth_counts = [0i32; 256];
    for &b in truth {
        truth_counts[b as usize] += 1;
    }
    let mut overlap = 0i32;
    let mut pred_counts = [0i32; 256];
    for &b in pred {
        pred_counts[b as usize] += 1;
    }
    for i in 0..256 {
        overlap += pred_counts[i].min(truth_counts[i]);
    }
    if overlap == 0 {
        return 0.0;
    }
    let p = overlap as f64 / pred.len() as f64;
    let r = overlap as f64 / truth.len() as f64;
    2.0 * p * r / (p + r)
}

/// Exact match (RULER/NIAH-style accuracy).
pub fn exact(pred: &[u8], truth: &[u8]) -> f64 {
    if pred == truth {
        1.0
    } else {
        0.0
    }
}

/// Normalized edit similarity (code tasks' Edit-Sim analog).
pub fn edit_sim(pred: &[u8], truth: &[u8]) -> f64 {
    let d = levenshtein(pred, truth);
    let m = pred.len().max(truth.len());
    if m == 0 {
        1.0
    } else {
        1.0 - d as f64 / m as f64
    }
}

pub fn levenshtein(a: &[u8], b: &[u8]) -> usize {
    let n = b.len();
    let mut prev: Vec<usize> = (0..=n).collect();
    let mut cur = vec![0usize; n + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let cost = if ca == cb { 0 } else { 1 };
            cur[j + 1] =
                (prev[j] + cost).min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[n]
}

/// The scoring metric each subtask uses (mirrors the paper's Table 5;
/// retrieval tasks score char-F1 — partial credit — because the tiny
/// build-time-trained substrate rarely emits byte-exact answers, and the
/// paper's claim structure is the *ranking* of methods, which F1 exposes
/// at much lower sample counts than exact match).
pub fn metric_for(task: &str) -> fn(&[u8], &[u8]) -> f64 {
    match task {
        "fn_return" => edit_sim,
        "passage_count" | "fwe" => exact,
        _ => char_f1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f1_basics() {
        assert_eq!(char_f1(b"abc", b"abc"), 1.0);
        assert_eq!(char_f1(b"", b""), 1.0);
        assert_eq!(char_f1(b"xyz", b"abc"), 0.0);
        let f = char_f1(b"ab", b"abcd");
        assert!((f - 2.0 * (1.0 * 0.5) / 1.5).abs() < 1e-9);
    }

    #[test]
    fn levenshtein_basics() {
        assert_eq!(levenshtein(b"kitten", b"sitting"), 3);
        assert_eq!(levenshtein(b"", b"abc"), 3);
        assert_eq!(levenshtein(b"abc", b"abc"), 0);
    }

    #[test]
    fn edit_sim_bounds() {
        assert_eq!(edit_sim(b"abc", b"abc"), 1.0);
        assert_eq!(edit_sim(b"", b""), 1.0);
        assert!(edit_sim(b"abcd", b"wxyz") <= 0.0 + 1e-9);
    }

    #[test]
    fn metric_dispatch() {
        // counting tasks are exact-match; retrieval tasks give partial
        // credit (char F1); code tasks use edit similarity
        assert_eq!(metric_for("passage_count")(b"3", b"3"), 1.0);
        assert_eq!(metric_for("passage_count")(b"34", b"3"), 0.0);
        assert!(metric_for("niah")(b"ab", b"a") > 0.0);
        assert!(metric_for("narrative_kv")(b"ab", b"abcd") > 0.0);
        assert!(metric_for("fn_return")(b"abc", b"abd") > 0.5);
    }
}
