//! Suite runners: drive (policy × workload) through the engine and
//! aggregate scores + latency, producing the rows of the paper's tables.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::coordinator::engine::{generate, GenStats};
use crate::coordinator::policies::{make_policy, Exec, PolicyCfg};
use crate::manifest::Manifest;
use crate::tokenizer::Tokenizer;
use crate::util::rng::Rng;
use crate::workload::{longbench, niah, ruler, Sample};

/// Aggregated outcome for one (policy, task, length) cell.
#[derive(Debug, Clone, Default)]
pub struct Cell {
    pub score_sum: f64,
    pub n: usize,
    pub prefill_secs: f64,
    pub decode_secs: f64,
    pub decode_steps: usize,
    pub compute_tokens: usize,
    pub full_compute_tokens: usize,
    pub cache_elems: usize,
    pub full_cache_elems: usize,
}

impl Cell {
    pub fn score(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            100.0 * self.score_sum / self.n as f64
        }
    }

    pub fn prefill_rate(&self) -> f64 {
        if self.full_compute_tokens == 0 {
            1.0
        } else {
            self.compute_tokens as f64 / self.full_compute_tokens as f64
        }
    }

    pub fn kv_rate(&self) -> f64 {
        if self.full_cache_elems == 0 {
            1.0
        } else {
            self.cache_elems as f64 / self.full_cache_elems as f64
        }
    }

    fn absorb(&mut self, score: f64, stats: &GenStats, layers: usize) {
        self.score_sum += score;
        self.n += 1;
        self.prefill_secs += stats.prefill_secs;
        self.decode_secs += stats.decode_secs;
        self.decode_steps += stats.decode_steps;
        self.compute_tokens += stats.compute_tokens;
        self.full_compute_tokens += layers * stats.prompt_tokens;
        self.cache_elems += stats.cache_elems;
        // full cache: prompt_tokens rows per layer
        self.full_cache_elems += 2 * layers * stats.prompt_tokens;
    }
}

pub struct EvalConfig {
    pub policy_cfg: PolicyCfg,
    pub samples_per_task: usize,
    pub max_new: usize,
    pub seed: u64,
}

/// Run one sample through a policy; returns (score, stats).
pub fn run_sample(
    ex: &dyn Exec,
    man: &Manifest,
    policy_name: &str,
    cfg: &PolicyCfg,
    sample: &Sample,
    max_new: usize,
) -> Result<(f64, GenStats)> {
    let tok = Tokenizer;
    let policy = make_policy(policy_name)?;
    let ids = tok.encode(&sample.prompt);
    let out = generate(ex, man, policy.as_ref(), cfg, &ids, max_new)?;
    let pred = tok.decode_answer(&out.tokens);
    let score = (crate::eval::metric_for(sample.task))(&pred, &sample.answer);
    Ok((score, out.stats))
}

/// LongBench-analog: per-category cells for one policy.
pub fn run_longbench(
    ex: &dyn Exec,
    man: &Manifest,
    policy: &str,
    ec: &EvalConfig,
    len: usize,
) -> Result<BTreeMap<String, Cell>> {
    let mut cells: BTreeMap<String, Cell> = BTreeMap::new();
    for (cat, subs) in longbench::CATEGORIES {
        for sub in *subs {
            let mut rng = Rng::new(ec.seed ^ hash_name(sub));
            for _ in 0..ec.samples_per_task {
                let s = longbench::sample(&mut rng, sub, len);
                let (score, stats) = run_sample(
                    ex, man, policy, &ec.policy_cfg, &s, ec.max_new,
                )?;
                cells
                    .entry(cat.to_string())
                    .or_default()
                    .absorb(score, &stats, man.model.n_layers);
            }
        }
    }
    Ok(cells)
}

/// RULER-analog: per-length average for one policy.
pub fn run_ruler(
    ex: &dyn Exec,
    man: &Manifest,
    policy: &str,
    ec: &EvalConfig,
    lengths: &[usize],
) -> Result<BTreeMap<usize, Cell>> {
    let mut cells: BTreeMap<usize, Cell> = BTreeMap::new();
    for &len in lengths {
        for task in ruler::TASKS {
            let mut rng = Rng::new(ec.seed ^ hash_name(task) ^ len as u64);
            for _ in 0..ec.samples_per_task {
                let s = ruler::sample(&mut rng, task, len);
                let (score, stats) = run_sample(
                    ex, man, policy, &ec.policy_cfg, &s, ec.max_new,
                )?;
                cells
                    .entry(len)
                    .or_default()
                    .absorb(score, &stats, man.model.n_layers);
            }
        }
    }
    Ok(cells)
}

/// NIAH grid: overall score + per-(len,depth) matrix for one policy.
pub fn run_niah(
    ex: &dyn Exec,
    man: &Manifest,
    policy: &str,
    ec: &EvalConfig,
    lengths: &[usize],
    depths: usize,
) -> Result<(Cell, Vec<(usize, f64, f64)>)> {
    let mut total = Cell::default();
    let mut grid_scores = Vec::new();
    for (len, depth) in niah::grid(lengths, depths) {
        let mut rng =
            Rng::new(ec.seed ^ (len as u64) ^ (depth * 1000.0) as u64);
        let mut cell = Cell::default();
        for _ in 0..ec.samples_per_task {
            let s = niah::sample(&mut rng, len, depth);
            let (score, stats) =
                run_sample(ex, man, policy, &ec.policy_cfg, &s, ec.max_new)?;
            cell.absorb(score, &stats, man.model.n_layers);
            total.absorb(score, &stats, man.model.n_layers);
        }
        grid_scores.push((len, depth, cell.score()));
    }
    Ok((total, grid_scores))
}

/// One row of the decode-budget accuracy sweep: suite scores at a
/// decode budget, with deltas against the unbudgeted baseline row
/// (`decode_budget == 0`, always first).
#[derive(Debug, Clone)]
pub struct BudgetPoint {
    /// `PolicyCfg::decode_budget` this row ran with (0 = baseline).
    pub decode_budget: usize,
    /// NIAH overall score (0-100).
    pub niah: f64,
    /// RULER average score across the swept lengths (0-100).
    pub ruler: f64,
    /// `niah - baseline.niah`.
    pub niah_delta: f64,
    /// `ruler - baseline.ruler`.
    pub ruler_delta: f64,
}

/// Decode-budget accuracy differential (SCOPE-style split budgets):
/// run NIAH + RULER with the same policy, samples, and seeds at each
/// decode budget and report score deltas against the unbudgeted
/// baseline, which is always run first and returned as row 0. Prefill
/// selection is identical across rows — only decode-phase eviction
/// differs — so a budget with slack reproduces the baseline streams
/// bit for bit (delta exactly 0) and tight budgets degrade gradually;
/// callers bound the deltas with their tolerance.
pub fn run_budget_sweep(
    ex: &dyn Exec,
    man: &Manifest,
    policy: &str,
    ec: &EvalConfig,
    budgets: &[usize],
    lengths: &[usize],
    depths: usize,
) -> Result<Vec<BudgetPoint>> {
    let mut points: Vec<BudgetPoint> = Vec::new();
    for &budget in std::iter::once(&0).chain(budgets.iter()) {
        if budget == 0 && !points.is_empty() {
            continue; // explicit 0 in the list duplicates the baseline
        }
        let mut cfg = ec.policy_cfg.clone();
        cfg.decode_budget = budget;
        let sub = EvalConfig {
            policy_cfg: cfg,
            samples_per_task: ec.samples_per_task,
            max_new: ec.max_new,
            seed: ec.seed,
        };
        let (niah_total, _) =
            run_niah(ex, man, policy, &sub, lengths, depths)?;
        let ruler_cells = run_ruler(ex, man, policy, &sub, lengths)?;
        let ruler = ruler_cells.values().map(|c| c.score()).sum::<f64>()
            / ruler_cells.len().max(1) as f64;
        let niah = niah_total.score();
        let (nb, rb) = points
            .first()
            .map(|p| (p.niah, p.ruler))
            .unwrap_or((niah, ruler));
        points.push(BudgetPoint {
            decode_budget: budget,
            niah,
            ruler,
            niah_delta: niah - nb,
            ruler_delta: ruler - rb,
        });
    }
    Ok(points)
}

fn hash_name(s: &str) -> u64 {
    // FNV-1a
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cell_aggregation() {
        let mut c = Cell::default();
        let stats = GenStats {
            prefill_secs: 0.5,
            decode_secs: 1.0,
            decode_steps: 10,
            prompt_tokens: 100,
            compute_tokens: 480,
            cache_elems: 200,
            decode_cap: 128,
            ..Default::default()
        };
        c.absorb(1.0, &stats, 8);
        c.absorb(0.0, &stats, 8);
        assert_eq!(c.score(), 50.0);
        assert!((c.prefill_rate() - 480.0 / 800.0).abs() < 1e-9);
        assert!((c.kv_rate() - 200.0 / 1600.0).abs() < 1e-9);
    }

    #[test]
    fn name_hash_distinct() {
        assert_ne!(hash_name("a"), hash_name("b"));
    }
}
