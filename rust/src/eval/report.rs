//! Paper-style table renderers (markdown) for the eval/bench CLIs.

use std::fmt::Write as _;

/// Render a markdown table.
pub fn table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> =
        headers.iter().map(|h| h.len()).collect();
    for r in rows {
        for (i, c) in r.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(c.len());
            }
        }
    }
    let mut out = String::new();
    let line = |out: &mut String, cells: &[String]| {
        let _ = write!(out, "|");
        for (i, c) in cells.iter().enumerate() {
            let w = widths.get(i).copied().unwrap_or(c.len());
            let _ = write!(out, " {c:w$} |");
        }
        let _ = writeln!(out);
    };
    line(
        &mut out,
        &headers.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
    );
    let sep: Vec<String> =
        widths.iter().map(|w| "-".repeat(*w)).collect();
    line(&mut out, &sep);
    for r in rows {
        line(&mut out, r);
    }
    out
}

pub fn pct(x: f64) -> String {
    format!("{:.0}%", 100.0 * x)
}

pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn f1(x: f64) -> String {
    format!("{x:.1}")
}

pub fn ms(secs: f64) -> String {
    format!("{:.1}", secs * 1e3)
}

/// Pretty method names matching the paper's tables.
pub fn method_label(name: &str) -> &'static str {
    match name {
        "full" => "Full-context",
        "streaming_llm" => "StreamingLLM",
        "h2o" => "H2O",
        "snapkv" => "SnapKV",
        "gemfilter" => "GemFilter",
        "pyramid_infer" => "PyramidInfer",
        "fastkv" => "FastKV",
        _ => "?",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_table() {
        let t = table(
            &["Method", "Score"],
            &[
                vec!["FastKV".into(), "48.4".into()],
                vec!["Full-context".into(), "50.1".into()],
            ],
        );
        assert!(t.contains("| FastKV"));
        assert!(t.contains("| Method"));
        assert_eq!(t.lines().count(), 4);
    }

    #[test]
    fn formats() {
        assert_eq!(pct(0.6), "60%");
        assert_eq!(f2(1.234), "1.23");
        assert_eq!(ms(0.0123), "12.3");
        assert_eq!(method_label("fastkv"), "FastKV");
    }
}
