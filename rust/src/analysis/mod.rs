//! Motivation & mechanism analyses (Fig. 1a, Fig. 1b, Fig. 3).
//!
//! All operate on score summaries / hidden states fetched from the
//! prefill artifacts; the math here is what the paper Section 3 plots.

use crate::tensor::normalized_l2;

/// Fig. 1(a): overlap ratio of the top-k critical tokens between layer
/// pairs at a given layer distance, split by the anchor layer.
///
/// `acc`: [L, H, N] accumulated attention mass; criticality of token i at
/// layer l = mean over heads of acc[l, :, i] (the paper's "highest average
/// attention mass across heads").
pub fn critical_sets(
    acc: &crate::tensor::HostTensor,
    n_valid: usize,
    top_k: usize,
) -> Vec<Vec<usize>> {
    let l = acc.shape[0];
    let h = acc.shape[1];
    let n = acc.shape[2];
    (0..l)
        .map(|li| {
            let mean = crate::coordinator::selection::head_mean(
                acc.row(li),
                h,
                n,
            );
            crate::coordinator::selection::top_k_with_forced(
                &mean,
                n_valid,
                top_k.min(n_valid),
                &[],
            )
        })
        .collect()
}

/// Overlap |A ∩ B| / |A| of two sorted index sets.
pub fn overlap(a: &[usize], b: &[usize]) -> f64 {
    if a.is_empty() {
        return 0.0;
    }
    let bset: std::collections::BTreeSet<usize> = b.iter().copied().collect();
    let inter = a.iter().filter(|x| bset.contains(x)).count();
    inter as f64 / a.len() as f64
}

/// Mean overlap at each layer distance, separately for anchors below and
/// at/above the split layer. Returns (distance, early_mean, late_mean).
pub fn overlap_by_distance(
    sets: &[Vec<usize>],
    split: usize,
) -> Vec<(usize, f64, f64)> {
    let l = sets.len();
    let mut out = Vec::new();
    for d in 1..l {
        let mut early = Vec::new();
        let mut late = Vec::new();
        for a in 0..l - d {
            let o = overlap(&sets[a], &sets[a + d]);
            if a < split {
                early.push(o);
            } else {
                late.push(o);
            }
        }
        let em = if early.is_empty() {
            f64::NAN
        } else {
            crate::util::mean_std(&early).0
        };
        let lm = if late.is_empty() {
            f64::NAN
        } else {
            crate::util::mean_std(&late).0
        };
        out.push((d, em, lm));
    }
    out
}

/// Fig. 1(b): top-K attention recall — the fraction of total attention
/// mass captured by the K most-attended tokens, per layer.
pub fn topk_recall(
    acc: &crate::tensor::HostTensor,
    n_valid: usize,
    k: usize,
) -> Vec<f64> {
    let l = acc.shape[0];
    let h = acc.shape[1];
    let n = acc.shape[2];
    (0..l)
        .map(|li| {
            let mean = crate::coordinator::selection::head_mean(
                acc.row(li),
                h,
                n,
            );
            let valid = &mean[..n_valid.min(n)];
            let total: f64 = valid.iter().map(|&x| x as f64).sum();
            if total <= 0.0 {
                return 0.0;
            }
            let mut sorted: Vec<f32> = valid.to_vec();
            sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let top: f64 = sorted
                .iter()
                .take(k)
                .map(|&x| x as f64)
                .sum();
            top / total
        })
        .collect()
}

/// Fig. 3 metric: normalized L2 distance between final hidden states.
pub fn hidden_distance(full: &[f32], variant: &[f32]) -> f64 {
    normalized_l2(full, variant)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::HostTensor;

    #[test]
    fn overlap_basics() {
        assert_eq!(overlap(&[1, 2, 3], &[2, 3, 4]), 2.0 / 3.0);
        assert_eq!(overlap(&[], &[1]), 0.0);
        assert_eq!(overlap(&[5], &[5]), 1.0);
    }

    #[test]
    fn overlap_by_distance_shape() {
        let sets = vec![
            vec![0, 1],
            vec![0, 1],
            vec![2, 3],
            vec![2, 3],
        ];
        let rows = overlap_by_distance(&sets, 2);
        assert_eq!(rows.len(), 3);
        // distance 1: anchors 0,1,2 -> early = anchors 0,1 (1.0, 0.0)
        let (d, em, lm) = rows[0];
        assert_eq!(d, 1);
        assert!((em - 0.5).abs() < 1e-9);
        assert!((lm - 1.0).abs() < 1e-9);
    }

    #[test]
    fn recall_concentrated_vs_uniform() {
        // layer 0: all mass on token 0; layer 1: uniform
        let n = 10;
        let mut data = vec![0.0f32; 2 * n];
        data[0] = 1.0;
        for i in 0..n {
            data[n + i] = 0.1;
        }
        let acc = HostTensor::new(vec![2, 1, n], data);
        let r = topk_recall(&acc, n, 1);
        assert!(r[0] > 0.99);
        assert!((r[1] - 0.1).abs() < 1e-6);
    }

    #[test]
    fn critical_sets_pick_heavy_tokens() {
        let n = 6;
        let mut data = vec![0.0f32; n];
        data[2] = 5.0;
        data[4] = 3.0;
        let acc = HostTensor::new(vec![1, 1, n], data);
        let sets = critical_sets(&acc, n, 2);
        assert_eq!(sets[0], vec![2, 4]);
    }
}
