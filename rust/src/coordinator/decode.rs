//! The decode planner/stepper shared by the single-request engine and the
//! continuous-batching server.
//!
//! Before this module, `engine.rs::generate` and `server.rs` each carried
//! their own decode loop: densify the whole KV store (`KvStore::stage`),
//! run the `decode_{B}x{C}` artifact, append, argmax, handle END. Both now
//! drive a [`DecodeBatch`]:
//!
//!  * [`DecodeBatch::step`] plans one batched decode step. When the store
//!    exposes a block-table [`DecodeView`] and the manifest carries the
//!    matching `decode_paged_{B}x{C}` artifact, the inputs are the block
//!    slab (device-pinned per store — see `Runtime::run_with_pinned`)
//!    plus table indices and lens: O(referenced blocks) planning work per
//!    token, with the slab materialized only when its version went stale
//!    (see the paging README for what that costs until buffer donation
//!    lands). Otherwise it falls back to the dense staged bridge
//!    (`decode_{B}x{C}`), which remains available behind
//!    `PagingConfig::dense_staging` and for the flat arena.
//!  * [`advance_lane`] applies one lane's slice of the outputs: append the
//!    new KV row (block-compacting under pool pressure when a
//!    [`CompactSpec`] is supplied), then sample the next token.
//!
//! Policy-level reactions stay with the callers: the engine stops on any
//! exhaustion (recording `truncated_by_capacity`), the server preempts.

use anyhow::Result;

use crate::coordinator::paging::{AppendResult, KvStore};
use crate::coordinator::policies::{Exec, PolicyCfg};
use crate::manifest::{decode_artifact_name, decode_paged_artifact_name, Manifest};
use crate::metrics::Metrics;
use crate::runtime::outputs::DecodeOut;
use crate::runtime::{In, PinnedInput};
use crate::tensor::HostTensorI32;
use crate::tokenizer::END;

/// One active lane's contribution to a batched decode step.
#[derive(Debug, Clone, Copy)]
pub struct LaneInput {
    pub slot: usize,
    /// Token being decoded this step.
    pub token: i32,
    /// Absolute position of that token.
    pub pos: usize,
}

/// Which input ABI a step used.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecodePath {
    /// Block-table-native: slab + tables + lens (`decode_paged_{B}x{C}`).
    BlockTable,
    /// Dense staging bridge (`decode_{B}x{C}`).
    Staged,
}

#[derive(Debug, Clone)]
struct PagedArtifact {
    name: String,
    /// Static pool bucket `nb` of the artifact's slab inputs.
    pool_blocks: usize,
    /// Static tokens-per-block the artifact was compiled for.
    block_tokens: usize,
    /// Static table width `mb = ceil(cap / block_tokens)`.
    max_blocks: usize,
}

impl PagedArtifact {
    /// Whether a store's live view fits this artifact's static shapes.
    fn accepts(&self, view: &crate::coordinator::paging::DecodeView<'_>, cap: usize) -> bool {
        view.block_tokens == self.block_tokens
            && view.num_blocks <= self.pool_blocks
            && view.max_blocks <= self.max_blocks
            && view.capacity == cap
    }
}

/// Plans batched decode steps for one `(batch, capacity)` bucket.
#[derive(Debug, Clone)]
pub struct DecodeBatch {
    b: usize,
    cap: usize,
    dense: String,
    paged: Option<PagedArtifact>,
}

impl DecodeBatch {
    /// Resolve the artifact family for a `(batch, capacity)` bucket. The
    /// paged artifact is optional: older artifact dirs without it simply
    /// keep the staged path.
    pub fn new(man: &Manifest, b: usize, cap: usize) -> DecodeBatch {
        let paged_name = decode_paged_artifact_name(b, cap);
        let paged = man.artifacts.get(&paged_name).map(|meta| {
            let bt = meta.block_tokens.max(1);
            PagedArtifact {
                name: paged_name,
                pool_blocks: meta.pool_blocks,
                block_tokens: bt,
                max_blocks: (cap + bt - 1) / bt,
            }
        });
        DecodeBatch { b, cap, dense: decode_artifact_name(b, cap), paged }
    }

    pub fn batch(&self) -> usize {
        self.b
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// The path [`DecodeBatch::step`] will take for this store.
    pub fn path_for(&self, store: &dyn KvStore) -> DecodePath {
        match (&self.paged, store.decode_view()) {
            (Some(art), Some(view)) if art.accepts(&view, self.cap) => {
                DecodePath::BlockTable
            }
            _ => DecodePath::Staged,
        }
    }

    /// Artifact name the next step will execute (for logs / warmup).
    pub fn artifact_for(&self, store: &dyn KvStore) -> &str {
        match self.path_for(store) {
            DecodePath::BlockTable => {
                &self.paged.as_ref().expect("paged artifact").name
            }
            DecodePath::Staged => &self.dense,
        }
    }

    /// Run one batched decode step over `lanes`. Idle slots decode a
    /// dummy token 0 at position 0 whose outputs are simply never applied
    /// (same contract the server loop always had).
    pub fn step(
        &self,
        ex: &dyn Exec,
        store: &dyn KvStore,
        lanes: &[LaneInput],
        metrics: Option<&Metrics>,
    ) -> Result<DecodeOut> {
        let b = self.b;
        let mut toks = vec![0i32; b];
        let mut poss = vec![0i32; b];
        for lane in lanes {
            toks[lane.slot] = lane.token;
            poss[lane.slot] = lane.pos as i32;
        }
        let toks = HostTensorI32::new(vec![b], toks);
        let poss = HostTensorI32::new(vec![b], poss);

        // Build the view once; it decides the path and feeds the inputs.
        let view = store.decode_view();
        let paged = match (&self.paged, &view) {
            (Some(art), Some(v)) if art.accepts(v, self.cap) => Some(art),
            _ => None,
        };
        let out = match paged {
            Some(art) => {
                let view = view.expect("checked above");
                // Slab planes are pinned on device per store (the store id
                // rides in the key, so two stores sharing one executor
                // never thrash or race each other's slot). The O(pool)
                // materialization below is skipped only when the slab is
                // unchanged since the last upload; appends change it every
                // generated token, so on the current pure-AOT ABI the
                // re-upload per step remains — deleting it needs PJRT
                // buffer donation (ROADMAP). What this path removes today
                // is the host-side cost: the dense densify/clone and the
                // incremental staging double-write.
                let sid = view.version >> 32;
                let k_key = format!("decode_slab_k:{sid:x}");
                let v_key = format!("decode_slab_v:{sid:x}");
                let current = ex.pinned_is_current(&k_key, view.version)
                    && ex.pinned_is_current(&v_key, view.version);
                let inputs = vec![
                    In::I32(toks),
                    In::I32(poss),
                    In::I32(view.tables_tensor(art.max_blocks)),
                    In::I32(view.lens_tensor()),
                ];
                if let Some(m) = metrics {
                    m.inc("decode_steps_block_table", 1);
                }
                let materialize = |v: &crate::coordinator::paging::DecodeView<'_>| {
                    let (sk, sv) = v.slab_tensors(art.pool_blocks);
                    vec![
                        PinnedInput::new(2, &k_key, v.version, sk),
                        PinnedInput::new(3, &v_key, v.version, sv),
                    ]
                };
                if current {
                    let cached = vec![
                        PinnedInput::cached(2, &k_key, view.version),
                        PinnedInput::cached(3, &v_key, view.version),
                    ];
                    match ex.run_pinned(&art.name, cached, inputs.clone()) {
                        Ok(r) => r,
                        // The residency check can race an LRU eviction on
                        // a shared executor; retry with payloads ONLY for
                        // that specific miss (`Runtime::run_with_pinned`'s
                        // "not resident" error) — any other failure is a
                        // genuine execution error and must surface as-is,
                        // not be masked by a silent re-execution.
                        Err(e) if format!("{e:#}").contains("is not resident") => {
                            ex.run_pinned(&art.name, materialize(&view), inputs)?
                        }
                        Err(e) => return Err(e),
                    }
                } else {
                    ex.run_pinned(&art.name, materialize(&view), inputs)?
                }
            }
            None => {
                let staged = store.stage();
                if let Some(m) = metrics {
                    m.inc("decode_steps_staged", 1);
                }
                ex.run(
                    &self.dense,
                    vec![
                        In::I32(toks),
                        In::I32(poss),
                        staged.k.into(),
                        staged.v.into(),
                        staged.lens.into(),
                    ],
                )?
            }
        };
        Ok(DecodeOut::from_vec(out))
    }
}

/// Compaction reaction to pool pressure during [`advance_lane`]: the
/// policy's per-layer keep-sets drive block-granular eviction before the
/// append is retried.
pub struct CompactSpec<'a> {
    pub policy_cfg: &'a PolicyCfg,
    /// Shrink factor per layer (`server::COMPACT_SHRINK`).
    pub shrink: f64,
    pub window: usize,
    pub metrics: Option<&'a Metrics>,
}

/// Per-lane outcome of applying one decode step's outputs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LaneAdvance {
    /// KV appended and the next token sampled; `ended` flags END.
    Next { token: i32, ended: bool },
    /// The lane hit its staging capacity `C`; generation must stop.
    CapacityStop,
    /// The block pool cannot grow the lane (even after compaction, when a
    /// [`CompactSpec`] was supplied); the caller decides preemption.
    PoolPressure,
}

/// Apply one lane's slice of a decode step's outputs: append the new KV
/// row (compacting under pressure if `compact` is given), then sample the
/// next token from the lane's logits row.
pub fn advance_lane(
    store: &mut dyn KvStore,
    slot: usize,
    out: &DecodeOut,
    compact: Option<&CompactSpec<'_>>,
) -> LaneAdvance {
    let mut res = store.append(slot, &out.k_new, &out.v_new);
    if res == AppendResult::PoolExhausted {
        if let Some(spec) = compact {
            let lens = store.layer_lens(slot);
            let keep = spec.policy_cfg.compaction_keep(
                &lens,
                spec.shrink,
                spec.window,
            );
            if store.compact(slot, &keep) > 0 {
                if let Some(m) = spec.metrics {
                    m.inc("compactions", 1);
                }
                res = store.append(slot, &out.k_new, &out.v_new);
            }
        }
    }
    match res {
        AppendResult::Ok => {
            let logits = out.logits.row(slot);
            let token = logits
                .iter()
                .enumerate()
                .max_by(|x, y| x.1.partial_cmp(y.1).unwrap())
                .map(|(i, _)| i as i32)
                .unwrap_or(0);
            LaneAdvance::Next { token, ended: token == END as i32 }
        }
        AppendResult::CapacityExhausted => LaneAdvance::CapacityStop,
        AppendResult::PoolExhausted => LaneAdvance::PoolPressure,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::kvcache::RequestCache;
    use crate::coordinator::paging::{PagedArena, PagingConfig};
    use crate::manifest::{ArtifactMeta, Buckets, Manifest, ModelMeta, TensorSig};
    use crate::tensor::HostTensor;
    use std::collections::BTreeMap;

    fn meta() -> ModelMeta {
        ModelMeta {
            vocab_size: 8,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 2,
            head_dim: 2,
            tsp_layer: 1,
            window: 2,
            pool_kernel: 3,
            max_train_len: 64,
        }
    }

    fn manifest(with_paged: bool) -> Manifest {
        let mut artifacts = BTreeMap::new();
        let mk = |name: &str, kind: &str, pool_blocks, block_tokens| ArtifactMeta {
            name: name.to_string(),
            file: format!("{name}.hlo.txt"),
            kind: kind.to_string(),
            n: 0,
            batch: 1,
            cap: 8,
            tsp_layer: 1,
            pool_blocks,
            block_tokens,
            inputs: Vec::<TensorSig>::new(),
            outputs: Vec::new(),
        };
        artifacts.insert(
            "decode_1x8".to_string(),
            mk("decode_1x8", "decode", 0, 0),
        );
        if with_paged {
            artifacts.insert(
                "decode_paged_1x8".to_string(),
                mk("decode_paged_1x8", "decode_paged", 8, 2),
            );
        }
        Manifest {
            dir: std::path::PathBuf::from("/tmp"),
            model: meta(),
            n_params: 1,
            kernel: "jnp".into(),
            buckets: Buckets {
                prefill_ns: vec![64],
                stage1_ns: vec![64],
                stage2_ns: vec![64],
                pyramid_ns: vec![64],
                decode_batches: vec![1],
                decode_caps: vec![8],
                sweep_n: 64,
                sweep_nt: 16,
                pallas_n: 64,
                max_gen: 8,
                block_tokens: 2,
            },
            artifacts,
        }
    }

    fn store() -> PagedArena {
        let m = meta();
        let cfg = PagingConfig { block_tokens: 2, ..Default::default() };
        let mut pa = PagedArena::new(&m, 1, 8, cfg);
        let mut rc = RequestCache::new(&m);
        let re = 4;
        for l in 0..2 {
            rc.k[l] = (0..3 * re).map(|i| i as f32).collect();
            rc.v[l] = (0..3 * re).map(|i| -(i as f32)).collect();
            rc.lens[l] = 3;
        }
        PagedArena::admit(&mut pa, &rc).unwrap();
        pa
    }

    #[test]
    fn picks_block_table_path_when_artifact_and_view_align() {
        let pa = store();
        let batch = DecodeBatch::new(&manifest(true), 1, 8);
        assert_eq!(batch.path_for(&pa), DecodePath::BlockTable);
        assert_eq!(batch.artifact_for(&pa), "decode_paged_1x8");
    }

    #[test]
    fn falls_back_without_paged_artifact_or_on_mismatch() {
        let pa = store();
        let batch = DecodeBatch::new(&manifest(false), 1, 8);
        assert_eq!(batch.path_for(&pa), DecodePath::Staged);
        assert_eq!(batch.artifact_for(&pa), "decode_1x8");

        // block-size mismatch between store and artifact -> staged
        let m = meta();
        let cfg = PagingConfig { block_tokens: 4, ..Default::default() };
        let other = PagedArena::new(&m, 1, 8, cfg);
        let batch = DecodeBatch::new(&manifest(true), 1, 8);
        assert_eq!(batch.path_for(&other), DecodePath::Staged);
    }

    #[test]
    fn dense_staging_flag_forces_staged_path() {
        let m = meta();
        let cfg = PagingConfig {
            block_tokens: 2,
            dense_staging: true,
            ..Default::default()
        };
        let pa = PagedArena::new(&m, 1, 8, cfg);
        let batch = DecodeBatch::new(&manifest(true), 1, 8);
        assert_eq!(batch.path_for(&pa), DecodePath::Staged);
        assert_eq!(batch.artifact_for(&pa), "decode_1x8");
    }

    #[test]
    fn advance_lane_appends_and_samples() {
        let mut pa = store();
        let logits = HostTensor::new(
            vec![1, 8],
            vec![0.0, 0.1, 3.0, 0.2, 0.0, 0.0, 0.0, 0.0],
        );
        let k_new = HostTensor::new(vec![2, 1, 2, 2], vec![7.0; 8]);
        let out = DecodeOut {
            logits,
            k_new: k_new.clone(),
            v_new: k_new,
        };
        match advance_lane(&mut pa, 0, &out, None) {
            LaneAdvance::Next { token, ended } => {
                assert_eq!(token, 2);
                assert!(!ended);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(pa.layer_lens(0), vec![4, 4]);
    }

    #[test]
    fn advance_lane_reports_capacity() {
        let m = meta();
        let cfg = PagingConfig { block_tokens: 2, ..Default::default() };
        let mut pa = PagedArena::new(&m, 1, 2, cfg);
        let mut rc = RequestCache::new(&m);
        for l in 0..2 {
            rc.k[l] = vec![1.0; 2 * 4];
            rc.v[l] = vec![1.0; 2 * 4];
            rc.lens[l] = 2;
        }
        let slot = PagedArena::admit(&mut pa, &rc).unwrap();
        let t = HostTensor::zeros(vec![2, 1, 2, 2]);
        let out = DecodeOut {
            logits: HostTensor::zeros(vec![1, 8]),
            k_new: t.clone(),
            v_new: t,
        };
        assert_eq!(
            advance_lane(&mut pa, slot, &out, None),
            LaneAdvance::CapacityStop
        );
    }
}
