//! The decode planner/stepper shared by the single-request engine and the
//! continuous-batching server.
//!
//! Before this module, `engine.rs::generate` and `server.rs` each carried
//! their own decode loop: densify the whole KV store (`KvStore::stage`),
//! run the `decode_{B}x{C}` artifact, append, argmax, handle END. Both now
//! drive a [`DecodeBatch`]:
//!
//!  * [`DecodeBatch::step`] plans one batched decode step. Path ladder,
//!    best first:
//!    1. **sharded block-table** (`decode_paged_shard_{B}x{C}s{S}`) when
//!       the store's slab is KV-head-sharded and the manifest carries the
//!       matching artifact: inputs are S per-shard slab pairs — each
//!       pinned under its own key/version so only the shards whose plane
//!       mutated re-upload ([`stale_shards`]) — plus the shared tables and
//!       lens; outputs are per-shard `k_new`/`v_new` head slices that the
//!       host-side combiner ([`combine_head_shards`]) reassembles;
//!    2. **quantized block-table** (`decode_paged_q8_{B}x{C}`) when the
//!       store's slab codec is int8 and the manifest carries the q8
//!       artifact: the quantized planes + per-row scales upload as four
//!       pinned tensors (~4x fewer slab bytes than the f32 pair) and the
//!       artifact dequantizes in-HLO; an int8 store *without* the q8
//!       artifact decodes through the plain paged family — the view
//!       dequantizes host-side at pinned upload, so correctness never
//!       depends on the artifact being present;
//!    3. **block-table** (`decode_paged_{B}x{C}`): the whole slab pinned
//!       as one pair, O(referenced blocks) planning work per token;
//!    4. **dense staged bridge** (`decode_{B}x{C}`), kept behind
//!       `PagingConfig::dense_staging` and for the flat arena.
//!  * [`advance_lane`] applies one lane's slice of the outputs: append the
//!    new KV row (block-compacting under pool pressure when a
//!    [`CompactSpec`] is supplied), then sample the next token.
//!
//! Steady-state input prep reuses caller-owned buffers: both serving
//! loops own a [`DecodeScratch`] whose tensors are refilled in place
//! each step (`Exec::run_pinned_ref` borrows them; only executors that
//! cross a thread boundary fall back to cloning). The one remaining
//! per-step allocation is the store's own `decode_view()` build
//! (O(referenced blocks) tables/lens Vecs) — the planner itself adds
//! none.
//!
//! Policy-level reactions stay with the callers: the engine stops on any
//! exhaustion (recording `truncated_by_capacity`), the server preempts.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::paging::{
    AppendResult, DecodeBudget, DecodeView, KvCodec, KvStore,
};
use crate::coordinator::policies::{Exec, PolicyCfg};
use crate::manifest::{
    decode_artifact_name, decode_paged_artifact_name,
    decode_paged_q8_artifact_name, decode_paged_shard_artifact_name, Manifest,
};
use crate::metrics::{names, Metrics};
use crate::runtime::outputs::DecodeOut;
use crate::runtime::{In, PinnedInput};
use crate::tensor::{HostTensor, HostTensorI32};
use crate::tokenizer::END;

/// One active lane's contribution to a batched decode step.
#[derive(Debug, Clone, Copy)]
pub struct LaneInput {
    pub slot: usize,
    /// Token being decoded this step.
    pub token: i32,
    /// Absolute position of that token.
    pub pos: usize,
}

/// Which input ABI a step used.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecodePath {
    /// KV-head-sharded block tables: S per-shard slab pairs + shared
    /// tables/lens (`decode_paged_shard_{B}x{C}s{S}`).
    Sharded,
    /// Quantized block tables: int8 slab planes + per-row scales + shared
    /// tables/lens, dequantized in-HLO (`decode_paged_q8_{B}x{C}`).
    BlockTableQ8,
    /// Block-table-native: slab + tables + lens (`decode_paged_{B}x{C}`).
    BlockTable,
    /// Dense staging bridge (`decode_{B}x{C}`).
    Staged,
}

#[derive(Debug, Clone)]
struct PagedArtifact {
    name: String,
    /// Static pool bucket `nb` of the artifact's slab inputs.
    pool_blocks: usize,
    /// Static tokens-per-block the artifact was compiled for.
    block_tokens: usize,
    /// Static table width `mb = ceil(cap / block_tokens)`.
    max_blocks: usize,
}

/// Shared shape-compatibility rule: whether a store's live view fits an
/// artifact's static block/pool/table/capacity buckets (the shard checks
/// ride on top for the sharded family).
fn view_fits(
    view: &DecodeView<'_>,
    cap: usize,
    block_tokens: usize,
    pool_blocks: usize,
    max_blocks: usize,
) -> bool {
    view.block_tokens == block_tokens
        && view.num_blocks <= pool_blocks
        && view.max_blocks <= max_blocks
        && view.capacity == cap
}

impl PagedArtifact {
    /// Whether a store's live view fits this artifact's static shapes.
    fn accepts(&self, view: &DecodeView<'_>, cap: usize) -> bool {
        view_fits(view, cap, self.block_tokens, self.pool_blocks, self.max_blocks)
    }
}

#[derive(Debug, Clone)]
struct ShardArtifact {
    name: String,
    /// Static pool bucket `nb` of each per-shard slab input.
    pool_blocks: usize,
    block_tokens: usize,
    max_blocks: usize,
    /// KV-head shard count `S` the artifact was compiled for.
    shards: usize,
    /// KV heads per shard (`KV / S`).
    shard_kv_heads: usize,
}

impl ShardArtifact {
    fn accepts(&self, view: &DecodeView<'_>, cap: usize) -> bool {
        view_fits(view, cap, self.block_tokens, self.pool_blocks, self.max_blocks)
            && view.shards == self.shards
            && self.shard_kv_heads * self.shards == view.kv_heads
    }
}

/// Plans batched decode steps for one `(batch, capacity)` bucket.
#[derive(Debug, Clone)]
pub struct DecodeBatch {
    b: usize,
    cap: usize,
    dense: String,
    paged: Option<PagedArtifact>,
    /// Quantized twin of `paged` (same slab/table buckets; the slab
    /// inputs are int8 planes + per-row scales, dequantized in-HLO).
    paged_q8: Option<PagedArtifact>,
    /// Sharded artifact per shard count `S` (from the manifest's
    /// `shard_counts` bucket).
    sharded: BTreeMap<usize, ShardArtifact>,
    /// Fine decode-budget stage ([`PolicyCfg::decode_budget_spec`]):
    /// when set, every step consumes the store's *budget-pruned* view —
    /// cold generated blocks dropped from the per-lane tables before the
    /// gather artifact sees them. `None` (the default) is the unbudgeted
    /// planner, bit-identical to the pre-budget behavior.
    budget: Option<DecodeBudget>,
}

/// Outcome of artifact resolution for one step, best path first.
#[derive(Clone, Copy)]
enum Resolved<'a> {
    Shard(&'a ShardArtifact),
    Q8(&'a PagedArtifact),
    Paged(&'a PagedArtifact),
    Staged,
}

impl DecodeBatch {
    /// Resolve the artifact family for a `(batch, capacity)` bucket. The
    /// paged and sharded artifacts are optional: older artifact dirs
    /// without them simply keep the staged (resp. unsharded) path.
    pub fn new(man: &Manifest, b: usize, cap: usize) -> DecodeBatch {
        let mk_paged = |name: String| {
            man.artifacts.get(&name).map(|meta| {
                let bt = meta.block_tokens.max(1);
                PagedArtifact {
                    name,
                    pool_blocks: meta.pool_blocks,
                    block_tokens: bt,
                    max_blocks: (cap + bt - 1) / bt,
                }
            })
        };
        let paged = mk_paged(decode_paged_artifact_name(b, cap));
        let paged_q8 = mk_paged(decode_paged_q8_artifact_name(b, cap));
        let mut sharded = BTreeMap::new();
        for &s in &man.buckets.shard_counts {
            let name = decode_paged_shard_artifact_name(b, cap, s);
            if let Some(meta) = man.artifacts.get(&name) {
                let bt = meta.block_tokens.max(1);
                sharded.insert(
                    s,
                    ShardArtifact {
                        name,
                        pool_blocks: meta.pool_blocks,
                        block_tokens: bt,
                        max_blocks: (cap + bt - 1) / bt,
                        shards: meta.shards.max(1),
                        shard_kv_heads: meta.shard_kv_heads,
                    },
                );
            }
        }
        DecodeBatch {
            b,
            cap,
            dense: decode_artifact_name(b, cap),
            paged,
            paged_q8,
            sharded,
            budget: None,
        }
    }

    pub fn batch(&self) -> usize {
        self.b
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Install (or clear) the fine decode-budget stage for every
    /// subsequent step. Builder-style so the serving loops can write
    /// `DecodeBatch::new(..).with_budget(cfg.decode_budget_spec())`.
    pub fn with_budget(mut self, budget: Option<DecodeBudget>) -> DecodeBatch {
        self.budget = budget;
        self
    }

    /// The fine decode-budget stage steps run under (`None` = unbudgeted).
    pub fn budget(&self) -> Option<&DecodeBudget> {
        self.budget.as_ref()
    }

    fn resolve<'s>(&'s self, view: &Option<DecodeView<'_>>) -> Resolved<'s> {
        let Some(v) = view else { return Resolved::Staged };
        if v.shards > 1 {
            if let Some(a) =
                self.sharded.get(&v.shards).filter(|a| a.accepts(v, self.cap))
            {
                // The per-shard upload win beats the q8 byte win for a
                // store that is both sharded and quantized; the shard
                // views dequantize host-side at materialization.
                return Resolved::Shard(a);
            }
        }
        if v.codec == KvCodec::Int8PerRow {
            if let Some(a) =
                self.paged_q8.as_ref().filter(|a| a.accepts(v, self.cap))
            {
                return Resolved::Q8(a);
            }
        }
        // A sharded (or quantized) store can still decode through the
        // unsharded paged artifact — the host keeps (or can reconstruct)
        // the canonical dense planes, so only the per-shard / quantized
        // upload win is lost, never correctness.
        match self.paged.as_ref().filter(|a| a.accepts(v, self.cap)) {
            Some(a) => Resolved::Paged(a),
            None => Resolved::Staged,
        }
    }

    /// The path [`DecodeBatch::step`] will take for this store.
    pub fn path_for(&self, store: &dyn KvStore) -> DecodePath {
        match self.resolve(&store.decode_view_budgeted(self.budget.as_ref())) {
            Resolved::Shard(_) => DecodePath::Sharded,
            Resolved::Q8(_) => DecodePath::BlockTableQ8,
            Resolved::Paged(_) => DecodePath::BlockTable,
            Resolved::Staged => DecodePath::Staged,
        }
    }

    /// Artifact name the next step will execute (for logs / warmup).
    pub fn artifact_for(&self, store: &dyn KvStore) -> &str {
        match self.resolve(&store.decode_view_budgeted(self.budget.as_ref())) {
            Resolved::Shard(a) => &a.name,
            Resolved::Q8(a) | Resolved::Paged(a) => &a.name,
            Resolved::Staged => &self.dense,
        }
    }

    /// Run one batched decode step over `lanes` with a throwaway scratch
    /// (tests/tools; the serving loops hold a [`DecodeScratch`] and call
    /// [`DecodeBatch::step_scratch`]). Idle slots decode a dummy token 0
    /// at position 0 whose outputs are simply never applied (same
    /// contract the server loop always had).
    pub fn step(
        &self,
        ex: &dyn Exec,
        store: &dyn KvStore,
        lanes: &[LaneInput],
        metrics: Option<&Metrics>,
    ) -> Result<DecodeOut> {
        let mut scratch = DecodeScratch::new();
        self.step_scratch(ex, store, lanes, metrics, &mut scratch)
    }

    /// [`DecodeBatch::step`] with caller-owned reusable buffers: after
    /// the first step, the planner's input prep allocates nothing —
    /// tables, lens, token/position tensors, pinned payloads, and key
    /// strings are all refilled in place. (The store's `decode_view()`
    /// build remains the one O(referenced blocks) allocation per step.)
    pub fn step_scratch(
        &self,
        ex: &dyn Exec,
        store: &dyn KvStore,
        lanes: &[LaneInput],
        metrics: Option<&Metrics>,
        scratch: &mut DecodeScratch,
    ) -> Result<DecodeOut> {
        let b = self.b;
        // Phase timers: `Instant::now` reads cost no allocation, and the
        // observes below refill existing histogram slots, so the scratch
        // path's allocation-free contract holds with or without tracing.
        let t_start = Instant::now();
        scratch.fill_lanes(b, lanes);

        // Build the view once; it decides the path and feeds the inputs.
        // The fine budget stage (if any) is applied inside the store's
        // view build: pruned tables are just shorter tables, refilled
        // into the same scratch tensors — the allocation-free contract
        // holds with pruning enabled.
        let view = store.decode_view_budgeted(self.budget.as_ref());
        let resolved = self.resolve(&view);
        if matches!(resolved, Resolved::Staged) {
            // Dense staged bridge (fallback/oracle path; deliberately not
            // scratch-buffered — `stage()` itself materializes the dense
            // copy, which dwarfs the input plumbing).
            let staged = store.stage();
            if let Some(m) = metrics {
                m.inc(names::DECODE_STEPS_STAGED, 1);
                m.observe(
                    names::DECODE_PREP_SECS,
                    t_start.elapsed().as_secs_f64(),
                );
            }
            let (toks, poss) = scratch.lane_tensors();
            let t_exec = Instant::now();
            let out = ex.run(
                &self.dense,
                vec![
                    In::I32(toks),
                    In::I32(poss),
                    staged.k.into(),
                    staged.v.into(),
                    staged.lens.into(),
                ],
            )?;
            if let Some(m) = metrics {
                m.observe(
                    names::DECODE_EXEC_SECS,
                    t_exec.elapsed().as_secs_f64(),
                );
            }
            return Ok(DecodeOut::from_vec(out));
        }

        let view = view.expect("paged/sharded path checked above");
        if let Some(m) = metrics {
            if view.pruned_blocks > 0 {
                m.inc(names::DECODE_BLOCKS_PRUNED, view.pruned_blocks as u64);
            }
        }
        if let Resolved::Q8(art) = resolved {
            return self.step_q8(ex, &view, art, metrics, scratch, t_start);
        }
        let (name, pool_blocks, max_blocks, shards) = match resolved {
            Resolved::Shard(a) => {
                (&a.name, a.pool_blocks, a.max_blocks, a.shards)
            }
            Resolved::Paged(a) => {
                (&a.name, a.pool_blocks, a.max_blocks, 1usize)
            }
            _ => unreachable!("resolved above"),
        };
        scratch.fill_tables(&view, max_blocks);
        // Pins follow the RESOLVED artifact's shard count, not the
        // store's: a sharded store falling back to the unsharded paged
        // artifact uploads the whole slab as one legacy-keyed pair.
        scratch.ensure_pins(&view, shards);
        let t_upload = Instant::now();
        if let Some(m) = metrics {
            m.observe(
                names::DECODE_PREP_SECS,
                (t_upload - t_start).as_secs_f64(),
            );
        }

        // Per-shard pinned-slab maintenance: only the shards whose plane
        // stamp moved since the executor last saw them are materialized
        // and re-uploaded — a mutation confined to one shard moves 1/S of
        // the slab (the unsharded path is the S=1 degenerate case). Each
        // materialization lands in a persistent scratch buffer.
        let stale = stale_shards(&view, &scratch.keys, &|k, v| {
            ex.pinned_is_current(k, v)
        });
        let mut uploads = 0usize;
        for s in 0..shards.max(1) {
            if stale.contains(&s) {
                scratch.materialize_shard(&view, s, pool_blocks);
                uploads += 1;
            } else {
                scratch.park_shard(&view, s);
            }
        }
        let t_exec = Instant::now();
        if let Some(m) = metrics {
            if shards > 1 {
                m.inc(names::DECODE_STEPS_SHARDED, 1);
            } else {
                m.inc(names::DECODE_STEPS_BLOCK_TABLE, 1);
            }
            m.inc(names::SHARD_UPLOADS, uploads as u64);
            m.observe(
                names::DECODE_UPLOAD_SECS,
                (t_exec - t_upload).as_secs_f64(),
            );
        }

        let out = match ex.run_pinned_ref(name, &scratch.pins, &scratch.ins) {
            Ok(r) => r,
            // The residency check can race an LRU eviction on a shared
            // executor; retry with payloads ONLY for that specific miss
            // (`Runtime::run_with_pinned`'s "not resident" error) — any
            // other failure is a genuine execution error and must surface
            // as-is, not be masked by a silent re-execution.
            Err(e) if format!("{e:#}").contains("is not resident") => {
                let mut retried = 0u64;
                for s in 0..shards.max(1) {
                    if scratch.pins[2 * s].tensor.is_none() {
                        scratch.materialize_shard(&view, s, pool_blocks);
                        retried += 1;
                    }
                }
                if let Some(m) = metrics {
                    m.inc(names::SHARD_UPLOADS, retried);
                }
                ex.run_pinned_ref(name, &scratch.pins, &scratch.ins)?
            }
            Err(e) => return Err(e),
        };
        let t_combine = Instant::now();
        if let Some(m) = metrics {
            m.observe(
                names::DECODE_EXEC_SECS,
                (t_combine - t_exec).as_secs_f64(),
            );
        }

        let out = if shards > 1 {
            combine_shard_outputs(out, shards)
        } else {
            DecodeOut::from_vec(out)
        };
        if let Some(m) = metrics {
            m.observe(
                names::DECODE_COMBINE_SECS,
                t_combine.elapsed().as_secs_f64(),
            );
        }
        Ok(out)
    }

    /// Quantized block-table step: the int8 slab planes + per-row scales
    /// travel as four pinned tensors (input indices 2..=5), tables/lens
    /// ride in the shared scratch slots, and the artifact dequantizes
    /// in-HLO. The four planes share the whole-slab stamp — any row write
    /// requantizes in place, so they go stale (and re-upload) together —
    /// which still moves ~4x fewer slab bytes than the f32 pair.
    fn step_q8(
        &self,
        ex: &dyn Exec,
        view: &DecodeView<'_>,
        art: &PagedArtifact,
        metrics: Option<&Metrics>,
        scratch: &mut DecodeScratch,
        t_start: Instant,
    ) -> Result<DecodeOut> {
        scratch.fill_tables(view, art.max_blocks);
        scratch.ensure_pins_q8(view);
        let t_upload = Instant::now();
        if let Some(m) = metrics {
            m.observe(
                names::DECODE_PREP_SECS,
                (t_upload - t_start).as_secs_f64(),
            );
        }

        let stale = scratch.keys.iter().any(|(a, b)| {
            !(ex.pinned_is_current(a, view.version)
                && ex.pinned_is_current(b, view.version))
        });
        if stale {
            scratch.materialize_q8(view, art.pool_blocks);
        } else {
            scratch.park_q8(view);
        }
        let t_exec = Instant::now();
        if let Some(m) = metrics {
            m.inc(names::DECODE_STEPS_Q8, 1);
            m.inc(names::SHARD_UPLOADS, stale as u64);
            m.observe(
                names::DECODE_UPLOAD_SECS,
                (t_exec - t_upload).as_secs_f64(),
            );
        }

        let out = match ex.run_pinned_ref(&art.name, &scratch.pins, &scratch.ins)
        {
            Ok(r) => r,
            // Same eviction-race retry contract as the f32 paths: resend
            // payloads only for the specific residency miss.
            Err(e) if format!("{e:#}").contains("is not resident") => {
                scratch.materialize_q8(view, art.pool_blocks);
                if let Some(m) = metrics {
                    m.inc(names::SHARD_UPLOADS, 1);
                }
                ex.run_pinned_ref(&art.name, &scratch.pins, &scratch.ins)?
            }
            Err(e) => return Err(e),
        };
        if let Some(m) = metrics {
            m.observe(names::DECODE_EXEC_SECS, t_exec.elapsed().as_secs_f64());
        }
        Ok(DecodeOut::from_vec(out))
    }
}

/// Pinned-buffer keys for `shards` slab-plane pairs of store `sid`: one
/// `(k_key, v_key)` pair per KV-head shard, or the legacy single pair
/// for the unsharded (whole-slab) layout. Keys embed the store id so two
/// stores sharing one executor never thrash or race each other's slots.
fn pin_keys(sid: u64, shards: usize) -> Vec<(String, String)> {
    if shards <= 1 {
        vec![(
            format!("decode_slab_k:{sid:x}"),
            format!("decode_slab_v:{sid:x}"),
        )]
    } else {
        (0..shards)
            .map(|s| {
                (
                    format!("decode_slab_k:{sid:x}s{s}"),
                    format!("decode_slab_v:{sid:x}s{s}"),
                )
            })
            .collect()
    }
}

/// Pinned-buffer keys for the q8 slab layout of store `sid`: two pairs,
/// (quantized K plane, K scales) and (quantized V plane, V scales). Keyed
/// apart from the f32 `decode_slab_{k,v}` family so a precision flip (or
/// a q8 artifact appearing mid-flight) never aliases a stale device
/// buffer of the other layout.
fn pin_keys_q8(sid: u64) -> Vec<(String, String)> {
    vec![
        (
            format!("decode_slab_kq:{sid:x}"),
            format!("decode_slab_ksc:{sid:x}"),
        ),
        (
            format!("decode_slab_vq:{sid:x}"),
            format!("decode_slab_vsc:{sid:x}"),
        ),
    ]
}

/// Pinned-buffer keys for a store's native shard layout (one pair per
/// shard of `view.shards`, the legacy single pair when unsharded).
pub fn shard_pin_keys(view: &DecodeView<'_>) -> Vec<(String, String)> {
    pin_keys(view.version >> 32, view.shards.max(1))
}

/// Which of `keys`' slab-plane pairs must re-upload this step, judged
/// against the executor's resident `(key, version)` pairs (`is_current`
/// is `Exec::pinned_is_current`, or a mirror in the upload-amplification
/// bench). The pair count follows `keys` — a single pair is judged on
/// the whole-slab version (the unsharded layout, whatever the store's
/// native shard count), per-shard pairs on their own stamps. This is
/// where per-shard versioning pays: a mutation confined to one shard
/// ([`crate::coordinator::paging::PagedArena::mutate_shard_row`])
/// leaves every other shard current.
pub fn stale_shards(
    view: &DecodeView<'_>,
    keys: &[(String, String)],
    is_current: &dyn Fn(&str, u64) -> bool,
) -> Vec<usize> {
    let n = keys.len();
    assert!(
        n == 1 || n == view.shards,
        "keys must cover one whole-slab pair or one pair per shard"
    );
    (0..n)
        .filter(|&s| {
            let ver = if n <= 1 {
                view.version
            } else {
                view.shard_versions[s]
            };
            !(is_current(&keys[s].0, ver) && is_current(&keys[s].1, ver))
        })
        .collect()
}

/// Host-side partial-output combiner: reassemble per-shard head slices
/// (`[L, B, KV/S, hd]` each, shard-major in `parts`) into the full
/// `[L, B, KV, hd]` row, concatenating along the KV-head axis. KV heads
/// are independent under attention, so this is exact — the sharded
/// artifact's outputs combined equal the unsharded artifact's.
pub fn combine_head_shards(parts: &[HostTensor]) -> HostTensor {
    assert!(!parts.is_empty(), "at least one shard");
    let shape = &parts[0].shape;
    assert_eq!(shape.len(), 4, "[L, B, KV/S, hd] shard outputs");
    let (l, b, kvs, hd) = (shape[0], shape[1], shape[2], shape[3]);
    for p in parts {
        assert_eq!(&p.shape, shape, "shard output shapes must match");
    }
    let s = parts.len();
    let sub = kvs * hd;
    // Row-major assembly writes every element exactly once — no zero
    // prefill pass on the sharded hot path.
    let mut data = Vec::with_capacity(l * b * sub * s);
    for row in 0..l * b {
        for p in parts {
            data.extend_from_slice(&p.data[row * sub..(row + 1) * sub]);
        }
    }
    HostTensor::new(vec![l, b, kvs * s, hd], data)
}

/// Assemble a [`DecodeOut`] from the sharded artifact's output tuple
/// `(logits, k_new_0, v_new_0, ..., k_new_{S-1}, v_new_{S-1})`.
fn combine_shard_outputs(out: Vec<HostTensor>, shards: usize) -> DecodeOut {
    assert_eq!(out.len(), 1 + 2 * shards, "sharded decode outputs");
    let mut it = out.into_iter();
    let logits = it.next().expect("logits");
    let mut k_parts = Vec::with_capacity(shards);
    let mut v_parts = Vec::with_capacity(shards);
    for _ in 0..shards {
        k_parts.push(it.next().expect("k_new shard"));
        v_parts.push(it.next().expect("v_new shard"));
    }
    DecodeOut {
        logits,
        k_new: combine_head_shards(&k_parts),
        v_new: combine_head_shards(&v_parts),
    }
}

/// Reusable buffers for a decode loop: token/position/table/lens tensors,
/// pinned slab payloads, and the per-store key strings are all refilled
/// in place, deleting the hot-loop churn `DecodeView::tables_tensor` &
/// co. used to cause (what remains per step is the store's own
/// `decode_view()` build).
pub struct DecodeScratch {
    /// `[toks, poss, tables, lens]` in the paged-artifact input order,
    /// owned here and borrowed by `Exec::run_pinned_ref`.
    ins: Vec<In>,
    /// One persistent pinned slot per slab plane (2 per shard), payloads
    /// parked in `spares` while the device copy is current.
    pins: Vec<PinnedInput>,
    spares: Vec<Option<HostTensor>>,
    /// `(k_key, v_key)` per pinned pair, cached per store id.
    keys: Vec<(String, String)>,
    /// Store id + effective pair count + q8-layout flag the keys/pins
    /// were built for.
    keys_for: (u64, usize, bool),
    /// Pair count of the RESOLVED artifact this step (1 when a sharded
    /// store falls back to the unsharded paged artifact — the whole slab
    /// then travels as one legacy-keyed pair).
    eff_shards: usize,
}

impl Default for DecodeScratch {
    fn default() -> Self {
        DecodeScratch::new()
    }
}

impl DecodeScratch {
    /// Empty scratch; buffers grow to steady-state size on the first step.
    pub fn new() -> DecodeScratch {
        DecodeScratch {
            ins: vec![
                In::I32(HostTensorI32::empty()),
                In::I32(HostTensorI32::empty()),
                In::I32(HostTensorI32::empty()),
                In::I32(HostTensorI32::empty()),
            ],
            pins: Vec::new(),
            spares: Vec::new(),
            keys: Vec::new(),
            keys_for: (u64::MAX, 0, false),
            eff_shards: 1,
        }
    }

    fn ins_i32(&mut self, idx: usize) -> &mut HostTensorI32 {
        match &mut self.ins[idx] {
            In::I32(t) => t,
            In::F32(_) => unreachable!("decode scratch inputs are i32"),
        }
    }

    /// Fill the `[B]` token/position tensors from this step's lanes.
    fn fill_lanes(&mut self, b: usize, lanes: &[LaneInput]) {
        let [In::I32(toks), In::I32(poss), ..] = &mut self.ins[..] else {
            unreachable!("decode scratch inputs are i32")
        };
        for t in [&mut *toks, &mut *poss] {
            t.shape.clear();
            t.shape.push(b);
            t.data.clear();
            t.data.resize(b, 0);
        }
        for lane in lanes {
            toks.data[lane.slot] = lane.token;
            poss.data[lane.slot] = lane.pos as i32;
        }
    }

    /// Clones of the token/position tensors (staged-bridge path, which
    /// moves owned inputs).
    fn lane_tensors(&mut self) -> (HostTensorI32, HostTensorI32) {
        let toks = self.ins_i32(0).clone();
        let poss = self.ins_i32(1).clone();
        (toks, poss)
    }

    /// Fill the table/lens tensors from the view (in place).
    fn fill_tables(&mut self, view: &DecodeView<'_>, mb: usize) {
        view.tables_tensor_into(mb, self.ins_i32(2));
        view.lens_tensor_into(self.ins_i32(3));
    }

    /// (Re)build the pinned slots and key strings when the store or the
    /// resolved artifact's pair count changed; steady-state steps find
    /// everything cached. `eff_shards` is the RESOLVED artifact's shard
    /// count — 1 (whole slab, legacy keys) when a sharded store falls
    /// back to the unsharded paged artifact.
    fn ensure_pins(&mut self, view: &DecodeView<'_>, eff_shards: usize) {
        let sid = view.version >> 32;
        let eff = eff_shards.max(1);
        self.eff_shards = eff;
        if self.keys_for == (sid, eff, false) {
            return;
        }
        self.keys = pin_keys(sid, eff);
        self.rebuild_pin_slots();
        self.keys_for = (sid, eff, false);
    }

    /// [`DecodeScratch::ensure_pins`]'s q8 twin: four pinned tensors at
    /// input indices 2..=5 — (q-K, K scales) then (q-V, V scales) — under
    /// the `decode_slab_{kq,ksc,vq,vsc}` key family.
    fn ensure_pins_q8(&mut self, view: &DecodeView<'_>) {
        let sid = view.version >> 32;
        self.eff_shards = 1;
        if self.keys_for == (sid, 2, true) {
            return;
        }
        self.keys = pin_keys_q8(sid);
        self.rebuild_pin_slots();
        self.keys_for = (sid, 2, true);
    }

    /// Rebuild the pinned slots from `self.keys`: pair `p` pins input
    /// indices `2 + 2p` and `3 + 2p` (inputs 0/1 are toks/poss; tables
    /// and lens fill the remaining slots in order after the splice).
    fn rebuild_pin_slots(&mut self) {
        self.pins.clear();
        self.spares.clear();
        for (p, (a_key, b_key)) in self.keys.iter().enumerate() {
            self.pins.push(PinnedInput::new(
                2 + 2 * p,
                a_key,
                0,
                HostTensor::empty(),
            ));
            self.pins.push(PinnedInput::new(
                3 + 2 * p,
                b_key,
                0,
                HostTensor::empty(),
            ));
            self.spares.push(None);
            self.spares.push(None);
        }
    }

    fn shard_version(&self, view: &DecodeView<'_>, s: usize) -> u64 {
        if self.eff_shards <= 1 {
            view.version
        } else {
            view.shard_versions[s]
        }
    }

    /// Materialize shard `s`'s slab planes into the persistent payload
    /// buffers (stale path: this pair re-uploads).
    fn materialize_shard(
        &mut self,
        view: &DecodeView<'_>,
        s: usize,
        pool_blocks: usize,
    ) {
        let ver = self.shard_version(view, s);
        let (ki, vi) = (2 * s, 2 * s + 1);
        let mut k = self.pins[ki]
            .tensor
            .take()
            .or_else(|| self.spares[ki].take())
            .unwrap_or_else(HostTensor::empty);
        let mut v = self.pins[vi]
            .tensor
            .take()
            .or_else(|| self.spares[vi].take())
            .unwrap_or_else(HostTensor::empty);
        if self.eff_shards <= 1 {
            // whole slab as one pair (unsharded artifact — also the
            // fallback for a sharded store without a shard artifact)
            view.slab_tensors_into(pool_blocks, &mut k, &mut v);
        } else {
            view.view_shard(s).slab_tensors_into(pool_blocks, &mut k, &mut v);
        }
        self.pins[ki].tensor = Some(k);
        self.pins[vi].tensor = Some(v);
        self.pins[ki].version = ver;
        self.pins[vi].version = ver;
    }

    /// Send shard `s` payload-less (current path: the device copy is
    /// reused); its buffers park in `spares` for the next stale step.
    fn park_shard(&mut self, view: &DecodeView<'_>, s: usize) {
        let ver = self.shard_version(view, s);
        for i in [2 * s, 2 * s + 1] {
            if let Some(t) = self.pins[i].tensor.take() {
                self.spares[i] = Some(t);
            }
            self.pins[i].version = ver;
        }
    }

    /// Take pinned slot `i`'s payload buffer (or its parked spare).
    fn take_buf(&mut self, i: usize) -> HostTensor {
        self.pins[i]
            .tensor
            .take()
            .or_else(|| self.spares[i].take())
            .unwrap_or_else(HostTensor::empty)
    }

    /// Materialize all four q8 planes into the persistent payload buffers
    /// (stale path: the whole quantized slab re-uploads).
    fn materialize_q8(&mut self, view: &DecodeView<'_>, pool_blocks: usize) {
        let mut kq = self.take_buf(0);
        let mut ksc = self.take_buf(1);
        let mut vq = self.take_buf(2);
        let mut vsc = self.take_buf(3);
        let ok = view.q8_slab_tensors_into(
            pool_blocks,
            &mut kq,
            &mut ksc,
            &mut vq,
            &mut vsc,
        );
        debug_assert!(ok, "q8 path resolved for a non-int8 store");
        for (i, t) in [kq, ksc, vq, vsc].into_iter().enumerate() {
            self.pins[i].tensor = Some(t);
            self.pins[i].version = view.version;
        }
    }

    /// Send the q8 pins payload-less (current path: the device copies are
    /// reused); buffers park in `spares` for the next stale step.
    fn park_q8(&mut self, view: &DecodeView<'_>) {
        for i in 0..4 {
            if let Some(t) = self.pins[i].tensor.take() {
                self.spares[i] = Some(t);
            }
            self.pins[i].version = view.version;
        }
    }
}

/// Compaction reaction to pool pressure during [`advance_lane`]: the
/// policy's per-layer keep-sets drive block-granular eviction before the
/// append is retried. Also the carrier of the decode-budget policy: when
/// `policy_cfg.decode_budget_spec()` resolves, every successful append is
/// followed by the coarse budget stage
/// ([`KvStore::enforce_decode_budget`]).
pub struct CompactSpec<'a> {
    pub policy_cfg: &'a PolicyCfg,
    /// Shrink factor per layer (`server::COMPACT_SHRINK`).
    pub shrink: f64,
    pub window: usize,
    pub metrics: Option<&'a Metrics>,
}

/// Per-lane outcome of applying one decode step's outputs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LaneAdvance {
    /// KV appended and the next token sampled; `ended` flags END.
    Next { token: i32, ended: bool },
    /// The lane hit its staging capacity `C`; generation must stop.
    CapacityStop,
    /// The block pool cannot grow the lane (even after compaction, when a
    /// [`CompactSpec`] was supplied); the caller decides preemption.
    PoolPressure,
}

/// Apply one lane's slice of a decode step's outputs: append the new KV
/// row (compacting under pressure if `compact` is given), then sample the
/// next token from the lane's logits row.
pub fn advance_lane(
    store: &mut dyn KvStore,
    slot: usize,
    out: &DecodeOut,
    compact: Option<&CompactSpec<'_>>,
) -> LaneAdvance {
    let mut res = store.append(slot, &out.k_new, &out.v_new);
    if res == AppendResult::PoolExhausted {
        if let Some(spec) = compact {
            let lens = store.layer_lens(slot);
            let keep = spec.policy_cfg.compaction_keep(
                &lens,
                spec.shrink,
                spec.window,
            );
            if store.compact(slot, &keep) > 0 {
                if let Some(m) = spec.metrics {
                    m.inc(names::COMPACTIONS, 1);
                }
                res = store.append(slot, &out.k_new, &out.v_new);
            }
        }
    }
    match res {
        AppendResult::Ok => {
            // Coarse decode-budget stage: with the row safely appended,
            // permanently release the lane's coldest generated blocks
            // down to the coarse cap (sinks, window, and prefill KV are
            // never candidates). Unbudgeted policies resolve to None and
            // skip this entirely — the pre-budget behavior.
            if let Some(spec) = compact {
                if let Some(budget) = spec.policy_cfg.decode_budget_spec() {
                    let released = store.enforce_decode_budget(slot, &budget);
                    if released > 0 {
                        if let Some(m) = spec.metrics {
                            m.inc(
                                names::DECODE_BLOCKS_EVICTED,
                                released as u64,
                            );
                        }
                    }
                }
            }
            let logits = out.logits.row(slot);
            let token = logits
                .iter()
                .enumerate()
                .max_by(|x, y| x.1.partial_cmp(y.1).unwrap())
                .map(|(i, _)| i as i32)
                .unwrap_or(0);
            LaneAdvance::Next { token, ended: token == END as i32 }
        }
        AppendResult::CapacityExhausted => LaneAdvance::CapacityStop,
        AppendResult::PoolExhausted => LaneAdvance::PoolPressure,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::kvcache::RequestCache;
    use crate::coordinator::paging::{PagedArena, PagingConfig};
    use crate::manifest::{ArtifactMeta, Buckets, Manifest, ModelMeta, TensorSig};
    use crate::tensor::HostTensor;
    use std::collections::BTreeMap;

    fn meta() -> ModelMeta {
        ModelMeta {
            vocab_size: 8,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 2,
            head_dim: 2,
            tsp_layer: 1,
            window: 2,
            pool_kernel: 3,
            max_train_len: 64,
        }
    }

    fn manifest(with_paged: bool) -> Manifest {
        manifest_sharded(with_paged, false)
    }

    fn manifest_sharded(with_paged: bool, with_sharded: bool) -> Manifest {
        let mut artifacts = BTreeMap::new();
        let mk = |name: &str,
                  kind: &str,
                  pool_blocks,
                  block_tokens,
                  shards,
                  shard_kv_heads| ArtifactMeta {
            name: name.to_string(),
            file: format!("{name}.hlo.txt"),
            kind: kind.to_string(),
            n: 0,
            batch: 1,
            cap: 8,
            tsp_layer: 1,
            pool_blocks,
            block_tokens,
            shards,
            shard_kv_heads,
            inputs: Vec::<TensorSig>::new(),
            outputs: Vec::new(),
        };
        artifacts.insert(
            "decode_1x8".to_string(),
            mk("decode_1x8", "decode", 0, 0, 0, 0),
        );
        if with_paged {
            artifacts.insert(
                "decode_paged_1x8".to_string(),
                mk("decode_paged_1x8", "decode_paged", 8, 2, 0, 0),
            );
        }
        if with_sharded {
            artifacts.insert(
                "decode_paged_shard_1x8s2".to_string(),
                mk(
                    "decode_paged_shard_1x8s2",
                    "decode_paged_shard",
                    8,
                    2,
                    2,
                    1,
                ),
            );
        }
        Manifest {
            dir: std::path::PathBuf::from("/tmp"),
            model: meta(),
            n_params: 1,
            kernel: "jnp".into(),
            buckets: Buckets {
                prefill_ns: vec![64],
                stage1_ns: vec![64],
                stage2_ns: vec![64],
                pyramid_ns: vec![64],
                decode_batches: vec![1],
                decode_caps: vec![8],
                sweep_n: 64,
                sweep_nt: 16,
                pallas_n: 64,
                max_gen: 8,
                block_tokens: 2,
                shard_counts: if with_sharded { vec![2] } else { vec![] },
            },
            artifacts,
        }
    }

    /// Manifest plus the quantized paged artifact for the 1x8 bucket.
    fn with_q8(mut man: Manifest) -> Manifest {
        man.artifacts.insert(
            "decode_paged_q8_1x8".to_string(),
            ArtifactMeta {
                name: "decode_paged_q8_1x8".to_string(),
                file: "decode_paged_q8_1x8.hlo.txt".to_string(),
                kind: "decode_paged_q8".to_string(),
                n: 0,
                batch: 1,
                cap: 8,
                tsp_layer: 1,
                pool_blocks: 8,
                block_tokens: 2,
                shards: 0,
                shard_kv_heads: 0,
                inputs: Vec::new(),
                outputs: Vec::new(),
            },
        );
        man
    }

    fn store() -> PagedArena {
        store_sharded(1)
    }

    /// Admit one 3-token lane into every layer of `pa`.
    fn admit_demo(pa: &mut PagedArena) {
        let mut rc = RequestCache::new(&meta());
        let re = 4;
        for l in 0..2 {
            rc.k[l] = (0..3 * re).map(|i| i as f32).collect();
            rc.v[l] = (0..3 * re).map(|i| -(i as f32)).collect();
            rc.lens[l] = 3;
        }
        PagedArena::admit(pa, &rc).unwrap();
    }

    fn store_sharded(shards: usize) -> PagedArena {
        let m = meta();
        let cfg = PagingConfig { block_tokens: 2, shards, ..Default::default() };
        let mut pa = PagedArena::new(&m, 1, 8, cfg);
        admit_demo(&mut pa);
        pa
    }

    fn store_q8() -> PagedArena {
        let m = meta();
        let cfg = PagingConfig {
            block_tokens: 2,
            precision: KvCodec::Int8PerRow,
            ..Default::default()
        };
        let mut pa = PagedArena::new(&m, 1, 8, cfg);
        admit_demo(&mut pa);
        pa
    }

    #[test]
    fn picks_block_table_path_when_artifact_and_view_align() {
        let pa = store();
        let batch = DecodeBatch::new(&manifest(true), 1, 8);
        assert_eq!(batch.path_for(&pa), DecodePath::BlockTable);
        assert_eq!(batch.artifact_for(&pa), "decode_paged_1x8");
    }

    #[test]
    fn picks_sharded_path_for_sharded_store_with_artifact() {
        let pa = store_sharded(2);
        let batch = DecodeBatch::new(&manifest_sharded(true, true), 1, 8);
        assert_eq!(batch.path_for(&pa), DecodePath::Sharded);
        assert_eq!(batch.artifact_for(&pa), "decode_paged_shard_1x8s2");
        // unsharded store in the same manifest keeps the plain paged path
        let flat = store();
        assert_eq!(batch.path_for(&flat), DecodePath::BlockTable);
    }

    #[test]
    fn sharded_store_without_shard_artifact_falls_back_to_paged() {
        // The host keeps canonical dense planes, so a sharded store can
        // always decode through the unsharded paged artifact.
        let pa = store_sharded(2);
        let batch = DecodeBatch::new(&manifest(true), 1, 8);
        assert_eq!(batch.path_for(&pa), DecodePath::BlockTable);
        assert_eq!(batch.artifact_for(&pa), "decode_paged_1x8");
    }

    #[test]
    fn int8_store_with_q8_artifact_takes_q8_path() {
        let pa = store_q8();
        let batch = DecodeBatch::new(&with_q8(manifest(true)), 1, 8);
        assert_eq!(batch.path_for(&pa), DecodePath::BlockTableQ8);
        assert_eq!(batch.artifact_for(&pa), "decode_paged_q8_1x8");
        // an f32 store in the same manifest ignores the q8 artifact
        let flat = store();
        assert_eq!(batch.path_for(&flat), DecodePath::BlockTable);
        assert_eq!(batch.artifact_for(&flat), "decode_paged_1x8");
    }

    #[test]
    fn int8_store_without_q8_artifact_host_dequantizes_via_paged() {
        // Correctness never depends on the q8 artifact: the view
        // dequantizes host-side at pinned upload on the plain paged path.
        let pa = store_q8();
        let batch = DecodeBatch::new(&manifest(true), 1, 8);
        assert_eq!(batch.path_for(&pa), DecodePath::BlockTable);
        assert_eq!(batch.artifact_for(&pa), "decode_paged_1x8");
    }

    #[test]
    fn sharded_quantized_store_prefers_shard_artifact() {
        // Per-shard upload granularity beats the q8 byte win when both
        // artifacts are available (shard views dequantize host-side).
        let m = meta();
        let cfg = PagingConfig {
            block_tokens: 2,
            shards: 2,
            precision: KvCodec::Int8PerRow,
            ..Default::default()
        };
        let mut pa = PagedArena::new(&m, 1, 8, cfg);
        admit_demo(&mut pa);
        let batch =
            DecodeBatch::new(&with_q8(manifest_sharded(true, true)), 1, 8);
        assert_eq!(batch.path_for(&pa), DecodePath::Sharded);
    }

    /// Exec that records each call's artifact name + input shapes (after
    /// the default pinned splice) and fabricates outputs — pins the input
    /// ABI a step actually sends without a PJRT backend.
    struct CaptureExec {
        calls: std::cell::RefCell<Vec<(String, Vec<Vec<usize>>)>>,
        outputs: Vec<HostTensor>,
    }

    impl CaptureExec {
        fn new(outputs: Vec<HostTensor>) -> Self {
            CaptureExec { calls: std::cell::RefCell::new(Vec::new()), outputs }
        }
    }

    impl Exec for CaptureExec {
        fn run(
            &self,
            name: &str,
            inputs: Vec<In>,
        ) -> Result<Vec<HostTensor>> {
            let shapes = inputs
                .iter()
                .map(|i| match i {
                    In::F32(t) => t.shape.clone(),
                    In::I32(t) => t.shape.clone(),
                })
                .collect();
            self.calls.borrow_mut().push((name.to_string(), shapes));
            Ok(self.outputs.clone())
        }
    }

    #[test]
    fn sharded_store_fallback_step_sends_one_whole_slab_pair() {
        // Regression: pins must follow the RESOLVED artifact's shard
        // count. A sharded store falling back to the unsharded paged
        // artifact sends (toks, poss, slab_k, slab_v, tables, lens) —
        // six inputs, full-KV slab planes — not 2*S half-head pairs.
        let pa = store_sharded(2);
        let batch = DecodeBatch::new(&manifest(true), 1, 8);
        let ex = CaptureExec::new(vec![
            HostTensor::zeros(vec![1, 8]),    // logits
            HostTensor::zeros(vec![2, 1, 2, 2]), // k_new
            HostTensor::zeros(vec![2, 1, 2, 2]), // v_new
        ]);
        let lane = LaneInput { slot: 0, token: 1, pos: 3 };
        let out = batch.step(&ex, &pa, &[lane], None).expect("step runs");
        assert_eq!(out.k_new.shape, vec![2, 1, 2, 2]);
        let calls = ex.calls.borrow();
        assert_eq!(calls.len(), 1);
        let (name, shapes) = &calls[0];
        assert_eq!(name, "decode_paged_1x8");
        assert_eq!(shapes.len(), 6, "whole-slab ABI: 6 inputs");
        assert_eq!(shapes[2], vec![8, 2, 2, 2], "full-KV slab_k");
        assert_eq!(shapes[3], vec![8, 2, 2, 2], "full-KV slab_v");
        assert_eq!(shapes[4], vec![2, 1, 4], "tables [L, B, mb=cap/bt]");
        assert_eq!(shapes[5], vec![2, 1], "lens");
    }

    #[test]
    fn sharded_step_sends_per_shard_pairs_and_combines_outputs() {
        let pa = store_sharded(2);
        let batch = DecodeBatch::new(&manifest_sharded(true, true), 1, 8);
        // fabricate per-shard outputs with distinguishable head slices
        let part = |tag: f32| {
            HostTensor::new(vec![2, 1, 1, 2], vec![tag; 4])
        };
        let ex = CaptureExec::new(vec![
            HostTensor::zeros(vec![1, 8]),
            part(1.0), // k_new shard 0
            part(2.0), // v_new shard 0
            part(3.0), // k_new shard 1
            part(4.0), // v_new shard 1
        ]);
        let lane = LaneInput { slot: 0, token: 1, pos: 3 };
        let out = batch.step(&ex, &pa, &[lane], None).expect("step runs");
        // combiner: shard 0's head then shard 1's head per row
        assert_eq!(out.k_new.shape, vec![2, 1, 2, 2]);
        assert_eq!(&out.k_new.data[..4], &[1.0, 1.0, 3.0, 3.0]);
        assert_eq!(&out.v_new.data[..4], &[2.0, 2.0, 4.0, 4.0]);
        let calls = ex.calls.borrow();
        let (name, shapes) = &calls[0];
        assert_eq!(name, "decode_paged_shard_1x8s2");
        assert_eq!(shapes.len(), 8, "sharded ABI: 8 inputs");
        assert_eq!(shapes[2], vec![8, 2, 1, 2], "shard 0 slab_k (KV/S)");
        assert_eq!(shapes[4], vec![8, 2, 1, 2], "shard 1 slab_k");
        assert_eq!(shapes[6], vec![2, 1, 4], "tables shared");
        assert_eq!(shapes[7], vec![2, 1], "lens shared");
    }

    #[test]
    fn q8_step_sends_quant_planes_with_scales() {
        // The q8 ABI: (toks, poss, q_k, k_scales, q_v, v_scales, tables,
        // lens) — quant planes ship as integer-valued f32 `[nb, bt, KV,
        // hd]`, scales as `[nb, bt]` (one per row per block).
        let pa = store_q8();
        let batch = DecodeBatch::new(&with_q8(manifest(true)), 1, 8);
        let ex = CaptureExec::new(vec![
            HostTensor::zeros(vec![1, 8]),       // logits
            HostTensor::zeros(vec![2, 1, 2, 2]), // k_new
            HostTensor::zeros(vec![2, 1, 2, 2]), // v_new
        ]);
        let lane = LaneInput { slot: 0, token: 1, pos: 3 };
        let out = batch.step(&ex, &pa, &[lane], None).expect("step runs");
        assert_eq!(out.k_new.shape, vec![2, 1, 2, 2]);
        let calls = ex.calls.borrow();
        assert_eq!(calls.len(), 1);
        let (name, shapes) = &calls[0];
        assert_eq!(name, "decode_paged_q8_1x8");
        assert_eq!(shapes.len(), 8, "q8 ABI: 8 inputs");
        assert_eq!(shapes[0], vec![1], "toks");
        assert_eq!(shapes[1], vec![1], "poss");
        assert_eq!(shapes[2], vec![8, 2, 2, 2], "quantized slab_k");
        assert_eq!(shapes[3], vec![8, 2], "per-row K scales");
        assert_eq!(shapes[4], vec![8, 2, 2, 2], "quantized slab_v");
        assert_eq!(shapes[5], vec![8, 2], "per-row V scales");
        assert_eq!(shapes[6], vec![2, 1, 4], "tables [L, B, mb]");
        assert_eq!(shapes[7], vec![2, 1], "lens");
    }

    #[test]
    fn falls_back_without_paged_artifact_or_on_mismatch() {
        let pa = store();
        let batch = DecodeBatch::new(&manifest(false), 1, 8);
        assert_eq!(batch.path_for(&pa), DecodePath::Staged);
        assert_eq!(batch.artifact_for(&pa), "decode_1x8");

        // block-size mismatch between store and artifact -> staged
        let m = meta();
        let cfg = PagingConfig { block_tokens: 4, ..Default::default() };
        let other = PagedArena::new(&m, 1, 8, cfg);
        let batch = DecodeBatch::new(&manifest(true), 1, 8);
        assert_eq!(batch.path_for(&other), DecodePath::Staged);
    }

    #[test]
    fn dense_staging_flag_forces_staged_path() {
        let m = meta();
        let cfg = PagingConfig {
            block_tokens: 2,
            dense_staging: true,
            ..Default::default()
        };
        let pa = PagedArena::new(&m, 1, 8, cfg);
        let batch = DecodeBatch::new(&manifest(true), 1, 8);
        assert_eq!(batch.path_for(&pa), DecodePath::Staged);
        assert_eq!(batch.artifact_for(&pa), "decode_1x8");
    }

    #[test]
    fn combine_head_shards_concatenates_along_kv_axis() {
        // Two shards of [L=1, B=2, KV/S=1, hd=2] -> [1, 2, 2, 2]; shard 0
        // supplies heads [0, 1), shard 1 heads [1, 2).
        let p0 = HostTensor::new(vec![1, 2, 1, 2], vec![1., 2., 3., 4.]);
        let p1 = HostTensor::new(vec![1, 2, 1, 2], vec![5., 6., 7., 8.]);
        let full = combine_head_shards(&[p0, p1]);
        assert_eq!(full.shape, vec![1, 2, 2, 2]);
        assert_eq!(full.data, vec![1., 2., 5., 6., 3., 4., 7., 8.]);
    }

    #[test]
    fn stale_shards_tracks_per_shard_versions() {
        use std::cell::RefCell;
        use std::collections::HashMap;
        let mut pa = store_sharded(2);
        let mirror: RefCell<HashMap<String, u64>> = RefCell::new(HashMap::new());
        let current =
            |k: &str, v: u64| mirror.borrow().get(k).copied() == Some(v);
        {
            let view = pa.view();
            let keys = shard_pin_keys(&view);
            assert_eq!(keys.len(), 2);
            assert_ne!(keys[0].0, keys[1].0, "per-shard keys are distinct");
            // nothing resident: every shard uploads
            assert_eq!(stale_shards(&view, &keys, &current), vec![0, 1]);
            for (s, (k, v)) in keys.iter().enumerate() {
                mirror.borrow_mut().insert(k.clone(), view.shard_versions[s]);
                mirror.borrow_mut().insert(v.clone(), view.shard_versions[s]);
            }
            assert!(stale_shards(&view, &keys, &current).is_empty());
        }
        // whole-row append dirties every shard
        let step = HostTensor::zeros(vec![2, 1, 2, 2]);
        assert_eq!(
            PagedArena::append(&mut pa, 0, &step, &step),
            AppendResult::Ok
        );
        {
            let view = pa.view();
            let keys = shard_pin_keys(&view);
            assert_eq!(stale_shards(&view, &keys, &current), vec![0, 1]);
            for (s, (k, v)) in keys.iter().enumerate() {
                mirror.borrow_mut().insert(k.clone(), view.shard_versions[s]);
                mirror.borrow_mut().insert(v.clone(), view.shard_versions[s]);
            }
        }
        // a head-local mutation dirties exactly its shard
        assert!(pa.mutate_shard_row(0, 0, 0, 1, &[9.0, 9.0], &[8.0, 8.0]));
        let view = pa.view();
        let keys = shard_pin_keys(&view);
        assert_eq!(
            stale_shards(&view, &keys, &current),
            vec![1],
            "only the mutated shard re-uploads"
        );
    }

    #[test]
    fn advance_lane_appends_and_samples() {
        let mut pa = store();
        let logits = HostTensor::new(
            vec![1, 8],
            vec![0.0, 0.1, 3.0, 0.2, 0.0, 0.0, 0.0, 0.0],
        );
        let k_new = HostTensor::new(vec![2, 1, 2, 2], vec![7.0; 8]);
        let out = DecodeOut {
            logits,
            k_new: k_new.clone(),
            v_new: k_new,
        };
        match advance_lane(&mut pa, 0, &out, None) {
            LaneAdvance::Next { token, ended } => {
                assert_eq!(token, 2);
                assert!(!ended);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(pa.layer_lens(0), vec![4, 4]);
    }

    #[test]
    fn advance_lane_reports_capacity() {
        let m = meta();
        let cfg = PagingConfig { block_tokens: 2, ..Default::default() };
        let mut pa = PagedArena::new(&m, 1, 2, cfg);
        let mut rc = RequestCache::new(&m);
        for l in 0..2 {
            rc.k[l] = vec![1.0; 2 * 4];
            rc.v[l] = vec![1.0; 2 * 4];
            rc.lens[l] = 2;
        }
        let slot = PagedArena::admit(&mut pa, &rc).unwrap();
        let t = HostTensor::zeros(vec![2, 1, 2, 2]);
        let out = DecodeOut {
            logits: HostTensor::zeros(vec![1, 8]),
            k_new: t.clone(),
            v_new: t,
        };
        assert_eq!(
            advance_lane(&mut pa, slot, &out, None),
            LaneAdvance::CapacityStop
        );
    }
}
