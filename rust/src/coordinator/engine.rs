//! Request engine: prefill plan → KV compression → decode loop.
//!
//! `generate` is the single-request path used by the evaluation harness and
//! benchmarks; the serving stack (`server.rs`) drives the same decode
//! machinery through the continuous batcher.

use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::paging::{AppendResult, PagedArena, PagingConfig};
use crate::coordinator::policies::{Exec, Policy, PolicyCfg};
use crate::manifest::Manifest;
use crate::runtime::outputs::DecodeOut;
use crate::tensor::HostTensorI32;
use crate::tokenizer::END;
use crate::util::bucket_for;

/// Timing + cache accounting for one generated request.
#[derive(Debug, Clone, Default)]
pub struct GenStats {
    pub prefill_secs: f64,
    pub decode_secs: f64,
    pub decode_steps: usize,
    pub prompt_tokens: usize,
    /// Σ_layers tokens processed during prefill (compute-rate numerator).
    pub compute_tokens: usize,
    /// f32 elements held in the compressed KV cache.
    pub cache_elems: usize,
    /// Decode cache capacity bucket used.
    pub decode_cap: usize,
}

#[derive(Debug, Clone)]
pub struct GenResult {
    /// Generated token ids (first token included), END excluded.
    pub tokens: Vec<i32>,
    pub stats: GenStats,
    pub final_h: Vec<f32>,
}

/// Pick the decode-capacity bucket for a cache of `max_len` entries plus
/// `max_gen` appended tokens (+1 staging slot).
pub fn decode_cap_for(
    man: &Manifest,
    max_len: usize,
    max_gen: usize,
) -> Result<usize> {
    bucket_for(max_len + max_gen + 1, &man.buckets.decode_caps).with_context(
        || {
            format!(
                "no decode cap bucket fits {} cached + {} generated",
                max_len, max_gen
            )
        },
    )
}

/// Generate up to `max_new` tokens for one prompt under `policy`.
pub fn generate(
    ex: &dyn Exec,
    man: &Manifest,
    policy: &dyn Policy,
    cfg: &PolicyCfg,
    prompt: &[i32],
    max_new: usize,
) -> Result<GenResult> {
    let t0 = Instant::now();
    let pre = policy.prefill(ex, man, prompt, cfg)?;
    let prefill_secs = t0.elapsed().as_secs_f64();

    let max_new = max_new.min(man.buckets.max_gen);
    let cap = decode_cap_for(man, pre.cache.max_len(), max_new)?;
    // Default KV backend: the paged arena (worst-case-sized pool for a
    // single lane, so admission cannot fail here). The prefix cache is
    // off: a single-request arena dropped at function exit can never
    // reuse anything, so content hashing would be pure overhead.
    let mut store = PagedArena::new(
        &man.model,
        1,
        cap,
        PagingConfig { prefix_cache: false, ..PagingConfig::default() },
    );
    let slot = store.admit(&pre.cache).expect("worst-case pool admits");

    let mut stats = GenStats {
        prefill_secs,
        prompt_tokens: prompt.len(),
        compute_tokens: pre.compute_tokens,
        cache_elems: pre.cache.total_elems(),
        decode_cap: cap,
        ..Default::default()
    };

    let artifact = format!("decode_1x{cap}");
    let mut tokens = vec![pre.first_token];
    let mut cur = pre.first_token;
    let mut pos = pre.next_pos;
    let t1 = Instant::now();
    while tokens.len() < max_new && cur != END as i32 {
        let staged = store.stage();
        let out = DecodeOut::from_vec(ex.run(
            &artifact,
            vec![
                HostTensorI32::new(vec![1], vec![cur]).into(),
                HostTensorI32::new(vec![1], vec![pos as i32]).into(),
                staged.k.into(),
                staged.v.into(),
                staged.lens.into(),
            ],
        )?);
        if store.append(slot, &out.k_new, &out.v_new) != AppendResult::Ok {
            break; // capacity exhausted
        }
        stats.decode_steps += 1;
        pos += 1;
        cur = out.logits.argmax() as i32;
        if cur == END as i32 {
            break;
        }
        tokens.push(cur);
    }
    stats.decode_secs = t1.elapsed().as_secs_f64();

    Ok(GenResult { tokens, stats, final_h: pre.final_h })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::{Buckets, Manifest, ModelMeta};
    use std::collections::BTreeMap;

    fn fake_manifest() -> Manifest {
        Manifest {
            dir: std::path::PathBuf::from("/tmp"),
            model: ModelMeta {
                vocab_size: 256,
                d_model: 96,
                n_layers: 8,
                n_heads: 4,
                n_kv_heads: 2,
                head_dim: 24,
                tsp_layer: 4,
                window: 8,
                pool_kernel: 7,
                max_train_len: 512,
            },
            n_params: 1,
            kernel: "jnp".into(),
            buckets: Buckets {
                prefill_ns: vec![64, 128],
                stage1_ns: vec![256],
                stage2_ns: vec![64],
                pyramid_ns: vec![256],
                decode_batches: vec![1, 4],
                decode_caps: vec![128, 320, 576],
                sweep_n: 256,
                sweep_nt: 64,
                pallas_n: 128,
                max_gen: 64,
            },
            artifacts: BTreeMap::new(),
        }
    }

    #[test]
    fn cap_bucketing() {
        let man = fake_manifest();
        assert_eq!(decode_cap_for(&man, 50, 64).unwrap(), 128);
        assert_eq!(decode_cap_for(&man, 100, 64).unwrap(), 320);
        assert!(decode_cap_for(&man, 600, 64).is_err());
    }
}
