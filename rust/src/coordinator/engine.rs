//! Request engine: prefill plan → KV compression → decode loop.
//!
//! `generate` is the single-request path used by the evaluation harness and
//! benchmarks; the serving stack (`server.rs`) drives the same decode
//! machinery through the continuous batcher.

use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::decode::{
    advance_lane, CompactSpec, DecodeBatch, DecodeScratch, LaneAdvance,
    LaneInput,
};
use crate::coordinator::paging::{PagedArena, PagingConfig, TenantId};
use crate::coordinator::policies::{Exec, Policy, PolicyCfg};
use crate::manifest::Manifest;
use crate::metrics::{names, Metrics};
use crate::tokenizer::END;
use crate::util::bucket_for;

/// Timing + cache accounting for one generated request.
#[derive(Debug, Clone, Default)]
pub struct GenStats {
    pub prefill_secs: f64,
    pub decode_secs: f64,
    pub decode_steps: usize,
    pub prompt_tokens: usize,
    /// Σ_layers tokens processed during prefill (compute-rate numerator).
    pub compute_tokens: usize,
    /// f32 elements held in the compressed KV cache.
    pub cache_elems: usize,
    /// Decode cache capacity bucket used.
    pub decode_cap: usize,
    /// True when generation stopped because the KV store could not grow
    /// (lane capacity or block pool), not because of END / max_new. The
    /// seed silently `break`-ed here; the condition is now also counted on
    /// `Metrics::global()` as `decode_truncated_by_capacity`.
    pub truncated_by_capacity: bool,
}

#[derive(Debug, Clone)]
pub struct GenResult {
    /// Generated token ids (first token included), END excluded.
    pub tokens: Vec<i32>,
    pub stats: GenStats,
    pub final_h: Vec<f32>,
}

/// Pick the decode-capacity bucket for a cache of `max_len` entries plus
/// `max_gen` appended tokens (+1 staging slot).
pub fn decode_cap_for(
    man: &Manifest,
    max_len: usize,
    max_gen: usize,
) -> Result<usize> {
    bucket_for(max_len + max_gen + 1, &man.buckets.decode_caps).with_context(
        || {
            format!(
                "no decode cap bucket fits {} cached + {} generated",
                max_len, max_gen
            )
        },
    )
}

/// Generate up to `max_new` tokens for one prompt under `policy`.
pub fn generate(
    ex: &dyn Exec,
    man: &Manifest,
    policy: &dyn Policy,
    cfg: &PolicyCfg,
    prompt: &[i32],
    max_new: usize,
) -> Result<GenResult> {
    let t0 = Instant::now();
    let pre = policy.prefill(ex, man, prompt, cfg)?;
    let prefill_secs = t0.elapsed().as_secs_f64();

    let max_new = max_new.min(man.buckets.max_gen);
    let cap = decode_cap_for(man, pre.cache.max_len(), max_new)?;
    // Default KV backend: the paged arena (worst-case-sized pool for a
    // single lane, so admission cannot fail here). The prefix cache is
    // off: a single-request arena dropped at function exit can never
    // reuse anything, so content hashing would be pure overhead. Swap is
    // off for the same reason — a single worst-case-sized lane is never
    // preempted. The block size follows the manifest's decode_paged
    // bucket — a mismatch would silently pin decode to the dense staged
    // bridge.
    let mut pc = PagingConfig {
        prefix_cache: false,
        swap_bytes: 0,
        ..PagingConfig::default()
    };
    if man.buckets.block_tokens > 0 {
        pc.block_tokens = man.buckets.block_tokens;
    }
    let mut store = PagedArena::new(&man.model, 1, cap, pc);
    // Single-tenant default: a one-lane, worst-case-sized private arena
    // has no contention for quotas to arbitrate.
    let slot = store
        .admit_for(&pre.cache, TenantId::DEFAULT)
        .expect("worst-case pool admits");

    let mut stats = GenStats {
        prefill_secs,
        prompt_tokens: prompt.len(),
        compute_tokens: pre.compute_tokens,
        cache_elems: pre.cache.total_elems(),
        decode_cap: cap,
        ..Default::default()
    };

    // Block-table-native decode by default: the batch planner feeds the
    // `decode_paged_1x{cap}` artifact the slab + table indices, falling
    // back to the dense staged bridge only when the manifest predates the
    // paged artifacts (or the store cannot expose a view).
    let batch =
        DecodeBatch::new(man, 1, cap).with_budget(cfg.decode_budget_spec());
    // Decode-phase budgets need the post-append hook in `advance_lane`:
    // hand it a `CompactSpec` only when a budget is configured, so the
    // unbudgeted single-request path keeps its historical
    // no-compaction behavior.
    let spec = CompactSpec {
        policy_cfg: cfg,
        shrink: 0.5,
        window: man.model.window,
        metrics: None,
    };
    let spec_opt =
        if cfg.decode_budget_spec().is_some() { Some(&spec) } else { None };
    // Reusable input-prep buffers: steady-state decode allocates nothing
    // for tables/lens/token tensors or pinned slab payloads (the store's
    // per-step view build is the one remaining allocation).
    let mut scratch = DecodeScratch::new();
    let mut tokens = vec![pre.first_token];
    let mut cur = pre.first_token;
    let mut pos = pre.next_pos;
    let t1 = Instant::now();
    while tokens.len() < max_new && cur != END as i32 {
        let lane = LaneInput { slot, token: cur, pos };
        let out = batch.step_scratch(ex, &store, &[lane], None, &mut scratch)?;
        match advance_lane(&mut store, slot, &out, spec_opt) {
            LaneAdvance::Next { token, ended } => {
                stats.decode_steps += 1;
                pos += 1;
                if ended {
                    break;
                }
                cur = token;
                tokens.push(cur);
            }
            LaneAdvance::CapacityStop | LaneAdvance::PoolPressure => {
                // The store cannot grow this request: surface it instead
                // of the seed's silent break.
                stats.truncated_by_capacity = true;
                Metrics::global()
                    .inc(names::DECODE_TRUNCATED_BY_CAPACITY, 1);
                break;
            }
        }
    }
    stats.decode_secs = t1.elapsed().as_secs_f64();

    Ok(GenResult { tokens, stats, final_h: pre.final_h })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::{Buckets, Manifest, ModelMeta};
    use std::collections::BTreeMap;

    fn fake_manifest() -> Manifest {
        Manifest {
            dir: std::path::PathBuf::from("/tmp"),
            model: ModelMeta {
                vocab_size: 256,
                d_model: 96,
                n_layers: 8,
                n_heads: 4,
                n_kv_heads: 2,
                head_dim: 24,
                tsp_layer: 4,
                window: 8,
                pool_kernel: 7,
                max_train_len: 512,
            },
            n_params: 1,
            kernel: "jnp".into(),
            buckets: Buckets {
                prefill_ns: vec![64, 128],
                stage1_ns: vec![256],
                stage2_ns: vec![64],
                pyramid_ns: vec![256],
                decode_batches: vec![1, 4],
                decode_caps: vec![128, 320, 576],
                sweep_n: 256,
                sweep_nt: 64,
                pallas_n: 128,
                max_gen: 64,
                block_tokens: 16,
                shard_counts: vec![],
            },
            artifacts: BTreeMap::new(),
        }
    }

    #[test]
    fn cap_bucketing() {
        let man = fake_manifest();
        assert_eq!(decode_cap_for(&man, 50, 64).unwrap(), 128);
        assert_eq!(decode_cap_for(&man, 100, 64).unwrap(), 320);
        assert!(decode_cap_for(&man, 600, 64).is_err());
    }
}
