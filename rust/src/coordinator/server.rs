//! The serving coordinator: a continuous-batching inference server.
//!
//! One serving thread owns the (non-Send) PJRT runtime and drives the
//! loop: admit → prefill (policy compresses KV) → batched decode steps →
//! retire. Clients submit prompts from any thread through `ServerHandle`
//! and receive a `Response` on a per-request channel.
//!
//! This is the deployment shape the paper targets ("readily compatible
//! with modern serving frameworks ... orthogonal to batching and paged
//! attention"): FastKV (or any baseline policy) plugs in as the prefill /
//! KV-compression stage, and the decode batcher sees only compressed
//! caches.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::engine::decode_cap_for;
use crate::coordinator::kvcache::BatchArena;
use crate::coordinator::policies::{make_policy, Exec, PolicyCfg};
use crate::coordinator::scheduler::{Action, AdmitOrder, Scheduler};
use crate::manifest::Manifest;
use crate::metrics::Metrics;
use crate::runtime::outputs::DecodeOut;
use crate::runtime::Runtime;
use crate::tensor::HostTensorI32;
use crate::tokenizer::END;

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub artifact_dir: std::path::PathBuf,
    pub policy: String,
    pub policy_cfg: PolicyCfg,
    /// Decode batch size (must be one of the compiled decode buckets).
    pub decode_batch: usize,
    /// Max tokens generated per request.
    pub max_new: usize,
    /// Largest prompt admitted (bucket-limited).
    pub max_prompt: usize,
    pub order: AdmitOrder,
}

#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    submitted: Instant,
    reply: mpsc::Sender<Response>,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub ttft_secs: f64,
    pub e2e_secs: f64,
    pub prefill_secs: f64,
    pub decode_steps: usize,
    pub error: Option<String>,
}

enum Msg {
    Submit(Request),
    Shutdown,
}

#[derive(Clone)]
pub struct ServerHandle {
    tx: mpsc::Sender<Msg>,
    next_id: Arc<std::sync::atomic::AtomicU64>,
    pub metrics: Arc<Metrics>,
}

impl ServerHandle {
    /// Submit a prompt; returns a receiver for the final response.
    pub fn submit(
        &self,
        prompt: Vec<i32>,
        max_new: usize,
    ) -> Result<(u64, mpsc::Receiver<Response>)> {
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Submit(Request {
                id,
                prompt,
                max_new,
                submitted: Instant::now(),
                reply,
            }))
            .map_err(|_| anyhow::anyhow!("server thread gone"))?;
        Ok((id, rx))
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Msg::Shutdown);
    }
}

pub struct Server {
    handle: ServerHandle,
    join: Option<std::thread::JoinHandle<()>>,
}

struct Active {
    req: Request,
    slot: usize,
    tokens: Vec<i32>,
    cur: i32,
    pos: usize,
    prefill_secs: f64,
    ttft_secs: f64,
    done: bool,
}

impl Server {
    pub fn spawn(cfg: ServerConfig) -> Result<Server> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let metrics = Arc::new(Metrics::default());
        let m2 = metrics.clone();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("fastkv-server".into())
            .spawn(move || serve_loop(cfg, rx, m2, ready_tx))?;
        ready_rx.recv()??;
        Ok(Server {
            handle: ServerHandle {
                tx,
                next_id: Arc::new(std::sync::atomic::AtomicU64::new(1)),
                metrics,
            },
            join: Some(join),
        })
    }

    pub fn handle(&self) -> ServerHandle {
        self.handle.clone()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.handle.shutdown();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn serve_loop(
    cfg: ServerConfig,
    rx: mpsc::Receiver<Msg>,
    metrics: Arc<Metrics>,
    ready: mpsc::Sender<Result<()>>,
) {
    let rt = match Runtime::new(&cfg.artifact_dir) {
        Ok(rt) => {
            let _ = ready.send(Ok(()));
            rt
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    if let Err(e) = serve_inner(&cfg, &rt, rx, &metrics) {
        eprintln!("[server] fatal: {e:#}");
    }
}

fn serve_inner(
    cfg: &ServerConfig,
    rt: &Runtime,
    rx: mpsc::Receiver<Msg>,
    metrics: &Metrics,
) -> Result<()> {
    let man = rt.manifest.clone();
    let policy = make_policy(&cfg.policy)?;
    // Worst-case cache: full-context policy keeps max_prompt entries.
    let worst = match cfg.policy.as_str() {
        "full" => cfg.max_prompt,
        "pyramid_infer" => cfg.max_prompt,
        _ => cfg
            .policy_cfg
            .kv_budget(cfg.max_prompt, man.model.window)
            .max(cfg.policy_cfg.tsp_count(cfg.max_prompt, man.model.window)),
    };
    let cap = decode_cap_for(&man, worst, cfg.max_new)?;
    let b = cfg.decode_batch;
    anyhow::ensure!(
        man.buckets.decode_batches.contains(&b),
        "decode batch {b} not compiled (buckets: {:?})",
        man.buckets.decode_batches
    );
    let artifact = format!("decode_{b}x{cap}");
    let mut arena = BatchArena::new(&man.model, b, cap);
    let mut sched: Scheduler<Request> = Scheduler::new(b, cfg.order);
    let mut active: Vec<Active> = Vec::new();
    let mut shutdown = false;

    while !(shutdown && sched.queue_len() == 0 && active.is_empty()) {
        // Drain incoming messages (non-blocking if we have work).
        loop {
            let msg = if active.is_empty() && sched.queue_len() == 0 {
                if shutdown {
                    break;
                }
                match rx.recv() {
                    Ok(m) => m,
                    Err(_) => {
                        shutdown = true;
                        break;
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(_) => break,
                }
            };
            match msg {
                Msg::Submit(r) => {
                    metrics.inc("submitted", 1);
                    sched.enqueue(r);
                }
                Msg::Shutdown => shutdown = true,
            }
        }
        if shutdown && sched.queue_len() == 0 && active.is_empty() {
            break;
        }

        match sched.next_action(active.len()) {
            Action::Prefill => {
                let req = sched.pop_next(|r| r.prompt.len()).unwrap();
                match admit(rt, &man, policy.as_ref(), cfg, req, &mut arena) {
                    Ok(a) => {
                        metrics.observe("prefill_secs", a.prefill_secs);
                        active.push(a);
                    }
                    Err((req, e)) => {
                        metrics.inc("rejected", 1);
                        let _ = req.reply.send(Response {
                            id: req.id,
                            tokens: vec![],
                            ttft_secs: 0.0,
                            e2e_secs: req.submitted.elapsed().as_secs_f64(),
                            prefill_secs: 0.0,
                            decode_steps: 0,
                            error: Some(format!("{e:#}")),
                        });
                    }
                }
            }
            Action::DecodeStep => {
                decode_step(rt, &artifact, &mut arena, &mut active, metrics)?;
                // Retire finished requests.
                let mut i = 0;
                while i < active.len() {
                    if active[i].done
                        || active[i].tokens.len() >= active[i].max_new()
                    {
                        let a = active.swap_remove(i);
                        arena.free_slot(a.slot);
                        metrics.inc("completed", 1);
                        metrics.observe(
                            "e2e_secs",
                            a.req.submitted.elapsed().as_secs_f64(),
                        );
                        metrics.observe("ttft_secs", a.ttft_secs);
                        metrics
                            .inc("tokens_out", a.tokens.len() as u64);
                        let _ = a.req.reply.send(Response {
                            id: a.req.id,
                            tokens: a.tokens,
                            ttft_secs: a.ttft_secs,
                            e2e_secs: a.req.submitted.elapsed().as_secs_f64(),
                            prefill_secs: a.prefill_secs,
                            decode_steps: a.pos,
                            error: None,
                        });
                    } else {
                        i += 1;
                    }
                }
            }
            Action::Idle => {}
        }
    }
    Ok(())
}

impl Active {
    fn max_new(&self) -> usize {
        self.req.max_new
    }
}

fn admit(
    rt: &Runtime,
    man: &Manifest,
    policy: &dyn crate::coordinator::policies::Policy,
    cfg: &ServerConfig,
    req: Request,
    arena: &mut BatchArena,
) -> std::result::Result<Active, (Request, anyhow::Error)> {
    if req.prompt.len() > cfg.max_prompt {
        return Err((
            req,
            anyhow::anyhow!("prompt exceeds max_prompt {}", cfg.max_prompt),
        ));
    }
    let t0 = Instant::now();
    let pre =
        match policy.prefill(rt, man, &req.prompt, &cfg.policy_cfg) {
            Ok(p) => p,
            Err(e) => return Err((req, e)),
        };
    let prefill_secs = t0.elapsed().as_secs_f64();
    let slot = match arena.alloc_slot() {
        Some(s) => s,
        None => return Err((req, anyhow::anyhow!("no free decode slot"))),
    };
    arena.load(slot, &pre.cache);
    let ttft = req.submitted.elapsed().as_secs_f64();
    Ok(Active {
        pos: pre.next_pos,
        cur: pre.first_token,
        tokens: vec![pre.first_token],
        slot,
        req,
        prefill_secs,
        ttft_secs: ttft,
        done: pre.first_token == END as i32,
    })
}

fn decode_step(
    rt: &Runtime,
    artifact: &str,
    arena: &mut BatchArena,
    active: &mut [Active],
    metrics: &Metrics,
) -> Result<()> {
    let b = arena.b;
    let mut toks = vec![0i32; b];
    let mut poss = vec![0i32; b];
    for a in active.iter() {
        toks[a.slot] = a.cur;
        poss[a.slot] = a.pos as i32;
    }
    let t0 = Instant::now();
    let out = DecodeOut::from_vec(
        Exec::run(
            rt,
            artifact,
            vec![
                HostTensorI32::new(vec![b], toks).into(),
                HostTensorI32::new(vec![b], poss).into(),
                arena.k.clone().into(),
                arena.v.clone().into(),
                arena.lens_tensor().into(),
            ],
        )
        .context("decode step")?,
    );
    metrics.observe("decode_step_secs", t0.elapsed().as_secs_f64());

    for a in active.iter_mut() {
        if !arena.append(a.slot, &out.k_new, &out.v_new) {
            a.done = true;
            continue;
        }
        a.pos += 1;
        let logits = out.logits.row(a.slot);
        let next = logits
            .iter()
            .enumerate()
            .max_by(|x, y| x.1.partial_cmp(y.1).unwrap())
            .map(|(i, _)| i as i32)
            .unwrap_or(0);
        if next == END as i32 {
            a.done = true;
        } else {
            a.cur = next;
            a.tokens.push(next);
        }
    }
    Ok(())
}
