//! The serving coordinator: a continuous-batching inference server over a
//! paged KV cache.
//!
//! One serving thread owns the (non-Send) PJRT runtime and drives the
//! loop: admit → prefill (policy compresses KV) → batched decode steps →
//! retire. Clients submit prompts from any thread through `ServerHandle`
//! and receive a `Response` on a per-request channel.
//!
//! Decode KV lives behind the [`KvStore`] trait; the default backend is
//! the paged [`PagedArena`] (block pool + prefix reuse), with the flat
//! [`BatchArena`] available for comparison. On top of the store the loop
//! implements:
//!
//!  * **memory-aware admission** — a queued request is admitted only when
//!    the block pool can cover its post-compression KV budget plus decode
//!    growth (`Scheduler::next_action_mem`);
//!  * **block-granular compaction** — on pool exhaustion mid-decode the
//!    affected lane first evicts by blocks using the policy's per-layer
//!    keep-sets (`PolicyCfg::compaction_keep`);
//!  * **preemption with swap-to-host resume** — if compaction cannot free
//!    enough, the *least-progress resumable lane* (fewest generated
//!    tokens, ties to fewest held blocks —
//!    `scheduler::pick_preemption_victim`) is preempted: its
//!    FastKV-selected blocks are serialized to the byte-budgeted host
//!    swap arena (`PagedArena::swap_out`) and the request parks on the
//!    resume queue carrying the `SwapHandle` plus its decode cursor. On
//!    re-admission the blocks are restored in place (`swap_in`) — zero
//!    policy work, zero prefill, bit-identical KV. Only when the swap
//!    budget refuses the lane or the handle is dropped under host-memory
//!    pressure does resume fall back to re-prefilling
//!    `prompt ++ generated-so-far` (recompute-resume, which re-pays the
//!    prefill FastKV eliminated and may re-select different KV). The
//!    full pressure ladder is: compact → swap → recompute → reject.
//!  * **chunked prefill + continuous batching** — with
//!    `--prefill-chunk N`, chunk-capable policies (fastkv, gemfilter)
//!    run stage-1 prefill in TSP-boundary-aware chunks
//!    (`Policy::begin_chunked`), one chunk per loop iteration with
//!    `--prefill-decode-ratio` decode rounds interleaved between chunks,
//!    so a long admission never stalls active decode lanes. The chunking
//!    lane parks between chunks under preemption and resumes from the
//!    completed-chunk boundary with zero recomputed chunks; the TSP +
//!    stage-2 tail runs exactly once, after the final chunk.
//!
//! Decode steps go through the shared [`DecodeBatch`] planner:
//! KV-head-sharded block tables (`decode_paged_shard_{B}x{C}s{S}`,
//! per-shard pinned slabs) when the store is sharded and the manifest
//! carries the family, block-table native (`decode_paged_{B}x{C}`, slab
//! + table indices) otherwise, dense staged bridge as the last resort.
//!
//!  * **multi-tenant fairness** — every request carries a
//!    [`TenantId`] (`ServerHandle::submit_for`; plain `submit` uses the
//!    single-tenant default), the admission gate judges the *tenant's*
//!    remaining quota (`KvStore::can_admit_for`), the queue is scanned
//!    for the first admissible request rather than head-blocking
//!    (`Scheduler::pop_admissible`) so a light tenant steps past a
//!    quota-blocked heavy one, preemption prefers lanes of tenants
//!    bursting past their reserved floor, and swap bytes are budgeted
//!    per tenant. Quotas are configured through
//!    `PagingConfig::tenant_quotas`.
//!
//! Block-pool gauges (blocks in use, prefix-cache hit rate, preemptions)
//! plus per-tenant gauges (`tenant_{id}_blocks_held`, swap bytes,
//! preemptions, rejects) are published through [`Metrics`] every
//! scheduler iteration.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::decode::{
    advance_lane, CompactSpec, DecodeBatch, DecodePath, DecodeScratch,
    LaneAdvance, LaneInput,
};
use crate::coordinator::engine::decode_cap_for;
use crate::coordinator::kvcache::BatchArena;
use crate::coordinator::paging::{
    KvStore, PagedArena, PagingConfig, SwapHandle, SwapIn, TenantId,
};
use crate::coordinator::policies::{
    make_policy, ChunkedPrefill, Exec, Policy, PolicyCfg, PrefillOutcome,
};
use crate::coordinator::scheduler::{
    pick_preemption_victim, Action, AdmitOrder, Scheduler,
};
use crate::manifest::Manifest;
use crate::metrics::{names, Metrics};
use crate::obs::trace::{EventKind, IncidentKind, ResumeMode, NO_LANE};
use crate::obs::ObsConfig;
use crate::runtime::outputs::DecodeOut;
use crate::runtime::Runtime;
use crate::tokenizer::END;

/// Shrink factor compaction applies to each layer's length when the pool
/// runs dry (keep-sets never drop the observation window or sinks).
const COMPACT_SHRINK: f64 = 0.5;

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub artifact_dir: std::path::PathBuf,
    pub policy: String,
    pub policy_cfg: PolicyCfg,
    /// Decode batch size (must be one of the compiled decode buckets).
    pub decode_batch: usize,
    /// Max tokens generated per request.
    pub max_new: usize,
    /// Largest prompt admitted (bucket-limited).
    pub max_prompt: usize,
    pub order: AdmitOrder,
    /// KV backend: `Some(cfg)` = paged arena (the default), `None` = the
    /// flat `BatchArena` (seed behavior, for comparison).
    pub paging: Option<PagingConfig>,
    /// Observability: lifecycle tracing and metric export (all off by
    /// default — see [`ObsConfig`]).
    pub obs: ObsConfig,
}

#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    /// Tenant this request is served under: its KV blocks and swap bytes
    /// are charged against this tenant's quota, and admission /
    /// preemption fairness is judged per tenant.
    pub tenant: TenantId,
    submitted: Instant,
    reply: mpsc::Sender<Response>,
    /// Tokens generated before a preemption. The final response always
    /// includes them; the recompute-resume *fallback* additionally
    /// re-prefills them as prompt context (the swap path never does).
    resumed: Vec<i32>,
    /// TTFT measured at first admission, preserved across preemptions.
    first_ttft: Option<f64>,
    /// Host-swapped KV from the last preemption plus the decode cursor;
    /// resume restores the blocks without touching the policy, falling
    /// back to recompute only when the handle is gone.
    swap: Option<SwapResume>,
    /// A completed prefill whose `store.admit` was deferred (pool
    /// momentarily full). The retry re-attempts admission only — the
    /// policy prefill is never recomputed for a deferral.
    pending: Option<PendingPrefill>,
    /// Set once a policy prefill has run for this request; any further
    /// prefill is paid-for work re-done (`names::PREFILL_RECOMPUTED`).
    prefilled: bool,
    /// Chunked-prefill state parked with a preempted request: the driver
    /// resumes from the completed-chunk boundary, so zero chunks (and
    /// zero policy prefills) are re-run.
    chunking: Option<ChunkCarry>,
}

/// A parked chunked prefill riding the resume queue with its request.
#[derive(Debug)]
struct ChunkCarry {
    ch: Box<dyn ChunkedPrefill>,
    /// Chunk wall time accumulated before the park.
    prefill_secs: f64,
}

/// Decode cursor riding with a swapped-out request on the resume queue.
#[derive(Debug, Clone, Copy)]
pub struct SwapResume {
    pub handle: SwapHandle,
    /// Token that was being decoded when the lane was preempted.
    pub cur: i32,
    /// Absolute position of that token.
    pub pos: usize,
}

/// Prefill outcome carried across a deferred admission.
#[derive(Debug)]
struct PendingPrefill {
    outcome: PrefillOutcome,
    prefill_secs: f64,
}

impl Request {
    /// Construct a request without a live server — tests and benches
    /// drive [`admit`] / [`preempt`] / [`try_resume`] directly against a
    /// store. The returned receiver observes the final [`Response`].
    pub fn synthetic(
        id: u64,
        prompt: Vec<i32>,
        max_new: usize,
    ) -> (Request, mpsc::Receiver<Response>) {
        Request::synthetic_for(id, prompt, max_new, TenantId::DEFAULT)
    }

    /// [`Request::synthetic`] under a specific tenant (quota tests).
    pub fn synthetic_for(
        id: u64,
        prompt: Vec<i32>,
        max_new: usize,
        tenant: TenantId,
    ) -> (Request, mpsc::Receiver<Response>) {
        let (reply, rx) = mpsc::channel();
        (
            Request {
                id,
                prompt,
                max_new,
                tenant,
                submitted: Instant::now(),
                reply,
                resumed: Vec::new(),
                first_ttft: None,
                swap: None,
                pending: None,
                prefilled: false,
                chunking: None,
            },
            rx,
        )
    }

    /// Generated-so-far tokens a preemption parked with this request.
    pub fn resumed_tokens(&self) -> &[i32] {
        &self.resumed
    }

    /// The swap ticket riding with this request, if it was swapped out.
    pub fn swap_resume(&self) -> Option<&SwapResume> {
        self.swap.as_ref()
    }

    /// Attach a completed prefill outcome so the next [`admit`] is
    /// store-only — the policy prefill will not re-run. Used by the
    /// chunked-prefill finish path and the sim harness; the deferral
    /// carry uses the same slot internally.
    pub fn carry_prefill(
        &mut self,
        outcome: PrefillOutcome,
        prefill_secs: f64,
    ) {
        self.prefilled = true;
        self.pending = Some(PendingPrefill { outcome, prefill_secs });
    }

    /// Park chunked-prefill state with this request (preempt between
    /// chunks). [`Request::resume_chunking`] takes it back; the driver
    /// continues from the completed-chunk boundary with zero chunks
    /// re-run.
    pub fn park_chunking(
        &mut self,
        ch: Box<dyn ChunkedPrefill>,
        prefill_secs: f64,
    ) {
        self.prefilled = true;
        self.chunking = Some(ChunkCarry { ch, prefill_secs });
    }

    /// Take back a parked chunked prefill: `(driver, accumulated chunk
    /// wall time)`.
    pub fn resume_chunking(
        &mut self,
    ) -> Option<(Box<dyn ChunkedPrefill>, f64)> {
        self.chunking.take().map(|c| (c.ch, c.prefill_secs))
    }

    /// Whether chunked-prefill state is parked with this request.
    pub fn is_chunking(&self) -> bool {
        self.chunking.is_some()
    }

    /// Whether a completed prefill outcome rides with this request
    /// (deferred admission or a finished chunked prefill).
    pub fn has_carried_prefill(&self) -> bool {
        self.pending.is_some()
    }
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Time to first token. `None` when no first token was ever decoded
    /// (the request was rejected before admission) — never a fake `0.0`,
    /// so TTFT percentiles stay honest.
    pub ttft_secs: Option<f64>,
    pub e2e_secs: f64,
    pub prefill_secs: f64,
    pub decode_steps: usize,
    pub error: Option<String>,
}

enum Msg {
    Submit(Request),
    Shutdown,
}

#[derive(Clone)]
pub struct ServerHandle {
    tx: mpsc::Sender<Msg>,
    next_id: Arc<std::sync::atomic::AtomicU64>,
    pub metrics: Arc<Metrics>,
}

impl ServerHandle {
    /// Submit a prompt under the single-tenant default; returns a
    /// receiver for the final response.
    pub fn submit(
        &self,
        prompt: Vec<i32>,
        max_new: usize,
    ) -> Result<(u64, mpsc::Receiver<Response>)> {
        self.submit_for(prompt, max_new, TenantId::DEFAULT)
    }

    /// Submit a prompt with the tenant chosen round-robin from the
    /// *request id* (`id % tenants`). Deterministic per request no matter
    /// how the submission loop is structured: a workload driver that
    /// restarts its loop (or interleaves several) still assigns every
    /// request the same tenant on every machine, which is what keeps
    /// multi-tenant bench runs reproducible. Returns the id and the
    /// tenant actually assigned alongside the response receiver.
    pub fn submit_round_robin(
        &self,
        prompt: Vec<i32>,
        max_new: usize,
        tenants: u32,
    ) -> Result<(u64, TenantId, mpsc::Receiver<Response>)> {
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let tenant = TenantId((id % tenants.max(1) as u64) as u32);
        let rx = self.submit_with(id, prompt, max_new, tenant)?;
        Ok((id, tenant, rx))
    }

    /// Submit a prompt on behalf of `tenant`: its KV blocks, swap bytes,
    /// admission and preemption fairness are all accounted against that
    /// tenant's quota (`PagingConfig::tenant_quotas`).
    pub fn submit_for(
        &self,
        prompt: Vec<i32>,
        max_new: usize,
        tenant: TenantId,
    ) -> Result<(u64, mpsc::Receiver<Response>)> {
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let rx = self.submit_with(id, prompt, max_new, tenant)?;
        Ok((id, rx))
    }

    /// Shared tail of every submit path: build the fresh `Request` and
    /// hand it to the serving thread.
    fn submit_with(
        &self,
        id: u64,
        prompt: Vec<i32>,
        max_new: usize,
        tenant: TenantId,
    ) -> Result<mpsc::Receiver<Response>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Submit(Request {
                id,
                prompt,
                max_new,
                tenant,
                submitted: Instant::now(),
                reply,
                resumed: Vec::new(),
                first_ttft: None,
                swap: None,
                pending: None,
                prefilled: false,
                chunking: None,
            }))
            .map_err(|_| anyhow::anyhow!("server thread gone"))?;
        Ok(rx)
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Msg::Shutdown);
    }
}

pub struct Server {
    handle: ServerHandle,
    join: Option<std::thread::JoinHandle<()>>,
}

/// One admitted request's decode-loop state. Public (with read-only
/// accessors) so tests and benches can drive the real
/// admit/decode/preempt/resume machinery without a PJRT runtime.
pub struct Active {
    req: Request,
    slot: usize,
    tokens: Vec<i32>,
    cur: i32,
    pos: usize,
    prefill_secs: f64,
    /// `None` only while the request has never produced a first token
    /// (possible on a deferred-then-finished edge); kept as an `Option`
    /// so rejects never invent a 0.0 TTFT.
    ttft_secs: Option<f64>,
    done: bool,
}

impl Active {
    pub fn slot(&self) -> usize {
        self.slot
    }

    pub fn cur(&self) -> i32 {
        self.cur
    }

    pub fn pos(&self) -> usize {
        self.pos
    }

    pub fn tokens(&self) -> &[i32] {
        &self.tokens
    }

    pub fn is_done(&self) -> bool {
        self.done
    }

    pub fn request_id(&self) -> u64 {
        self.req.id
    }

    /// Tenant the underlying request is served under.
    pub fn tenant(&self) -> TenantId {
        self.req.tenant
    }

    /// Apply one lane-step outcome to this request's decode cursor
    /// (token bookkeeping only — the KV append already happened inside
    /// `advance_lane`). `PoolPressure` is the caller's problem.
    pub fn apply(&mut self, adv: LaneAdvance) {
        match adv {
            LaneAdvance::Next { token, ended } => {
                self.pos += 1;
                if ended {
                    self.done = true;
                } else {
                    self.cur = token;
                    self.tokens.push(token);
                }
            }
            LaneAdvance::CapacityStop => self.done = true,
            LaneAdvance::PoolPressure => {}
        }
    }
}

impl Server {
    pub fn spawn(cfg: ServerConfig) -> Result<Server> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let metrics = Arc::new(Metrics::default());
        let m2 = metrics.clone();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("fastkv-server".into())
            .spawn(move || serve_loop(cfg, rx, m2, ready_tx))?;
        ready_rx.recv()??;
        Ok(Server {
            handle: ServerHandle {
                tx,
                next_id: Arc::new(std::sync::atomic::AtomicU64::new(1)),
                metrics,
            },
            join: Some(join),
        })
    }

    pub fn handle(&self) -> ServerHandle {
        self.handle.clone()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.handle.shutdown();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn serve_loop(
    cfg: ServerConfig,
    rx: mpsc::Receiver<Msg>,
    metrics: Arc<Metrics>,
    ready: mpsc::Sender<Result<()>>,
) {
    let rt = match Runtime::new(&cfg.artifact_dir) {
        Ok(rt) => {
            let _ = ready.send(Ok(()));
            rt
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    if let Err(e) = serve_inner(&cfg, &rt, rx, &metrics) {
        eprintln!("[server] fatal: {e:#}");
    }
}

/// Reject a queued/parked request with an error response. Public so
/// tests and the sim harness can drive full lifecycles. A rejected
/// request that never decoded a first token reports `ttft_secs: None`
/// and bumps `names::TTFT_UNMEASURED` — it must not pollute the TTFT
/// histogram with a fake 0.0.
pub fn reject(
    mut req: Request,
    store: &mut dyn KvStore,
    metrics: &Metrics,
    why: String,
) {
    // A rejected request never resumes: free its host-swapped KV.
    if let Some(sr) = req.swap.take() {
        store.swap_drop(sr.handle);
    }
    metrics.inc(names::REJECTED, 1);
    metrics.inc(&names::tenant_rejected(req.tenant), 1);
    if req.first_ttft.is_none() {
        metrics.inc(names::TTFT_UNMEASURED, 1);
    }
    let tracer = metrics.tracer();
    tracer.record(req.id, req.tenant, NO_LANE, EventKind::Reject);
    tracer.incident(IncidentKind::Reject, req.id, req.tenant);
    let tokens = std::mem::take(&mut req.resumed);
    let _ = req.reply.send(Response {
        id: req.id,
        tokens,
        ttft_secs: req.first_ttft,
        e2e_secs: req.submitted.elapsed().as_secs_f64(),
        prefill_secs: 0.0,
        decode_steps: 0,
        error: Some(why),
    });
}

/// Largest prompt the policy's prefill path can bucket. Resume-by-
/// recompute re-prefills `prompt ++ generated`, so a request may only be
/// preempted while that combined length still fits — otherwise it could
/// never be re-admitted.
fn prefill_len_limit(man: &Manifest, policy: &str, cfg: &PolicyCfg) -> usize {
    let max = |v: &[usize]| v.iter().copied().max().unwrap_or(0);
    match policy {
        "fastkv" | "gemfilter" => {
            let mono = max(&man.buckets.stage1_ns);
            // Chunk-capable policies with chunking on admit up to the
            // largest carried-KV chunk bucket — deliberately past the
            // biggest monolithic stage-1 bucket, so prompts too long for
            // any single bucket chunk instead of rejecting (and their
            // recompute-resume chunks again).
            if cfg.prefill_chunk > 0 && man.buckets.chunk_c > 0 {
                mono.max(max(&man.buckets.chunk_ns))
            } else {
                mono
            }
        }
        "pyramid_infer" => max(&man.buckets.pyramid_ns),
        _ => {
            // run_prefill_full can also take the Pallas artifact, whose
            // bucket may exceed the jnp prefill buckets.
            let lim = max(&man.buckets.prefill_ns);
            if cfg.use_pallas {
                lim.max(man.buckets.pallas_n)
            } else {
                lim
            }
        }
    }
}

/// Memory-aware admission verdict for a queued request, matched to the
/// path it will actually take:
///
///  * swapped resume — can the exact swapped blocks be restored now
///    (already judged against the owning tenant's quota)?
///  * deferred admission — the cache is already materialized; gate on
///    its true per-layer footprint, not the prompt-length estimate;
///  * fresh / recompute — the policy's worst-case estimate for the
///    (re-)prefill, as before.
///
/// Every verdict is the *tenant's*: `can_admit_for` holds the take to
/// the request tenant's burst ceiling and to the other tenants' unused
/// reserved floors.
///
/// `remaining` deliberately has no `.max(1)` clamp: a request with no
/// decode budget left reserves zero growth headroom, and `admit` agrees
/// by finishing it without growing the cache (`resume_admit_state`).
fn admit_gate(
    cfg: &ServerConfig,
    man: &Manifest,
    store: &dyn KvStore,
    r: &Request,
) -> bool {
    let remaining = r.max_new.saturating_sub(r.resumed.len());
    if let Some(sr) = &r.swap {
        if store.swap_contains(sr.handle) {
            return store.can_swap_in(sr.handle, remaining);
        }
        // handle dropped: this request will recompute-resume below
    }
    if let Some(p) = &r.pending {
        return store.can_admit_for(
            p.outcome.cache.max_len(),
            remaining,
            r.tenant,
        );
    }
    let n = (r.prompt.len() + r.resumed.len())
        .min(cfg.max_prompt + cfg.max_new);
    let per_layer =
        cfg.policy_cfg.per_layer_budget(&cfg.policy, n, man.model.window);
    store.can_admit_for(per_layer, remaining, r.tenant)
}

/// Retire a finished request: release its lane and send the response.
/// Public so tests and the sim harness can drive full lifecycles. TTFT
/// is observed only when it was actually measured (`names::
/// TTFT_UNMEASURED` counts the remainder).
pub fn finish(mut a: Active, store: &mut dyn KvStore, metrics: &Metrics) {
    // Defensive: a finishing request must never leak a swap entry (the
    // resume ladder clears it, but budget bytes are too precious to
    // trust that from here).
    if let Some(sr) = a.req.swap.take() {
        store.swap_drop(sr.handle);
    }
    store.release(a.slot);
    metrics.inc(names::COMPLETED, 1);
    metrics.inc(&names::tenant_completed(a.req.tenant), 1);
    metrics
        .observe(names::E2E_SECS, a.req.submitted.elapsed().as_secs_f64());
    match a.ttft_secs {
        Some(t) => metrics.observe(names::TTFT_SECS, t),
        None => metrics.inc(names::TTFT_UNMEASURED, 1),
    }
    metrics.inc(names::TOKENS_OUT, a.tokens.len() as u64);
    metrics.tracer().record(
        a.req.id,
        a.req.tenant,
        a.slot as i32,
        EventKind::Finish { tokens_out: a.tokens.len() as u32 },
    );
    let _ = a.req.reply.send(Response {
        id: a.req.id,
        tokens: a.tokens,
        ttft_secs: a.ttft_secs,
        e2e_secs: a.req.submitted.elapsed().as_secs_f64(),
        prefill_secs: a.prefill_secs,
        decode_steps: a.pos,
        error: None,
    });
}

fn publish_pool_gauges(store: &dyn KvStore, metrics: &Metrics) {
    let ps = store.pool_stats();
    metrics.set_gauge(names::POOL_BLOCKS_TOTAL, ps.blocks_total as f64);
    metrics.set_gauge(names::POOL_BLOCKS_IN_USE, ps.blocks_in_use as f64);
    // High-water mark: the instantaneous gauge reads 0 once the pool
    // drains, so peak utilization gets its own gauge.
    let peak = metrics
        .gauge(names::POOL_BLOCKS_IN_USE_PEAK)
        .max(ps.blocks_in_use as f64);
    metrics.set_gauge(names::POOL_BLOCKS_IN_USE_PEAK, peak);
    metrics.set_gauge(names::POOL_BLOCKS_CACHED, ps.blocks_cached as f64);
    metrics.set_gauge(names::POOL_PREFIX_HITS, ps.prefix_hits as f64);
    metrics.set_gauge(names::POOL_PREFIX_MISSES, ps.prefix_misses as f64);
    metrics.set_gauge(names::POOL_PREFIX_HIT_RATE, ps.prefix_hit_rate());
    metrics.set_gauge(names::POOL_COW_COPIES, ps.cow_copies as f64);
    metrics.set_gauge(names::POOL_EVICTIONS, ps.evictions as f64);
    metrics
        .set_gauge(names::POOL_ALLOC_FAILURES, ps.alloc_failures as f64);
    metrics.set_gauge(names::POOL_QUOTA_DENIALS, ps.quota_denials as f64);
    // Blocks holding at least one generated row — the working set the
    // decode-phase budgets act on (prefill-selected blocks excluded).
    metrics.set_gauge(
        names::DECODE_REGION_BLOCKS,
        ps.decode_region_blocks as f64,
    );
    // Per-tenant rows: block charges reconcile with the pool gauge
    // (Σ tenant_{id}_blocks_held == pool_blocks_in_use), swap bytes with
    // the arena's used_bytes.
    for ts in store.tenant_stats() {
        metrics.set_gauge(
            &names::tenant_blocks_held(ts.tenant),
            ts.held_blocks as f64,
        );
        metrics.set_gauge(
            &names::tenant_blocks_reserved(ts.tenant),
            ts.reserved_blocks as f64,
        );
        metrics.set_gauge(
            &names::tenant_swap_bytes_used(ts.tenant),
            ts.swap_bytes_used as f64,
        );
    }
    let ss = store.swap_stats();
    metrics.set_gauge(names::SWAP_BYTES_USED, ss.used_bytes as f64);
    metrics.set_gauge(names::SWAP_BYTES_BUDGET, ss.budget_bytes as f64);
    metrics.set_gauge(names::SWAP_ENTRIES, ss.entries as f64);
    metrics.set_gauge(names::SWAP_DROPPED, ss.dropped as f64);
    // Slab codec accounting: resident encoded bytes plus the store's
    // cumulative quantize/dequantize row counts and bulk codec time.
    metrics.set_gauge(names::POOL_BYTES_QUANTIZED, ps.slab_bytes as f64);
    metrics.set_gauge(names::QUANT_ROWS, ps.quant_rows as f64);
    metrics.set_gauge(names::DEQUANT_ROWS, ps.dequant_rows as f64);
    metrics.set_gauge(names::QUANT_DEQUANT_SECS, ps.codec_secs);
    // Per-tier lane rows: every tier published (zeros included) so a
    // tier emptying never drops the series.
    for (codec, lanes) in store.lanes_by_tier() {
        metrics.set_gauge(&names::lanes_tier(codec), lanes as f64);
    }
    // Per-shard slab rows (empty for unsharded backends): the device
    // bytes each shard executor pins for this store's K + V planes.
    for (s, bytes) in store.shard_slab_bytes().into_iter().enumerate() {
        metrics.set_gauge(&names::shard_slab_bytes(s), bytes as f64);
    }
}

/// Write the configured export files: the JSON metrics snapshot (with a
/// Prometheus text sibling at `<metrics_out>.prom`) on every call, and
/// the Chrome trace only on the final (shutdown) call — the ring keeps
/// filling until then.
fn export_obs(obs: &ObsConfig, metrics: &Metrics, is_final: bool) {
    if let Some(path) = &obs.metrics_out {
        if let Err(e) = crate::obs::write_json_snapshot(metrics, path) {
            eprintln!("[server] metrics export failed: {e}");
        }
        let prom = path.with_extension("prom");
        if let Err(e) = crate::obs::write_prometheus(metrics, &prom) {
            eprintln!("[server] prometheus export failed: {e}");
        }
    }
    if is_final {
        if let Some(path) = &obs.trace_out {
            if let Err(e) =
                crate::obs::write_chrome_trace(metrics.tracer(), path)
            {
                eprintln!("[server] trace export failed: {e}");
            }
        }
    }
}

/// The serve loop's single in-flight chunked prefill. The request is
/// held out of both the queue and the active set while its stage-1
/// chunks run one per loop iteration, interleaved with decode rounds.
/// `decode_credit` is the number of decode rounds still owed to the
/// active lanes before the next chunk may run (refilled to
/// `PolicyCfg::prefill_decode_ratio` after every chunk — see
/// `Scheduler::next_action_chunked`).
struct PrefillInProgress {
    req: Request,
    ch: Box<dyn ChunkedPrefill>,
    /// Chunk wall time accumulated so far; becomes the request's
    /// `prefill_secs` once the tail finishes.
    prefill_secs: f64,
    decode_credit: usize,
}

fn serve_inner(
    cfg: &ServerConfig,
    rt: &Runtime,
    rx: mpsc::Receiver<Msg>,
    metrics: &Metrics,
) -> Result<()> {
    let man = rt.manifest.clone();
    if cfg.obs.trace_events > 0 {
        metrics.tracer().enable(cfg.obs.trace_events);
    }
    let policy = make_policy(&cfg.policy)?;
    // Worst-case per-layer retention for the largest admissible prompt —
    // sizes the decode capacity bucket.
    let worst = cfg.policy_cfg.per_layer_budget(
        &cfg.policy,
        cfg.max_prompt,
        man.model.window,
    );
    let cap = decode_cap_for(&man, worst, cfg.max_new)?;
    let b = cfg.decode_batch;
    anyhow::ensure!(
        man.buckets.decode_batches.contains(&b),
        "decode batch {b} not compiled (buckets: {:?})",
        man.buckets.decode_batches
    );
    let batch = DecodeBatch::new(&man, b, cap)
        .with_budget(cfg.policy_cfg.decode_budget_spec());
    let mut store: Box<dyn KvStore> = match &cfg.paging {
        Some(pc) => {
            Box::new(PagedArena::new(&man.model, b, cap, pc.clone()))
        }
        None => Box::new(BatchArena::new(&man.model, b, cap)),
    };
    // Surface the decode path once: a paged store silently pinned to the
    // dense bridge (block-size mismatch, pool larger than the artifact's
    // slab bucket, or a manifest without decode_paged artifacts) is the
    // O(cap)-per-token regression this stack exists to avoid — make it
    // loud rather than discoverable only via the step counters.
    let path = batch.path_for(store.as_ref());
    let block_table =
        matches!(path, DecodePath::BlockTable | DecodePath::Sharded);
    metrics.set_gauge(
        names::DECODE_BLOCK_TABLE,
        if block_table { 1.0 } else { 0.0 },
    );
    metrics.set_gauge(
        names::DECODE_SHARDED,
        if path == DecodePath::Sharded { 1.0 } else { 0.0 },
    );
    let wants_block_table =
        cfg.paging.as_ref().map(|p| !p.dense_staging).unwrap_or(false);
    if wants_block_table && !block_table {
        eprintln!(
            "[server] block-table decode unavailable — falling back to the \
             dense staged bridge via `{}` (check block_tokens vs the \
             manifest's, pool size vs the artifact slab bucket, and that \
             the artifact dir carries decode_paged_{b}x{cap})",
            batch.artifact_for(store.as_ref())
        );
    }
    let mut sched: Scheduler<Request> = Scheduler::new(b, cfg.order);
    let mut active: Vec<Active> = Vec::new();
    // Reusable decode input-prep buffers: the planner allocates nothing
    // per step beyond the store's own view build.
    let mut scratch = DecodeScratch::new();
    let mut shutdown = false;
    // Set after a deferred admission: forces one decode pass before the
    // next admission attempt so the loop cannot hot-spin on
    // prefill-then-defer while the pool estimate and reality disagree.
    let mut admission_paused = false;
    // Serve-loop iteration counter, for the periodic metrics export.
    let mut iter: usize = 0;
    // At most one chunked prefill is in flight at a time; its request
    // lives here, outside both the queue and the active set.
    let mut chunking: Option<PrefillInProgress> = None;

    while !(shutdown
        && sched.queue_len() == 0
        && active.is_empty()
        && chunking.is_none())
    {
        // Drain incoming messages (non-blocking if we have work — an
        // in-flight chunked prefill counts as work and must never park
        // the loop on a blocking recv).
        loop {
            let msg = if active.is_empty()
                && sched.queue_len() == 0
                && chunking.is_none()
            {
                if shutdown {
                    break;
                }
                match rx.recv() {
                    Ok(m) => m,
                    Err(_) => {
                        shutdown = true;
                        break;
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(_) => break,
                }
            };
            match msg {
                Msg::Submit(r) => {
                    metrics.inc(names::SUBMITTED, 1);
                    metrics.tracer().record(
                        r.id,
                        r.tenant,
                        NO_LANE,
                        EventKind::Submit {
                            prompt_tokens: r.prompt.len() as u32,
                        },
                    );
                    sched.enqueue(r);
                }
                Msg::Shutdown => shutdown = true,
            }
        }
        if shutdown
            && sched.queue_len() == 0
            && active.is_empty()
            && chunking.is_none()
        {
            break;
        }

        // Memory-aware, tenant-fair admission: can the pool cover ANY
        // queued request's post-compression budget within its tenant's
        // quota (plus minimal growth headroom — see `KvStore::can_admit`;
        // full decode growth is over-committed)? Scanning past the head
        // is what keeps a light tenant from starving behind a
        // quota-blocked heavy request. The O(queue) gate sweep runs at
        // most once per iteration, and only when its verdict can matter:
        // a full batch cannot admit and an empty queue has nothing to
        // scan. When a slot is free the sweep pops the winning request
        // directly, so Prefill never pays a second identical scan.
        let mut admissible: Option<Request> = None;
        let admit_ok = if std::mem::take(&mut admission_paused) {
            false
        } else if sched.queue_len() == 0 {
            true
        } else if active.len() >= sched.max_active {
            false
        } else {
            let chunk_busy = chunking.is_some();
            admissible = sched.pop_admissible(
                |r| r.prompt.len(),
                |r| {
                    // A parked chunked prefill resumes into the (single)
                    // chunking lane: it needs that lane free and claims
                    // no pool blocks until its tail finishes, so the
                    // memory gate below does not apply.
                    if r.is_chunking() {
                        return !chunk_busy;
                    }
                    // While a chunked prefill is in flight, only
                    // non-prefill admissions may pop (swap restores and
                    // carried/deferred prefills); a fresh blocking
                    // prefill would stall the very decode lanes the
                    // chunking exists to keep fed.
                    if chunk_busy
                        && r.swap_resume().is_none()
                        && !r.has_carried_prefill()
                    {
                        return false;
                    }
                    let ok = admit_gate(cfg, &man, store.as_ref(), r);
                    // Trace quota-blocked deferrals only (a gate miss on
                    // raw pool pressure is the common case under load and
                    // would flood the ring every scan).
                    if !ok && store.tenant_over_quota(r.tenant) {
                        let tracer = metrics.tracer();
                        tracer.record(
                            r.id,
                            r.tenant,
                            NO_LANE,
                            EventKind::QuotaDefer,
                        );
                        tracer.incident(
                            IncidentKind::QuotaBlocked,
                            r.id,
                            r.tenant,
                        );
                    }
                    ok
                },
            );
            admissible.is_some()
        };

        // The sweep pops the winning request *before* the action is
        // chosen, so `queue_len` has already shrunk — the action must be
        // decided from the sweep's own verdict, never from a re-read of
        // the post-pop queue state (pinned by scheduler.rs's
        // `post_pop_action_never_drops_the_popped_request`). A popped
        // request always outranks an in-flight chunk: swap restores and
        // deferred admissions must not starve behind a long admission.
        let action = sched.next_action_chunked(
            active.len(),
            admissible.is_some(),
            chunking.as_ref().map(|p| p.decode_credit),
        );
        // A decode round granted on chunk credit spends one credit.
        if action == Action::DecodeStep {
            if let Some(pip) = chunking.as_mut() {
                pip.decode_credit = pip.decode_credit.saturating_sub(1);
            }
        }
        match action {
            Action::Prefill => {
                let req = admissible
                    .take()
                    .expect("Prefill forced only with a popped request");
                // Swap-first resume ladder: restore host-swapped blocks
                // with zero policy work; recompute only when the handle
                // is gone (dropped under host-memory pressure).
                let req = match try_resume(req, store.as_mut(), metrics) {
                    Resume::Restored(a) => {
                        active.push(a);
                        None
                    }
                    Resume::Busy(mut req) => {
                        if active.is_empty() {
                            // Nothing decoding, so the pool can never
                            // improve on its own: drop the entry and
                            // recompute-resume right now rather than
                            // livelock.
                            if let Some(sr) = req.swap.take() {
                                store.as_mut().swap_drop(sr.handle);
                                metrics
                                    .inc(names::SWAP_FALLBACK_RECOMPUTE, 1);
                            }
                            Some(req)
                        } else {
                            metrics.inc(names::ADMIT_DEFERRED, 1);
                            metrics.tracer().record(
                                req.id,
                                req.tenant,
                                NO_LANE,
                                EventKind::AdmitDeferred,
                            );
                            sched.requeue_front(req);
                            admission_paused = true;
                            None
                        }
                    }
                    Resume::Recompute(req) => Some(req),
                };
                // Chunk-capable requests divert into the chunking lane
                // instead of the blocking admit below; everything else
                // falls through unchanged.
                let req = match req {
                    Some(mut req) => {
                        if let Some((ch, secs)) = req.resume_chunking() {
                            // Parked mid-chunking: resume from the
                            // completed-chunk boundary. Zero chunks are
                            // re-run, so this recompute-mode resume
                            // deliberately does NOT count
                            // PREFILL_RECOMPUTED (pinned by the
                            // chunked-serve suite).
                            let tracer = metrics.tracer();
                            tracer.record(
                                req.id,
                                req.tenant,
                                NO_LANE,
                                EventKind::Resume {
                                    mode: ResumeMode::Recompute,
                                },
                            );
                            tracer.record(
                                req.id,
                                req.tenant,
                                NO_LANE,
                                EventKind::PrefillStart {
                                    tokens: (req.prompt.len()
                                        + req.resumed.len())
                                        as u32,
                                },
                            );
                            chunking = Some(PrefillInProgress {
                                req,
                                ch,
                                prefill_secs: secs,
                                decode_credit: 0,
                            });
                            None
                        } else if chunking.is_none()
                            && !req.has_carried_prefill()
                        {
                            // Fresh (or recompute-resume) prefill: let a
                            // chunk-capable policy take it incrementally.
                            let full_prompt: Vec<i32> =
                                if req.resumed.is_empty() {
                                    req.prompt.clone()
                                } else {
                                    let mut p = req.prompt.clone();
                                    p.extend_from_slice(&req.resumed);
                                    p
                                };
                            match policy.begin_chunked(
                                &man,
                                &full_prompt,
                                &cfg.policy_cfg,
                            ) {
                                Some(Ok(ch)) => {
                                    note_prefill_start(
                                        &mut req,
                                        metrics,
                                        full_prompt.len(),
                                    );
                                    chunking = Some(PrefillInProgress {
                                        req,
                                        ch,
                                        prefill_secs: 0.0,
                                        decode_credit: 0,
                                    });
                                    None
                                }
                                Some(Err(e)) => {
                                    reject(
                                        req,
                                        store.as_mut(),
                                        metrics,
                                        format!("{e:#}"),
                                    );
                                    None
                                }
                                None => Some(req),
                            }
                        } else {
                            Some(req)
                        }
                    }
                    None => None,
                };
                if let Some(req) = req {
                    // A blocking monolithic prefill while lanes are
                    // decoding is exactly the stall chunked prefill
                    // exists to eliminate (deferred admissions carry
                    // their finished prefill and cost only the
                    // store.admit retry, so they don't count).
                    if !req.has_carried_prefill() && !active.is_empty() {
                        metrics.inc(names::DECODE_STALL_STEPS, 1);
                    }
                    match admit(
                        rt,
                        &man,
                        policy.as_ref(),
                        cfg,
                        req,
                        store.as_mut(),
                        metrics,
                    ) {
                        Ok(a) => {
                            if a.done {
                                // Resumed request already at its token
                                // budget (or END on the first token):
                                // respond now rather than dragging it
                                // through a decode step that must ignore
                                // it.
                                finish(a, store.as_mut(), metrics);
                            } else {
                                active.push(a);
                            }
                        }
                        Err(AdmitFail::Defer(req)) => {
                            // The pool could not take the cache; the
                            // finished prefill rides with the request so
                            // the retry is admission-only. With nothing
                            // active the pool can never improve, so
                            // reject instead of livelocking; with
                            // actives, pause admission for one iteration
                            // so the loop decodes (and frees blocks)
                            // instead of hot-spinning on admit-then-defer.
                            if active.is_empty() {
                                reject(
                                    req,
                                    store.as_mut(),
                                    metrics,
                                    "request cannot fit the KV block pool"
                                        .into(),
                                );
                            } else {
                                metrics.inc(names::ADMIT_DEFERRED, 1);
                                metrics.tracer().record(
                                    req.id,
                                    req.tenant,
                                    NO_LANE,
                                    EventKind::AdmitDeferred,
                                );
                                sched.requeue_front(req);
                                admission_paused = true;
                            }
                        }
                        Err(AdmitFail::Reject(req, e)) => {
                            reject(
                                req,
                                store.as_mut(),
                                metrics,
                                format!("{e:#}"),
                            );
                        }
                    }
                }
            }
            Action::PrefillChunk => {
                let mut pip = chunking
                    .take()
                    .expect("PrefillChunk chosen only with a chunking lane");
                let idx = pip.ch.chunks_done() as u32;
                let t0 = Instant::now();
                match pip.ch.step(rt, &man) {
                    Ok(tokens) => {
                        let secs = t0.elapsed().as_secs_f64();
                        pip.prefill_secs += secs;
                        metrics.observe(names::PREFILL_CHUNK_SECS, secs);
                        metrics.inc(names::PREFILL_CHUNKS_TOTAL, 1);
                        metrics.tracer().record(
                            pip.req.id,
                            pip.req.tenant,
                            NO_LANE,
                            EventKind::PrefillChunk {
                                index: idx,
                                tokens: tokens as u32,
                            },
                        );
                        if pip.ch.chunks_done() == pip.ch.total_chunks() {
                            // Last chunk done: run the tail (TSP
                            // selection, stage 2, compression — exactly
                            // once) and hand the outcome back to the
                            // queue as a carried prefill, so the very
                            // next sweep admits it through the deferred-
                            // admission path (store.admit only).
                            let t1 = Instant::now();
                            match pip.ch.finish(rt, &man) {
                                Ok(outcome) => {
                                    let total = pip.prefill_secs
                                        + t1.elapsed().as_secs_f64();
                                    metrics
                                        .observe(names::PREFILL_SECS, total);
                                    metrics.tracer().record(
                                        pip.req.id,
                                        pip.req.tenant,
                                        NO_LANE,
                                        EventKind::PrefillEnd {
                                            kept_rows: outcome
                                                .cache
                                                .max_len()
                                                as u32,
                                        },
                                    );
                                    let mut req = pip.req;
                                    req.carry_prefill(outcome, total);
                                    sched.requeue_front(req);
                                }
                                Err(e) => reject(
                                    pip.req,
                                    store.as_mut(),
                                    metrics,
                                    format!("{e:#}"),
                                ),
                            }
                        } else {
                            // More chunks to go: owe the active lanes
                            // their decode rounds before the next one.
                            pip.decode_credit =
                                cfg.policy_cfg.prefill_decode_ratio;
                            chunking = Some(pip);
                        }
                    }
                    Err(e) => reject(
                        pip.req,
                        store.as_mut(),
                        metrics,
                        format!("{e:#}"),
                    ),
                }
            }
            Action::DecodeStep => {
                let out = decode_step(
                    rt,
                    &batch,
                    store.as_ref(),
                    &active,
                    metrics,
                    &mut scratch,
                )?;
                apply_decode(
                    cfg,
                    &man,
                    store.as_mut(),
                    &mut sched,
                    &mut active,
                    &out,
                    metrics,
                );
                // Retire finished requests.
                let mut i = 0;
                while i < active.len() {
                    if active[i].done
                        || active[i].tokens.len() >= active[i].max_new()
                    {
                        let a = active.swap_remove(i);
                        finish(a, store.as_mut(), metrics);
                    } else {
                        i += 1;
                    }
                }
            }
            Action::Idle => {
                // Queue blocked on memory with nothing active. A swapped
                // request deserves one resume attempt first (its gate may
                // have been conservative — prefix sharing can make the
                // actual restore cheaper); anything else can never fit.
                if !admit_ok && active.is_empty() && sched.queue_len() > 0 {
                    let req = sched.pop_next(|r| r.prompt.len()).unwrap();
                    match try_resume(req, store.as_mut(), metrics) {
                        // Conservative gate, real restore: prefix sharing
                        // can make the actual swap-in cheaper than the
                        // no-sharing estimate.
                        Resume::Restored(a) => active.push(a),
                        Resume::Busy(mut req) => {
                            // The swapped blocks cannot fit even a
                            // drained pool: the entry is useless. Drop it
                            // and give recompute-resume one shot — its
                            // re-run policy re-compresses the generated
                            // tokens too, so its footprint can be smaller
                            // than the swapped one.
                            if let Some(sr) = req.swap.take() {
                                store.as_mut().swap_drop(sr.handle);
                                metrics
                                    .inc(names::SWAP_FALLBACK_RECOMPUTE, 1);
                            }
                            if admit_gate(cfg, &man, store.as_ref(), &req) {
                                sched.requeue_front(req);
                            } else {
                                reject(
                                    req,
                                    store.as_mut(),
                                    metrics,
                                    "request cannot fit the KV block pool"
                                        .into(),
                                );
                            }
                        }
                        // Never swapped (or already fell back): the
                        // recompute gate itself said no — the pool will
                        // never improve, fail fast.
                        Resume::Recompute(req) => {
                            reject(
                                req,
                                store.as_mut(),
                                metrics,
                                "request cannot fit the KV block pool".into(),
                            );
                        }
                    }
                }
            }
        }
        publish_pool_gauges(store.as_ref(), metrics);
        metrics.set_gauge(
            names::RESUME_QUEUE_DEPTH,
            sched.resume_len() as f64,
        );
        iter += 1;
        if cfg.obs.export_every > 0 && iter % cfg.obs.export_every == 0 {
            export_obs(&cfg.obs, metrics, false);
        }
    }
    export_obs(&cfg.obs, metrics, true);
    Ok(())
}

impl Active {
    fn max_new(&self) -> usize {
        self.req.max_new
    }
}

pub enum AdmitFail {
    /// Permanent failure: send an error response.
    Reject(Request, anyhow::Error),
    /// Pool momentarily too full: requeue and retry after decode frees
    /// blocks. The completed prefill rides along inside the request
    /// (`PendingPrefill`), so the retry costs only a `store.admit`.
    Defer(Request),
}

/// Token list + finished flag for a request right after (re-)admission.
/// A request already at its budget — fully generated before a
/// preemption, or `max_new == 0` — is finished *as-is*: the freshly
/// decoded first token must NOT be appended (doing so used to emit
/// `max_new + 1` tokens) and the lane must never grow the cache.
pub fn resume_admit_state(
    resumed: &[i32],
    first_token: i32,
    max_new: usize,
) -> (Vec<i32>, bool) {
    let mut tokens = resumed.to_vec();
    if tokens.len() >= max_new {
        return (tokens, true);
    }
    tokens.push(first_token);
    let done = first_token == END as i32 || tokens.len() >= max_new;
    (tokens, done)
}

/// Pre-prefill bookkeeping shared by the blocking [`admit`] path and the
/// chunked begin in the serve loop: recompute-resume accounting when
/// this prefill re-does paid-for work (or the first queue-wait
/// observation when it doesn't), then the PrefillStart event. Marks the
/// request prefilled so a later preemption knows its prefill is sunk
/// cost. A chunk-boundary resume does NOT come through here — it re-runs
/// zero chunks, so it is not a recompute.
fn note_prefill_start(req: &mut Request, metrics: &Metrics, tokens: usize) {
    let tracer = metrics.tracer();
    if req.prefilled {
        // Recompute-resume (or a deferral that lost its carried
        // prefill — which the carry exists to prevent): this prefill is
        // paid-for work being re-done. Every recompute path funnels
        // through here (dropped handle, refused swap, busy fallback),
        // so the resume event and its incident are recorded here.
        metrics.inc(names::PREFILL_RECOMPUTED, 1);
        tracer.record(
            req.id,
            req.tenant,
            NO_LANE,
            EventKind::Resume { mode: ResumeMode::Recompute },
        );
        tracer.incident(IncidentKind::RecomputeResume, req.id, req.tenant);
    } else {
        // First prefill for this request: everything since submission
        // was queue wait.
        metrics.observe(
            names::QUEUE_WAIT_SECS,
            req.submitted.elapsed().as_secs_f64(),
        );
    }
    tracer.record(
        req.id,
        req.tenant,
        NO_LANE,
        EventKind::PrefillStart { tokens: tokens as u32 },
    );
    req.prefilled = true;
}

/// Prefill (or reuse a carried prefill) and load the request's cache
/// into the store. Public so tests can drive the real admission path
/// with a stub policy and no PJRT runtime.
pub fn admit(
    ex: &dyn Exec,
    man: &Manifest,
    policy: &dyn Policy,
    cfg: &ServerConfig,
    mut req: Request,
    store: &mut dyn KvStore,
    metrics: &Metrics,
) -> std::result::Result<Active, AdmitFail> {
    if req.prompt.len() > cfg.max_prompt {
        return Err(AdmitFail::Reject(
            req,
            anyhow::anyhow!("prompt exceeds max_prompt {}", cfg.max_prompt),
        ));
    }
    let tracer = metrics.tracer();
    let (pre, prefill_secs) = match req.pending.take() {
        // Deferred admission: the prefill already ran — only the
        // `store.admit` below is retried.
        Some(p) => (p.outcome, p.prefill_secs),
        None => {
            // Recompute-resume re-prefills the original prompt plus
            // everything generated before the preemption.
            let full_prompt: Vec<i32> = if req.resumed.is_empty() {
                req.prompt.clone()
            } else {
                let mut p = req.prompt.clone();
                p.extend_from_slice(&req.resumed);
                p
            };
            note_prefill_start(&mut req, metrics, full_prompt.len());
            let t0 = Instant::now();
            let pre =
                match policy.prefill(ex, man, &full_prompt, &cfg.policy_cfg) {
                    Ok(p) => p,
                    Err(e) => return Err(AdmitFail::Reject(req, e)),
                };
            let secs = t0.elapsed().as_secs_f64();
            metrics.observe(names::PREFILL_SECS, secs);
            tracer.record(
                req.id,
                req.tenant,
                NO_LANE,
                EventKind::PrefillEnd {
                    kept_rows: pre.cache.max_len() as u32,
                },
            );
            (pre, secs)
        }
    };
    let slot = match store.admit_for(&pre.cache, req.tenant) {
        Some(s) => s,
        None => {
            req.pending = Some(PendingPrefill { outcome: pre, prefill_secs });
            return Err(AdmitFail::Defer(req));
        }
    };
    tracer.record(
        req.id,
        req.tenant,
        slot as i32,
        EventKind::Admit { blocks_held: store.held_blocks(slot) as u32 },
    );
    let ttft = Some(
        req.first_ttft
            .unwrap_or_else(|| req.submitted.elapsed().as_secs_f64()),
    );
    let (tokens, done) =
        resume_admit_state(&req.resumed, pre.first_token, req.max_new);
    Ok(Active {
        pos: pre.next_pos,
        cur: pre.first_token,
        tokens,
        slot,
        req,
        prefill_secs,
        ttft_secs: ttft,
        done,
    })
}

fn decode_step(
    rt: &Runtime,
    batch: &DecodeBatch,
    store: &dyn KvStore,
    active: &[Active],
    metrics: &Metrics,
    scratch: &mut DecodeScratch,
) -> Result<DecodeOut> {
    let lanes: Vec<LaneInput> = active
        .iter()
        .map(|a| LaneInput { slot: a.slot, token: a.cur, pos: a.pos })
        .collect();
    let t0 = Instant::now();
    let out = batch
        .step_scratch(rt, store, &lanes, Some(metrics), scratch)
        .context("decode step")?;
    metrics.observe(names::DECODE_STEP_SECS, t0.elapsed().as_secs_f64());
    Ok(out)
}

/// Core resumability test (public for the preemption edge-case tests):
/// the re-prefill of `full_len = prompt + generated` tokens must fit the
/// policy's prefill buckets, and the store must be able to take the
/// regrown cache back even from a drained state (lane capacity AND total
/// pool size, judged within the *tenant's* quota — another tenant's
/// reserved floor is never coming back). Deliberately judged on the
/// *recompute* fallback even when swap is enabled — a swap handle can be
/// dropped under host-memory pressure at any time, so a victim that
/// could only resume via swap would risk ending in rejection.
pub fn can_resume_parts(
    full_len: usize,
    len_limit: usize,
    per_layer_budget: usize,
    tenant: TenantId,
    store: &dyn KvStore,
) -> bool {
    full_len <= len_limit
        && store.could_ever_admit_for(per_layer_budget, tenant)
}

/// Whether a lane could resume after preemption (see
/// [`can_resume_parts`]).
fn can_resume(
    cfg: &ServerConfig,
    man: &Manifest,
    a: &Active,
    store: &dyn KvStore,
) -> bool {
    let full_len = a.req.prompt.len() + a.tokens.len();
    let budget = cfg.policy_cfg.per_layer_budget(
        &cfg.policy,
        full_len,
        man.model.window,
    );
    let len_limit =
        prefill_len_limit(man, &cfg.policy, &cfg.policy_cfg);
    can_resume_parts(full_len, len_limit, budget, a.req.tenant, store)
}

/// Preempt the lane at `idx` and park its request on the resume queue.
/// Fast path: the lane's FastKV-selected blocks are swapped to host
/// (within the lane tenant's swap byte budget) and the [`SwapHandle`] +
/// decode cursor ride with the request, so resume is a block restore —
/// no policy re-run. Fallback (swap disabled or over budget): release
/// the blocks and carry only the generated tokens for recompute-resume.
/// A lane that already spent its token budget is finished on the spot
/// instead of parked — re-admitting it could only emit tokens past
/// `max_new`. Order-preserving removal so the caller's scan index stays
/// meaningful.
pub fn preempt(
    active: &mut Vec<Active>,
    idx: usize,
    store: &mut dyn KvStore,
    sched: &mut Scheduler<Request>,
    metrics: &Metrics,
) {
    let a = active.remove(idx);
    if a.tokens.len() >= a.req.max_new {
        finish(a, store, metrics);
        return;
    }
    metrics.inc(names::PREEMPTED, 1);
    metrics.inc(&names::tenant_preempted(a.req.tenant), 1);
    let Active { mut req, slot, tokens, cur, pos, ttft_secs, .. } = a;
    req.first_ttft = ttft_secs;
    req.resumed = tokens;
    let tracer = metrics.tracer();
    // Payload computation (swap-bytes delta) is gated on `is_enabled` so
    // the traced-off path stays a branch.
    let traced = tracer.is_enabled();
    let swap_before =
        if traced { store.swap_stats().used_bytes } else { 0 };
    let t0 = Instant::now();
    match store.swap_out(slot) {
        Some(handle) => {
            // Blocks are on host; the lane's pool blocks were released
            // by `swap_out` itself.
            metrics.inc(names::SWAP_OUTS, 1);
            metrics
                .observe(names::SWAP_OUT_SECS, t0.elapsed().as_secs_f64());
            if traced {
                tracer.record(
                    req.id,
                    req.tenant,
                    slot as i32,
                    EventKind::Preempt {
                        mode: ResumeMode::Swap,
                        generated: req.resumed.len() as u32,
                    },
                );
                let bytes = store
                    .swap_stats()
                    .used_bytes
                    .saturating_sub(swap_before);
                tracer.record(
                    req.id,
                    req.tenant,
                    NO_LANE,
                    EventKind::SwapOut { bytes: bytes as u64 },
                );
            }
            req.swap = Some(SwapResume { handle, cur, pos });
        }
        None => {
            // Swap disabled or budget exhausted: recompute-resume.
            store.release(slot);
            metrics.inc(names::SWAP_REFUSED, 1);
            if traced {
                tracer.record(
                    req.id,
                    req.tenant,
                    slot as i32,
                    EventKind::Preempt {
                        mode: ResumeMode::Recompute,
                        generated: req.resumed.len() as u32,
                    },
                );
                tracer.incident(
                    IncidentKind::SwapRefused,
                    req.id,
                    req.tenant,
                );
            }
            req.swap = None;
        }
    }
    sched.requeue_front(req);
}

/// Attempted resume outcome for a request popped off the resume queue.
pub enum Resume {
    /// KV restored from the host swap arena; decode continues exactly
    /// where it stopped with zero prefill work.
    Restored(Active),
    /// No swap entry to restore (never swapped, or the handle was
    /// dropped under budget pressure): fall back to recompute-resume.
    Recompute(Request),
    /// Lane or pool momentarily full; retry after decode frees memory.
    Busy(Request),
}

/// Swap-first resume: restore a preempted request's host-swapped KV if
/// it has any, skipping the policy prefill entirely.
pub fn try_resume(
    mut req: Request,
    store: &mut dyn KvStore,
    metrics: &Metrics,
) -> Resume {
    let Some(sr) = req.swap else { return Resume::Recompute(req) };
    let t0 = Instant::now();
    match store.swap_in(sr.handle) {
        SwapIn::Restored(slot) => {
            metrics.inc(names::SWAP_INS, 1);
            metrics
                .observe(names::SWAP_IN_SECS, t0.elapsed().as_secs_f64());
            metrics.tracer().record(
                req.id,
                req.tenant,
                slot as i32,
                EventKind::Resume { mode: ResumeMode::Swap },
            );
            req.swap = None;
            let tokens = std::mem::take(&mut req.resumed);
            // `done` is always false here: fully-generated lanes are
            // finished at preemption time, never parked (see `preempt`).
            Resume::Restored(Active {
                slot,
                tokens,
                cur: sr.cur,
                pos: sr.pos,
                prefill_secs: 0.0,
                ttft_secs: req.first_ttft,
                done: false,
                req,
            })
        }
        SwapIn::Busy => Resume::Busy(req),
        SwapIn::Gone => {
            metrics.inc(names::SWAP_FALLBACK_RECOMPUTE, 1);
            req.swap = None;
            Resume::Recompute(req)
        }
    }
}

/// Decode-progress events are sampled once per this many generated
/// tokens per lane — a per-step event would hold a third of a 64k ring
/// after one 20k-token batch.
const DECODE_TRACE_EVERY: usize = 4;

/// Apply one decode step's outputs through the shared lane stepper:
/// append + sample per lane, compacting under pool pressure; when
/// compaction cannot free enough, preempt the least-progress resumable
/// lane (which may be another lane than the one that hit the wall) and
/// retry.
fn apply_decode(
    cfg: &ServerConfig,
    man: &Manifest,
    store: &mut dyn KvStore,
    sched: &mut Scheduler<Request>,
    active: &mut Vec<Active>,
    out: &DecodeOut,
    metrics: &Metrics,
) {
    let spec = CompactSpec {
        policy_cfg: &cfg.policy_cfg,
        shrink: COMPACT_SHRINK,
        window: man.model.window,
        metrics: Some(metrics),
    };
    let mut i = 0;
    while i < active.len() {
        if active[i].done {
            // Already finished (max_new reached on resume, or END) —
            // never grow the cache or sample past the end; the retire
            // loop collects it right after this pass.
            i += 1;
            continue;
        }
        let slot = active[i].slot;
        // Policy compaction fires at most ONCE per lane per step (the
        // first attempt); victim-preemption retries must not compound
        // shrink^k eviction onto the same lane within a single step.
        let mut allow_compact = true;
        loop {
            let spec_opt = if allow_compact { Some(&spec) } else { None };
            // Compactions happen inside `advance_lane`; diff the counter
            // around the call to attribute them to this lane. Gated on
            // `is_enabled` so the traced-off step adds two branches, not
            // two registry reads.
            let traced = metrics.tracer().is_enabled();
            let compactions_before = if traced {
                metrics.counter(names::COMPACTIONS)
            } else {
                0
            };
            match advance_lane(store, slot, out, spec_opt) {
                adv @ (LaneAdvance::Next { .. }
                | LaneAdvance::CapacityStop) => {
                    if traced {
                        let a = &active[i];
                        if metrics.counter(names::COMPACTIONS)
                            > compactions_before
                        {
                            metrics.tracer().record(
                                a.req.id,
                                a.req.tenant,
                                slot as i32,
                                EventKind::Compact,
                            );
                        }
                        if a.tokens.len() % DECODE_TRACE_EVERY == 1 {
                            metrics.tracer().record(
                                a.req.id,
                                a.req.tenant,
                                slot as i32,
                                EventKind::DecodeStep {
                                    step: a.pos as u32,
                                    tokens_out: a.tokens.len() as u32,
                                },
                            );
                        }
                    }
                    active[i].apply(adv);
                    i += 1;
                    break;
                }
                LaneAdvance::PoolPressure => {
                    allow_compact = false;
                    // Victim selection among every lane that can actually
                    // resume — not necessarily the lane that hit pool
                    // exhaustion: over-quota tenants' lanes first (quota
                    // pressure lands on whoever is bursting), then least
                    // decode progress, then fewest held blocks. Lanes
                    // whose preemption cannot relieve the pressured
                    // tenant (cross-tenant frees when it is
                    // ceiling-bound, or victims inside their own
                    // protected floor whose frees are owed back to that
                    // floor) are filtered out up front, so innocent
                    // lanes are never churned for a denial their blocks
                    // cannot fix (`KvStore::preempt_helps`).
                    let pressured = active[i].req.tenant;
                    let mut candidates: Vec<(usize, (bool, usize, usize))> =
                        Vec::new();
                    for (j, a) in active.iter().enumerate() {
                        if !a.done
                            && store.preempt_helps(a.req.tenant, pressured)
                            && can_resume(cfg, man, a, store)
                        {
                            candidates.push((
                                j,
                                (
                                    store.tenant_over_quota(a.req.tenant),
                                    a.tokens.len(),
                                    store.held_blocks(a.slot),
                                ),
                            ));
                        }
                    }
                    let keys: Vec<(bool, usize, usize)> =
                        candidates.iter().map(|&(_, k)| k).collect();
                    let victim = pick_preemption_victim(&keys)
                        .map(|k| candidates[k].0);
                    match victim {
                        Some(v) if v != i => {
                            preempt(active, v, store, sched, metrics);
                            if v < i {
                                i -= 1; // removal shifted this lane left
                            }
                            // retry the pressured lane with freed blocks
                        }
                        Some(_) => {
                            // this lane is itself the cheapest victim; the
                            // next lane slides into index i
                            preempt(active, i, store, sched, metrics);
                            break;
                        }
                        None => {
                            // Nobody can resume: finish gracefully with
                            // what was generated (like a capacity stop)
                            // instead of parking a request that would end
                            // in rejection.
                            metrics.inc(names::FINISHED_ON_PRESSURE, 1);
                            active[i].done = true;
                            i += 1;
                            break;
                        }
                    }
                }
            }
        }
    }
}
