//! The serving coordinator: a continuous-batching inference server over a
//! paged KV cache.
//!
//! One serving thread owns the (non-Send) PJRT runtime and drives the
//! loop: admit → prefill (policy compresses KV) → batched decode steps →
//! retire. Clients submit prompts from any thread through `ServerHandle`
//! and receive a `Response` on a per-request channel.
//!
//! Decode KV lives behind the [`KvStore`] trait; the default backend is
//! the paged [`PagedArena`] (block pool + prefix reuse), with the flat
//! [`BatchArena`] available for comparison. On top of the store the loop
//! implements:
//!
//!  * **memory-aware admission** — a queued request is admitted only when
//!    the block pool can cover its post-compression KV budget plus decode
//!    growth (`Scheduler::next_action_mem`);
//!  * **block-granular compaction** — on pool exhaustion mid-decode the
//!    affected lane first evicts by blocks using the policy's per-layer
//!    keep-sets (`PolicyCfg::compaction_keep`);
//!  * **preemption with resume** — if compaction cannot free enough, the
//!    *least-progress resumable lane* (fewest generated tokens, ties to
//!    fewest held blocks — `scheduler::pick_preemption_victim`) releases
//!    its blocks and returns to the head of the queue; on re-admission it
//!    re-prefills `prompt ++ generated-so-far` and continues where it
//!    left off instead of aborting.
//!
//! Decode steps go through the shared [`DecodeBatch`] planner: block-table
//! native (`decode_paged_{B}x{C}`, slab + table indices) whenever the
//! store and manifest support it, dense staged bridge otherwise.
//!
//! Block-pool gauges (blocks in use, prefix-cache hit rate, preemptions)
//! are published through [`Metrics`] every scheduler iteration.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::decode::{
    advance_lane, CompactSpec, DecodeBatch, DecodePath, LaneAdvance,
    LaneInput,
};
use crate::coordinator::engine::decode_cap_for;
use crate::coordinator::kvcache::BatchArena;
use crate::coordinator::paging::{KvStore, PagedArena, PagingConfig};
use crate::coordinator::policies::{make_policy, PolicyCfg};
use crate::coordinator::scheduler::{
    pick_preemption_victim, Action, AdmitOrder, Scheduler,
};
use crate::manifest::Manifest;
use crate::metrics::Metrics;
use crate::runtime::outputs::DecodeOut;
use crate::runtime::Runtime;
use crate::tokenizer::END;

/// Shrink factor compaction applies to each layer's length when the pool
/// runs dry (keep-sets never drop the observation window or sinks).
const COMPACT_SHRINK: f64 = 0.5;

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub artifact_dir: std::path::PathBuf,
    pub policy: String,
    pub policy_cfg: PolicyCfg,
    /// Decode batch size (must be one of the compiled decode buckets).
    pub decode_batch: usize,
    /// Max tokens generated per request.
    pub max_new: usize,
    /// Largest prompt admitted (bucket-limited).
    pub max_prompt: usize,
    pub order: AdmitOrder,
    /// KV backend: `Some(cfg)` = paged arena (the default), `None` = the
    /// flat `BatchArena` (seed behavior, for comparison).
    pub paging: Option<PagingConfig>,
}

#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    submitted: Instant,
    reply: mpsc::Sender<Response>,
    /// Tokens generated before a preemption; re-prefilled as part of the
    /// prompt on resume so generation continues seamlessly.
    resumed: Vec<i32>,
    /// TTFT measured at first admission, preserved across preemptions.
    first_ttft: Option<f64>,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub ttft_secs: f64,
    pub e2e_secs: f64,
    pub prefill_secs: f64,
    pub decode_steps: usize,
    pub error: Option<String>,
}

enum Msg {
    Submit(Request),
    Shutdown,
}

#[derive(Clone)]
pub struct ServerHandle {
    tx: mpsc::Sender<Msg>,
    next_id: Arc<std::sync::atomic::AtomicU64>,
    pub metrics: Arc<Metrics>,
}

impl ServerHandle {
    /// Submit a prompt; returns a receiver for the final response.
    pub fn submit(
        &self,
        prompt: Vec<i32>,
        max_new: usize,
    ) -> Result<(u64, mpsc::Receiver<Response>)> {
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Submit(Request {
                id,
                prompt,
                max_new,
                submitted: Instant::now(),
                reply,
                resumed: Vec::new(),
                first_ttft: None,
            }))
            .map_err(|_| anyhow::anyhow!("server thread gone"))?;
        Ok((id, rx))
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Msg::Shutdown);
    }
}

pub struct Server {
    handle: ServerHandle,
    join: Option<std::thread::JoinHandle<()>>,
}

struct Active {
    req: Request,
    slot: usize,
    tokens: Vec<i32>,
    cur: i32,
    pos: usize,
    prefill_secs: f64,
    ttft_secs: f64,
    done: bool,
}

impl Server {
    pub fn spawn(cfg: ServerConfig) -> Result<Server> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let metrics = Arc::new(Metrics::default());
        let m2 = metrics.clone();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("fastkv-server".into())
            .spawn(move || serve_loop(cfg, rx, m2, ready_tx))?;
        ready_rx.recv()??;
        Ok(Server {
            handle: ServerHandle {
                tx,
                next_id: Arc::new(std::sync::atomic::AtomicU64::new(1)),
                metrics,
            },
            join: Some(join),
        })
    }

    pub fn handle(&self) -> ServerHandle {
        self.handle.clone()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.handle.shutdown();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn serve_loop(
    cfg: ServerConfig,
    rx: mpsc::Receiver<Msg>,
    metrics: Arc<Metrics>,
    ready: mpsc::Sender<Result<()>>,
) {
    let rt = match Runtime::new(&cfg.artifact_dir) {
        Ok(rt) => {
            let _ = ready.send(Ok(()));
            rt
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    if let Err(e) = serve_inner(&cfg, &rt, rx, &metrics) {
        eprintln!("[server] fatal: {e:#}");
    }
}

fn reject(mut req: Request, metrics: &Metrics, why: String) {
    metrics.inc("rejected", 1);
    let tokens = std::mem::take(&mut req.resumed);
    let _ = req.reply.send(Response {
        id: req.id,
        tokens,
        ttft_secs: req.first_ttft.unwrap_or(0.0),
        e2e_secs: req.submitted.elapsed().as_secs_f64(),
        prefill_secs: 0.0,
        decode_steps: 0,
        error: Some(why),
    });
}

/// Largest prompt the policy's prefill path can bucket. Resume-by-
/// recompute re-prefills `prompt ++ generated`, so a request may only be
/// preempted while that combined length still fits — otherwise it could
/// never be re-admitted.
fn prefill_len_limit(man: &Manifest, policy: &str, use_pallas: bool) -> usize {
    let max = |v: &[usize]| v.iter().copied().max().unwrap_or(0);
    match policy {
        "fastkv" | "gemfilter" => max(&man.buckets.stage1_ns),
        "pyramid_infer" => max(&man.buckets.pyramid_ns),
        _ => {
            // run_prefill_full can also take the Pallas artifact, whose
            // bucket may exceed the jnp prefill buckets.
            let lim = max(&man.buckets.prefill_ns);
            if use_pallas {
                lim.max(man.buckets.pallas_n)
            } else {
                lim
            }
        }
    }
}

/// Retire a finished request: release its lane and send the response.
fn finish(a: Active, store: &mut dyn KvStore, metrics: &Metrics) {
    store.release(a.slot);
    metrics.inc("completed", 1);
    metrics.observe("e2e_secs", a.req.submitted.elapsed().as_secs_f64());
    metrics.observe("ttft_secs", a.ttft_secs);
    metrics.inc("tokens_out", a.tokens.len() as u64);
    let _ = a.req.reply.send(Response {
        id: a.req.id,
        tokens: a.tokens,
        ttft_secs: a.ttft_secs,
        e2e_secs: a.req.submitted.elapsed().as_secs_f64(),
        prefill_secs: a.prefill_secs,
        decode_steps: a.pos,
        error: None,
    });
}

fn publish_pool_gauges(store: &dyn KvStore, metrics: &Metrics) {
    let ps = store.pool_stats();
    metrics.set_gauge("pool_blocks_total", ps.blocks_total as f64);
    metrics.set_gauge("pool_blocks_in_use", ps.blocks_in_use as f64);
    // High-water mark: the instantaneous gauge reads 0 once the pool
    // drains, so peak utilization gets its own gauge.
    let peak = metrics
        .gauge("pool_blocks_in_use_peak")
        .max(ps.blocks_in_use as f64);
    metrics.set_gauge("pool_blocks_in_use_peak", peak);
    metrics.set_gauge("pool_blocks_cached", ps.blocks_cached as f64);
    metrics.set_gauge("pool_prefix_hits", ps.prefix_hits as f64);
    metrics.set_gauge("pool_prefix_misses", ps.prefix_misses as f64);
    metrics.set_gauge("pool_prefix_hit_rate", ps.prefix_hit_rate());
    metrics.set_gauge("pool_cow_copies", ps.cow_copies as f64);
    metrics.set_gauge("pool_evictions", ps.evictions as f64);
    metrics.set_gauge("pool_alloc_failures", ps.alloc_failures as f64);
}

fn serve_inner(
    cfg: &ServerConfig,
    rt: &Runtime,
    rx: mpsc::Receiver<Msg>,
    metrics: &Metrics,
) -> Result<()> {
    let man = rt.manifest.clone();
    let policy = make_policy(&cfg.policy)?;
    // Worst-case per-layer retention for the largest admissible prompt —
    // sizes the decode capacity bucket.
    let worst = cfg.policy_cfg.per_layer_budget(
        &cfg.policy,
        cfg.max_prompt,
        man.model.window,
    );
    let cap = decode_cap_for(&man, worst, cfg.max_new)?;
    let b = cfg.decode_batch;
    anyhow::ensure!(
        man.buckets.decode_batches.contains(&b),
        "decode batch {b} not compiled (buckets: {:?})",
        man.buckets.decode_batches
    );
    let batch = DecodeBatch::new(&man, b, cap);
    let mut store: Box<dyn KvStore> = match &cfg.paging {
        Some(pc) => {
            Box::new(PagedArena::new(&man.model, b, cap, pc.clone()))
        }
        None => Box::new(BatchArena::new(&man.model, b, cap)),
    };
    // Surface the decode path once: a paged store silently pinned to the
    // dense bridge (block-size mismatch, pool larger than the artifact's
    // slab bucket, or a manifest without decode_paged artifacts) is the
    // O(cap)-per-token regression this stack exists to avoid — make it
    // loud rather than discoverable only via the step counters.
    let block_table = batch.path_for(store.as_ref()) == DecodePath::BlockTable;
    metrics.set_gauge("decode_block_table", if block_table { 1.0 } else { 0.0 });
    let wants_block_table =
        cfg.paging.as_ref().map(|p| !p.dense_staging).unwrap_or(false);
    if wants_block_table && !block_table {
        eprintln!(
            "[server] block-table decode unavailable — falling back to the \
             dense staged bridge via `{}` (check block_tokens vs the \
             manifest's, pool size vs the artifact slab bucket, and that \
             the artifact dir carries decode_paged_{b}x{cap})",
            batch.artifact_for(store.as_ref())
        );
    }
    let mut sched: Scheduler<Request> = Scheduler::new(b, cfg.order);
    let mut active: Vec<Active> = Vec::new();
    let mut shutdown = false;
    // Set after a deferred admission: forces one decode pass before the
    // next admission attempt so the loop cannot hot-spin on
    // prefill-then-defer while the pool estimate and reality disagree.
    let mut admission_paused = false;

    while !(shutdown && sched.queue_len() == 0 && active.is_empty()) {
        // Drain incoming messages (non-blocking if we have work).
        loop {
            let msg = if active.is_empty() && sched.queue_len() == 0 {
                if shutdown {
                    break;
                }
                match rx.recv() {
                    Ok(m) => m,
                    Err(_) => {
                        shutdown = true;
                        break;
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(_) => break,
                }
            };
            match msg {
                Msg::Submit(r) => {
                    metrics.inc("submitted", 1);
                    sched.enqueue(r);
                }
                Msg::Shutdown => shutdown = true,
            }
        }
        if shutdown && sched.queue_len() == 0 && active.is_empty() {
            break;
        }

        // Memory-aware admission: can the pool cover the head request's
        // post-compression budget (plus minimal growth headroom — see
        // `KvStore::can_admit`; full decode growth is over-committed)?
        let admit_ok = if std::mem::take(&mut admission_paused) {
            false
        } else {
            match sched.peek_next(|r: &Request| r.prompt.len()) {
                None => true,
                Some(r) => {
                    let n = (r.prompt.len() + r.resumed.len())
                        .min(cfg.max_prompt + cfg.max_new);
                    let per_layer = cfg.policy_cfg.per_layer_budget(
                        &cfg.policy,
                        n,
                        man.model.window,
                    );
                    let remaining =
                        r.max_new.saturating_sub(r.resumed.len()).max(1);
                    store.can_admit(per_layer, remaining)
                }
            }
        };

        match sched.next_action_mem(active.len(), admit_ok) {
            Action::Prefill => {
                let req = sched.pop_next(|r| r.prompt.len()).unwrap();
                match admit(rt, &man, policy.as_ref(), cfg, req, store.as_mut())
                {
                    Ok(a) => {
                        metrics.observe("prefill_secs", a.prefill_secs);
                        if a.done {
                            // Resumed request already at its token budget
                            // (or END on the first token): respond now
                            // rather than dragging it through a decode
                            // step that must ignore it.
                            finish(a, store.as_mut(), metrics);
                        } else {
                            active.push(a);
                        }
                    }
                    Err(AdmitFail::Defer(req)) => {
                        // Prefilled but the pool could not take the cache;
                        // resume from the queue head once decoding frees
                        // blocks. With nothing active the pool can never
                        // improve, so reject instead of livelocking; with
                        // actives, pause admission for one iteration so
                        // the loop decodes (and frees blocks) instead of
                        // hot-spinning on prefill-then-defer.
                        if active.is_empty() {
                            reject(
                                req,
                                metrics,
                                "request cannot fit the KV block pool".into(),
                            );
                        } else {
                            metrics.inc("admit_deferred", 1);
                            sched.requeue_front(req);
                            admission_paused = true;
                        }
                    }
                    Err(AdmitFail::Reject(req, e)) => {
                        reject(req, metrics, format!("{e:#}"));
                    }
                }
            }
            Action::DecodeStep => {
                let out = decode_step(
                    rt,
                    &batch,
                    store.as_ref(),
                    &active,
                    metrics,
                )?;
                apply_decode(
                    cfg,
                    &man,
                    store.as_mut(),
                    &mut sched,
                    &mut active,
                    &out,
                    metrics,
                );
                // Retire finished requests.
                let mut i = 0;
                while i < active.len() {
                    if active[i].done
                        || active[i].tokens.len() >= active[i].max_new()
                    {
                        let a = active.swap_remove(i);
                        finish(a, store.as_mut(), metrics);
                    } else {
                        i += 1;
                    }
                }
            }
            Action::Idle => {
                // Queue blocked on memory with nothing active: the pool
                // will never improve, so fail the head request fast.
                if !admit_ok && active.is_empty() && sched.queue_len() > 0 {
                    let req = sched.pop_next(|r| r.prompt.len()).unwrap();
                    reject(
                        req,
                        metrics,
                        "request cannot fit the KV block pool".into(),
                    );
                }
            }
        }
        publish_pool_gauges(store.as_ref(), metrics);
    }
    Ok(())
}

impl Active {
    fn max_new(&self) -> usize {
        self.req.max_new
    }
}

enum AdmitFail {
    /// Permanent failure: send an error response.
    Reject(Request, anyhow::Error),
    /// Pool momentarily too full: requeue and retry after decode frees
    /// blocks.
    Defer(Request),
}

fn admit(
    rt: &Runtime,
    man: &Manifest,
    policy: &dyn crate::coordinator::policies::Policy,
    cfg: &ServerConfig,
    req: Request,
    store: &mut dyn KvStore,
) -> std::result::Result<Active, AdmitFail> {
    if req.prompt.len() > cfg.max_prompt {
        return Err(AdmitFail::Reject(
            req,
            anyhow::anyhow!("prompt exceeds max_prompt {}", cfg.max_prompt),
        ));
    }
    // Resume support: re-prefill the original prompt plus everything
    // generated before the preemption.
    let full_prompt: Vec<i32> = if req.resumed.is_empty() {
        req.prompt.clone()
    } else {
        let mut p = req.prompt.clone();
        p.extend_from_slice(&req.resumed);
        p
    };
    let t0 = Instant::now();
    let pre = match policy.prefill(rt, man, &full_prompt, &cfg.policy_cfg) {
        Ok(p) => p,
        Err(e) => return Err(AdmitFail::Reject(req, e)),
    };
    let prefill_secs = t0.elapsed().as_secs_f64();
    let slot = match store.admit(&pre.cache) {
        Some(s) => s,
        None => return Err(AdmitFail::Defer(req)),
    };
    let ttft = req
        .first_ttft
        .unwrap_or_else(|| req.submitted.elapsed().as_secs_f64());
    let mut tokens = req.resumed.clone();
    tokens.push(pre.first_token);
    let done =
        pre.first_token == END as i32 || tokens.len() >= req.max_new;
    Ok(Active {
        pos: pre.next_pos,
        cur: pre.first_token,
        tokens,
        slot,
        req,
        prefill_secs,
        ttft_secs: ttft,
        done,
    })
}

fn decode_step(
    rt: &Runtime,
    batch: &DecodeBatch,
    store: &dyn KvStore,
    active: &[Active],
    metrics: &Metrics,
) -> Result<DecodeOut> {
    let lanes: Vec<LaneInput> = active
        .iter()
        .map(|a| LaneInput { slot: a.slot, token: a.cur, pos: a.pos })
        .collect();
    let t0 = Instant::now();
    let out = batch
        .step(rt, store, &lanes, Some(metrics))
        .context("decode step")?;
    metrics.observe("decode_step_secs", t0.elapsed().as_secs_f64());
    Ok(out)
}

/// Whether a lane could resume after preemption: the re-prefill of
/// prompt + generated tokens must fit the policy's prefill buckets, and
/// the store must be able to take the regrown cache back even from a
/// drained state (lane capacity AND total pool size).
fn can_resume(
    cfg: &ServerConfig,
    man: &Manifest,
    a: &Active,
    store: &dyn KvStore,
) -> bool {
    let full_len = a.req.prompt.len() + a.tokens.len();
    let budget = cfg.policy_cfg.per_layer_budget(
        &cfg.policy,
        full_len,
        man.model.window,
    );
    let len_limit =
        prefill_len_limit(man, &cfg.policy, cfg.policy_cfg.use_pallas);
    full_len <= len_limit && store.could_ever_admit(budget)
}

/// Preempt the lane at `idx`: release its blocks and park the request on
/// the resume queue (generated tokens ride along and are re-prefilled as
/// prompt context on re-admission). Order-preserving removal so the
/// caller's scan index stays meaningful.
fn preempt(
    active: &mut Vec<Active>,
    idx: usize,
    store: &mut dyn KvStore,
    sched: &mut Scheduler<Request>,
    metrics: &Metrics,
) {
    let a = active.remove(idx);
    store.release(a.slot);
    metrics.inc("preempted", 1);
    let mut req = a.req;
    req.resumed = a.tokens;
    req.first_ttft = Some(a.ttft_secs);
    sched.requeue_front(req);
}

/// Apply one decode step's outputs through the shared lane stepper:
/// append + sample per lane, compacting under pool pressure; when
/// compaction cannot free enough, preempt the least-progress resumable
/// lane (which may be another lane than the one that hit the wall) and
/// retry.
fn apply_decode(
    cfg: &ServerConfig,
    man: &Manifest,
    store: &mut dyn KvStore,
    sched: &mut Scheduler<Request>,
    active: &mut Vec<Active>,
    out: &DecodeOut,
    metrics: &Metrics,
) {
    let spec = CompactSpec {
        policy_cfg: &cfg.policy_cfg,
        shrink: COMPACT_SHRINK,
        window: man.model.window,
        metrics: Some(metrics),
    };
    let mut i = 0;
    while i < active.len() {
        if active[i].done {
            // Already finished (max_new reached on resume, or END) —
            // never grow the cache or sample past the end; the retire
            // loop collects it right after this pass.
            i += 1;
            continue;
        }
        let slot = active[i].slot;
        // Policy compaction fires at most ONCE per lane per step (the
        // first attempt); victim-preemption retries must not compound
        // shrink^k eviction onto the same lane within a single step.
        let mut allow_compact = true;
        loop {
            let spec_opt = if allow_compact { Some(&spec) } else { None };
            match advance_lane(store, slot, out, spec_opt) {
                LaneAdvance::Next { token, ended } => {
                    let a = &mut active[i];
                    a.pos += 1;
                    if ended {
                        a.done = true;
                    } else {
                        a.cur = token;
                        a.tokens.push(token);
                    }
                    i += 1;
                    break;
                }
                LaneAdvance::CapacityStop => {
                    active[i].done = true;
                    i += 1;
                    break;
                }
                LaneAdvance::PoolPressure => {
                    allow_compact = false;
                    // Victim selection: the lane losing the least decode
                    // progress among every lane that can actually resume —
                    // not necessarily the lane that hit pool exhaustion.
                    let mut candidates: Vec<(usize, (usize, usize))> =
                        Vec::new();
                    for (j, a) in active.iter().enumerate() {
                        if !a.done && can_resume(cfg, man, a, store) {
                            candidates.push((
                                j,
                                (a.tokens.len(), store.held_blocks(a.slot)),
                            ));
                        }
                    }
                    let keys: Vec<(usize, usize)> =
                        candidates.iter().map(|&(_, k)| k).collect();
                    let victim = pick_preemption_victim(&keys)
                        .map(|k| candidates[k].0);
                    match victim {
                        Some(v) if v != i => {
                            preempt(active, v, store, sched, metrics);
                            if v < i {
                                i -= 1; // removal shifted this lane left
                            }
                            // retry the pressured lane with freed blocks
                        }
                        Some(_) => {
                            // this lane is itself the cheapest victim; the
                            // next lane slides into index i
                            preempt(active, i, store, sched, metrics);
                            break;
                        }
                        None => {
                            // Nobody can resume: finish gracefully with
                            // what was generated (like a capacity stop)
                            // instead of parking a request that would end
                            // in rejection.
                            metrics.inc("finished_on_pressure", 1);
                            active[i].done = true;
                            i += 1;
                            break;
                        }
                    }
                }
            }
        }
    }
}
