//! Token-saliency selection: the paper's Eq. 1–2 machinery.
//!
//! All selection runs host-side in the coordinator (L3): the artifacts
//! export raw per-head score summaries (`win`, `acc`) and the policies
//! reduce them — head/group averaging, max-pooling (kernel 7), top-k with
//! forced inclusion of the observation window — exactly as
//! `KVCompress`/`HiddenCompress` in the paper's Algorithm 1.

/// Mean over heads: scores [H, N] (row-major) -> [N].  (Eq. 2)
pub fn head_mean(scores: &[f32], h: usize, n: usize) -> Vec<f32> {
    assert_eq!(scores.len(), h * n);
    let mut out = vec![0.0f32; n];
    for hi in 0..h {
        let row = &scores[hi * n..(hi + 1) * n];
        for (o, s) in out.iter_mut().zip(row) {
            *o += s;
        }
    }
    let inv = 1.0 / h as f32;
    out.iter_mut().for_each(|x| *x *= inv);
    out
}

/// Mean over the query heads of one GQA group: scores [H, N], group `g`
/// covers heads [g*groups, (g+1)*groups).  (paper: "averaging head-wise
/// saliency values within each key-value group")
pub fn group_mean(
    scores: &[f32],
    h: usize,
    n: usize,
    kv_heads: usize,
    g: usize,
) -> Vec<f32> {
    assert_eq!(scores.len(), h * n);
    let groups = h / kv_heads;
    let mut out = vec![0.0f32; n];
    for hi in g * groups..(g + 1) * groups {
        let row = &scores[hi * n..(hi + 1) * n];
        for (o, s) in out.iter_mut().zip(row) {
            *o += s;
        }
    }
    let inv = 1.0 / groups as f32;
    out.iter_mut().for_each(|x| *x *= inv);
    out
}

/// 1-d max-pool, stride 1, 'same' padding (paper kernel size 7).  Matches
/// `kernels/ref.maxpool1d_ref` and torch `MaxPool1d(k, 1, k//2)`.
pub fn maxpool1d(x: &[f32], kernel: usize) -> Vec<f32> {
    assert!(kernel % 2 == 1, "kernel must be odd");
    let pad = kernel / 2;
    let n = x.len();
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let lo = i.saturating_sub(pad);
        let hi = (i + pad + 1).min(n);
        let m = x[lo..hi].iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        out.push(m);
    }
    out
}

/// Top-`k` indices of `scores[..n_valid]`, with `forced` indices always
/// included (the observation window), result sorted ascending (causal
/// order). `k` counts the total selected including forced entries.
pub fn top_k_with_forced(
    scores: &[f32],
    n_valid: usize,
    k: usize,
    forced: &[usize],
) -> Vec<usize> {
    let n_valid = n_valid.min(scores.len());
    let k = k.min(n_valid);
    let mut is_forced = vec![false; n_valid];
    let mut n_forced = 0;
    for &f in forced {
        if f < n_valid && !is_forced[f] {
            is_forced[f] = true;
            n_forced += 1;
        }
    }
    let mut sel: Vec<usize> = (0..n_valid).filter(|&i| is_forced[i]).collect();
    if k > n_forced {
        let mut rest: Vec<usize> =
            (0..n_valid).filter(|&i| !is_forced[i]).collect();
        let take = (k - n_forced).min(rest.len());
        // Partial selection: O(n) select_nth + sort of the winning prefix.
        if take > 0 && take < rest.len() {
            rest.select_nth_unstable_by(take - 1, |&a, &b| {
                scores[b]
                    .partial_cmp(&scores[a])
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(&b))
            });
            rest.truncate(take);
        }
        sel.extend(rest.into_iter().take(take));
    } else {
        sel.truncate(k);
    }
    sel.sort_unstable();
    sel
}

/// The observation-window indices: the last `window` valid positions.
pub fn window_indices(n_valid: usize, window: usize) -> Vec<usize> {
    (n_valid.saturating_sub(window)..n_valid).collect()
}

/// Full Eq. 1-2 TSP / SnapKV-style selection from raw win scores [H, N]:
/// head-mean -> max-pool -> top-k ∪ window, ascending.
pub fn select_salient(
    win: &[f32],
    h: usize,
    n: usize,
    n_valid: usize,
    k: usize,
    window: usize,
    pool_kernel: usize,
) -> Vec<usize> {
    let s = head_mean(win, h, n);
    let s = maxpool1d(&s, pool_kernel);
    top_k_with_forced(&s, n_valid, k, &window_indices(n_valid, window))
}

/// Group-wise KV selection (`KVCompress`): one index set per KV head.
pub fn select_kv_groupwise(
    win: &[f32],
    h: usize,
    n: usize,
    n_valid: usize,
    kv_heads: usize,
    k: usize,
    window: usize,
    pool_kernel: usize,
) -> Vec<Vec<usize>> {
    let forced = window_indices(n_valid, window);
    (0..kv_heads)
        .map(|g| {
            let s = group_mean(win, h, n, kv_heads, g);
            let s = maxpool1d(&s, pool_kernel);
            top_k_with_forced(&s, n_valid, k, &forced)
        })
        .collect()
}

/// StreamingLLM selection: attention sinks (first `sinks`) + most recent.
pub fn select_streaming(
    n_valid: usize,
    k: usize,
    sinks: usize,
) -> Vec<usize> {
    let k = k.min(n_valid);
    let sinks = sinks.min(k);
    let recent = k - sinks;
    let mut sel: Vec<usize> = (0..sinks.min(n_valid)).collect();
    sel.extend(n_valid.saturating_sub(recent)..n_valid);
    sel.dedup();
    // sinks may overlap recent for tiny prompts
    sel.sort_unstable();
    sel.dedup();
    sel.truncate(k);
    sel
}

/// H2O selection: accumulated attention scores (no pooling) + recent
/// window, per the heavy-hitter oracle.
pub fn select_h2o(
    acc: &[f32],
    h: usize,
    n: usize,
    n_valid: usize,
    k: usize,
    window: usize,
) -> Vec<usize> {
    let s = head_mean(acc, h, n);
    top_k_with_forced(&s, n_valid, k, &window_indices(n_valid, window))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_mean_basic() {
        // H=2, N=3
        let s = [1.0, 2.0, 3.0, 3.0, 4.0, 5.0];
        assert_eq!(head_mean(&s, 2, 3), vec![2.0, 3.0, 4.0]);
    }

    #[test]
    fn group_mean_splits_heads() {
        // H=4, KV=2, N=2: group 0 = heads 0,1; group 1 = heads 2,3
        let s = [1.0, 1.0, 3.0, 3.0, 10.0, 10.0, 20.0, 20.0];
        assert_eq!(group_mean(&s, 4, 2, 2, 0), vec![2.0, 2.0]);
        assert_eq!(group_mean(&s, 4, 2, 2, 1), vec![15.0, 15.0]);
    }

    #[test]
    fn maxpool_same_padding() {
        let x = [0.0, 5.0, 0.0, 0.0, 0.0, 0.0, 1.0];
        assert_eq!(maxpool1d(&x, 3), vec![5., 5., 5., 0., 0., 1., 1.]);
    }

    #[test]
    fn topk_respects_forced_and_order() {
        let scores = [0.9, 0.1, 0.8, 0.2, 0.7];
        // k=3 with forced {3}: top scores 0.9@0, 0.8@2 + forced 3
        assert_eq!(top_k_with_forced(&scores, 5, 3, &[3]), vec![0, 2, 3]);
    }

    #[test]
    fn topk_ignores_padding() {
        let scores = [0.1, 0.2, 0.9, 100.0];
        // n_valid=3 masks index 3 despite its huge score
        assert_eq!(top_k_with_forced(&scores, 3, 2, &[]), vec![1, 2]);
    }

    #[test]
    fn topk_k_exceeds_valid() {
        assert_eq!(top_k_with_forced(&[1.0, 2.0], 2, 10, &[]), vec![0, 1]);
    }

    #[test]
    fn topk_all_forced() {
        // window bigger than k: truncates to k forced entries
        let sel = top_k_with_forced(&[0.0; 8], 8, 2, &[4, 5, 6, 7]);
        assert_eq!(sel.len(), 2);
        assert!(sel.iter().all(|&i| (4..8).contains(&i)));
    }

    #[test]
    fn select_salient_prefers_pooled_neighborhood() {
        // One spike at index 5; pooling (k=3) spreads it to 4..=6, so with
        // k=4 and window size 1 (forcing index 7) we expect {4,5,6,7}.
        let n = 8;
        let mut win = vec![0.0f32; 2 * n];
        win[5] = 1.0; // head 0
        win[n + 5] = 1.0; // head 1
        let sel = select_salient(&win, 2, n, n, 4, 1, 3);
        assert_eq!(sel, vec![4, 5, 6, 7]);
    }

    #[test]
    fn groupwise_selection_differs_per_group() {
        // H=2, KV=2 (1 head per group), N=4; head 0 loves idx 0,
        // head 1 loves idx 2. window=1 forces idx 3.
        let win = [1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 1.0, 0.0];
        let sel = select_kv_groupwise(&win, 2, 4, 4, 2, 2, 1, 1);
        assert_eq!(sel[0], vec![0, 3]);
        assert_eq!(sel[1], vec![2, 3]);
    }

    #[test]
    fn streaming_sinks_plus_recent() {
        assert_eq!(select_streaming(100, 6, 2), vec![0, 1, 96, 97, 98, 99]);
        // degenerate small prompt
        assert_eq!(select_streaming(3, 6, 2), vec![0, 1, 2]);
    }

    #[test]
    fn h2o_keeps_heavy_hitters_and_recent() {
        let n = 6;
        let mut acc = vec![0.0f32; n];
        acc[1] = 9.0;
        let sel = select_h2o(&acc, 1, n, n, 3, 2);
        assert_eq!(sel, vec![1, 4, 5]);
    }

    #[test]
    fn window_indices_clamps() {
        assert_eq!(window_indices(3, 8), vec![0, 1, 2]);
        assert_eq!(window_indices(10, 2), vec![8, 9]);
    }
}
