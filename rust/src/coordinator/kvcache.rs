//! Compressed KV-cache storage.
//!
//! `LayerCache` holds one request's selected KV rows for one layer;
//! `RequestCache` stacks all layers; `BatchArena` is the decode-artifact
//! staging area in exactly the artifact's [L, B, C, KV, hd] layout so a
//! decode step is one contiguous host→device copy, and appends during
//! decoding write in place (no per-step reassembly).

use crate::manifest::ModelMeta;
use crate::tensor::HostTensor;

/// One request's per-layer compressed cache (token-major rows).
#[derive(Debug, Clone)]
pub struct RequestCache {
    /// [L][len * KV * hd] selected K rows per layer (may differ per layer).
    pub k: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
    /// Valid entries per layer.
    pub lens: Vec<usize>,
    pub kv_heads: usize,
    pub head_dim: usize,
}

impl RequestCache {
    pub fn new(meta: &ModelMeta) -> Self {
        RequestCache {
            k: vec![Vec::new(); meta.n_layers],
            v: vec![Vec::new(); meta.n_layers],
            lens: vec![0; meta.n_layers],
            kv_heads: meta.n_kv_heads,
            head_dim: meta.head_dim,
        }
    }

    pub fn row_elems(&self) -> usize {
        self.kv_heads * self.head_dim
    }

    /// Fill layer `l` by gathering `selected` token rows from a prefill
    /// KV tensor shaped [layers, N, KV, hd] at layer-offset `src_layer`.
    ///
    /// `per_group`: one index set per KV head (group-wise compression); all
    /// sets must be equal length. With a single shared set pass it
    /// duplicated.
    pub fn fill_layer_grouped(
        &mut self,
        l: usize,
        k_src: &HostTensor,
        v_src: &HostTensor,
        src_layer: usize,
        per_group: &[Vec<usize>],
    ) {
        assert_eq!(per_group.len(), self.kv_heads);
        let len = per_group[0].len();
        assert!(per_group.iter().all(|s| s.len() == len));
        let hd = self.head_dim;
        let re = self.row_elems();
        let kk = &mut self.k[l];
        let vv = &mut self.v[l];
        kk.clear();
        vv.clear();
        kk.resize(len * re, 0.0);
        vv.resize(len * re, 0.0);
        for (slot, _) in per_group[0].iter().enumerate() {
            for g in 0..self.kv_heads {
                let tok = per_group[g][slot];
                let ks = k_src.row2(src_layer, tok);
                let vs = v_src.row2(src_layer, tok);
                let dst = slot * re + g * hd;
                kk[dst..dst + hd].copy_from_slice(&ks[g * hd..(g + 1) * hd]);
                vv[dst..dst + hd].copy_from_slice(&vs[g * hd..(g + 1) * hd]);
            }
        }
        self.lens[l] = len;
    }

    /// Shared-index fill (same token set for every group).
    pub fn fill_layer(
        &mut self,
        l: usize,
        k_src: &HostTensor,
        v_src: &HostTensor,
        src_layer: usize,
        selected: &[usize],
    ) {
        let sets: Vec<Vec<usize>> =
            (0..self.kv_heads).map(|_| selected.to_vec()).collect();
        self.fill_layer_grouped(l, k_src, v_src, src_layer, &sets);
    }

    pub fn max_len(&self) -> usize {
        self.lens.iter().copied().max().unwrap_or(0)
    }

    /// Total cached f32 elements (the "KV cache size" metric).
    pub fn total_elems(&self) -> usize {
        self.k.iter().map(|k| k.len()).sum::<usize>() * 2
    }
}

/// Decode staging arena in artifact layout [L, B, C, KV, hd].
#[derive(Debug)]
pub struct BatchArena {
    pub l: usize,
    pub b: usize,
    pub c: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    pub k: HostTensor,
    pub v: HostTensor,
    /// lens[l * b + slot] — valid rows per layer per slot.
    pub lens: Vec<i32>,
    /// Slot occupancy.
    pub used: Vec<bool>,
}

impl BatchArena {
    pub fn new(meta: &ModelMeta, b: usize, c: usize) -> Self {
        let l = meta.n_layers;
        let shape = vec![l, b, c, meta.n_kv_heads, meta.head_dim];
        BatchArena {
            l,
            b,
            c,
            kv_heads: meta.n_kv_heads,
            head_dim: meta.head_dim,
            k: HostTensor::zeros(shape.clone()),
            v: HostTensor::zeros(shape),
            lens: vec![0; l * b],
            used: vec![false; b],
        }
    }

    pub fn row_elems(&self) -> usize {
        self.kv_heads * self.head_dim
    }

    fn base(&self, l: usize, slot: usize, row: usize) -> usize {
        ((l * self.b + slot) * self.c + row) * self.row_elems()
    }

    pub fn alloc_slot(&mut self) -> Option<usize> {
        let slot = self.used.iter().position(|u| !u)?;
        self.used[slot] = true;
        for l in 0..self.l {
            self.lens[l * self.b + slot] = 0;
        }
        Some(slot)
    }

    /// Free a slot. Returns false (and touches nothing) if the slot is
    /// already free: a double free must never zero a region that may have
    /// been handed to another request in between.
    pub fn free_slot(&mut self, slot: usize) -> bool {
        if slot >= self.b || !self.used[slot] {
            return false;
        }
        self.used[slot] = false;
        // Zero the slot's rows so stale data can never leak into another
        // request even if lens bookkeeping were wrong.
        for l in 0..self.l {
            let re = self.row_elems();
            let base = self.base(l, slot, 0);
            self.k.data[base..base + self.c * re].fill(0.0);
            self.v.data[base..base + self.c * re].fill(0.0);
            self.lens[l * self.b + slot] = 0;
        }
        true
    }

    pub fn free_slots(&self) -> usize {
        self.used.iter().filter(|u| !**u).count()
    }

    /// Load a request's compressed cache into `slot`.
    pub fn load(&mut self, slot: usize, cache: &RequestCache) {
        assert!(self.used[slot], "load into unallocated slot");
        assert_eq!(cache.k.len(), self.l);
        let re = self.row_elems();
        for l in 0..self.l {
            let len = cache.lens[l];
            assert!(
                len <= self.c,
                "cache len {len} exceeds arena capacity {}",
                self.c
            );
            let base = self.base(l, slot, 0);
            self.k.data[base..base + len * re]
                .copy_from_slice(&cache.k[l][..len * re]);
            self.v.data[base..base + len * re]
                .copy_from_slice(&cache.v[l][..len * re]);
            // Clear any leftover rows above len.
            self.k.data[base + len * re..base + self.c * re].fill(0.0);
            self.v.data[base + len * re..base + self.c * re].fill(0.0);
            self.lens[l * self.b + slot] = len as i32;
        }
    }

    /// Append the decode step's new KV (k_new/v_new: [L, B, KV, hd]) for
    /// `slot` and bump its lens. Returns false (no-op) if any layer is at
    /// capacity.
    pub fn append(
        &mut self,
        slot: usize,
        k_new: &HostTensor,
        v_new: &HostTensor,
    ) -> bool {
        let re = self.row_elems();
        for l in 0..self.l {
            if self.lens[l * self.b + slot] as usize >= self.c {
                return false;
            }
        }
        for l in 0..self.l {
            let len = self.lens[l * self.b + slot] as usize;
            let base = self.base(l, slot, len);
            let src = &k_new.row2(l, slot)[..re];
            self.k.data[base..base + re].copy_from_slice(src);
            let src = &v_new.row2(l, slot)[..re];
            self.v.data[base..base + re].copy_from_slice(src);
            self.lens[l * self.b + slot] += 1;
        }
        true
    }

    /// In-place eviction for the flat layout: retain only `keep[l]` rows
    /// (ascending logical indices) on each layer of `slot`, moving
    /// survivors down and zeroing the trimmed tail. The paged backend's
    /// block-granular equivalent is `PagedArena::compact`.
    pub fn compact_slot(&mut self, slot: usize, keep: &[Vec<usize>]) {
        if slot >= self.b || !self.used[slot] {
            return;
        }
        assert_eq!(keep.len(), self.l, "keep sets per layer");
        let re = self.row_elems();
        for l in 0..self.l {
            let old_len = self.lens[l * self.b + slot] as usize;
            let keep_l = &keep[l];
            let mut tk = Vec::with_capacity(keep_l.len() * re);
            let mut tv = Vec::with_capacity(keep_l.len() * re);
            for &idx in keep_l {
                assert!(idx < old_len, "keep index {idx} >= len {old_len}");
                let base = self.base(l, slot, idx);
                tk.extend_from_slice(&self.k.data[base..base + re]);
                tv.extend_from_slice(&self.v.data[base..base + re]);
            }
            let new_len = keep_l.len();
            let base = self.base(l, slot, 0);
            self.k.data[base..base + new_len * re].copy_from_slice(&tk);
            self.v.data[base..base + new_len * re].copy_from_slice(&tv);
            self.k.data[base + new_len * re..base + old_len * re].fill(0.0);
            self.v.data[base + new_len * re..base + old_len * re].fill(0.0);
            self.lens[l * self.b + slot] = new_len as i32;
        }
    }

    pub fn lens_tensor(&self) -> crate::tensor::HostTensorI32 {
        crate::tensor::HostTensorI32::new(
            vec![self.l, self.b],
            self.lens.clone(),
        )
    }

    pub fn slot_len(&self, slot: usize) -> usize {
        self.lens[slot] as usize // layer 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> ModelMeta {
        ModelMeta {
            vocab_size: 256,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 2,
            head_dim: 2,
            tsp_layer: 1,
            window: 2,
            pool_kernel: 3,
            max_train_len: 64,
        }
    }

    fn kv_src(l: usize, n: usize, kv: usize, hd: usize) -> HostTensor {
        // element value encodes (layer, token, group, dim) uniquely
        let mut data = Vec::with_capacity(l * n * kv * hd);
        for li in 0..l {
            for t in 0..n {
                for g in 0..kv {
                    for d in 0..hd {
                        data.push(
                            (li * 1000 + t * 10 + g * 2 + d) as f32,
                        );
                    }
                }
            }
        }
        HostTensor::new(vec![l, n, kv, hd], data)
    }

    #[test]
    fn fill_layer_gathers_rows() {
        let m = meta();
        let k = kv_src(2, 4, 2, 2);
        let v = kv_src(2, 4, 2, 2);
        let mut rc = RequestCache::new(&m);
        rc.fill_layer(0, &k, &v, 0, &[1, 3]);
        assert_eq!(rc.lens[0], 2);
        // token 1, group 0 => values 10,11 ; group 1 => 12,13
        assert_eq!(&rc.k[0][..4], &[10.0, 11.0, 12.0, 13.0]);
        // token 3 row
        assert_eq!(&rc.k[0][4..8], &[30.0, 31.0, 32.0, 33.0]);
    }

    #[test]
    fn groupwise_fill_uses_per_group_tokens() {
        let m = meta();
        let k = kv_src(2, 4, 2, 2);
        let v = kv_src(2, 4, 2, 2);
        let mut rc = RequestCache::new(&m);
        rc.fill_layer_grouped(1, &k, &v, 1, &[vec![0, 2], vec![1, 3]]);
        // slot 0: group0 from token0 (layer1 => 1000+0+0,1) group1 from
        // token1 (1000+10+2,3)
        assert_eq!(&rc.k[1][..4], &[1000.0, 1001.0, 1012.0, 1013.0]);
    }

    #[test]
    fn arena_slot_lifecycle() {
        let m = meta();
        let mut arena = BatchArena::new(&m, 2, 4);
        let s0 = arena.alloc_slot().unwrap();
        let s1 = arena.alloc_slot().unwrap();
        assert_eq!((s0, s1), (0, 1));
        assert!(arena.alloc_slot().is_none());
        arena.free_slot(s0);
        assert_eq!(arena.alloc_slot(), Some(0));
    }

    #[test]
    fn arena_load_and_append() {
        let m = meta();
        let k = kv_src(2, 4, 2, 2);
        let v = kv_src(2, 4, 2, 2);
        let mut rc = RequestCache::new(&m);
        rc.fill_layer(0, &k, &v, 0, &[0, 2]);
        rc.fill_layer(1, &k, &v, 1, &[1]);
        let mut arena = BatchArena::new(&m, 2, 4);
        let slot = arena.alloc_slot().unwrap();
        arena.load(slot, &rc);
        assert_eq!(arena.lens[slot], 2); // layer 0
        assert_eq!(arena.lens[1 * 2 + slot], 1); // layer 1

        // append new rows for both layers
        let k_new = HostTensor::new(
            vec![2, 2, 2, 2],
            (0..16).map(|x| x as f32).collect(),
        );
        let v_new = k_new.clone();
        assert!(arena.append(slot, &k_new, &v_new));
        assert_eq!(arena.lens[slot], 3);
        assert_eq!(arena.lens[2 + slot], 2);
        // layer 0 slot row 2 should hold k_new[0, slot]
        let re = arena.row_elems();
        let base = ((0 * 2 + slot) * 4 + 2) * re;
        assert_eq!(
            &arena.k.data[base..base + 4],
            k_new.row2(0, slot)
        );
    }

    #[test]
    fn append_stops_at_capacity() {
        let m = meta();
        let mut arena = BatchArena::new(&m, 1, 2);
        let slot = arena.alloc_slot().unwrap();
        let k_new = HostTensor::zeros(vec![2, 1, 2, 2]);
        assert!(arena.append(slot, &k_new, &k_new));
        assert!(arena.append(slot, &k_new, &k_new));
        assert!(!arena.append(slot, &k_new, &k_new));
    }

    #[test]
    fn double_free_cannot_clobber_reallocated_slot() {
        // Regression (slot-lifecycle audit): freeing a slot twice used to
        // silently re-zero it; if the slot had been handed to a new
        // request in between, that request's KV was wiped. Now the second
        // free reports false and leaves the region alone.
        let m = meta();
        let mut arena = BatchArena::new(&m, 1, 2);
        let slot = arena.alloc_slot().unwrap();
        let k_new = HostTensor::new(
            vec![2, 1, 2, 2],
            (1..=8).map(|x| x as f32).collect(),
        );
        arena.append(slot, &k_new, &k_new);
        assert!(arena.free_slot(slot));
        // slot re-allocated by a new "request"
        let slot2 = arena.alloc_slot().unwrap();
        assert_eq!(slot2, slot);
        arena.append(slot2, &k_new, &k_new);
        // stale double-free from the old owner: must be a no-op
        assert!(!arena.free_slot(slot));
        assert_eq!(arena.lens[slot2], 1, "new owner's len survived");
        let re = arena.row_elems();
        assert_eq!(
            &arena.k.data[..re],
            k_new.row2(0, slot2),
            "new owner's data survived"
        );
        // out-of-range frees are rejected, not a panic
        assert!(!arena.free_slot(99));
    }

    #[test]
    fn realloc_resets_stale_lens() {
        // Regression (slot-lifecycle audit): a re-allocated slot must
        // never inherit the previous occupant's lens.
        let m = meta();
        let mut arena = BatchArena::new(&m, 1, 4);
        let slot = arena.alloc_slot().unwrap();
        let k_new = HostTensor::zeros(vec![2, 1, 2, 2]);
        arena.append(slot, &k_new, &k_new);
        arena.append(slot, &k_new, &k_new);
        assert_eq!(arena.slot_len(slot), 2);
        arena.free_slot(slot);
        let slot2 = arena.alloc_slot().unwrap();
        assert_eq!(arena.slot_len(slot2), 0, "stale length leaked");
        assert!(arena.lens.iter().all(|&l| l == 0));
    }

    #[test]
    fn compact_slot_keeps_rows_and_zeroes_tail() {
        let m = meta();
        let k = kv_src(2, 4, 2, 2);
        let v = kv_src(2, 4, 2, 2);
        let mut rc = RequestCache::new(&m);
        rc.fill_layer(0, &k, &v, 0, &[0, 1, 2, 3]);
        rc.fill_layer(1, &k, &v, 1, &[0, 1, 2]);
        let mut arena = BatchArena::new(&m, 1, 4);
        let slot = arena.alloc_slot().unwrap();
        arena.load(slot, &rc);
        arena.compact_slot(slot, &[vec![1, 3], vec![2]]);
        assert_eq!(arena.lens, vec![2, 1]);
        let re = arena.row_elems();
        // layer 0 row 0 now holds original token 1, row 1 token 3
        assert_eq!(&arena.k.data[..re], &rc.k[0][re..2 * re]);
        assert_eq!(&arena.k.data[re..2 * re], &rc.k[0][3 * re..4 * re]);
        // trimmed tail zeroed
        assert!(arena.k.data[2 * re..4 * re].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn free_slot_zeroes_data() {
        let m = meta();
        let mut arena = BatchArena::new(&m, 1, 2);
        let slot = arena.alloc_slot().unwrap();
        let k_new = HostTensor::new(
            vec![2, 1, 2, 2],
            (1..=8).map(|x| x as f32).collect(),
        );
        arena.append(slot, &k_new, &k_new);
        arena.free_slot(slot);
        assert!(arena.k.data.iter().all(|&x| x == 0.0));
        assert_eq!(arena.lens, vec![0, 0]);
    }
}
