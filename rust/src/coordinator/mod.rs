//! L3 coordinator: the paper's system contribution.
//!
//! * `policies`  — FastKV + the five baselines (prefill plans + KV
//!   selection); all Eq. 1-2 selection math lives in `selection`.
//! * `kvcache`   — compressed per-request caches and the decode batch
//!   arena (artifact-layout staging).
//! * `engine`    — single-request generate loop (evals/benches).
//! * `scheduler` + `server` — the continuous-batching serving stack.

pub mod engine;
pub mod kvcache;
pub mod policies;
pub mod scheduler;
pub mod selection;
pub mod server;
