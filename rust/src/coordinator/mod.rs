//! L3 coordinator: the paper's system contribution.
//!
//! * `policies`  — FastKV + the five baselines (prefill plans + KV
//!   selection); all Eq. 1-2 selection math lives in `selection`.
//! * `kvcache`   — compressed per-request caches and the flat decode batch
//!   arena (artifact-layout staging).
//! * `paging`    — the paged KV-cache subsystem: block pool + allocator,
//!   prefix reuse, FastKV-aware eviction, the `KvStore` backend trait
//!   (`PagedArena` is the default backend; `BatchArena` the flat
//!   fallback), and the block-table `DecodeView`.
//! * `decode`    — the `DecodeBatch` planner/stepper both decode loops
//!   drive (block-table-native by default, staged fallback).
//! * `engine`    — single-request generate loop (evals/benches).
//! * `scheduler` + `server` — the continuous-batching serving stack with
//!   memory-aware admission and preemption.

pub mod decode;
pub mod engine;
pub mod kvcache;
pub mod paging;
pub mod policies;
pub mod scheduler;
pub mod selection;
pub mod server;
