//! `KvCodec` — the unified element codec shared by the resident slab and
//! the swap path.
//!
//! FastKV's context reduction decides *which* KV rows survive prefill;
//! the codec decides *how many bytes* each survivor costs. Quantizing the
//! rows that remain multiplies how many lanes fit per byte of slab: at a
//! fixed pool budget, int8 admits ~4x the f32 lane count (the
//! `BENCH_paging_quant.json` capacity sweep pins >= 1.9x). Three tiers:
//!
//! * [`KvCodec::F32`] — verbatim rows, bit-identical everywhere. The
//!   default; every pre-existing differential runs (and stays) on it.
//! * [`KvCodec::F16`] — IEEE 754 binary16 per element (the PR 5 swap
//!   codec, folded in here unchanged; `swap.rs` re-exports the
//!   conversion functions so its exhaustive tests keep pinning them).
//! * [`KvCodec::Int8PerRow`] — one i8 per element plus one f32 scale per
//!   token row (`scale = max|row| / 127`), the row-structured scheme
//!   KVComp-style lossy KV compression shows decode tolerates. Per-row
//!   scales keep the layout shard-oblivious: a head-range slice of a row
//!   reuses the row's scale, so `project_plane`/`reassemble_planes` and
//!   `write_row_range` never need per-shard rescaling.
//!
//! The enum itself is a fieldless *selector* (`Copy + Eq + Hash`) so it
//! can ride on config structs ([`super::tenant::TenantQuota::precision`],
//! `PagingConfig::precision`); encoded data lives in the stores
//! (`block.rs` planes, `swap.rs` lanes). Error discipline: int8
//! dequantization is within `scale / 2` per element of the encoded f32
//! (exhaustively tested below); f16 within one rounding step (relative
//! `2^-11`, exhaustively tested in `swap.rs`); f32 exact.

// ---------------------------------------------------------------------------
// f16 element codec (moved verbatim from swap.rs, which re-exports it)
//
// IEEE 754 binary16 keeps ~3 decimal digits (relative step 2^-11), ample
// for attention KV; out-of-range magnitudes saturate to ±65504 rather
// than overflowing to infinity. Round-to-nearest-even, verified
// exhaustively against numpy's float16 casts (all 65536 bit patterns
// decode exactly; every finite half re-encodes to itself — see swap.rs
// tests).

/// Encode one f32 as IEEE 754 binary16 bits (round-to-nearest-even,
/// saturating at ±65504; NaN maps to a quiet NaN).
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;
    if exp == 0xff {
        // inf / nan
        return sign | 0x7c00 | if mant != 0 { 0x200 } else { 0 };
    }
    let e = exp - 127;
    if e > 15 {
        return sign | 0x7bff; // saturate to ±65504
    }
    if e < -25 {
        return sign; // underflow to signed zero
    }
    if e < -14 {
        // subnormal half: mantissa = round(full / 2^(13 + (-14 - e)))
        let full = mant | 0x0080_0000;
        let drop = (13 + (-14 - e)) as u32;
        let m = full >> drop;
        let round_bit = (full >> (drop - 1)) & 1;
        let sticky = (full & ((1u32 << (drop - 1)) - 1)) != 0;
        let up = round_bit & u32::from(sticky || (m & 1) == 1);
        return sign | (m + up) as u16;
    }
    // normal
    let m = mant >> 13;
    let round_bit = (mant >> 12) & 1;
    let sticky = (mant & 0xfff) != 0;
    let mut h = sign as u32 | (((e + 15) as u32) << 10) | m;
    h += round_bit & u32::from(sticky || (m & 1) == 1);
    if (h & 0x7fff) >= 0x7c00 {
        // rounded past the largest normal: saturate, never overflow to inf
        return sign | 0x7bff;
    }
    h as u16
}

/// Decode IEEE 754 binary16 bits to f32 (exact for every finite half).
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = if h & 0x8000 != 0 { -1.0f32 } else { 1.0 };
    let exp = ((h >> 10) & 0x1f) as i32;
    let mant = (h & 0x3ff) as f32;
    match exp {
        0 => sign * mant * (2.0f32).powi(-24),
        31 => {
            if mant == 0.0 {
                sign * f32::INFINITY
            } else {
                f32::NAN
            }
        }
        e => sign * (1.0 + mant / 1024.0) * (2.0f32).powi(e - 15),
    }
}

// ---------------------------------------------------------------------------
// int8 per-row codec

/// Per-row quantization scale: `max|row| / 127` (0.0 for an all-zero
/// row, under which every element encodes and decodes as exactly 0).
pub fn int8_row_scale(row: &[f32]) -> f32 {
    let maxabs = row.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
    maxabs / 127.0
}

/// Quantize one row in place into `q` (`q.len() == row.len()`), returning
/// the scale. `q[i] = round(row[i] / scale)` clamped to `[-127, 127]`;
/// dequantization error is `<= scale / 2` per element.
pub fn quantize_row_int8(row: &[f32], q: &mut [i8]) -> f32 {
    debug_assert_eq!(row.len(), q.len());
    let scale = int8_row_scale(row);
    quantize_row_int8_with(row, q, scale);
    scale
}

/// Quantize `row` into `q` under a *given* scale (clamping to ±127).
/// Used by `write_row_range`'s keep-scale-if-possible patching: when a
/// patched sub-range still fits the row's current scale, requantizing
/// only the patch leaves every untouched element's stored bits unchanged.
pub fn quantize_row_int8_with(row: &[f32], q: &mut [i8], scale: f32) {
    if scale == 0.0 {
        q.fill(0);
        return;
    }
    for (qi, &x) in q.iter_mut().zip(row) {
        *qi = (x / scale).round().clamp(-127.0, 127.0) as i8;
    }
}

/// Dequantize one row: `out[i] = q[i] * scale`.
pub fn dequantize_row_int8(q: &[i8], scale: f32, out: &mut [f32]) {
    debug_assert_eq!(q.len(), out.len());
    for (o, &qi) in out.iter_mut().zip(q) {
        *o = f32::from(qi) * scale;
    }
}

// ---------------------------------------------------------------------------
// the codec selector

/// Element codec for KV rows — shared by the resident slab
/// (`BlockStore`), the swap path (`swap::KvLane`), and every byte gauge.
///
/// Fieldless by design: this is the *selector* carried on configs and
/// tenant quotas; the encoded payloads (and, for int8, the per-row scale
/// planes) live in the stores themselves.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, Hash)]
pub enum KvCodec {
    /// 4 bytes/element, bit-identical storage (the pre-codec behavior).
    #[default]
    F32,
    /// 2 bytes/element, IEEE 754 binary16 (one rounding step of error).
    F16,
    /// 1 byte/element + one f32 scale per row; error `<= scale / 2` per
    /// element where `scale = max|row| / 127`.
    Int8PerRow,
}

impl KvCodec {
    /// Host/device bytes one token row of `row_elems` elements occupies
    /// under this codec, per plane (K or V), scale storage included.
    /// This is THE bytes-per-row helper: slab gauges, swap budget
    /// predictions, and `shard_{s}_slab_bytes` all route through it so
    /// accounting can never drift from the encoded layout.
    pub fn bytes_per_row(self, row_elems: usize) -> usize {
        match self {
            KvCodec::F32 => row_elems * std::mem::size_of::<f32>(),
            KvCodec::F16 => row_elems * std::mem::size_of::<u16>(),
            KvCodec::Int8PerRow => {
                row_elems * std::mem::size_of::<i8>()
                    + std::mem::size_of::<f32>()
            }
        }
    }

    /// Whether encode-then-decode is bit-identical for every finite f32.
    pub fn is_lossless(self) -> bool {
        matches!(self, KvCodec::F32)
    }

    /// Short stable name (CLI values, metric label suffixes, bench JSON).
    pub fn name(self) -> &'static str {
        match self {
            KvCodec::F32 => "f32",
            KvCodec::F16 => "f16",
            KvCodec::Int8PerRow => "int8",
        }
    }

    /// Parse a CLI spelling (`--precision f32|f16|int8`).
    pub fn parse(s: &str) -> Result<KvCodec, String> {
        match s {
            "f32" => Ok(KvCodec::F32),
            "f16" | "half" => Ok(KvCodec::F16),
            "int8" | "q8" => Ok(KvCodec::Int8PerRow),
            other => Err(format!(
                "unknown precision {other:?} (expected f32|f16|int8)"
            )),
        }
    }

    /// All tiers, for sweeps and per-tier gauges.
    pub const ALL: [KvCodec; 3] =
        [KvCodec::F32, KvCodec::F16, KvCodec::Int8PerRow];
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng_next(s: &mut u64) -> u64 {
        *s ^= *s << 13;
        *s ^= *s >> 7;
        *s ^= *s << 17;
        *s
    }

    fn rand_row(seed: u64, n: usize, span: f32) -> Vec<f32> {
        let mut s = seed.max(1);
        (0..n)
            .map(|_| {
                let u = (rng_next(&mut s) >> 11) as f32
                    / (1u64 << 53) as f32;
                (u * 2.0 - 1.0) * span
            })
            .collect()
    }

    /// The headline bound: every element of a quantize/dequantize
    /// round-trip is within `scale / 2` of the input. Exhaustive over the
    /// quantized domain (every i8 level at many scales) plus randomized
    /// rows across magnitudes from subnormal-adjacent to 1e6.
    #[test]
    fn int8_roundtrip_error_is_within_half_scale() {
        // Exhaustive over levels: an f32 that sits exactly on a level
        // round-trips with zero error; one mid-way between levels sees
        // exactly scale/2.
        for scale in [1e-6f32, 0.03, 1.0, 512.0] {
            for level in -127i8..=127 {
                let x = f32::from(level) * scale;
                let mut q = [0i8];
                // encode under the fixed scale (as a stored row would be)
                quantize_row_int8_with(&[x], &mut q, scale);
                let mut out = [0.0f32];
                dequantize_row_int8(&q, scale, &mut out);
                assert!(
                    (out[0] - x).abs() <= scale * 0.5 + f32::EPSILON,
                    "level {level} scale {scale}: {x} -> {}",
                    out[0]
                );
            }
        }
        // Randomized full rows with the row-derived scale.
        for (i, span) in [1e-5f32, 0.1, 1.0, 37.0, 1e6].iter().enumerate() {
            let row = rand_row(0x9e3779b9 + i as u64, 96, *span);
            let mut q = vec![0i8; row.len()];
            let scale = quantize_row_int8(&row, &mut q);
            let mut out = vec![0.0f32; row.len()];
            dequantize_row_int8(&q, scale, &mut out);
            for (a, b) in row.iter().zip(&out) {
                assert!(
                    (a - b).abs() <= scale * 0.5 * (1.0 + 1e-5),
                    "span {span}: |{a} - {b}| > {}/2",
                    scale
                );
            }
        }
    }

    #[test]
    fn int8_zero_row_encodes_exactly() {
        let row = [0.0f32; 8];
        let mut q = [1i8; 8];
        let scale = quantize_row_int8(&row, &mut q);
        assert_eq!(scale, 0.0);
        assert!(q.iter().all(|&x| x == 0));
        let mut out = [9.0f32; 8];
        dequantize_row_int8(&q, scale, &mut out);
        assert_eq!(out, [0.0f32; 8]);
    }

    #[test]
    fn int8_max_magnitude_is_exact_at_the_top_level() {
        // The row max lands exactly on level ±127, so the extreme
        // element round-trips to (maxabs/127)*127 — within one ulp.
        let row = [3.5f32, -7.0, 1.25];
        let mut q = [0i8; 3];
        let scale = quantize_row_int8(&row, &mut q);
        assert_eq!(q[1], -127);
        let mut out = [0.0f32; 3];
        dequantize_row_int8(&q, scale, &mut out);
        assert!((out[1] - -7.0).abs() <= 7.0 * f32::EPSILON * 2.0);
    }

    #[test]
    fn bytes_per_row_matches_the_encoded_layout() {
        let re = 48;
        assert_eq!(KvCodec::F32.bytes_per_row(re), re * 4);
        assert_eq!(KvCodec::F16.bytes_per_row(re), re * 2);
        assert_eq!(KvCodec::Int8PerRow.bytes_per_row(re), re + 4);
        assert!(KvCodec::F32.is_lossless());
        assert!(!KvCodec::F16.is_lossless());
        assert!(!KvCodec::Int8PerRow.is_lossless());
    }

    #[test]
    fn parse_accepts_cli_spellings() {
        assert_eq!(KvCodec::parse("f32"), Ok(KvCodec::F32));
        assert_eq!(KvCodec::parse("f16"), Ok(KvCodec::F16));
        assert_eq!(KvCodec::parse("int8"), Ok(KvCodec::Int8PerRow));
        assert!(KvCodec::parse("bf16").is_err());
        assert_eq!(KvCodec::default(), KvCodec::F32);
    }
}
