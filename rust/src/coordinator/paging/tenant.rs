//! Tenant identity and per-tenant resource quotas for the shared block
//! pool.
//!
//! FastKV's decoupling of the TSP rate from the KV retention rate only
//! pays off at serving scale if many users can share one block pool
//! without a single heavy tenant starving the rest. This module supplies
//! the vocabulary: a [`TenantId`] rides on every request, and a
//! [`TenantQuota`] bounds what that tenant may take from the shared
//! resources — a **reserved floor** of blocks other tenants can never
//! consume, a **burstable ceiling** it may grow into when the pool has
//! slack, and an optional cap on the host swap bytes its preempted lanes
//! may park.
//!
//! # Charging model: first-toucher
//!
//! Prefix-shared blocks are charged to **exactly one** tenant — the one
//! whose allocation or prefix-cache revival brought the block into its
//! current live (`ref_count > 0`) period — for that entire live period.
//! Later sharers (prefix hits on a live block, `fork`) ride free; the
//! charge is dropped only when the last reference goes away. The
//! alternative, fractional charging per referencing tenant, would need
//! per-(block, tenant) refcounts and would make `can_admit` verdicts
//! depend on sharing that is only discovered *during* admission; the
//! first-toucher rule keeps the invariant `Σ_tenants held == blocks_in_use`
//! exact at every step, which the quota tests and the per-tenant metrics
//! gauges rely on. The documented consequence: a tenant stays charged for
//! a block even if it drops its own reference while another tenant still
//! holds one. In practice sharing is overwhelmingly same-prompt traffic
//! where the first toucher is also the longest holder.

use super::codec::KvCodec;

/// Identity of the tenant (user, organization, API key, ...) a request is
/// served under. Dense small integers by convention — the serving CLIs
/// number tenants `0..N` — but any `u32` works.
#[derive(
    Debug, Clone, Copy, Default, PartialEq, Eq, PartialOrd, Ord, Hash,
)]
pub struct TenantId(
    /// Raw tenant number.
    pub u32,
);

impl TenantId {
    /// The single-tenant default every non-tenant-aware entry point uses
    /// (the engine, legacy `submit`, tests that predate quotas).
    pub const DEFAULT: TenantId = TenantId(0);
}

impl std::fmt::Display for TenantId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

/// Per-tenant resource bounds enforced by the block allocator and the
/// swap arena. Tenants without a configured quota get the default: no
/// reserved floor, unlimited ceiling, the arena-wide swap budget —
/// i.e. exactly the pre-quota behavior.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TenantQuota {
    /// Blocks guaranteed to this tenant: the allocator refuses to hand
    /// other tenants blocks that would eat into the *unused* part of this
    /// floor, so up to `reserved_blocks` are always obtainable by this
    /// tenant no matter how hard the rest of the pool is contended.
    pub reserved_blocks: usize,
    /// Hard cap on blocks charged to this tenant at once (burst ceiling
    /// over the shared pool). `usize::MAX` means no cap.
    pub ceiling_blocks: usize,
    /// Host swap bytes this tenant's preempted lanes may hold in the
    /// [`super::swap::SwapArena`]. `None` inherits the arena-wide budget;
    /// `Some(0)` disables swapping for this tenant (its preemptions
    /// always recompute-resume).
    pub swap_bytes: Option<usize>,
    /// Precision *tier*: the [`KvCodec`] this tenant's preempted lanes
    /// are encoded under in the swap arena (and the codec its swap-budget
    /// predictions are priced at — `PagedArena::swap_out` consults this
    /// tier, not the global flag). `None` inherits the pool default
    /// (`PagingConfig::swap_half` → f16, else the slab codec). Premium
    /// tenants pin `Some(KvCodec::F32)` for bit-identical restores; bulk
    /// tiers ride `Some(KvCodec::Int8PerRow)` for ~4x cheaper parking.
    pub precision: Option<KvCodec>,
}

impl Default for TenantQuota {
    fn default() -> Self {
        TenantQuota {
            reserved_blocks: 0,
            ceiling_blocks: usize::MAX,
            swap_bytes: None,
            precision: None,
        }
    }
}

impl TenantQuota {
    /// Quota with a reserved floor and no burst ceiling — the common
    /// "protect the light tenants" configuration the serve CLIs expose as
    /// `--quota-blocks`.
    pub fn reserved(blocks: usize) -> Self {
        TenantQuota { reserved_blocks: blocks, ..Default::default() }
    }

    /// Quota with both a floor and a ceiling.
    pub fn bounded(reserved: usize, ceiling: usize) -> Self {
        TenantQuota {
            reserved_blocks: reserved,
            ceiling_blocks: ceiling,
            ..Default::default()
        }
    }

    /// This quota with an explicit precision tier.
    pub fn with_precision(mut self, codec: KvCodec) -> Self {
        self.precision = Some(codec);
        self
    }
}

/// Point-in-time per-tenant accounting, published as metrics gauges by
/// the server (`tenant_{id}_*`) and reported by the serve demos. Sourced
/// from the allocator's charge table and the swap arena's per-tenant byte
/// accounting; `Σ held_blocks` over all tenants always equals the pool's
/// `blocks_in_use`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TenantStats {
    /// Which tenant this row describes.
    pub tenant: TenantId,
    /// Blocks currently charged to the tenant (first-toucher rule).
    pub held_blocks: usize,
    /// Configured reserved floor (0 when no quota is set).
    pub reserved_blocks: usize,
    /// Configured burst ceiling (`usize::MAX` when uncapped).
    pub ceiling_blocks: usize,
    /// Host swap bytes currently held by this tenant's parked lanes.
    pub swap_bytes_used: usize,
    /// Effective swap byte cap for this tenant (the arena-wide budget
    /// unless the quota overrides it).
    pub swap_bytes_budget: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_quota_is_unconstrained() {
        let q = TenantQuota::default();
        assert_eq!(q.reserved_blocks, 0);
        assert_eq!(q.ceiling_blocks, usize::MAX);
        assert_eq!(q.swap_bytes, None);
        assert_eq!(q.precision, None, "untiered tenants inherit the pool");
    }

    #[test]
    fn constructors() {
        let q = TenantQuota::reserved(8);
        assert_eq!((q.reserved_blocks, q.ceiling_blocks), (8, usize::MAX));
        let q = TenantQuota::bounded(4, 12);
        assert_eq!((q.reserved_blocks, q.ceiling_blocks), (4, 12));
        let q = TenantQuota::reserved(2).with_precision(KvCodec::Int8PerRow);
        assert_eq!(q.precision, Some(KvCodec::Int8PerRow));
        assert_eq!(TenantId::DEFAULT, TenantId(0));
        assert_eq!(format!("{}", TenantId(3)), "3");
    }
}
