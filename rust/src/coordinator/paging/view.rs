//! `DecodeView` — the zero-copy, block-table-native description of one
//! decode step's KV inputs.
//!
//! A view borrows the block store (no KV data is copied) and carries the
//! per-(layer, lane) block tables and valid lengths in exactly the layout
//! the `decode_paged_{B}x{C}` artifact family consumes:
//!
//! ```text
//!   slab_k / slab_v   [num_blocks, block_tokens, KV, hd]   (borrowed)
//!   tables            [L, B, max_blocks] i32, -1 padded
//!   lens              [L, B] i32
//! ```
//!
//! `max_blocks` is the widest table *actually held* this step, so building
//! a view costs O(referenced blocks) — independent of both the pool size
//! and the staging capacity `C`. That is the property that deletes the
//! dense staging bridge: the old hot path cloned a full `[L, B, C, KV, hd]`
//! tensor pair per generated token.
//!
//! **Codecs.** The borrowed store may be quantized ([`KvCodec`], exposed
//! as [`DecodeView::codec`]). Row reads return `Cow<[f32]>` — borrowed
//! in place under f32, decoded to an owned buffer otherwise — and
//! [`DecodeView::slab_tensors_into`] dequantizes the whole slab
//! (the host-side fallback that keeps f32 artifacts working over a
//! quantized pool). Under [`KvCodec::Int8PerRow`],
//! [`DecodeView::q8_slab_tensors_into`] instead exports the raw
//! quantized planes (as integer-valued f32) plus the per-row scale
//! tensors the `decode_paged_q8_{B}x{C}` artifacts dequantize in-HLO.
//!
//! The same view also serves as the host-side gather oracle:
//! [`DecodeView::k_row`] / [`DecodeView::v_row`] resolve a logical token
//! row through the table, and [`DecodeView::gather_dense`] materializes
//! the dense staging layout on demand (used by `PagedArena::stage()` when
//! the incremental staging copy is disabled, and by the differential
//! tests that pin block-table decode against the staged path).

use std::borrow::Cow;

use crate::tensor::{HostTensor, HostTensorI32};

use super::block::{BlockId, BlockStore};
use super::codec::KvCodec;
use super::shard::{self, ShardSpec};
use super::Staged;

/// Borrowed block-table description of a paged KV store's decode inputs.
#[derive(Debug)]
pub struct DecodeView<'a> {
    /// Slab mutation stamp: upper 32 bits identify the owning store, lower
    /// 32 bits count its mutations. Lets a device-side pinned-buffer cache
    /// skip re-uploading an unchanged slab (`runtime::Runtime::run_pinned`).
    pub version: u64,
    /// Layers.
    pub l: usize,
    /// Decode lanes (batch slots).
    pub b: usize,
    /// Per-lane staging capacity `C` of the owning store (the dense layout
    /// this view replaces; `gather_dense` reproduces it exactly).
    pub capacity: usize,
    /// Token rows per physical block.
    pub block_tokens: usize,
    /// KV heads per token row.
    pub kv_heads: usize,
    /// Elements per head.
    pub head_dim: usize,
    /// Physical blocks in the slab.
    pub num_blocks: usize,
    /// Widest table across all (layer, lane) pairs this step (>= 1).
    pub max_blocks: usize,
    /// `tables[(l * b + slot) * max_blocks + i]` = physical block id of the
    /// lane's i-th logical block, or -1 past the table's end.
    pub tables: Vec<i32>,
    /// `lens[l * b + slot]` = valid token rows.
    pub lens: Vec<i32>,
    /// KV-head shard count of the owning store (1 = unsharded).
    pub shards: usize,
    /// Per-shard slab stamps (`shard_versions[s]`, same store-id-in-the-
    /// upper-bits encoding as [`DecodeView::version`]); length `shards`.
    /// A pinned-slab cache keyed per shard re-uploads only the shards
    /// whose stamp moved.
    pub shard_versions: Vec<u64>,
    /// The codec the borrowed slab is stored under — tells decode
    /// whether a `decode_paged_q8_*` artifact applies
    /// ([`KvCodec::Int8PerRow`]) or the slab tensors need host
    /// dequantization before an f32 artifact.
    pub codec: KvCodec,
    /// Blocks the fine decode-budget stage dropped from the tables this
    /// step, summed over every (layer, lane)
    /// (`PagedArena::view_budgeted`). 0 for unbudgeted views — the
    /// `decode_blocks_pruned` counter's per-step increment.
    pub pruned_blocks: usize,
    pub(super) store: &'a BlockStore,
}

impl<'a> DecodeView<'a> {
    /// f32 elements per token row (`KV * hd`).
    pub fn row_elems(&self) -> usize {
        self.kv_heads * self.head_dim
    }

    /// Valid rows of `(layer, slot)`.
    pub fn len(&self, layer: usize, slot: usize) -> usize {
        self.lens[layer * self.b + slot] as usize
    }

    /// True when no lane holds any valid rows.
    pub fn is_empty(&self) -> bool {
        self.lens.iter().all(|&n| n == 0)
    }

    /// The lane's block table for one layer (including -1 padding).
    pub fn table(&self, layer: usize, slot: usize) -> &[i32] {
        let base = (layer * self.b + slot) * self.max_blocks;
        &self.tables[base..base + self.max_blocks]
    }

    fn block_of(&self, layer: usize, slot: usize, row: usize) -> (BlockId, usize) {
        debug_assert!(row < self.len(layer, slot), "row past len");
        let bt = self.block_tokens;
        let bid = self.table(layer, slot)[row / bt];
        debug_assert!(bid >= 0, "logical row maps to a padded table entry");
        (BlockId(bid as u32), row % bt)
    }

    /// Logical token row `row` of `(layer, slot)`, resolved through the
    /// block table (the gather the paged decode artifact performs in
    /// HLO). Borrowed under f32, decoded-to-owned under a lossy codec.
    pub fn k_row(&self, layer: usize, slot: usize, row: usize) -> Cow<'a, [f32]> {
        let (bid, r) = self.block_of(layer, slot, row);
        self.store.k_row(bid, r)
    }

    /// V-plane counterpart of [`DecodeView::k_row`].
    pub fn v_row(&self, layer: usize, slot: usize, row: usize) -> Cow<'a, [f32]> {
        let (bid, r) = self.block_of(layer, slot, row);
        self.store.v_row(bid, r)
    }

    /// Block tables as the artifact's `[L, B, mb]` i32 input, padded (or
    /// exactly sized) to `mb >= self.max_blocks`.
    pub fn tables_tensor(&self, mb: usize) -> HostTensorI32 {
        let mut out = HostTensorI32::empty();
        self.tables_tensor_into(mb, &mut out);
        out
    }

    /// [`DecodeView::tables_tensor`] into a caller-owned tensor, reusing
    /// its buffers (scratch variant: zero heap allocation once the
    /// buffers reach steady-state size — see `decode::DecodeScratch`).
    pub fn tables_tensor_into(&self, mb: usize, out: &mut HostTensorI32) {
        assert!(
            mb >= self.max_blocks,
            "artifact table width {mb} < live width {}",
            self.max_blocks
        );
        out.shape.clear();
        out.shape.extend_from_slice(&[self.l, self.b, mb]);
        out.data.clear();
        out.data.resize(self.l * self.b * mb, -1);
        for ls in 0..self.l * self.b {
            let src = &self.tables[ls * self.max_blocks..(ls + 1) * self.max_blocks];
            out.data[ls * mb..ls * mb + self.max_blocks].copy_from_slice(src);
        }
    }

    /// Valid lengths as the artifact's `[L, B]` i32 input.
    pub fn lens_tensor(&self) -> HostTensorI32 {
        HostTensorI32::new(vec![self.l, self.b], self.lens.clone())
    }

    /// [`DecodeView::lens_tensor`] into a caller-owned tensor (scratch
    /// variant).
    pub fn lens_tensor_into(&self, out: &mut HostTensorI32) {
        out.shape.clear();
        out.shape.extend_from_slice(&[self.l, self.b]);
        out.data.clear();
        out.data.extend_from_slice(&self.lens);
    }

    /// Slab planes as the artifact's `[nb, bt, KV, hd]` f32 inputs, zero
    /// padded to the artifact's pool bucket `nb >= self.num_blocks`. This
    /// is the one O(pool) copy left on the paged path, and it runs only
    /// when the device-side pinned slab is stale (see `Runtime::run_pinned`).
    ///
    /// Under a lossy codec this *dequantizes* the slab — the host-side
    /// fallback that lets plain f32 artifacts decode over a quantized
    /// pool (the dequant cost lands in `PoolStats::codec_secs`).
    pub fn slab_tensors(&self, nb: usize) -> (HostTensor, HostTensor) {
        let mut k = HostTensor::empty();
        let mut v = HostTensor::empty();
        self.slab_tensors_into(nb, &mut k, &mut v);
        (k, v)
    }

    /// [`DecodeView::slab_tensors`] into caller-owned tensors (scratch
    /// variant for the stale-slab re-upload path).
    pub fn slab_tensors_into(
        &self,
        nb: usize,
        k: &mut HostTensor,
        v: &mut HostTensor,
    ) {
        assert!(
            nb >= self.num_blocks,
            "artifact pool bucket {nb} < live pool {}",
            self.num_blocks
        );
        let shape = [nb, self.block_tokens, self.kv_heads, self.head_dim];
        let elems = nb * self.block_tokens * self.row_elems();
        for t in [&mut *k, &mut *v] {
            t.shape.clear();
            t.shape.extend_from_slice(&shape);
            t.data.clear();
            t.data.resize(elems, 0.0);
        }
        self.store.decode_k_plane_into(&mut k.data);
        self.store.decode_v_plane_into(&mut v.data);
    }

    /// The int8 slab as the `decode_paged_q8_{B}x{C}` artifact's inputs:
    /// quantized K/V planes as **integer-valued f32** tensors
    /// `[nb, bt, KV, hd]` (the runtime's host tensors are f32-only) plus
    /// per-row scale tensors `[nb, bt]`, all zero-padded to the pool
    /// bucket `nb`. The artifact dequantizes in-HLO
    /// (`slab * scales[:, :, None, None]`). Returns false — leaving the
    /// outputs untouched — unless the store codec is
    /// [`KvCodec::Int8PerRow`]; callers then fall back to the
    /// dequantizing [`DecodeView::slab_tensors_into`].
    pub fn q8_slab_tensors_into(
        &self,
        nb: usize,
        k_q: &mut HostTensor,
        k_scales: &mut HostTensor,
        v_q: &mut HostTensor,
        v_scales: &mut HostTensor,
    ) -> bool {
        let Some(q8) = self.store.q8_planes() else {
            return false;
        };
        assert!(
            nb >= self.num_blocks,
            "artifact pool bucket {nb} < live pool {}",
            self.num_blocks
        );
        let bt = self.block_tokens;
        let plane_shape = [nb, bt, self.kv_heads, self.head_dim];
        let elems = nb * bt * self.row_elems();
        for t in [&mut *k_q, &mut *v_q] {
            t.shape.clear();
            t.shape.extend_from_slice(&plane_shape);
            t.data.clear();
            t.data.resize(elems, 0.0);
        }
        for t in [&mut *k_scales, &mut *v_scales] {
            t.shape.clear();
            t.shape.extend_from_slice(&[nb, bt]);
            t.data.clear();
            t.data.resize(nb * bt, 0.0);
        }
        for (dst, src) in [(&mut *k_q, q8.k_q), (&mut *v_q, q8.v_q)] {
            for (o, &q) in dst.data.iter_mut().zip(src) {
                *o = q as f32;
            }
        }
        k_scales.data[..q8.k_scales.len()].copy_from_slice(q8.k_scales);
        v_scales.data[..q8.v_scales.len()].copy_from_slice(q8.v_scales);
        true
    }

    /// Convenience form of [`DecodeView::q8_slab_tensors_into`]:
    /// `(k_q, k_scales, v_q, v_scales)`, or `None` for non-int8 stores.
    pub fn q8_slab_tensors(
        &self,
        nb: usize,
    ) -> Option<(HostTensor, HostTensor, HostTensor, HostTensor)> {
        let (mut kq, mut ks, mut vq, mut vs) = (
            HostTensor::empty(),
            HostTensor::empty(),
            HostTensor::empty(),
            HostTensor::empty(),
        );
        self.q8_slab_tensors_into(nb, &mut kq, &mut ks, &mut vq, &mut vs)
            .then_some((kq, ks, vq, vs))
    }

    /// The shard layout of the owning store.
    pub fn shard_spec(&self) -> ShardSpec {
        debug_assert_eq!(self.kv_heads % self.shards, 0, "validated at config");
        ShardSpec { shards: self.shards, kv_heads: self.kv_heads, head_dim: self.head_dim }
    }

    /// Per-shard projection of this view: shard `s`'s slice of the slab
    /// planes plus its own version stamp. Tables and lens are shared —
    /// build them once from the parent view; only the slab planes differ
    /// per shard.
    pub fn view_shard(&self, shard: usize) -> ShardView<'_> {
        assert!(shard < self.shards, "shard {shard} of {}", self.shards);
        ShardView {
            shard,
            spec: self.shard_spec(),
            version: self.shard_versions[shard],
            block_tokens: self.block_tokens,
            num_blocks: self.num_blocks,
            codec: self.codec,
            store: self.store,
        }
    }

    /// Reassembled dense planes from every shard's projection — the
    /// differential oracle's check that sharding loses nothing:
    /// identical to the (dequantized) whole-slab planes for any valid
    /// shard count, bit for bit under lossless codecs.
    pub fn reassembled_slab(&self) -> (Vec<f32>, Vec<f32>) {
        let spec = self.shard_spec();
        let nb = self.num_blocks;
        let ks: Vec<HostTensor> = (0..self.shards)
            .map(|s| self.view_shard(s).slab_tensors(nb).0)
            .collect();
        let vs: Vec<HostTensor> = (0..self.shards)
            .map(|s| self.view_shard(s).slab_tensors(nb).1)
            .collect();
        (
            shard::reassemble_planes(spec, &ks, nb, self.block_tokens),
            shard::reassemble_planes(spec, &vs, nb, self.block_tokens),
        )
    }

    /// Materialize the dense `[L, B, C, KV, hd]` staging layout (plus
    /// `[L, B]` lens) this view replaces. Byte-identical to what the
    /// incrementally-maintained staging copy would hold: only valid rows
    /// are written, everything else stays zero. (Under a lossy codec both
    /// paths hold the decoded quantized rows — the staging copy mirrors
    /// the store's read-back, this gathers it directly.)
    pub fn gather_dense(&self) -> Staged {
        let re = self.row_elems();
        let shape =
            vec![self.l, self.b, self.capacity, self.kv_heads, self.head_dim];
        let mut k = HostTensor::zeros(shape.clone());
        let mut v = HostTensor::zeros(shape);
        for l in 0..self.l {
            for s in 0..self.b {
                let n = self.len(l, s);
                for row in 0..n {
                    let dst = ((l * self.b + s) * self.capacity + row) * re;
                    k.data[dst..dst + re]
                        .copy_from_slice(&self.k_row(l, s, row));
                    v.data[dst..dst + re]
                        .copy_from_slice(&self.v_row(l, s, row));
                }
            }
        }
        Staged { k, v, lens: self.lens_tensor() }
    }
}

/// One KV-head shard's slice of a [`DecodeView`]: the inputs shard `s`'s
/// executor consumes. Block tables and lens are deliberately *not* here —
/// they are shard-oblivious and shared from the parent view; only the
/// slab planes (and their staleness stamp) differ per shard.
#[derive(Debug)]
pub struct ShardView<'a> {
    /// Which shard this is.
    pub shard: usize,
    /// The owning store's shard layout.
    pub spec: ShardSpec,
    /// This shard's slab stamp (same encoding as [`DecodeView::version`]);
    /// drives the per-shard pinned-buffer cache.
    pub version: u64,
    /// Token rows per physical block.
    pub block_tokens: usize,
    /// Physical blocks in the (shared) pool.
    pub num_blocks: usize,
    /// Codec of the underlying store. Sharded decode over a lossy store
    /// takes the host-dequant path ([`ShardView::slab_tensors_into`]
    /// decodes before projecting).
    pub codec: KvCodec,
    store: &'a BlockStore,
}

impl<'a> ShardView<'a> {
    /// f32 elements of this shard's slice of a token row (`KV/S * hd`).
    pub fn row_elems(&self) -> usize {
        self.spec.shard_row_elems()
    }

    /// This shard's slice of one physical block row (a shard's heads are
    /// contiguous inside the dense row). Zero-copy under f32; under a
    /// lossy codec the row is decoded and the slice owned.
    pub fn k_block_row(&self, block: usize, row: usize) -> Cow<'a, [f32]> {
        self.block_row(false, block, row)
    }

    /// V-plane counterpart of [`ShardView::k_block_row`].
    pub fn v_block_row(&self, block: usize, row: usize) -> Cow<'a, [f32]> {
        self.block_row(true, block, row)
    }

    fn block_row(&self, v: bool, block: usize, row: usize) -> Cow<'a, [f32]> {
        let range = self.spec.row_range(self.shard);
        let bid = BlockId(block as u32);
        let full = if v {
            self.store.v_row(bid, row)
        } else {
            self.store.k_row(bid, row)
        };
        match full {
            Cow::Borrowed(r) => Cow::Borrowed(&r[range]),
            Cow::Owned(r) => Cow::Owned(r[range].to_vec()),
        }
    }

    /// This shard's slab planes in the sharded artifact's layout
    /// `[nb, bt, KV/S, hd]`, zero-padded to the artifact pool bucket
    /// `nb >= num_blocks`. The per-shard counterpart of
    /// [`DecodeView::slab_tensors`]: 1/S of the copy, and only run for
    /// shards whose pinned device plane went stale.
    pub fn slab_tensors(&self, nb: usize) -> (HostTensor, HostTensor) {
        let mut k = HostTensor::empty();
        let mut v = HostTensor::empty();
        self.slab_tensors_into(nb, &mut k, &mut v);
        (k, v)
    }

    /// [`ShardView::slab_tensors`] into caller-owned tensors (scratch
    /// variant). Under a lossy codec the dense plane is decoded into a
    /// scratch buffer first and the shard projected from it — the sharded
    /// host-dequant fallback (in-HLO q8 dequant is wired for the
    /// unsharded family; see `decode.rs`).
    pub fn slab_tensors_into(
        &self,
        nb: usize,
        k: &mut HostTensor,
        v: &mut HostTensor,
    ) {
        assert!(
            nb >= self.num_blocks,
            "artifact pool bucket {nb} < live pool {}",
            self.num_blocks
        );
        let srw = self.row_elems();
        let shape =
            [nb, self.block_tokens, self.spec.kv_per_shard(), self.spec.head_dim];
        let elems = nb * self.block_tokens * srw;
        for t in [&mut *k, &mut *v] {
            t.shape.clear();
            t.shape.extend_from_slice(&shape);
            t.data.clear();
            t.data.resize(elems, 0.0);
        }
        match (self.store.k_plane_f32(), self.store.v_plane_f32()) {
            (Some(kp), Some(vp)) => {
                shard::project_plane_into(
                    kp,
                    self.spec,
                    self.shard,
                    self.num_blocks,
                    self.block_tokens,
                    &mut k.data,
                );
                shard::project_plane_into(
                    vp,
                    self.spec,
                    self.shard,
                    self.num_blocks,
                    self.block_tokens,
                    &mut v.data,
                );
            }
            _ => {
                let rows = self.num_blocks * self.block_tokens;
                let mut dense = vec![0.0f32; rows * self.spec.row_elems()];
                self.store.decode_k_plane_into(&mut dense);
                shard::project_plane_into(
                    &dense,
                    self.spec,
                    self.shard,
                    self.num_blocks,
                    self.block_tokens,
                    &mut k.data,
                );
                self.store.decode_v_plane_into(&mut dense);
                shard::project_plane_into(
                    &dense,
                    self.spec,
                    self.shard,
                    self.num_blocks,
                    self.block_tokens,
                    &mut v.data,
                );
            }
        }
    }
}
