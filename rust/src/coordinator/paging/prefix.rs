//! Hash-based prefix cache: content-addressed reuse of full KV blocks.
//!
//! Each full block loaded at admission is hashed with a *chained* FNV-1a
//! over (previous chain value, layer, the block's K and V rows), so a hash
//! identifies both the block's content and its position in the sequence —
//! exactly the vLLM prefix-caching keying, except we hash the compressed
//! KV rows themselves rather than prompt token ids. Hashing content makes
//! reuse policy-aware for free: two requests share a block iff the policy
//! actually produced identical retained KV for that span, which holds for
//! shared prompts under any deterministic policy.
//!
//! Collisions: 64-bit FNV over full row bytes; a false positive requires a
//! 2^-64-scale collision on same-layer same-chain content. Accepted (and
//! documented) like vLLM's token-hash scheme.

use std::collections::HashMap;

use super::block::BlockId;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Chain seed for the first block of a layer (layer-distinct so identical
/// content in different layers never aliases).
pub fn layer_seed(layer: usize) -> u64 {
    fnv1a(FNV_OFFSET, &(layer as u64).to_le_bytes())
}

/// Chained block hash: previous chain value + layer + row contents.
pub fn chain_hash(prev: u64, layer: usize, k_rows: &[f32], v_rows: &[f32]) -> u64 {
    let mut h = fnv1a(prev, &(layer as u64).to_le_bytes());
    for &x in k_rows {
        h = fnv1a(h, &x.to_bits().to_le_bytes());
    }
    for &x in v_rows {
        h = fnv1a(h, &x.to_bits().to_le_bytes());
    }
    h
}

/// Hash → physical block map with hit/miss accounting.
#[derive(Debug)]
pub struct PrefixCache {
    map: HashMap<u64, BlockId>,
    /// Whether prefix reuse is on (off = every lookup is skipped).
    pub enabled: bool,
    /// Lookups that found a registered block.
    pub hits: u64,
    /// Lookups that missed.
    pub misses: u64,
}

impl PrefixCache {
    /// Empty cache; `enabled = false` turns registration/lookup off.
    pub fn new(enabled: bool) -> Self {
        PrefixCache { map: HashMap::new(), enabled, hits: 0, misses: 0 }
    }

    /// Look up a block by chain hash, counting the hit or miss.
    pub fn lookup(&mut self, hash: u64) -> Option<BlockId> {
        let got = self.map.get(&hash).copied();
        if got.is_some() {
            self.hits += 1;
        } else {
            self.misses += 1;
        }
        got
    }

    /// Register a sealed block under its chain hash.
    pub fn insert(&mut self, hash: u64, id: BlockId) {
        self.map.insert(hash, id);
    }

    /// Unregister a hash (block evicted, diverged, or stale).
    pub fn remove(&mut self, hash: u64) {
        self.map.remove(&hash);
    }

    /// Registered hashes.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True when nothing is registered.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Hit fraction over all lookups (0 when none happened).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_hash_discriminates() {
        let k = [1.0f32, 2.0];
        let v = [3.0f32, 4.0];
        let h0 = chain_hash(layer_seed(0), 0, &k, &v);
        // different layer, same content
        assert_ne!(h0, chain_hash(layer_seed(1), 1, &k, &v));
        // different predecessor
        assert_ne!(h0, chain_hash(h0, 0, &k, &v));
        // different content
        assert_ne!(h0, chain_hash(layer_seed(0), 0, &[1.0, 2.5], &v));
        // deterministic
        assert_eq!(h0, chain_hash(layer_seed(0), 0, &k, &v));
    }

    #[test]
    fn lookup_counts_hits_and_misses() {
        let mut p = PrefixCache::new(true);
        assert!(p.lookup(42).is_none());
        p.insert(42, BlockId(3));
        assert_eq!(p.lookup(42), Some(BlockId(3)));
        assert_eq!((p.hits, p.misses), (1, 1));
        assert!((p.hit_rate() - 0.5).abs() < 1e-12);
        p.remove(42);
        assert!(p.lookup(42).is_none());
        assert!(p.is_empty());
    }
}
