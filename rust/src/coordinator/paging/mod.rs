//! Paged KV-cache subsystem: block pool, prefix reuse, and FastKV-aware
//! eviction.
//!
//! The seed runtime staged decode KV in a flat [`BatchArena`] — one
//! contiguous `[L, B, C, KV, hd]` region, one whole slot per request, no
//! sharing, no incremental growth. This module replaces that with a
//! vLLM-style paged design while keeping the decode-artifact ABI intact:
//!
//!  * [`block::BlockStore`] — a global slab of fixed-size token blocks;
//!  * [`allocator::BlockAllocator`] — free-list allocation, ref-counting,
//!    copy-on-write, and LRU reclamation of cached blocks;
//!  * [`prefix::PrefixCache`] — chained content hashes so requests sharing
//!    a compressed-KV prefix reuse physical blocks;
//!  * [`PagedArena`] — the per-batch façade: per-(sequence, layer) block
//!    tables over the shared slab;
//!  * [`view::DecodeView`] — the block-table-native decode description
//!    (slab borrow + tables + lens, no KV copies) consumed by the
//!    `decode_paged_{B}x{C}` artifacts and the host-side gather oracle;
//!  * [`swap::SwapArena`] — byte-budgeted host parking for preempted
//!    lanes, so resume restores the FastKV-selected KV instead of
//!    re-prefilling it ([`PagedArena::swap_out`] / [`PagedArena::swap_in`]);
//!  * [`tenant`] — multi-tenant quotas: every lane belongs to a
//!    [`TenantId`], blocks are charged to the tenant that first touched
//!    them, and a [`TenantQuota`] bounds each tenant with a reserved
//!    floor, a burst ceiling, and a per-tenant swap byte cap, so one
//!    heavy tenant cannot starve the pool for everyone else;
//!  * [`shard`] — KV-head sharding of the slab across executors
//!    ([`ShardSpec`], [`ShardedSlabs`]): the K/V planes split into `S`
//!    per-shard slabs of `[num_blocks, block_tokens, KV/S, hd]` with
//!    per-shard pinned-upload staleness stamps, while the block table,
//!    allocator, prefix cache, quotas, swap, and compaction stay
//!    shard-oblivious (`PagingConfig::shards`, default 1 ≡ the
//!    bit-identical unsharded path).
//!
//! Decode is block-table-native by default: a step hands the runtime the
//! slab plus block-table indices instead of densifying the pool. The old
//! dense staging bridge survives behind
//! [`PagingConfig::dense_staging`] as a differential fallback — with it
//! enabled the arena additionally maintains the `[L, B, C, KV, hd]`
//! staging copy incrementally, and `stage()` returns that copy instead of
//! gathering on demand.
//!
//! Both arenas implement [`KvStore`], the backend trait the engine,
//! server, and scheduler program against; `PagedArena` is the default.
//! See `README.md` in this directory for the design rationale.
#![warn(missing_docs)]

pub mod allocator;
pub mod block;
pub mod codec;
pub mod prefix;
pub mod shard;
pub mod swap;
pub mod tenant;
pub mod view;

pub use codec::KvCodec;
pub use shard::{ShardSpec, ShardedSlabs};
pub use swap::{SwapHandle, SwapIn, SwapStats};
pub use tenant::{TenantId, TenantQuota, TenantStats};
pub use view::{DecodeView, ShardView};

use crate::coordinator::kvcache::{BatchArena, RequestCache};
use crate::manifest::ModelMeta;
use crate::tensor::{HostTensor, HostTensorI32};

use std::collections::BTreeMap;

use allocator::{BlockAllocator, Revive};
use block::BlockId;
use prefix::PrefixCache;
use swap::{KvLane, SwapArena, SwapEntry};

/// Tunables for [`PagedArena`].
#[derive(Debug, Clone)]
pub struct PagingConfig {
    /// Tokens per physical block.
    pub block_tokens: usize,
    /// Pool size in blocks. `None` sizes the pool for the worst case
    /// (`L * B * ceil(C / block_tokens)`), which can never under-provision;
    /// smaller pools enable real memory-aware admission and preemption.
    pub num_blocks: Option<usize>,
    /// Enable hash-based prefix reuse of full blocks.
    pub prefix_cache: bool,
    /// Fallback: additionally maintain the dense `[L, B, C, KV, hd]`
    /// staging copy incrementally and serve `stage()` from it (the
    /// pre-block-table decode bridge). Off by default — decode reads block
    /// tables directly through [`DecodeView`], and `stage()` gathers on
    /// demand (tests/tools only). Kept so a differential oracle can pin
    /// block-table decode against the staged path.
    pub dense_staging: bool,
    /// Host-side swap budget in bytes for preempted lanes
    /// ([`swap::SwapArena`]). A preempted lane's blocks are serialized to
    /// host within this budget and restored on resume — no re-prefill, no
    /// policy re-run. `0` disables swapping (preemption always
    /// recompute-resumes, the pre-swap behavior).
    pub swap_bytes: usize,
    /// Legacy alias for a pool-wide f16 *swap* tier: encode swapped lane
    /// payloads as IEEE 754 binary16 ([`swap::KvLane::F16`]) instead of
    /// verbatim f32, halving host budget pressure at a per-element
    /// precision cost of one f16 rounding step (relative 2^-11). Off by
    /// default; restores under it are *not* bit-identical, so lossy
    /// entries never re-register their preserved prefix hashes for
    /// freshly-written blocks. Subsumed by `precision` + per-tenant
    /// [`TenantQuota::precision`] tiers, which also govern the resident
    /// slab; a tenant with an explicit tier ignores this flag.
    pub swap_half: bool,
    /// [`KvCodec`] the resident block-pool slab is stored under
    /// (in-slab quantization). [`KvCodec::F32`] (the default) is the
    /// pre-quantization store, bit for bit. [`KvCodec::F16`] and
    /// [`KvCodec::Int8PerRow`] shrink the pool footprint 2x / ~4x;
    /// both are lossy, so prefix hashes are still computed over the
    /// exact pre-quantization rows and lossy restores never re-seal.
    /// Tenants without an explicit [`TenantQuota::precision`] tier also
    /// swap at this codec (unless `swap_half` overrides it to f16).
    pub precision: KvCodec,
    /// Per-tenant quotas installed at construction (reserved block
    /// floor, burst ceiling, optional swap byte cap — see
    /// [`TenantQuota`]). Empty (the default) means single-tenant
    /// behavior: every request runs as [`TenantId::DEFAULT`] with the
    /// whole pool available.
    pub tenant_quotas: Vec<(TenantId, TenantQuota)>,
    /// KV-head shard count `S` for the slab ([`ShardSpec`]). Must divide
    /// the model's `kv_heads` — [`PagedArena::new`] panics with
    /// [`ShardSpec::new`]'s message otherwise (CLIs validate first and
    /// report it as a config error). `1` (the default) is the unsharded
    /// single-executor path and is bit-identical to the pre-shard store.
    pub shards: usize,
}

impl Default for PagingConfig {
    fn default() -> Self {
        PagingConfig {
            block_tokens: 16,
            num_blocks: None,
            prefix_cache: true,
            dense_staging: false,
            // Generous default for an f32 host cache: preemption should
            // swap unless the operator opts out (`swap_bytes: 0`).
            swap_bytes: 128 << 20,
            swap_half: false,
            precision: KvCodec::F32,
            tenant_quotas: Vec::new(),
            shards: 1,
        }
    }
}

/// Outcome of a per-step KV append.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AppendResult {
    /// Row appended on every layer.
    Ok,
    /// The sequence hit its staging-lane capacity `C`; the request is done
    /// growing (same condition the flat arena reported as `false`).
    CapacityExhausted,
    /// The block pool cannot supply the blocks this step needs; the caller
    /// should compact or preempt — the sequence itself is intact.
    PoolExhausted,
}

/// Dense decode-step inputs materialized from a KV store.
#[derive(Debug, Clone)]
pub struct Staged {
    /// K rows, `[L, B, C, KV, hd]`.
    pub k: HostTensor,
    /// V rows, same layout as `k`.
    pub v: HostTensor,
    /// `[L, B]` valid rows.
    pub lens: HostTensorI32,
}

/// Resolved decode-phase KV budget a [`KvStore`] enforces over a lane's
/// *generated* rows (everything appended after admission). Prefill rows —
/// the FastKV-selected KV the lane was admitted with — are never touched:
/// the budget decouples decode-time eviction from prefill-time selection
/// (SCOPE-style split budgets) the same way TSP decoupled prefill
/// selection from per-layer compaction.
///
/// Two stages, RocketKV-style:
///  * **coarse** ([`KvStore::enforce_decode_budget`]): when a lane's
///    resident generated rows exceed `coarse_rows`, whole cold blocks are
///    permanently released back to the allocator (scored by the per-block
///    recency/attention-mass heuristic in [`block::BlockMeta`]);
///  * **fine** ([`KvStore::decode_view_budgeted`]): each step's attention
///    view keeps only the top-scoring generated blocks so at most
///    `fine_rows` generated rows per (layer, lane) are attended — a pruned
///    per-lane block table handed to the existing gather artifacts, no new
///    HLO.
///
/// Both stages always retain the first `sinks` token rows (attention
/// sinks) and the trailing `window` generated rows (the sliding decode
/// window); blocks overlapping either — or any prefill row — are never
/// candidates. Built from policy knobs by
/// [`crate::coordinator::policies::PolicyCfg::decode_budget_spec`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DecodeBudget {
    /// Fine-stage cap: generated rows per (layer, lane) a decode step's
    /// attention view may cover. `>= window.max(1)`.
    pub fine_rows: usize,
    /// Coarse-stage cap: resident generated rows per (layer, lane) above
    /// which the coldest full generated blocks are permanently released.
    /// `>= fine_rows` (the slack between them is the survivor set the
    /// fine stage re-ranks every step).
    pub coarse_rows: usize,
    /// Sliding decode window: trailing rows always resident and attended.
    pub window: usize,
    /// Leading token rows (attention sinks) always resident and attended.
    pub sinks: usize,
}

/// Block-pool gauges for metrics/reporting.
#[derive(Debug, Clone, Default)]
pub struct PoolStats {
    /// Pool size in blocks.
    pub blocks_total: usize,
    /// Blocks referenced by live block tables.
    pub blocks_in_use: usize,
    /// Ref-0 blocks kept for prefix reuse (reclaimable).
    pub blocks_cached: usize,
    /// Blocks on the free list.
    pub blocks_free: usize,
    /// Token rows per block.
    pub block_tokens: usize,
    /// Prefix-cache lookups that found a reusable block.
    pub prefix_hits: u64,
    /// Prefix-cache lookups that missed.
    pub prefix_misses: u64,
    /// Copy-on-write block copies performed.
    pub cow_copies: u64,
    /// Cached blocks reclaimed for new allocations.
    pub evictions: u64,
    /// Admissions/appends the pool could not supply blocks for.
    pub alloc_failures: u64,
    /// Block takes refused by a tenant quota while the pool itself still
    /// had allocatable blocks (pure exhaustion is `alloc_failures`).
    pub quota_denials: u64,
    /// Resident slab footprint in bytes under the pool's codec (K + V
    /// planes, scale planes included for int8) — the
    /// `pool_bytes_quantized` gauge. Codec-aware: an int8 pool reports
    /// ~1/4 the bytes of the same pool at f32.
    pub slab_bytes: usize,
    /// [`KvCodec`] the resident slab is stored under.
    pub codec: KvCodec,
    /// K/V rows quantized into a lossy slab (write side; 0 at f32).
    pub quant_rows: u64,
    /// K/V rows dequantized out of a lossy slab (read side; 0 at f32).
    pub dequant_rows: u64,
    /// Seconds spent in bulk plane encode/decode (the
    /// `quant_dequant_secs` counter; per-row codec work is counted in
    /// the row counters but deliberately not timed).
    pub codec_secs: f64,
    /// Blocks holding at least one *generated* (decode-appended) row
    /// across all used lanes — the resident set decode budgets bound
    /// (the `decode_region_blocks` gauge). 0 for non-paged backends.
    pub decode_region_blocks: usize,
}

impl PoolStats {
    /// Prefix-cache hit fraction (0 when no lookups happened).
    pub fn prefix_hit_rate(&self) -> f64 {
        let total = self.prefix_hits + self.prefix_misses;
        if total == 0 {
            0.0
        } else {
            self.prefix_hits as f64 / total as f64
        }
    }
}

/// Backend abstraction for decode-stage KV storage. The flat
/// [`BatchArena`] and the paged [`PagedArena`] both implement it; the
/// engine and server program against this trait only.
pub trait KvStore {
    /// Number of decode lanes (batch slots).
    fn slots(&self) -> usize;
    /// Lanes not currently serving a request.
    fn free_slots(&self) -> usize;
    /// Per-lane token capacity `C` of the staging layout.
    fn capacity(&self) -> usize;
    /// Cheap pre-prefill admission check from a post-compression per-layer
    /// token estimate. `max_new` is the remaining decode budget; backends
    /// may reserve only minimal growth headroom for it (over-commit),
    /// relying on compaction/preemption for the rest.
    fn can_admit(&self, per_layer_tokens: usize, max_new: usize) -> bool;
    /// Whether a request with this post-compression budget could EVER be
    /// admitted, even on a fully drained store (lane + pool sizing
    /// permitting). Distinguishes "wait for memory" from "hopeless" —
    /// e.g. preemption must not requeue a request the pool can never
    /// take back.
    fn could_ever_admit(&self, per_layer_tokens: usize) -> bool;
    /// Load a compressed request cache; `None` when no lane is free or the
    /// pool cannot cover it (the store is left unchanged in that case).
    fn admit(&mut self, cache: &RequestCache) -> Option<usize>;
    /// Release a lane and its storage. Returns false if it was not in use
    /// (double-release guard).
    fn release(&mut self, slot: usize) -> bool;
    /// Append one decode step's KV row per layer
    /// (`k_new`/`v_new`: `[L, B, KV, hd]`).
    fn append(&mut self, slot: usize, k_new: &HostTensor, v_new: &HostTensor) -> AppendResult;
    /// Valid rows per layer for a lane.
    fn layer_lens(&self, slot: usize) -> Vec<usize>;
    /// Longest per-layer length for a lane.
    fn seq_len(&self, slot: usize) -> usize {
        self.layer_lens(slot).into_iter().max().unwrap_or(0)
    }
    /// Block-granular eviction: retain only `keep[l]` (ascending logical
    /// row indices) on each layer. Returns physical blocks actually
    /// released back to the pool.
    fn compact(&mut self, slot: usize, keep: &[Vec<usize>]) -> usize;
    /// Materialize dense decode inputs (fallback / oracle path — the
    /// default decode hot path consumes [`KvStore::decode_view`] instead).
    fn stage(&self) -> Staged;
    /// Block-table-native decode description, if this backend supports it.
    /// `None` (the flat arena) forces the dense staged path.
    fn decode_view(&self) -> Option<DecodeView<'_>> {
        None
    }
    /// [`KvStore::decode_view`] with the fine budget stage applied: lanes
    /// whose generated rows exceed `budget.fine_rows` get a *pruned* block
    /// table (lowest-scoring generated blocks dropped; sinks, window, and
    /// every prefill block always kept). `None` budget — and backends
    /// without budget support — fall back to the unpruned view, so the
    /// unbudgeted path is bit-identical to the pre-budget store.
    fn decode_view_budgeted(
        &self,
        budget: Option<&DecodeBudget>,
    ) -> Option<DecodeView<'_>> {
        let _ = budget;
        self.decode_view()
    }
    /// Coarse budget stage: permanently release a lane's coldest full
    /// generated blocks until its resident generated rows are within
    /// `budget.coarse_rows` per layer. Returns blocks released back to the
    /// pool (0 for backends without budget support — the unbounded
    /// pre-budget behavior). Sink rows, the sliding window, and prefill
    /// rows are never released.
    fn enforce_decode_budget(&mut self, slot: usize, budget: &DecodeBudget) -> usize {
        let _ = (slot, budget);
        0
    }
    /// Physical blocks currently held by a lane (0 for non-paged
    /// backends). Drives preemption victim selection.
    fn held_blocks(&self, _slot: usize) -> usize {
        0
    }
    /// Block-pool gauges snapshot.
    fn pool_stats(&self) -> PoolStats;

    // --- KV-head slab sharding (optional capability) ------------------
    // Backends without a sharded slab keep these defaults: one logical
    // shard, no per-shard gauges — the pre-shard behavior.

    /// KV-head shard count of the slab (1 = unsharded).
    fn shard_count(&self) -> usize {
        1
    }
    /// Per-shard slab bytes (K + V planes), indexed by shard — feeds the
    /// `shard_{s}_slab_bytes` gauges. Empty for unsharded backends.
    fn shard_slab_bytes(&self) -> Vec<usize> {
        Vec::new()
    }
    /// Used lanes grouped by their effective precision tier (the
    /// tenant's [`TenantQuota::precision`] or the pool default) — feeds
    /// the `lanes_tier_{f32,f16,int8}` gauges. Empty for backends
    /// without precision tiers.
    fn lanes_by_tier(&self) -> Vec<(KvCodec, usize)> {
        Vec::new()
    }

    // --- multi-tenant quotas (optional capability) -------------------
    // Backends without tenancy keep these defaults: every request runs
    // as `TenantId::DEFAULT` with no quota, the pre-tenancy behavior.

    /// Tenant-aware [`KvStore::can_admit`]: additionally requires that
    /// the take fits the tenant's burst ceiling and leaves every *other*
    /// tenant's unused reserved floor obtainable.
    fn can_admit_for(
        &self,
        per_layer_tokens: usize,
        max_new: usize,
        tenant: TenantId,
    ) -> bool {
        let _ = tenant;
        self.can_admit(per_layer_tokens, max_new)
    }
    /// Tenant-aware [`KvStore::could_ever_admit`]: judged against the
    /// most this tenant could ever obtain (pool minus other tenants'
    /// full floors, capped by its own ceiling).
    fn could_ever_admit_for(
        &self,
        per_layer_tokens: usize,
        tenant: TenantId,
    ) -> bool {
        let _ = tenant;
        self.could_ever_admit(per_layer_tokens)
    }
    /// Tenant-aware [`KvStore::admit`]: the lane and every block it
    /// takes are charged to `tenant`.
    fn admit_for(
        &mut self,
        cache: &RequestCache,
        tenant: TenantId,
    ) -> Option<usize> {
        let _ = tenant;
        self.admit(cache)
    }
    /// Install (or replace) a tenant's quota at runtime. No-op for
    /// backends without tenancy.
    fn set_tenant_quota(&mut self, tenant: TenantId, quota: TenantQuota) {
        let _ = (tenant, quota);
    }
    /// Tenant a lane is charged to ([`TenantId::DEFAULT`] for non-tenant
    /// backends or unused lanes).
    fn tenant_of(&self, slot: usize) -> TenantId {
        let _ = slot;
        TenantId::DEFAULT
    }
    /// Whether `tenant` currently holds more blocks than its reserved
    /// floor (always false when no quotas are configured). Preemption
    /// victim selection prefers lanes of over-quota tenants.
    fn tenant_over_quota(&self, tenant: TenantId) -> bool {
        let _ = tenant;
        false
    }
    /// Whether `tenant` sits at its burst ceiling: freeing *other*
    /// tenants' blocks cannot relieve it, so pool pressure from its
    /// lanes must be resolved within the tenant (or by finishing the
    /// lane). Always false without tenancy.
    fn tenant_at_ceiling(&self, tenant: TenantId) -> bool {
        let _ = tenant;
        false
    }
    /// Whether preempting a lane of tenant `victim` can increase what
    /// `pressured` may take from the pool. Victim-selection filter: it
    /// rules out lanes whose frees are owed straight back to a quota
    /// (the victim's own protected floor, or any cross-tenant free when
    /// the pressured tenant is ceiling-bound). Always true without
    /// tenancy.
    fn preempt_helps(&self, victim: TenantId, pressured: TenantId) -> bool {
        let _ = (victim, pressured);
        true
    }
    /// Per-tenant accounting rows for metrics/reporting (empty for
    /// backends without tenancy).
    fn tenant_stats(&self) -> Vec<TenantStats> {
        Vec::new()
    }

    // --- swap-to-host preemption (optional capability) ---------------
    // Backends without host swap keep these defaults: every preemption
    // then takes the recompute-resume fallback, the pre-swap behavior.

    /// Serialize a lane to host memory and release its blocks. `None`
    /// when unsupported, disabled, or over budget — the lane is left
    /// intact and the caller falls back to recompute-resume.
    fn swap_out(&mut self, _slot: usize) -> Option<SwapHandle> {
        None
    }
    /// Restore a swapped lane; see [`SwapIn`] for the outcome ladder.
    fn swap_in(&mut self, _handle: SwapHandle) -> SwapIn {
        SwapIn::Gone
    }
    /// Whether the handle still holds a restorable entry (false once it
    /// was dropped under budget pressure or consumed).
    fn swap_contains(&self, _handle: SwapHandle) -> bool {
        false
    }
    /// Admission-gate check: could `swap_in` succeed right now?
    fn can_swap_in(&self, _handle: SwapHandle, _max_new_remaining: usize) -> bool {
        false
    }
    /// Discard a swapped entry whose request will never resume.
    fn swap_drop(&mut self, _handle: SwapHandle) -> bool {
        false
    }
    /// Swap-arena gauges/counters snapshot.
    fn swap_stats(&self) -> SwapStats {
        SwapStats::default()
    }
}

// ---------------------------------------------------------------------------
// PagedArena

/// Dense staging tensors in artifact layout, maintained only under the
/// [`PagingConfig::dense_staging`] fallback.
#[derive(Debug)]
struct StageBuf {
    k: HostTensor,
    v: HostTensor,
}

/// Paged decode KV store: per-(lane, layer) block tables over a shared
/// ref-counted pool. Decode consumes [`DecodeView`] (block tables + slab
/// borrow); the dense staging copy exists only under the
/// `dense_staging` fallback.
#[derive(Debug)]
pub struct PagedArena {
    l: usize,
    b: usize,
    c: usize,
    kv_heads: usize,
    head_dim: usize,
    block_tokens: usize,
    alloc: BlockAllocator,
    prefix: PrefixCache,
    /// Host-side parking lot for preempted lanes (swap-to-host resume).
    swap: SwapArena,
    /// Encode swapped payloads as f16 (`PagingConfig::swap_half`),
    /// for tenants without an explicit precision tier.
    swap_half: bool,
    /// Resident slab codec (`PagingConfig::precision`); also the swap
    /// codec for untiered tenants when `swap_half` is off.
    codec: KvCodec,
    /// Per-tenant precision tiers ([`TenantQuota::precision`]); consulted
    /// by [`PagedArena::swap_out`] instead of the global flag.
    tier: BTreeMap<TenantId, KvCodec>,
    /// KV-head shard layout + per-shard slab mutation stamps.
    shard_slabs: ShardedSlabs,
    /// `tables[slot][layer]` → physical blocks, in logical order.
    tables: Vec<Vec<Vec<BlockId>>>,
    /// `lens[slot][layer]` → valid tokens.
    lens: Vec<Vec<usize>>,
    /// `prefill_rows[slot][layer]` → rows the lane was admitted (or
    /// swap-restored) with: the FastKV-selected prefill KV. Decode
    /// budgets protect rows below this boundary unconditionally — only
    /// rows at or above it are generated-region eviction candidates.
    prefill_rows: Vec<Vec<usize>>,
    used: Vec<bool>,
    /// Tenant each lane is serving (meaningful while `used[slot]`; block
    /// takes for the lane are charged against this tenant's quota).
    tenants: Vec<TenantId>,
    stage_buf: Option<StageBuf>,
    /// Process-unique store id (upper half of the view version, so a
    /// device-side pinned-slab cache can never confuse two stores).
    id: u64,
    /// Mutation counter (lower half of the view version).
    mutations: u32,
    alloc_failures: u64,
}

fn ceil_div(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

fn next_store_id() -> u64 {
    use std::sync::atomic::{AtomicU64, Ordering};
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

impl PagedArena {
    /// Arena for `b` decode lanes of capacity `c` over a shared block
    /// pool sized by `cfg` (worst case when `cfg.num_blocks` is `None`),
    /// with `cfg.tenant_quotas` installed on the allocator and the swap
    /// arena.
    pub fn new(meta: &ModelMeta, b: usize, c: usize, cfg: PagingConfig) -> Self {
        let l = meta.n_layers;
        let re = meta.n_kv_heads * meta.head_dim;
        let bt = cfg.block_tokens.max(1);
        // Config-time rejection: an S that cannot split the KV heads has
        // no valid slab layout; the message names the valid counts.
        let spec =
            ShardSpec::new(cfg.shards.max(1), meta.n_kv_heads, meta.head_dim)
                .unwrap_or_else(|e| panic!("invalid PagingConfig::shards: {e}"));
        let worst = l * b * ceil_div(c.max(1), bt);
        let num_blocks = cfg.num_blocks.unwrap_or(worst).max(1);
        let shape = vec![l, b, c, meta.n_kv_heads, meta.head_dim];
        let stage_buf = cfg.dense_staging.then(|| StageBuf {
            k: HostTensor::zeros(shape.clone()),
            v: HostTensor::zeros(shape),
        });
        let mut alloc =
            BlockAllocator::with_codec(num_blocks, bt, re, cfg.precision);
        let mut swap = SwapArena::new(cfg.swap_bytes);
        let mut tier = BTreeMap::new();
        for &(t, q) in &cfg.tenant_quotas {
            alloc.set_quota(t, q);
            if let Some(sb) = q.swap_bytes {
                swap.set_tenant_budget(t, sb);
            }
            if let Some(p) = q.precision {
                tier.insert(t, p);
            }
        }
        PagedArena {
            l,
            b,
            c,
            kv_heads: meta.n_kv_heads,
            head_dim: meta.head_dim,
            block_tokens: bt,
            alloc,
            prefix: PrefixCache::new(cfg.prefix_cache),
            swap,
            swap_half: cfg.swap_half,
            codec: cfg.precision,
            tier,
            shard_slabs: ShardedSlabs::new(spec),
            tables: vec![vec![Vec::new(); l]; b],
            lens: vec![vec![0; l]; b],
            prefill_rows: vec![vec![0; l]; b],
            used: vec![false; b],
            tenants: vec![TenantId::DEFAULT; b],
            stage_buf,
            id: next_store_id(),
            mutations: 0,
            alloc_failures: 0,
        }
    }

    /// Install (or replace) a tenant's quota after construction (tests,
    /// runtime re-configuration). Blocks already charged are unaffected.
    pub fn set_tenant_quota(&mut self, tenant: TenantId, quota: TenantQuota) {
        self.alloc.set_quota(tenant, quota);
        if let Some(sb) = quota.swap_bytes {
            self.swap.set_tenant_budget(tenant, sb);
        }
        match quota.precision {
            Some(p) => {
                self.tier.insert(tenant, p);
            }
            None => {
                self.tier.remove(&tenant);
            }
        }
    }

    /// The [`KvCodec`] `tenant`'s preempted lanes are parked under: its
    /// [`TenantQuota::precision`] tier when set, otherwise the pool
    /// default (`swap_half` → f16, else the slab codec).
    fn swap_codec_for(&self, tenant: TenantId) -> KvCodec {
        self.tier.get(&tenant).copied().unwrap_or(if self.swap_half {
            KvCodec::F16
        } else {
            self.codec
        })
    }

    /// Used lanes grouped by effective precision tier (all three tiers
    /// reported, zero included, so the gauges never disappear).
    pub fn lanes_by_tier(&self) -> Vec<(KvCodec, usize)> {
        let mut counts = [0usize; KvCodec::ALL.len()];
        for slot in 0..self.b {
            if !self.used[slot] {
                continue;
            }
            let codec = self.swap_codec_for(self.tenants[slot]);
            let i = KvCodec::ALL
                .iter()
                .position(|c| *c == codec)
                .expect("codec in ALL");
            counts[i] += 1;
        }
        KvCodec::ALL.iter().copied().zip(counts).collect()
    }

    /// Tenant the lane is charged to ([`TenantId::DEFAULT`] for unused
    /// lanes).
    pub fn tenant_of(&self, slot: usize) -> TenantId {
        if slot < self.b && self.used[slot] {
            self.tenants[slot]
        } else {
            TenantId::DEFAULT
        }
    }

    /// Whether `tenant` is bursting past its reserved floor (see
    /// [`allocator::BlockAllocator::over_quota`]).
    pub fn tenant_over_quota(&self, tenant: TenantId) -> bool {
        self.alloc.over_quota(tenant)
    }

    /// Whether `tenant` sits at its burst ceiling (see
    /// [`allocator::BlockAllocator::at_ceiling`]).
    pub fn tenant_at_ceiling(&self, tenant: TenantId) -> bool {
        self.alloc.at_ceiling(tenant)
    }

    /// Can preempting a lane of `victim` relieve `pressured`'s block
    /// shortage?
    ///
    ///  * same tenant — always: its own charges drop, which helps
    ///    against ceiling and floor denials alike;
    ///  * `pressured` at its burst ceiling — no cross-tenant free can
    ///    ever help;
    ///  * otherwise a cross-tenant free helps only if `victim` is over
    ///    its reserved floor: a victim *inside* its floor hands every
    ///    freed block straight back to that floor's protected headroom,
    ///    leaving `available_to(pressured)` unchanged (the floor
    ///    arithmetic in [`allocator::BlockAllocator::available_to`]);
    ///  * no quotas configured — everyone helps (pre-tenancy behavior).
    pub fn preempt_helps(&self, victim: TenantId, pressured: TenantId) -> bool {
        if victim == pressured {
            return true;
        }
        if self.alloc.at_ceiling(pressured) {
            return false;
        }
        !self.alloc.quotas_configured() || self.alloc.over_quota(victim)
    }

    /// Per-tenant accounting rows: block charges + quota bounds from the
    /// allocator, swap bytes from the swap arena. `Σ held_blocks` always
    /// equals [`PoolStats::blocks_in_use`].
    pub fn tenant_stats(&self) -> Vec<TenantStats> {
        self.alloc
            .tenants()
            .into_iter()
            .map(|t| {
                let q = self.alloc.quota(t);
                TenantStats {
                    tenant: t,
                    held_blocks: self.alloc.held(t),
                    reserved_blocks: q.reserved_blocks,
                    ceiling_blocks: q.ceiling_blocks,
                    swap_bytes_used: self.swap.tenant_used(t),
                    swap_bytes_budget: self.swap.tenant_cap(t),
                }
            })
            .collect()
    }

    /// Slab/table mutation stamp consumed by [`DecodeView::version`]:
    /// store id in the upper 32 bits, mutation count in the lower.
    pub fn version(&self) -> u64 {
        ((self.id & 0xffff_ffff) << 32) | self.mutations as u64
    }

    fn touch(&mut self) {
        self.mutations = self.mutations.wrapping_add(1);
        // Whole-row mutations dirty every KV-head shard's plane.
        self.shard_slabs.touch_all();
    }

    /// A head-local mutation: the global stamp moves (whole-slab pinning
    /// must re-upload) but only `shard`'s plane stamp does, so a
    /// per-shard pinned cache re-uploads 1/S of the slab.
    fn touch_shard(&mut self, shard: usize) {
        self.mutations = self.mutations.wrapping_add(1);
        self.shard_slabs.touch_one(shard);
    }

    /// The KV-head shard layout this store was built with.
    pub fn shard_spec(&self) -> ShardSpec {
        self.shard_slabs.spec()
    }

    /// Per-shard slab bytes (K + V planes), indexed by shard — the
    /// `shard_{s}_slab_bytes` gauges. Every shard is the same size:
    /// `num_blocks * block_tokens * bytes_per_row(KV/S * hd) * 2`,
    /// codec-aware ([`KvCodec::bytes_per_row`]); under int8 the per-row
    /// scale planes are counted once per shard, since each shard's
    /// executor receives the shared scale tensors alongside its plane.
    pub fn shard_slab_bytes(&self) -> Vec<usize> {
        let spec = self.shard_slabs.spec();
        let per = self.alloc.blocks_total()
            * self.block_tokens
            * self.codec.bytes_per_row(spec.shard_row_elems())
            * 2;
        vec![per; spec.shards]
    }

    /// Overwrite one KV-head shard's slice of a logical token row
    /// (`k_sub`/`v_sub`: `KV/S * hd` elements). This is the head-local
    /// mutation path: only `shard`'s plane stamp moves, so a sharded
    /// decode step re-uploads exactly one shard's slab. On the current
    /// single-device runtime it exists for per-shard refresh flows (and
    /// is what the upload-amplification bench and the locality tests
    /// drive); on real multi-device bindings it is the host mirror of a
    /// device-local write. Returns false (and touches nothing) when the
    /// lane, layer, or row does not exist.
    pub fn mutate_shard_row(
        &mut self,
        slot: usize,
        layer: usize,
        row: usize,
        shard: usize,
        k_sub: &[f32],
        v_sub: &[f32],
    ) -> bool {
        let spec = self.shard_slabs.spec();
        if slot >= self.b
            || !self.used[slot]
            || layer >= self.l
            || row >= self.lens[slot][layer]
            || shard >= spec.shards
        {
            return false;
        }
        let bt = self.block_tokens;
        let bid = self.tables[slot][layer][row / bt];
        // The row's content diverges from whatever prefix hash the block
        // was sealed under: unregister before mutating (same discipline
        // as append's uniquely-owned-tail unseal). Shared blocks are NOT
        // copy-on-write here — head-local refresh is a whole-content
        // decision; refuse instead of silently mutating a neighbour.
        if self.alloc.meta(bid).ref_count > 1 {
            return false;
        }
        if self.alloc.meta(bid).hash.is_some() {
            if let Some(h) = self.alloc.unseal(bid) {
                self.prefix.remove(h);
            }
        }
        self.alloc.store_mut().write_row_range(
            bid,
            row % bt,
            spec.row_range(shard),
            k_sub,
            v_sub,
        );
        // Keep the dense-staging fallback coherent (it mirrors full rows).
        // The mirrored bits are read BACK from the store, not copied from
        // the input: under a lossy slab codec the stored row is the
        // quantized one, and the oracle must see exactly what decode will.
        let range = spec.row_range(shard);
        let base = self.stage_base(layer, slot, row) + range.start;
        if let Some(buf) = self.stage_buf.as_mut() {
            let store = self.alloc.store();
            let r = row % bt;
            buf.k.data[base..base + k_sub.len()]
                .copy_from_slice(&store.k_row(bid, r)[range.clone()]);
            buf.v.data[base..base + v_sub.len()]
                .copy_from_slice(&store.v_row(bid, r)[range]);
        }
        if self.codec.is_lossless() {
            self.touch_shard(shard);
        } else {
            // A lossy patch can rescale the whole stored row (when the
            // new sub-row exceeds the row's current int8 scale), moving
            // bits that belong to *other* shards' planes — every shard's
            // stamp must move, not just this one's.
            self.touch();
        }
        true
    }

    /// Physical blocks currently referenced by a lane's tables.
    pub fn held_blocks(&self, slot: usize) -> usize {
        if slot >= self.b || !self.used[slot] {
            return 0;
        }
        self.tables[slot].iter().map(|t| t.len()).sum()
    }

    /// Per-layer prefill boundary for a lane: rows below it are the
    /// admitted (FastKV-selected, or swap-restored) KV that decode
    /// budgets never touch. Rows at or above it were appended by decode
    /// and are fair game for the two budget stages.
    pub fn prefill_boundary(&self, slot: usize) -> Vec<usize> {
        self.prefill_rows[slot].clone()
    }

    /// Table indices in `slot`/`l` a decode budget may drop: full
    /// non-tail blocks whose rows all sit in the generated region past
    /// the sink prefix (`>= max(prefill boundary, sinks)`) and entirely
    /// before the sliding window. Returned in table order.
    fn budget_candidates(
        &self,
        slot: usize,
        l: usize,
        budget: &DecodeBudget,
    ) -> Vec<usize> {
        let bt = self.block_tokens;
        let len = self.lens[slot][l];
        let prot = self.prefill_rows[slot][l].max(budget.sinks);
        let keep_from = len.saturating_sub(budget.window);
        let table_len = self.tables[slot][l].len();
        (0..table_len.saturating_sub(1))
            .filter(|&k| k * bt >= prot && (k + 1) * bt <= keep_from)
            .collect()
    }

    /// Order candidate table indices coldest-first: lowest per-row
    /// attention-mass score ([`block::BlockMeta::row_score`]), ties
    /// broken toward the oldest write stamp, then the lowest index
    /// (deterministic for the differential oracles).
    fn sort_coldest(&self, slot: usize, l: usize, cands: &mut [usize]) {
        let table = &self.tables[slot][l];
        cands.sort_by(|&a, &b| {
            let ma = self.alloc.meta(table[a]);
            let mb = self.alloc.meta(table[b]);
            ma.row_score()
                .partial_cmp(&mb.row_score())
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(ma.last_write.cmp(&mb.last_write))
                .then(a.cmp(&b))
        });
    }

    /// Coarse budget stage: permanently release the lane's coldest full
    /// generated blocks until each layer's resident generated rows are
    /// within `budget.coarse_rows` (or no candidate remains — sink,
    /// window, and prefill protection win over the cap). Dropping a
    /// block from mid-table is pure bookkeeping: positions were
    /// RoPE-baked at write time, so the survivors simply close ranks in
    /// logical order, exactly like [`PagedArena::compact`] — but with
    /// zero data movement, since whole blocks survive in place. Returns
    /// blocks released back to the pool.
    pub fn enforce_decode_budget(
        &mut self,
        slot: usize,
        budget: &DecodeBudget,
    ) -> usize {
        if slot >= self.b || !self.used[slot] {
            return 0;
        }
        let bt = self.block_tokens;
        let re = self.row_elems();
        let mut released = 0usize;
        for l in 0..self.l {
            let old_len = self.lens[slot][l];
            let gen = old_len.saturating_sub(self.prefill_rows[slot][l]);
            if gen <= budget.coarse_rows {
                continue;
            }
            let mut cands = self.budget_candidates(slot, l, budget);
            self.sort_coldest(slot, l, &mut cands);
            cands.truncate(ceil_div(gen - budget.coarse_rows, bt));
            if cands.is_empty() {
                continue;
            }
            // Remove in descending table order so indices stay valid.
            // Candidates never overlap the window (judged against the
            // pre-release `len`), and releases only shift rows *after*
            // a removed block, so sinks, prefill rows, and the trailing
            // window rows all keep their content.
            cands.sort_unstable_by(|a, b| b.cmp(a));
            for k in cands {
                let bid = self.tables[slot][l].remove(k);
                debug_assert_eq!(
                    self.alloc.meta(bid).filled as usize,
                    bt,
                    "only full blocks are eviction candidates"
                );
                self.alloc.decref(bid);
                self.lens[slot][l] -= bt;
                released += 1;
            }
            // Dense-staging fallback: survivors shifted down — re-mirror
            // the layer and zero the vacated tail (compact's discipline).
            let new_len = self.lens[slot][l];
            let base = self.stage_base(l, slot, 0);
            if let Some(buf) = self.stage_buf.as_mut() {
                let store = self.alloc.store();
                let mut row = 0usize;
                for &bid in &self.tables[slot][l] {
                    let filled = self.alloc.meta(bid).filled as usize;
                    let b0 = base + row * re;
                    buf.k.data[b0..b0 + filled * re]
                        .copy_from_slice(&store.k_rows(bid, filled));
                    buf.v.data[b0..b0 + filled * re]
                        .copy_from_slice(&store.v_rows(bid, filled));
                    row += filled;
                }
                debug_assert_eq!(row, new_len, "surviving rows vs len");
                let tail0 = base + new_len * re;
                let tail1 = base + old_len * re;
                buf.k.data[tail0..tail1].fill(0.0);
                buf.v.data[tail0..tail1].fill(0.0);
            }
        }
        if released > 0 {
            self.touch();
        }
        released
    }

    /// Build the block-table-native decode description for this step:
    /// tables + lens are copied (O(referenced blocks)), the slab is
    /// borrowed in place.
    pub fn view(&self) -> DecodeView<'_> {
        self.view_budgeted(None)
    }

    /// [`PagedArena::view`] with the fine budget stage applied: lanes
    /// whose resident generated rows exceed `budget.fine_rows` hand
    /// decode a *pruned* table — the coldest candidate blocks dropped,
    /// survivors in logical order — so the step attends to at most
    /// `fine_rows` generated rows (plus all prefill, sink, and window
    /// rows) per layer. The slab, version stamps, and artifact ABI are
    /// untouched: a pruned table is just a shorter table. `None` is
    /// bit-identical to the unbudgeted view.
    pub fn view_budgeted(&self, budget: Option<&DecodeBudget>) -> DecodeView<'_> {
        let bt = self.block_tokens;
        // Fine stage: per (lane, layer), sorted table indices this view
        // drops (empty = attend to everything resident).
        let mut drops: Vec<Vec<usize>> = vec![Vec::new(); self.b * self.l];
        let mut pruned_blocks = 0usize;
        if let Some(bud) = budget {
            for slot in 0..self.b {
                if !self.used[slot] {
                    continue;
                }
                for l in 0..self.l {
                    let len = self.lens[slot][l];
                    let gen = len.saturating_sub(self.prefill_rows[slot][l]);
                    if gen <= bud.fine_rows {
                        continue;
                    }
                    let mut cands = self.budget_candidates(slot, l, bud);
                    self.sort_coldest(slot, l, &mut cands);
                    cands.truncate(ceil_div(gen - bud.fine_rows, bt));
                    cands.sort_unstable();
                    pruned_blocks += cands.len();
                    drops[slot * self.l + l] = cands;
                }
            }
        }
        let mut max_blocks = 1usize;
        for slot in 0..self.b {
            for l in 0..self.l {
                let kept = self.tables[slot][l].len()
                    - drops[slot * self.l + l].len();
                max_blocks = max_blocks.max(kept);
            }
        }
        let mut tables = vec![-1i32; self.l * self.b * max_blocks];
        let mut lens = vec![0i32; self.l * self.b];
        for slot in 0..self.b {
            for l in 0..self.l {
                let drop = &drops[slot * self.l + l];
                let base = (l * self.b + slot) * max_blocks;
                let mut i = 0usize;
                let mut di = 0usize;
                for (k, bid) in self.tables[slot][l].iter().enumerate() {
                    if di < drop.len() && drop[di] == k {
                        di += 1;
                        continue;
                    }
                    tables[base + i] = bid.0 as i32;
                    i += 1;
                }
                // Dropped blocks are always full, so the pruned length
                // is exact (and non-tail survivors stay full — `k_row`'s
                // `table[row/bt]` arithmetic holds on pruned tables).
                lens[l * self.b + slot] =
                    (self.lens[slot][l] - drop.len() * bt) as i32;
            }
        }
        let spec = self.shard_slabs.spec();
        let shard_versions = (0..spec.shards)
            .map(|s| {
                ((self.id & 0xffff_ffff) << 32)
                    | self.shard_slabs.version(s) as u64
            })
            .collect();
        DecodeView {
            version: self.version(),
            l: self.l,
            b: self.b,
            capacity: self.c,
            block_tokens: self.block_tokens,
            kv_heads: self.kv_heads,
            head_dim: self.head_dim,
            num_blocks: self.alloc.blocks_total(),
            max_blocks,
            tables,
            lens,
            shards: spec.shards,
            shard_versions,
            codec: self.alloc.store().codec(),
            pruned_blocks,
            store: self.alloc.store(),
        }
    }

    /// f32 elements per token row (`KV * hd`).
    pub fn row_elems(&self) -> usize {
        self.kv_heads * self.head_dim
    }

    /// Token rows per physical block.
    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    fn stage_base(&self, l: usize, slot: usize, row: usize) -> usize {
        ((l * self.b + slot) * self.c + row) * self.row_elems()
    }

    /// Blocks a sequence of `per_layer_tokens` per layer would need,
    /// assuming no sharing (conservative).
    pub fn blocks_for(&self, per_layer_tokens: usize) -> usize {
        self.l * ceil_div(per_layer_tokens, self.block_tokens)
    }

    fn find_free_lane(&self) -> Option<usize> {
        (0..self.b).find(|&s| !self.used[s])
    }

    /// Undo a partial admission: drop every reference acquired so far.
    /// Callers record the failure cause themselves
    /// ([`PagedArena::count_take_failure`]) — a quota denial and a pool
    /// shortfall must land in different stats.
    fn rollback(&mut self, acquired: Vec<BlockId>) {
        for id in acquired {
            self.alloc.decref(id);
        }
    }

    /// Record a failed block take by cause, keeping
    /// [`PoolStats::alloc_failures`] (pool exhaustion) and
    /// [`PoolStats::quota_denials`] (tenant quota, counted inside
    /// [`allocator::BlockAllocator::alloc`]) disjoint. Call *before* any
    /// rollback decrefs put blocks back.
    fn count_take_failure(&mut self) {
        if self.alloc.allocatable() == 0 {
            self.alloc_failures += 1;
        }
    }

    /// Chunk `len` rows of K/V (row-major, `row_elems`-wide) into freshly
    /// allocated, unsealed blocks charged to `tenant`. The caller must
    /// have pre-checked pool *and quota* feasibility — every `alloc` here
    /// is expected to succeed.
    fn fill_blocks(
        &mut self,
        tenant: TenantId,
        k_rows: &[f32],
        v_rows: &[f32],
        len: usize,
    ) -> Vec<BlockId> {
        let bt = self.block_tokens;
        let re = self.row_elems();
        let mut table = Vec::with_capacity(ceil_div(len, bt));
        let mut row0 = 0usize;
        while row0 < len {
            let rows = (len - row0).min(bt);
            let out = self.alloc.alloc(tenant).expect("pre-checked block alloc");
            if let Some(old_hash) = out.evicted_hash {
                self.prefix.remove(old_hash);
            }
            for r in 0..rows {
                let s = (row0 + r) * re;
                self.alloc.store_mut().write_row(
                    out.id,
                    r,
                    &k_rows[s..s + re],
                    &v_rows[s..s + re],
                );
            }
            self.alloc.set_filled(out.id, rows as u32);
            table.push(out.id);
            row0 += rows;
        }
        table
    }

    /// Load a compressed request cache into a free lane for the default
    /// tenant (single-tenant entry points: the engine, pre-tenancy tests
    /// and tools). See [`PagedArena::admit_for`].
    pub fn admit(&mut self, cache: &RequestCache) -> Option<usize> {
        self.admit_for(cache, TenantId::DEFAULT)
    }

    /// Load a compressed request cache into a free lane for `tenant`,
    /// sharing full blocks through the prefix cache where the content
    /// chain matches. Every block the lane takes (fresh allocations and
    /// revivals of cached blocks, but not shares of live blocks — the
    /// first-toucher rule) is charged against the tenant's quota; a
    /// quota denial mid-load rolls the admission back and returns `None`
    /// exactly like pool exhaustion, so the serving loop's defer path
    /// handles both.
    ///
    /// NOTE: [`PagedArena::swap_in`] mirrors this fill-and-commit
    /// structure with preserved hashes instead of computed chain hashes —
    /// a fix to the chunk/seal/staging logic here almost certainly
    /// applies there too (the swap differential oracle in
    /// `rust/tests/paging.rs` pins the two together).
    pub fn admit_for(
        &mut self,
        cache: &RequestCache,
        tenant: TenantId,
    ) -> Option<usize> {
        let slot = self.find_free_lane()?;
        assert_eq!(cache.k.len(), self.l, "cache layer count");
        let re = self.row_elems();
        assert_eq!(cache.row_elems(), re, "cache row width");
        for l in 0..self.l {
            if cache.lens[l] > self.c {
                return None;
            }
        }

        let bt = self.block_tokens;
        let mut new_tables: Vec<Vec<BlockId>> = Vec::with_capacity(self.l);
        let mut acquired: Vec<BlockId> = Vec::new();
        for l in 0..self.l {
            let len = cache.lens[l];
            let mut table = Vec::with_capacity(ceil_div(len, bt));
            let mut chain = prefix::layer_seed(l);
            let mut row0 = 0usize;
            while row0 < len {
                let rows = (len - row0).min(bt);
                let full = rows == bt;
                let k_rows = &cache.k[l][row0 * re..(row0 + rows) * re];
                let v_rows = &cache.v[l][row0 * re..(row0 + rows) * re];
                let mut reused = None;
                let mut hash = 0u64;
                if full && self.prefix.enabled {
                    hash = prefix::chain_hash(chain, l, k_rows, v_rows);
                    if let Some(bid) = self.prefix.lookup(hash) {
                        match self.alloc.revive(bid, tenant) {
                            Revive::Revived => reused = Some(bid),
                            // stale map entry; treat as a miss
                            Revive::Stale => self.prefix.remove(hash),
                            // quota-blocked: fall through to alloc, which
                            // will be refused too and roll the load back
                            Revive::OverQuota => {}
                        }
                    }
                }
                let bid = match reused {
                    Some(bid) => bid,
                    None => match self.alloc.alloc(tenant) {
                        Some(out) => {
                            if let Some(old) = out.evicted_hash {
                                self.prefix.remove(old);
                            }
                            for r in 0..rows {
                                self.alloc.store_mut().write_row(
                                    out.id,
                                    r,
                                    &k_rows[r * re..(r + 1) * re],
                                    &v_rows[r * re..(r + 1) * re],
                                );
                            }
                            self.alloc.set_filled(out.id, rows as u32);
                            if full && self.prefix.enabled {
                                self.alloc.seal(out.id, hash);
                                self.prefix.insert(hash, out.id);
                            }
                            out.id
                        }
                        None => {
                            self.count_take_failure();
                            self.rollback(acquired);
                            return None;
                        }
                    },
                };
                table.push(bid);
                acquired.push(bid);
                if full {
                    chain = hash;
                }
                row0 += rows;
            }
            new_tables.push(table);
        }

        // Commit: bookkeeping, plus the dense staging copy under the
        // fallback (read rows back from the store so shared and fresh
        // blocks take the same path).
        self.used[slot] = true;
        self.tenants[slot] = tenant;
        for (l, table) in new_tables.iter().enumerate() {
            let mut row = 0usize;
            {
                let alloc = &self.alloc;
                let store = alloc.store();
                let stage = self.stage_buf.as_mut();
                if let Some(buf) = stage {
                    for &bid in table {
                        let filled = alloc.meta(bid).filled as usize;
                        for r in 0..filled {
                            let base =
                                ((l * self.b + slot) * self.c + row) * re;
                            buf.k.data[base..base + re]
                                .copy_from_slice(&store.k_row(bid, r));
                            buf.v.data[base..base + re]
                                .copy_from_slice(&store.v_row(bid, r));
                            row += 1;
                        }
                    }
                } else {
                    for &bid in table {
                        row += alloc.meta(bid).filled as usize;
                    }
                }
            }
            debug_assert_eq!(row, cache.lens[l], "block rows vs cache len");
            // lane was zeroed on release; rows above `row` are already 0
            self.lens[slot][l] = cache.lens[l];
            // Everything admitted is FastKV-selected prefill KV: decode
            // budgets must never evict below this boundary.
            self.prefill_rows[slot][l] = cache.lens[l];
        }
        self.tables[slot] = new_tables;
        self.touch();
        Some(slot)
    }

    /// Fork a lane (shared-prefix clone for parallel decoding): every
    /// block gains a reference; appends later copy-on-write the shared
    /// tail. Fails only when no lane is free.
    pub fn fork(&mut self, slot: usize) -> Option<usize> {
        if !self.used[slot] {
            return None;
        }
        let dst = self.find_free_lane()?;
        let tables = self.tables[slot].clone();
        for layer_table in &tables {
            for &bid in layer_table {
                self.alloc.incref(bid);
            }
        }
        self.tables[dst] = tables;
        self.lens[dst] = self.lens[slot].clone();
        self.prefill_rows[dst] = self.prefill_rows[slot].clone();
        self.used[dst] = true;
        // The clone serves the same tenant; its future appends (and COW
        // copies) are charged there.
        self.tenants[dst] = self.tenants[slot];
        let re = self.row_elems();
        for l in 0..self.l {
            let src = self.stage_base(l, slot, 0);
            let d = self.stage_base(l, dst, 0);
            let n = self.c * re;
            if let Some(buf) = self.stage_buf.as_mut() {
                buf.k.data.copy_within(src..src + n, d);
                buf.v.data.copy_within(src..src + n, d);
            }
        }
        self.touch();
        Some(dst)
    }

    /// Release a lane and its storage. Returns false if it was not in
    /// use (double-release guard).
    pub fn release(&mut self, slot: usize) -> bool {
        if slot >= self.b || !self.used[slot] {
            return false;
        }
        let tables = std::mem::take(&mut self.tables[slot]);
        for layer_table in tables {
            for bid in layer_table {
                self.alloc.decref(bid);
            }
        }
        self.tables[slot] = vec![Vec::new(); self.l];
        self.lens[slot] = vec![0; self.l];
        self.prefill_rows[slot] = vec![0; self.l];
        self.used[slot] = false;
        self.tenants[slot] = TenantId::DEFAULT;
        let re = self.row_elems();
        for l in 0..self.l {
            let base = self.stage_base(l, slot, 0);
            let n = self.c * re;
            if let Some(buf) = self.stage_buf.as_mut() {
                buf.k.data[base..base + n].fill(0.0);
                buf.v.data[base..base + n].fill(0.0);
            }
        }
        self.touch();
        true
    }

    /// Serialize a lane to the host swap arena and release its blocks
    /// back to the pool. The entry preserves per-layer lens, every row in
    /// logical order, and the prefix-hash chain (per-block seals), so
    /// [`PagedArena::swap_in`] restores the exact FastKV-selected cache —
    /// no policy re-run, no re-prefill, no re-hashing.
    ///
    /// Returns `None` — with the lane left fully intact — when swapping
    /// is disabled or the byte budget cannot take the lane even after
    /// dropping older entries; the caller then falls back to
    /// recompute-resume (releasing the lane itself).
    pub fn swap_out(&mut self, slot: usize) -> Option<SwapHandle> {
        if slot >= self.b || !self.used[slot] || !self.swap.enabled() {
            return None;
        }
        let re = self.row_elems();
        // The payload size is fully determined by the lane's lens and the
        // codec — ask the arena *before* serializing, so a lane the
        // budget can never take (per-tenant cap, possibly 0) costs
        // nothing to refuse instead of an O(lane-bytes) copy per
        // preemption. The codec is the *tenant's* precision tier
        // (falling back to the pool default), so a premium-f32 tenant is
        // priced — and refused — at f32 even in an `--swap-half` pool.
        let codec = self.swap_codec_for(self.tenants[slot]);
        let predicted: usize =
            self.lens[slot].iter().sum::<usize>() * 2 * codec.bytes_per_row(re);
        if self.swap.would_refuse(predicted, self.tenants[slot]) {
            return None;
        }
        let mut lens = Vec::with_capacity(self.l);
        let mut ks: Vec<KvLane> = Vec::with_capacity(self.l);
        let mut vs: Vec<KvLane> = Vec::with_capacity(self.l);
        let mut hashes: Vec<Vec<Option<u64>>> = Vec::with_capacity(self.l);
        for l in 0..self.l {
            let len = self.lens[slot][l];
            let mut k = Vec::with_capacity(len * re);
            let mut v = Vec::with_capacity(len * re);
            let mut hs = Vec::with_capacity(self.tables[slot][l].len());
            let mut rows = 0usize;
            for &bid in &self.tables[slot][l] {
                let meta = self.alloc.meta(bid);
                let filled = meta.filled as usize;
                hs.push(meta.hash);
                k.extend_from_slice(&self.alloc.store().k_rows(bid, filled));
                v.extend_from_slice(&self.alloc.store().v_rows(bid, filled));
                rows += filled;
            }
            debug_assert_eq!(rows, len, "block rows vs lane len");
            lens.push(len);
            ks.push(KvLane::encode(k, codec, re));
            vs.push(KvLane::encode(v, codec, re));
            hashes.push(hs);
        }
        let bytes = ks
            .iter()
            .chain(&vs)
            .map(|lane| lane.payload_bytes())
            .sum::<usize>();
        debug_assert_eq!(bytes, predicted, "codec-size prediction");
        let handle = self.swap.insert(SwapEntry {
            lens,
            k: ks,
            v: vs,
            hashes,
            bytes,
            // The parked bytes stay charged to the lane's tenant, so one
            // tenant's preemption churn can only displace its own
            // entries (per-tenant swap budgets).
            tenant: self.tenants[slot],
        })?;
        self.release(slot);
        Some(handle)
    }

    /// Restore a swapped lane into freshly allocated blocks, re-sharing
    /// sealed full blocks through the prefix cache via their preserved
    /// hashes. A successful restore consumes the handle; [`SwapIn::Busy`]
    /// leaves it valid (lane or pool momentarily unavailable) and
    /// [`SwapIn::Gone`] means the entry was dropped under budget pressure
    /// — recompute-resume is the only way back.
    ///
    /// NOTE: deliberately mirrors [`PagedArena::admit`]'s fill-and-commit
    /// structure (hash source is the only difference: preserved seals vs
    /// computed chain); keep the two in lockstep when changing either —
    /// the swap differential oracle pins them together.
    pub fn swap_in(&mut self, handle: SwapHandle) -> SwapIn {
        if !self.swap.contains(handle) {
            return SwapIn::Gone;
        }
        let slot = match self.find_free_lane() {
            Some(s) => s,
            None => return SwapIn::Busy,
        };
        let entry = self.swap.take(handle).expect("checked contains");
        debug_assert_eq!(entry.lens.len(), self.l, "swap entry layer count");
        // Restored blocks are charged to the tenant the lane was
        // preempted from; a quota denial mid-restore reports Busy (entry
        // kept) exactly like a pool shortfall.
        let tenant = entry.tenant;
        let bt = self.block_tokens;
        let re = self.row_elems();
        // A lossy entry decodes to *approximately* the serialized rows:
        // reviving a still-cached exact block through its preserved hash
        // is fine (better, even), but a freshly-written decoded block
        // must NOT be sealed under the original hash — the prefix cache
        // would alias lossy content to the exact chain and hand it to
        // future admissions. A lossy *slab* codec triggers the same
        // guard even for f32 entries: writing exact rows into a
        // quantizing store changes them, so preserved hashes must never
        // be re-sealed over freshly-written blocks there either.
        let lossy = entry.is_lossy() || !self.codec.is_lossless();

        let mut new_tables: Vec<Vec<BlockId>> = Vec::with_capacity(self.l);
        let mut acquired: Vec<BlockId> = Vec::new();
        let mut shortfall = false;
        'layers: for l in 0..self.l {
            let len = entry.lens[l];
            let k_lane = entry.k[l].as_f32();
            let v_lane = entry.v[l].as_f32();
            let mut table = Vec::with_capacity(ceil_div(len, bt));
            let mut row0 = 0usize;
            let mut bi = 0usize;
            while row0 < len {
                let rows = (len - row0).min(bt);
                let hash = entry.hashes[l].get(bi).copied().flatten();
                let k_rows = &k_lane[row0 * re..(row0 + rows) * re];
                let v_rows = &v_lane[row0 * re..(row0 + rows) * re];
                let mut reused = None;
                if let Some(h) = hash {
                    if self.prefix.enabled {
                        if let Some(bid) = self.prefix.lookup(h) {
                            match self.alloc.revive(bid, tenant) {
                                Revive::Revived => reused = Some(bid),
                                Revive::Stale => self.prefix.remove(h),
                                Revive::OverQuota => {}
                            }
                        }
                    }
                }
                let bid = match reused {
                    Some(bid) => bid,
                    None => match self.alloc.alloc(tenant) {
                        Some(out) => {
                            if let Some(old) = out.evicted_hash {
                                self.prefix.remove(old);
                            }
                            for r in 0..rows {
                                self.alloc.store_mut().write_row(
                                    out.id,
                                    r,
                                    &k_rows[r * re..(r + 1) * re],
                                    &v_rows[r * re..(r + 1) * re],
                                );
                            }
                            self.alloc.set_filled(out.id, rows as u32);
                            if let Some(h) = hash {
                                if self.prefix.enabled && !lossy {
                                    self.alloc.seal(out.id, h);
                                    self.prefix.insert(h, out.id);
                                }
                            }
                            out.id
                        }
                        None => {
                            shortfall = true;
                            break 'layers;
                        }
                    },
                };
                table.push(bid);
                acquired.push(bid);
                row0 += rows;
                bi += 1;
            }
            new_tables.push(table);
        }
        if shortfall {
            self.count_take_failure();
            self.rollback(acquired);
            self.swap.put_back(handle, entry);
            return SwapIn::Busy;
        }

        // Commit (mirrors `admit`): bookkeeping plus the dense staging
        // copy under the fallback, reading rows back from the store so
        // shared and fresh blocks take the same path.
        self.used[slot] = true;
        self.tenants[slot] = tenant;
        for (l, table) in new_tables.iter().enumerate() {
            let mut row = 0usize;
            {
                let alloc = &self.alloc;
                let store = alloc.store();
                let stage = self.stage_buf.as_mut();
                if let Some(buf) = stage {
                    for &bid in table {
                        let filled = alloc.meta(bid).filled as usize;
                        for r in 0..filled {
                            let base =
                                ((l * self.b + slot) * self.c + row) * re;
                            buf.k.data[base..base + re]
                                .copy_from_slice(&store.k_row(bid, r));
                            buf.v.data[base..base + re]
                                .copy_from_slice(&store.v_row(bid, r));
                            row += 1;
                        }
                    }
                } else {
                    for &bid in table {
                        row += alloc.meta(bid).filled as usize;
                    }
                }
            }
            debug_assert_eq!(row, entry.lens[l], "restored rows vs entry len");
            self.lens[slot][l] = entry.lens[l];
            // Conservative ratchet: everything restored counts as
            // protected prefill KV (the swap entry does not distinguish
            // prefill from generated rows). A lane that cycles through
            // preemption therefore re-protects up to `coarse_rows` of
            // previously-generated KV per trip — safe (never evicts what
            // the policy selected), and bounded by the coarse cap between
            // preemptions.
            self.prefill_rows[slot][l] = entry.lens[l];
        }
        self.tables[slot] = new_tables;
        self.swap.note_swap_in();
        self.touch();
        SwapIn::Restored(slot)
    }

    /// Whether [`PagedArena::swap_in`] could restore this handle right
    /// now: a free lane plus pool coverage of its blocks (conservative,
    /// no sharing assumed) *within the owning tenant's quota*, with one
    /// growth block per layer reserved when the request will keep
    /// decoding — the same over-commit contract as [`KvStore::can_admit`].
    pub fn can_swap_in(&self, handle: SwapHandle, max_new_remaining: usize) -> bool {
        let Some(e) = self.swap.get(handle) else { return false };
        if self.free_lanes() == 0 || e.max_len() > self.c {
            return false;
        }
        let headroom = if max_new_remaining == 0 { 0 } else { self.l };
        self.alloc
            .can_take(e.tenant, e.total_blocks(self.block_tokens) + headroom)
    }

    /// Whether the handle still holds a restorable entry.
    pub fn swap_contains(&self, handle: SwapHandle) -> bool {
        self.swap.contains(handle)
    }

    /// Discard a swapped entry (its request finished or was rejected).
    pub fn swap_drop(&mut self, handle: SwapHandle) -> bool {
        self.swap.drop_entry(handle)
    }

    /// Swap-arena gauges/counters snapshot.
    pub fn swap_stats(&self) -> SwapStats {
        self.swap.stats()
    }

    /// Append one decode row per layer, allocating / copy-on-writing tail
    /// blocks as needed; fresh blocks are charged to the lane's tenant.
    /// All-or-nothing: a pool (or quota) shortfall is detected before any
    /// mutation and reported as [`AppendResult::PoolExhausted`].
    pub fn append(
        &mut self,
        slot: usize,
        k_new: &HostTensor,
        v_new: &HostTensor,
    ) -> AppendResult {
        if slot >= self.b || !self.used[slot] {
            debug_assert!(false, "append to unused slot {slot}");
            return AppendResult::CapacityExhausted;
        }
        let bt = self.block_tokens;
        for l in 0..self.l {
            if self.lens[slot][l] >= self.c {
                return AppendResult::CapacityExhausted;
            }
        }
        // Pre-pass: blocks this step must obtain from the pool.
        let mut needed = 0usize;
        for l in 0..self.l {
            let len = self.lens[slot][l];
            if len % bt == 0 {
                needed += 1; // fresh tail block
            } else {
                let cur = *self.tables[slot][l].last().expect("tail block");
                if self.alloc.meta(cur).ref_count > 1 {
                    needed += 1; // copy-on-write
                }
            }
        }
        let tenant = self.tenants[slot];
        if !self.alloc.can_take(tenant, needed) {
            // Exhaustion and quota denial stay disjoint in the stats; no
            // allocation runs here, so the denial is counted inline.
            if self.alloc.allocatable() < needed {
                self.alloc_failures += 1;
            } else {
                self.alloc.quota_denials += 1;
            }
            return AppendResult::PoolExhausted;
        }

        let re = self.row_elems();
        // Recency stamp for this step's rows: the mutation counter the
        // store will hold after the append's `touch()` (monotonic per
        // store, which is all the eviction tie-break needs).
        let stamp = self.mutations.wrapping_add(1) as u64;
        for l in 0..self.l {
            let len = self.lens[slot][l];
            let row_in_block = len % bt;
            let bid = if row_in_block == 0 {
                let out = self.alloc.alloc(tenant).expect("pre-checked alloc");
                if let Some(old) = out.evicted_hash {
                    self.prefix.remove(old);
                }
                self.tables[slot][l].push(out.id);
                out.id
            } else {
                let cur = *self.tables[slot][l].last().expect("tail block");
                let meta = self.alloc.meta(cur).clone();
                if meta.ref_count > 1 {
                    // Copy-on-write: private copy of the shared tail.
                    let out = self.alloc.alloc(tenant).expect("pre-checked alloc");
                    if let Some(old) = out.evicted_hash {
                        self.prefix.remove(old);
                    }
                    self.alloc
                        .store_mut()
                        .copy_rows(cur, out.id, meta.filled as usize);
                    self.alloc.set_filled(out.id, meta.filled);
                    self.alloc.decref(cur);
                    *self.tables[slot][l].last_mut().expect("tail") = out.id;
                    self.alloc.note_cow();
                    out.id
                } else {
                    if meta.hash.is_some() {
                        // Uniquely owned but registered: unregister before
                        // mutating so the prefix cache never aliases
                        // diverged content.
                        if let Some(h) = self.alloc.unseal(cur) {
                            self.prefix.remove(h);
                        }
                    }
                    cur
                }
            };
            let k_row = &k_new.row2(l, slot)[..re];
            let v_row = &v_new.row2(l, slot)[..re];
            self.alloc.store_mut().write_row(bid, row_in_block, k_row, v_row);
            self.alloc.set_filled(bid, (row_in_block + 1) as u32);
            // Decode-budget scoring: accumulate the row's mean |K| (a
            // cheap attention-mass proxy — high-magnitude keys draw the
            // most attention) plus a recency stamp on the block. Free for
            // unbudgeted stacks beyond this add; consumed by
            // `enforce_decode_budget` / the pruned view.
            let mass = k_row.iter().map(|x| x.abs()).sum::<f32>() / re as f32;
            self.alloc.note_row_write(bid, mass, stamp);
            let base = self.stage_base(l, slot, len);
            if let Some(buf) = self.stage_buf.as_mut() {
                // Mirror what the store *kept* (quantized under a lossy
                // codec), not the raw input — the dense oracle must match
                // block-table decode bit for bit. At f32 the read-back is
                // the input, so the legacy differentials are unaffected.
                let store = self.alloc.store();
                buf.k.data[base..base + re]
                    .copy_from_slice(&store.k_row(bid, row_in_block));
                buf.v.data[base..base + re]
                    .copy_from_slice(&store.v_row(bid, row_in_block));
            }
            self.lens[slot][l] = len + 1;
        }
        self.touch();
        AppendResult::Ok
    }

    /// Block-granular eviction: keep only `keep[l]` rows per layer,
    /// rebuilding only the layers that actually shrink so dropped tokens
    /// release pool blocks (identity keep-sets touch nothing). No-op
    /// (returns 0) if the pool temporarily cannot hold the rebuilt layers
    /// (possible when old blocks are shared).
    pub fn compact(&mut self, slot: usize, keep: &[Vec<usize>]) -> usize {
        if slot >= self.b || !self.used[slot] {
            return 0;
        }
        assert_eq!(keep.len(), self.l, "keep sets per layer");
        let bt = self.block_tokens;
        let re = self.row_elems();

        // Only layers that shrink are rebuilt: an identity keep-set (all
        // rows retained — ascending distinct indices below len imply
        // exactly that when the counts match) would otherwise burn scarce
        // blocks and privatize shared ones for zero release.
        let shrinking: Vec<usize> = (0..self.l)
            .filter(|&l| keep[l].len() < self.lens[slot][l])
            .collect();
        if shrinking.is_empty() {
            return 0;
        }

        // Feasibility: all shrinking layers are gathered and decref'd
        // BEFORE any allocation (see below), so the rebuild draws from
        // allocatable() + every exclusively-owned old block — evaluated
        // under the lane tenant's quota, with the releases' per-owner
        // uncharges simulated (a freed block may be owed to another
        // tenant's reserved floor rather than to this rebuild).
        let tenant = self.tenants[slot];
        let mut needed_new = 0usize;
        let mut released: Vec<BlockId> = Vec::new();
        for &l in &shrinking {
            needed_new += ceil_div(keep[l].len(), bt);
            released.extend_from_slice(&self.tables[slot][l]);
        }
        if !self.alloc.can_take_after_release(tenant, needed_new, &released) {
            return 0;
        }

        let in_use_before = self.alloc.blocks_in_use();
        // Phase 1: gather every shrinking layer's survivors, then release
        // every old block. Interleaving gather/alloc per layer would let
        // an early layer's allocations consume blocks a later layer's
        // decrefs were counted on (shared early layers free nothing), and
        // decref zeroes freed blocks — so all reads complete first.
        let mut gathered: Vec<(usize, usize, Vec<f32>, Vec<f32>)> =
            Vec::with_capacity(shrinking.len());
        for &l in &shrinking {
            let old_len = self.lens[slot][l];
            let keep_l = &keep[l];
            debug_assert!(
                keep_l.windows(2).all(|w| w[0] < w[1]),
                "keep indices must be ascending and distinct"
            );
            let mut tk = Vec::with_capacity(keep_l.len() * re);
            let mut tv = Vec::with_capacity(keep_l.len() * re);
            for &idx in keep_l {
                assert!(idx < old_len, "keep index {idx} >= len {old_len}");
                let bid = self.tables[slot][l][idx / bt];
                let r = idx % bt;
                tk.extend_from_slice(&self.alloc.store().k_row(bid, r));
                tv.extend_from_slice(&self.alloc.store().v_row(bid, r));
            }
            gathered.push((l, old_len, tk, tv));
        }
        for &l in &shrinking {
            let old = std::mem::take(&mut self.tables[slot][l]);
            for bid in old {
                self.alloc.decref(bid);
            }
        }

        // Phase 2: rebuild (unsealed: content has diverged from any
        // registered prefix). The feasibility check above guarantees
        // every alloc() succeeds.
        for (l, old_len, tk, tv) in gathered {
            let new_len = keep[l].len();
            self.tables[slot][l] = self.fill_blocks(tenant, &tk, &tv, new_len);
            self.lens[slot][l] = new_len;
            // The prefill boundary maps through the keep-set: kept rows
            // below the old boundary land (keep is ascending) as a prefix
            // of the rebuilt layer, so the new boundary is their count.
            let boundary = self.prefill_rows[slot][l];
            self.prefill_rows[slot][l] =
                keep[l].iter().take_while(|&&i| i < boundary).count();
            // Staging fallback: survivors first, zero the trimmed tail.
            // Survivor rows are read back from the rebuilt blocks — under
            // a lossy codec the rebuild requantizes, and the oracle must
            // hold the requantized bits (at f32 this is `tk`/`tv` again).
            let base = self.stage_base(l, slot, 0);
            if let Some(buf) = self.stage_buf.as_mut() {
                let store = self.alloc.store();
                let mut row = 0usize;
                for &bid in &self.tables[slot][l] {
                    let filled = self.alloc.meta(bid).filled as usize;
                    let b0 = base + row * re;
                    buf.k.data[b0..b0 + filled * re]
                        .copy_from_slice(&store.k_rows(bid, filled));
                    buf.v.data[b0..b0 + filled * re]
                        .copy_from_slice(&store.v_rows(bid, filled));
                    row += filled;
                }
                debug_assert_eq!(row, new_len, "rebuilt rows vs keep len");
                let tail0 = base + new_len * re;
                let tail1 = base + old_len * re;
                buf.k.data[tail0..tail1].fill(0.0);
                buf.v.data[tail0..tail1].fill(0.0);
            }
        }
        self.touch();
        in_use_before.saturating_sub(self.alloc.blocks_in_use())
    }

    /// Valid rows per layer for a lane.
    pub fn layer_lens(&self, slot: usize) -> Vec<usize> {
        self.lens[slot].clone()
    }

    /// Materialize dense decode inputs (fallback / oracle path).
    pub fn stage(&self) -> Staged {
        match &self.stage_buf {
            // Fallback: the incrementally-maintained dense copy (one clone
            // per call — the old per-token decode cost).
            Some(buf) => {
                let mut lens = vec![0i32; self.l * self.b];
                for slot in 0..self.b {
                    for l in 0..self.l {
                        lens[l * self.b + slot] = self.lens[slot][l] as i32;
                    }
                }
                Staged {
                    k: buf.k.clone(),
                    v: buf.v.clone(),
                    lens: HostTensorI32::new(vec![self.l, self.b], lens),
                }
            }
            // Default: gather through the block tables on demand. Decode
            // never takes this path (it consumes the view directly); it
            // exists for tests, tools, and the differential oracle.
            None => self.view().gather_dense(),
        }
    }

    /// Block-pool gauges snapshot.
    pub fn pool_stats(&self) -> PoolStats {
        let store = self.alloc.store();
        // Blocks with at least one generated row: table entries past the
        // last all-prefill block (`boundary / bt` full prefill blocks).
        let mut decode_region_blocks = 0usize;
        for slot in 0..self.b {
            if !self.used[slot] {
                continue;
            }
            for l in 0..self.l {
                let len = self.lens[slot][l];
                let boundary = self.prefill_rows[slot][l].min(len);
                if len > boundary {
                    decode_region_blocks += self.tables[slot][l].len()
                        - boundary / self.block_tokens;
                }
            }
        }
        PoolStats {
            blocks_total: self.alloc.blocks_total(),
            blocks_in_use: self.alloc.blocks_in_use(),
            blocks_cached: self.alloc.blocks_cached(),
            blocks_free: self.alloc.blocks_free(),
            block_tokens: self.block_tokens,
            prefix_hits: self.prefix.hits,
            prefix_misses: self.prefix.misses,
            cow_copies: self.alloc.cow_copies,
            evictions: self.alloc.evictions,
            alloc_failures: self.alloc_failures,
            quota_denials: self.alloc.quota_denials,
            slab_bytes: store.slab_bytes(),
            codec: store.codec(),
            quant_rows: store.quant_rows(),
            dequant_rows: store.dequant_rows(),
            codec_secs: store.codec_secs(),
            decode_region_blocks,
        }
    }

    /// Lanes not currently serving a request.
    pub fn free_lanes(&self) -> usize {
        self.used.iter().filter(|u| !**u).count()
    }
}

impl KvStore for PagedArena {
    fn slots(&self) -> usize {
        self.b
    }

    fn free_slots(&self) -> usize {
        self.free_lanes()
    }

    fn capacity(&self) -> usize {
        self.c
    }

    fn can_admit(&self, per_layer_tokens: usize, max_new: usize) -> bool {
        self.can_admit_for(per_layer_tokens, max_new, TenantId::DEFAULT)
    }

    fn can_admit_for(
        &self,
        per_layer_tokens: usize,
        max_new: usize,
        tenant: TenantId,
    ) -> bool {
        if self.free_lanes() == 0 || per_layer_tokens > self.c {
            return false;
        }
        // Admission covers the request's post-compression KV budget plus
        // one growth block per layer if it will decode at all. Growth
        // beyond that headroom is deliberately NOT reserved (vLLM-style
        // over-commit): it is absorbed by block compaction and, failing
        // that, preemption — reserving worst-case `max_new` growth up
        // front would forfeit most of the batching the paged pool exists
        // to provide. `can_take` additionally holds the take to the
        // tenant's ceiling and to the other tenants' unused reserved
        // floors.
        let headroom = if max_new == 0 { 0 } else { self.l };
        self.alloc
            .can_take(tenant, self.blocks_for(per_layer_tokens) + headroom)
    }

    fn could_ever_admit(&self, per_layer_tokens: usize) -> bool {
        self.could_ever_admit_for(per_layer_tokens, TenantId::DEFAULT)
    }

    fn could_ever_admit_for(
        &self,
        per_layer_tokens: usize,
        tenant: TenantId,
    ) -> bool {
        per_layer_tokens <= self.c
            && self.blocks_for(per_layer_tokens) + self.l
                <= self.alloc.max_ever_available(tenant)
    }

    fn admit(&mut self, cache: &RequestCache) -> Option<usize> {
        PagedArena::admit(self, cache)
    }

    fn admit_for(
        &mut self,
        cache: &RequestCache,
        tenant: TenantId,
    ) -> Option<usize> {
        PagedArena::admit_for(self, cache, tenant)
    }

    fn set_tenant_quota(&mut self, tenant: TenantId, quota: TenantQuota) {
        PagedArena::set_tenant_quota(self, tenant, quota)
    }

    fn tenant_of(&self, slot: usize) -> TenantId {
        PagedArena::tenant_of(self, slot)
    }

    fn tenant_over_quota(&self, tenant: TenantId) -> bool {
        PagedArena::tenant_over_quota(self, tenant)
    }

    fn tenant_at_ceiling(&self, tenant: TenantId) -> bool {
        PagedArena::tenant_at_ceiling(self, tenant)
    }

    fn preempt_helps(&self, victim: TenantId, pressured: TenantId) -> bool {
        PagedArena::preempt_helps(self, victim, pressured)
    }

    fn tenant_stats(&self) -> Vec<TenantStats> {
        PagedArena::tenant_stats(self)
    }

    fn release(&mut self, slot: usize) -> bool {
        PagedArena::release(self, slot)
    }

    fn append(&mut self, slot: usize, k_new: &HostTensor, v_new: &HostTensor) -> AppendResult {
        PagedArena::append(self, slot, k_new, v_new)
    }

    fn layer_lens(&self, slot: usize) -> Vec<usize> {
        PagedArena::layer_lens(self, slot)
    }

    fn compact(&mut self, slot: usize, keep: &[Vec<usize>]) -> usize {
        PagedArena::compact(self, slot, keep)
    }

    fn stage(&self) -> Staged {
        PagedArena::stage(self)
    }

    fn decode_view(&self) -> Option<DecodeView<'_>> {
        if self.stage_buf.is_some() {
            // dense-staging fallback: decode must take the staged bridge
            // (that is the whole point of the flag); the inherent `view()`
            // stays callable for tests and oracles.
            None
        } else {
            Some(PagedArena::view(self))
        }
    }

    fn decode_view_budgeted(
        &self,
        budget: Option<&DecodeBudget>,
    ) -> Option<DecodeView<'_>> {
        if self.stage_buf.is_some() {
            // Staged decode attends to everything resident; the coarse
            // stage still bounds residency, only fine pruning is lost.
            None
        } else {
            Some(PagedArena::view_budgeted(self, budget))
        }
    }

    fn enforce_decode_budget(&mut self, slot: usize, budget: &DecodeBudget) -> usize {
        PagedArena::enforce_decode_budget(self, slot, budget)
    }

    fn held_blocks(&self, slot: usize) -> usize {
        PagedArena::held_blocks(self, slot)
    }

    fn pool_stats(&self) -> PoolStats {
        PagedArena::pool_stats(self)
    }

    fn shard_count(&self) -> usize {
        self.shard_spec().shards
    }

    fn shard_slab_bytes(&self) -> Vec<usize> {
        PagedArena::shard_slab_bytes(self)
    }

    fn lanes_by_tier(&self) -> Vec<(KvCodec, usize)> {
        PagedArena::lanes_by_tier(self)
    }

    fn swap_out(&mut self, slot: usize) -> Option<SwapHandle> {
        PagedArena::swap_out(self, slot)
    }

    fn swap_in(&mut self, handle: SwapHandle) -> SwapIn {
        PagedArena::swap_in(self, handle)
    }

    fn swap_contains(&self, handle: SwapHandle) -> bool {
        PagedArena::swap_contains(self, handle)
    }

    fn can_swap_in(&self, handle: SwapHandle, max_new_remaining: usize) -> bool {
        PagedArena::can_swap_in(self, handle, max_new_remaining)
    }

    fn swap_drop(&mut self, handle: SwapHandle) -> bool {
        PagedArena::swap_drop(self, handle)
    }

    fn swap_stats(&self) -> SwapStats {
        PagedArena::swap_stats(self)
    }
}

// ---------------------------------------------------------------------------
// Flat BatchArena as a KvStore backend (the seed behavior, kept for
// comparison benches and as a fallback).

impl KvStore for BatchArena {
    fn slots(&self) -> usize {
        self.b
    }

    fn free_slots(&self) -> usize {
        BatchArena::free_slots(self)
    }

    fn capacity(&self) -> usize {
        self.c
    }

    fn can_admit(&self, per_layer_tokens: usize, _max_new: usize) -> bool {
        // Seed semantics: admission needs a lane and a cache that fits;
        // decode growth past C just stops the request early.
        BatchArena::free_slots(self) > 0 && per_layer_tokens <= self.c
    }

    fn could_ever_admit(&self, per_layer_tokens: usize) -> bool {
        per_layer_tokens <= self.c
    }

    fn admit(&mut self, cache: &RequestCache) -> Option<usize> {
        if cache.max_len() > self.c {
            return None;
        }
        let slot = self.alloc_slot()?;
        self.load(slot, cache);
        Some(slot)
    }

    fn release(&mut self, slot: usize) -> bool {
        self.free_slot(slot)
    }

    fn append(&mut self, slot: usize, k_new: &HostTensor, v_new: &HostTensor) -> AppendResult {
        if BatchArena::append(self, slot, k_new, v_new) {
            AppendResult::Ok
        } else {
            AppendResult::CapacityExhausted
        }
    }

    fn layer_lens(&self, slot: usize) -> Vec<usize> {
        (0..self.l).map(|l| self.lens[l * self.b + slot] as usize).collect()
    }

    fn compact(&mut self, slot: usize, keep: &[Vec<usize>]) -> usize {
        self.compact_slot(slot, keep);
        0 // flat slab: no blocks to release
    }

    fn stage(&self) -> Staged {
        Staged {
            k: self.k.clone(),
            v: self.v.clone(),
            lens: self.lens_tensor(),
        }
    }

    fn pool_stats(&self) -> PoolStats {
        PoolStats::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> ModelMeta {
        ModelMeta {
            vocab_size: 256,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 2,
            head_dim: 2,
            tsp_layer: 1,
            window: 2,
            pool_kernel: 3,
            max_train_len: 64,
        }
    }

    fn cache_with(m: &ModelMeta, lens: &[usize], tag: f32) -> RequestCache {
        let mut rc = RequestCache::new(m);
        let re = m.n_kv_heads * m.head_dim;
        for (l, &len) in lens.iter().enumerate() {
            rc.k[l] = (0..len * re)
                .map(|i| tag + (l * 10_000 + i) as f32)
                .collect();
            rc.v[l] = (0..len * re)
                .map(|i| -(tag + (l * 10_000 + i) as f32))
                .collect();
            rc.lens[l] = len;
        }
        rc
    }

    #[test]
    fn admit_stage_release_roundtrip() {
        let m = meta();
        let cfg = PagingConfig { block_tokens: 4, ..Default::default() };
        let mut pa = PagedArena::new(&m, 2, 12, cfg);
        let rc = cache_with(&m, &[6, 3], 1.0);
        let slot = PagedArena::admit(&mut pa, &rc).unwrap();
        assert_eq!(pa.layer_lens(slot), vec![6, 3]);
        let st = pa.stage();
        let re = pa.row_elems();
        // layer 0 row 0 must equal the cache's first row
        let base = ((0 * 2 + slot) * 12) * re;
        assert_eq!(&st.k.data[base..base + re], &rc.k[0][..re]);
        assert_eq!(st.lens.data[slot], 6);
        assert_eq!(st.lens.data[2 + slot], 3);
        // blocks: layer0 ceil(6/4)=2, layer1 ceil(3/4)=1
        assert_eq!(pa.pool_stats().blocks_in_use, 3);
        assert!(pa.release(slot));
        assert!(!pa.release(slot), "double release guarded");
        // full unshared blocks were sealed, so they park in the cache
        let ps = pa.pool_stats();
        assert_eq!(ps.blocks_in_use, 0);
        assert!(st.k.data[base] != 0.0);
    }

    #[test]
    fn shared_prompt_reuses_full_blocks() {
        let m = meta();
        let cfg = PagingConfig { block_tokens: 4, ..Default::default() };
        let mut pa = PagedArena::new(&m, 2, 16, cfg);
        let rc = cache_with(&m, &[8, 8], 2.0);
        let s0 = PagedArena::admit(&mut pa, &rc).unwrap();
        let used_one = pa.pool_stats().blocks_in_use;
        assert_eq!(used_one, 4); // 2 layers x 2 full blocks
        let s1 = PagedArena::admit(&mut pa, &rc).unwrap();
        let ps = pa.pool_stats();
        // identical content: the second admit allocates nothing new
        assert_eq!(ps.blocks_in_use, used_one);
        assert!(ps.prefix_hits >= 4, "hits {}", ps.prefix_hits);
        // staged lanes identical
        let st = pa.stage();
        let re = pa.row_elems();
        for l in 0..2 {
            let b0 = ((l * 2 + s0) * 16) * re;
            let b1 = ((l * 2 + s1) * 16) * re;
            assert_eq!(
                &st.k.data[b0..b0 + 8 * re],
                &st.k.data[b1..b1 + 8 * re]
            );
        }
    }

    #[test]
    fn append_and_capacity() {
        let m = meta();
        let cfg = PagingConfig { block_tokens: 2, ..Default::default() };
        let mut pa = PagedArena::new(&m, 1, 3, cfg);
        let rc = cache_with(&m, &[2, 2], 3.0);
        let slot = PagedArena::admit(&mut pa, &rc).unwrap();
        let step = HostTensor::new(
            vec![2, 1, 2, 2],
            (0..8).map(|x| 100.0 + x as f32).collect(),
        );
        assert_eq!(
            PagedArena::append(&mut pa, slot, &step, &step),
            AppendResult::Ok
        );
        assert_eq!(pa.layer_lens(slot), vec![3, 3]);
        assert_eq!(
            PagedArena::append(&mut pa, slot, &step, &step),
            AppendResult::CapacityExhausted
        );
        let st = pa.stage();
        let re = pa.row_elems();
        let base = ((0 * 1 + slot) * 3 + 2) * re;
        assert_eq!(&st.k.data[base..base + re], step.row2(0, slot));
    }

    #[test]
    fn pool_exhaustion_is_not_capacity() {
        let m = meta();
        let cfg = PagingConfig {
            block_tokens: 2,
            num_blocks: Some(2),
            ..Default::default()
        };
        let mut pa = PagedArena::new(&m, 1, 8, cfg);
        let rc = cache_with(&m, &[2, 2], 4.0);
        let slot = PagedArena::admit(&mut pa, &rc).unwrap();
        let step = HostTensor::zeros(vec![2, 1, 2, 2]);
        // both blocks used; the next append needs 2 fresh tail blocks
        assert_eq!(
            PagedArena::append(&mut pa, slot, &step, &step),
            AppendResult::PoolExhausted
        );
        assert_eq!(pa.layer_lens(slot), vec![2, 2], "append was atomic");
        assert_eq!(pa.pool_stats().alloc_failures, 1);
    }

    #[test]
    fn fork_then_append_copies_on_write() {
        let m = meta();
        let cfg = PagingConfig { block_tokens: 4, ..Default::default() };
        let mut pa = PagedArena::new(&m, 2, 8, cfg);
        let rc = cache_with(&m, &[6, 6], 5.0);
        let s0 = PagedArena::admit(&mut pa, &rc).unwrap();
        let used_one = pa.pool_stats().blocks_in_use;
        let s1 = pa.fork(s0).unwrap();
        assert_eq!(pa.pool_stats().blocks_in_use, used_one, "fork is free");
        let step = HostTensor::new(vec![2, 2, 2, 2], vec![9.0; 16]);
        assert_eq!(
            PagedArena::append(&mut pa, s1, &step, &step),
            AppendResult::Ok
        );
        let ps = pa.pool_stats();
        assert!(ps.cow_copies >= 1, "tail was shared -> COW");
        // parent unchanged: its staged tail row is still zero
        let st = pa.stage();
        let re = pa.row_elems();
        let parent_row6 = ((0 * 2 + s0) * 8 + 6) * re;
        assert!(st.k.data[parent_row6..parent_row6 + re]
            .iter()
            .all(|&x| x == 0.0));
        let child_row6 = ((0 * 2 + s1) * 8 + 6) * re;
        assert_eq!(&st.k.data[child_row6..child_row6 + re], &[9.0; 4][..]);
    }

    #[test]
    fn compact_releases_blocks() {
        let m = meta();
        let cfg = PagingConfig { block_tokens: 2, ..Default::default() };
        let mut pa = PagedArena::new(&m, 1, 8, cfg);
        let rc = cache_with(&m, &[8, 8], 6.0);
        let slot = PagedArena::admit(&mut pa, &rc).unwrap();
        assert_eq!(pa.pool_stats().blocks_in_use, 8);
        // keep rows {0, 7} per layer
        let keep = vec![vec![0usize, 7], vec![0usize, 7]];
        let released = PagedArena::compact(&mut pa, slot, &keep);
        assert!(released >= 6, "released {released}");
        assert_eq!(pa.layer_lens(slot), vec![2, 2]);
        let st = pa.stage();
        let re = pa.row_elems();
        // row 1 of layer 0 staging now holds old row 7
        let base = ((0 * 1 + slot) * 8 + 1) * re;
        assert_eq!(&st.k.data[base..base + re], &rc.k[0][7 * re..8 * re]);
        // rows beyond the kept set are zeroed
        let tail = ((0 * 1 + slot) * 8 + 2) * re;
        assert!(st.k.data[tail..tail + re].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn view_gathers_identically_to_dense_staging() {
        // The block-table view and the dense-staging fallback must describe
        // the exact same KV, whichever way it is read.
        let m = meta();
        let mk = |dense| PagingConfig {
            block_tokens: 3,
            dense_staging: dense,
            ..Default::default()
        };
        let mut a = PagedArena::new(&m, 2, 10, mk(false));
        let mut b = PagedArena::new(&m, 2, 10, mk(true));
        let rc = cache_with(&m, &[7, 4], 8.0);
        let sa = PagedArena::admit(&mut a, &rc).unwrap();
        let sb = PagedArena::admit(&mut b, &rc).unwrap();
        assert_eq!(sa, sb);
        let step = HostTensor::new(
            vec![2, 2, 2, 2],
            (0..16).map(|x| 50.0 + x as f32).collect(),
        );
        assert_eq!(PagedArena::append(&mut a, sa, &step, &step), AppendResult::Ok);
        assert_eq!(PagedArena::append(&mut b, sb, &step, &step), AppendResult::Ok);
        let keep = vec![vec![0usize, 2, 7], vec![1usize, 4]];
        PagedArena::compact(&mut a, sa, &keep);
        PagedArena::compact(&mut b, sb, &keep);

        let st_a = a.stage(); // gather-on-demand
        let st_b = b.stage(); // incremental dense copy
        assert_eq!(st_a.lens.data, st_b.lens.data);
        assert_eq!(st_a.k.data, st_b.k.data);
        assert_eq!(st_a.v.data, st_b.v.data);

        // row-level gather matches the staged layout
        let view = a.view();
        let re = a.row_elems();
        for l in 0..2 {
            for row in 0..view.len(l, sa) {
                let base = ((l * 2 + sa) * 10 + row) * re;
                assert_eq!(view.k_row(l, sa, row), &st_b.k.data[base..base + re]);
                assert_eq!(view.v_row(l, sa, row), &st_b.v.data[base..base + re]);
            }
        }
        // artifact-shaped tensors are consistent with the view
        let tt = view.tables_tensor(view.max_blocks + 2);
        assert_eq!(tt.shape, vec![2, 2, view.max_blocks + 2]);
        let (sk, sv) = view.slab_tensors(view.num_blocks + 1);
        assert_eq!(sk.shape[0], view.num_blocks + 1);
        assert_eq!(sk.data.len(), sv.data.len());
    }

    #[test]
    fn view_version_tracks_mutations() {
        let m = meta();
        let mut pa =
            PagedArena::new(&m, 1, 8, PagingConfig::default());
        let v0 = pa.version();
        let rc = cache_with(&m, &[4, 4], 9.0);
        let slot = PagedArena::admit(&mut pa, &rc).unwrap();
        let v1 = pa.version();
        assert_ne!(v0, v1, "admit must bump the version");
        let step = HostTensor::zeros(vec![2, 1, 2, 2]);
        PagedArena::append(&mut pa, slot, &step, &step);
        assert_ne!(v1, pa.version(), "append must bump the version");
        // distinct stores can never share a version (store id in the
        // upper bits)
        let pb = PagedArena::new(&m, 1, 8, PagingConfig::default());
        assert_ne!(pa.version() >> 32, pb.version() >> 32);
    }

    #[test]
    fn held_blocks_counts_lane_tables() {
        let m = meta();
        let cfg = PagingConfig { block_tokens: 2, ..Default::default() };
        let mut pa = PagedArena::new(&m, 2, 8, cfg);
        assert_eq!(pa.held_blocks(0), 0);
        let rc = cache_with(&m, &[5, 2], 10.0);
        let slot = PagedArena::admit(&mut pa, &rc).unwrap();
        // layer0 ceil(5/2)=3 + layer1 ceil(2/2)=1
        assert_eq!(pa.held_blocks(slot), 4);
        pa.release(slot);
        assert_eq!(pa.held_blocks(slot), 0);
    }

    #[test]
    fn swap_roundtrip_restores_lane_and_pool_accounting() {
        let m = meta();
        let cfg = PagingConfig {
            block_tokens: 2,
            prefix_cache: false,
            ..Default::default()
        };
        let mut pa = PagedArena::new(&m, 2, 8, cfg);
        let rc = cache_with(&m, &[5, 3], 11.0);
        let slot = PagedArena::admit(&mut pa, &rc).unwrap();
        let step = HostTensor::new(
            vec![2, 2, 2, 2],
            (0..16).map(|x| 60.0 + x as f32).collect(),
        );
        assert_eq!(
            PagedArena::append(&mut pa, slot, &step, &step),
            AppendResult::Ok
        );
        let before = pa.stage();
        let lens_before = pa.layer_lens(slot);
        let in_use = pa.pool_stats().blocks_in_use;

        let h = pa.swap_out(slot).expect("default budget takes one lane");
        assert_eq!(pa.pool_stats().blocks_in_use, 0, "blocks released");
        assert!(!pa.used[slot]);
        assert!(pa.swap_contains(h));
        assert!(pa.can_swap_in(h, 4));

        match pa.swap_in(h) {
            SwapIn::Restored(s) => {
                assert_eq!(s, slot, "same free lane picked");
            }
            other => panic!("expected restore, got {other:?}"),
        }
        assert!(!pa.swap_contains(h), "handle consumed");
        assert_eq!(pa.layer_lens(slot), lens_before);
        assert_eq!(pa.pool_stats().blocks_in_use, in_use);
        let after = pa.stage();
        assert_eq!(before.lens.data, after.lens.data);
        assert_eq!(before.k.data, after.k.data);
        assert_eq!(before.v.data, after.v.data);
        let ss = pa.swap_stats();
        assert_eq!((ss.swap_outs, ss.swap_ins, ss.used_bytes), (1, 1, 0));
        // consumed handles are gone, not busy
        assert_eq!(pa.swap_in(h), SwapIn::Gone);
    }

    #[test]
    fn swap_in_reshares_sealed_blocks_through_prefix_cache() {
        let m = meta();
        let cfg = PagingConfig { block_tokens: 4, ..Default::default() };
        let mut pa = PagedArena::new(&m, 2, 16, cfg);
        let rc = cache_with(&m, &[8, 8], 12.0);
        let s0 = PagedArena::admit(&mut pa, &rc).unwrap();
        let s1 = PagedArena::admit(&mut pa, &rc).unwrap();
        let shared = pa.pool_stats().blocks_in_use;
        assert_eq!(shared, 4, "both lanes share the sealed blocks");
        let h = pa.swap_out(s1).unwrap();
        // blocks stay alive through s0's references
        assert_eq!(pa.pool_stats().blocks_in_use, shared);
        let hits_before = pa.pool_stats().prefix_hits;
        match pa.swap_in(h) {
            SwapIn::Restored(_) => {}
            other => panic!("expected restore, got {other:?}"),
        }
        let ps = pa.pool_stats();
        assert_eq!(
            ps.blocks_in_use, shared,
            "restore revived via preserved hashes, no fresh blocks"
        );
        assert!(ps.prefix_hits > hits_before);
        let _ = s0;
    }

    #[test]
    fn swap_disabled_or_over_budget_refuses_and_leaves_lane_intact() {
        let m = meta();
        let mk = |bytes| PagingConfig {
            block_tokens: 2,
            prefix_cache: false,
            swap_bytes: bytes,
            ..Default::default()
        };
        // disabled
        let mut off = PagedArena::new(&m, 1, 8, mk(0));
        let rc = cache_with(&m, &[4, 4], 13.0);
        let slot = PagedArena::admit(&mut off, &rc).unwrap();
        assert!(off.swap_out(slot).is_none());
        assert_eq!(off.layer_lens(slot), vec![4, 4], "lane untouched");
        // budget smaller than one lane
        let mut tiny = PagedArena::new(&m, 1, 8, mk(8));
        let slot = PagedArena::admit(&mut tiny, &rc).unwrap();
        assert!(tiny.swap_out(slot).is_none());
        assert_eq!(tiny.layer_lens(slot), vec![4, 4], "lane untouched");
        assert_eq!(tiny.swap_stats().refused, 1);
    }

    #[test]
    fn swap_in_reports_busy_until_memory_frees() {
        let m = meta();
        let cfg = PagingConfig {
            block_tokens: 2,
            num_blocks: Some(8),
            prefix_cache: false,
            ..Default::default()
        };
        let mut pa = PagedArena::new(&m, 1, 8, cfg);
        let rc = cache_with(&m, &[4, 4], 14.0);
        let slot = PagedArena::admit(&mut pa, &rc).unwrap();
        let h = pa.swap_out(slot).unwrap();
        // occupy the only lane (and most of the pool) with another request
        let other = cache_with(&m, &[6, 6], 15.0);
        let s2 = PagedArena::admit(&mut pa, &other).unwrap();
        assert!(!pa.can_swap_in(h, 2), "no free lane");
        assert_eq!(pa.swap_in(h), SwapIn::Busy);
        assert!(pa.swap_contains(h), "busy keeps the entry");
        pa.release(s2);
        assert!(pa.can_swap_in(h, 0));
        match pa.swap_in(h) {
            SwapIn::Restored(s) => assert_eq!(pa.layer_lens(s), vec![4, 4]),
            other => panic!("expected restore, got {other:?}"),
        }
    }

    #[test]
    fn tenant_quota_bounds_admission_and_stats_reconcile() {
        let m = meta();
        let cfg = PagingConfig {
            block_tokens: 2,
            num_blocks: Some(8),
            prefix_cache: false,
            tenant_quotas: vec![(TenantId(1), TenantQuota::reserved(4))],
            ..Default::default()
        };
        let mut pa = PagedArena::new(&m, 2, 8, cfg);
        // heavy tenant 2 may take only pool - light tenant's floor = 4
        assert!(KvStore::can_admit_for(&pa, 4, 0, TenantId(2)));
        assert!(
            !KvStore::can_admit_for(&pa, 4, 1, TenantId(2)),
            "growth headroom would eat the light tenant's floor"
        );
        let heavy = cache_with(&m, &[4, 4], 20.0);
        let s_heavy = pa.admit_for(&heavy, TenantId(2)).unwrap();
        // the floor protects the remaining 4 blocks: a second heavy admit
        // rolls back on quota while the light tenant still fits
        assert!(pa.admit_for(&heavy, TenantId(2)).is_none());
        assert!(pa.pool_stats().quota_denials > 0);
        let light = cache_with(&m, &[4, 4], 21.0);
        let s_light = pa.admit_for(&light, TenantId(1)).unwrap();
        // charges reconcile with pool accounting
        let ts = pa.tenant_stats();
        let held: usize = ts.iter().map(|t| t.held_blocks).sum();
        assert_eq!(held, pa.pool_stats().blocks_in_use);
        assert!(pa.tenant_over_quota(TenantId(2)), "bursting past floor 0");
        assert!(!pa.tenant_over_quota(TenantId(1)), "within its floor");
        assert_eq!(pa.tenant_of(s_heavy), TenantId(2));
        assert_eq!(pa.tenant_of(s_light), TenantId(1));
        // ever-admissible is floor-aware per tenant
        assert!(!KvStore::could_ever_admit_for(&pa, 6, TenantId(2)));
        assert!(KvStore::could_ever_admit_for(&pa, 6, TenantId(1)));
        pa.release(s_heavy);
        pa.release(s_light);
        assert!(pa.tenant_stats().iter().all(|t| t.held_blocks == 0));
    }

    #[test]
    fn preempt_helps_filters_useless_victims() {
        let m = meta();
        let cfg = PagingConfig {
            block_tokens: 2,
            num_blocks: Some(8),
            prefix_cache: false,
            tenant_quotas: vec![
                (TenantId(1), TenantQuota::reserved(4)),
                (TenantId(2), TenantQuota::bounded(0, 4)),
            ],
            ..Default::default()
        };
        let mut pa = PagedArena::new(&m, 2, 8, cfg);
        // T1 sits exactly at its floor; T2 bursts exactly to its ceiling
        let s1 =
            pa.admit_for(&cache_with(&m, &[4, 4], 30.0), TenantId(1)).unwrap();
        let s2 =
            pa.admit_for(&cache_with(&m, &[4, 4], 31.0), TenantId(2)).unwrap();
        assert!(pa.tenant_at_ceiling(TenantId(2)));
        // own lanes always help
        assert!(pa.preempt_helps(TenantId(2), TenantId(2)));
        // ceiling-bound pressured tenant: no cross-tenant free can help
        assert!(!pa.preempt_helps(TenantId(1), TenantId(2)));
        // a victim inside its own floor hands its frees back to the
        // floor — useless to any third tenant
        assert!(!pa.preempt_helps(TenantId(1), TenantId(3)));
        // an over-floor victim frees real headroom
        assert!(pa.preempt_helps(TenantId(2), TenantId(3)));
        let _ = (s1, s2);
        // without quotas everyone helps (pre-tenancy behavior)
        let pb = PagedArena::new(
            &m,
            1,
            8,
            PagingConfig { block_tokens: 2, ..Default::default() },
        );
        assert!(pb.preempt_helps(TenantId(7), TenantId(9)));
    }

    #[test]
    fn can_admit_accounts_for_pool() {
        let m = meta();
        let cfg = PagingConfig {
            block_tokens: 2,
            num_blocks: Some(4),
            ..Default::default()
        };
        let pa = PagedArena::new(&m, 2, 8, cfg);
        // budget 2 layers x ceil(2/2)=2 + 2 headroom -> 4 blocks: fits
        assert!(KvStore::can_admit(&pa, 2, 2));
        // budget 2 layers x ceil(4/2)=4 + 2 headroom -> 6 blocks: too big
        assert!(!KvStore::can_admit(&pa, 4, 2));
        // no decode growth -> no headroom reserved: exactly fits
        assert!(KvStore::can_admit(&pa, 4, 0));
    }
}
