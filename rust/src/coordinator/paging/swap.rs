//! Host-side KV swap arena: preempted lanes become durable artifacts.
//!
//! FastKV's retained KV is expensive, carefully-selected state — the
//! TSP-layer selection ran once at prefill and everything the lane
//! decoded since rode on it. Recompute-resume (re-prefilling
//! `prompt ++ generated` after a preemption) re-pays exactly that cost
//! and, worse, re-*selects*: the re-run policy sees a longer prompt and
//! may retain different entries than the cache the lane was decoding
//! against (selection drift). Swap-to-host treats the once-compressed KV
//! as a durable artifact instead: at preemption the lane's blocks are
//! serialized to a byte-budgeted host arena (per-layer lens + rows + the
//! prefix-hash chain), and resume restores them into freshly allocated
//! blocks — no policy re-run, no prefill, bit-identical KV.
//!
//! Budgeting: the arena holds at most `budget_bytes` of payload. A new
//! swap-out evicts the *oldest* entries to make room (their owners fall
//! back to recompute-resume — the handle reports [`SwapIn::Gone`]), and
//! is refused outright only when the lane alone exceeds the budget.
//! `budget_bytes == 0` disables swapping entirely (pure recompute-resume,
//! the pre-swap behavior).
//!
//! The arena is deliberately dumb storage: which lane to swap, when to
//! restore, and what to do on `Gone`/`Busy` are the serving loop's
//! decisions (`server.rs`); block allocation and prefix re-sharing on
//! restore are `PagedArena::swap_in`'s.

use std::collections::{HashMap, VecDeque};

/// Opaque ticket for a lane swapped out to host memory. Rides on the
/// scheduler's resume-queue entry; consumed by a successful swap-in.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SwapHandle(pub u64);

/// Outcome of a swap-in attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SwapIn {
    /// KV restored into this lane; the handle is consumed.
    Restored(usize),
    /// No free lane, or the block pool cannot cover the restore right
    /// now. The handle stays valid — retry after decode frees memory.
    Busy,
    /// The handle was dropped under host-memory pressure (or never
    /// existed). The caller must fall back to recompute-resume.
    Gone,
}

/// One serialized lane: dense per-layer rows plus the per-block prefix
/// hashes captured at swap-out.
#[derive(Debug, Clone)]
pub struct SwapEntry {
    /// Valid rows per layer.
    pub lens: Vec<usize>,
    /// `[layer][len * row_elems]` K rows in logical order.
    pub k: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
    /// `[layer][block]` chain hash of each block at swap-out: `Some` for
    /// full sealed blocks (so swap-in re-shares them through the prefix
    /// cache without re-hashing), `None` for mutable tails and
    /// decode-written blocks.
    pub hashes: Vec<Vec<Option<u64>>>,
    /// Host bytes held by the K + V payload.
    pub bytes: usize,
}

impl SwapEntry {
    /// Blocks a restore needs, assuming no prefix sharing (conservative —
    /// mirrors `PagedArena::blocks_for`).
    pub fn total_blocks(&self, block_tokens: usize) -> usize {
        let bt = block_tokens.max(1);
        self.lens.iter().map(|&n| (n + bt - 1) / bt).sum()
    }

    pub fn max_len(&self) -> usize {
        self.lens.iter().copied().max().unwrap_or(0)
    }
}

/// Aggregate swap gauges/counters for metrics and reporting.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SwapStats {
    pub budget_bytes: usize,
    pub used_bytes: usize,
    pub entries: usize,
    /// Lanes serialized to host.
    pub swap_outs: u64,
    /// Lanes restored from host.
    pub swap_ins: u64,
    /// Swap-outs refused because one lane exceeded the whole budget (or
    /// swapping is disabled).
    pub refused: u64,
    /// Entries evicted (oldest-first) to make room for newer swap-outs;
    /// their owners recompute-resume.
    pub dropped: u64,
}

/// Byte-budgeted store of swapped lanes. Insertion evicts oldest-first
/// under pressure; lookups are O(1).
#[derive(Debug)]
pub struct SwapArena {
    budget: usize,
    used: usize,
    entries: HashMap<u64, SwapEntry>,
    /// Insertion order, oldest in front. May hold ids already consumed by
    /// a swap-in or an explicit drop — validated against `entries` when
    /// popped for eviction (same stale-marker discipline as the block
    /// allocator's evictable queue).
    order: VecDeque<u64>,
    next: u64,
    swap_outs: u64,
    swap_ins: u64,
    refused: u64,
    dropped: u64,
}

impl SwapArena {
    pub fn new(budget_bytes: usize) -> Self {
        SwapArena {
            budget: budget_bytes,
            used: 0,
            entries: HashMap::new(),
            order: VecDeque::new(),
            next: 1,
            swap_outs: 0,
            swap_ins: 0,
            refused: 0,
            dropped: 0,
        }
    }

    pub fn enabled(&self) -> bool {
        self.budget > 0
    }

    /// Park a serialized lane. Evicts oldest entries while over budget;
    /// refuses (`None`) when the entry alone cannot fit — the caller
    /// falls back to recompute-resume and the lane is left untouched.
    pub fn insert(&mut self, entry: SwapEntry) -> Option<SwapHandle> {
        if entry.bytes > self.budget {
            self.refused += 1;
            return None;
        }
        while self.used + entry.bytes > self.budget {
            let Some(old) = self.order.pop_front() else { break };
            if let Some(e) = self.entries.remove(&old) {
                self.used -= e.bytes;
                self.dropped += 1;
            }
        }
        let id = self.next;
        self.next += 1;
        self.used += entry.bytes;
        self.entries.insert(id, entry);
        self.order.push_back(id);
        self.swap_outs += 1;
        Some(SwapHandle(id))
    }

    pub fn contains(&self, h: SwapHandle) -> bool {
        self.entries.contains_key(&h.0)
    }

    pub fn get(&self, h: SwapHandle) -> Option<&SwapEntry> {
        self.entries.get(&h.0)
    }

    /// Remove an entry for a restore attempt. If the attempt fails
    /// (pool shortfall), pair with [`SwapArena::put_back`] — the entry's
    /// order id stays in the queue across the round trip (so it keeps
    /// its eviction priority and `insert` can always reach it), which is
    /// why pruning happens only on *final* removals.
    pub fn take(&mut self, h: SwapHandle) -> Option<SwapEntry> {
        let e = self.entries.remove(&h.0)?;
        self.used -= e.bytes;
        Some(e)
    }

    /// Drop consumed ids from the order queue once stale ids dominate it
    /// — the same bounded-stale-markers discipline as the block
    /// allocator's evictable queue. Called on final removals only (a
    /// taken-but-put-back entry must keep its queue id), it bounds
    /// `order` at ~2x the live entry count plus a small floor no matter
    /// how many preempt/resume cycles a long-running server performs.
    fn prune_order(&mut self) {
        if self.order.len() > 2 * self.entries.len() + 8 {
            let entries = &self.entries;
            self.order.retain(|id| entries.contains_key(id));
        }
    }

    /// Undo a [`SwapArena::take`] after a failed restore. Never evicts:
    /// the bytes were part of the budget a moment ago. The handle's
    /// `order` entry is still in the queue (stale-marker discipline), so
    /// its eviction priority is preserved.
    pub fn put_back(&mut self, h: SwapHandle, entry: SwapEntry) {
        self.used += entry.bytes;
        self.entries.insert(h.0, entry);
    }

    /// Discard an entry (request finished, rejected, or restored).
    pub fn drop_entry(&mut self, h: SwapHandle) -> bool {
        match self.entries.remove(&h.0) {
            Some(e) => {
                self.used -= e.bytes;
                self.prune_order();
                true
            }
            None => false,
        }
    }

    /// Count a successful restore (the entry was consumed via `take` and
    /// will not come back — its order id is now prunable).
    pub fn note_swap_in(&mut self) {
        self.swap_ins += 1;
        self.prune_order();
    }

    pub fn stats(&self) -> SwapStats {
        SwapStats {
            budget_bytes: self.budget,
            used_bytes: self.used,
            entries: self.entries.len(),
            swap_outs: self.swap_outs,
            swap_ins: self.swap_ins,
            refused: self.refused,
            dropped: self.dropped,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(bytes: usize) -> SwapEntry {
        SwapEntry {
            lens: vec![bytes / 8, bytes / 8],
            k: vec![Vec::new(); 2],
            v: vec![Vec::new(); 2],
            hashes: vec![Vec::new(); 2],
            bytes,
        }
    }

    #[test]
    fn insert_take_putback_roundtrip() {
        let mut a = SwapArena::new(100);
        let h = a.insert(entry(40)).unwrap();
        assert!(a.contains(h));
        assert_eq!(a.stats().used_bytes, 40);
        let e = a.take(h).unwrap();
        assert_eq!(a.stats().used_bytes, 0);
        assert!(!a.contains(h));
        a.put_back(h, e);
        assert!(a.contains(h));
        assert_eq!(a.stats().used_bytes, 40);
        assert!(a.drop_entry(h));
        assert!(!a.drop_entry(h), "double drop guarded");
    }

    #[test]
    fn over_budget_entry_is_refused() {
        let mut a = SwapArena::new(10);
        assert!(a.insert(entry(11)).is_none());
        assert_eq!(a.stats().refused, 1);
        assert_eq!(a.stats().used_bytes, 0);
        // zero budget disables swapping entirely
        let mut z = SwapArena::new(0);
        assert!(!z.enabled());
        assert!(z.insert(entry(1)).is_none());
    }

    #[test]
    fn pressure_drops_oldest_first() {
        let mut a = SwapArena::new(100);
        let h0 = a.insert(entry(40)).unwrap();
        let h1 = a.insert(entry(40)).unwrap();
        // 40 + 40 + 40 > 100: h0 (oldest) is dropped
        let h2 = a.insert(entry(40)).unwrap();
        assert!(!a.contains(h0), "oldest evicted");
        assert!(a.contains(h1) && a.contains(h2));
        let s = a.stats();
        assert_eq!((s.dropped, s.entries, s.used_bytes), (1, 2, 80));
        // consumed entries leave stale order ids that eviction skips
        let e1 = a.take(h1).unwrap();
        a.note_swap_in();
        drop(e1);
        let h3 = a.insert(entry(60)).unwrap(); // 40 + 60 > 100: drops h2
        assert!(!a.contains(h2));
        assert!(a.contains(h3));
        assert_eq!(a.stats().dropped, 2);
    }

    #[test]
    fn order_queue_bounded_across_many_roundtrips() {
        // Regression: every swap-out used to leave its id in `order`
        // forever once consumed — unbounded growth over a long-running
        // server's preempt/resume cycles. Final removals prune.
        let mut a = SwapArena::new(1000);
        for _ in 0..500 {
            let h = a.insert(entry(10)).unwrap();
            let e = a.take(h).unwrap();
            drop(e);
            a.note_swap_in();
        }
        assert!(
            a.order.len() <= 2 * a.entries.len() + 8,
            "order queue leaked: {} ids for {} entries",
            a.order.len(),
            a.entries.len()
        );
        for _ in 0..500 {
            let h = a.insert(entry(10)).unwrap();
            assert!(a.drop_entry(h));
        }
        assert!(a.order.len() <= 8, "drops must prune too");
        // a failed-restore round trip keeps the id: the entry must stay
        // reachable for pressure eviction afterwards
        let h = a.insert(entry(900)).unwrap();
        let e = a.take(h).unwrap();
        a.put_back(h, e);
        let h2 = a.insert(entry(900)).unwrap(); // over budget: evicts h
        assert!(!a.contains(h), "put-back entry still evictable");
        assert!(a.contains(h2));
    }

    #[test]
    fn entry_block_math() {
        let e = SwapEntry {
            lens: vec![5, 0, 8],
            k: vec![Vec::new(); 3],
            v: vec![Vec::new(); 3],
            hashes: vec![Vec::new(); 3],
            bytes: 0,
        };
        assert_eq!(e.total_blocks(4), 2 + 0 + 2);
        assert_eq!(e.max_len(), 8);
    }
}
