//! Host-side KV swap arena: preempted lanes become durable artifacts.
//!
//! FastKV's retained KV is expensive, carefully-selected state — the
//! TSP-layer selection ran once at prefill and everything the lane
//! decoded since rode on it. Recompute-resume (re-prefilling
//! `prompt ++ generated` after a preemption) re-pays exactly that cost
//! and, worse, re-*selects*: the re-run policy sees a longer prompt and
//! may retain different entries than the cache the lane was decoding
//! against (selection drift). Swap-to-host treats the once-compressed KV
//! as a durable artifact instead: at preemption the lane's blocks are
//! serialized to a byte-budgeted host arena (per-layer lens + rows + the
//! prefix-hash chain), and resume restores them into freshly allocated
//! blocks — no policy re-run, no prefill, bit-identical KV.
//!
//! Budgeting: the arena holds at most `budget_bytes` of payload. A new
//! swap-out evicts the *oldest* entries to make room (their owners fall
//! back to recompute-resume — the handle reports [`SwapIn::Gone`]), and
//! is refused outright only when the lane alone exceeds the budget.
//! `budget_bytes == 0` disables swapping entirely (pure recompute-resume,
//! the pre-swap behavior).
//!
//! **Per-tenant budgets**: each entry is charged to the tenant of the
//! lane it came from, and a tenant may hold at most its configured swap
//! byte cap ([`SwapArena::set_tenant_budget`]; the arena-wide budget by
//! default). A tenant over its own cap first drops *its own* oldest
//! entries; if the lane alone exceeds the cap the swap-out is refused —
//! so one tenant's preemption churn degrades only that tenant to
//! recompute-resume, never its neighbours. Global pressure still evicts
//! oldest-first across tenants.
//!
//! The arena is deliberately dumb storage: which lane to swap, when to
//! restore, and what to do on `Gone`/`Busy` are the serving loop's
//! decisions (`server.rs`); block allocation and prefix re-sharing on
//! restore are `PagedArena::swap_in`'s.

use std::borrow::Cow;
use std::collections::{BTreeMap, HashMap, VecDeque};

use super::codec::{self, KvCodec};
use super::tenant::TenantId;

// Re-exported from the unified codec module: the f16 element conversions
// started life here as the swap-only `swap_half` codec (PR 5) and moved
// to `codec.rs` when the slab learned to quantize too. The spelling
// `swap::f32_to_f16` stays valid so the exhaustive tests below (and any
// external caller) keep pinning the exact same functions.
pub use super::codec::{f16_to_f32, f32_to_f16};

/// One layer's serialized K or V rows under a [`KvCodec`]: verbatim f32
/// (the default), the f16 encoding (`PagingConfig::swap_half` or an f16
/// precision tier), or per-row-scaled int8 for bulk tiers.
/// `SwapEntry::bytes` and every budget check see the *encoded* size,
/// which is the point of the codec. Swapped lanes are cold storage —
/// written once at preemption, read once at resume — so the lossy tiers
/// trade restore exactness for parking 2–4x more lanes per host byte at
/// zero hot-path cost.
#[derive(Debug, Clone)]
pub enum KvLane {
    /// Verbatim rows; restore is bit-identical.
    F32(Vec<f32>),
    /// Half-precision rows; restore is within one f16 rounding step
    /// (relative 2^-11) per element.
    F16(Vec<u16>),
    /// Per-row-scaled int8 rows; restore is within `scale / 2` per
    /// element (`scale = max|row| / 127`, one per row).
    Int8PerRow {
        /// Quantized elements, `scales.len() * row_elems` of them.
        q: Vec<i8>,
        /// One scale per serialized row.
        scales: Vec<f32>,
        /// Elements per row (needed to decode).
        row_elems: usize,
    },
}

impl KvLane {
    /// Encode `rows` (a whole-multiple of `row_elems` elements) under the
    /// chosen codec.
    pub fn encode(rows: Vec<f32>, codec: KvCodec, row_elems: usize) -> KvLane {
        match codec {
            KvCodec::F32 => KvLane::F32(rows),
            KvCodec::F16 => {
                KvLane::F16(rows.into_iter().map(f32_to_f16).collect())
            }
            KvCodec::Int8PerRow => {
                assert!(row_elems > 0, "row_elems must be positive");
                assert_eq!(rows.len() % row_elems, 0, "partial row");
                let n = rows.len() / row_elems;
                let mut q = vec![0i8; rows.len()];
                let mut scales = vec![0.0f32; n];
                for r in 0..n {
                    scales[r] = codec::quantize_row_int8(
                        &rows[r * row_elems..(r + 1) * row_elems],
                        &mut q[r * row_elems..(r + 1) * row_elems],
                    );
                }
                KvLane::Int8PerRow { q, scales, row_elems }
            }
        }
    }

    /// Elements held (row count x row width).
    pub fn len_elems(&self) -> usize {
        match self {
            KvLane::F32(v) => v.len(),
            KvLane::F16(v) => v.len(),
            KvLane::Int8PerRow { q, .. } => q.len(),
        }
    }

    /// Host bytes this lane's payload occupies (what the budget charges;
    /// int8 includes its per-row scales, matching
    /// [`KvCodec::bytes_per_row`]).
    pub fn payload_bytes(&self) -> usize {
        match self {
            KvLane::F32(v) => v.len() * std::mem::size_of::<f32>(),
            KvLane::F16(v) => v.len() * std::mem::size_of::<u16>(),
            KvLane::Int8PerRow { q, scales, .. } => {
                q.len() * std::mem::size_of::<i8>()
                    + scales.len() * std::mem::size_of::<f32>()
            }
        }
    }

    /// Whether a decode loses bits relative to the serialized f32 rows.
    pub fn is_lossy(&self) -> bool {
        !matches!(self, KvLane::F32(_))
    }

    /// Rows as f32: borrowed verbatim for [`KvLane::F32`], decoded into a
    /// fresh buffer otherwise (restore-time only — the hot path never
    /// touches swapped lanes).
    pub fn as_f32(&self) -> Cow<'_, [f32]> {
        match self {
            KvLane::F32(v) => Cow::Borrowed(v),
            KvLane::F16(v) => {
                Cow::Owned(v.iter().map(|&h| f16_to_f32(h)).collect())
            }
            KvLane::Int8PerRow { q, scales, row_elems } => {
                let mut out = vec![0.0f32; q.len()];
                for (r, &s) in scales.iter().enumerate() {
                    codec::dequantize_row_int8(
                        &q[r * row_elems..(r + 1) * row_elems],
                        s,
                        &mut out[r * row_elems..(r + 1) * row_elems],
                    );
                }
                Cow::Owned(out)
            }
        }
    }
}

/// Opaque ticket for a lane swapped out to host memory. Rides on the
/// scheduler's resume-queue entry; consumed by a successful swap-in.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SwapHandle(
    /// Raw arena entry id.
    pub u64,
);

/// Outcome of a swap-in attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SwapIn {
    /// KV restored into this lane; the handle is consumed.
    Restored(usize),
    /// No free lane, or the block pool cannot cover the restore right
    /// now. The handle stays valid — retry after decode frees memory.
    Busy,
    /// The handle was dropped under host-memory pressure (or never
    /// existed). The caller must fall back to recompute-resume.
    Gone,
}

/// One serialized lane: dense per-layer rows plus the per-block prefix
/// hashes captured at swap-out.
#[derive(Debug, Clone)]
pub struct SwapEntry {
    /// Valid rows per layer.
    pub lens: Vec<usize>,
    /// `[layer]` K rows (`len * row_elems` elements each, logical order),
    /// under the lane's [`KvCodec`] tier ([`KvLane`]).
    pub k: Vec<KvLane>,
    /// V rows, same layout as `k`.
    pub v: Vec<KvLane>,
    /// `[layer][block]` chain hash of each block at swap-out: `Some` for
    /// full sealed blocks (so swap-in re-shares them through the prefix
    /// cache without re-hashing), `None` for mutable tails and
    /// decode-written blocks.
    pub hashes: Vec<Vec<Option<u64>>>,
    /// Host bytes held by the K + V payload.
    pub bytes: usize,
    /// Tenant of the lane this entry was serialized from; the bytes are
    /// charged against this tenant's swap budget, and a restore's block
    /// allocations are charged to it too.
    pub tenant: TenantId,
}

impl SwapEntry {
    /// Blocks a restore needs, assuming no prefix sharing (conservative —
    /// mirrors `PagedArena::blocks_for`).
    pub fn total_blocks(&self, block_tokens: usize) -> usize {
        let bt = block_tokens.max(1);
        self.lens.iter().map(|&n| (n + bt - 1) / bt).sum()
    }

    /// Longest per-layer length (lane-capacity check on restore).
    pub fn max_len(&self) -> usize {
        self.lens.iter().copied().max().unwrap_or(0)
    }

    /// Whether restoring this entry loses bits vs the serialized rows
    /// (any lossy tier). Lossy restores must not re-register preserved
    /// hashes for freshly-written blocks — see `PagedArena::swap_in`.
    pub fn is_lossy(&self) -> bool {
        self.k.iter().chain(&self.v).any(|l| l.is_lossy())
    }
}

/// Aggregate swap gauges/counters for metrics and reporting.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SwapStats {
    /// Configured arena-wide byte budget.
    pub budget_bytes: usize,
    /// Bytes currently parked across all tenants.
    pub used_bytes: usize,
    /// Live parked entries.
    pub entries: usize,
    /// Lanes serialized to host.
    pub swap_outs: u64,
    /// Lanes restored from host.
    pub swap_ins: u64,
    /// Swap-outs refused because one lane exceeded the whole budget (or
    /// swapping is disabled).
    pub refused: u64,
    /// Entries evicted (oldest-first) to make room for newer swap-outs;
    /// their owners recompute-resume.
    pub dropped: u64,
}

/// Byte-budgeted store of swapped lanes. Insertion evicts oldest-first
/// under pressure; lookups are O(1).
#[derive(Debug)]
pub struct SwapArena {
    budget: usize,
    used: usize,
    /// Per-tenant byte caps; tenants absent here get the arena-wide
    /// `budget`.
    tenant_budgets: BTreeMap<TenantId, usize>,
    /// Bytes currently parked per tenant.
    used_by: BTreeMap<TenantId, usize>,
    entries: HashMap<u64, SwapEntry>,
    /// Insertion order, oldest in front. May hold ids already consumed by
    /// a swap-in or an explicit drop — validated against `entries` when
    /// popped for eviction (same stale-marker discipline as the block
    /// allocator's evictable queue).
    order: VecDeque<u64>,
    next: u64,
    swap_outs: u64,
    swap_ins: u64,
    refused: u64,
    dropped: u64,
}

impl SwapArena {
    /// Arena with an overall byte budget (`0` disables swapping).
    pub fn new(budget_bytes: usize) -> Self {
        SwapArena {
            budget: budget_bytes,
            used: 0,
            tenant_budgets: BTreeMap::new(),
            used_by: BTreeMap::new(),
            entries: HashMap::new(),
            order: VecDeque::new(),
            next: 1,
            swap_outs: 0,
            swap_ins: 0,
            refused: 0,
            dropped: 0,
        }
    }

    /// Whether swapping is enabled at all.
    pub fn enabled(&self) -> bool {
        self.budget > 0
    }

    /// Cap the bytes `tenant` may park (clamped to the arena budget at
    /// check time; `0` disables swapping for this tenant only).
    pub fn set_tenant_budget(&mut self, tenant: TenantId, bytes: usize) {
        self.tenant_budgets.insert(tenant, bytes);
    }

    /// Effective byte cap for `tenant` (the arena budget unless
    /// overridden).
    pub fn tenant_cap(&self, tenant: TenantId) -> usize {
        self.tenant_budgets
            .get(&tenant)
            .copied()
            .unwrap_or(self.budget)
            .min(self.budget)
    }

    /// Bytes currently parked by `tenant`.
    pub fn tenant_used(&self, tenant: TenantId) -> usize {
        self.used_by.get(&tenant).copied().unwrap_or(0)
    }

    fn remove_entry(&mut self, id: u64) -> Option<SwapEntry> {
        let e = self.entries.remove(&id)?;
        self.used -= e.bytes;
        if let Some(u) = self.used_by.get_mut(&e.tenant) {
            *u = u.saturating_sub(e.bytes);
        }
        Some(e)
    }

    /// Pre-serialization gate: would an entry of `bytes` for `tenant` be
    /// refused outright (it alone exceeds the tenant's cap or the arena
    /// budget)? Counts the refusal, so callers can skip the O(lane)
    /// serialization entirely — a tenant pinned to `swap_bytes: Some(0)`
    /// would otherwise pay a full KV copy on every preemption just to be
    /// told no.
    pub fn would_refuse(&mut self, bytes: usize, tenant: TenantId) -> bool {
        if bytes > self.tenant_cap(tenant) {
            self.refused += 1;
            return true;
        }
        false
    }

    /// Oldest live entry belonging to `tenant`, if any (its order id is
    /// left in the queue as a stale marker, per the usual discipline).
    fn oldest_of(&self, tenant: TenantId) -> Option<u64> {
        self.order
            .iter()
            .copied()
            .find(|id| self.entries.get(id).is_some_and(|e| e.tenant == tenant))
    }

    /// Park a serialized lane, charging `entry.tenant`. Pressure ladder:
    /// the tenant's *own* oldest entries are dropped while it is over its
    /// per-tenant cap, then globally-oldest entries are dropped while the
    /// arena is over the overall budget. Refuses (`None`) only when the
    /// entry alone cannot fit its tenant's cap (or the arena budget) —
    /// the caller falls back to recompute-resume for that tenant and the
    /// lane is left untouched.
    pub fn insert(&mut self, entry: SwapEntry) -> Option<SwapHandle> {
        let cap = self.tenant_cap(entry.tenant);
        if entry.bytes > cap {
            self.refused += 1;
            return None;
        }
        // Per-tenant pressure: a bursty tenant cannibalizes itself only.
        // (Self-evicted ids stay in `order` as stale markers; unlike
        // global-pressure eviction they are not popped on the way out, so
        // prune here keeps the queue bounded under per-tenant churn.)
        let mut self_evicted = false;
        while self.tenant_used(entry.tenant) + entry.bytes > cap {
            let Some(old) = self.oldest_of(entry.tenant) else { break };
            self.remove_entry(old);
            self.dropped += 1;
            self_evicted = true;
        }
        if self_evicted {
            self.prune_order();
        }
        // Global pressure: oldest-first across tenants, as before.
        while self.used + entry.bytes > self.budget {
            let Some(old) = self.order.pop_front() else { break };
            if self.remove_entry(old).is_some() {
                self.dropped += 1;
            }
        }
        let id = self.next;
        self.next += 1;
        self.used += entry.bytes;
        *self.used_by.entry(entry.tenant).or_insert(0) += entry.bytes;
        self.entries.insert(id, entry);
        self.order.push_back(id);
        self.swap_outs += 1;
        Some(SwapHandle(id))
    }

    /// Whether the handle still refers to a live entry.
    pub fn contains(&self, h: SwapHandle) -> bool {
        self.entries.contains_key(&h.0)
    }

    /// Borrow an entry (admission-gate sizing).
    pub fn get(&self, h: SwapHandle) -> Option<&SwapEntry> {
        self.entries.get(&h.0)
    }

    /// Remove an entry for a restore attempt. If the attempt fails
    /// (pool shortfall), pair with [`SwapArena::put_back`] — the entry's
    /// order id stays in the queue across the round trip (so it keeps
    /// its eviction priority and `insert` can always reach it), which is
    /// why pruning happens only on *final* removals.
    pub fn take(&mut self, h: SwapHandle) -> Option<SwapEntry> {
        self.remove_entry(h.0)
    }

    /// Drop consumed ids from the order queue once stale ids dominate it
    /// — the same bounded-stale-markers discipline as the block
    /// allocator's evictable queue. Called on final removals only (a
    /// taken-but-put-back entry must keep its queue id), it bounds
    /// `order` at ~2x the live entry count plus a small floor no matter
    /// how many preempt/resume cycles a long-running server performs.
    fn prune_order(&mut self) {
        if self.order.len() > 2 * self.entries.len() + 8 {
            let entries = &self.entries;
            self.order.retain(|id| entries.contains_key(id));
        }
    }

    /// Undo a [`SwapArena::take`] after a failed restore. Never evicts:
    /// the bytes were part of the budget a moment ago. The handle's
    /// `order` entry is still in the queue (stale-marker discipline), so
    /// its eviction priority is preserved.
    pub fn put_back(&mut self, h: SwapHandle, entry: SwapEntry) {
        self.used += entry.bytes;
        *self.used_by.entry(entry.tenant).or_insert(0) += entry.bytes;
        self.entries.insert(h.0, entry);
    }

    /// Discard an entry (request finished, rejected, or restored).
    pub fn drop_entry(&mut self, h: SwapHandle) -> bool {
        match self.remove_entry(h.0) {
            Some(_) => {
                self.prune_order();
                true
            }
            None => false,
        }
    }

    /// Count a successful restore (the entry was consumed via `take` and
    /// will not come back — its order id is now prunable).
    pub fn note_swap_in(&mut self) {
        self.swap_ins += 1;
        self.prune_order();
    }

    /// Aggregate gauges/counters snapshot.
    pub fn stats(&self) -> SwapStats {
        SwapStats {
            budget_bytes: self.budget,
            used_bytes: self.used,
            entries: self.entries.len(),
            swap_outs: self.swap_outs,
            swap_ins: self.swap_ins,
            refused: self.refused,
            dropped: self.dropped,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry_for(bytes: usize, tenant: TenantId) -> SwapEntry {
        SwapEntry {
            lens: vec![bytes / 8, bytes / 8],
            k: vec![KvLane::F32(Vec::new()); 2],
            v: vec![KvLane::F32(Vec::new()); 2],
            hashes: vec![Vec::new(); 2],
            bytes,
            tenant,
        }
    }

    fn entry(bytes: usize) -> SwapEntry {
        entry_for(bytes, TenantId::DEFAULT)
    }

    #[test]
    fn insert_take_putback_roundtrip() {
        let mut a = SwapArena::new(100);
        let h = a.insert(entry(40)).unwrap();
        assert!(a.contains(h));
        assert_eq!(a.stats().used_bytes, 40);
        let e = a.take(h).unwrap();
        assert_eq!(a.stats().used_bytes, 0);
        assert!(!a.contains(h));
        a.put_back(h, e);
        assert!(a.contains(h));
        assert_eq!(a.stats().used_bytes, 40);
        assert!(a.drop_entry(h));
        assert!(!a.drop_entry(h), "double drop guarded");
    }

    #[test]
    fn over_budget_entry_is_refused() {
        let mut a = SwapArena::new(10);
        assert!(a.insert(entry(11)).is_none());
        assert_eq!(a.stats().refused, 1);
        assert_eq!(a.stats().used_bytes, 0);
        // zero budget disables swapping entirely
        let mut z = SwapArena::new(0);
        assert!(!z.enabled());
        assert!(z.insert(entry(1)).is_none());
    }

    #[test]
    fn pressure_drops_oldest_first() {
        let mut a = SwapArena::new(100);
        let h0 = a.insert(entry(40)).unwrap();
        let h1 = a.insert(entry(40)).unwrap();
        // 40 + 40 + 40 > 100: h0 (oldest) is dropped
        let h2 = a.insert(entry(40)).unwrap();
        assert!(!a.contains(h0), "oldest evicted");
        assert!(a.contains(h1) && a.contains(h2));
        let s = a.stats();
        assert_eq!((s.dropped, s.entries, s.used_bytes), (1, 2, 80));
        // consumed entries leave stale order ids that eviction skips
        let e1 = a.take(h1).unwrap();
        a.note_swap_in();
        drop(e1);
        let h3 = a.insert(entry(60)).unwrap(); // 40 + 60 > 100: drops h2
        assert!(!a.contains(h2));
        assert!(a.contains(h3));
        assert_eq!(a.stats().dropped, 2);
    }

    #[test]
    fn order_queue_bounded_across_many_roundtrips() {
        // Regression: every swap-out used to leave its id in `order`
        // forever once consumed — unbounded growth over a long-running
        // server's preempt/resume cycles. Final removals prune.
        let mut a = SwapArena::new(1000);
        for _ in 0..500 {
            let h = a.insert(entry(10)).unwrap();
            let e = a.take(h).unwrap();
            drop(e);
            a.note_swap_in();
        }
        assert!(
            a.order.len() <= 2 * a.entries.len() + 8,
            "order queue leaked: {} ids for {} entries",
            a.order.len(),
            a.entries.len()
        );
        for _ in 0..500 {
            let h = a.insert(entry(10)).unwrap();
            assert!(a.drop_entry(h));
        }
        assert!(a.order.len() <= 8, "drops must prune too");
        // a failed-restore round trip keeps the id: the entry must stay
        // reachable for pressure eviction afterwards
        let h = a.insert(entry(900)).unwrap();
        let e = a.take(h).unwrap();
        a.put_back(h, e);
        let h2 = a.insert(entry(900)).unwrap(); // over budget: evicts h
        assert!(!a.contains(h), "put-back entry still evictable");
        assert!(a.contains(h2));
    }

    #[test]
    fn per_tenant_budget_isolates_neighbours() {
        let t1 = TenantId(1);
        let t2 = TenantId(2);
        let mut a = SwapArena::new(100);
        a.set_tenant_budget(t1, 40);
        // a lane bigger than the tenant cap is refused outright, even
        // though the arena as a whole could take it
        assert!(a.insert(entry_for(50, t1)).is_none());
        assert_eq!(a.stats().refused, 1);
        // within the cap: fine, and charged to t1
        let h0 = a.insert(entry_for(30, t1)).unwrap();
        assert_eq!(a.tenant_used(t1), 30);
        // t1 over its own cap drops its OWN oldest entry, not t2's
        let h2 = a.insert(entry_for(50, t2)).unwrap();
        let h1 = a.insert(entry_for(30, t1)).unwrap();
        assert!(!a.contains(h0), "t1 self-evicted its oldest");
        assert!(a.contains(h2), "t2 untouched by t1's churn");
        assert!(a.contains(h1));
        assert_eq!(a.tenant_used(t1), 30);
        assert_eq!(a.tenant_used(t2), 50);
        assert_eq!(a.stats().dropped, 1);
        // uncapped tenants still fall under the arena-wide budget
        assert_eq!(a.tenant_cap(t2), 100);
        // take/put_back keep per-tenant accounting exact
        let e = a.take(h1).unwrap();
        assert_eq!(a.tenant_used(t1), 0);
        a.put_back(h1, e);
        assert_eq!(a.tenant_used(t1), 30);
        assert!(a.drop_entry(h1));
        assert_eq!(a.tenant_used(t1), 0);
    }

    #[test]
    fn order_queue_bounded_under_per_tenant_self_eviction_churn() {
        // Per-tenant eviction leaves stale order ids behind; the insert
        // path must prune them or a capped tenant churning swap-outs
        // grows the queue forever.
        let t1 = TenantId(1);
        let mut a = SwapArena::new(10_000);
        a.set_tenant_budget(t1, 25);
        for _ in 0..500 {
            // each insert (20 bytes) self-evicts the previous one
            let _ = a.insert(entry_for(20, t1)).unwrap();
        }
        assert!(
            a.order.len() <= 2 * a.entries.len() + 8,
            "order queue leaked under self-eviction: {} ids for {} entries",
            a.order.len(),
            a.entries.len()
        );
        assert_eq!(a.tenant_used(t1), 20);
        assert_eq!(a.stats().dropped, 499);
    }

    #[test]
    fn zero_tenant_budget_disables_swap_for_that_tenant_only() {
        let t1 = TenantId(1);
        let mut a = SwapArena::new(100);
        a.set_tenant_budget(t1, 0);
        assert!(a.insert(entry_for(10, t1)).is_none());
        assert_eq!(a.stats().refused, 1);
        assert!(a.insert(entry(10)).is_some(), "other tenants unaffected");
    }

    #[test]
    fn entry_block_math() {
        let e = SwapEntry {
            lens: vec![5, 0, 8],
            k: vec![KvLane::F32(Vec::new()); 3],
            v: vec![KvLane::F32(Vec::new()); 3],
            hashes: vec![Vec::new(); 3],
            bytes: 0,
            tenant: TenantId::DEFAULT,
        };
        assert_eq!(e.total_blocks(4), 2 + 0 + 2);
        assert_eq!(e.max_len(), 8);
        assert!(!e.is_lossy());
    }

    #[test]
    fn f16_codec_roundtrips_every_finite_half_exactly() {
        // Decode is exact for all 65536 bit patterns; every finite half
        // re-encodes to the same bits (so rounding can only move a value
        // by at most half an f16 step).
        for h in 0..=u16::MAX {
            let x = f16_to_f32(h);
            if x.is_nan() {
                assert_eq!(h & 0x7c00, 0x7c00, "NaN only from exp=31");
                continue;
            }
            if x.is_infinite() {
                continue; // saturating encode never reproduces inf
            }
            assert_eq!(f32_to_f16(x), h, "half {h:#06x} -> {x} -> re-encode");
        }
        // spot values
        assert_eq!(f16_to_f32(f32_to_f16(1.0)), 1.0);
        assert_eq!(f16_to_f32(f32_to_f16(-2.5)), -2.5);
        assert_eq!(f16_to_f32(f32_to_f16(65504.0)), 65504.0);
        assert_eq!(f16_to_f32(f32_to_f16(1.0e9)), 65504.0, "saturates");
        assert_eq!(f16_to_f32(f32_to_f16(-1.0e9)), -65504.0);
        assert_eq!(f16_to_f32(f32_to_f16(0.0)), 0.0);
        assert_eq!(f16_to_f32(f32_to_f16(1.0e-12)), 0.0, "underflows");
    }

    #[test]
    fn f16_codec_relative_error_bounded() {
        let mut x = 1.37e-6f32;
        while x < 6.0e4 {
            for v in [x, -x] {
                let y = f16_to_f32(f32_to_f16(v));
                let tol = v.abs() * (2.0f32).powi(-11) + (2.0f32).powi(-25);
                assert!(
                    (y - v).abs() <= tol,
                    "{v} -> {y}, err {} > tol {tol}",
                    (y - v).abs()
                );
            }
            x *= 1.0937; // dense sweep across binades
        }
    }

    #[test]
    fn lane_codec_encodes_and_reports_bytes() {
        let rows: Vec<f32> = vec![0.5, -1.25, 3.0, 10000.0];
        let full = KvLane::encode(rows.clone(), KvCodec::F32, 4);
        assert!(!full.is_lossy());
        assert_eq!(full.payload_bytes(), 16);
        assert_eq!(full.as_f32().as_ref(), &rows[..]);
        let half = KvLane::encode(rows.clone(), KvCodec::F16, 4);
        assert!(half.is_lossy());
        assert_eq!(half.payload_bytes(), 8, "half the f32 size");
        assert_eq!(half.len_elems(), 4);
        for (a, b) in half.as_f32().iter().zip(&rows) {
            let tol = b.abs() * (2.0f32).powi(-11) + 1e-7;
            assert!((a - b).abs() <= tol, "{a} vs {b}");
        }
        // two rows of two elements under int8: one scale per row, bytes
        // match KvCodec::bytes_per_row exactly
        let q8 = KvLane::encode(rows.clone(), KvCodec::Int8PerRow, 2);
        assert!(q8.is_lossy());
        assert_eq!(q8.len_elems(), 4);
        assert_eq!(
            q8.payload_bytes(),
            2 * KvCodec::Int8PerRow.bytes_per_row(2)
        );
        let KvLane::Int8PerRow { ref scales, .. } = q8 else {
            panic!("int8 lane expected")
        };
        assert_eq!(scales.len(), 2);
        for (a, b) in q8.as_f32().iter().zip(&rows) {
            let scale = if b.abs() <= 1.25 { 1.25 } else { 10000.0 } / 127.0;
            assert!((a - b).abs() <= scale * 0.5 + 1e-5, "{a} vs {b}");
        }
    }

    /// Satellite pin: folding the PR 5 `swap_half` bool into `KvCodec`
    /// is a pure refactor — the f16 lane a given row vector encodes to is
    /// bit-identical to mapping `f32_to_f16` over it, which is exactly
    /// what `encode(rows, true)` did before the trait existed.
    #[test]
    fn f16_lane_refactor_is_bit_identical_to_the_elementwise_codec() {
        let rows: Vec<f32> =
            (0..64).map(|i| (i as f32 - 31.5) * 0.37 + 1e-4).collect();
        let lane = KvLane::encode(rows.clone(), KvCodec::F16, 8);
        let KvLane::F16(ref bits) = lane else { panic!("f16 expected") };
        let legacy: Vec<u16> = rows.iter().map(|&x| f32_to_f16(x)).collect();
        assert_eq!(bits, &legacy, "refactor changed the encoded bits");
    }
}
