//! KV-head sharding of the paged block slab across executors.
//!
//! The paged slab stores one token row as `[KV, hd]` f32. KV heads are
//! independent under attention (with GQA every query head attends only
//! within its own KV group), so the slab can be split head-wise into `S`
//! *shards*: shard `s` owns heads `[s * KV/S, (s+1) * KV/S)` of every
//! row, i.e. a per-shard slab of `[num_blocks, block_tokens, KV/S, hd]`.
//!
//! What is sharded and what deliberately is not:
//!
//!  * **sharded** — the K/V *planes* handed to executors: each shard has
//!    its own pinned device slab (`decode_slab_{k,v}:{store}s{s}` keys,
//!    store id in hex), its own mutation stamp ([`ShardedSlabs`]), and
//!    its own slice of the `decode_paged_shard_{B}x{C}s{S}` artifact's
//!    inputs/outputs;
//!  * **not sharded** — the block table, allocator, prefix cache, tenant
//!    quotas, swap arena, and compaction. All of those address whole
//!    blocks by id, never head ranges, so one shard-oblivious copy serves
//!    every shard (this is exactly why the block tables were made
//!    device-agnostic).
//!
//! The host keeps the canonical dense planes in [`super::block::BlockStore`]
//! (hashing, swap serialization, compaction gathers, and the staging
//! oracle all read whole rows); shard planes are *projections* of it,
//! materialized only when a shard's pinned device copy goes stale. On
//! real multi-device bindings each projection lives on its own device and
//! the per-shard stamps below decide which device re-uploads.

use crate::tensor::HostTensor;

/// How a store's K/V planes are partitioned across executors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    /// Number of shards `S` (1 = unsharded, today's single-executor path).
    pub shards: usize,
    /// KV heads per token row, across all shards.
    pub kv_heads: usize,
    /// Elements per head.
    pub head_dim: usize,
}

impl ShardSpec {
    /// Validated spec: `shards` must be positive and divide `kv_heads`
    /// evenly — KV-head parallelism has no way to split a head. The
    /// error is the user-facing config message.
    pub fn new(
        shards: usize,
        kv_heads: usize,
        head_dim: usize,
    ) -> Result<ShardSpec, String> {
        if shards == 0 {
            return Err("shard count must be at least 1".into());
        }
        if kv_heads == 0 || kv_heads % shards != 0 {
            return Err(format!(
                "shard count {shards} does not divide kv_heads {kv_heads}: \
                 KV-head-parallel sharding needs kv_heads % shards == 0 \
                 (valid counts here: {:?})",
                (1..=kv_heads).filter(|s| kv_heads % s == 0).collect::<Vec<_>>()
            ));
        }
        Ok(ShardSpec { shards, kv_heads, head_dim })
    }

    /// The unsharded spec (one slab, one executor — the legacy path).
    pub fn single(kv_heads: usize, head_dim: usize) -> ShardSpec {
        ShardSpec { shards: 1, kv_heads, head_dim }
    }

    /// KV heads each shard owns.
    pub fn kv_per_shard(&self) -> usize {
        self.kv_heads / self.shards
    }

    /// f32 elements of a full token row (`KV * hd`).
    pub fn row_elems(&self) -> usize {
        self.kv_heads * self.head_dim
    }

    /// f32 elements of one shard's slice of a token row (`KV/S * hd`).
    pub fn shard_row_elems(&self) -> usize {
        self.kv_per_shard() * self.head_dim
    }

    /// Element range shard `s` occupies inside a full row (heads are
    /// split contiguously, so a shard's slice of a row is contiguous).
    pub fn row_range(&self, shard: usize) -> std::ops::Range<usize> {
        debug_assert!(shard < self.shards, "shard out of range");
        let srw = self.shard_row_elems();
        shard * srw..(shard + 1) * srw
    }
}

/// Per-shard mutation stamps for a store's slab planes. The owning
/// `PagedArena` bumps *every* shard on ordinary mutations (admits,
/// appends, COW, compaction — a full row touches all heads) and exactly
/// one shard for head-local writes ([`super::PagedArena::mutate_shard_row`]),
/// so a pinned-slab cache re-uploads only the shards whose bytes changed.
#[derive(Debug)]
pub struct ShardedSlabs {
    spec: ShardSpec,
    versions: Vec<u32>,
}

impl ShardedSlabs {
    /// Stamps for `spec.shards` shards, all starting at 0.
    pub fn new(spec: ShardSpec) -> ShardedSlabs {
        ShardedSlabs { spec, versions: vec![0; spec.shards] }
    }

    /// The partitioning this store was built with.
    pub fn spec(&self) -> ShardSpec {
        self.spec
    }

    /// Bump every shard's stamp (a whole-row mutation).
    pub fn touch_all(&mut self) {
        for v in &mut self.versions {
            *v = v.wrapping_add(1);
        }
    }

    /// Bump one shard's stamp (a head-local mutation).
    pub fn touch_one(&mut self, shard: usize) {
        self.versions[shard] = self.versions[shard].wrapping_add(1);
    }

    /// Current stamp of one shard.
    pub fn version(&self, shard: usize) -> u32 {
        self.versions[shard]
    }

    /// All shard stamps, indexed by shard.
    pub fn versions(&self) -> &[u32] {
        &self.versions
    }
}

/// Strided projection of shard `s` out of a dense plane
/// (`[num_blocks, block_tokens, KV, hd]` row major) into the per-shard
/// artifact layout `[nb_pad, block_tokens, KV/S, hd]`, zero-padded to the
/// artifact's pool bucket `nb_pad >= num_blocks`. This is the per-shard
/// replacement for `DecodeView::slab_tensors` — 1/S of the copy, and only
/// for shards whose pinned device copy went stale.
pub fn project_plane(
    plane: &[f32],
    spec: ShardSpec,
    shard: usize,
    num_blocks: usize,
    block_tokens: usize,
    nb_pad: usize,
) -> HostTensor {
    assert!(
        nb_pad >= num_blocks,
        "artifact pool bucket {nb_pad} < live pool {num_blocks}"
    );
    let srw = spec.shard_row_elems();
    let mut out = HostTensor::zeros(vec![
        nb_pad,
        block_tokens,
        spec.kv_per_shard(),
        spec.head_dim,
    ]);
    project_plane_into(plane, spec, shard, num_blocks, block_tokens, &mut out.data[..nb_pad * block_tokens * srw]);
    out
}

/// [`project_plane`] into a caller-owned buffer of exactly
/// `nb_pad * block_tokens * shard_row_elems` f32 (scratch-buffer variant
/// for the zero-allocation decode hot loop). Rows past `num_blocks` are
/// zeroed.
pub fn project_plane_into(
    plane: &[f32],
    spec: ShardSpec,
    shard: usize,
    num_blocks: usize,
    block_tokens: usize,
    out: &mut [f32],
) {
    let re = spec.row_elems();
    let srw = spec.shard_row_elems();
    let range = spec.row_range(shard);
    let rows = num_blocks * block_tokens;
    debug_assert_eq!(plane.len(), rows * re, "dense plane size");
    assert!(out.len() >= rows * srw, "projection buffer too small");
    for row in 0..rows {
        let src = row * re + range.start;
        let dst = row * srw;
        out[dst..dst + srw].copy_from_slice(&plane[src..src + srw]);
    }
    out[rows * srw..].fill(0.0);
}

/// Reassemble `S` per-shard planes (artifact layout, possibly padded past
/// `num_blocks`) back into the dense `[num_blocks, block_tokens, KV, hd]`
/// layout. Differential-oracle helper: `reassemble(project(s) for s)` must
/// be bit-identical to the dense plane it came from.
pub fn reassemble_planes(
    spec: ShardSpec,
    shards: &[HostTensor],
    num_blocks: usize,
    block_tokens: usize,
) -> Vec<f32> {
    assert_eq!(shards.len(), spec.shards, "one plane per shard");
    let re = spec.row_elems();
    let srw = spec.shard_row_elems();
    let rows = num_blocks * block_tokens;
    let mut out = vec![0.0f32; rows * re];
    for (s, plane) in shards.iter().enumerate() {
        assert!(
            plane.data.len() >= rows * srw,
            "shard {s} plane smaller than the live pool"
        );
        let range = spec.row_range(s);
        for row in 0..rows {
            let dst = row * re + range.start;
            let src = row * srw;
            out[dst..dst + srw].copy_from_slice(&plane.data[src..src + srw]);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_validates_divisibility() {
        assert!(ShardSpec::new(0, 4, 2).is_err());
        let e = ShardSpec::new(3, 4, 2).unwrap_err();
        assert!(e.contains("does not divide"), "{e}");
        assert!(e.contains("kv_heads 4"), "{e}");
        let s = ShardSpec::new(2, 4, 3).unwrap();
        assert_eq!(s.kv_per_shard(), 2);
        assert_eq!(s.row_elems(), 12);
        assert_eq!(s.shard_row_elems(), 6);
        assert_eq!(s.row_range(1), 6..12);
        assert_eq!(ShardSpec::single(4, 3).shards, 1);
    }

    #[test]
    fn stamps_track_whole_row_and_head_local_mutations() {
        let mut s = ShardedSlabs::new(ShardSpec::new(4, 4, 2).unwrap());
        assert_eq!(s.versions(), &[0, 0, 0, 0]);
        s.touch_all();
        assert_eq!(s.versions(), &[1, 1, 1, 1]);
        s.touch_one(2);
        assert_eq!(s.versions(), &[1, 1, 2, 1]);
        assert_eq!(s.version(2), 2);
        assert_eq!(s.spec().shards, 4);
    }

    #[test]
    fn project_and_reassemble_roundtrip_bit_identically() {
        let spec = ShardSpec::new(2, 4, 2).unwrap();
        let (nb, bt) = (3, 2);
        let re = spec.row_elems();
        let plane: Vec<f32> =
            (0..nb * bt * re).map(|i| i as f32 * 0.25).collect();
        let shards: Vec<HostTensor> = (0..spec.shards)
            .map(|s| project_plane(&plane, spec, s, nb, bt, nb + 2))
            .collect();
        // shard 1 of row 0 = elems [4, 8) of the dense row
        assert_eq!(shards[1].shape, vec![nb + 2, bt, 2, 2]);
        assert_eq!(&shards[1].data[..4], &plane[4..8]);
        // padded tail blocks are zero
        let srw = spec.shard_row_elems();
        assert!(shards[0].data[nb * bt * srw..].iter().all(|&x| x == 0.0));
        let back = reassemble_planes(spec, &shards, nb, bt);
        assert_eq!(back, plane);
    }

    #[test]
    fn single_shard_projection_is_the_whole_plane() {
        let spec = ShardSpec::single(2, 3);
        let (nb, bt) = (2, 2);
        let plane: Vec<f32> =
            (0..nb * bt * spec.row_elems()).map(|i| i as f32).collect();
        let p = project_plane(&plane, spec, 0, nb, bt, nb);
        assert_eq!(p.data, plane, "S=1 projection is bit-identical");
    }
}
