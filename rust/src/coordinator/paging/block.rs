//! Physical block storage for the paged KV cache.
//!
//! A *block* holds `block_tokens` consecutive token rows of K and V data
//! (each row is `kv_heads * head_dim` elements). Blocks carry no layer or
//! sequence identity of their own — that mapping lives in the per-sequence
//! block tables owned by `PagedArena` — so any block can serve any
//! (sequence, layer) position, which is what makes prefix sharing and
//! copy-on-write possible.
//!
//! **In-slab quantization.** The slab's element encoding is a
//! [`KvCodec`]: verbatim f32 (the default — bit-identical to the
//! pre-codec store), IEEE 754 binary16, or int8 with one f32 scale per
//! token row per plane (`scale = max|row| / 127`). Rows are encoded at
//! write time and decoded at read time; `copy_rows`/`zero_block` operate
//! on the *encoded* representation (exact, no drift), and the per-row
//! scale layout keeps every operation shard-oblivious — a head-range
//! patch via [`BlockStore::write_row_range`] reuses the row's scale when
//! the patch fits it, so untouched elements keep their stored bits, and
//! only rescales (a whole-row requantization) when the patch grows the
//! row's magnitude. The API stays f32 at the surface: reads return
//! `Cow<[f32]>` (borrowed under f32, decoded-to-owned otherwise).

use std::borrow::Cow;
use std::sync::atomic::{AtomicU64, Ordering};

use super::codec::{self, KvCodec};
use super::tenant::TenantId;

/// Index of a physical block in the pool slab.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BlockId(
    /// Position in the slab, `0..num_blocks`.
    pub u32,
);

impl BlockId {
    /// The block's position as a slab/`meta` index.
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// Per-block bookkeeping kept by the allocator.
#[derive(Debug, Clone, Default)]
pub struct BlockMeta {
    /// Number of block-table entries pointing at this block. 0 means the
    /// block is on the free list or parked in the evictable (prefix-cache)
    /// queue.
    pub ref_count: u32,
    /// Valid rows in `[0, block_tokens]`.
    pub filled: u32,
    /// Chained content hash once the block is full, immutable, and
    /// registered in the prefix cache. `None` for mutable tail blocks and
    /// decode-written blocks.
    pub hash: Option<u64>,
    /// True while an entry for this block sits in the allocator's
    /// evictable queue (possibly stale after a revive). Guarantees at most
    /// one queue entry per block, bounding the queue at pool size.
    pub parked: bool,
    /// Tenant charged for this block (first-toucher rule): whoever
    /// allocated or revived it into its current live period. Meaningful
    /// only while `ref_count > 0`; quota accounting in
    /// `BlockAllocator` charges and uncharges through it.
    pub owner: TenantId,
    /// Accumulated attention-mass proxy for decode-written rows: the sum
    /// of mean-|K| over every row appended into this block during its
    /// current live period. A cheap per-block salience heuristic — the
    /// decode-phase coarse eviction stage releases the lowest-scoring
    /// cold blocks first (see `PagedArena::enforce_decode_budget`).
    pub score: f32,
    /// Write-recency stamp: the owning arena's mutation counter at the
    /// last decode-row write into this block. 0 for blocks never written
    /// by decode (admission-filled blocks are budget-protected anyway).
    /// Ties in `score` break toward evicting the oldest stamp.
    pub last_write: u64,
}

impl BlockMeta {
    /// Mean `score` per valid row — the comparable salience number when
    /// blocks hold different numbers of rows.
    pub fn row_score(&self) -> f32 {
        if self.filled == 0 {
            0.0
        } else {
            self.score / self.filled as f32
        }
    }
}

/// The int8 planes of a quantized slab, borrowed raw for device upload:
/// quantized values (`[num_blocks, block_tokens, row_elems]` i8, same
/// row-major layout as the f32 planes) plus one f32 scale per token row
/// per plane (`[num_blocks, block_tokens]`). The `decode_paged_q8_*`
/// artifacts take these and dequantize in-HLO.
#[derive(Debug, Clone, Copy)]
pub struct Q8Planes<'a> {
    /// Quantized K plane.
    pub k_q: &'a [i8],
    /// Per-row K scales.
    pub k_scales: &'a [f32],
    /// Quantized V plane.
    pub v_q: &'a [i8],
    /// Per-row V scales.
    pub v_scales: &'a [f32],
}

/// One K or V plane under the slab codec. Scales (int8 only) are indexed
/// by global row `block * block_tokens + row`.
#[derive(Debug)]
enum Plane {
    F32(Vec<f32>),
    F16(Vec<u16>),
    Int8 { q: Vec<i8>, scales: Vec<f32> },
}

impl Plane {
    fn new(codec: KvCodec, rows: usize, row_elems: usize) -> Plane {
        let elems = rows * row_elems;
        match codec {
            KvCodec::F32 => Plane::F32(vec![0.0; elems]),
            KvCodec::F16 => Plane::F16(vec![0; elems]),
            KvCodec::Int8PerRow => Plane::Int8 {
                q: vec![0; elems],
                scales: vec![0.0; rows],
            },
        }
    }

    /// Decode `re` elements starting at element `base` (row `ri`) into
    /// `out`. `range` is the element sub-range of the row (full row:
    /// `0..re`).
    fn decode_range_into(
        &self,
        base: usize,
        ri: usize,
        range: std::ops::Range<usize>,
        out: &mut [f32],
    ) {
        let (s, e) = (base + range.start, base + range.end);
        match self {
            Plane::F32(p) => out.copy_from_slice(&p[s..e]),
            Plane::F16(p) => {
                for (o, &h) in out.iter_mut().zip(&p[s..e]) {
                    *o = codec::f16_to_f32(h);
                }
            }
            Plane::Int8 { q, scales } => {
                codec::dequantize_row_int8(&q[s..e], scales[ri], out);
            }
        }
    }

    /// Encode one full row (`re` elements at element `base`, row `ri`).
    fn encode_row(&mut self, base: usize, ri: usize, re: usize, row: &[f32]) {
        match self {
            Plane::F32(p) => p[base..base + re].copy_from_slice(row),
            Plane::F16(p) => {
                for (h, &x) in p[base..base + re].iter_mut().zip(row) {
                    *h = codec::f32_to_f16(x);
                }
            }
            Plane::Int8 { q, scales } => {
                scales[ri] =
                    codec::quantize_row_int8(row, &mut q[base..base + re]);
            }
        }
    }

    /// Patch a sub-range of a row. Lossless/f16 planes re-encode just the
    /// patch; int8 keeps the row's scale when the patch fits it (so the
    /// untouched elements' stored bits never move) and requantizes the
    /// whole row only when the patch grows the row's magnitude.
    fn patch_row(
        &mut self,
        base: usize,
        ri: usize,
        re: usize,
        range: std::ops::Range<usize>,
        sub: &[f32],
    ) {
        let (s, e) = (base + range.start, base + range.end);
        match self {
            Plane::F32(p) => p[s..e].copy_from_slice(sub),
            Plane::F16(p) => {
                for (h, &x) in p[s..e].iter_mut().zip(sub) {
                    *h = codec::f32_to_f16(x);
                }
            }
            Plane::Int8 { q, scales } => {
                let scale = scales[ri];
                let submax = sub.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
                if submax <= scale * 127.0 {
                    codec::quantize_row_int8_with(sub, &mut q[s..e], scale);
                } else {
                    let mut full = vec![0.0f32; re];
                    codec::dequantize_row_int8(
                        &q[base..base + re],
                        scale,
                        &mut full,
                    );
                    full[range].copy_from_slice(sub);
                    scales[ri] = codec::quantize_row_int8(
                        &full,
                        &mut q[base..base + re],
                    );
                }
            }
        }
    }

    /// Copy `rows` encoded rows (plus scales) from `src_row` to `dst_row`
    /// (global row indices; ranges never overlap — distinct blocks).
    fn copy_rows(&mut self, src_row: usize, dst_row: usize, rows: usize, re: usize) {
        let (s, d, n) = (src_row * re, dst_row * re, rows * re);
        match self {
            Plane::F32(p) => p.copy_within(s..s + n, d),
            Plane::F16(p) => p.copy_within(s..s + n, d),
            Plane::Int8 { q, scales } => {
                q.copy_within(s..s + n, d);
                scales.copy_within(src_row..src_row + rows, dst_row);
            }
        }
    }

    fn zero_rows(&mut self, row0: usize, rows: usize, re: usize) {
        let (s, n) = (row0 * re, rows * re);
        match self {
            Plane::F32(p) => p[s..s + n].fill(0.0),
            Plane::F16(p) => p[s..s + n].fill(0),
            Plane::Int8 { q, scales } => {
                q[s..s + n].fill(0);
                scales[row0..row0 + rows].fill(0.0);
            }
        }
    }
}

/// Contiguous slab of `num_blocks` fixed-size blocks (K and V planes),
/// stored under a [`KvCodec`].
#[derive(Debug)]
pub struct BlockStore {
    block_tokens: usize,
    row_elems: usize,
    num_blocks: usize,
    codec: KvCodec,
    k: Plane,
    v: Plane,
    /// Rows encoded through a lossy codec (write-side; `PoolStats`).
    quant_rows: u64,
    /// Rows decoded from a lossy codec. Atomic: reads are `&self`.
    dequant_rows: AtomicU64,
    /// Nanoseconds spent in *bulk* codec conversions (whole-plane
    /// dequantization at slab materialization). Per-row conversions ride
    /// along untimed — they are smaller than the timer call itself.
    codec_nanos: AtomicU64,
}

impl BlockStore {
    /// Zero-initialized f32 slab (the lossless default).
    pub fn new(num_blocks: usize, block_tokens: usize, row_elems: usize) -> Self {
        Self::with_codec(num_blocks, block_tokens, row_elems, KvCodec::F32)
    }

    /// Zero-initialized slab of `num_blocks` blocks, each holding
    /// `block_tokens` rows of `row_elems` elements per K/V plane, encoded
    /// under `codec`.
    pub fn with_codec(
        num_blocks: usize,
        block_tokens: usize,
        row_elems: usize,
        codec: KvCodec,
    ) -> Self {
        assert!(block_tokens > 0, "block_tokens must be positive");
        assert!(row_elems > 0, "row_elems must be positive");
        let rows = num_blocks * block_tokens;
        BlockStore {
            block_tokens,
            row_elems,
            num_blocks,
            codec,
            k: Plane::new(codec, rows, row_elems),
            v: Plane::new(codec, rows, row_elems),
            quant_rows: 0,
            dequant_rows: AtomicU64::new(0),
            codec_nanos: AtomicU64::new(0),
        }
    }

    /// Blocks in the slab.
    pub fn num_blocks(&self) -> usize {
        self.num_blocks
    }

    /// Token rows per block.
    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Elements per token row (`kv_heads * head_dim`).
    pub fn row_elems(&self) -> usize {
        self.row_elems
    }

    /// The slab's element codec.
    pub fn codec(&self) -> KvCodec {
        self.codec
    }

    /// Total logical f32 elements held (K + V planes), codec-independent.
    pub fn total_elems(&self) -> usize {
        2 * self.num_blocks * self.block_tokens * self.row_elems
    }

    /// Host bytes the slab occupies under its codec (K + V planes, scale
    /// planes included) — the `pool_bytes_quantized` gauge. Routes
    /// through [`KvCodec::bytes_per_row`] like every other byte account.
    pub fn slab_bytes(&self) -> usize {
        2 * self.num_blocks
            * self.block_tokens
            * self.codec.bytes_per_row(self.row_elems)
    }

    /// The whole K plane as f32 (`[num_blocks, block_tokens, row_elems]`
    /// row major) — `Some` only under the f32 codec, where `DecodeView`
    /// borrows the slab in place instead of densifying it.
    pub fn k_plane_f32(&self) -> Option<&[f32]> {
        match &self.k {
            Plane::F32(p) => Some(p),
            _ => None,
        }
    }

    /// The whole V plane as f32 (layout mirrors
    /// [`BlockStore::k_plane_f32`]).
    pub fn v_plane_f32(&self) -> Option<&[f32]> {
        match &self.v {
            Plane::F32(p) => Some(p),
            _ => None,
        }
    }

    /// Raw int8 planes + per-row scale planes for device upload — `Some`
    /// only under [`KvCodec::Int8PerRow`].
    pub fn q8_planes(&self) -> Option<Q8Planes<'_>> {
        match (&self.k, &self.v) {
            (
                Plane::Int8 { q: kq, scales: ks },
                Plane::Int8 { q: vq, scales: vs },
            ) => Some(Q8Planes {
                k_q: kq,
                k_scales: ks,
                v_q: vq,
                v_scales: vs,
            }),
            _ => None,
        }
    }

    /// Dequantize the whole K plane into the prefix of `out`
    /// (`out.len() >= num_blocks * block_tokens * row_elems`): the
    /// host-side dequant fallback that keeps the dense/staged oracle path
    /// (and non-q8 artifacts over a quantized store) working.
    pub fn decode_k_plane_into(&self, out: &mut [f32]) {
        self.decode_plane_into(false, out);
    }

    /// Dequantize the whole V plane into the prefix of `out`.
    pub fn decode_v_plane_into(&self, out: &mut [f32]) {
        self.decode_plane_into(true, out);
    }

    fn decode_plane_into(&self, v: bool, out: &mut [f32]) {
        let re = self.row_elems;
        let rows = self.num_blocks * self.block_tokens;
        assert!(out.len() >= rows * re, "plane decode target too small");
        let plane = if v { &self.v } else { &self.k };
        if let Plane::F32(p) = plane {
            out[..rows * re].copy_from_slice(p);
            return;
        }
        let t0 = std::time::Instant::now();
        for ri in 0..rows {
            plane.decode_range_into(
                ri * re,
                ri,
                0..re,
                &mut out[ri * re..ri * re + re],
            );
        }
        self.dequant_rows.fetch_add(rows as u64, Ordering::Relaxed);
        self.codec_nanos
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }

    fn base(&self, block: BlockId, row: usize) -> usize {
        debug_assert!(block.index() < self.num_blocks, "block out of range");
        debug_assert!(row < self.block_tokens, "row out of range");
        (block.index() * self.block_tokens + row) * self.row_elems
    }

    fn row_index(&self, block: BlockId, row: usize) -> usize {
        block.index() * self.block_tokens + row
    }

    /// Write one token row of K and V into a block (encoding under the
    /// slab codec; int8 derives the row's scale here).
    pub fn write_row(&mut self, block: BlockId, row: usize, k_row: &[f32], v_row: &[f32]) {
        let re = self.row_elems;
        assert_eq!(k_row.len(), re, "k row width");
        assert_eq!(v_row.len(), re, "v row width");
        let base = self.base(block, row);
        let ri = self.row_index(block, row);
        self.k.encode_row(base, ri, re, k_row);
        self.v.encode_row(base, ri, re, v_row);
        if !self.codec.is_lossless() {
            self.quant_rows += 2;
        }
    }

    /// Overwrite one contiguous element sub-range of a token row on both
    /// planes (a KV-head shard's slice — see `super::shard::ShardSpec::
    /// row_range`). The head-local counterpart of [`BlockStore::write_row`];
    /// callers own the per-shard staleness bookkeeping. Under int8 the
    /// row's scale is kept when the patch fits it (untouched elements'
    /// stored bits are unchanged); a patch that grows the row's magnitude
    /// requantizes the whole row — see `PagedArena::mutate_shard_row` for
    /// why lossy codecs then mark *all* shards stale.
    pub fn write_row_range(
        &mut self,
        block: BlockId,
        row: usize,
        range: std::ops::Range<usize>,
        k_sub: &[f32],
        v_sub: &[f32],
    ) {
        assert!(range.end <= self.row_elems, "sub-row past row width");
        assert_eq!(k_sub.len(), range.len(), "k sub-row width");
        assert_eq!(v_sub.len(), range.len(), "v sub-row width");
        let re = self.row_elems;
        let base = self.base(block, row);
        let ri = self.row_index(block, row);
        self.k.patch_row(base, ri, re, range.clone(), k_sub);
        self.v.patch_row(base, ri, re, range, v_sub);
        if !self.codec.is_lossless() {
            self.quant_rows += 2;
        }
    }

    /// One token row of the K plane (borrowed under f32, decoded
    /// otherwise).
    pub fn k_row(&self, block: BlockId, row: usize) -> Cow<'_, [f32]> {
        self.rows_cow(false, self.base(block, row), self.row_index(block, row), 1)
    }

    /// One token row of the V plane.
    pub fn v_row(&self, block: BlockId, row: usize) -> Cow<'_, [f32]> {
        self.rows_cow(true, self.base(block, row), self.row_index(block, row), 1)
    }

    /// `rows` consecutive K rows starting at row 0 (hashing/gather
    /// helper).
    pub fn k_rows(&self, block: BlockId, rows: usize) -> Cow<'_, [f32]> {
        self.rows_cow(false, self.base(block, 0), self.row_index(block, 0), rows)
    }

    /// `rows` consecutive V rows starting at row 0.
    pub fn v_rows(&self, block: BlockId, rows: usize) -> Cow<'_, [f32]> {
        self.rows_cow(true, self.base(block, 0), self.row_index(block, 0), rows)
    }

    fn rows_cow(&self, v: bool, base: usize, ri0: usize, rows: usize) -> Cow<'_, [f32]> {
        let re = self.row_elems;
        let plane = if v { &self.v } else { &self.k };
        if let Plane::F32(p) = plane {
            return Cow::Borrowed(&p[base..base + rows * re]);
        }
        let mut out = vec![0.0f32; rows * re];
        for r in 0..rows {
            plane.decode_range_into(
                base + r * re,
                ri0 + r,
                0..re,
                &mut out[r * re..(r + 1) * re],
            );
        }
        self.dequant_rows.fetch_add(rows as u64, Ordering::Relaxed);
        Cow::Owned(out)
    }

    /// Copy the first `rows` rows of `src` into `dst` (copy-on-write).
    /// Operates on the *encoded* representation (scales included), so the
    /// copy is exact under every codec. `src` and `dst` are distinct
    /// blocks, so the ranges never overlap.
    pub fn copy_rows(&mut self, src: BlockId, dst: BlockId, rows: usize) {
        assert_ne!(src, dst, "copy_rows onto itself");
        let re = self.row_elems;
        let s = self.row_index(src, 0);
        let d = self.row_index(dst, 0);
        self.k.copy_rows(s, d, rows, re);
        self.v.copy_rows(s, d, rows, re);
    }

    /// Zero a block's contents (hygiene when returning to the free list).
    pub fn zero_block(&mut self, block: BlockId) {
        let re = self.row_elems;
        let r0 = self.row_index(block, 0);
        self.k.zero_rows(r0, self.block_tokens, re);
        self.v.zero_rows(r0, self.block_tokens, re);
    }

    /// Rows encoded through a lossy codec since construction.
    pub fn quant_rows(&self) -> u64 {
        self.quant_rows
    }

    /// Rows decoded from a lossy codec since construction.
    pub fn dequant_rows(&self) -> u64 {
        self.dequant_rows.load(Ordering::Relaxed)
    }

    /// Seconds spent in bulk codec conversions (whole-plane
    /// dequantization for slab materialization / the staged oracle).
    pub fn codec_secs(&self) -> f64 {
        self.codec_nanos.load(Ordering::Relaxed) as f64 / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_roundtrip() {
        let mut s = BlockStore::new(4, 2, 3);
        s.write_row(BlockId(1), 0, &[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]);
        s.write_row(BlockId(1), 1, &[7.0, 8.0, 9.0], &[10.0, 11.0, 12.0]);
        assert_eq!(&s.k_row(BlockId(1), 0)[..], &[1.0, 2.0, 3.0]);
        assert_eq!(&s.v_row(BlockId(1), 1)[..], &[10.0, 11.0, 12.0]);
        assert_eq!(
            &s.k_rows(BlockId(1), 2)[..],
            &[1.0, 2.0, 3.0, 7.0, 8.0, 9.0]
        );
        // neighbours untouched
        assert!(s.k_row(BlockId(0), 0).iter().all(|&x| x == 0.0));
        assert!(s.k_row(BlockId(2), 0).iter().all(|&x| x == 0.0));
        // f32 is the zero-copy path and loses nothing
        assert_eq!(s.codec(), KvCodec::F32);
        assert_eq!(s.quant_rows(), 0);
        assert!(s.k_plane_f32().is_some() && s.q8_planes().is_none());
    }

    #[test]
    fn write_row_range_touches_only_the_slice() {
        let mut s = BlockStore::new(2, 2, 4);
        s.write_row(BlockId(0), 1, &[1.0; 4], &[2.0; 4]);
        s.write_row_range(BlockId(0), 1, 2..4, &[8.0, 9.0], &[-8.0, -9.0]);
        assert_eq!(&s.k_row(BlockId(0), 1)[..], &[1.0, 1.0, 8.0, 9.0]);
        assert_eq!(&s.v_row(BlockId(0), 1)[..], &[2.0, 2.0, -8.0, -9.0]);
    }

    #[test]
    fn copy_and_zero() {
        let mut s = BlockStore::new(3, 2, 2);
        s.write_row(BlockId(0), 0, &[1.0, 1.0], &[2.0, 2.0]);
        s.write_row(BlockId(0), 1, &[3.0, 3.0], &[4.0, 4.0]);
        s.copy_rows(BlockId(0), BlockId(2), 2);
        assert_eq!(&s.k_row(BlockId(2), 1)[..], &[3.0, 3.0]);
        assert_eq!(&s.v_row(BlockId(2), 0)[..], &[2.0, 2.0]);
        s.zero_block(BlockId(0));
        assert!(s.k_rows(BlockId(0), 2).iter().all(|&x| x == 0.0));
        // the copy survives zeroing the source
        assert_eq!(&s.k_row(BlockId(2), 1)[..], &[3.0, 3.0]);
    }

    #[test]
    fn int8_store_roundtrips_within_half_scale() {
        let mut s = BlockStore::with_codec(2, 2, 4, KvCodec::Int8PerRow);
        let k = [1.0f32, -2.5, 0.25, 4.0];
        let v = [-0.5f32, 0.5, 3.0, -3.0];
        s.write_row(BlockId(1), 0, &k, &v);
        let ks = 4.0 / 127.0; // k row scale = max|k| / 127
        let vs = 3.0 / 127.0;
        for (got, want, sc) in [
            (s.k_row(BlockId(1), 0), &k[..], ks),
            (s.v_row(BlockId(1), 0), &v[..], vs),
        ] {
            for (a, b) in got.iter().zip(want) {
                assert!((a - b).abs() <= sc * 0.5 + f32::EPSILON);
            }
        }
        assert_eq!(s.quant_rows(), 2);
        assert!(s.dequant_rows() >= 2);
        assert!(s.k_plane_f32().is_none());
        let q8 = s.q8_planes().expect("int8 planes");
        assert_eq!(q8.k_scales.len(), 2 * 2); // one scale per row
        assert!((q8.k_scales[2] - ks).abs() <= f32::EPSILON);
    }

    #[test]
    fn int8_patch_within_scale_keeps_untouched_bits() {
        let mut s = BlockStore::with_codec(1, 1, 4, KvCodec::Int8PerRow);
        s.write_row(BlockId(0), 0, &[4.0, -2.0, 1.0, 0.5], &[1.0; 4]);
        let before_q = s.q8_planes().unwrap().k_q.to_vec();
        let before_scale = s.q8_planes().unwrap().k_scales[0];
        // patch fits the current scale (|3.0| <= 4.0): scale kept,
        // elements outside the patch keep their exact stored bits
        s.write_row_range(BlockId(0), 0, 1..3, &[3.0, -1.5], &[1.0, 1.0]);
        let q8 = s.q8_planes().unwrap();
        assert_eq!(q8.k_scales[0], before_scale);
        assert_eq!(q8.k_q[0], before_q[0]);
        assert_eq!(q8.k_q[3], before_q[3]);
        // patch that grows the row magnitude rescales the whole row
        s.write_row_range(BlockId(0), 0, 1..3, &[9.0, 0.0], &[1.0, 1.0]);
        let q8 = s.q8_planes().unwrap();
        assert!((q8.k_scales[0] - 9.0 / 127.0).abs() <= f32::EPSILON);
        let row = s.k_row(BlockId(0), 0);
        assert!((row[0] - 4.0).abs() <= (9.0 / 127.0) * 0.5 + 1e-6);
        assert!((row[1] - 9.0).abs() <= (9.0 / 127.0) * 0.5 + 1e-6);
    }

    #[test]
    fn slab_bytes_tracks_the_codec() {
        for (codec, per_row) in [
            (KvCodec::F32, 4 * 4usize),
            (KvCodec::F16, 4 * 2),
            (KvCodec::Int8PerRow, 4 + 4),
        ] {
            let s = BlockStore::with_codec(3, 2, 4, codec);
            assert_eq!(s.slab_bytes(), 2 * 3 * 2 * per_row);
            assert_eq!(s.total_elems(), 2 * 3 * 2 * 4);
        }
    }

    #[test]
    fn f16_store_decodes_whole_planes() {
        let mut s = BlockStore::with_codec(2, 1, 2, KvCodec::F16);
        s.write_row(BlockId(0), 0, &[1.5, -0.25], &[2.0, 0.0]);
        let mut out = vec![0.0f32; 4];
        s.decode_k_plane_into(&mut out);
        assert_eq!(&out[..2], &[1.5, -0.25]); // exactly f16-representable
        s.decode_v_plane_into(&mut out);
        assert_eq!(&out[..2], &[2.0, 0.0]);
        assert!(s.codec_secs() >= 0.0);
    }
}
