//! Physical block storage for the paged KV cache.
//!
//! A *block* holds `block_tokens` consecutive token rows of K and V data
//! (each row is `kv_heads * head_dim` f32). Blocks carry no layer or
//! sequence identity of their own — that mapping lives in the per-sequence
//! block tables owned by `PagedArena` — so any block can serve any
//! (sequence, layer) position, which is what makes prefix sharing and
//! copy-on-write possible.

use super::tenant::TenantId;

/// Index of a physical block in the pool slab.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BlockId(
    /// Position in the slab, `0..num_blocks`.
    pub u32,
);

impl BlockId {
    /// The block's position as a slab/`meta` index.
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// Per-block bookkeeping kept by the allocator.
#[derive(Debug, Clone, Default)]
pub struct BlockMeta {
    /// Number of block-table entries pointing at this block. 0 means the
    /// block is on the free list or parked in the evictable (prefix-cache)
    /// queue.
    pub ref_count: u32,
    /// Valid rows in `[0, block_tokens]`.
    pub filled: u32,
    /// Chained content hash once the block is full, immutable, and
    /// registered in the prefix cache. `None` for mutable tail blocks and
    /// decode-written blocks.
    pub hash: Option<u64>,
    /// True while an entry for this block sits in the allocator's
    /// evictable queue (possibly stale after a revive). Guarantees at most
    /// one queue entry per block, bounding the queue at pool size.
    pub parked: bool,
    /// Tenant charged for this block (first-toucher rule): whoever
    /// allocated or revived it into its current live period. Meaningful
    /// only while `ref_count > 0`; quota accounting in
    /// `BlockAllocator` charges and uncharges through it.
    pub owner: TenantId,
}

/// Contiguous slab of `num_blocks` fixed-size blocks (K and V planes).
#[derive(Debug)]
pub struct BlockStore {
    block_tokens: usize,
    row_elems: usize,
    num_blocks: usize,
    k: Vec<f32>,
    v: Vec<f32>,
}

impl BlockStore {
    /// Zero-initialized slab of `num_blocks` blocks, each holding
    /// `block_tokens` rows of `row_elems` f32 per K/V plane.
    pub fn new(num_blocks: usize, block_tokens: usize, row_elems: usize) -> Self {
        assert!(block_tokens > 0, "block_tokens must be positive");
        assert!(row_elems > 0, "row_elems must be positive");
        let elems = num_blocks * block_tokens * row_elems;
        BlockStore {
            block_tokens,
            row_elems,
            num_blocks,
            k: vec![0.0; elems],
            v: vec![0.0; elems],
        }
    }

    /// Blocks in the slab.
    pub fn num_blocks(&self) -> usize {
        self.num_blocks
    }

    /// Token rows per block.
    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// f32 elements per token row (`kv_heads * head_dim`).
    pub fn row_elems(&self) -> usize {
        self.row_elems
    }

    /// Total f32 elements held (K + V planes), for memory reporting.
    pub fn total_elems(&self) -> usize {
        self.k.len() + self.v.len()
    }

    /// The whole K plane (`[num_blocks, block_tokens, row_elems]` row
    /// major) — borrowed by `DecodeView` so block-table decode reads the
    /// slab in place instead of densifying it.
    pub fn k_plane(&self) -> &[f32] {
        &self.k
    }

    /// The whole V plane (layout mirrors [`BlockStore::k_plane`]).
    pub fn v_plane(&self) -> &[f32] {
        &self.v
    }

    fn base(&self, block: BlockId, row: usize) -> usize {
        debug_assert!(block.index() < self.num_blocks, "block out of range");
        debug_assert!(row < self.block_tokens, "row out of range");
        (block.index() * self.block_tokens + row) * self.row_elems
    }

    /// Write one token row of K and V into a block.
    pub fn write_row(&mut self, block: BlockId, row: usize, k_row: &[f32], v_row: &[f32]) {
        let re = self.row_elems;
        assert_eq!(k_row.len(), re, "k row width");
        assert_eq!(v_row.len(), re, "v row width");
        let base = self.base(block, row);
        self.k[base..base + re].copy_from_slice(k_row);
        self.v[base..base + re].copy_from_slice(v_row);
    }

    /// Overwrite one contiguous element sub-range of a token row on both
    /// planes (a KV-head shard's slice — see `super::shard::ShardSpec::
    /// row_range`). The head-local counterpart of [`BlockStore::write_row`];
    /// callers own the per-shard staleness bookkeeping.
    pub fn write_row_range(
        &mut self,
        block: BlockId,
        row: usize,
        range: std::ops::Range<usize>,
        k_sub: &[f32],
        v_sub: &[f32],
    ) {
        assert!(range.end <= self.row_elems, "sub-row past row width");
        assert_eq!(k_sub.len(), range.len(), "k sub-row width");
        assert_eq!(v_sub.len(), range.len(), "v sub-row width");
        let base = self.base(block, row);
        self.k[base + range.start..base + range.end].copy_from_slice(k_sub);
        self.v[base + range.start..base + range.end].copy_from_slice(v_sub);
    }

    /// One token row of the K plane.
    pub fn k_row(&self, block: BlockId, row: usize) -> &[f32] {
        let base = self.base(block, row);
        &self.k[base..base + self.row_elems]
    }

    /// One token row of the V plane.
    pub fn v_row(&self, block: BlockId, row: usize) -> &[f32] {
        let base = self.base(block, row);
        &self.v[base..base + self.row_elems]
    }

    /// Borrow `rows` consecutive K rows starting at row 0 (hashing helper).
    pub fn k_rows(&self, block: BlockId, rows: usize) -> &[f32] {
        let base = self.base(block, 0);
        &self.k[base..base + rows * self.row_elems]
    }

    /// Borrow `rows` consecutive V rows starting at row 0.
    pub fn v_rows(&self, block: BlockId, rows: usize) -> &[f32] {
        let base = self.base(block, 0);
        &self.v[base..base + rows * self.row_elems]
    }

    /// Copy the first `rows` rows of `src` into `dst` (copy-on-write).
    /// `src` and `dst` are distinct blocks, so the ranges never overlap.
    pub fn copy_rows(&mut self, src: BlockId, dst: BlockId, rows: usize) {
        assert_ne!(src, dst, "copy_rows onto itself");
        let n = rows * self.row_elems;
        let s = self.base(src, 0);
        let d = self.base(dst, 0);
        self.k.copy_within(s..s + n, d);
        self.v.copy_within(s..s + n, d);
    }

    /// Zero a block's contents (hygiene when returning to the free list).
    pub fn zero_block(&mut self, block: BlockId) {
        let n = self.block_tokens * self.row_elems;
        let base = self.base(block, 0);
        self.k[base..base + n].fill(0.0);
        self.v[base..base + n].fill(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_roundtrip() {
        let mut s = BlockStore::new(4, 2, 3);
        s.write_row(BlockId(1), 0, &[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]);
        s.write_row(BlockId(1), 1, &[7.0, 8.0, 9.0], &[10.0, 11.0, 12.0]);
        assert_eq!(s.k_row(BlockId(1), 0), &[1.0, 2.0, 3.0]);
        assert_eq!(s.v_row(BlockId(1), 1), &[10.0, 11.0, 12.0]);
        assert_eq!(s.k_rows(BlockId(1), 2), &[1.0, 2.0, 3.0, 7.0, 8.0, 9.0]);
        // neighbours untouched
        assert!(s.k_row(BlockId(0), 0).iter().all(|&x| x == 0.0));
        assert!(s.k_row(BlockId(2), 0).iter().all(|&x| x == 0.0));
    }

    #[test]
    fn write_row_range_touches_only_the_slice() {
        let mut s = BlockStore::new(2, 2, 4);
        s.write_row(BlockId(0), 1, &[1.0; 4], &[2.0; 4]);
        s.write_row_range(BlockId(0), 1, 2..4, &[8.0, 9.0], &[-8.0, -9.0]);
        assert_eq!(s.k_row(BlockId(0), 1), &[1.0, 1.0, 8.0, 9.0]);
        assert_eq!(s.v_row(BlockId(0), 1), &[2.0, 2.0, -8.0, -9.0]);
    }

    #[test]
    fn copy_and_zero() {
        let mut s = BlockStore::new(3, 2, 2);
        s.write_row(BlockId(0), 0, &[1.0, 1.0], &[2.0, 2.0]);
        s.write_row(BlockId(0), 1, &[3.0, 3.0], &[4.0, 4.0]);
        s.copy_rows(BlockId(0), BlockId(2), 2);
        assert_eq!(s.k_row(BlockId(2), 1), &[3.0, 3.0]);
        assert_eq!(s.v_row(BlockId(2), 0), &[2.0, 2.0]);
        s.zero_block(BlockId(0));
        assert!(s.k_rows(BlockId(0), 2).iter().all(|&x| x == 0.0));
        // the copy survives zeroing the source
        assert_eq!(s.k_row(BlockId(2), 1), &[3.0, 3.0]);
    }
}
