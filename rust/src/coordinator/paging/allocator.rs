//! Free-list block allocator with reference counting and a prefix-cache
//! eviction queue.
//!
//! Lifecycle of a block:
//!
//! ```text
//!            alloc()                      decref() -> 0, unhashed
//!   free ───────────────▶ in use (ref>0) ───────────────────────▶ free
//!     ▲                      │      ▲
//!     │ alloc() (evict,      │      │ revive() on a prefix hit
//!     │ hash unregistered)   │ decref() -> 0, hashed
//!     │                      ▼      │
//!     └──────────────── evictable (ref=0, content kept)
//! ```
//!
//! *Evictable* blocks are the prefix cache's working set: their contents
//! are intact and addressable by hash, but they are reclaimed (oldest
//! first) the moment the free list runs dry.

use std::collections::VecDeque;

use super::block::{BlockId, BlockMeta, BlockStore};

/// Result of an allocation: the block, plus the hash that must be removed
/// from the prefix cache if the block was reclaimed from the evictable
/// queue.
#[derive(Debug, Clone, Copy)]
pub struct AllocOutcome {
    pub id: BlockId,
    pub evicted_hash: Option<u64>,
}

#[derive(Debug)]
pub struct BlockAllocator {
    store: BlockStore,
    meta: Vec<BlockMeta>,
    /// Strictly free blocks (no useful content).
    free: Vec<BlockId>,
    /// Candidate queue of ref-0 cached blocks, oldest in front (LRU
    /// eviction order). May contain *stale* entries for blocks revived
    /// through the prefix cache since being pushed — `revive` is O(1) and
    /// leaves its entry behind; `alloc` validates on pop. The `parked`
    /// flag bounds the queue at one entry per block, and `sweep_stale`
    /// backstops that bound (triggered by `decref` past
    /// `2 * blocks_total`). `cached` is the exact count of
    /// currently-evictable blocks.
    evictable: VecDeque<BlockId>,
    cached: usize,
    /// Copy-on-write block copies performed (stat).
    pub cow_copies: u64,
    /// Cached blocks reclaimed for new allocations (stat).
    pub evictions: u64,
}

impl BlockAllocator {
    pub fn new(num_blocks: usize, block_tokens: usize, row_elems: usize) -> Self {
        // Reverse push so blocks are handed out in 0, 1, 2, ... order
        // (deterministic layouts make the differential tests readable).
        let free: Vec<BlockId> =
            (0..num_blocks as u32).rev().map(BlockId).collect();
        BlockAllocator {
            store: BlockStore::new(num_blocks, block_tokens, row_elems),
            meta: vec![BlockMeta::default(); num_blocks],
            free,
            evictable: VecDeque::new(),
            cached: 0,
            cow_copies: 0,
            evictions: 0,
        }
    }

    pub fn store(&self) -> &BlockStore {
        &self.store
    }

    pub fn store_mut(&mut self) -> &mut BlockStore {
        &mut self.store
    }

    pub fn meta(&self, id: BlockId) -> &BlockMeta {
        &self.meta[id.index()]
    }

    pub fn blocks_total(&self) -> usize {
        self.store.num_blocks()
    }

    pub fn blocks_free(&self) -> usize {
        self.free.len()
    }

    pub fn blocks_cached(&self) -> usize {
        self.cached
    }

    pub fn blocks_in_use(&self) -> usize {
        self.blocks_total() - self.free.len() - self.cached
    }

    /// Blocks a new allocation burst can obtain (free + evictable).
    pub fn allocatable(&self) -> usize {
        self.free.len() + self.cached
    }

    /// Take a block, preferring the free list and falling back to evicting
    /// the oldest cached block. Returns `None` only when every block in
    /// the pool is referenced by a live sequence. Handed-out blocks are
    /// zeroed: stale KV must never be observable through a fresh block
    /// even if `filled` bookkeeping were wrong (same hygiene contract as
    /// `BatchArena::free_slot`).
    pub fn alloc(&mut self) -> Option<AllocOutcome> {
        if let Some(id) = self.free.pop() {
            let m = &mut self.meta[id.index()];
            debug_assert_eq!(m.ref_count, 0, "free block had refs");
            m.ref_count = 1;
            m.filled = 0;
            m.hash = None;
            return Some(AllocOutcome { id, evicted_hash: None });
        }
        // Pop until a still-valid cached block surfaces; stale entries
        // (revived since they were parked) are discarded along the way.
        while let Some(id) = self.evictable.pop_front() {
            let m = &mut self.meta[id.index()];
            m.parked = false; // entry consumed either way
            if m.ref_count != 0 || m.hash.is_none() {
                continue; // stale: revived or already freed since parked
            }
            let evicted_hash = m.hash.take();
            m.ref_count = 1;
            m.filled = 0;
            self.cached -= 1;
            self.evictions += 1;
            self.store.zero_block(id);
            return Some(AllocOutcome { id, evicted_hash });
        }
        None
    }

    pub fn incref(&mut self, id: BlockId) {
        let m = &mut self.meta[id.index()];
        assert!(m.ref_count > 0, "incref on unreferenced block {id:?}");
        m.ref_count += 1;
    }

    /// Drop one reference. At zero, hashed blocks park in the evictable
    /// queue (content reusable through the prefix cache); unhashed blocks
    /// are zeroed and return straight to the free list. Returns the new
    /// count.
    pub fn decref(&mut self, id: BlockId) -> u32 {
        let idx = id.index();
        assert!(
            self.meta[idx].ref_count > 0,
            "decref on unreferenced block {id:?}"
        );
        self.meta[idx].ref_count -= 1;
        let count = self.meta[idx].ref_count;
        if count == 0 {
            if self.meta[idx].hash.is_some() {
                // A revived-then-reparked block may still own a (stale)
                // queue entry; `parked` keeps it to one entry per block so
                // the queue can never outgrow the pool.
                if !self.meta[idx].parked {
                    self.evictable.push_back(id);
                    self.meta[idx].parked = true;
                    // Defensive backstop: with `parked` bookkeeping intact
                    // the queue is bounded at one entry per block, so this
                    // can only fire if that invariant regresses — sweep
                    // the stale entries instead of growing without bound
                    // under a churny prefix-hit workload.
                    if self.evictable.len() > 2 * self.blocks_total() {
                        self.sweep_stale();
                    }
                }
                self.cached += 1;
            } else {
                self.meta[idx].filled = 0;
                self.store.zero_block(id);
                self.free.push(id);
            }
        }
        count
    }

    /// Entries currently sitting in the evictable queue, valid *and*
    /// stale (observability + regression tests pin this against
    /// `blocks_total`).
    pub fn evictable_len(&self) -> usize {
        self.evictable.len()
    }

    /// Drop stale evictable entries in place (blocks revived or freed
    /// since they were parked), preserving the LRU order of the valid
    /// ones. O(queue). Normally unnecessary — `parked` caps the queue at
    /// one entry per block — this exists as the backstop `decref`
    /// triggers if the queue ever outgrows `2 * blocks_total`.
    pub fn sweep_stale(&mut self) {
        // Clear every queue entry's mark first, then keep exactly one
        // entry per still-cached block (re-marking as we go) — this both
        // drops stale entries and dedupes, so the queue is <= blocks_total
        // afterwards no matter how the invariant was violated.
        let meta = &mut self.meta;
        for id in self.evictable.iter() {
            meta[id.index()].parked = false;
        }
        self.evictable.retain(|id| {
            let m = &mut meta[id.index()];
            let keep = m.ref_count == 0 && m.hash.is_some() && !m.parked;
            if keep {
                m.parked = true;
            }
            keep
        });
    }

    /// Claim a block found through the prefix cache: live shared blocks
    /// gain a reference; ref-0 cached blocks are revived in O(1) (their
    /// evictable-queue entry is left behind as a stale marker that `alloc`
    /// skips on pop). Returns false if the block no longer holds cached
    /// content (stale map entry), in which case the caller must treat the
    /// lookup as a miss.
    pub fn revive(&mut self, id: BlockId) -> bool {
        let m = &mut self.meta[id.index()];
        if m.hash.is_none() {
            return false;
        }
        if m.ref_count > 0 {
            m.ref_count += 1;
        } else {
            m.ref_count = 1;
            self.cached -= 1;
        }
        true
    }

    /// Mark a full block immutable and addressable under `hash`.
    pub fn seal(&mut self, id: BlockId, hash: u64) {
        let m = &mut self.meta[id.index()];
        debug_assert!(m.ref_count > 0, "sealing unreferenced block");
        m.hash = Some(hash);
    }

    /// Clear a seal before mutating a uniquely-owned block in place;
    /// returns the hash the caller must unregister from the prefix cache.
    pub fn unseal(&mut self, id: BlockId) -> Option<u64> {
        self.meta[id.index()].hash.take()
    }

    pub fn set_filled(&mut self, id: BlockId, rows: u32) {
        debug_assert!(rows as usize <= self.store.block_tokens());
        self.meta[id.index()].filled = rows;
    }

    pub fn note_cow(&mut self) {
        self.cow_copies += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn alloc3() -> BlockAllocator {
        BlockAllocator::new(3, 4, 2)
    }

    #[test]
    fn alloc_free_roundtrip() {
        let mut a = alloc3();
        assert_eq!(a.blocks_free(), 3);
        let b0 = a.alloc().unwrap().id;
        let b1 = a.alloc().unwrap().id;
        assert_eq!((b0, b1), (BlockId(0), BlockId(1)));
        assert_eq!(a.blocks_in_use(), 2);
        assert_eq!(a.decref(b0), 0);
        assert_eq!(a.blocks_free(), 2);
        assert_eq!(a.blocks_in_use(), 1);
    }

    #[test]
    fn refcounted_block_survives_one_decref() {
        let mut a = alloc3();
        let b = a.alloc().unwrap().id;
        a.incref(b);
        assert_eq!(a.decref(b), 1);
        assert_eq!(a.blocks_in_use(), 1);
        assert_eq!(a.decref(b), 0);
        assert_eq!(a.blocks_in_use(), 0);
    }

    #[test]
    fn hashed_blocks_park_then_evict_oldest() {
        let mut a = alloc3();
        let b0 = a.alloc().unwrap().id;
        a.seal(b0, 111);
        let b1 = a.alloc().unwrap().id;
        a.seal(b1, 222);
        a.decref(b0);
        a.decref(b1);
        assert_eq!(a.blocks_cached(), 2);
        assert_eq!(a.blocks_free(), 1);
        // exhaust the free list, then evictions begin with the oldest (b0)
        let _ = a.alloc().unwrap();
        let out = a.alloc().unwrap();
        assert_eq!(out.id, b0);
        assert_eq!(out.evicted_hash, Some(111));
        assert_eq!(a.evictions, 1);
    }

    #[test]
    fn revive_pulls_from_evictable() {
        let mut a = alloc3();
        let b = a.alloc().unwrap().id;
        a.seal(b, 7);
        a.decref(b);
        assert_eq!(a.blocks_cached(), 1);
        assert!(a.revive(b));
        assert_eq!(a.meta(b).ref_count, 1);
        assert_eq!(a.blocks_cached(), 0);
        // live shared revive just bumps the count
        assert!(a.revive(b));
        assert_eq!(a.meta(b).ref_count, 2);
        // unhashed blocks cannot be revived
        let u = a.alloc().unwrap().id;
        a.decref(u);
        assert!(!a.revive(u));
    }

    #[test]
    fn stale_evictable_entries_are_skipped_on_alloc() {
        // revive() leaves its queue entry behind as a stale marker;
        // alloc() must discard it instead of evicting the live block, and
        // accounting must stay exact throughout.
        let mut a = alloc3();
        let b = a.alloc().unwrap().id;
        a.seal(b, 7);
        a.decref(b); // parked
        assert!(a.revive(b)); // live again; queue entry now stale
        assert_eq!(a.blocks_cached(), 0);
        let c = a.alloc().unwrap().id;
        a.seal(c, 9);
        a.decref(c); // queue: [b(stale), c(valid)]
        assert_eq!(a.blocks_cached(), 1, "counter ignores stale entry");
        let _ = a.alloc().unwrap(); // drains the free list
        // eviction must skip the stale b entry and take c
        let out = a.alloc().unwrap();
        assert_eq!(out.id, c);
        assert_eq!(out.evicted_hash, Some(9));
        assert_eq!(a.blocks_cached(), 0);
        assert_eq!(a.blocks_in_use(), 3);
        assert!(a.alloc().is_none(), "pool truly exhausted");
        assert_eq!(a.evictions, 1);
        // park/revive/park keeps a single queue entry per block: b can be
        // evicted exactly once afterwards, not twice
        a.decref(b);
        assert!(a.revive(b));
        a.decref(b);
        assert_eq!(a.blocks_cached(), 1);
        let out = a.alloc().unwrap();
        assert_eq!(out.id, b);
        assert_eq!(out.evicted_hash, Some(7));
        assert!(a.alloc().is_none(), "no duplicate entry to double-evict");
    }

    #[test]
    fn freed_and_evicted_blocks_are_zeroed() {
        let mut a = alloc3();
        let b = a.alloc().unwrap().id;
        a.store_mut().write_row(b, 0, &[1.0, 2.0], &[3.0, 4.0]);
        a.decref(b); // unhashed -> free list, zeroed
        assert!(a.store().k_rows(b, 1).iter().all(|&x| x == 0.0));
        assert!(a.store().v_rows(b, 1).iter().all(|&x| x == 0.0));
        // hashed blocks keep content while cached, zeroed on eviction
        let h = a.alloc().unwrap().id;
        a.store_mut().write_row(h, 0, &[5.0, 5.0], &[6.0, 6.0]);
        a.seal(h, 42);
        a.decref(h);
        assert_eq!(a.store().k_row(h, 0), &[5.0, 5.0], "cached content kept");
        let _ = a.alloc().unwrap(); // free list
        let _ = a.alloc().unwrap(); // free list
        let out = a.alloc().unwrap(); // evicts h
        assert_eq!(out.id, h);
        assert!(a.store().k_rows(h, 1).iter().all(|&x| x == 0.0));
    }

    #[test]
    fn evictable_queue_bounded_and_sweep_drops_stale() {
        let mut a = alloc3();
        let b = a.alloc().unwrap().id;
        a.seal(b, 7);
        // churny prefix-hit workload: park + revive over and over must
        // not accumulate queue entries
        for _ in 0..100 {
            a.decref(b);
            assert!(a.revive(b));
        }
        assert!(
            a.evictable_len() <= a.blocks_total(),
            "queue leaked: {} entries for {} blocks",
            a.evictable_len(),
            a.blocks_total()
        );
        // the surviving entry is stale (block is live): sweep drops it
        a.sweep_stale();
        assert_eq!(a.evictable_len(), 0);
        // and the block can still park + evict normally afterwards
        a.decref(b);
        assert_eq!(a.evictable_len(), 1);
        assert_eq!(a.blocks_cached(), 1);
        let _ = a.alloc().unwrap();
        let _ = a.alloc().unwrap();
        let out = a.alloc().unwrap();
        assert_eq!(out.id, b);
        assert_eq!(out.evicted_hash, Some(7));
        // sweep on a queue holding only valid entries is a no-op
        let mut v = alloc3();
        let x = v.alloc().unwrap().id;
        v.seal(x, 1);
        v.decref(x);
        v.sweep_stale();
        assert_eq!(v.evictable_len(), 1);
        assert!(v.revive(x), "valid entry survived the sweep");
    }

    #[test]
    fn exhaustion_returns_none() {
        let mut a = alloc3();
        let ids: Vec<BlockId> = (0..3).map(|_| a.alloc().unwrap().id).collect();
        assert!(a.alloc().is_none());
        a.decref(ids[1]);
        assert!(a.alloc().is_some());
    }
}
