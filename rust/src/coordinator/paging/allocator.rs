//! Free-list block allocator with reference counting, a prefix-cache
//! eviction queue, and per-tenant quota enforcement.
//!
//! Lifecycle of a block:
//!
//! ```text
//!            alloc()                      decref() -> 0, unhashed
//!   free ───────────────▶ in use (ref>0) ───────────────────────▶ free
//!     ▲                      │      ▲
//!     │ alloc() (evict,      │      │ revive() on a prefix hit
//!     │ hash unregistered)   │ decref() -> 0, hashed
//!     │                      ▼      │
//!     └──────────────── evictable (ref=0, content kept)
//! ```
//!
//! *Evictable* blocks are the prefix cache's working set: their contents
//! are intact and addressable by hash, but they are reclaimed (oldest
//! first) the moment the free list runs dry.
//!
//! # Tenancy
//!
//! Every transition into the live (`ref > 0`) state — `alloc` from the
//! free list or evictable queue, `revive` of a ref-0 cached block — names
//! the tenant performing it, and that tenant is *charged* for the block
//! until its refcount returns to zero (the first-toucher rule; see
//! [`super::tenant`] for why). Charges are what quotas bound:
//!
//! * a tenant may never hold more than its **ceiling** of charged blocks;
//! * a tenant may never take a block that the pool needs in order to keep
//!   every *other* tenant's unused **reserved floor** satisfiable.
//!
//! With no quotas configured every tenant gets the default (floor 0,
//! ceiling unlimited) and the allocator behaves exactly as it did before
//! tenancy existed.

use std::collections::{BTreeMap, VecDeque};

use super::block::{BlockId, BlockMeta, BlockStore};
use super::codec::KvCodec;
use super::tenant::{TenantId, TenantQuota};

/// Result of an allocation: the block, plus the hash that must be removed
/// from the prefix cache if the block was reclaimed from the evictable
/// queue.
#[derive(Debug, Clone, Copy)]
pub struct AllocOutcome {
    /// The freshly chargeable block (zeroed, `ref_count == 1`).
    pub id: BlockId,
    /// Hash of the cached content this allocation evicted, if any; the
    /// caller must unregister it from the prefix cache.
    pub evicted_hash: Option<u64>,
}

/// Outcome of claiming a block through the prefix cache
/// ([`BlockAllocator::revive`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Revive {
    /// The block is live for the caller (ref bumped, or pulled from the
    /// evictable queue and charged to the reviving tenant).
    Revived,
    /// The block no longer holds cached content — a stale prefix-map
    /// entry the caller must unregister and treat as a miss.
    Stale,
    /// Reviving the cached block would breach the tenant's quota (or eat
    /// another tenant's reserved floor). The map entry is still valid;
    /// the caller should treat the lookup as a miss *without*
    /// unregistering it.
    OverQuota,
}

/// Free-list block allocator: ref-counting, LRU reclamation of cached
/// blocks, and per-tenant charge accounting against [`TenantQuota`]s.
#[derive(Debug)]
pub struct BlockAllocator {
    store: BlockStore,
    meta: Vec<BlockMeta>,
    /// Strictly free blocks (no useful content).
    free: Vec<BlockId>,
    /// Candidate queue of ref-0 cached blocks, oldest in front (LRU
    /// eviction order). May contain *stale* entries for blocks revived
    /// through the prefix cache since being pushed — `revive` is O(1) and
    /// leaves its entry behind; `alloc` validates on pop. The `parked`
    /// flag bounds the queue at one entry per block, and `sweep_stale`
    /// backstops that bound (triggered by `decref` past
    /// `2 * blocks_total`). `cached` is the exact count of
    /// currently-evictable blocks.
    evictable: VecDeque<BlockId>,
    cached: usize,
    /// Configured quotas; tenants absent here get the default (unconstrained) quota.
    quotas: BTreeMap<TenantId, TenantQuota>,
    /// Live blocks charged per tenant (first-toucher rule). Maintained so
    /// that `Σ held == blocks_in_use` at all times.
    held: BTreeMap<TenantId, usize>,
    /// Copy-on-write block copies performed (stat).
    pub cow_copies: u64,
    /// Cached blocks reclaimed for new allocations (stat).
    pub evictions: u64,
    /// Block takes refused by a tenant quota while the pool still had
    /// allocatable blocks (stat; pure pool exhaustion is not counted,
    /// and each denied take counts exactly once — a quota-blocked
    /// revival falls through to the allocation attempt that counts it).
    pub quota_denials: u64,
}

impl BlockAllocator {
    /// Pool of `num_blocks` blocks of `block_tokens` rows, each row
    /// `row_elems` elements wide (per K/V plane), stored as f32.
    pub fn new(num_blocks: usize, block_tokens: usize, row_elems: usize) -> Self {
        Self::with_codec(num_blocks, block_tokens, row_elems, KvCodec::F32)
    }

    /// [`BlockAllocator::new`] with an explicit slab codec
    /// (`PagingConfig::precision`).
    pub fn with_codec(
        num_blocks: usize,
        block_tokens: usize,
        row_elems: usize,
        codec: KvCodec,
    ) -> Self {
        // Reverse push so blocks are handed out in 0, 1, 2, ... order
        // (deterministic layouts make the differential tests readable).
        let free: Vec<BlockId> =
            (0..num_blocks as u32).rev().map(BlockId).collect();
        BlockAllocator {
            store: BlockStore::with_codec(
                num_blocks,
                block_tokens,
                row_elems,
                codec,
            ),
            meta: vec![BlockMeta::default(); num_blocks],
            free,
            evictable: VecDeque::new(),
            cached: 0,
            quotas: BTreeMap::new(),
            held: BTreeMap::new(),
            cow_copies: 0,
            evictions: 0,
            quota_denials: 0,
        }
    }

    /// The underlying block slab.
    pub fn store(&self) -> &BlockStore {
        &self.store
    }

    /// Mutable access to the slab (row writes, COW copies).
    pub fn store_mut(&mut self) -> &mut BlockStore {
        &mut self.store
    }

    /// Bookkeeping for one block.
    pub fn meta(&self, id: BlockId) -> &BlockMeta {
        &self.meta[id.index()]
    }

    /// Pool size in blocks.
    pub fn blocks_total(&self) -> usize {
        self.store.num_blocks()
    }

    /// Blocks on the free list.
    pub fn blocks_free(&self) -> usize {
        self.free.len()
    }

    /// Ref-0 blocks whose cached content is still addressable by hash.
    pub fn blocks_cached(&self) -> usize {
        self.cached
    }

    /// Blocks referenced by at least one live block table.
    pub fn blocks_in_use(&self) -> usize {
        self.blocks_total() - self.free.len() - self.cached
    }

    /// Blocks a new allocation burst can obtain (free + evictable),
    /// ignoring quotas — see [`BlockAllocator::available_to`] for the
    /// tenant-facing number.
    pub fn allocatable(&self) -> usize {
        self.free.len() + self.cached
    }

    // --- tenant quota accounting ------------------------------------

    /// Install (or replace) a tenant's quota. Applies to future
    /// allocations only; blocks already charged are never clawed back.
    pub fn set_quota(&mut self, tenant: TenantId, quota: TenantQuota) {
        self.quotas.insert(tenant, quota);
    }

    /// The tenant's effective quota (the default unconstrained quota when none
    /// was configured).
    pub fn quota(&self, tenant: TenantId) -> TenantQuota {
        self.quotas.get(&tenant).copied().unwrap_or_default()
    }

    /// Whether any quota is configured at all (the victim-selection
    /// tie-breaker only activates then).
    pub fn quotas_configured(&self) -> bool {
        !self.quotas.is_empty()
    }

    /// Blocks currently charged to `tenant`.
    pub fn held(&self, tenant: TenantId) -> usize {
        self.held.get(&tenant).copied().unwrap_or(0)
    }

    /// Tenants worth reporting: every tenant with a configured quota or
    /// that has *ever* held blocks. Zero-held tenants are deliberately
    /// kept (the `held` map never forgets a key) so a published
    /// `tenant_{id}_blocks_held` gauge is written back to 0 after the
    /// tenant's last release instead of going stale at its old value.
    pub fn tenants(&self) -> Vec<TenantId> {
        let mut ids: Vec<TenantId> = self.quotas.keys().copied().collect();
        for &t in self.held.keys() {
            if !ids.contains(&t) {
                ids.push(t);
            }
        }
        ids.sort();
        ids
    }

    /// Whether `tenant` is bursting past its reserved floor. Always false
    /// when no quotas are configured (preserving the pre-tenancy
    /// preemption-victim ordering).
    pub fn over_quota(&self, tenant: TenantId) -> bool {
        self.quotas_configured()
            && self.held(tenant) > self.quota(tenant).reserved_blocks
    }

    /// Whether `tenant` sits at (or past) its burst ceiling: its next
    /// take is refused no matter how many blocks *other* tenants free —
    /// only this tenant's own releases (or compaction) can relieve it.
    /// Preemption victim selection uses this to avoid churning innocent
    /// lanes whose blocks could never help.
    pub fn at_ceiling(&self, tenant: TenantId) -> bool {
        self.held(tenant) >= self.quota(tenant).ceiling_blocks
    }

    /// Unused reserved floor of every tenant except `tenant`: blocks the
    /// pool must keep obtainable for them, i.e. blocks `tenant` may not
    /// take.
    fn reserved_headroom_excluding(&self, tenant: TenantId) -> usize {
        self.quotas
            .iter()
            .filter(|(&t, _)| t != tenant)
            .map(|(&t, q)| q.reserved_blocks.saturating_sub(self.held(t)))
            .sum()
    }

    /// Blocks `tenant` can obtain right now: the allocatable pool minus
    /// every other tenant's unused reserved floor (its own floor is, by
    /// construction, part of what remains).
    pub fn available_to(&self, tenant: TenantId) -> usize {
        self.allocatable()
            .saturating_sub(self.reserved_headroom_excluding(tenant))
    }

    /// Most blocks `tenant` could ever hold, even on a fully drained
    /// pool: total pool minus the other tenants' full reserved floors,
    /// capped by its own ceiling. Drives `could_ever_admit` — a request
    /// above this can never be admitted for this tenant, no matter how
    /// long it waits.
    pub fn max_ever_available(&self, tenant: TenantId) -> usize {
        let floors: usize = self
            .quotas
            .iter()
            .filter(|(&t, _)| t != tenant)
            .map(|(_, q)| q.reserved_blocks)
            .sum();
        self.blocks_total()
            .saturating_sub(floors)
            .min(self.quota(tenant).ceiling_blocks)
    }

    /// Whether `tenant` may take `n` more blocks right now (ceiling and
    /// other tenants' floors both respected).
    pub fn can_take(&self, tenant: TenantId, n: usize) -> bool {
        let q = self.quota(tenant);
        self.held(tenant).saturating_add(n) <= q.ceiling_blocks
            && n <= self.available_to(tenant)
    }

    /// [`BlockAllocator::can_take`], evaluated *as if* every block in
    /// `released` with `ref_count == 1` had just been decref'd to zero
    /// (compaction's release-then-rebuild feasibility check). Uncharges
    /// are simulated per owning tenant, so a rebuild is refused if the
    /// release would widen *another* tenant's unused floor enough to
    /// starve this one.
    pub fn can_take_after_release(
        &self,
        tenant: TenantId,
        n: usize,
        released: &[BlockId],
    ) -> bool {
        let mut freed_total = 0usize;
        let mut freed_by: BTreeMap<TenantId, usize> = BTreeMap::new();
        for &id in released {
            let m = &self.meta[id.index()];
            if m.ref_count == 1 {
                freed_total += 1;
                *freed_by.entry(m.owner).or_default() += 1;
            }
        }
        let freed_of = |t: TenantId| freed_by.get(&t).copied().unwrap_or(0);
        let q = self.quota(tenant);
        let held_t = self.held(tenant).saturating_sub(freed_of(tenant));
        if held_t.saturating_add(n) > q.ceiling_blocks {
            return false;
        }
        let floors: usize = self
            .quotas
            .iter()
            .filter(|(&t, _)| t != tenant)
            .map(|(&t, q)| {
                q.reserved_blocks
                    .saturating_sub(self.held(t).saturating_sub(freed_of(t)))
            })
            .sum();
        n <= (self.allocatable() + freed_total).saturating_sub(floors)
    }

    fn charge(&mut self, tenant: TenantId, id: BlockId) {
        self.meta[id.index()].owner = tenant;
        *self.held.entry(tenant).or_insert(0) += 1;
    }

    fn uncharge(&mut self, id: BlockId) {
        let owner = self.meta[id.index()].owner;
        let h = self
            .held
            .get_mut(&owner)
            .expect("uncharge of a tenant that holds nothing");
        debug_assert!(*h > 0, "held underflow for tenant {owner:?}");
        *h -= 1;
    }

    // --- allocation --------------------------------------------------

    /// Take a block for `tenant`, preferring the free list and falling
    /// back to evicting the oldest cached block. Returns `None` when
    /// every block in the pool is referenced by a live sequence **or**
    /// when the tenant's quota refuses the take (counted in
    /// `quota_denials` if the pool itself had blocks). Handed-out blocks
    /// are zeroed: stale KV must never be observable through a fresh
    /// block even if `filled` bookkeeping were wrong (same hygiene
    /// contract as `BatchArena::free_slot`).
    pub fn alloc(&mut self, tenant: TenantId) -> Option<AllocOutcome> {
        if !self.can_take(tenant, 1) {
            if self.allocatable() > 0 {
                self.quota_denials += 1;
            }
            return None;
        }
        if let Some(id) = self.free.pop() {
            let m = &mut self.meta[id.index()];
            debug_assert_eq!(m.ref_count, 0, "free block had refs");
            m.ref_count = 1;
            m.filled = 0;
            m.hash = None;
            m.score = 0.0;
            m.last_write = 0;
            self.charge(tenant, id);
            return Some(AllocOutcome { id, evicted_hash: None });
        }
        // Pop until a still-valid cached block surfaces; stale entries
        // (revived since they were parked) are discarded along the way.
        while let Some(id) = self.evictable.pop_front() {
            let m = &mut self.meta[id.index()];
            m.parked = false; // entry consumed either way
            if m.ref_count != 0 || m.hash.is_none() {
                continue; // stale: revived or already freed since parked
            }
            let evicted_hash = m.hash.take();
            m.ref_count = 1;
            m.filled = 0;
            m.score = 0.0;
            m.last_write = 0;
            self.cached -= 1;
            self.evictions += 1;
            self.charge(tenant, id);
            self.store.zero_block(id);
            return Some(AllocOutcome { id, evicted_hash });
        }
        None
    }

    /// Add a reference to a live block (prefix sharing, `fork`). The
    /// charge stays with the block's current owner — sharing is free for
    /// the new referent under the first-toucher rule.
    pub fn incref(&mut self, id: BlockId) {
        let m = &mut self.meta[id.index()];
        assert!(m.ref_count > 0, "incref on unreferenced block {id:?}");
        m.ref_count += 1;
    }

    /// Drop one reference. At zero, the owning tenant is uncharged, then
    /// hashed blocks park in the evictable queue (content reusable
    /// through the prefix cache) and unhashed blocks are zeroed and
    /// return straight to the free list. Returns the new count.
    pub fn decref(&mut self, id: BlockId) -> u32 {
        let idx = id.index();
        assert!(
            self.meta[idx].ref_count > 0,
            "decref on unreferenced block {id:?}"
        );
        self.meta[idx].ref_count -= 1;
        let count = self.meta[idx].ref_count;
        if count == 0 {
            self.uncharge(id);
            if self.meta[idx].hash.is_some() {
                // A revived-then-reparked block may still own a (stale)
                // queue entry; `parked` keeps it to one entry per block so
                // the queue can never outgrow the pool.
                if !self.meta[idx].parked {
                    self.evictable.push_back(id);
                    self.meta[idx].parked = true;
                    // Defensive backstop: with `parked` bookkeeping intact
                    // the queue is bounded at one entry per block, so this
                    // can only fire if that invariant regresses — sweep
                    // the stale entries instead of growing without bound
                    // under a churny prefix-hit workload.
                    if self.evictable.len() > 2 * self.blocks_total() {
                        self.sweep_stale();
                    }
                }
                self.cached += 1;
            } else {
                self.meta[idx].filled = 0;
                self.store.zero_block(id);
                self.free.push(id);
            }
        }
        count
    }

    /// Entries currently sitting in the evictable queue, valid *and*
    /// stale (observability + regression tests pin this against
    /// `blocks_total`).
    pub fn evictable_len(&self) -> usize {
        self.evictable.len()
    }

    /// Drop stale evictable entries in place (blocks revived or freed
    /// since they were parked), preserving the LRU order of the valid
    /// ones. O(queue). Normally unnecessary — `parked` caps the queue at
    /// one entry per block — this exists as the backstop `decref`
    /// triggers if the queue ever outgrows `2 * blocks_total`.
    pub fn sweep_stale(&mut self) {
        // Clear every queue entry's mark first, then keep exactly one
        // entry per still-cached block (re-marking as we go) — this both
        // drops stale entries and dedupes, so the queue is <= blocks_total
        // afterwards no matter how the invariant was violated.
        let meta = &mut self.meta;
        for id in self.evictable.iter() {
            meta[id.index()].parked = false;
        }
        self.evictable.retain(|id| {
            let m = &mut meta[id.index()];
            let keep = m.ref_count == 0 && m.hash.is_some() && !m.parked;
            if keep {
                m.parked = true;
            }
            keep
        });
    }

    /// Claim a block found through the prefix cache for `tenant`: live
    /// shared blocks gain a reference (no charge — first-toucher rule);
    /// ref-0 cached blocks are revived in O(1) and charged to the
    /// reviving tenant (their evictable-queue entry is left behind as a
    /// stale marker that `alloc` skips on pop). See [`Revive`] for the
    /// three outcomes; only [`Revive::Stale`] means the prefix-map entry
    /// should be unregistered.
    pub fn revive(&mut self, id: BlockId, tenant: TenantId) -> Revive {
        if self.meta[id.index()].hash.is_none() {
            return Revive::Stale;
        }
        if self.meta[id.index()].ref_count > 0 {
            self.meta[id.index()].ref_count += 1;
            return Revive::Revived;
        }
        // Pulling a cached block out of the evictable pool consumes one
        // allocatable block, exactly like `alloc` — same quota gate. Not
        // counted in `quota_denials` here: the arena's load loop falls
        // through to an `alloc` attempt that re-evaluates the same gate,
        // and a single denied take must count once.
        if !self.can_take(tenant, 1) {
            return Revive::OverQuota;
        }
        self.meta[id.index()].ref_count = 1;
        self.cached -= 1;
        self.charge(tenant, id);
        Revive::Revived
    }

    /// Mark a full block immutable and addressable under `hash`.
    pub fn seal(&mut self, id: BlockId, hash: u64) {
        let m = &mut self.meta[id.index()];
        debug_assert!(m.ref_count > 0, "sealing unreferenced block");
        m.hash = Some(hash);
    }

    /// Clear a seal before mutating a uniquely-owned block in place;
    /// returns the hash the caller must unregister from the prefix cache.
    pub fn unseal(&mut self, id: BlockId) -> Option<u64> {
        self.meta[id.index()].hash.take()
    }

    /// Record how many rows of a block hold valid KV.
    pub fn set_filled(&mut self, id: BlockId, rows: u32) {
        debug_assert!(rows as usize <= self.store.block_tokens());
        self.meta[id.index()].filled = rows;
    }

    /// Accumulate the decode-eviction salience heuristic for one row
    /// written into `id`: `mass` (mean |K| of the row) adds to the
    /// block's score, `stamp` (the arena's monotonic mutation counter)
    /// becomes its write-recency mark. See [`BlockMeta::score`].
    pub fn note_row_write(&mut self, id: BlockId, mass: f32, stamp: u64) {
        let m = &mut self.meta[id.index()];
        m.score += mass;
        m.last_write = stamp;
    }

    /// Count one copy-on-write block copy (stat).
    pub fn note_cow(&mut self) {
        self.cow_copies += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const T0: TenantId = TenantId::DEFAULT;
    const T1: TenantId = TenantId(1);
    const T2: TenantId = TenantId(2);

    fn alloc3() -> BlockAllocator {
        BlockAllocator::new(3, 4, 2)
    }

    /// `Σ held == blocks_in_use` must hold at every step.
    fn assert_charges_reconcile(a: &BlockAllocator) {
        let total: usize = a.tenants().iter().map(|&t| a.held(t)).sum();
        assert_eq!(total, a.blocks_in_use(), "charges vs in-use blocks");
    }

    #[test]
    fn alloc_free_roundtrip() {
        let mut a = alloc3();
        assert_eq!(a.blocks_free(), 3);
        let b0 = a.alloc(T0).unwrap().id;
        let b1 = a.alloc(T0).unwrap().id;
        assert_eq!((b0, b1), (BlockId(0), BlockId(1)));
        assert_eq!(a.blocks_in_use(), 2);
        assert_eq!(a.held(T0), 2);
        assert_charges_reconcile(&a);
        assert_eq!(a.decref(b0), 0);
        assert_eq!(a.blocks_free(), 2);
        assert_eq!(a.blocks_in_use(), 1);
        assert_eq!(a.held(T0), 1);
        assert_charges_reconcile(&a);
    }

    #[test]
    fn refcounted_block_survives_one_decref() {
        let mut a = alloc3();
        let b = a.alloc(T0).unwrap().id;
        a.incref(b);
        assert_eq!(a.decref(b), 1);
        assert_eq!(a.blocks_in_use(), 1);
        assert_eq!(a.held(T0), 1, "charge persists while referenced");
        assert_eq!(a.decref(b), 0);
        assert_eq!(a.blocks_in_use(), 0);
        assert_eq!(a.held(T0), 0);
    }

    #[test]
    fn hashed_blocks_park_then_evict_oldest() {
        let mut a = alloc3();
        let b0 = a.alloc(T0).unwrap().id;
        a.seal(b0, 111);
        let b1 = a.alloc(T0).unwrap().id;
        a.seal(b1, 222);
        a.decref(b0);
        a.decref(b1);
        assert_eq!(a.blocks_cached(), 2);
        assert_eq!(a.blocks_free(), 1);
        // exhaust the free list, then evictions begin with the oldest (b0)
        let _ = a.alloc(T0).unwrap();
        let out = a.alloc(T0).unwrap();
        assert_eq!(out.id, b0);
        assert_eq!(out.evicted_hash, Some(111));
        assert_eq!(a.evictions, 1);
    }

    #[test]
    fn revive_pulls_from_evictable() {
        let mut a = alloc3();
        let b = a.alloc(T0).unwrap().id;
        a.seal(b, 7);
        a.decref(b);
        assert_eq!(a.blocks_cached(), 1);
        assert_eq!(a.revive(b, T0), Revive::Revived);
        assert_eq!(a.meta(b).ref_count, 1);
        assert_eq!(a.blocks_cached(), 0);
        // live shared revive just bumps the count
        assert_eq!(a.revive(b, T0), Revive::Revived);
        assert_eq!(a.meta(b).ref_count, 2);
        // unhashed blocks cannot be revived
        let u = a.alloc(T0).unwrap().id;
        a.decref(u);
        assert_eq!(a.revive(u, T0), Revive::Stale);
    }

    #[test]
    fn stale_evictable_entries_are_skipped_on_alloc() {
        // revive() leaves its queue entry behind as a stale marker;
        // alloc() must discard it instead of evicting the live block, and
        // accounting must stay exact throughout.
        let mut a = alloc3();
        let b = a.alloc(T0).unwrap().id;
        a.seal(b, 7);
        a.decref(b); // parked
        assert_eq!(a.revive(b, T0), Revive::Revived); // queue entry now stale
        assert_eq!(a.blocks_cached(), 0);
        let c = a.alloc(T0).unwrap().id;
        a.seal(c, 9);
        a.decref(c); // queue: [b(stale), c(valid)]
        assert_eq!(a.blocks_cached(), 1, "counter ignores stale entry");
        let _ = a.alloc(T0).unwrap(); // drains the free list
        // eviction must skip the stale b entry and take c
        let out = a.alloc(T0).unwrap();
        assert_eq!(out.id, c);
        assert_eq!(out.evicted_hash, Some(9));
        assert_eq!(a.blocks_cached(), 0);
        assert_eq!(a.blocks_in_use(), 3);
        assert!(a.alloc(T0).is_none(), "pool truly exhausted");
        assert_eq!(a.evictions, 1);
        // park/revive/park keeps a single queue entry per block: b can be
        // evicted exactly once afterwards, not twice
        a.decref(b);
        assert_eq!(a.revive(b, T0), Revive::Revived);
        a.decref(b);
        assert_eq!(a.blocks_cached(), 1);
        let out = a.alloc(T0).unwrap();
        assert_eq!(out.id, b);
        assert_eq!(out.evicted_hash, Some(7));
        assert!(a.alloc(T0).is_none(), "no duplicate entry to double-evict");
    }

    #[test]
    fn freed_and_evicted_blocks_are_zeroed() {
        let mut a = alloc3();
        let b = a.alloc(T0).unwrap().id;
        a.store_mut().write_row(b, 0, &[1.0, 2.0], &[3.0, 4.0]);
        a.decref(b); // unhashed -> free list, zeroed
        assert!(a.store().k_rows(b, 1).iter().all(|&x| x == 0.0));
        assert!(a.store().v_rows(b, 1).iter().all(|&x| x == 0.0));
        // hashed blocks keep content while cached, zeroed on eviction
        let h = a.alloc(T0).unwrap().id;
        a.store_mut().write_row(h, 0, &[5.0, 5.0], &[6.0, 6.0]);
        a.seal(h, 42);
        a.decref(h);
        assert_eq!(
            &a.store().k_row(h, 0)[..],
            &[5.0, 5.0],
            "cached content kept"
        );
        let _ = a.alloc(T0).unwrap(); // free list
        let _ = a.alloc(T0).unwrap(); // free list
        let out = a.alloc(T0).unwrap(); // evicts h
        assert_eq!(out.id, h);
        assert!(a.store().k_rows(h, 1).iter().all(|&x| x == 0.0));
    }

    #[test]
    fn evictable_queue_bounded_and_sweep_drops_stale() {
        let mut a = alloc3();
        let b = a.alloc(T0).unwrap().id;
        a.seal(b, 7);
        // churny prefix-hit workload: park + revive over and over must
        // not accumulate queue entries
        for _ in 0..100 {
            a.decref(b);
            assert_eq!(a.revive(b, T0), Revive::Revived);
        }
        assert!(
            a.evictable_len() <= a.blocks_total(),
            "queue leaked: {} entries for {} blocks",
            a.evictable_len(),
            a.blocks_total()
        );
        // the surviving entry is stale (block is live): sweep drops it
        a.sweep_stale();
        assert_eq!(a.evictable_len(), 0);
        // and the block can still park + evict normally afterwards
        a.decref(b);
        assert_eq!(a.evictable_len(), 1);
        assert_eq!(a.blocks_cached(), 1);
        let _ = a.alloc(T0).unwrap();
        let _ = a.alloc(T0).unwrap();
        let out = a.alloc(T0).unwrap();
        assert_eq!(out.id, b);
        assert_eq!(out.evicted_hash, Some(7));
        // sweep on a queue holding only valid entries is a no-op
        let mut v = alloc3();
        let x = v.alloc(T0).unwrap().id;
        v.seal(x, 1);
        v.decref(x);
        v.sweep_stale();
        assert_eq!(v.evictable_len(), 1);
        assert_eq!(v.revive(x, T0), Revive::Revived, "entry survived sweep");
    }

    #[test]
    fn exhaustion_returns_none() {
        let mut a = alloc3();
        let ids: Vec<BlockId> =
            (0..3).map(|_| a.alloc(T0).unwrap().id).collect();
        assert!(a.alloc(T0).is_none());
        assert_eq!(a.quota_denials, 0, "pool exhaustion is not a denial");
        a.decref(ids[1]);
        assert!(a.alloc(T0).is_some());
    }

    // --- tenancy ------------------------------------------------------

    #[test]
    fn ceiling_caps_a_tenants_charges() {
        let mut a = BlockAllocator::new(4, 4, 2);
        a.set_quota(T1, TenantQuota::bounded(0, 2));
        let b0 = a.alloc(T1).unwrap().id;
        let _b1 = a.alloc(T1).unwrap().id;
        assert!(a.alloc(T1).is_none(), "ceiling reached");
        assert_eq!(a.quota_denials, 1);
        assert!(a.at_ceiling(T1), "other tenants' frees cannot help T1");
        assert!(!a.at_ceiling(T2));
        // another tenant still allocates freely
        assert!(a.alloc(T2).is_some());
        assert_charges(&a, &[(T1, 2), (T2, 1)]);
        // releasing makes room under the ceiling again
        a.decref(b0);
        assert!(!a.at_ceiling(T1));
        assert!(a.alloc(T1).is_some());
    }

    #[test]
    fn reserved_floor_is_protected_from_other_tenants() {
        let mut a = BlockAllocator::new(4, 4, 2);
        a.set_quota(T1, TenantQuota::reserved(2));
        // T2 may take only pool - T1's unused floor = 2 blocks
        assert_eq!(a.available_to(T2), 2);
        assert!(a.alloc(T2).is_some());
        assert!(a.alloc(T2).is_some());
        assert!(a.alloc(T2).is_none(), "floor protected");
        assert_eq!(a.quota_denials, 1);
        // T1 itself can still take its full floor
        assert_eq!(a.available_to(T1), 2);
        assert!(a.alloc(T1).is_some());
        assert!(a.alloc(T1).is_some());
        assert!(a.alloc(T1).is_none(), "pool genuinely exhausted now");
        // as T1 uses its floor, T2's availability does not grow
        assert_eq!(a.available_to(T2), 0);
        assert!(a.over_quota(T2), "T2 bursts past its (zero) floor");
        assert!(!a.over_quota(T1), "T1 sits exactly at its floor");
    }

    #[test]
    fn revive_of_cached_block_is_quota_gated_and_charged() {
        let mut a = BlockAllocator::new(3, 4, 2);
        a.set_quota(T2, TenantQuota::reserved(2));
        let b = a.alloc(T1).unwrap().id;
        a.seal(b, 7);
        a.decref(b); // cached, uncharged
        assert_eq!(a.held(T1), 0);
        // reviving the cached block would eat T2's floor (allocatable 3,
        // T2 floor 2, T1 already... 0 held; available_to(T1) = 1) — one
        // revive fits, a second take does not
        assert_eq!(a.revive(b, T1), Revive::Revived);
        assert_eq!(a.held(T1), 1, "revival charges the reviving tenant");
        assert!(a.alloc(T1).is_none(), "floor blocks the second take");
        assert_eq!(a.quota_denials, 1);
        // live-block sharing is free and never quota-gated
        assert_eq!(a.revive(b, T2), Revive::Revived);
        assert_eq!(a.held(T2), 0, "sharer is not charged");
        assert_eq!(a.meta(b).ref_count, 2);
        // OverQuota must NOT be reported as Stale: with a ceiling of 0,
        // T0 cannot revive a cached block, but the map entry stays valid
        a.decref(b);
        a.decref(b); // cached again
        a.set_quota(T0, TenantQuota::bounded(0, 0));
        assert_eq!(a.revive(b, T0), Revive::OverQuota);
        assert_eq!(a.revive(b, T1), Revive::Revived, "entry still valid");
    }

    #[test]
    fn first_toucher_charge_follows_live_period() {
        let mut a = BlockAllocator::new(3, 4, 2);
        a.set_quota(T1, TenantQuota::default());
        let b = a.alloc(T1).unwrap().id;
        a.seal(b, 9);
        a.incref(b); // T2 shares it (e.g. prefix hit): no charge
        assert_charges(&a, &[(T1, 1), (T2, 0)]);
        // first toucher drops its ref; the charge stays with T1 while the
        // block is live (documented first-toucher consequence)
        a.decref(b);
        assert_charges(&a, &[(T1, 1), (T2, 0)]);
        // last ref gone: uncharged; a revival by T2 charges T2
        a.decref(b);
        assert_charges(&a, &[(T1, 0), (T2, 0)]);
        assert_eq!(a.revive(b, T2), Revive::Revived);
        assert_charges(&a, &[(T1, 0), (T2, 1)]);
        assert_eq!(a.blocks_in_use(), 1);
    }

    #[test]
    fn can_take_after_release_simulates_uncharges() {
        // Quota installed *after* the pool filled, so the drained state
        // already violates T1's floor — exactly the situation compaction
        // feasibility has to reason about.
        let mut a = BlockAllocator::new(4, 4, 2);
        let r0 = a.alloc(T2).unwrap().id;
        let r1 = a.alloc(T2).unwrap().id;
        let _r2 = a.alloc(T2).unwrap().id;
        let t1b = a.alloc(T1).unwrap().id;
        a.set_quota(T1, TenantQuota::reserved(2));
        assert_eq!(a.allocatable(), 0);
        // T1 holds 1 of its floor of 2: one of the two blocks a T2
        // release frees is owed to T1, so T2 may rebuild into only one
        assert!(a.can_take_after_release(T2, 1, &[r0, r1]));
        assert!(
            !a.can_take_after_release(T2, 2, &[r0, r1]),
            "second freed block is owed to T1's unused floor"
        );
        // T1's own rebuild is not taxed by its own floor
        assert!(a.can_take_after_release(T1, 1, &[t1b]));
        assert!(!a.can_take_after_release(T1, 2, &[t1b]));
        // shared blocks (ref > 1) free nothing
        a.incref(r0);
        assert!(!a.can_take_after_release(T2, 1, &[r0]));
    }

    #[test]
    fn max_ever_available_respects_floors_and_ceiling() {
        let mut a = BlockAllocator::new(10, 4, 2);
        assert_eq!(a.max_ever_available(T0), 10, "no quotas: whole pool");
        a.set_quota(T1, TenantQuota::reserved(3));
        a.set_quota(T2, TenantQuota::bounded(2, 4));
        assert_eq!(a.max_ever_available(T0), 10 - 3 - 2);
        assert_eq!(a.max_ever_available(T1), 10 - 2, "own floor not counted");
        assert_eq!(a.max_ever_available(T2), 4, "ceiling caps it");
    }

    fn assert_charges(a: &BlockAllocator, want: &[(TenantId, usize)]) {
        for &(t, n) in want {
            assert_eq!(a.held(t), n, "held({t:?})");
        }
        let total: usize = a.tenants().iter().map(|&t| a.held(t)).sum();
        assert_eq!(total, a.blocks_in_use(), "Σ held == blocks_in_use");
    }
}
