//! Admission & scheduling policy for the serving loop.
//!
//! Implements continuous batching with decode-priority: free decode slots
//! are refilled from the FCFS queue (one prefill at a time — prefills are
//! long and run on the same device), and decoding proceeds in lockstep
//! batched steps between admissions. This mirrors the vLLM-style router
//! architecture referenced in DESIGN.md, scaled to one device.

use std::collections::VecDeque;

/// What the serving loop should do next.
#[derive(Debug, PartialEq, Eq)]
pub enum Action {
    /// Run the prefill for the queued request at this queue index.
    Prefill,
    /// Run one batched decode step over the active set.
    DecodeStep,
    /// Nothing to do; block for new work.
    Idle,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmitOrder {
    /// First come, first served.
    Fcfs,
    /// Shortest prompt first (reduces head-of-line blocking for mixed
    /// lengths; used by the ablation bench).
    ShortestFirst,
}

#[derive(Debug)]
pub struct Scheduler<T> {
    queue: VecDeque<T>,
    pub order: AdmitOrder,
    /// Admit only when at least this many decode slots are free AND the
    /// active set has drained below the watermark (hysteresis avoids
    /// thrashing between prefill and decode).
    pub max_active: usize,
}

impl<T> Scheduler<T> {
    pub fn new(max_active: usize, order: AdmitOrder) -> Self {
        Scheduler { queue: VecDeque::new(), order, max_active }
    }

    pub fn enqueue(&mut self, item: T) {
        self.queue.push_back(item);
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Decide the next action given the number of active decode slots.
    pub fn next_action(&self, active: usize) -> Action {
        if active < self.max_active && !self.queue.is_empty() {
            Action::Prefill
        } else if active > 0 {
            Action::DecodeStep
        } else {
            Action::Idle
        }
    }

    /// Pop the next request to admit per the configured order.
    /// `prompt_len` extracts the length for ShortestFirst.
    pub fn pop_next(&mut self, prompt_len: impl Fn(&T) -> usize) -> Option<T> {
        match self.order {
            AdmitOrder::Fcfs => self.queue.pop_front(),
            AdmitOrder::ShortestFirst => {
                let idx = self
                    .queue
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, t)| prompt_len(t))?
                    .0;
                self.queue.remove(idx)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admit_then_decode_then_idle() {
        let mut s: Scheduler<usize> = Scheduler::new(2, AdmitOrder::Fcfs);
        assert_eq!(s.next_action(0), Action::Idle);
        s.enqueue(10);
        s.enqueue(20);
        s.enqueue(30);
        assert_eq!(s.next_action(0), Action::Prefill);
        assert_eq!(s.next_action(1), Action::Prefill);
        // active full -> decode even though queue non-empty
        assert_eq!(s.next_action(2), Action::DecodeStep);
        s.queue.clear();
        assert_eq!(s.next_action(1), Action::DecodeStep);
        assert_eq!(s.next_action(0), Action::Idle);
    }

    #[test]
    fn fcfs_order() {
        let mut s: Scheduler<usize> = Scheduler::new(4, AdmitOrder::Fcfs);
        s.enqueue(5);
        s.enqueue(1);
        assert_eq!(s.pop_next(|&x| x), Some(5));
        assert_eq!(s.pop_next(|&x| x), Some(1));
    }

    #[test]
    fn shortest_first_order() {
        let mut s: Scheduler<usize> =
            Scheduler::new(4, AdmitOrder::ShortestFirst);
        s.enqueue(50);
        s.enqueue(10);
        s.enqueue(30);
        assert_eq!(s.pop_next(|&x| x), Some(10));
        assert_eq!(s.pop_next(|&x| x), Some(30));
        assert_eq!(s.pop_next(|&x| x), Some(50));
    }
}
