//! Admission & scheduling policy for the serving loop.
//!
//! Implements continuous batching with decode-priority: free decode slots
//! are refilled from the FCFS queue (one prefill at a time — prefills are
//! long and run on the same device), and decoding proceeds in lockstep
//! batched steps between admissions. This mirrors the vLLM-style router
//! architecture referenced in DESIGN.md, scaled to one device.

use std::collections::VecDeque;

/// What the serving loop should do next.
#[derive(Debug, PartialEq, Eq)]
pub enum Action {
    /// Run the prefill for the queued request at this queue index.
    Prefill,
    /// Run one batched decode step over the active set.
    DecodeStep,
    /// Nothing to do; block for new work.
    Idle,
}

/// Choose which active lane to preempt when the block pool runs dry:
/// the lane with the least decode progress (fewest generated tokens)
/// loses the least recompute work on resume; ties break toward the lane
/// holding the fewest blocks (its re-admission is cheapest). Candidates
/// are `(progress, held_blocks)` pairs; returns the winning index.
pub fn pick_preemption_victim(candidates: &[(usize, usize)]) -> Option<usize> {
    candidates
        .iter()
        .enumerate()
        .min_by_key(|(_, &(progress, blocks))| (progress, blocks))
        .map(|(i, _)| i)
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmitOrder {
    /// First come, first served.
    Fcfs,
    /// Shortest prompt first (reduces head-of-line blocking for mixed
    /// lengths; used by the ablation bench).
    ShortestFirst,
}

#[derive(Debug)]
pub struct Scheduler<T> {
    queue: VecDeque<T>,
    /// Preempted requests waiting to resume. Always admitted before the
    /// regular queue — under *any* admit order — so preemption never
    /// starves a request (ShortestFirst would otherwise keep picking
    /// fresh short prompts over a preempted long one forever). Entries
    /// carry their resume capital with them: a swap handle to
    /// host-parked KV (restore, zero prefill) or just the generated
    /// tokens (recompute fallback) — see `server::SwapResume`.
    resume: VecDeque<T>,
    pub order: AdmitOrder,
    /// Admit only when at least this many decode slots are free AND the
    /// active set has drained below the watermark (hysteresis avoids
    /// thrashing between prefill and decode).
    pub max_active: usize,
}

impl<T> Scheduler<T> {
    pub fn new(max_active: usize, order: AdmitOrder) -> Self {
        Scheduler {
            queue: VecDeque::new(),
            resume: VecDeque::new(),
            order,
            max_active,
        }
    }

    pub fn enqueue(&mut self, item: T) {
        self.queue.push_back(item);
    }

    /// Put a preempted request on the resume queue: it is re-admitted
    /// before anything in the regular queue once memory frees up
    /// (resume, not starve), FIFO among preempted peers.
    pub fn requeue_front(&mut self, item: T) {
        self.resume.push_back(item);
    }

    pub fn queue_len(&self) -> usize {
        self.resume.len() + self.queue.len()
    }

    /// Preempted requests currently parked for resume (resume-queue
    /// depth gauge).
    pub fn resume_len(&self) -> usize {
        self.resume.len()
    }

    /// Decide the next action given the number of active decode slots.
    pub fn next_action(&self, active: usize) -> Action {
        self.next_action_mem(active, true)
    }

    /// Memory-aware variant: `can_admit` is the KV store's verdict on
    /// whether the head-of-queue request's post-compression KV budget fits
    /// the block pool. When it does not, queued work waits and decoding
    /// continues (draining the pool) instead of admitting a request that
    /// would immediately be preempted.
    pub fn next_action_mem(&self, active: usize, can_admit: bool) -> Action {
        if active < self.max_active && self.queue_len() > 0 && can_admit {
            Action::Prefill
        } else if active > 0 {
            Action::DecodeStep
        } else {
            Action::Idle
        }
    }

    /// Borrow the request `pop_next` would return, without removing it
    /// (admission checks need its prompt length first).
    pub fn peek_next(&self, prompt_len: impl Fn(&T) -> usize) -> Option<&T> {
        if let Some(r) = self.resume.front() {
            return Some(r);
        }
        match self.order {
            AdmitOrder::Fcfs => self.queue.front(),
            AdmitOrder::ShortestFirst => {
                self.queue.iter().min_by_key(|t| prompt_len(*t))
            }
        }
    }

    /// Pop the next request to admit: preempted requests first, then the
    /// regular queue per the configured order. `prompt_len` extracts the
    /// length for ShortestFirst.
    pub fn pop_next(&mut self, prompt_len: impl Fn(&T) -> usize) -> Option<T> {
        if let Some(r) = self.resume.pop_front() {
            return Some(r);
        }
        match self.order {
            AdmitOrder::Fcfs => self.queue.pop_front(),
            AdmitOrder::ShortestFirst => {
                let idx = self
                    .queue
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, t)| prompt_len(t))?
                    .0;
                self.queue.remove(idx)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admit_then_decode_then_idle() {
        let mut s: Scheduler<usize> = Scheduler::new(2, AdmitOrder::Fcfs);
        assert_eq!(s.next_action(0), Action::Idle);
        s.enqueue(10);
        s.enqueue(20);
        s.enqueue(30);
        assert_eq!(s.next_action(0), Action::Prefill);
        assert_eq!(s.next_action(1), Action::Prefill);
        // active full -> decode even though queue non-empty
        assert_eq!(s.next_action(2), Action::DecodeStep);
        s.queue.clear();
        assert_eq!(s.next_action(1), Action::DecodeStep);
        assert_eq!(s.next_action(0), Action::Idle);
    }

    #[test]
    fn fcfs_order() {
        let mut s: Scheduler<usize> = Scheduler::new(4, AdmitOrder::Fcfs);
        s.enqueue(5);
        s.enqueue(1);
        assert_eq!(s.pop_next(|&x| x), Some(5));
        assert_eq!(s.pop_next(|&x| x), Some(1));
    }

    #[test]
    fn memory_pressure_blocks_admission_but_not_decode() {
        let mut s: Scheduler<usize> = Scheduler::new(2, AdmitOrder::Fcfs);
        s.enqueue(10);
        // pool says no: keep decoding instead of admitting
        assert_eq!(s.next_action_mem(1, false), Action::DecodeStep);
        assert_eq!(s.next_action_mem(1, true), Action::Prefill);
        // nothing active and nothing admissible: wait for memory
        assert_eq!(s.next_action_mem(0, false), Action::Idle);
    }

    #[test]
    fn requeue_front_resumes_before_queue_under_any_order() {
        // Regression: under ShortestFirst a push_front-based requeue was a
        // no-op — fresh short prompts kept overtaking the preempted
        // request forever. The resume queue must win under both orders.
        for order in [AdmitOrder::Fcfs, AdmitOrder::ShortestFirst] {
            let mut s: Scheduler<usize> = Scheduler::new(4, order);
            s.enqueue(1);
            s.enqueue(2);
            let preempted = 99; // longer than everything queued
            s.requeue_front(preempted);
            assert_eq!(s.queue_len(), 3);
            assert_eq!(*s.peek_next(|&x| x).unwrap(), 99, "{order:?}");
            assert_eq!(s.pop_next(|&x| x), Some(99), "{order:?}");
            assert_eq!(s.pop_next(|&x| x), Some(1));
        }
    }

    #[test]
    fn victim_is_least_progress_then_fewest_blocks() {
        // least generated tokens wins outright
        assert_eq!(
            pick_preemption_victim(&[(10, 1), (2, 50), (7, 0)]),
            Some(1)
        );
        // tie on progress -> fewest held blocks
        assert_eq!(
            pick_preemption_victim(&[(3, 9), (3, 2), (5, 0)]),
            Some(1)
        );
        // stable choice for full ties: first candidate
        assert_eq!(pick_preemption_victim(&[(3, 2), (3, 2)]), Some(0));
        assert_eq!(pick_preemption_victim(&[]), None);
    }

    #[test]
    fn peek_matches_pop() {
        for order in [AdmitOrder::Fcfs, AdmitOrder::ShortestFirst] {
            let mut s: Scheduler<usize> = Scheduler::new(4, order);
            s.enqueue(50);
            s.enqueue(10);
            s.enqueue(30);
            let peeked = *s.peek_next(|&x| x).unwrap();
            assert_eq!(s.pop_next(|&x| x), Some(peeked));
        }
        let s: Scheduler<usize> = Scheduler::new(4, AdmitOrder::Fcfs);
        assert!(s.peek_next(|&x| x).is_none());
    }

    #[test]
    fn shortest_first_order() {
        let mut s: Scheduler<usize> =
            Scheduler::new(4, AdmitOrder::ShortestFirst);
        s.enqueue(50);
        s.enqueue(10);
        s.enqueue(30);
        assert_eq!(s.pop_next(|&x| x), Some(10));
        assert_eq!(s.pop_next(|&x| x), Some(30));
        assert_eq!(s.pop_next(|&x| x), Some(50));
    }
}
