//! Admission & scheduling policy for the serving loop.
//!
//! Implements continuous batching with decode-priority: free decode slots
//! are refilled from the FCFS queue (one prefill at a time — prefills are
//! long and run on the same device), and decoding proceeds in lockstep
//! batched steps between admissions. This mirrors the vLLM-style router
//! architecture referenced in DESIGN.md, scaled to one device.
//!
//! Under multi-tenant quotas admission is *fair* rather than strictly
//! head-of-line: [`Scheduler::pop_admissible`] takes the first queued
//! request that passes the memory-and-quota gate, so a light tenant's
//! request steps past a quota-blocked heavy one instead of starving
//! behind it, and [`pick_preemption_victim`] prefers lanes of tenants
//! bursting past their reserved floor.

use std::collections::VecDeque;

/// What the serving loop should do next.
#[derive(Debug, PartialEq, Eq)]
pub enum Action {
    /// Run the prefill for the queued request at this queue index.
    Prefill,
    /// Run the next chunk of the in-flight chunked prefill.
    PrefillChunk,
    /// Run one batched decode step over the active set.
    DecodeStep,
    /// Nothing to do; block for new work.
    Idle,
}

/// Choose which active lane to preempt when the block pool runs dry.
/// Candidates are `(over_quota, progress, held_blocks)` triples:
///
/// 1. lanes whose **tenant is bursting past its reserved floor**
///    (`over_quota`, from `KvStore::tenant_over_quota`) are preferred —
///    quota pressure lands on the tenant causing it, not on a tenant
///    inside its guaranteed floor (always `false` when no quotas are
///    configured, restoring the pre-tenancy ordering);
/// 2. then the lane with the least decode progress (fewest generated
///    tokens), which loses the least recompute work on resume;
/// 3. ties break toward the lane holding the fewest blocks (its
///    re-admission is cheapest).
///
/// Returns the winning index.
pub fn pick_preemption_victim(
    candidates: &[(bool, usize, usize)],
) -> Option<usize> {
    candidates
        .iter()
        .enumerate()
        .min_by_key(|(_, &(over_quota, progress, blocks))| {
            (!over_quota, progress, blocks)
        })
        .map(|(i, _)| i)
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmitOrder {
    /// First come, first served.
    Fcfs,
    /// Shortest prompt first (reduces head-of-line blocking for mixed
    /// lengths; used by the ablation bench).
    ShortestFirst,
}

#[derive(Debug)]
pub struct Scheduler<T> {
    queue: VecDeque<T>,
    /// Preempted requests waiting to resume. Always admitted before the
    /// regular queue — under *any* admit order — so preemption never
    /// starves a request (ShortestFirst would otherwise keep picking
    /// fresh short prompts over a preempted long one forever). Entries
    /// carry their resume capital with them: a swap handle to
    /// host-parked KV (restore, zero prefill) or just the generated
    /// tokens (recompute fallback) — see `server::SwapResume`.
    resume: VecDeque<T>,
    pub order: AdmitOrder,
    /// Admit only when at least this many decode slots are free AND the
    /// active set has drained below the watermark (hysteresis avoids
    /// thrashing between prefill and decode).
    pub max_active: usize,
}

impl<T> Scheduler<T> {
    pub fn new(max_active: usize, order: AdmitOrder) -> Self {
        Scheduler {
            queue: VecDeque::new(),
            resume: VecDeque::new(),
            order,
            max_active,
        }
    }

    pub fn enqueue(&mut self, item: T) {
        self.queue.push_back(item);
    }

    /// Put a preempted request on the resume queue: it is re-admitted
    /// before anything in the regular queue once memory frees up
    /// (resume, not starve), FIFO among preempted peers.
    pub fn requeue_front(&mut self, item: T) {
        self.resume.push_back(item);
    }

    pub fn queue_len(&self) -> usize {
        self.resume.len() + self.queue.len()
    }

    /// Preempted requests currently parked for resume (resume-queue
    /// depth gauge).
    pub fn resume_len(&self) -> usize {
        self.resume.len()
    }

    /// Decide the next action given the number of active decode slots.
    pub fn next_action(&self, active: usize) -> Action {
        self.next_action_mem(active, true)
    }

    /// Memory-aware variant: `can_admit` is the KV store's verdict on
    /// whether the head-of-queue request's post-compression KV budget fits
    /// the block pool. When it does not, queued work waits and decoding
    /// continues (draining the pool) instead of admitting a request that
    /// would immediately be preempted.
    pub fn next_action_mem(&self, active: usize, can_admit: bool) -> Action {
        if active < self.max_active && self.queue_len() > 0 && can_admit {
            Action::Prefill
        } else if active > 0 {
            Action::DecodeStep
        } else {
            Action::Idle
        }
    }

    /// Post-pop, chunk-aware action decision for the serving loop's
    /// admission sweep.
    ///
    /// The sweep *pops* the winning request before deciding the action,
    /// so `queue_len` has already shrunk by the time any decision runs —
    /// [`Scheduler::next_action_mem`] re-reading it would see the stale
    /// post-pop count and could return `Idle`/`DecodeStep` with the
    /// popped request still in hand (dropping it on the floor when the
    /// pop emptied the queue). This variant takes the sweep's own
    /// verdict instead: `popped` — whether a request was actually popped
    /// this iteration — is the post-pop truth, and `Prefill` is returned
    /// exactly when there is a popped request to act on.
    ///
    /// `chunk_credit` is `Some(decode_credit)` while a chunked prefill
    /// is in flight: the loop owes its active lanes `decode_credit`
    /// decode rounds before the next chunk (continuous batching
    /// interleave); credit exhausted (or no active lanes to serve) runs
    /// the chunk. A popped request still takes priority — swap-resumes
    /// and deferred admissions stay cheap and must not starve behind a
    /// long chunked admission.
    pub fn next_action_chunked(
        &self,
        active: usize,
        popped: bool,
        chunk_credit: Option<usize>,
    ) -> Action {
        if popped {
            return Action::Prefill;
        }
        match chunk_credit {
            Some(credit) if credit > 0 && active > 0 => Action::DecodeStep,
            Some(_) => Action::PrefillChunk,
            None if active > 0 => Action::DecodeStep,
            None => Action::Idle,
        }
    }

    /// Borrow the request `pop_next` would return, without removing it
    /// (admission checks need its prompt length first).
    pub fn peek_next(&self, prompt_len: impl Fn(&T) -> usize) -> Option<&T> {
        if let Some(r) = self.resume.front() {
            return Some(r);
        }
        match self.order {
            AdmitOrder::Fcfs => self.queue.front(),
            AdmitOrder::ShortestFirst => {
                self.queue.iter().min_by_key(|t| prompt_len(*t))
            }
        }
    }

    /// Pop the next request to admit: preempted requests first, then the
    /// regular queue per the configured order. `prompt_len` extracts the
    /// length for ShortestFirst.
    pub fn pop_next(&mut self, prompt_len: impl Fn(&T) -> usize) -> Option<T> {
        if let Some(r) = self.resume.pop_front() {
            return Some(r);
        }
        match self.order {
            AdmitOrder::Fcfs => self.queue.pop_front(),
            AdmitOrder::ShortestFirst => {
                let idx = self
                    .queue
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, t)| prompt_len(t))?
                    .0;
                self.queue.remove(idx)
            }
        }
    }

    /// Whether any queued request passes the `ok` predicate (the serving
    /// loop's memory-and-quota admission gate). Companion to
    /// [`Scheduler::pop_admissible`].
    pub fn has_admissible(&self, mut ok: impl FnMut(&T) -> bool) -> bool {
        self.resume.iter().any(|t| ok(t)) || self.queue.iter().any(|t| ok(t))
    }

    /// Fair admission: pop the first request that passes the `ok`
    /// predicate instead of head-blocking on an inadmissible one.
    /// Preempted requests are still scanned first (FIFO among
    /// themselves), then the regular queue per the configured order. With
    /// a single tenant this degrades gracefully — the head is admissible
    /// whenever anything is, since every request draws on the same pool —
    /// but under per-tenant quotas it is what lets a light tenant's
    /// request step past a quota-blocked heavy one at the head of the
    /// queue rather than starve behind it.
    ///
    /// Lifecycle tracing rides on the `ok` predicate: the serving loop's
    /// gate closure records a `QuotaDefer` event (plus a `QuotaBlocked`
    /// flight-recorder incident) for requests it turns down *because of
    /// quota*, so per-request traces show why admission was skipped even
    /// though this scheduler never touches the tracer itself.
    pub fn pop_admissible(
        &mut self,
        prompt_len: impl Fn(&T) -> usize,
        mut ok: impl FnMut(&T) -> bool,
    ) -> Option<T> {
        if let Some(i) = self.resume.iter().position(|t| ok(t)) {
            return self.resume.remove(i);
        }
        let idx = match self.order {
            AdmitOrder::Fcfs => self.queue.iter().position(|t| ok(t))?,
            AdmitOrder::ShortestFirst => {
                self.queue
                    .iter()
                    .enumerate()
                    .filter(|&(_, t)| ok(t))
                    .min_by_key(|&(_, t)| prompt_len(t))?
                    .0
            }
        };
        self.queue.remove(idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admit_then_decode_then_idle() {
        let mut s: Scheduler<usize> = Scheduler::new(2, AdmitOrder::Fcfs);
        assert_eq!(s.next_action(0), Action::Idle);
        s.enqueue(10);
        s.enqueue(20);
        s.enqueue(30);
        assert_eq!(s.next_action(0), Action::Prefill);
        assert_eq!(s.next_action(1), Action::Prefill);
        // active full -> decode even though queue non-empty
        assert_eq!(s.next_action(2), Action::DecodeStep);
        s.queue.clear();
        assert_eq!(s.next_action(1), Action::DecodeStep);
        assert_eq!(s.next_action(0), Action::Idle);
    }

    #[test]
    fn fcfs_order() {
        let mut s: Scheduler<usize> = Scheduler::new(4, AdmitOrder::Fcfs);
        s.enqueue(5);
        s.enqueue(1);
        assert_eq!(s.pop_next(|&x| x), Some(5));
        assert_eq!(s.pop_next(|&x| x), Some(1));
    }

    #[test]
    fn memory_pressure_blocks_admission_but_not_decode() {
        let mut s: Scheduler<usize> = Scheduler::new(2, AdmitOrder::Fcfs);
        s.enqueue(10);
        // pool says no: keep decoding instead of admitting
        assert_eq!(s.next_action_mem(1, false), Action::DecodeStep);
        assert_eq!(s.next_action_mem(1, true), Action::Prefill);
        // nothing active and nothing admissible: wait for memory
        assert_eq!(s.next_action_mem(0, false), Action::Idle);
    }

    #[test]
    fn requeue_front_resumes_before_queue_under_any_order() {
        // Regression: under ShortestFirst a push_front-based requeue was a
        // no-op — fresh short prompts kept overtaking the preempted
        // request forever. The resume queue must win under both orders.
        for order in [AdmitOrder::Fcfs, AdmitOrder::ShortestFirst] {
            let mut s: Scheduler<usize> = Scheduler::new(4, order);
            s.enqueue(1);
            s.enqueue(2);
            let preempted = 99; // longer than everything queued
            s.requeue_front(preempted);
            assert_eq!(s.queue_len(), 3);
            assert_eq!(*s.peek_next(|&x| x).unwrap(), 99, "{order:?}");
            assert_eq!(s.pop_next(|&x| x), Some(99), "{order:?}");
            assert_eq!(s.pop_next(|&x| x), Some(1));
        }
    }

    #[test]
    fn victim_is_least_progress_then_fewest_blocks() {
        // no tenant over quota: least generated tokens wins outright
        assert_eq!(
            pick_preemption_victim(&[
                (false, 10, 1),
                (false, 2, 50),
                (false, 7, 0)
            ]),
            Some(1)
        );
        // tie on progress -> fewest held blocks
        assert_eq!(
            pick_preemption_victim(&[
                (false, 3, 9),
                (false, 3, 2),
                (false, 5, 0)
            ]),
            Some(1)
        );
        // stable choice for full ties: first candidate
        assert_eq!(
            pick_preemption_victim(&[(false, 3, 2), (false, 3, 2)]),
            Some(0)
        );
        assert_eq!(pick_preemption_victim(&[]), None);
    }

    #[test]
    fn victim_prefers_over_quota_tenants() {
        // an over-quota lane loses even against a least-progress one
        assert_eq!(
            pick_preemption_victim(&[
                (false, 0, 1),
                (true, 50, 99),
                (false, 2, 0)
            ]),
            Some(1)
        );
        // among over-quota lanes, least progress then fewest blocks
        assert_eq!(
            pick_preemption_victim(&[
                (true, 5, 1),
                (true, 2, 9),
                (true, 2, 3),
                (false, 0, 0)
            ]),
            Some(2)
        );
    }

    #[test]
    fn pop_admissible_skips_blocked_head() {
        let mut s: Scheduler<usize> = Scheduler::new(4, AdmitOrder::Fcfs);
        s.enqueue(100); // quota-blocked head
        s.enqueue(7);
        s.enqueue(8);
        assert!(s.has_admissible(|&x| x < 50));
        // the blocked head is skipped, FIFO among the admissible rest
        assert_eq!(s.pop_admissible(|&x| x, |&x| x < 50), Some(7));
        assert_eq!(s.pop_admissible(|&x| x, |&x| x < 50), Some(8));
        assert_eq!(s.pop_admissible(|&x| x, |&x| x < 50), None);
        assert!(!s.has_admissible(|&x| x < 50));
        // the blocked request is still queued, not dropped
        assert_eq!(s.queue_len(), 1);
        assert_eq!(s.pop_next(|&x| x), Some(100));
    }

    #[test]
    fn pop_admissible_resume_first_and_shortest_order() {
        // resume entries win over fresher (even shorter) queued requests
        let mut s: Scheduler<usize> =
            Scheduler::new(4, AdmitOrder::ShortestFirst);
        s.enqueue(3);
        s.requeue_front(40);
        assert_eq!(s.pop_admissible(|&x| x, |_| true), Some(40));
        // inadmissible resume entries are skipped, then ShortestFirst
        // picks the shortest admissible queued request
        s.requeue_front(99);
        s.enqueue(10);
        assert_eq!(s.pop_admissible(|&x| x, |&x| x < 50), Some(3));
        assert_eq!(s.pop_admissible(|&x| x, |&x| x < 50), Some(10));
        assert_eq!(s.queue_len(), 1, "inadmissible resume entry kept");
    }

    #[test]
    fn peek_matches_pop() {
        for order in [AdmitOrder::Fcfs, AdmitOrder::ShortestFirst] {
            let mut s: Scheduler<usize> = Scheduler::new(4, order);
            s.enqueue(50);
            s.enqueue(10);
            s.enqueue(30);
            let peeked = *s.peek_next(|&x| x).unwrap();
            assert_eq!(s.pop_next(|&x| x), Some(peeked));
        }
        let s: Scheduler<usize> = Scheduler::new(4, AdmitOrder::Fcfs);
        assert!(s.peek_next(|&x| x).is_none());
    }

    #[test]
    fn post_pop_action_never_drops_the_popped_request() {
        // Regression: the admission sweep pops the winning request
        // BEFORE the action decision runs. With the last queued request
        // popped, queue_len() reads 0 — next_action_mem on that stale
        // count would return Idle and the popped request would be
        // dropped on the floor. next_action_chunked takes the sweep's
        // post-pop verdict instead.
        let mut s: Scheduler<usize> = Scheduler::new(2, AdmitOrder::Fcfs);
        s.enqueue(7);
        let popped = s.pop_admissible(|&x| x, |_| true);
        assert!(popped.is_some());
        assert_eq!(s.queue_len(), 0);
        // the stale-read hazard next_action_mem exposes:
        assert_eq!(s.next_action_mem(0, true), Action::Idle);
        // the post-pop decision acts on the popped request:
        assert_eq!(s.next_action_chunked(0, true, None), Action::Prefill);
        // ... and with nothing popped, falls back to decode/idle
        assert_eq!(s.next_action_chunked(1, false, None), Action::DecodeStep);
        assert_eq!(s.next_action_chunked(0, false, None), Action::Idle);
    }

    #[test]
    fn chunked_action_alternates_decode_and_chunks() {
        let s: Scheduler<usize> = Scheduler::new(2, AdmitOrder::Fcfs);
        // credit owed and lanes active: decode round first
        assert_eq!(
            s.next_action_chunked(3, false, Some(2)),
            Action::DecodeStep
        );
        // credit spent: run the next chunk
        assert_eq!(
            s.next_action_chunked(3, false, Some(0)),
            Action::PrefillChunk
        );
        // no active lanes: credit is moot, chunk immediately
        assert_eq!(
            s.next_action_chunked(0, false, Some(5)),
            Action::PrefillChunk
        );
        // a popped request (swap-resume / deferred admission) still
        // outranks the in-flight chunked prefill
        assert_eq!(
            s.next_action_chunked(3, true, Some(0)),
            Action::Prefill
        );
    }

    #[test]
    fn shortest_first_order() {
        let mut s: Scheduler<usize> =
            Scheduler::new(4, AdmitOrder::ShortestFirst);
        s.enqueue(50);
        s.enqueue(10);
        s.enqueue(30);
        assert_eq!(s.pop_next(|&x| x), Some(10));
        assert_eq!(s.pop_next(|&x| x), Some(30));
        assert_eq!(s.pop_next(|&x| x), Some(50));
    }
}
