//! The seven KV-compression policies: the paper's FastKV plus its five
//! baselines and the full-context reference.
//!
//! A policy turns a prompt into (first generated token, per-layer
//! compressed `RequestCache`, bookkeeping). All token selection runs here
//! in the coordinator, on the score summaries exported by the prefill
//! artifacts — see `selection.rs`.
//!
//! | policy        | prefill plan                  | KV selection        |
//! |---------------|-------------------------------|---------------------|
//! | full          | prefill_full                  | keep everything     |
//! | streaming_llm | prefill_full                  | sinks + recent      |
//! | h2o           | prefill_full                  | accumulated scores  |
//! | snapkv        | prefill_full                  | win scores (Eq.1-2) |
//! | gemfilter     | stage1 to filter layer, then  | = selected tokens   |
//! |               | re-prefill selected tokens    |   (coupled)         |
//! | pyramid_infer | prefill_pyramid (cosine decay)| = per-layer tokens  |
//! |               |                               |   (coupled)         |
//! | fastkv        | stage1 full-ctx -> TSP ->     | win scores per layer|
//! |               | stage2 on selected            |   (decoupled)       |

use anyhow::{bail, Context, Result};

use crate::coordinator::kvcache::RequestCache;
use crate::coordinator::paging::DecodeBudget;
use crate::coordinator::selection as sel;
use crate::manifest::{prefill_stage1_chunk_artifact_name, Manifest};
use crate::runtime::outputs::{
    PrefillFullOut, PyramidOut, Stage1ChunkOut, Stage1Out, Stage2Out,
};
use crate::runtime::In;
use crate::tensor::{HostTensor, HostTensorI32};
use crate::util::bucket_for;

/// Execution abstraction: the single-threaded `Runtime` or the channel
/// backed `ExecutorHandle` both implement it.
pub trait Exec {
    fn run(&self, name: &str, inputs: Vec<In>) -> Result<Vec<HostTensor>>;

    /// Whether pinned input `key` is still resident on the executor at
    /// exactly `version` (lets callers skip materializing the payload).
    /// Executors without a pinned-buffer cache report `false`.
    fn pinned_is_current(&self, _key: &str, _version: u64) -> bool {
        false
    }

    /// Run with some inputs pinned on device across calls (the paged
    /// decode slab). The default splices the payloads in as ordinary
    /// inputs — correct for any executor, just without reuse.
    fn run_pinned(
        &self,
        name: &str,
        pinned: Vec<crate::runtime::PinnedInput>,
        inputs: Vec<In>,
    ) -> Result<Vec<HostTensor>> {
        let n = pinned.len() + inputs.len();
        let mut slots: Vec<Option<In>> = (0..n).map(|_| None).collect();
        for p in pinned {
            anyhow::ensure!(
                p.index < n && slots[p.index].is_none(),
                "pinned input `{}` index {} out of range or duplicated",
                p.key,
                p.index
            );
            let t = p.tensor.with_context(|| {
                format!(
                    "pinned input `{}` sent without payload to an \
                     executor that cannot cache it",
                    p.key
                )
            })?;
            slots[p.index] = Some(In::F32(t));
        }
        let mut rest = inputs.into_iter();
        let assembled: Vec<In> = slots
            .into_iter()
            .map(|s| s.or_else(|| rest.next()).expect("arity"))
            .collect();
        self.run(name, assembled)
    }

    /// Borrowing twin of [`Exec::run_pinned`] for scratch-driven hot
    /// loops (`decode::DecodeScratch`): the caller keeps ownership of
    /// every tensor, so steady-state decode performs zero heap
    /// allocation for input prep. Executors that can borrow (the
    /// in-thread `Runtime`) override this; the default clones once for
    /// executors that must move data across a thread boundary.
    fn run_pinned_ref(
        &self,
        name: &str,
        pinned: &[crate::runtime::PinnedInput],
        inputs: &[In],
    ) -> Result<Vec<HostTensor>> {
        self.run_pinned(name, pinned.to_vec(), inputs.to_vec())
    }
}

impl Exec for crate::runtime::Runtime {
    fn run(&self, name: &str, inputs: Vec<In>) -> Result<Vec<HostTensor>> {
        crate::runtime::Runtime::run(self, name, &inputs)
    }

    fn pinned_is_current(&self, key: &str, version: u64) -> bool {
        crate::runtime::Runtime::pinned_is_current(self, key, version)
    }

    fn run_pinned(
        &self,
        name: &str,
        pinned: Vec<crate::runtime::PinnedInput>,
        inputs: Vec<In>,
    ) -> Result<Vec<HostTensor>> {
        crate::runtime::Runtime::run_with_pinned(self, name, &pinned, &inputs)
    }

    fn run_pinned_ref(
        &self,
        name: &str,
        pinned: &[crate::runtime::PinnedInput],
        inputs: &[In],
    ) -> Result<Vec<HostTensor>> {
        // In-thread runtime: a true borrow, no clone anywhere.
        crate::runtime::Runtime::run_with_pinned(self, name, pinned, inputs)
    }
}

impl Exec for crate::runtime::exec_thread::ExecutorHandle {
    fn run(&self, name: &str, inputs: Vec<In>) -> Result<Vec<HostTensor>> {
        crate::runtime::exec_thread::ExecutorHandle::run(self, name, inputs)
    }

    fn pinned_is_current(&self, key: &str, version: u64) -> bool {
        crate::runtime::exec_thread::ExecutorHandle::pinned_is_current(
            self, key, version,
        )
    }

    fn run_pinned(
        &self,
        name: &str,
        pinned: Vec<crate::runtime::PinnedInput>,
        inputs: Vec<In>,
    ) -> Result<Vec<HostTensor>> {
        crate::runtime::exec_thread::ExecutorHandle::run_pinned(
            self, name, pinned, inputs,
        )
    }
}

/// Tunables shared by all policies (paper Section 5.1 defaults).
#[derive(Debug, Clone)]
pub struct PolicyCfg {
    /// KV retention rate (paper: 0.1 / 0.2).
    pub kv_rate: f64,
    /// TSP rate (paper: 0.2). Only used by fastkv.
    pub tsp_rate: f64,
    /// StreamingLLM attention sinks.
    pub sinks: usize,
    /// GemFilter filter layer (paper: 13 for a TSP layer of 15; here
    /// `tsp_layer - 1` by default, set in `PolicyCfg::default_for`).
    pub filter_layer: usize,
    /// Use the Pallas-kernel prefill artifact where available.
    pub use_pallas: bool,
    /// Hard cap (tokens) on the prefill-phase per-layer KV budget, layered
    /// on top of `kv_rate` (SCOPE-style split budgets: prefill and decode
    /// are bounded independently). 0 = rate-derived only.
    pub prefill_budget: usize,
    /// Decode-phase budget: generated-token KV rows attended per layer per
    /// lane. 0 = unbudgeted (generated KV grows until pool pressure), the
    /// pre-budget behavior. See [`PolicyCfg::decode_budget_spec`].
    pub decode_budget: usize,
    /// Sliding window of the most recent generated rows that decode
    /// eviction always retains (`default_for`: the model's observation
    /// window).
    pub decode_window: usize,
    /// Chunked-prefill chunk size in tokens (capped at the compiled
    /// chunk capacity `buckets.chunk_c`). 0 = monolithic prefill, the
    /// pre-chunking behavior.
    pub prefill_chunk: usize,
    /// Decode rounds the serve loop runs between consecutive prefill
    /// chunks (continuous batching interleave budget).
    pub prefill_decode_ratio: usize,
}

/// Coarse-stage slack factor: resident generated rows may exceed the
/// attended (fine) budget by this factor before cold blocks are
/// permanently released. RocketKV-style two-stage headroom — the fine
/// stage re-ranks within the survivors each step, so the coarse stage
/// must retain strictly more than the fine stage attends for the
/// re-ranking to have any freedom.
pub const DECODE_COARSE_SLACK: usize = 2;

impl PolicyCfg {
    pub fn default_for(man: &Manifest) -> PolicyCfg {
        PolicyCfg {
            kv_rate: 0.1,
            tsp_rate: 0.2,
            sinks: 4,
            filter_layer: man.model.tsp_layer.saturating_sub(1),
            use_pallas: false,
            prefill_budget: 0,
            decode_budget: 0,
            decode_window: man.model.window,
            prefill_chunk: 0,
            prefill_decode_ratio: 1,
        }
    }

    /// KV budget in tokens for a prompt of length `n` (≥ window so the
    /// observation window always fits). With `prefill_budget` set, the
    /// rate-derived budget is additionally capped at that many tokens
    /// (still floored at the window).
    pub fn kv_budget(&self, n: usize, window: usize) -> usize {
        let rate = ((self.kv_rate * n as f64).ceil() as usize).max(window).min(n);
        if self.prefill_budget == 0 {
            rate
        } else {
            rate.min(self.prefill_budget.max(window))
        }
    }

    /// Resolved decode-phase budget spec, or `None` when decode budgets
    /// are off (`decode_budget == 0`). The fine (attended-per-step) row
    /// count is floored at the sliding window; the coarse (resident) cap
    /// is [`DECODE_COARSE_SLACK`] times that, so the per-step top-k always
    /// has cold candidates to re-rank before the coarse stage permanently
    /// releases them.
    pub fn decode_budget_spec(&self) -> Option<DecodeBudget> {
        if self.decode_budget == 0 {
            return None;
        }
        let fine = self.decode_budget.max(self.decode_window).max(1);
        Some(DecodeBudget {
            fine_rows: fine,
            coarse_rows: fine.saturating_mul(DECODE_COARSE_SLACK),
            window: self.decode_window,
            sinks: self.sinks,
        })
    }

    pub fn tsp_count(&self, n: usize, window: usize) -> usize {
        ((self.tsp_rate * n as f64).ceil() as usize).max(window).min(n)
    }

    /// Worst-case per-layer retained tokens after this policy compresses a
    /// prompt of `n` tokens (the admission controller's estimate of the
    /// post-compression KV budget).
    pub fn per_layer_budget(&self, policy: &str, n: usize, window: usize) -> usize {
        match policy {
            // coupled / uncompressed policies retain up to the full prompt
            "full" | "pyramid_infer" => n,
            _ => self.kv_budget(n, window).max(self.tsp_count(n, window)),
        }
    }

    /// Decode-time eviction: per-layer keep-sets for block-granular
    /// compaction under memory pressure. Each layer keeps its attention
    /// sinks, the observation window, and the most recent tokens, shrunk
    /// to `shrink` of its current length (floored so the window + sinks
    /// always survive). The per-layer lengths come from the KV store, so
    /// FastKV's decoupled per-layer retention carries straight through to
    /// which blocks are released.
    pub fn compaction_keep(
        &self,
        layer_lens: &[usize],
        shrink: f64,
        window: usize,
    ) -> Vec<Vec<usize>> {
        layer_lens
            .iter()
            .map(|&n| {
                let target = ((n as f64 * shrink).floor() as usize)
                    .max(window + self.sinks)
                    .min(n);
                sel::select_streaming(n, target, self.sinks)
            })
            .collect()
    }
}

/// Prefill outcome handed to the decode engine.
#[derive(Debug)]
pub struct PrefillOutcome {
    pub first_token: i32,
    pub cache: RequestCache,
    /// Absolute position of the next (first generated) token.
    pub next_pos: usize,
    /// Final-layer hidden state at the last prompt position (Fig. 3).
    pub final_h: Vec<f32>,
    /// Σ_layers (tokens processed) — numerator of the prefill-compute
    /// rate reported in the paper's tables.
    pub compute_tokens: usize,
}

pub trait Policy: Send + Sync {
    fn name(&self) -> &'static str;
    fn prefill(
        &self,
        ex: &dyn Exec,
        man: &Manifest,
        tokens: &[i32],
        cfg: &PolicyCfg,
    ) -> Result<PrefillOutcome>;

    /// Begin a resumable chunked prefill, or `None` when this policy (or
    /// this manifest / this config) cannot chunk — the caller falls back
    /// to the blocking [`Policy::prefill`]. Chunk-capable policies
    /// (fastkv, gemfilter) return a driver when `cfg.prefill_chunk > 0`
    /// and the manifest carries the `prefill_stage1_chunk_*` family.
    fn begin_chunked(
        &self,
        man: &Manifest,
        tokens: &[i32],
        cfg: &PolicyCfg,
    ) -> Option<Result<Box<dyn ChunkedPrefill>>> {
        let _ = (man, tokens, cfg);
        None
    }
}

/// A resumable chunked stage-1 prefill owned by a chunk-capable policy.
///
/// The serve loop runs one [`ChunkedPrefill::step`] per scheduling slot,
/// interleaving decode rounds between chunks, then calls
/// [`ChunkedPrefill::finish`] exactly once after the last chunk (TSP
/// selection + stage 2 + KV compression run once, on the carried
/// buffers). The whole object is `Send` so a parked chunking lane can
/// ride the scheduler queues and resume from the completed-chunk
/// boundary with zero recomputed chunks.
pub trait ChunkedPrefill: Send + std::fmt::Debug {
    /// Total chunks in the plan.
    fn total_chunks(&self) -> usize;
    /// Chunks completed so far.
    fn chunks_done(&self) -> usize;
    /// Valid tokens in the next chunk (0 when all chunks are done).
    fn next_chunk_tokens(&self) -> usize;
    /// Run the next chunk; returns the number of tokens it processed.
    fn step(&mut self, ex: &dyn Exec, man: &Manifest) -> Result<usize>;
    /// Run the post-stage-1 tail (selection, stage 2, compression).
    /// Call exactly once, after `chunks_done() == total_chunks()`.
    fn finish(&mut self, ex: &dyn Exec, man: &Manifest)
        -> Result<PrefillOutcome>;
}

/// Split `n` prompt tokens into contiguous chunk spans `(start, len)`.
///
/// Every span is at most `chunk` tokens, and the final span always
/// contains at least `min(window, n)` tokens so the whole observation
/// window lives in the last chunk — that chunk's `win` output is then
/// bit-identical to the monolithic stage-1 window scores (see
/// `prefill_stage1_chunk` in `python/compile/model.py`). When the
/// leftover after full chunks would be smaller than the window, the
/// second-to-last span is shortened instead (spans need not be full:
/// the artifact masks `c_valid < chunk`).
pub fn chunk_spans(
    n: usize,
    chunk: usize,
    window: usize,
) -> Vec<(usize, usize)> {
    let w = window.min(n).max(1);
    let chunk = chunk.max(w);
    let mut spans = Vec::new();
    let mut pos = 0;
    while pos < n {
        let remaining = n - pos;
        let len = if remaining <= chunk {
            remaining
        } else if remaining - chunk < w {
            remaining - w
        } else {
            chunk
        };
        spans.push((pos, len));
        pos += len;
    }
    spans
}

/// All policy names, in the paper's table order.
pub const ALL_POLICIES: &[&str] = &[
    "full",
    "streaming_llm",
    "h2o",
    "snapkv",
    "pyramid_infer",
    "gemfilter",
    "fastkv",
];

pub fn make_policy(name: &str) -> Result<Box<dyn Policy>> {
    Ok(match name {
        "full" => Box::new(FullPolicy),
        "streaming_llm" => Box::new(StreamingPolicy),
        "h2o" => Box::new(H2OPolicy),
        "snapkv" => Box::new(SnapKVPolicy),
        "gemfilter" => Box::new(GemFilterPolicy),
        "pyramid_infer" => Box::new(PyramidPolicy),
        "fastkv" => Box::new(FastKVPolicy),
        other => bail!("unknown policy `{other}`"),
    })
}

// --------------------------------------------------------------------------
// shared helpers

fn pad_tokens(tokens: &[i32], bucket: usize) -> HostTensorI32 {
    let mut data = tokens.to_vec();
    data.resize(bucket, 0);
    HostTensorI32::new(vec![bucket], data)
}

fn run_prefill_full(
    ex: &dyn Exec,
    man: &Manifest,
    tokens: &[i32],
    use_pallas: bool,
) -> Result<(PrefillFullOut, usize)> {
    let n = tokens.len();
    if use_pallas && n <= man.buckets.pallas_n {
        let b = man.buckets.pallas_n;
        let name = format!("prefill_pallas_{b}");
        let out = ex.run(
            &name,
            vec![pad_tokens(tokens, b).into(), In::scalar_i32(n as i32)],
        )?;
        return Ok((PrefillFullOut::from_vec(out), b));
    }
    let b = bucket_for(n, &man.buckets.prefill_ns)
        .with_context(|| format!("prompt of {n} tokens exceeds prefill buckets"))?;
    let name = format!("prefill_full_{b}");
    let out = ex.run(
        &name,
        vec![pad_tokens(tokens, b).into(), In::scalar_i32(n as i32)],
    )?;
    Ok((PrefillFullOut::from_vec(out), b))
}

/// Per-layer group-wise SnapKV/FastKV-style compression from win scores
/// [layers, H, N] into `cache` layers [layer_off, layer_off + layers).
#[allow(
    clippy::too_many_arguments,
    reason = "internal helper shared by every policy's prefill; bundling \
              the per-layer slices into a struct would be built and torn \
              down on each call for no reuse"
)]
fn compress_layers_groupwise(
    cache: &mut RequestCache,
    k: &HostTensor,
    v: &HostTensor,
    win: &HostTensor,
    layer_off: usize,
    n_valid: usize,
    budget: usize,
    man: &Manifest,
) {
    let layers = win.shape[0];
    let h = win.shape[1];
    let n = win.shape[2];
    for l in 0..layers {
        let w = win.row(l);
        let groups = sel::select_kv_groupwise(
            w,
            h,
            n,
            n_valid,
            man.model.n_kv_heads,
            budget,
            man.model.window,
            man.model.pool_kernel,
        );
        cache.fill_layer_grouped(layer_off + l, k, v, l, &groups);
    }
}

/// Borrowed view of a completed stage-1 pass: either a monolithic
/// [`Stage1Out`] or the chunked driver's accumulated host buffers —
/// the tails below cannot tell the difference, which is what makes
/// chunked ≡ monolithic exact end to end.
struct Stage1View<'a> {
    hidden: &'a HostTensor,
    k: &'a HostTensor,
    v: &'a HostTensor,
    win: &'a HostTensor,
}

/// FastKV's post-stage-1 tail: TSP selection on the last stage-1
/// layer's window scores (Eq. 1-2), stage 2 over the selected hidden
/// rows, then decoupled layer-wise KV compression.
fn fastkv_tail(
    ex: &dyn Exec,
    man: &Manifest,
    cfg: &PolicyCfg,
    n: usize,
    s1: Stage1View<'_>,
) -> Result<PrefillOutcome> {
    let t = man.model.tsp_layer;
    let lall = man.model.n_layers;

    let k_tsp = cfg.tsp_count(n, man.model.window);
    let (h, nb) = (s1.win.shape[1], s1.win.shape[2]);
    let tsp = sel::select_salient(
        s1.win.row(t - 1),
        h,
        nb,
        n,
        k_tsp,
        man.model.window,
        man.model.pool_kernel,
    );

    // Stage 2: propagate selected hidden states through layers [T, L).
    let b2 = bucket_for(tsp.len(), &man.buckets.stage2_ns)
        .context("TSP count exceeds stage2 buckets")?;
    let d = man.model.d_model;
    let mut hidden = vec![0.0f32; b2 * d];
    let mut positions = vec![0i32; b2];
    for (row, &tok) in tsp.iter().enumerate() {
        hidden[row * d..(row + 1) * d]
            .copy_from_slice(&s1.hidden.row(tok)[..d]);
        positions[row] = tok as i32;
    }
    let s2 = Stage2Out::from_vec(ex.run(
        &format!("prefill_stage2_{b2}"),
        vec![
            HostTensor::new(vec![b2, d], hidden).into(),
            HostTensorI32::new(vec![b2], positions).into(),
            In::scalar_i32(tsp.len() as i32),
        ],
    )?);

    // Decoupled layer-wise KV retention (budget independent of TSP).
    let budget = cfg.kv_budget(n, man.model.window);
    let mut cache = RequestCache::new(&man.model);
    compress_layers_groupwise(
        &mut cache, s1.k, s1.v, s1.win, 0, n, budget, man,
    );
    // Stage-2 layers select among the propagated rows only.
    let budget2 = budget.min(tsp.len());
    compress_layers_groupwise(
        &mut cache, &s2.k, &s2.v, &s2.win, t, tsp.len(), budget2, man,
    );
    debug_assert_eq!(cache.lens[lall - 1], budget2);

    Ok(PrefillOutcome {
        first_token: s2.logits.argmax() as i32,
        cache,
        next_pos: n,
        final_h: s2.final_h.data,
        compute_tokens: t * n + (lall - t) * tsp.len(),
    })
}

/// GemFilter's post-stage-1 tail: single global selection on the filter
/// layer's window scores, then a from-scratch re-prefill of only the
/// selected token ids.
fn gemfilter_tail(
    ex: &dyn Exec,
    man: &Manifest,
    cfg: &PolicyCfg,
    tokens: &[i32],
    win: &HostTensor,
) -> Result<PrefillOutcome> {
    let n = tokens.len();
    let budget = cfg.kv_budget(n, man.model.window);
    let (h, nb) = (win.shape[1], win.shape[2]);
    let keep = sel::select_salient(
        win.row(cfg.filter_layer),
        h,
        nb,
        n,
        budget,
        man.model.window,
        man.model.pool_kernel,
    );
    // Restart prefill with only the selected token ids (fresh contiguous
    // positions — GemFilter re-runs from scratch, which is exactly how
    // it fragments context).
    let sel_tokens: Vec<i32> = keep.iter().map(|&i| tokens[i]).collect();
    let m = sel_tokens.len();
    let (out2, _b2) = run_prefill_full(ex, man, &sel_tokens, false)?;
    let mut cache = RequestCache::new(&man.model);
    let all: Vec<usize> = (0..m).collect();
    for l in 0..man.model.n_layers {
        cache.fill_layer(l, &out2.k, &out2.v, l, &all);
    }
    Ok(PrefillOutcome {
        first_token: out2.logits.argmax() as i32,
        cache,
        next_pos: m,
        final_h: out2.final_h.data,
        // layers 0..=filter on n tokens + all layers on m tokens
        compute_tokens: (cfg.filter_layer + 1) * n + man.model.n_layers * m,
    })
}

/// Which post-stage-1 tail a [`ChunkedStage1`] driver runs at finish.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChunkTail {
    FastKv,
    GemFilter,
}

/// The chunked stage-1 driver shared by fastkv and gemfilter.
///
/// Carries the growing stage-1 state host-side across chunks — hidden
/// rows `[N, D]`, per-layer KV `[T, N, KV, hd]` (the exact `Stage1Out`
/// layouts) and the final chunk's window scores — by feeding the whole
/// buffer back into each `prefill_stage1_chunk_{c}x{n}` call and copying
/// the chunk's new rows out. After the last chunk the accumulated
/// buffers are handed to the policy's ordinary tail, so selection,
/// stage 2 and compression run exactly once on state bit-identical to a
/// monolithic `prefill_stage1` (pinned at the JAX layer by
/// `test_model.py::test_chunked_stage1_bit_identical`).
///
/// The per-chunk buffer re-upload is O(T·N·KV·hd) host work; keeping the
/// buffer device-resident across chunks (pinned-input style) is the
/// obvious follow-up and changes nothing semantically.
#[derive(Debug)]
pub struct ChunkedStage1 {
    tail: ChunkTail,
    tokens: Vec<i32>,
    spans: Vec<(usize, usize)>,
    next: usize,
    chunk_c: usize,
    bucket_n: usize,
    kbuf: HostTensor,
    vbuf: HostTensor,
    hidden: HostTensor,
    win: HostTensor,
    cfg: PolicyCfg,
}

impl ChunkedStage1 {
    pub fn begin(
        man: &Manifest,
        tokens: &[i32],
        cfg: &PolicyCfg,
        tail: ChunkTail,
    ) -> Result<ChunkedStage1> {
        let n = tokens.len();
        if n == 0 {
            bail!("empty prompt");
        }
        if man.buckets.chunk_c == 0 || man.buckets.chunk_ns.is_empty() {
            bail!("manifest has no prefill_stage1_chunk artifacts");
        }
        let w = man.model.window;
        // The serve knob picks the span length; the compiled chunk
        // capacity caps it (spans may under-fill the artifact) and the
        // observation window floors it (the last span must hold it whole).
        let floor = w.min(n).max(1);
        if floor > man.buckets.chunk_c {
            bail!(
                "observation window {w} exceeds compiled chunk capacity {}",
                man.buckets.chunk_c
            );
        }
        let step = cfg.prefill_chunk.clamp(floor, man.buckets.chunk_c);
        let bucket_n = bucket_for(n, &man.buckets.chunk_ns)
            .context("prompt exceeds chunked stage1 buckets")?;
        let (t, kv, hd) = (
            man.model.tsp_layer,
            man.model.n_kv_heads,
            man.model.head_dim,
        );
        Ok(ChunkedStage1 {
            tail,
            tokens: tokens.to_vec(),
            spans: chunk_spans(n, step, w),
            next: 0,
            chunk_c: man.buckets.chunk_c,
            bucket_n,
            kbuf: HostTensor::zeros(vec![t, bucket_n, kv, hd]),
            vbuf: HostTensor::zeros(vec![t, bucket_n, kv, hd]),
            hidden: HostTensor::zeros(vec![bucket_n, man.model.d_model]),
            win: HostTensor::zeros(vec![t, man.model.n_heads, bucket_n]),
            cfg: cfg.clone(),
        })
    }
}

impl ChunkedPrefill for ChunkedStage1 {
    fn total_chunks(&self) -> usize {
        self.spans.len()
    }

    fn chunks_done(&self) -> usize {
        self.next
    }

    fn next_chunk_tokens(&self) -> usize {
        self.spans.get(self.next).map_or(0, |&(_, len)| len)
    }

    fn step(&mut self, ex: &dyn Exec, man: &Manifest) -> Result<usize> {
        let _ = man;
        let Some(&(start, len)) = self.spans.get(self.next) else {
            bail!("chunked prefill already complete");
        };
        let mut ctoks = vec![0i32; self.chunk_c];
        ctoks[..len].copy_from_slice(&self.tokens[start..start + len]);
        let name =
            prefill_stage1_chunk_artifact_name(self.chunk_c, self.bucket_n);
        let out = Stage1ChunkOut::from_vec(ex.run(
            &name,
            vec![
                HostTensorI32::new(vec![self.chunk_c], ctoks).into(),
                self.kbuf.clone().into(),
                self.vbuf.clone().into(),
                In::scalar_i32(start as i32),
                In::scalar_i32(len as i32),
                In::scalar_i32(self.tokens.len() as i32),
            ],
        )?);
        // Copy the chunk's new rows into the carried buffers.
        for i in 0..len {
            self.hidden
                .row_mut(start + i)
                .copy_from_slice(out.hidden.row(i));
        }
        let t = self.kbuf.shape[0];
        let rl = self.kbuf.shape[2] * self.kbuf.shape[3];
        for l in 0..t {
            for i in 0..len {
                let dst = ((l * self.bucket_n) + start + i) * rl;
                self.kbuf.data[dst..dst + rl]
                    .copy_from_slice(out.k_c.row2(l, i));
                self.vbuf.data[dst..dst + rl]
                    .copy_from_slice(out.v_c.row2(l, i));
            }
        }
        self.next += 1;
        if self.next == self.spans.len() {
            // The final span contains the whole observation window, so
            // its win output is the complete (monolithic) one.
            self.win = out.win;
        }
        Ok(len)
    }

    fn finish(
        &mut self,
        ex: &dyn Exec,
        man: &Manifest,
    ) -> Result<PrefillOutcome> {
        if self.next != self.spans.len() {
            bail!(
                "chunked prefill finish() before all chunks ran ({}/{})",
                self.next,
                self.spans.len()
            );
        }
        match self.tail {
            ChunkTail::FastKv => fastkv_tail(
                ex,
                man,
                &self.cfg,
                self.tokens.len(),
                Stage1View {
                    hidden: &self.hidden,
                    k: &self.kbuf,
                    v: &self.vbuf,
                    win: &self.win,
                },
            ),
            ChunkTail::GemFilter => {
                gemfilter_tail(ex, man, &self.cfg, &self.tokens, &self.win)
            }
        }
    }
}

/// Shared `begin_chunked` guard for the chunk-capable policies.
fn begin_chunked_stage1(
    man: &Manifest,
    tokens: &[i32],
    cfg: &PolicyCfg,
    tail: ChunkTail,
) -> Option<Result<Box<dyn ChunkedPrefill>>> {
    if cfg.prefill_chunk == 0
        || man.buckets.chunk_c == 0
        || man.buckets.chunk_ns.is_empty()
    {
        return None;
    }
    Some(
        ChunkedStage1::begin(man, tokens, cfg, tail)
            .map(|c| Box::new(c) as Box<dyn ChunkedPrefill>),
    )
}

// --------------------------------------------------------------------------
// full-context

pub struct FullPolicy;

impl Policy for FullPolicy {
    fn name(&self) -> &'static str {
        "full"
    }

    fn prefill(
        &self,
        ex: &dyn Exec,
        man: &Manifest,
        tokens: &[i32],
        cfg: &PolicyCfg,
    ) -> Result<PrefillOutcome> {
        let n = tokens.len();
        let (out, _b) = run_prefill_full(ex, man, tokens, cfg.use_pallas)?;
        let mut cache = RequestCache::new(&man.model);
        let all: Vec<usize> = (0..n).collect();
        for l in 0..man.model.n_layers {
            cache.fill_layer(l, &out.k, &out.v, l, &all);
        }
        Ok(PrefillOutcome {
            first_token: out.logits.argmax() as i32,
            cache,
            next_pos: n,
            final_h: out.final_h.data,
            compute_tokens: man.model.n_layers * n,
        })
    }
}

// --------------------------------------------------------------------------
// StreamingLLM: sinks + recency, identical selection every layer

pub struct StreamingPolicy;

impl Policy for StreamingPolicy {
    fn name(&self) -> &'static str {
        "streaming_llm"
    }

    fn prefill(
        &self,
        ex: &dyn Exec,
        man: &Manifest,
        tokens: &[i32],
        cfg: &PolicyCfg,
    ) -> Result<PrefillOutcome> {
        let n = tokens.len();
        let (out, _b) = run_prefill_full(ex, man, tokens, cfg.use_pallas)?;
        let budget = cfg.kv_budget(n, man.model.window);
        let keep = sel::select_streaming(n, budget, cfg.sinks);
        let mut cache = RequestCache::new(&man.model);
        for l in 0..man.model.n_layers {
            cache.fill_layer(l, &out.k, &out.v, l, &keep);
        }
        Ok(PrefillOutcome {
            first_token: out.logits.argmax() as i32,
            cache,
            next_pos: n,
            final_h: out.final_h.data,
            compute_tokens: man.model.n_layers * n,
        })
    }
}

// --------------------------------------------------------------------------
// H2O: heavy hitters by accumulated attention + recent window

pub struct H2OPolicy;

impl Policy for H2OPolicy {
    fn name(&self) -> &'static str {
        "h2o"
    }

    fn prefill(
        &self,
        ex: &dyn Exec,
        man: &Manifest,
        tokens: &[i32],
        cfg: &PolicyCfg,
    ) -> Result<PrefillOutcome> {
        let n = tokens.len();
        let (out, _b) = run_prefill_full(ex, man, tokens, cfg.use_pallas)?;
        let budget = cfg.kv_budget(n, man.model.window);
        let (h, nb) = (out.acc.shape[1], out.acc.shape[2]);
        let mut cache = RequestCache::new(&man.model);
        for l in 0..man.model.n_layers {
            let keep = sel::select_h2o(
                out.acc.row(l),
                h,
                nb,
                n,
                budget,
                man.model.window,
            );
            cache.fill_layer(l, &out.k, &out.v, l, &keep);
        }
        Ok(PrefillOutcome {
            first_token: out.logits.argmax() as i32,
            cache,
            next_pos: n,
            final_h: out.final_h.data,
            compute_tokens: man.model.n_layers * n,
        })
    }
}

// --------------------------------------------------------------------------
// SnapKV: observation-window scores, pooled, group-wise — decoding-only

pub struct SnapKVPolicy;

impl Policy for SnapKVPolicy {
    fn name(&self) -> &'static str {
        "snapkv"
    }

    fn prefill(
        &self,
        ex: &dyn Exec,
        man: &Manifest,
        tokens: &[i32],
        cfg: &PolicyCfg,
    ) -> Result<PrefillOutcome> {
        let n = tokens.len();
        let (out, _b) = run_prefill_full(ex, man, tokens, cfg.use_pallas)?;
        let budget = cfg.kv_budget(n, man.model.window);
        let mut cache = RequestCache::new(&man.model);
        compress_layers_groupwise(
            &mut cache, &out.k, &out.v, &out.win, 0, n, budget, man,
        );
        Ok(PrefillOutcome {
            first_token: out.logits.argmax() as i32,
            cache,
            next_pos: n,
            final_h: out.final_h.data,
            compute_tokens: man.model.n_layers * n,
        })
    }
}

// --------------------------------------------------------------------------
// GemFilter: filter-layer selection + re-prefill of selected tokens only.
// KV budget is COUPLED to the selected-token count (the paper's critique).

pub struct GemFilterPolicy;

impl Policy for GemFilterPolicy {
    fn name(&self) -> &'static str {
        "gemfilter"
    }

    fn prefill(
        &self,
        ex: &dyn Exec,
        man: &Manifest,
        tokens: &[i32],
        cfg: &PolicyCfg,
    ) -> Result<PrefillOutcome> {
        let n = tokens.len();
        let t = man.model.tsp_layer;
        if cfg.filter_layer >= t {
            bail!(
                "filter layer {} must precede the stage-1 cut {t}",
                cfg.filter_layer
            );
        }
        // Pass 1: full context up to the stage-1 cut; the filter layer's
        // win scores drive the single global token selection.
        let b1 = bucket_for(n, &man.buckets.stage1_ns)
            .context("prompt exceeds stage1 buckets")?;
        let s1 = Stage1Out::from_vec(ex.run(
            &format!("prefill_stage1_{b1}"),
            vec![pad_tokens(tokens, b1).into(), In::scalar_i32(n as i32)],
        )?);
        gemfilter_tail(ex, man, cfg, tokens, &s1.win)
    }

    fn begin_chunked(
        &self,
        man: &Manifest,
        tokens: &[i32],
        cfg: &PolicyCfg,
    ) -> Option<Result<Box<dyn ChunkedPrefill>>> {
        if cfg.filter_layer >= man.model.tsp_layer {
            return Some(Err(anyhow::anyhow!(
                "filter layer {} must precede the stage-1 cut {}",
                cfg.filter_layer,
                man.model.tsp_layer
            )));
        }
        begin_chunked_stage1(man, tokens, cfg, ChunkTail::GemFilter)
    }
}

// --------------------------------------------------------------------------
// PyramidInfer: per-layer cosine decay baked into the artifact; retention
// is coupled to the per-layer compute schedule.

pub struct PyramidPolicy;

impl Policy for PyramidPolicy {
    fn name(&self) -> &'static str {
        "pyramid_infer"
    }

    fn prefill(
        &self,
        ex: &dyn Exec,
        man: &Manifest,
        tokens: &[i32],
        _cfg: &PolicyCfg,
    ) -> Result<PrefillOutcome> {
        let n = tokens.len();
        let b = bucket_for(n, &man.buckets.pyramid_ns)
            .context("prompt exceeds pyramid buckets")?;
        let out = PyramidOut::from_vec(ex.run(
            &format!("prefill_pyramid_{b}"),
            vec![pad_tokens(tokens, b).into(), In::scalar_i32(n as i32)],
        )?);
        let mut cache = RequestCache::new(&man.model);
        let mut compute = 0usize;
        for l in 0..man.model.n_layers {
            let len = out.lens.data[l] as usize;
            let rows: Vec<usize> = (0..len).collect();
            cache.fill_layer(l, &out.k, &out.v, l, &rows);
            compute += len;
        }
        Ok(PrefillOutcome {
            first_token: out.logits.argmax() as i32,
            cache,
            next_pos: n,
            final_h: Vec::new(),
            compute_tokens: compute,
        })
    }
}

// --------------------------------------------------------------------------
// FastKV: two-stage prefill with TSP + decoupled per-layer KV retention

pub struct FastKVPolicy;

impl Policy for FastKVPolicy {
    fn name(&self) -> &'static str {
        "fastkv"
    }

    fn prefill(
        &self,
        ex: &dyn Exec,
        man: &Manifest,
        tokens: &[i32],
        cfg: &PolicyCfg,
    ) -> Result<PrefillOutcome> {
        let n = tokens.len();

        // Stage 1: full context through layers [0, T).
        let b1 = bucket_for(n, &man.buckets.stage1_ns)
            .context("prompt exceeds stage1 buckets")?;
        let s1 = Stage1Out::from_vec(ex.run(
            &format!("prefill_stage1_{b1}"),
            vec![pad_tokens(tokens, b1).into(), In::scalar_i32(n as i32)],
        )?);
        fastkv_tail(
            ex,
            man,
            cfg,
            n,
            Stage1View {
                hidden: &s1.hidden,
                k: &s1.k,
                v: &s1.v,
                win: &s1.win,
            },
        )
    }

    fn begin_chunked(
        &self,
        man: &Manifest,
        tokens: &[i32],
        cfg: &PolicyCfg,
    ) -> Option<Result<Box<dyn ChunkedPrefill>>> {
        begin_chunked_stage1(man, tokens, cfg, ChunkTail::FastKv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(sinks: usize) -> PolicyCfg {
        PolicyCfg {
            kv_rate: 0.1,
            tsp_rate: 0.2,
            sinks,
            filter_layer: 3,
            use_pallas: false,
            prefill_budget: 0,
            decode_budget: 0,
            decode_window: 0,
            prefill_chunk: 0,
            prefill_decode_ratio: 1,
        }
    }

    #[test]
    fn budget_floors_at_window_and_caps_at_n() {
        let cfg = cfg(4);
        assert_eq!(cfg.kv_budget(1000, 8), 100);
        assert_eq!(cfg.kv_budget(10, 8), 8);
        assert_eq!(cfg.kv_budget(4, 8), 4);
        assert_eq!(cfg.tsp_count(1000, 8), 200);
    }

    #[test]
    fn prefill_budget_caps_the_rate_derived_budget() {
        let mut c = cfg(4);
        c.prefill_budget = 64;
        assert_eq!(c.kv_budget(1000, 8), 64, "cap beats the rate");
        assert_eq!(c.kv_budget(100, 8), 10, "rate beats the cap");
        c.prefill_budget = 4;
        assert_eq!(c.kv_budget(1000, 8), 8, "window floor survives the cap");
    }

    #[test]
    fn decode_budget_spec_resolves_two_stage_rows() {
        let mut c = cfg(2);
        assert!(c.decode_budget_spec().is_none(), "0 = unbudgeted");
        c.decode_budget = 16;
        c.decode_window = 4;
        let b = c.decode_budget_spec().unwrap();
        assert_eq!(b.fine_rows, 16);
        assert_eq!(b.coarse_rows, 16 * DECODE_COARSE_SLACK);
        assert_eq!(b.window, 4);
        assert_eq!(b.sinks, 2);
        // fine stage floors at the sliding window
        c.decode_budget = 2;
        assert_eq!(c.decode_budget_spec().unwrap().fine_rows, 4);
    }

    #[test]
    fn compaction_keep_shrinks_per_layer_and_keeps_anchors() {
        let cfg = cfg(2);
        // FastKV-style decoupled lens: early layers long, late layers short
        let lens = [40usize, 40, 10, 10];
        let keep = cfg.compaction_keep(&lens, 0.5, 4);
        assert_eq!(keep.len(), 4);
        for (l, k) in keep.iter().enumerate() {
            let n = lens[l];
            let target = (n / 2).max(4 + 2).min(n);
            assert_eq!(k.len(), target, "layer {l}");
            assert!(k.windows(2).all(|w| w[0] < w[1]));
            // sinks survive
            assert!(k.contains(&0) && k.contains(&1), "layer {l}: {k:?}");
            // most recent token survives
            assert!(k.contains(&(n - 1)), "layer {l}");
        }
    }

    #[test]
    fn per_layer_budget_matches_policy_class() {
        let cfg = cfg(4);
        assert_eq!(cfg.per_layer_budget("full", 1000, 8), 1000);
        assert_eq!(cfg.per_layer_budget("pyramid_infer", 1000, 8), 1000);
        // decoupled policies: max(kv budget, tsp count) = 200
        assert_eq!(cfg.per_layer_budget("fastkv", 1000, 8), 200);
        assert_eq!(cfg.per_layer_budget("snapkv", 1000, 8), 200);
    }

    #[test]
    fn make_policy_covers_all() {
        for name in ALL_POLICIES {
            assert_eq!(make_policy(name).unwrap().name(), *name);
        }
        assert!(make_policy("bogus").is_err());
    }

    #[test]
    fn chunk_spans_cover_exactly_once_and_respect_the_window() {
        for (n, chunk, w) in [
            (64, 16, 8),
            (64, 24, 8),
            (50, 16, 8),
            (33, 64, 8),
            (17, 16, 8),
            (1, 16, 8),
            (100, 7, 8), // chunk smaller than window: floors at w
            (8, 16, 8),
        ] {
            let spans = chunk_spans(n, chunk, w);
            // contiguous, in order, exact coverage
            let mut pos = 0usize;
            for &(start, len) in &spans {
                assert_eq!(start, pos, "n={n} chunk={chunk}");
                assert!(len > 0);
                pos += len;
            }
            assert_eq!(pos, n, "n={n} chunk={chunk}");
            // every span fits the compiled chunk capacity
            let eff = chunk.max(w.min(n).max(1));
            assert!(
                spans.iter().all(|&(_, l)| l <= eff),
                "n={n} chunk={chunk}: {spans:?}"
            );
            // the final span holds the whole observation window, so the
            // last chunk's win output is the complete monolithic one
            let last = spans.last().unwrap().1;
            assert!(
                last >= w.min(n),
                "n={n} chunk={chunk}: last span {last} < window"
            );
        }
    }

    /// Recording fake executor for the chunked driver: notes every
    /// artifact call and hands back shaped outputs whose values encode
    /// (layer, global row), so the test can check the carried-buffer
    /// assembly without a real runtime.
    #[derive(Debug, Default)]
    struct ChunkRecorder {
        calls: std::cell::RefCell<Vec<(String, i32, i32, i32)>>,
    }

    impl Exec for ChunkRecorder {
        fn run(
            &self,
            name: &str,
            inputs: Vec<In>,
        ) -> Result<Vec<HostTensor>> {
            let scalar = |x: &In| match x {
                In::I32(t) => t.data[0],
                In::F32(_) => panic!("expected i32 scalar"),
            };
            let (pos0, c_valid, n_valid) = (
                scalar(&inputs[3]),
                scalar(&inputs[4]),
                scalar(&inputs[5]),
            );
            self.calls.borrow_mut().push((
                name.to_string(),
                pos0,
                c_valid,
                n_valid,
            ));
            let (t, h, kv, hd, d) = (2usize, 2usize, 1usize, 2usize, 4usize);
            let (cc, n) = (8usize, 32usize);
            let mut hidden = HostTensor::zeros(vec![cc, d]);
            let mut k_c = HostTensor::zeros(vec![t, cc, kv, hd]);
            let mut v_c = HostTensor::zeros(vec![t, cc, kv, hd]);
            for i in 0..cc {
                let g = pos0 as usize + i;
                hidden.row_mut(i)[0] = g as f32;
                for l in 0..t {
                    let rl = kv * hd;
                    let off = ((l * cc) + i) * rl;
                    k_c.data[off] = (l * 1000 + g) as f32;
                    v_c.data[off] = -((l * 1000 + g) as f32);
                }
            }
            // win encodes which call produced it, via pos0
            let mut win = HostTensor::zeros(vec![t, h, n]);
            win.data[0] = pos0 as f32;
            let acc = HostTensor::zeros(vec![t, h, n]);
            Ok(vec![hidden, k_c, v_c, win, acc])
        }
    }

    fn chunk_manifest() -> Manifest {
        Manifest {
            dir: std::path::PathBuf::new(),
            model: crate::manifest::ModelMeta {
                vocab_size: 16,
                d_model: 4,
                n_layers: 4,
                n_heads: 2,
                n_kv_heads: 1,
                head_dim: 2,
                tsp_layer: 2,
                window: 4,
                pool_kernel: 3,
                max_train_len: 64,
            },
            n_params: 0,
            kernel: "ref".into(),
            buckets: crate::manifest::Buckets {
                prefill_ns: vec![32],
                stage1_ns: vec![32],
                stage2_ns: vec![8],
                chunk_c: 8,
                chunk_ns: vec![32],
                pyramid_ns: vec![],
                decode_batches: vec![1],
                decode_caps: vec![32],
                sweep_n: 0,
                sweep_nt: 0,
                pallas_n: 0,
                max_gen: 8,
                block_tokens: 0,
                shard_counts: vec![],
            },
            artifacts: std::collections::BTreeMap::new(),
        }
    }

    #[test]
    fn chunked_driver_carries_kv_and_takes_the_final_win() {
        let man = chunk_manifest();
        let mut c = cfg(0);
        c.prefill_chunk = 8;
        let tokens: Vec<i32> = (0..20).collect();
        let mut ch =
            ChunkedStage1::begin(&man, &tokens, &c, ChunkTail::FastKv)
                .unwrap();
        // 20 tokens, chunk 8, window 4 -> spans (0,8)(8,8)(16,4)
        assert_eq!(ch.total_chunks(), 3);
        assert_eq!(ch.chunks_done(), 0);
        assert_eq!(ch.next_chunk_tokens(), 8);

        let ex = ChunkRecorder::default();
        assert_eq!(ch.step(&ex, &man).unwrap(), 8);
        assert_eq!(ch.step(&ex, &man).unwrap(), 8);
        assert_eq!(ch.next_chunk_tokens(), 4);
        assert_eq!(ch.step(&ex, &man).unwrap(), 4);
        assert_eq!(ch.chunks_done(), 3);
        assert!(ch.step(&ex, &man).is_err(), "no fourth chunk");

        let calls = ex.calls.borrow();
        assert_eq!(calls.len(), 3);
        for (name, ..) in calls.iter() {
            assert_eq!(name, "prefill_stage1_chunk_8x32");
        }
        assert_eq!((calls[0].1, calls[0].2, calls[0].3), (0, 8, 20));
        assert_eq!((calls[1].1, calls[1].2), (8, 8));
        assert_eq!((calls[2].1, calls[2].2), (16, 4));

        // carried buffers hold every chunk's rows at global offsets
        for g in 0..20 {
            assert_eq!(ch.hidden.row(g)[0], g as f32, "hidden row {g}");
            for l in 0..2 {
                let off = ((l * 32) + g) * 2;
                assert_eq!(ch.kbuf.data[off], (l * 1000 + g) as f32);
                assert_eq!(ch.vbuf.data[off], -((l * 1000 + g) as f32));
            }
        }
        // win is the FINAL chunk's output (pos0 = 16), not an earlier one
        assert_eq!(ch.win.data[0], 16.0);
    }

    #[test]
    fn begin_chunked_gates_on_knob_and_manifest() {
        let man = chunk_manifest();
        let mut c = cfg(0);
        let toks: Vec<i32> = (0..20).collect();
        assert!(
            FastKVPolicy.begin_chunked(&man, &toks, &c).is_none(),
            "prefill_chunk=0 disables chunking"
        );
        c.prefill_chunk = 8;
        assert!(FastKVPolicy.begin_chunked(&man, &toks, &c).is_some());
        let mut old = man.clone();
        old.buckets.chunk_c = 0;
        old.buckets.chunk_ns.clear();
        assert!(
            FastKVPolicy.begin_chunked(&old, &toks, &c).is_none(),
            "pre-chunking manifest falls back to monolithic"
        );
        // gemfilter validates the filter layer up front
        c.filter_layer = 5; // >= tsp_layer 2
        let r = GemFilterPolicy.begin_chunked(&man, &toks, &c).unwrap();
        assert!(r.is_err());
        // full-context policy never chunks
        assert!(FullPolicy.begin_chunked(&man, &toks, &c).is_none());
    }
}
