//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. Parsed from `artifacts/manifest.json`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Value;

/// Architecture of the compiled model (mirrors `configs.ModelConfig`).
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub tsp_layer: usize,
    pub window: usize,
    pub pool_kernel: usize,
    pub max_train_len: usize,
}

/// Shape buckets the artifacts were compiled for.
#[derive(Debug, Clone)]
pub struct Buckets {
    pub prefill_ns: Vec<usize>,
    pub stage1_ns: Vec<usize>,
    pub stage2_ns: Vec<usize>,
    /// Chunk capacity (tokens per chunk) of the
    /// `prefill_stage1_chunk_{c}x{n}` family (0 on manifests that predate
    /// chunked prefill).
    pub chunk_c: usize,
    /// Carried-KV buffer capacities of the chunked stage-1 family. May
    /// extend past the biggest `stage1_ns` bucket: prompts too long for
    /// any monolithic bucket chunk instead of rejecting (empty on
    /// manifests that predate chunked prefill).
    pub chunk_ns: Vec<usize>,
    pub pyramid_ns: Vec<usize>,
    pub decode_batches: Vec<usize>,
    pub decode_caps: Vec<usize>,
    pub sweep_n: usize,
    pub sweep_nt: usize,
    pub pallas_n: usize,
    pub max_gen: usize,
    /// Tokens per physical block the `decode_paged_*` artifacts were
    /// compiled for (0 on manifests that predate them).
    pub block_tokens: usize,
    /// KV-head shard counts the `decode_paged_shard_*` family was
    /// compiled for (empty on manifests that predate slab sharding).
    pub shard_counts: Vec<usize>,
}

/// Canonical name of the dense decode artifact for a `(batch, cap)` bucket.
pub fn decode_artifact_name(batch: usize, cap: usize) -> String {
    format!("decode_{batch}x{cap}")
}

/// Canonical name of the block-table decode artifact for a bucket.
pub fn decode_paged_artifact_name(batch: usize, cap: usize) -> String {
    format!("decode_paged_{batch}x{cap}")
}

/// Canonical name of the chunked stage-1 prefill artifact: `chunk` tokens
/// run against a carried stage-1 KV buffer of capacity `n`.
pub fn prefill_stage1_chunk_artifact_name(chunk: usize, n: usize) -> String {
    format!("prefill_stage1_chunk_{chunk}x{n}")
}

/// Canonical name of the KV-head-sharded block-table decode artifact for
/// a bucket and shard count.
pub fn decode_paged_shard_artifact_name(
    batch: usize,
    cap: usize,
    shards: usize,
) -> String {
    format!("decode_paged_shard_{batch}x{cap}s{shards}")
}

/// Canonical name of the int8-slab block-table decode artifact for a
/// bucket: consumes quantized K/V planes (integer-valued f32) plus
/// per-row scale tensors and dequantizes in-HLO.
pub fn decode_paged_q8_artifact_name(batch: usize, cap: usize) -> String {
    format!("decode_paged_q8_{batch}x{cap}")
}

/// Canonical name of the sharded int8-slab decode artifact for a bucket
/// and shard count (emitted by the compiler; the rust coordinator
/// currently drives the unsharded q8 family and host-dequantizes for
/// sharded quantized stores).
pub fn decode_paged_q8_shard_artifact_name(
    batch: usize,
    cap: usize,
    shards: usize,
) -> String {
    format!("decode_paged_q8_shard_{batch}x{cap}s{shards}")
}

#[derive(Debug, Clone)]
pub struct TensorSig {
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub n: usize,
    pub batch: usize,
    pub cap: usize,
    pub tsp_layer: usize,
    /// `decode_paged`/`decode_paged_shard` only: static pool bucket of
    /// the slab inputs.
    pub pool_blocks: usize,
    /// `decode_paged`/`decode_paged_shard` only: tokens per physical
    /// block.
    pub block_tokens: usize,
    /// `decode_paged_shard` only: KV-head shard count `S` (0 otherwise).
    pub shards: usize,
    /// `decode_paged_shard` only: KV heads per shard (0 otherwise).
    pub shard_kv_heads: usize,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelMeta,
    pub n_params: usize,
    pub kernel: String,
    pub buckets: Buckets,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
}

fn sigs(v: &Value) -> Vec<TensorSig> {
    v.as_arr()
        .unwrap_or(&[])
        .iter()
        .map(|e| TensorSig {
            shape: e.req("shape").usize_arr(),
            dtype: e.req("dtype").as_str().unwrap_or("float32").to_string(),
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts` first)"))?;
        let v = Value::parse(&text)
            .with_context(|| format!("parsing {path:?}"))?;

        let m = v.req("model");
        let model = ModelMeta {
            vocab_size: m.req("vocab_size").as_usize().unwrap(),
            d_model: m.req("d_model").as_usize().unwrap(),
            n_layers: m.req("n_layers").as_usize().unwrap(),
            n_heads: m.req("n_heads").as_usize().unwrap(),
            n_kv_heads: m.req("n_kv_heads").as_usize().unwrap(),
            head_dim: m.req("head_dim").as_usize().unwrap(),
            tsp_layer: m.req("tsp_layer").as_usize().unwrap(),
            window: m.req("window").as_usize().unwrap(),
            pool_kernel: m.req("pool_kernel").as_usize().unwrap(),
            max_train_len: m.req("max_train_len").as_usize().unwrap(),
        };

        let b = v.req("buckets");
        let buckets = Buckets {
            prefill_ns: b.req("prefill_ns").usize_arr(),
            stage1_ns: b.req("stage1_ns").usize_arr(),
            stage2_ns: b.req("stage2_ns").usize_arr(),
            pyramid_ns: b.req("pyramid_ns").usize_arr(),
            decode_batches: b.req("decode_batches").usize_arr(),
            decode_caps: b.req("decode_caps").usize_arr(),
            sweep_n: b.req("sweep_n").as_usize().unwrap(),
            sweep_nt: b.req("sweep_nt").as_usize().unwrap(),
            pallas_n: b.req("pallas_n").as_usize().unwrap(),
            max_gen: b.req("max_gen").as_usize().unwrap(),
            // absent on manifests that predate chunked prefill
            chunk_c: b.get("chunk_c").and_then(|x| x.as_usize()).unwrap_or(0),
            chunk_ns: b
                .get("chunk_ns")
                .map(|x| x.usize_arr())
                .unwrap_or_default(),
            // absent on manifests that predate block-table decode
            block_tokens: b
                .get("block_tokens")
                .and_then(|x| x.as_usize())
                .unwrap_or(0),
            // absent on manifests that predate slab sharding
            shard_counts: b
                .get("shard_counts")
                .map(|x| x.usize_arr())
                .unwrap_or_default(),
        };

        let mut artifacts = BTreeMap::new();
        for a in v.req("artifacts").as_arr().unwrap_or(&[]) {
            let meta = ArtifactMeta {
                name: a.req("name").as_str().unwrap().to_string(),
                file: a.req("file").as_str().unwrap().to_string(),
                kind: a.req("kind").as_str().unwrap().to_string(),
                n: a.get("n").and_then(|x| x.as_usize()).unwrap_or(0),
                batch: a.get("batch").and_then(|x| x.as_usize()).unwrap_or(1),
                cap: a.get("cap").and_then(|x| x.as_usize()).unwrap_or(0),
                tsp_layer: a
                    .get("tsp_layer")
                    .and_then(|x| x.as_usize())
                    .unwrap_or(model.tsp_layer),
                pool_blocks: a
                    .get("pool_blocks")
                    .and_then(|x| x.as_usize())
                    .unwrap_or(0),
                block_tokens: a
                    .get("block_tokens")
                    .and_then(|x| x.as_usize())
                    .unwrap_or(0),
                shards: a
                    .get("shards")
                    .and_then(|x| x.as_usize())
                    .unwrap_or(0),
                shard_kv_heads: a
                    .get("shard_kv_heads")
                    .and_then(|x| x.as_usize())
                    .unwrap_or(0),
                inputs: sigs(a.req("inputs")),
                outputs: sigs(a.req("outputs")),
            };
            artifacts.insert(meta.name.clone(), meta);
        }
        if artifacts.is_empty() {
            bail!("manifest {path:?} lists no artifacts");
        }

        Ok(Manifest {
            dir: dir.to_path_buf(),
            model,
            n_params: v.req("n_params").as_usize().unwrap(),
            kernel: v
                .get("kernel")
                .and_then(|k| k.as_str())
                .unwrap_or("jnp")
                .to_string(),
            buckets,
            artifacts,
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact `{name}` not in manifest"))
    }

    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.artifact(name)?.file))
    }

    /// Load the flat f32 weight vector.
    pub fn load_weights(&self) -> Result<Vec<f32>> {
        let path = self.dir.join("weights.bin");
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {path:?}"))?;
        if bytes.len() != self.n_params * 4 {
            bail!(
                "weights.bin has {} bytes, expected {} ({} f32 params)",
                bytes.len(),
                self.n_params * 4,
                self.n_params
            );
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Default artifact dir: $FASTKV_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("FASTKV_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_manifest() {
        let dir = std::env::temp_dir().join("fastkv_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        let json = r#"{
          "model": {"vocab_size":256,"d_model":96,"n_layers":8,"n_heads":4,
                    "n_kv_heads":2,"head_dim":24,"tsp_layer":4,"window":8,
                    "pool_kernel":7,"max_train_len":512,"d_ffn":192,
                    "rope_theta":10000.0,"norm_eps":1e-5,"gqa_groups":2},
          "n_params": 10,
          "kernel": "jnp",
          "buckets": {"prefill_ns":[64,128],"stage1_ns":[256],
                      "stage2_ns":[64],"pyramid_ns":[256],
                      "decode_batches":[1,4],"decode_caps":[128],
                      "sweep_n":256,"sweep_nt":64,"pallas_n":128,
                      "max_gen":64,"block_tokens":16},
          "params": [],
          "artifacts": [
            {"name":"prefill_full_64","file":"prefill_full_64.hlo.txt",
             "kind":"prefill_full","n":64,"layers":8,
             "inputs":[{"shape":[10],"dtype":"float32"}],
             "outputs":[{"shape":[256],"dtype":"float32"}]},
            {"name":"decode_paged_1x128","file":"decode_paged_1x128.hlo.txt",
             "kind":"decode_paged","batch":1,"cap":128,
             "pool_blocks":64,"block_tokens":16,
             "inputs":[{"shape":[10],"dtype":"float32"}],
             "outputs":[{"shape":[256],"dtype":"float32"}]}
          ]
        }"#;
        std::fs::write(dir.join("manifest.json"), json).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model.n_layers, 8);
        assert_eq!(m.buckets.decode_caps, vec![128]);
        assert_eq!(m.buckets.block_tokens, 16);
        let a = m.artifact("prefill_full_64").unwrap();
        assert_eq!(a.outputs[0].shape, vec![256]);
        assert_eq!(a.pool_blocks, 0, "non-paged artifacts default to 0");
        let p = m.artifact("decode_paged_1x128").unwrap();
        assert_eq!((p.pool_blocks, p.block_tokens), (64, 16));
        assert_eq!((p.shards, p.shard_kv_heads), (0, 0), "unsharded default");
        assert!(
            m.buckets.shard_counts.is_empty(),
            "pre-shard manifests parse with no shard counts"
        );
        assert_eq!(
            (m.buckets.chunk_c, m.buckets.chunk_ns.len()),
            (0, 0),
            "pre-chunking manifests parse with no chunk buckets"
        );
        assert!(m.artifact("nope").is_err());
    }

    #[test]
    fn decode_artifact_names() {
        assert_eq!(decode_artifact_name(4, 320), "decode_4x320");
        assert_eq!(
            prefill_stage1_chunk_artifact_name(256, 4096),
            "prefill_stage1_chunk_256x4096"
        );
        assert_eq!(decode_paged_artifact_name(1, 128), "decode_paged_1x128");
        assert_eq!(
            decode_paged_q8_artifact_name(1, 128),
            "decode_paged_q8_1x128"
        );
        assert_eq!(
            decode_paged_q8_shard_artifact_name(4, 320, 2),
            "decode_paged_q8_shard_4x320s2"
        );
        assert_eq!(
            decode_paged_shard_artifact_name(4, 320, 2),
            "decode_paged_shard_4x320s2"
        );
    }
}
