//! Minimal JSON parser/serializer.
//!
//! The environment vendors no `serde_json`, so the manifest loader uses this
//! self-contained implementation. It supports the full JSON grammar except
//! `\u` surrogate pairs outside the BMP (not needed: manifests are ASCII).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

#[derive(Debug, Clone)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Value {
    pub fn parse(s: &str) -> Result<Value, ParseError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Panicking accessor for required manifest fields (the manifest is a
    /// build artifact we control; a malformed one is a build bug).
    pub fn req(&self, key: &str) -> &Value {
        self.get(key)
            .unwrap_or_else(|| panic!("missing json key `{key}`"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn usize_arr(&self) -> Vec<usize> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
            .unwrap_or_default()
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{s}`")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(m)),
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(v)),
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self
                                .bump()
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            let d = (c as char)
                                .to_digit(16)
                                .ok_or_else(|| self.err("bad hex digit"))?;
                            code = code * 16 + d;
                        }
                        s.push(
                            char::from_u32(code)
                                .ok_or_else(|| self.err("bad codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => {
                    return Err(self.err("control char in string"))
                }
                Some(c) => {
                    // Collect the full UTF-8 sequence.
                    let start = self.pos - 1;
                    let len = match c {
                        0x00..=0x7f => 1,
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    self.pos = start + len;
                    if self.pos > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse().ok())
            .map(Value::Num)
            .ok_or_else(|| self.err("invalid number"))
    }
}

/// Serialize with escaping (used by report writers).
impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Null => write!(f, "null"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Value::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => {
                            write!(f, "\\u{:04x}", c as u32)?
                        }
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Value::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Value::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Value::Str(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("-1.5e2").unwrap(), Value::Num(-150.0));
        assert_eq!(
            Value::parse(r#""a\nb""#).unwrap(),
            Value::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Value::parse(r#"{"a":[1,2,{"b":"c"}],"d":null}"#).unwrap();
        assert_eq!(v.req("a").as_arr().unwrap().len(), 3);
        assert_eq!(
            v.req("a").as_arr().unwrap()[2].req("b").as_str(),
            Some("c")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("1 2").is_err());
        assert!(Value::parse(r#""unterminated"#).is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"n":null,"t":true}"#;
        let v = Value::parse(src).unwrap();
        let v2 = Value::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Value::parse(r#""A""#).unwrap(),
            Value::Str("A".into())
        );
    }
}
