//! Deterministic PRNG (splitmix64 core) — no `rand` crate in this
//! environment. Used by the workload generators and the property-test
//! helpers; determinism per seed is part of the eval contract (every
//! table in EXPERIMENTS.md is regenerable bit-for-bit).

#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9e3779b97f4a7c15) }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }

    /// Uniform in [0, bound).
    pub fn below(&mut self, bound: usize) -> usize {
        assert!(bound > 0);
        // Lemire's multiply-shift rejection-free variant is fine here: the
        // bias for bound << 2^64 is negligible for workload generation.
        ((self.next_u64() as u128 * bound as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Sample `k` distinct indices from [0, n), ascending.
    pub fn distinct_sorted(&mut self, k: usize, n: usize) -> Vec<usize> {
        assert!(k <= n);
        // Floyd's algorithm.
        let mut set = std::collections::BTreeSet::new();
        for j in n - k..n {
            let t = self.below(j + 1);
            if !set.insert(t) {
                set.insert(j);
            }
        }
        set.into_iter().collect()
    }

    /// Weighted index sample.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(2);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let x = r.range(3, 5);
            assert!((3..=5).contains(&x));
            seen_lo |= x == 3;
            seen_hi |= x == 5;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn distinct_sorted_properties() {
        let mut r = Rng::new(3);
        for _ in 0..200 {
            let k = r.range(0, 10);
            let v = r.distinct_sorted(k, 20);
            assert_eq!(v.len(), k);
            assert!(v.windows(2).all(|w| w[0] < w[1]));
            assert!(v.iter().all(|&x| x < 20));
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(4);
        let mean: f64 =
            (0..10_000).map(|_| r.f64()).sum::<f64>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
