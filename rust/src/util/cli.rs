//! Tiny CLI argument parser (`clap` is not vendored in this environment).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

pub const FLAG_SET: &str = "true";

impl Args {
    /// Parse from an iterator of raw args (exclusive of argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.flags.insert(rest.to_string(), FLAG_SET.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key}: not a number: {v}")))
            .unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key}: not a number: {v}")))
            .unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// Comma-separated list, e.g. `--ns 256,512,1024`.
    pub fn usize_list(&self, key: &str, default: &[usize]) -> Vec<usize> {
        match self.get(key) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .map(|s| s.trim().parse().unwrap_or_else(|_| panic!("--{key}: bad list item {s}")))
                .collect(),
        }
    }

    pub fn str_list(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.get(key) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn positional_and_flags() {
        // NB: a bare `--flag` followed by a non-flag token consumes it as
        // the value (`--verbose extra` => verbose=extra); boolean flags go
        // last or use `=`.
        let a = parse("serve extra --port 8080 --verbose");
        assert_eq!(a.positional, vec!["serve", "extra"]);
        assert_eq!(a.get("port"), Some("8080"));
        assert!(a.has("verbose"));
    }

    #[test]
    fn equals_form() {
        let a = parse("--rate=0.2 --n=512");
        assert_eq!(a.f64("rate", 0.0), 0.2);
        assert_eq!(a.usize("n", 0), 512);
    }

    #[test]
    fn lists() {
        let a = parse("--ns 256,512 --methods fastkv,snapkv");
        assert_eq!(a.usize_list("ns", &[]), vec![256, 512]);
        assert_eq!(a.str_list("methods", &[]), vec!["fastkv", "snapkv"]);
        assert_eq!(a.usize_list("missing", &[1, 2]), vec![1, 2]);
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.usize("x", 7), 7);
        assert_eq!(a.str_or("m", "full"), "full");
    }
}
