//! Substrate utilities built in-repo (this environment vendors no
//! serde_json / rand / clap): JSON, PRNG, CLI parsing, and small helpers.

pub mod cli;
pub mod json;
pub mod rng;

/// Round `n` up to the smallest bucket that fits; `None` if none fits.
pub fn bucket_for(n: usize, buckets: &[usize]) -> Option<usize> {
    buckets.iter().copied().filter(|&b| b >= n).min()
}

/// Simple mean/std over f64 samples.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
        / xs.len() as f64;
    (mean, var.sqrt())
}

/// Percentile (nearest-rank) of an unsorted sample.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[idx.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_picks_smallest_fit() {
        assert_eq!(bucket_for(100, &[64, 128, 256]), Some(128));
        assert_eq!(bucket_for(128, &[64, 128, 256]), Some(128));
        assert_eq!(bucket_for(300, &[64, 128, 256]), None);
    }

    #[test]
    fn stats() {
        let (m, s) = mean_std(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((m - 5.0).abs() < 1e-9);
        assert!((s - 2.0).abs() < 1e-9);
        assert_eq!(percentile(&[1.0, 2.0, 3.0, 4.0], 0.0), 1.0);
        assert_eq!(percentile(&[1.0, 2.0, 3.0, 4.0], 100.0), 4.0);
    }
}
