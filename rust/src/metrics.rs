//! Serving metrics: counters, gauges, and log-bucketed latency
//! histograms, printed by the server and the bench harness and exported
//! by `obs::export` (Prometheus text / JSON snapshot).
//!
//! Steady-state updates are allocation-free: `inc` / `set_gauge` /
//! `observe` look the series up by `&str` first and only allocate the
//! owned key on the *first* touch of a new name, and [`Histogram`]
//! stores fixed log-spaced bucket counts rather than raw samples — a
//! million-request run has O(1) histogram memory. The exact-sample
//! [`ExactHistogram`] survives for tests that need reference
//! percentiles.
//!
//! Each [`Metrics`] also embeds a [`TraceRecorder`]
//! ([`Metrics::tracer`]) so every serving function that already takes a
//! metrics handle can record lifecycle trace events without a signature
//! change; tracing is off (and free) by default.

#![warn(missing_docs)]

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Duration;

use crate::obs::trace::TraceRecorder;

/// Metric names shared across the serving stack so producers (server),
/// consumers (benches, demos), and assertions (tests) can never drift
/// apart on spelling.
pub mod names {
    // ------------------------------------------------ request lifecycle
    /// Requests received by the serving thread.
    pub const SUBMITTED: &str = "submitted";
    /// Requests retired successfully.
    pub const COMPLETED: &str = "completed";
    /// Requests failed permanently (cannot fit, prompt too long,
    /// prefill error).
    pub const REJECTED: &str = "rejected";
    /// Admissions deferred because the pool (or a swap-in) was
    /// momentarily full; the request retries after decode frees blocks.
    pub const ADMIT_DEFERRED: &str = "admit_deferred";
    /// Lanes preempted under pool pressure.
    pub const PREEMPTED: &str = "preempted";
    /// Lanes finished with what they had generated because nothing could
    /// be preempted to relieve pool pressure.
    pub const FINISHED_ON_PRESSURE: &str = "finished_on_pressure";
    /// Tokens emitted across all completed requests.
    pub const TOKENS_OUT: &str = "tokens_out";
    /// Completed requests whose TTFT was never measured (finished
    /// without ever producing a first token — e.g. rejected after
    /// preemption). Counted here instead of polluting `ttft_secs`
    /// with a fake 0.0 sample.
    pub const TTFT_UNMEASURED: &str = "ttft_unmeasured";

    // -------------------------------------------------- latency phases
    /// Submit → final response, per completed request.
    pub const E2E_SECS: &str = "e2e_secs";
    /// Submit → first token, per completed request that produced one.
    pub const TTFT_SECS: &str = "ttft_secs";
    /// Submit → first prefill start (scheduler queue wait), per request.
    pub const QUEUE_WAIT_SECS: &str = "queue_wait_secs";
    /// Policy prefill wall time, per prefill actually run.
    pub const PREFILL_SECS: &str = "prefill_secs";
    /// One chunked-prefill chunk, end to end (artifact run + carried
    /// buffer copies). The sum over a request's chunks ≈ its
    /// `prefill_secs`.
    pub const PREFILL_CHUNK_SECS: &str = "prefill_chunk_secs";
    /// One batched decode step, end to end.
    pub const DECODE_STEP_SECS: &str = "decode_step_secs";
    /// Decode-step phase: input prep (lane tensors, tables, pins).
    pub const DECODE_PREP_SECS: &str = "decode_prep_secs";
    /// Decode-step phase: stale shard-slab materialization for device
    /// upload.
    pub const DECODE_UPLOAD_SECS: &str = "decode_upload_secs";
    /// Decode-step phase: artifact execution.
    pub const DECODE_EXEC_SECS: &str = "decode_exec_secs";
    /// Decode-step phase: host-side per-shard output combine.
    pub const DECODE_COMBINE_SECS: &str = "decode_combine_secs";
    /// Serializing one preempted lane to the host swap arena.
    pub const SWAP_OUT_SECS: &str = "swap_out_secs";
    /// Restoring one lane from the host swap arena.
    pub const SWAP_IN_SECS: &str = "swap_in_secs";

    // ---------------------------------------------------- decode path
    /// Decode steps served through the dense staged bridge.
    pub const DECODE_STEPS_STAGED: &str = "decode_steps_staged";
    /// Decode steps served through the (unsharded) block-table path.
    pub const DECODE_STEPS_BLOCK_TABLE: &str = "decode_steps_block_table";
    /// Gauge (0/1): 1 = the serving loop resolved a block-table decode
    /// path (sharded or not) at startup.
    pub const DECODE_BLOCK_TABLE: &str = "decode_block_table";
    /// Single-request engine generations stopped by lane/pool capacity
    /// rather than END or `max_new` (on `Metrics::global()`).
    pub const DECODE_TRUNCATED_BY_CAPACITY: &str =
        "decode_truncated_by_capacity";
    /// Block-granular compactions fired under pool pressure.
    pub const COMPACTIONS: &str = "compactions";

    // ------------------------------------------------------ scheduler
    /// Gauge: requests parked on the scheduler queue at iteration end.
    pub const RESUME_QUEUE_DEPTH: &str = "resume_queue_depth";

    /// Policy prefills re-run for a request that already completed one —
    /// recompute-resume after a lost swap handle, or a deferred admission
    /// that somehow dropped its carried prefill. The swap-to-host and
    /// carried-prefill paths exist precisely to keep this at zero; tests
    /// pin it there.
    pub const PREFILL_RECOMPUTED: &str = "prefill_recomputed";
    /// Chunked-prefill chunks executed (across all requests). Stays 0
    /// when chunking is off (`--prefill-chunk 0`).
    pub const PREFILL_CHUNKS_TOTAL: &str = "prefill_chunks_total";
    /// Serve-loop iterations where a *monolithic* (blocking) prefill ran
    /// while decode lanes were active — every such iteration is a decode
    /// stall the chunked path exists to eliminate; the interleaving bench
    /// pins the chunked path at zero.
    pub const DECODE_STALL_STEPS: &str = "decode_stall_steps";
    /// Preempted lanes serialized to the host swap arena.
    pub const SWAP_OUTS: &str = "swap_outs";
    /// Lanes restored from the swap arena (zero-prefill resume).
    pub const SWAP_INS: &str = "swap_ins";
    /// Preemptions that could not swap (disabled, or the lane alone
    /// exceeds the budget) and fell back to recompute-resume.
    pub const SWAP_REFUSED: &str = "swap_out_refused";
    /// Resumes whose handle was gone (dropped under host-memory
    /// pressure) and fell back to recompute-resume.
    pub const SWAP_FALLBACK_RECOMPUTE: &str = "swap_fallback_recompute";
    /// Gauge: host bytes currently held by swapped lanes.
    pub const SWAP_BYTES_USED: &str = "swap_bytes_used";
    /// Gauge: configured swap budget in bytes.
    pub const SWAP_BYTES_BUDGET: &str = "swap_bytes_budget";
    /// Gauge: swapped lanes currently parked on host.
    pub const SWAP_ENTRIES: &str = "swap_entries";
    /// Gauge: entries evicted oldest-first to make room for newer
    /// swap-outs (their owners recompute-resume).
    pub const SWAP_DROPPED: &str = "swap_entries_dropped";

    // ------------------------------------------------------ block pool
    /// Gauge: blocks in the pool.
    pub const POOL_BLOCKS_TOTAL: &str = "pool_blocks_total";
    /// Gauge: blocks currently referenced by lanes or the prefix cache's
    /// live sharers.
    pub const POOL_BLOCKS_IN_USE: &str = "pool_blocks_in_use";
    /// Gauge: high-water mark of `pool_blocks_in_use` over the run.
    pub const POOL_BLOCKS_IN_USE_PEAK: &str = "pool_blocks_in_use_peak";
    /// Gauge: blocks retained only by the prefix cache.
    pub const POOL_BLOCKS_CACHED: &str = "pool_blocks_cached";
    /// Gauge: prefix-cache hits.
    pub const POOL_PREFIX_HITS: &str = "pool_prefix_hits";
    /// Gauge: prefix-cache misses.
    pub const POOL_PREFIX_MISSES: &str = "pool_prefix_misses";
    /// Gauge: hits / (hits + misses).
    pub const POOL_PREFIX_HIT_RATE: &str = "pool_prefix_hit_rate";
    /// Gauge: copy-on-write block copies.
    pub const POOL_COW_COPIES: &str = "pool_cow_copies";
    /// Gauge: prefix-cache evictions.
    pub const POOL_EVICTIONS: &str = "pool_evictions";
    /// Gauge: block allocation failures (pool exhausted).
    pub const POOL_ALLOC_FAILURES: &str = "pool_alloc_failures";
    /// Gauge: block takes refused by a tenant quota while the pool still
    /// had allocatable blocks (from `PoolStats::quota_denials`).
    pub const POOL_QUOTA_DENIALS: &str = "pool_quota_denials";
    /// Counter: shard slab planes materialized for device upload (the
    /// per-shard staleness win: a mutation confined to one shard counts
    /// 1, a whole-row append counts S; an all-current step counts 0).
    /// On the unsharded path this counts whole-slab re-uploads.
    pub const SHARD_UPLOADS: &str = "shard_uploads";
    /// Counter: decode steps served through the KV-head-sharded
    /// block-table path (`decode_paged_shard_{B}x{C}s{S}`).
    pub const DECODE_STEPS_SHARDED: &str = "decode_steps_sharded";
    /// Gauge (0/1): 1 = the serving loop resolved the sharded decode
    /// path at startup.
    pub const DECODE_SHARDED: &str = "decode_sharded";
    /// Counter: decode steps served through the quantized block-table
    /// path (`decode_paged_q8_{B}x{C}` — int8 planes + per-row scales,
    /// dequantized in-HLO).
    pub const DECODE_STEPS_Q8: &str = "decode_steps_q8";

    // ------------------------------------------------- decode budgets
    /// Counter: generated-token blocks permanently released by the
    /// coarse decode-budget stage (`KvStore::enforce_decode_budget` —
    /// resident generated rows held to `coarse_rows` per layer per
    /// lane). 0 when `--decode-budget` is off.
    pub const DECODE_BLOCKS_EVICTED: &str = "decode_blocks_evicted";
    /// Counter: blocks the fine decode-budget stage dropped from decode
    /// attention views (pruned per-lane tables; the blocks stay
    /// resident — only this step's attention skips them). Summed over
    /// (layer, lane) per step.
    pub const DECODE_BLOCKS_PRUNED: &str = "decode_blocks_pruned";
    /// Gauge: blocks holding at least one generated (decode-appended)
    /// row across all lanes — the resident set decode budgets bound
    /// (from `PoolStats::decode_region_blocks`).
    pub const DECODE_REGION_BLOCKS: &str = "decode_region_blocks";

    // ------------------------------------------------- slab quantization
    /// Gauge: resident bytes of the slab's encoded K + V planes under the
    /// pool codec (equals `pool_blocks_total * block_tokens *
    /// bytes_per_row(KV*hd) * 2`; for int8 this includes the per-row
    /// scale planes). Named "quantized" for the tiers where it diverges
    /// from the f32 figure, but published for every codec so dashboards
    /// can diff precision configurations.
    pub const POOL_BYTES_QUANTIZED: &str = "pool_bytes_quantized";
    /// Gauge: cumulative seconds the store spent in bulk codec work
    /// (whole-plane decode for view materialization; per-row write-side
    /// quantization is too fine to time without distorting it).
    pub const QUANT_DEQUANT_SECS: &str = "quant_dequant_secs";
    /// Gauge: rows quantized by write-side encodes since startup.
    pub const QUANT_ROWS: &str = "quant_rows";
    /// Gauge: rows dequantized by read-side decodes since startup.
    pub const DEQUANT_ROWS: &str = "dequant_rows";

    use crate::coordinator::paging::{KvCodec, TenantId};

    /// Gauge name: active lanes whose effective swap tier is `codec`
    /// (the tenant's precision tier, else the pool default). All three
    /// tiers are published — zero-valued gauges included — so dashboards
    /// never lose a series when a tier empties.
    pub fn lanes_tier(codec: KvCodec) -> String {
        format!("lanes_tier_{}", codec.name())
    }

    /// Gauge name: device bytes shard `s` pins for this store's K + V
    /// slab planes (`num_blocks * block_tokens *
    /// codec.bytes_per_row(KV/S * hd) * 2` — 4 bytes/elem at f32, 2 at
    /// f16, 1 + the amortized scale at int8).
    pub fn shard_slab_bytes(s: usize) -> String {
        format!("shard_{s}_slab_bytes")
    }

    /// Gauge name: blocks currently charged to the tenant (first-toucher
    /// rule; reconciles with `pool_blocks_in_use` summed over tenants).
    pub fn tenant_blocks_held(id: TenantId) -> String {
        format!("tenant_{id}_blocks_held")
    }

    /// Gauge name: the tenant's configured reserved block floor.
    pub fn tenant_blocks_reserved(id: TenantId) -> String {
        format!("tenant_{id}_blocks_reserved")
    }

    /// Gauge name: host swap bytes currently parked by the tenant's
    /// preempted lanes.
    pub fn tenant_swap_bytes_used(id: TenantId) -> String {
        format!("tenant_{id}_swap_bytes_used")
    }

    /// Counter name: lanes of this tenant preempted under pool pressure.
    pub fn tenant_preempted(id: TenantId) -> String {
        format!("tenant_{id}_preempted")
    }

    /// Counter name: this tenant's requests rejected (pool can never fit,
    /// prompt too long, or prefill failure).
    pub fn tenant_rejected(id: TenantId) -> String {
        format!("tenant_{id}_rejected")
    }

    /// Counter name: this tenant's requests completed successfully.
    pub fn tenant_completed(id: TenantId) -> String {
        format!("tenant_{id}_completed")
    }
}

/// Fixed bucket count of [`Histogram`].
pub const HIST_BUCKETS: usize = 64;

/// Lower edge of the first log bucket: 1 µs (samples below land in
/// bucket 0).
const HIST_MIN: f64 = 1e-6;

/// Bucket-to-bucket growth ratio (√2): 64 buckets cover 1 µs … ~36 min,
/// with the last bucket catching everything beyond.
const HIST_RATIO_LOG2: f64 = 0.5;

/// Log-bucketed latency histogram: fixed √2-spaced buckets from 1 µs,
/// plus exact count/sum/min/max. O(1) memory regardless of sample
/// count; percentiles interpolate within the winning bucket (clamped to
/// the observed min/max, so single-sample histograms report exactly).
#[derive(Debug, Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            counts: vec![0; HIST_BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

/// Index of the bucket a sample falls in.
fn bucket_of(s: f64) -> usize {
    if s < HIST_MIN {
        return 0;
    }
    let idx = 1 + ((s / HIST_MIN).log2() / HIST_RATIO_LOG2).floor() as usize;
    idx.min(HIST_BUCKETS - 1)
}

impl Histogram {
    /// Upper bound (exclusive) of bucket `i`; the last bucket is
    /// unbounded.
    pub fn upper_bound(i: usize) -> f64 {
        if i + 1 >= HIST_BUCKETS {
            f64::INFINITY
        } else {
            HIST_MIN * (HIST_RATIO_LOG2 * i as f64).exp2()
        }
    }

    /// Record a duration.
    pub fn record(&mut self, d: Duration) {
        self.record_secs(d.as_secs_f64());
    }

    /// Record a sample in seconds. Negative samples clamp to 0 and
    /// non-finite samples are dropped (they would poison the sum).
    pub fn record_secs(&mut self, s: f64) {
        if !s.is_finite() {
            return;
        }
        let s = s.max(0.0);
        self.counts[bucket_of(s)] += 1;
        self.count += 1;
        self.sum += s;
        self.min = self.min.min(s);
        self.max = self.max.max(s);
    }

    /// Samples recorded.
    pub fn count(&self) -> usize {
        self.count as usize
    }

    /// Exact mean of all samples.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Smallest sample (0 when empty).
    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Largest sample (0 when empty).
    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Percentile estimate: find the bucket holding the target rank and
    /// interpolate linearly inside it, clamped to the observed min/max.
    /// Error is bounded by the bucket width (√2 relative).
    pub fn p(&self, pct: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((pct / 100.0) * self.count as f64).ceil().max(1.0)
            as u64;
        let target = target.min(self.count);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            seen += c;
            if seen >= target {
                let floor =
                    if i == 0 { 0.0 } else { Histogram::upper_bound(i - 1) };
                let lo = floor.max(self.min);
                let hi = Histogram::upper_bound(i).min(self.max).max(lo);
                let into = (target - (seen - c)) as f64 / c as f64;
                return lo + (hi - lo) * into;
            }
        }
        self.max()
    }

    /// Sum of all samples.
    pub fn total(&self) -> f64 {
        self.sum
    }

    /// Per-bucket sample counts (length [`HIST_BUCKETS`]); bucket `i`
    /// covers `[upper_bound(i-1), upper_bound(i))`.
    pub fn bucket_counts(&self) -> &[u64] {
        &self.counts
    }

    /// One-line human summary used by [`Metrics::report`].
    pub fn summary(&self) -> String {
        if self.count == 0 {
            return "n=0".into();
        }
        format!(
            "n={} mean={:.1}ms p50={:.1}ms p95={:.1}ms p99={:.1}ms",
            self.count(),
            self.mean() * 1e3,
            self.p(50.0) * 1e3,
            self.p(95.0) * 1e3,
            self.p(99.0) * 1e3,
        )
    }
}

/// Exact-sample histogram (the pre-bucketing implementation): stores
/// every sample and computes nearest-rank percentiles. Unbounded memory
/// — kept for tests that need reference percentiles to judge
/// [`Histogram`]'s interpolation against, and for short offline runs.
#[derive(Debug, Clone, Default)]
pub struct ExactHistogram {
    samples: Vec<f64>,
}

impl ExactHistogram {
    /// Record a duration.
    pub fn record(&mut self, d: Duration) {
        self.samples.push(d.as_secs_f64());
    }

    /// Record a sample in seconds.
    pub fn record_secs(&mut self, s: f64) {
        self.samples.push(s);
    }

    /// Samples recorded.
    pub fn count(&self) -> usize {
        self.samples.len()
    }

    /// Exact mean.
    pub fn mean(&self) -> f64 {
        crate::util::mean_std(&self.samples).0
    }

    /// Exact nearest-rank percentile.
    pub fn p(&self, pct: f64) -> f64 {
        crate::util::percentile(&self.samples, pct)
    }

    /// Sum of all samples.
    pub fn total(&self) -> f64 {
        self.samples.iter().sum()
    }
}

/// Shared registry for the serving stack.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
    tracer: TraceRecorder,
}

#[derive(Debug, Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Histogram>,
}

/// Point-in-time copy of every series in a [`Metrics`] registry — the
/// input to the `obs::export` renderers (Prometheus text, JSON).
#[derive(Debug, Clone, Default)]
pub struct MetricsSnapshot {
    /// Monotonic counters by name.
    pub counters: BTreeMap<String, u64>,
    /// Point-in-time gauges by name.
    pub gauges: BTreeMap<String, f64>,
    /// Latency histograms by name.
    pub histograms: BTreeMap<String, Histogram>,
}

impl Metrics {
    /// Process-wide registry for paths that have no `Metrics` handle of
    /// their own (e.g. `engine::generate`, which is invoked by evals and
    /// benches without a serving stack around it). Servers keep their own
    /// per-instance registries; this one aggregates engine-level events
    /// such as `decode_truncated_by_capacity`.
    pub fn global() -> &'static Metrics {
        use std::sync::OnceLock;
        static GLOBAL: OnceLock<Metrics> = OnceLock::new();
        GLOBAL.get_or_init(Metrics::default)
    }

    /// The lifecycle trace recorder riding with this registry (disabled
    /// until `tracer().enable(cap)`).
    pub fn tracer(&self) -> &TraceRecorder {
        &self.tracer
    }

    /// Add `by` to a counter. Allocation-free once the name exists.
    pub fn inc(&self, name: &str, by: u64) {
        let mut g = self.inner.lock().unwrap();
        if let Some(c) = g.counters.get_mut(name) {
            *c += by;
        } else {
            g.counters.insert(name.to_string(), by);
        }
    }

    /// Set a point-in-time gauge (block-pool occupancy, hit rates, ...).
    /// Allocation-free once the name exists.
    pub fn set_gauge(&self, name: &str, value: f64) {
        let mut g = self.inner.lock().unwrap();
        if let Some(v) = g.gauges.get_mut(name) {
            *v = value;
        } else {
            g.gauges.insert(name.to_string(), value);
        }
    }

    /// Current gauge value (0 when never set).
    pub fn gauge(&self, name: &str) -> f64 {
        self.inner
            .lock()
            .unwrap()
            .gauges
            .get(name)
            .copied()
            .unwrap_or(0.0)
    }

    /// Record a histogram sample in seconds. Allocation-free once the
    /// name exists (the histogram's buckets are fixed).
    pub fn observe(&self, name: &str, secs: f64) {
        let mut g = self.inner.lock().unwrap();
        if let Some(h) = g.histograms.get_mut(name) {
            h.record_secs(secs);
        } else {
            let mut h = Histogram::default();
            h.record_secs(secs);
            g.histograms.insert(name.to_string(), h);
        }
    }

    /// Current counter value (0 when never incremented).
    pub fn counter(&self, name: &str) -> u64 {
        self.inner
            .lock()
            .unwrap()
            .counters
            .get(name)
            .copied()
            .unwrap_or(0)
    }

    /// Copy of a histogram (empty when never observed).
    pub fn histogram(&self, name: &str) -> Histogram {
        self.inner
            .lock()
            .unwrap()
            .histograms
            .get(name)
            .cloned()
            .unwrap_or_default()
    }

    /// Copy every series at once (the export plane's input; one lock).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        MetricsSnapshot {
            counters: g.counters.clone(),
            gauges: g.gauges.clone(),
            histograms: g.histograms.clone(),
        }
    }

    /// Human-readable dump of every series.
    pub fn report(&self) -> String {
        let g = self.inner.lock().unwrap();
        let mut out = String::new();
        for (k, v) in &g.counters {
            out.push_str(&format!("{k:32} {v}\n"));
        }
        for (k, v) in &g.gauges {
            out.push_str(&format!("{k:32} {v:.3}\n"));
        }
        for (k, h) in &g.histograms {
            out.push_str(&format!("{k:32} {}\n", h.summary()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_histograms() {
        let m = Metrics::default();
        m.inc("requests", 2);
        m.inc("requests", 3);
        assert_eq!(m.counter("requests"), 5);
        m.observe("latency", 0.010);
        m.observe("latency", 0.020);
        let h = m.histogram("latency");
        assert_eq!(h.count(), 2);
        assert!((h.mean() - 0.015).abs() < 1e-9);
        assert!(m.report().contains("requests"));
    }

    #[test]
    fn gauges_set_and_read() {
        let m = Metrics::default();
        assert_eq!(m.gauge("blocks_in_use"), 0.0);
        m.set_gauge("blocks_in_use", 12.0);
        m.set_gauge("blocks_in_use", 7.0); // gauges overwrite
        assert_eq!(m.gauge("blocks_in_use"), 7.0);
        m.set_gauge("prefix_hit_rate", 0.5);
        let rep = m.report();
        assert!(rep.contains("blocks_in_use"), "{rep}");
        assert!(rep.contains("prefix_hit_rate"), "{rep}");
    }

    #[test]
    fn global_registry_is_shared() {
        let a = Metrics::global();
        let before = a.counter("global_test_counter");
        Metrics::global().inc("global_test_counter", 2);
        assert_eq!(a.counter("global_test_counter"), before + 2);
    }

    #[test]
    fn percentiles_ordered() {
        let mut h = Histogram::default();
        for i in 1..=100 {
            h.record_secs(i as f64);
        }
        assert!(h.p(50.0) <= h.p(95.0));
        assert!(h.p(95.0) <= h.p(99.0));
    }

    #[test]
    fn buckets_are_monotone_and_cover() {
        let mut prev = 0.0;
        for i in 0..HIST_BUCKETS {
            let b = Histogram::upper_bound(i);
            assert!(b > prev, "bucket {i} bound {b} <= {prev}");
            prev = b;
        }
        assert_eq!(Histogram::upper_bound(0), 1e-6);
        assert!(Histogram::upper_bound(HIST_BUCKETS - 1).is_infinite());
        // every finite sample lands in exactly one in-range bucket
        for s in [0.0, 1e-9, 1e-6, 3.3e-4, 1.0, 17.0, 1e9] {
            assert!(bucket_of(s) < HIST_BUCKETS);
        }
        // boundary: a sample exactly on a bound goes to the bucket above
        assert_eq!(bucket_of(1e-6), 1);
        assert!(bucket_of(0.999e-6) == 0);
    }

    #[test]
    fn histogram_memory_is_bounded_and_stats_exact() {
        let mut h = Histogram::default();
        for i in 0..100_000u64 {
            h.record_secs(1e-4 + (i % 100) as f64 * 1e-5);
        }
        assert_eq!(h.counts.len(), HIST_BUCKETS); // no per-sample storage
        assert_eq!(h.count(), 100_000);
        assert!((h.min() - 1e-4).abs() < 1e-12);
        assert!((h.max() - (1e-4 + 99.0 * 1e-5)).abs() < 1e-12);
        // mean/sum are exact (not bucketed)
        let exact_mean = 1e-4 + 49.5 * 1e-5;
        assert!((h.mean() - exact_mean).abs() < 1e-9);
    }

    #[test]
    fn bucketed_percentiles_track_exact_within_bucket_error() {
        // Log-uniform samples over 1µs..1s: the bucketed estimate must
        // stay within one √2 bucket of the exact nearest-rank value.
        let mut h = Histogram::default();
        let mut e = ExactHistogram::default();
        for i in 0..2000 {
            let s = 1e-6 * (1.0218_f64).powi(i % 683);
            h.record_secs(s);
            e.record_secs(s);
        }
        for pct in [50.0, 90.0, 95.0, 99.0] {
            let (a, b) = (h.p(pct), e.p(pct));
            assert!(
                a / b < 1.5 && b / a < 1.5,
                "p{pct}: bucketed {a} vs exact {b}"
            );
        }
    }

    #[test]
    fn single_sample_percentile_is_exact() {
        let mut h = Histogram::default();
        h.record_secs(0.0123);
        // min/max clamping pins every percentile to the one sample
        assert!((h.p(50.0) - 0.0123).abs() < 1e-12);
        assert!((h.p(99.0) - 0.0123).abs() < 1e-12);
        assert!((h.min() - 0.0123).abs() < 1e-12);
        assert!((h.max() - 0.0123).abs() < 1e-12);
    }

    #[test]
    fn non_finite_samples_are_dropped() {
        let mut h = Histogram::default();
        h.record_secs(f64::NAN);
        h.record_secs(f64::INFINITY);
        assert_eq!(h.count(), 0);
        h.record_secs(-1.0); // clamps to 0
        assert_eq!(h.count(), 1);
        assert_eq!(h.min(), 0.0);
    }
}
