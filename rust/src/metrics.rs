//! Serving metrics: counters + latency histograms (log-bucketed), printed
//! by the server and the bench harness.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Duration;

/// Metric names shared across the serving stack so producers (server),
/// consumers (benches, demos), and assertions (tests) can never drift
/// apart on spelling.
pub mod names {
    /// Policy prefills re-run for a request that already completed one —
    /// recompute-resume after a lost swap handle, or a deferred admission
    /// that somehow dropped its carried prefill. The swap-to-host and
    /// carried-prefill paths exist precisely to keep this at zero; tests
    /// pin it there.
    pub const PREFILL_RECOMPUTED: &str = "prefill_recomputed";
    /// Preempted lanes serialized to the host swap arena.
    pub const SWAP_OUTS: &str = "swap_outs";
    /// Lanes restored from the swap arena (zero-prefill resume).
    pub const SWAP_INS: &str = "swap_ins";
    /// Preemptions that could not swap (disabled, or the lane alone
    /// exceeds the budget) and fell back to recompute-resume.
    pub const SWAP_REFUSED: &str = "swap_out_refused";
    /// Resumes whose handle was gone (dropped under host-memory
    /// pressure) and fell back to recompute-resume.
    pub const SWAP_FALLBACK_RECOMPUTE: &str = "swap_fallback_recompute";
    /// Gauge: host bytes currently held by swapped lanes.
    pub const SWAP_BYTES_USED: &str = "swap_bytes_used";
    /// Gauge: configured swap budget in bytes.
    pub const SWAP_BYTES_BUDGET: &str = "swap_bytes_budget";
    /// Gauge: swapped lanes currently parked on host.
    pub const SWAP_ENTRIES: &str = "swap_entries";
    /// Gauge: entries evicted oldest-first to make room for newer
    /// swap-outs (their owners recompute-resume).
    pub const SWAP_DROPPED: &str = "swap_entries_dropped";
    /// Gauge: block takes refused by a tenant quota while the pool still
    /// had allocatable blocks (from `PoolStats::quota_denials`).
    pub const POOL_QUOTA_DENIALS: &str = "pool_quota_denials";
    /// Counter: shard slab planes materialized for device upload (the
    /// per-shard staleness win: a mutation confined to one shard counts
    /// 1, a whole-row append counts S; an all-current step counts 0).
    /// On the unsharded path this counts whole-slab re-uploads.
    pub const SHARD_UPLOADS: &str = "shard_uploads";
    /// Counter: decode steps served through the KV-head-sharded
    /// block-table path (`decode_paged_shard_{B}x{C}s{S}`).
    pub const DECODE_STEPS_SHARDED: &str = "decode_steps_sharded";
    /// Gauge (0/1): 1 = the serving loop resolved the sharded decode
    /// path at startup.
    pub const DECODE_SHARDED: &str = "decode_sharded";

    use crate::coordinator::paging::TenantId;

    /// Gauge name: device bytes shard `s` pins for this store's K + V
    /// slab planes (`num_blocks * block_tokens * KV/S * hd * 4 * 2`).
    pub fn shard_slab_bytes(s: usize) -> String {
        format!("shard_{s}_slab_bytes")
    }

    /// Gauge name: blocks currently charged to the tenant (first-toucher
    /// rule; reconciles with `pool_blocks_in_use` summed over tenants).
    pub fn tenant_blocks_held(t: TenantId) -> String {
        format!("tenant_{t}_blocks_held")
    }

    /// Gauge name: the tenant's configured reserved block floor.
    pub fn tenant_blocks_reserved(t: TenantId) -> String {
        format!("tenant_{t}_blocks_reserved")
    }

    /// Gauge name: host swap bytes currently parked by the tenant's
    /// preempted lanes.
    pub fn tenant_swap_bytes_used(t: TenantId) -> String {
        format!("tenant_{t}_swap_bytes_used")
    }

    /// Counter name: lanes of this tenant preempted under pool pressure.
    pub fn tenant_preempted(t: TenantId) -> String {
        format!("tenant_{t}_preempted")
    }

    /// Counter name: this tenant's requests rejected (pool can never fit,
    /// prompt too long, or prefill failure).
    pub fn tenant_rejected(t: TenantId) -> String {
        format!("tenant_{t}_rejected")
    }

    /// Counter name: this tenant's requests completed successfully.
    pub fn tenant_completed(t: TenantId) -> String {
        format!("tenant_{t}_completed")
    }
}

/// Log-bucketed latency histogram (microsecond resolution).
#[derive(Debug, Clone, Default)]
pub struct Histogram {
    samples: Vec<f64>,
}

impl Histogram {
    pub fn record(&mut self, d: Duration) {
        self.samples.push(d.as_secs_f64());
    }

    pub fn record_secs(&mut self, s: f64) {
        self.samples.push(s);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        crate::util::mean_std(&self.samples).0
    }

    pub fn p(&self, pct: f64) -> f64 {
        crate::util::percentile(&self.samples, pct)
    }

    pub fn total(&self) -> f64 {
        self.samples.iter().sum()
    }

    pub fn summary(&self) -> String {
        if self.samples.is_empty() {
            return "n=0".into();
        }
        format!(
            "n={} mean={:.1}ms p50={:.1}ms p95={:.1}ms p99={:.1}ms",
            self.count(),
            self.mean() * 1e3,
            self.p(50.0) * 1e3,
            self.p(95.0) * 1e3,
            self.p(99.0) * 1e3,
        )
    }
}

/// Shared registry for the serving stack.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Histogram>,
}

impl Metrics {
    /// Process-wide registry for paths that have no `Metrics` handle of
    /// their own (e.g. `engine::generate`, which is invoked by evals and
    /// benches without a serving stack around it). Servers keep their own
    /// per-instance registries; this one aggregates engine-level events
    /// such as `decode_truncated_by_capacity`.
    pub fn global() -> &'static Metrics {
        use std::sync::OnceLock;
        static GLOBAL: OnceLock<Metrics> = OnceLock::new();
        GLOBAL.get_or_init(Metrics::default)
    }

    pub fn inc(&self, name: &str, by: u64) {
        let mut g = self.inner.lock().unwrap();
        *g.counters.entry(name.to_string()).or_default() += by;
    }

    /// Set a point-in-time gauge (block-pool occupancy, hit rates, ...).
    pub fn set_gauge(&self, name: &str, value: f64) {
        let mut g = self.inner.lock().unwrap();
        g.gauges.insert(name.to_string(), value);
    }

    pub fn gauge(&self, name: &str) -> f64 {
        self.inner
            .lock()
            .unwrap()
            .gauges
            .get(name)
            .copied()
            .unwrap_or(0.0)
    }

    pub fn observe(&self, name: &str, secs: f64) {
        let mut g = self.inner.lock().unwrap();
        g.histograms
            .entry(name.to_string())
            .or_default()
            .record_secs(secs);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.inner
            .lock()
            .unwrap()
            .counters
            .get(name)
            .copied()
            .unwrap_or(0)
    }

    pub fn histogram(&self, name: &str) -> Histogram {
        self.inner
            .lock()
            .unwrap()
            .histograms
            .get(name)
            .cloned()
            .unwrap_or_default()
    }

    pub fn report(&self) -> String {
        let g = self.inner.lock().unwrap();
        let mut out = String::new();
        for (k, v) in &g.counters {
            out.push_str(&format!("{k:32} {v}\n"));
        }
        for (k, v) in &g.gauges {
            out.push_str(&format!("{k:32} {v:.3}\n"));
        }
        for (k, h) in &g.histograms {
            out.push_str(&format!("{k:32} {}\n", h.summary()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_histograms() {
        let m = Metrics::default();
        m.inc("requests", 2);
        m.inc("requests", 3);
        assert_eq!(m.counter("requests"), 5);
        m.observe("latency", 0.010);
        m.observe("latency", 0.020);
        let h = m.histogram("latency");
        assert_eq!(h.count(), 2);
        assert!((h.mean() - 0.015).abs() < 1e-9);
        assert!(m.report().contains("requests"));
    }

    #[test]
    fn gauges_set_and_read() {
        let m = Metrics::default();
        assert_eq!(m.gauge("blocks_in_use"), 0.0);
        m.set_gauge("blocks_in_use", 12.0);
        m.set_gauge("blocks_in_use", 7.0); // gauges overwrite
        assert_eq!(m.gauge("blocks_in_use"), 7.0);
        m.set_gauge("prefix_hit_rate", 0.5);
        let rep = m.report();
        assert!(rep.contains("blocks_in_use"), "{rep}");
        assert!(rep.contains("prefix_hit_rate"), "{rep}");
    }

    #[test]
    fn global_registry_is_shared() {
        let a = Metrics::global();
        let before = a.counter("global_test_counter");
        Metrics::global().inc("global_test_counter", 2);
        assert_eq!(a.counter("global_test_counter"), before + 2);
    }

    #[test]
    fn percentiles_ordered() {
        let mut h = Histogram::default();
        for i in 1..=100 {
            h.record_secs(i as f64);
        }
        assert!(h.p(50.0) <= h.p(95.0));
        assert!(h.p(95.0) <= h.p(99.0));
    }
}
