//! PJRT runtime: loads AOT-compiled HLO artifacts and executes them.
//!
//! Pattern (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//!
//! Performance notes (EXPERIMENTS.md §Perf):
//!  * executables are compiled once and cached (lazy, on first use);
//!  * the flat weight vector (~2.8 MB) is transferred to a device buffer
//!    once at startup and reused via `execute_b`, so the per-call host→
//!    device traffic is only the small activations;
//!  * PJRT objects hold raw pointers (`!Send`), so threaded callers go
//!    through `exec_thread::ExecutorHandle` which owns the runtime on a
//!    dedicated thread.

pub mod exec_thread;
pub mod outputs;

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::manifest::Manifest;
use crate::tensor::{HostTensor, HostTensorI32};

/// One artifact input (f32 or i32 host tensor).
#[derive(Debug, Clone)]
pub enum In {
    F32(HostTensor),
    I32(HostTensorI32),
}

impl In {
    pub fn scalar_i32(v: i32) -> In {
        In::I32(HostTensorI32::scalar(v))
    }
}

impl From<HostTensor> for In {
    fn from(t: HostTensor) -> In {
        In::F32(t)
    }
}

impl From<HostTensorI32> for In {
    fn from(t: HostTensorI32) -> In {
        In::I32(t)
    }
}

/// Cumulative executor statistics (exposed by the `stats` CLI).
#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    pub compiles: usize,
    pub compile_secs: f64,
    pub executions: usize,
    pub execute_secs: f64,
    pub per_artifact: BTreeMap<String, (usize, f64)>,
}

pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    weights: xla::PjRtBuffer,
    weights_host: Vec<f32>,
    exes: RefCell<BTreeMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
    stats: RefCell<RuntimeStats>,
}

impl Runtime {
    pub fn new(artifact_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PjRtClient::cpu: {e}"))?;
        let weights_host = manifest.load_weights()?;
        let weights = client
            .buffer_from_host_buffer(&weights_host, &[weights_host.len()], None)
            .map_err(|e| anyhow::anyhow!("weights upload: {e}"))?;
        Ok(Runtime {
            client,
            manifest,
            weights,
            weights_host,
            exes: RefCell::new(BTreeMap::new()),
            stats: RefCell::new(RuntimeStats::default()),
        })
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats.borrow().clone()
    }

    pub fn weights_host(&self) -> &[f32] {
        &self.weights_host
    }

    /// Compile (or fetch cached) an artifact executable.
    fn executable(
        &self,
        name: &str,
    ) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.borrow().get(name) {
            return Ok(e.clone());
        }
        let path = self.manifest.hlo_path(name)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("loading {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {name}: {e}"))?;
        let dt = t0.elapsed().as_secs_f64();
        {
            let mut s = self.stats.borrow_mut();
            s.compiles += 1;
            s.compile_secs += dt;
        }
        let rc = std::rc::Rc::new(exe);
        self.exes.borrow_mut().insert(name.to_string(), rc.clone());
        Ok(rc)
    }

    /// Pre-compile a set of artifacts (warmup; avoids first-request jitter).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    /// Execute artifact `name`. `inputs` EXCLUDES the leading weight
    /// vector (input 0), which is pinned on device. Returns one host
    /// tensor per artifact output (f32 outputs only — all our artifacts
    /// emit f32; integer outputs would extend `outputs.rs`).
    pub fn run(&self, name: &str, inputs: &[In]) -> Result<Vec<HostTensor>> {
        let meta = self.manifest.artifact(name)?.clone();
        if inputs.len() + 1 != meta.inputs.len() {
            bail!(
                "{name}: got {} inputs, artifact takes {} (+weights)",
                inputs.len(),
                meta.inputs.len() - 1
            );
        }
        let exe = self.executable(name)?;
        let t0 = Instant::now();

        let mut bufs: Vec<xla::PjRtBuffer> = Vec::with_capacity(inputs.len());
        for (i, input) in inputs.iter().enumerate() {
            let sig = &meta.inputs[i + 1];
            let buf = match input {
                In::F32(t) => {
                    if t.shape != sig.shape {
                        bail!(
                            "{name} input {i}: shape {:?} != expected {:?}",
                            t.shape,
                            sig.shape
                        );
                    }
                    self.client
                        .buffer_from_host_buffer(&t.data, &t.shape, None)
                }
                In::I32(t) => {
                    if t.shape != sig.shape {
                        bail!(
                            "{name} input {i}: shape {:?} != expected {:?}",
                            t.shape,
                            sig.shape
                        );
                    }
                    self.client
                        .buffer_from_host_buffer(&t.data, &t.shape, None)
                }
            }
            .map_err(|e| anyhow::anyhow!("{name} input {i} upload: {e}"))?;
            bufs.push(buf);
        }

        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(bufs.len() + 1);
        args.push(&self.weights);
        args.extend(bufs.iter());

        let result = exe
            .execute_b(&args)
            .map_err(|e| anyhow::anyhow!("{name} execute: {e}"))?;
        let literal = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("{name} fetch: {e}"))?;
        let parts = literal
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("{name} untuple: {e}"))?;
        if parts.len() != meta.outputs.len() {
            bail!(
                "{name}: {} outputs, manifest says {}",
                parts.len(),
                meta.outputs.len()
            );
        }

        let mut out = Vec::with_capacity(parts.len());
        for (lit, sig) in parts.iter().zip(&meta.outputs) {
            // Integer outputs (e.g. pyramid per-layer lens) are widened to
            // f32 host-side; all values fit exactly.
            let data = if sig.dtype.contains("int") {
                lit.to_vec::<i32>()
                    .map_err(|e| anyhow::anyhow!("{name} output fetch: {e}"))?
                    .into_iter()
                    .map(|x| x as f32)
                    .collect()
            } else {
                lit.to_vec::<f32>()
                    .map_err(|e| anyhow::anyhow!("{name} output fetch: {e}"))?
            };
            out.push(HostTensor::new(sig.shape.clone(), data));
        }

        let dt = t0.elapsed().as_secs_f64();
        {
            let mut s = self.stats.borrow_mut();
            s.executions += 1;
            s.execute_secs += dt;
            let e = s.per_artifact.entry(name.to_string()).or_insert((0, 0.0));
            e.0 += 1;
            e.1 += dt;
        }
        Ok(out)
    }
}
