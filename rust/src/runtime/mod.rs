//! PJRT runtime: loads AOT-compiled HLO artifacts and executes them.
//!
//! Pattern (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//!
//! Performance notes (EXPERIMENTS.md §Perf):
//!  * executables are compiled once and cached (lazy, on first use);
//!  * the flat weight vector (~2.8 MB) is transferred to a device buffer
//!    once at startup and reused via `execute_b`, so the per-call host→
//!    device traffic is only the small activations;
//!  * large recurring inputs — the paged decode artifacts' block-slab
//!    planes — go through [`Runtime::run_with_pinned`]: a device buffer is
//!    kept per `(key, version)` (keys are per-store, LRU-bounded) and
//!    re-uploaded only when the version stamp changes, so an unchanged
//!    slab costs zero host→device traffic. Note that appends change the
//!    slab every generated token, so per-step re-upload persists on the
//!    pure-AOT ABI until PJRT buffer donation lands (the API shape here
//!    already supports swapping that in); the win decode banks today is
//!    host-side (no densify/clone per token);
//!  * PJRT objects hold raw pointers (`!Send`), so threaded callers go
//!    through `exec_thread::ExecutorHandle` which owns the runtime on a
//!    dedicated thread.
//!
//! Gather-based decode ABI (`decode_paged_{B}x{C}`, see
//! `python/compile/model.py::decode_paged_step`): inputs are
//! `(weights, tokens [B] i32, positions [B] i32, slab_k [NB, bt, KV, hd],
//! slab_v [NB, bt, KV, hd], tables [L, B, MB] i32, lens [L, B] i32)`; the
//! slab planes are the pinned inputs (indices 2 and 3), everything else is
//! per-step. Inputs are validated against the manifest signature by shape
//! *and* dtype — an f32 tensor where the artifact expects i32 block-table
//! indices would silently reinterpret bits on a real device.

pub mod exec_thread;
pub mod outputs;

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::manifest::Manifest;
use crate::tensor::{HostTensor, HostTensorI32};

/// One artifact input (f32 or i32 host tensor).
#[derive(Debug, Clone)]
pub enum In {
    F32(HostTensor),
    I32(HostTensorI32),
}

impl In {
    pub fn scalar_i32(v: i32) -> In {
        In::I32(HostTensorI32::scalar(v))
    }
}

impl From<HostTensor> for In {
    fn from(t: HostTensor) -> In {
        In::F32(t)
    }
}

impl From<HostTensorI32> for In {
    fn from(t: HostTensorI32) -> In {
        In::I32(t)
    }
}

/// A large recurring artifact input held on device across calls, keyed by
/// `(key, version)`. Built by the decode planner for the paged artifacts'
/// block-slab planes.
#[derive(Debug, Clone)]
pub struct PinnedInput {
    /// Position among the artifact's non-weight inputs.
    pub index: usize,
    pub key: String,
    /// Content stamp; a matching resident buffer is reused without upload.
    pub version: u64,
    /// Host payload. `None` when the caller verified residency first via
    /// `Exec::pinned_is_current` — the executor errors if it is wrong.
    pub tensor: Option<HostTensor>,
}

impl PinnedInput {
    pub fn new(index: usize, key: &str, version: u64, tensor: HostTensor) -> Self {
        PinnedInput { index, key: key.to_string(), version, tensor: Some(tensor) }
    }

    /// Reference an already-resident `(key, version)` without a payload.
    pub fn cached(index: usize, key: &str, version: u64) -> Self {
        PinnedInput { index, key: key.to_string(), version, tensor: None }
    }
}

/// Cumulative executor statistics (exposed by the `stats` CLI).
#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    pub compiles: usize,
    pub compile_secs: f64,
    pub executions: usize,
    pub execute_secs: f64,
    /// Pinned-input uploads actually performed (version changed).
    pub pinned_uploads: usize,
    /// Pinned-input reuses (version matched, no host→device traffic).
    pub pinned_hits: usize,
    /// Device bytes currently held by pinned inputs.
    pub pinned_bytes: usize,
    pub per_artifact: BTreeMap<String, (usize, f64)>,
}

/// A resident pinned buffer plus the bookkeeping to validate reuse.
struct PinnedSlot {
    version: u64,
    shape: Vec<usize>,
    bytes: usize,
    /// Monotonic use stamp for LRU eviction.
    last_used: u64,
    buf: xla::PjRtBuffer,
}

/// Most pinned keys the runtime keeps resident. Keys are per-store
/// (`decode_slab_k:{store_id}`), so without a cap a long-lived runtime
/// serving many short-lived engine stores would accumulate dead buffers;
/// least-recently-used entries are dropped past this bound.
/// `ExecutorHandle`'s residency mirror bounds itself to the same value —
/// a larger mirror would over-claim residency for evicted keys.
pub const PINNED_CACHE_CAP: usize = 8;

pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    weights: xla::PjRtBuffer,
    weights_host: Vec<f32>,
    exes: RefCell<BTreeMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
    pinned: RefCell<BTreeMap<String, PinnedSlot>>,
    pinned_clock: std::cell::Cell<u64>,
    stats: RefCell<RuntimeStats>,
}

impl Runtime {
    pub fn new(artifact_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PjRtClient::cpu: {e}"))?;
        let weights_host = manifest.load_weights()?;
        let weights = client
            .buffer_from_host_buffer(&weights_host, &[weights_host.len()], None)
            .map_err(|e| anyhow::anyhow!("weights upload: {e}"))?;
        Ok(Runtime {
            client,
            manifest,
            weights,
            weights_host,
            exes: RefCell::new(BTreeMap::new()),
            pinned: RefCell::new(BTreeMap::new()),
            pinned_clock: std::cell::Cell::new(0),
            stats: RefCell::new(RuntimeStats::default()),
        })
    }

    /// Whether pinned input `key` is resident at exactly `version`.
    pub fn pinned_is_current(&self, key: &str, version: u64) -> bool {
        self.pinned
            .borrow()
            .get(key)
            .map(|s| s.version == version)
            .unwrap_or(false)
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats.borrow().clone()
    }

    pub fn weights_host(&self) -> &[f32] {
        &self.weights_host
    }

    /// Compile (or fetch cached) an artifact executable.
    fn executable(
        &self,
        name: &str,
    ) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.borrow().get(name) {
            return Ok(e.clone());
        }
        let path = self.manifest.hlo_path(name)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("loading {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {name}: {e}"))?;
        let dt = t0.elapsed().as_secs_f64();
        {
            let mut s = self.stats.borrow_mut();
            s.compiles += 1;
            s.compile_secs += dt;
        }
        let rc = std::rc::Rc::new(exe);
        self.exes.borrow_mut().insert(name.to_string(), rc.clone());
        Ok(rc)
    }

    /// Pre-compile a set of artifacts (warmup; avoids first-request jitter).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    /// Upload one ordinary input after validating shape AND dtype against
    /// the manifest signature (`i` is the absolute non-weight input index).
    fn upload_input(
        &self,
        name: &str,
        i: usize,
        input: &In,
        sig: &crate::manifest::TensorSig,
    ) -> Result<xla::PjRtBuffer> {
        let want_int = sig.dtype.contains("int");
        let buf = match input {
            In::F32(t) => {
                if want_int {
                    bail!(
                        "{name} input {i}: f32 tensor where artifact \
                         expects {}",
                        sig.dtype
                    );
                }
                if t.shape != sig.shape {
                    bail!(
                        "{name} input {i}: shape {:?} != expected {:?}",
                        t.shape,
                        sig.shape
                    );
                }
                self.client.buffer_from_host_buffer(&t.data, &t.shape, None)
            }
            In::I32(t) => {
                if !want_int {
                    bail!(
                        "{name} input {i}: i32 tensor where artifact \
                         expects {}",
                        sig.dtype
                    );
                }
                if t.shape != sig.shape {
                    bail!(
                        "{name} input {i}: shape {:?} != expected {:?}",
                        t.shape,
                        sig.shape
                    );
                }
                self.client.buffer_from_host_buffer(&t.data, &t.shape, None)
            }
        }
        .map_err(|e| anyhow::anyhow!("{name} input {i} upload: {e}"))?;
        Ok(buf)
    }

    /// Execute artifact `name`. `inputs` EXCLUDES the leading weight
    /// vector (input 0), which is pinned on device. Returns one host
    /// tensor per artifact output (f32 outputs only — all our artifacts
    /// emit f32; integer outputs would extend `outputs.rs`).
    pub fn run(&self, name: &str, inputs: &[In]) -> Result<Vec<HostTensor>> {
        self.run_with_pinned(name, &[], inputs)
    }

    /// Multi-shard dispatch for the `decode_paged_shard_{B}x{C}s{S}`
    /// family: one group of pinned inputs per KV-head shard (shard `s`'s
    /// slab planes, under per-shard keys/versions), flattened onto
    /// [`Runtime::run_with_pinned`]. On this single-device runtime every
    /// shard's buffers share one executor, and the decode planner drives
    /// the equivalent flat call directly (`Exec::run_pinned_ref` with the
    /// same per-shard keys) — the win is already real there (each shard
    /// re-uploads independently, so a mutation confined to one shard
    /// moves 1/S of the slab). This method is the multi-device fan-out
    /// point: with real bindings each group instead targets shard `s`'s
    /// own device/executor (`exec_thread::ShardedExecutor`).
    pub fn run_sharded(
        &self,
        name: &str,
        shard_pinned: &[Vec<PinnedInput>],
        inputs: &[In],
    ) -> Result<Vec<HostTensor>> {
        let flat: Vec<PinnedInput> = shard_pinned
            .iter()
            .flat_map(|group| group.iter().cloned())
            .collect();
        self.run_with_pinned(name, &flat, inputs)
    }

    /// Like [`Runtime::run`], with some inputs device-pinned across calls:
    /// each [`PinnedInput`] occupies `index` among the non-weight inputs
    /// and is re-uploaded only when its `(key, version)` is not already
    /// resident — an unchanged slab costs nothing. (A slab mutated since
    /// the last call is re-uploaded in full; in-place device append needs
    /// buffer donation, tracked on the ROADMAP.)
    pub fn run_with_pinned(
        &self,
        name: &str,
        pinned: &[PinnedInput],
        inputs: &[In],
    ) -> Result<Vec<HostTensor>> {
        let meta = self.manifest.artifact(name)?.clone();
        let n = meta.inputs.len() - 1;
        if inputs.len() + pinned.len() != n {
            bail!(
                "{name}: got {} inputs + {} pinned, artifact takes {n} \
                 (+weights)",
                inputs.len(),
                pinned.len()
            );
        }
        let exe = self.executable(name)?;
        let t0 = Instant::now();

        // Ensure every pinned input is resident at the requested version.
        {
            let mut cache = self.pinned.borrow_mut();
            let mut stats = self.stats.borrow_mut();
            for p in pinned {
                if p.index >= n {
                    bail!("{name}: pinned input index {} out of range", p.index);
                }
                let sig = &meta.inputs[p.index + 1];
                let now = self.pinned_clock.get() + 1;
                self.pinned_clock.set(now);
                let hit = match cache.get_mut(&p.key) {
                    Some(s) if s.version == p.version && s.shape == sig.shape => {
                        s.last_used = now;
                        true
                    }
                    _ => false,
                };
                if hit {
                    stats.pinned_hits += 1;
                    continue;
                }
                let t = p.tensor.as_ref().ok_or_else(|| {
                    anyhow::anyhow!(
                        "{name}: pinned input `{}`@{} is not resident and \
                         no payload was provided",
                        p.key,
                        p.version
                    )
                })?;
                if t.shape != sig.shape {
                    bail!(
                        "{name} pinned `{}`: shape {:?} != expected {:?}",
                        p.key,
                        t.shape,
                        sig.shape
                    );
                }
                if sig.dtype.contains("int") {
                    bail!(
                        "{name} pinned `{}`: f32 payload where artifact \
                         expects {}",
                        p.key,
                        sig.dtype
                    );
                }
                let buf = self
                    .client
                    .buffer_from_host_buffer(&t.data, &t.shape, None)
                    .map_err(|e| {
                        anyhow::anyhow!("{name} pinned `{}` upload: {e}", p.key)
                    })?;
                let bytes = buf
                    .on_device_size_in_bytes()
                    .unwrap_or(t.data.len() * 4);
                if let Some(old) = cache.insert(
                    p.key.clone(),
                    PinnedSlot {
                        version: p.version,
                        shape: sig.shape.clone(),
                        bytes,
                        last_used: now,
                        buf,
                    },
                ) {
                    stats.pinned_bytes =
                        stats.pinned_bytes.saturating_sub(old.bytes);
                }
                stats.pinned_uploads += 1;
                stats.pinned_bytes += bytes;
            }
            // LRU bound — but never evict a key this call is about to use.
            while cache.len() > PINNED_CACHE_CAP {
                let victim = cache
                    .iter()
                    .filter(|(k, _)| {
                        !pinned.iter().any(|p| p.key.as_str() == k.as_str())
                    })
                    .min_by_key(|(_, s)| s.last_used)
                    .map(|(k, _)| k.clone());
                match victim {
                    Some(k) => {
                        if let Some(old) = cache.remove(&k) {
                            stats.pinned_bytes =
                                stats.pinned_bytes.saturating_sub(old.bytes);
                        }
                    }
                    None => break, // every resident key is in use this call
                }
            }
        }

        // Upload the per-step inputs into the positions pinned ones skip.
        let mut fresh: Vec<xla::PjRtBuffer> = Vec::with_capacity(inputs.len());
        let mut fresh_at: Vec<Option<usize>> = vec![None; n];
        let mut pinned_at: Vec<Option<&PinnedInput>> = vec![None; n];
        for p in pinned {
            if pinned_at[p.index].is_some() {
                bail!("{name}: duplicate pinned input index {}", p.index);
            }
            pinned_at[p.index] = Some(p);
        }
        {
            let mut next = inputs.iter();
            for slot in 0..n {
                if pinned_at[slot].is_some() {
                    continue;
                }
                let input = next.next().expect("input arity checked");
                let sig = &meta.inputs[slot + 1];
                fresh_at[slot] = Some(fresh.len());
                fresh.push(self.upload_input(name, slot, input, sig)?);
            }
        }

        let cache = self.pinned.borrow();
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(n + 1);
        args.push(&self.weights);
        for slot in 0..n {
            if let Some(p) = pinned_at[slot] {
                args.push(&cache.get(&p.key).expect("pinned resident").buf);
            } else {
                args.push(&fresh[fresh_at[slot].expect("fresh uploaded")]);
            }
        }

        let result = exe
            .execute_b(&args)
            .map_err(|e| anyhow::anyhow!("{name} execute: {e}"))?;
        let literal = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("{name} fetch: {e}"))?;
        let parts = literal
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("{name} untuple: {e}"))?;
        if parts.len() != meta.outputs.len() {
            bail!(
                "{name}: {} outputs, manifest says {}",
                parts.len(),
                meta.outputs.len()
            );
        }

        let mut out = Vec::with_capacity(parts.len());
        for (lit, sig) in parts.iter().zip(&meta.outputs) {
            // Integer outputs (e.g. pyramid per-layer lens) are widened to
            // f32 host-side; all values fit exactly.
            let data = if sig.dtype.contains("int") {
                lit.to_vec::<i32>()
                    .map_err(|e| anyhow::anyhow!("{name} output fetch: {e}"))?
                    .into_iter()
                    .map(|x| x as f32)
                    .collect()
            } else {
                lit.to_vec::<f32>()
                    .map_err(|e| anyhow::anyhow!("{name} output fetch: {e}"))?
            };
            out.push(HostTensor::new(sig.shape.clone(), data));
        }

        let dt = t0.elapsed().as_secs_f64();
        {
            let mut s = self.stats.borrow_mut();
            s.executions += 1;
            s.execute_secs += dt;
            let e = s.per_artifact.entry(name.to_string()).or_insert((0, 0.0));
            e.0 += 1;
            e.1 += dt;
        }
        Ok(out)
    }
}
