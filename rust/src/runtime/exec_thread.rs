//! Executor thread: owns the (non-`Send`) PJRT runtime and serves execute
//! requests over channels, so the threaded serving coordinator can call
//! into PJRT from any thread.
//!
//! This is the substrate a GPU serving stack gets from CUDA streams; here
//! the single executor thread also matches the paper's single-A100 testbed
//! (one device, requests serialized onto it).

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::Result;

use crate::runtime::{In, PinnedInput, Runtime, RuntimeStats};
use crate::tensor::HostTensor;

enum Msg {
    Run {
        name: String,
        inputs: Vec<In>,
        reply: mpsc::Sender<Result<Vec<HostTensor>>>,
    },
    RunPinned {
        name: String,
        pinned: Vec<PinnedInput>,
        inputs: Vec<In>,
        reply: mpsc::Sender<Result<Vec<HostTensor>>>,
    },
    Warmup {
        names: Vec<String>,
        reply: mpsc::Sender<Result<()>>,
    },
    Stats {
        reply: mpsc::Sender<RuntimeStats>,
    },
    Shutdown,
}

/// Cloneable handle; all clones feed the same executor thread.
#[derive(Clone)]
pub struct ExecutorHandle {
    tx: mpsc::Sender<Msg>,
    /// Handle-side mirror of which pinned `(key, version)` pairs the
    /// executor holds, so callers can skip materializing an unchanged
    /// slab before sending (shared by every clone of this handle).
    pinned_versions: Arc<Mutex<BTreeMap<String, u64>>>,
}

pub struct Executor {
    handle: ExecutorHandle,
    join: Option<JoinHandle<()>>,
}

impl Executor {
    /// Spawn the executor thread; fails fast if the runtime cannot load.
    pub fn spawn(artifact_dir: PathBuf) -> Result<Executor> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("fastkv-executor".into())
            .spawn(move || {
                let rt = match Runtime::new(&artifact_dir) {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(msg) = rx.recv() {
                    match msg {
                        Msg::Run { name, inputs, reply } => {
                            let _ = reply.send(rt.run(&name, &inputs));
                        }
                        Msg::RunPinned { name, pinned, inputs, reply } => {
                            let _ = reply.send(rt.run_with_pinned(
                                &name, &pinned, &inputs,
                            ));
                        }
                        Msg::Warmup { names, reply } => {
                            let refs: Vec<&str> =
                                names.iter().map(|s| s.as_str()).collect();
                            let _ = reply.send(rt.warmup(&refs));
                        }
                        Msg::Stats { reply } => {
                            let _ = reply.send(rt.stats());
                        }
                        Msg::Shutdown => break,
                    }
                }
            })?;
        ready_rx.recv()??;
        Ok(Executor {
            handle: ExecutorHandle {
                tx,
                pinned_versions: Arc::new(Mutex::new(BTreeMap::new())),
            },
            join: Some(join),
        })
    }

    pub fn handle(&self) -> ExecutorHandle {
        self.handle.clone()
    }
}

impl Drop for Executor {
    fn drop(&mut self) {
        let _ = self.handle.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// One executor thread per KV-head shard. Shard `s`'s handle owns the
/// residency of shard `s`'s pinned slab planes (its own `Runtime`, its
/// own pinned cache, its own version mirror) — the thread-level
/// embodiment of "each shard's slab lives on its own device". The
/// coordinator uploads through `handle(s)` and combines the per-shard
/// partial outputs host-side (`coordinator::decode::combine_head_shards`).
///
/// On the current single-device PJRT runtime the decode hot path keeps
/// all shards on one executor (`Runtime::run_sharded`) because PJRT
/// buffers are not shareable across clients; this pool exists so the
/// multi-device dispatch has its shape ready — spawning, addressing, and
/// tearing down S runtimes is already exercised.
pub struct ShardedExecutor {
    execs: Vec<Executor>,
}

impl ShardedExecutor {
    /// Spawn `shards` executor threads over the same artifact dir; fails
    /// fast if any runtime cannot load (and tears down the ones that
    /// did).
    pub fn spawn(artifact_dir: PathBuf, shards: usize) -> Result<ShardedExecutor> {
        anyhow::ensure!(shards >= 1, "shard count must be at least 1");
        let mut execs = Vec::with_capacity(shards);
        for _ in 0..shards {
            execs.push(Executor::spawn(artifact_dir.clone())?);
        }
        Ok(ShardedExecutor { execs })
    }

    /// Number of shard executors in the pool.
    pub fn shards(&self) -> usize {
        self.execs.len()
    }

    /// Handle to shard `s`'s executor thread.
    pub fn handle(&self, shard: usize) -> ExecutorHandle {
        self.execs[shard].handle()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sharded_executor_validates_and_fails_fast() {
        let dir = std::path::PathBuf::from("/nonexistent-artifacts");
        // zero shards is rejected before any runtime is touched
        let err = ShardedExecutor::spawn(dir.clone(), 0).unwrap_err();
        assert!(format!("{err:#}").contains("shard count"), "{err:#}");
        // a runtime that cannot load (missing artifacts here; the PJRT
        // stub in this image) propagates from the first shard's spawn
        // instead of leaving half a pool running
        assert!(ShardedExecutor::spawn(dir, 2).is_err());
    }
}

impl ExecutorHandle {
    pub fn run(&self, name: &str, inputs: Vec<In>) -> Result<Vec<HostTensor>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Run { name: name.to_string(), inputs, reply })
            .map_err(|_| anyhow::anyhow!("executor thread gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("executor dropped reply"))?
    }

    /// Whether the executor holds pinned input `key` at `version`, per
    /// this handle's mirror of successful `run_pinned` calls.
    pub fn pinned_is_current(&self, key: &str, version: u64) -> bool {
        self.pinned_versions
            .lock()
            .unwrap()
            .get(key)
            .map(|&v| v == version)
            .unwrap_or(false)
    }

    /// Forward a pinned run to the executor thread; on success, record
    /// the pinned versions it now holds.
    pub fn run_pinned(
        &self,
        name: &str,
        pinned: Vec<PinnedInput>,
        inputs: Vec<In>,
    ) -> Result<Vec<HostTensor>> {
        let versions: Vec<(String, u64)> =
            pinned.iter().map(|p| (p.key.clone(), p.version)).collect();
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::RunPinned { name: name.to_string(), pinned, inputs, reply })
            .map_err(|_| anyhow::anyhow!("executor thread gone"))?;
        let out = rx
            .recv()
            .map_err(|_| anyhow::anyhow!("executor dropped reply"))?;
        let mut map = self.pinned_versions.lock().unwrap();
        if out.is_ok() {
            // Bound the mirror to the executor's own pinned cache cap: a
            // mirror that only ever grows would both leak (one fresh
            // store id per engine call) and over-claim residency for
            // LRU-evicted keys. Past the cap, keep only the keys this
            // call touched.
            if map.len() >= crate::runtime::PINNED_CACHE_CAP {
                map.retain(|k, _| versions.iter().any(|(vk, _)| vk == k));
            }
            for (k, v) in versions {
                map.insert(k, v);
            }
        } else {
            // Unknown executor state for these keys — stop claiming them
            // so the next step sends payloads instead of racing a miss.
            for (k, _) in versions {
                map.remove(&k);
            }
        }
        drop(map);
        out
    }

    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Warmup {
                names: names.iter().map(|s| s.to_string()).collect(),
                reply,
            })
            .map_err(|_| anyhow::anyhow!("executor thread gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("executor dropped reply"))?
    }

    pub fn stats(&self) -> Result<RuntimeStats> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Stats { reply })
            .map_err(|_| anyhow::anyhow!("executor thread gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("executor dropped reply"))
    }
}
