//! Executor thread: owns the (non-`Send`) PJRT runtime and serves execute
//! requests over channels, so the threaded serving coordinator can call
//! into PJRT from any thread.
//!
//! This is the substrate a GPU serving stack gets from CUDA streams; here
//! the single executor thread also matches the paper's single-A100 testbed
//! (one device, requests serialized onto it).

use std::path::PathBuf;
use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::Result;

use crate::runtime::{In, Runtime, RuntimeStats};
use crate::tensor::HostTensor;

enum Msg {
    Run {
        name: String,
        inputs: Vec<In>,
        reply: mpsc::Sender<Result<Vec<HostTensor>>>,
    },
    Warmup {
        names: Vec<String>,
        reply: mpsc::Sender<Result<()>>,
    },
    Stats {
        reply: mpsc::Sender<RuntimeStats>,
    },
    Shutdown,
}

/// Cloneable handle; all clones feed the same executor thread.
#[derive(Clone)]
pub struct ExecutorHandle {
    tx: mpsc::Sender<Msg>,
}

pub struct Executor {
    handle: ExecutorHandle,
    join: Option<JoinHandle<()>>,
}

impl Executor {
    /// Spawn the executor thread; fails fast if the runtime cannot load.
    pub fn spawn(artifact_dir: PathBuf) -> Result<Executor> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("fastkv-executor".into())
            .spawn(move || {
                let rt = match Runtime::new(&artifact_dir) {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(msg) = rx.recv() {
                    match msg {
                        Msg::Run { name, inputs, reply } => {
                            let _ = reply.send(rt.run(&name, &inputs));
                        }
                        Msg::Warmup { names, reply } => {
                            let refs: Vec<&str> =
                                names.iter().map(|s| s.as_str()).collect();
                            let _ = reply.send(rt.warmup(&refs));
                        }
                        Msg::Stats { reply } => {
                            let _ = reply.send(rt.stats());
                        }
                        Msg::Shutdown => break,
                    }
                }
            })?;
        ready_rx.recv()??;
        Ok(Executor { handle: ExecutorHandle { tx }, join: Some(join) })
    }

    pub fn handle(&self) -> ExecutorHandle {
        self.handle.clone()
    }
}

impl Drop for Executor {
    fn drop(&mut self) {
        let _ = self.handle.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl ExecutorHandle {
    pub fn run(&self, name: &str, inputs: Vec<In>) -> Result<Vec<HostTensor>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Run { name: name.to_string(), inputs, reply })
            .map_err(|_| anyhow::anyhow!("executor thread gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("executor dropped reply"))?
    }

    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Warmup {
                names: names.iter().map(|s| s.to_string()).collect(),
                reply,
            })
            .map_err(|_| anyhow::anyhow!("executor thread gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("executor dropped reply"))?
    }

    pub fn stats(&self) -> Result<RuntimeStats> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Stats { reply })
            .map_err(|_| anyhow::anyhow!("executor thread gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("executor dropped reply"))
    }
}
