//! Typed views over artifact outputs.
//!
//! Each artifact kind has a fixed output tuple (see `python/compile/model.py`
//! docstrings); these structs name the members so the coordinator never
//! indexes raw tuples.

use crate::tensor::HostTensor;

/// `prefill_full_{N}` / `prefill_pallas_{N}`:
/// (logits [V], k [L,N,KV,hd], v, win [L,H,N], acc [L,H,N], final_h [D])
#[derive(Debug)]
pub struct PrefillFullOut {
    pub logits: HostTensor,
    pub k: HostTensor,
    pub v: HostTensor,
    pub win: HostTensor,
    pub acc: HostTensor,
    pub final_h: HostTensor,
}

impl PrefillFullOut {
    pub fn from_vec(mut v: Vec<HostTensor>) -> Self {
        assert_eq!(v.len(), 6, "prefill_full outputs");
        let final_h = v.pop().unwrap();
        let acc = v.pop().unwrap();
        let win = v.pop().unwrap();
        let vv = v.pop().unwrap();
        let k = v.pop().unwrap();
        let logits = v.pop().unwrap();
        PrefillFullOut { logits, k, v: vv, win, acc, final_h }
    }
}

/// `prefill_stage1_{N}`:
/// (hidden [N,D], k [T,N,KV,hd], v, win [T,H,N], acc [T,H,N])
#[derive(Debug)]
pub struct Stage1Out {
    pub hidden: HostTensor,
    pub k: HostTensor,
    pub v: HostTensor,
    pub win: HostTensor,
    pub acc: HostTensor,
}

impl Stage1Out {
    pub fn from_vec(mut v: Vec<HostTensor>) -> Self {
        assert_eq!(v.len(), 5, "stage1 outputs");
        let acc = v.pop().unwrap();
        let win = v.pop().unwrap();
        let vv = v.pop().unwrap();
        let k = v.pop().unwrap();
        let hidden = v.pop().unwrap();
        Stage1Out { hidden, k, v: vv, win, acc }
    }
}

/// `prefill_stage1_chunk_{c}x{N}`:
/// (hidden [c,D], k_c [T,c,KV,hd], v_c, win [T,H,N], acc [T,H,N])
///
/// `k_c`/`v_c` are only the chunk's *new* KV rows — the chunked driver
/// (`policies::ChunkedStage1`) copies them back into its host-side
/// carried buffer after each chunk. `win` spans the whole buffer and is
/// complete (bit-identical to the monolithic stage-1 `win`) on the final
/// chunk, whose span always contains the whole observation window.
#[derive(Debug)]
pub struct Stage1ChunkOut {
    pub hidden: HostTensor,
    pub k_c: HostTensor,
    pub v_c: HostTensor,
    pub win: HostTensor,
    pub acc: HostTensor,
}

impl Stage1ChunkOut {
    pub fn from_vec(mut v: Vec<HostTensor>) -> Self {
        assert_eq!(v.len(), 5, "stage1_chunk outputs");
        let acc = v.pop().unwrap();
        let win = v.pop().unwrap();
        let v_c = v.pop().unwrap();
        let k_c = v.pop().unwrap();
        let hidden = v.pop().unwrap();
        Stage1ChunkOut { hidden, k_c, v_c, win, acc }
    }
}

/// `prefill_stage2_{Nt}`:
/// (logits [V], k [L-T,Nt,KV,hd], v, win, acc, final_h [D])
#[derive(Debug)]
pub struct Stage2Out {
    pub logits: HostTensor,
    pub k: HostTensor,
    pub v: HostTensor,
    pub win: HostTensor,
    pub acc: HostTensor,
    pub final_h: HostTensor,
}

impl Stage2Out {
    pub fn from_vec(v: Vec<HostTensor>) -> Self {
        let f = PrefillFullOut::from_vec(v);
        Stage2Out {
            logits: f.logits,
            k: f.k,
            v: f.v,
            win: f.win,
            acc: f.acc,
            final_h: f.final_h,
        }
    }
}

/// `prefill_pyramid_{N}`: (logits [V], k [L,N,KV,hd], v, lens [L])
#[derive(Debug)]
pub struct PyramidOut {
    pub logits: HostTensor,
    pub k: HostTensor,
    pub v: HostTensor,
    pub lens: HostTensor,
}

impl PyramidOut {
    pub fn from_vec(mut v: Vec<HostTensor>) -> Self {
        assert_eq!(v.len(), 4, "pyramid outputs");
        let lens = v.pop().unwrap();
        let vv = v.pop().unwrap();
        let k = v.pop().unwrap();
        let logits = v.pop().unwrap();
        PyramidOut { logits, k, v: vv, lens }
    }
}

/// `decode_{B}x{C}` and `decode_paged_{B}x{C}`:
/// (logits [B,V], k_new [L,B,KV,hd], v_new) — the block-table artifact
/// deliberately shares the dense artifact's output tuple so the decode
/// stepper applies either path's outputs identically.
#[derive(Debug)]
pub struct DecodeOut {
    pub logits: HostTensor,
    pub k_new: HostTensor,
    pub v_new: HostTensor,
}

impl DecodeOut {
    pub fn from_vec(mut v: Vec<HostTensor>) -> Self {
        assert_eq!(v.len(), 3, "decode outputs");
        let v_new = v.pop().unwrap();
        let k_new = v.pop().unwrap();
        let logits = v.pop().unwrap();
        DecodeOut { logits, k_new, v_new }
    }
}

/// `sweep_tsp_l{t}_{N}`: (logits [V], final_h [D])
#[derive(Debug)]
pub struct SweepOut {
    pub logits: HostTensor,
    pub final_h: HostTensor,
}

impl SweepOut {
    pub fn from_vec(mut v: Vec<HostTensor>) -> Self {
        assert_eq!(v.len(), 2, "sweep outputs");
        let final_h = v.pop().unwrap();
        let logits = v.pop().unwrap();
        SweepOut { logits, final_h }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: Vec<usize>) -> HostTensor {
        HostTensor::zeros(shape)
    }

    #[test]
    fn prefill_full_unpack_order() {
        let out = PrefillFullOut::from_vec(vec![
            t(vec![256]),
            t(vec![8, 64, 2, 24]),
            t(vec![8, 64, 2, 24]),
            t(vec![8, 4, 64]),
            t(vec![8, 4, 64]),
            t(vec![96]),
        ]);
        assert_eq!(out.logits.shape, vec![256]);
        assert_eq!(out.k.shape, vec![8, 64, 2, 24]);
        assert_eq!(out.win.shape, vec![8, 4, 64]);
        assert_eq!(out.final_h.shape, vec![96]);
    }

    #[test]
    fn stage1_chunk_unpack_order() {
        let out = Stage1ChunkOut::from_vec(vec![
            t(vec![256, 96]),
            t(vec![4, 256, 2, 24]),
            t(vec![4, 256, 2, 24]),
            t(vec![4, 4, 1024]),
            t(vec![4, 4, 1024]),
        ]);
        assert_eq!(out.hidden.shape, vec![256, 96]);
        assert_eq!(out.k_c.shape, vec![4, 256, 2, 24]);
        assert_eq!(out.win.shape, vec![4, 4, 1024]);
    }

    #[test]
    fn decode_unpack_order() {
        let out = DecodeOut::from_vec(vec![
            t(vec![4, 256]),
            t(vec![8, 4, 2, 24]),
            t(vec![8, 4, 2, 24]),
        ]);
        assert_eq!(out.logits.shape, vec![4, 256]);
        assert_eq!(out.k_new.shape, vec![8, 4, 2, 24]);
    }

    #[test]
    #[should_panic]
    fn wrong_arity_panics() {
        DecodeOut::from_vec(vec![t(vec![1])]);
    }
}
