//! Byte-level tokenizer + the synthetic-corpus wire format.
//!
//! Mirrors `python/compile/data.py` exactly (the model was trained on this
//! format). Control bytes 0x01-0x06 are task markers; everything else is a
//! literal byte.

pub const KEY_START: u8 = 1;
pub const KV_SEP: u8 = 2;
pub const END: u8 = 3;
pub const QUERY: u8 = 4;
pub const MARK: u8 = 5;
pub const DOC_SEP: u8 = 6;

#[derive(Debug, Clone, Default)]
pub struct Tokenizer;

impl Tokenizer {
    pub fn encode(&self, text: &[u8]) -> Vec<i32> {
        text.iter().map(|&b| b as i32).collect()
    }

    pub fn decode(&self, ids: &[i32]) -> Vec<u8> {
        ids.iter().map(|&t| (t.clamp(0, 255)) as u8).collect()
    }

    /// Decode generated ids up to (exclusive of) the END marker, for
    /// answer scoring.
    pub fn decode_answer(&self, ids: &[i32]) -> Vec<u8> {
        let mut out = Vec::new();
        for &t in ids {
            let b = t.clamp(0, 255) as u8;
            if b == END {
                break;
            }
            out.push(b);
        }
        out
    }

    /// Printable rendering for logs: control bytes as ⟨n⟩.
    pub fn render(&self, bytes: &[u8]) -> String {
        bytes
            .iter()
            .map(|&b| {
                if (0x20..0x7f).contains(&b) {
                    (b as char).to_string()
                } else {
                    format!("⟨{b}⟩")
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let t = Tokenizer;
        let src = b"hello \x01key\x02value\x03";
        let ids = t.encode(src);
        assert_eq!(t.decode(&ids), src.to_vec());
    }

    #[test]
    fn answer_stops_at_end() {
        let t = Tokenizer;
        let ids = t.encode(b"abc\x03def");
        assert_eq!(t.decode_answer(&ids), b"abc".to_vec());
    }

    #[test]
    fn render_marks_control_bytes() {
        let t = Tokenizer;
        assert_eq!(t.render(b"a\x01b"), "a⟨1⟩b");
    }
}
