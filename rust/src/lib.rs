//! FastKV — reproduction of "FastKV: Decoupling of Context Reduction and
//! KV Cache Compression for Prefill-Decoding Acceleration" as a
//! three-layer Rust + JAX + Pallas serving stack.
//!
//! Layers:
//!  * L1 (Pallas, build-time python): fused attention + saliency kernel —
//!    `python/compile/kernels/`.
//!  * L2 (JAX, build-time python): GQA decoder AOT-lowered to HLO text —
//!    `python/compile/model.py` + `aot.py`.
//!  * L3 (this crate): PJRT runtime, compression policies (FastKV + 5
//!    baselines), KV-cache manager, continuous-batching server, eval &
//!    bench harnesses.
//!
//! # KV-cache backends
//!
//! Decode-stage KV lives behind the [`coordinator::paging::KvStore`]
//! trait. The default backend is [`coordinator::paging::PagedArena`], a
//! vLLM-style paged cache: a global pool of fixed-size token blocks with a
//! free-list allocator, ref-counted blocks with copy-on-write append, and
//! a hash-based prefix cache so requests sharing a compressed-KV prefix
//! reuse physical blocks. The seed's flat
//! [`coordinator::kvcache::BatchArena`] remains available as the
//! comparison backend. The serving stack layers memory-aware admission
//! (admit only when the pool covers the request's post-compression KV
//! budget), block-granular compaction driven by the policies' per-layer
//! retention, and preemption with **swap-to-host resume** on top of this
//! substrate: a preempted lane's FastKV-selected blocks are serialized
//! to a byte-budgeted host arena ([`coordinator::paging::swap`]) and
//! restored on resume — no re-prefill, no policy re-run — falling back
//! to recompute-resume only when the swap budget refuses the lane or
//! drops its entry. See `rust/src/coordinator/paging/README.md` for the
//! design.
//!
//! # Block-table-native decode
//!
//! Decode is block-table-native by default: both decode loops (the
//! single-request engine and the batched server) drive
//! [`coordinator::decode::DecodeBatch`], which hands the
//! `decode_paged_{B}x{C}` artifacts the block slab (device-pinned by
//! version) plus table indices through
//! [`coordinator::paging::DecodeView`] — O(referenced blocks) planning
//! work per token instead of the old O(pool) densify (`KvStore::stage`);
//! the per-step slab re-upload itself remains until PJRT buffer donation
//! lands (see the paging README for the exact accounting). The
//! dense staged bridge survives behind
//! [`coordinator::paging::PagingConfig::dense_staging`] and as the
//! automatic fallback for manifests that predate the paged artifacts.
//!
//! # Multi-tenant serving
//!
//! Every request is served under a [`TenantId`]
//! (`ServerHandle::submit_for`; plain `submit` uses the single-tenant
//! default), and [`PagingConfig::tenant_quotas`] installs per-tenant
//! [`TenantQuota`]s: a reserved block floor other tenants can never
//! consume, a burstable ceiling over the shared pool, and a per-tenant
//! swap byte cap. Blocks are charged to the tenant that first touched
//! them (prefix sharers ride free), admission gates on the *tenant's*
//! remaining quota with fair queue scanning (no head-of-line starvation
//! behind a quota-blocked heavy tenant), and preemption prefers lanes of
//! tenants bursting past their floor. Per-tenant gauges
//! (`tenant_{id}_blocks_held`, swap bytes, preemptions, rejects) are
//! published alongside the pool gauges — see `docs/metrics.md`.
//!
//! # Observability
//!
//! The serving stack traces every request lifecycle into a bounded ring
//! of typed events ([`obs::TraceRecorder`], embedded in
//! [`metrics::Metrics`]) and times each decode phase (input prep, shard
//! upload, exec, host-side combine) into log-bucketed histograms.
//! [`obs::export`] renders the registry as Prometheus text or a JSON
//! snapshot and the ring as Chrome trace-event JSON; anomalies (reject,
//! swap refusal, recompute resume, quota denial) file flight-recorder
//! incidents carrying the request's last events. Tracing is off by
//! default and the decode scratch path stays allocation-free either
//! way — see `docs/observability.md`.
//!
//! Quick start (after `make artifacts`): see `examples/quickstart.rs`;
//! `examples/paging_demo.rs` exercises prefix reuse and preemption without
//! artifacts.

// The entire first-party stack is safe Rust; the only unsafe in the tree
// lives in the vendored PJRT stub (its own crate, exempt). Backed up by
// the package-level `[lints]` table in Cargo.toml, which extends the ban
// to bins/tests/benches/examples.
#![forbid(unsafe_code)]

pub mod analysis;
pub mod coordinator;
pub mod eval;
pub mod manifest;
pub mod metrics;
pub mod obs;
pub mod runtime;
pub mod tensor;
pub mod tokenizer;
pub mod util;
pub mod workload;

pub use coordinator::decode::{DecodeBatch, DecodePath, DecodeScratch};
pub use coordinator::engine::{generate, GenResult, GenStats};
pub use coordinator::paging::{
    AppendResult, DecodeView, KvCodec, KvStore, PagedArena, PagingConfig,
    PoolStats, ShardSpec, ShardView, SwapHandle, SwapIn, SwapStats, TenantId,
    TenantQuota, TenantStats,
};
pub use coordinator::policies::{
    make_policy, Policy, PolicyCfg, ALL_POLICIES,
};
pub use manifest::Manifest;
pub use obs::{ObsConfig, TraceRecorder};
pub use runtime::Runtime;
