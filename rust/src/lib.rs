//! FastKV — reproduction of "FastKV: Decoupling of Context Reduction and
//! KV Cache Compression for Prefill-Decoding Acceleration" as a
//! three-layer Rust + JAX + Pallas serving stack.
//!
//! Layers:
//!  * L1 (Pallas, build-time python): fused attention + saliency kernel —
//!    `python/compile/kernels/`.
//!  * L2 (JAX, build-time python): GQA decoder AOT-lowered to HLO text —
//!    `python/compile/model.py` + `aot.py`.
//!  * L3 (this crate): PJRT runtime, compression policies (FastKV + 5
//!    baselines), KV-cache manager, continuous-batching server, eval &
//!    bench harnesses.
//!
//! Quick start (after `make artifacts`): see `examples/quickstart.rs`.

pub mod analysis;
pub mod coordinator;
pub mod eval;
pub mod manifest;
pub mod metrics;
pub mod runtime;
pub mod tensor;
pub mod tokenizer;
pub mod util;
pub mod workload;

pub use coordinator::engine::{generate, GenResult, GenStats};
pub use coordinator::policies::{
    make_policy, Policy, PolicyCfg, ALL_POLICIES,
};
pub use manifest::Manifest;
pub use runtime::Runtime;
