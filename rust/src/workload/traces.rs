//! Request-arrival traces for the serving benchmarks: Poisson open-loop
//! and bursty (ON/OFF) arrival processes over the task generators —
//! exercises the scheduler/batcher under realistic load shapes.

use crate::util::rng::Rng;
use crate::workload::{kv_recall, Sample};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArrivalKind {
    /// Poisson arrivals at `rate` req/s.
    Poisson,
    /// Bursts: ON period with Poisson(rate), OFF period idle.
    Bursty,
}

#[derive(Debug, Clone)]
pub struct TraceEvent {
    /// Seconds from trace start.
    pub at: f64,
    pub sample: Sample,
    pub max_new: usize,
}

/// Generate a trace of `n` requests with prompt lengths drawn from
/// `lens` (uniform) and the given arrival process.
pub fn generate(
    seed: u64,
    n: usize,
    rate: f64,
    lens: &[usize],
    max_new: usize,
    kind: ArrivalKind,
) -> Vec<TraceEvent> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let dt = match kind {
            ArrivalKind::Poisson => exp_sample(&mut rng, rate),
            ArrivalKind::Bursty => {
                // bursts of ~5 at 4x rate, then a gap
                if i % 5 == 0 && i > 0 {
                    exp_sample(&mut rng, rate / 4.0)
                } else {
                    exp_sample(&mut rng, rate * 4.0)
                }
            }
        };
        t += dt;
        let len = *rng.choice(lens);
        let sample = kv_recall(&mut rng, len, None, 1);
        out.push(TraceEvent { at: t, sample, max_new });
    }
    out
}

fn exp_sample(rng: &mut Rng, rate: f64) -> f64 {
    let u = rng.f64().max(1e-12);
    -u.ln() / rate.max(1e-9)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_sorted_and_sized() {
        let tr = generate(1, 50, 10.0, &[128, 256], 8, ArrivalKind::Poisson);
        assert_eq!(tr.len(), 50);
        assert!(tr.windows(2).all(|w| w[0].at <= w[1].at));
        assert!(tr.iter().all(|e| e.sample.prompt.len() == 128
            || e.sample.prompt.len() == 256));
    }

    #[test]
    fn poisson_rate_roughly_matches() {
        let tr =
            generate(2, 400, 20.0, &[128], 8, ArrivalKind::Poisson);
        let span = tr.last().unwrap().at;
        let rate = 400.0 / span;
        assert!((10.0..40.0).contains(&rate), "rate {rate}");
    }

    #[test]
    fn bursty_has_higher_variance_of_gaps() {
        let p = generate(3, 200, 10.0, &[128], 8, ArrivalKind::Poisson);
        let b = generate(3, 200, 10.0, &[128], 8, ArrivalKind::Bursty);
        let gaps = |tr: &[TraceEvent]| {
            tr.windows(2).map(|w| w[1].at - w[0].at).collect::<Vec<_>>()
        };
        let (_, sp) = crate::util::mean_std(&gaps(&p));
        let (_, sb) = crate::util::mean_std(&gaps(&b));
        assert!(sb > sp, "bursty std {sb} <= poisson std {sp}");
    }
}
