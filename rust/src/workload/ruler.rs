//! RULER-analog suite (Table 3): retrieval (NIAH variants), aggregation
//! (common/frequent words), and multi-hop tracing (variable chains),
//! parameterized by context length.

use super::{
    assemble, filler, kv_recall, mark, pair, place, query_for, query_hop2,
    word, Sample,
};
use crate::tokenizer::{MARK, QUERY};
use crate::util::rng::Rng;

pub const TASKS: &[&str] = &[
    "niah_single",
    "niah_multikey",
    "niah_multiquery",
    "cwe",
    "fwe",
    "vt_chain2",
];

pub fn sample(rng: &mut Rng, task: &str, len: usize) -> Sample {
    match task {
        "niah_single" => {
            let mut s = kv_recall(rng, len, None, 0);
            s.task = "niah_single";
            s
        }
        "niah_multikey" => {
            let mut s = kv_recall(rng, len, None, 4);
            s.task = "niah_multikey";
            s
        }
        // multi-query approximated by querying one of several needles
        // placed adversarially deep
        "niah_multiquery" => {
            let mut s = kv_recall(rng, len, Some(0.1), 3);
            s.task = "niah_multiquery";
            s
        }
        "cwe" => cwe(rng, len),
        "fwe" => fwe(rng, len),
        "vt_chain2" => vt_chain2(rng, len),
        other => panic!("unknown ruler task {other}"),
    }
}

/// Common-words extraction: emit the marked words in order (trained as
/// `marked_copy`).
pub fn cwe(rng: &mut Rng, len: usize) -> Sample {
    let words: Vec<Vec<u8>> = (0..3).map(|_| word(rng, 3, 6)).collect();
    let inserts: Vec<Vec<u8>> = words.iter().map(|w| mark(w)).collect();
    let body = filler(rng, len.saturating_sub(64));
    let ctx = place(rng, &body, &inserts, None);
    let mut answer = Vec::new();
    for (i, w) in words.iter().enumerate() {
        if i > 0 {
            answer.push(b' ');
        }
        answer.extend_from_slice(w);
    }
    Sample {
        prompt: assemble(rng, ctx, &[QUERY, MARK], len),
        answer,
        task: "cwe",
    }
}

/// Frequent-words estimation analog: count marks (trained `count_marks`).
pub fn fwe(rng: &mut Rng, len: usize) -> Sample {
    let n = rng.range(1, 9);
    let inserts: Vec<Vec<u8>> =
        (0..n).map(|_| mark(&word(rng, 3, 6))).collect();
    let body = filler(rng, len.saturating_sub(72));
    let ctx = place(rng, &body, &inserts, None);
    Sample {
        prompt: assemble(rng, ctx, &[QUERY, QUERY, MARK], len),
        answer: vec![b'0' + n as u8],
        task: "fwe",
    }
}

/// Variable tracking: x1 = x2, x2 = value; query x1 (2-hop chain, trained
/// as `hop2`).
pub fn vt_chain2(rng: &mut Rng, len: usize) -> Sample {
    let x1 = word(rng, 3, 6);
    let x2 = word(rng, 3, 6);
    let v = word(rng, 3, 6);
    let mut inserts = vec![pair(&x1, &x2), pair(&x2, &v)];
    if rng.chance(0.5) {
        inserts.reverse();
    }
    let body = filler(rng, len.saturating_sub(64));
    let ctx = place(rng, &body, &inserts, None);
    Sample {
        prompt: assemble(rng, ctx, &query_hop2(&x1), len),
        answer: v,
        task: "vt_chain2",
    }
}

/// A NIAH single-needle sample with an extra distractor key that shares a
/// prefix with the queried key — adversarial retrieval.
pub fn niah_hard(rng: &mut Rng, len: usize) -> Sample {
    let key = word(rng, 4, 6);
    let value = word(rng, 3, 6);
    let mut decoy_key = key.clone();
    let last = decoy_key.len() - 1;
    decoy_key[last] = if decoy_key[last] == b'z' {
        b'a'
    } else {
        decoy_key[last] + 1
    };
    let inserts = vec![pair(&key, &value), pair(&decoy_key, &word(rng, 3, 6))];
    let body = filler(rng, len.saturating_sub(64));
    let ctx = place(rng, &body, &inserts, None);
    Sample {
        prompt: assemble(rng, ctx, &query_for(&key), len),
        answer: value,
        task: "niah_hard",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tasks_generate_at_all_lengths() {
        let mut rng = Rng::new(3);
        for t in TASKS {
            for len in [128usize, 256, 512] {
                let s = sample(&mut rng, t, len);
                assert_eq!(s.prompt.len(), len, "{t}@{len}");
                assert!(!s.answer.is_empty());
            }
        }
    }

    #[test]
    fn vt_chain_has_both_links() {
        for seed in 0..10 {
            let mut rng = Rng::new(seed);
            let s = vt_chain2(&mut rng, 256);
            let key_starts = s
                .prompt
                .iter()
                .filter(|&&b| b == crate::tokenizer::KEY_START)
                .count();
            assert!(key_starts >= 3, "two pairs + query");
        }
    }

    #[test]
    fn niah_hard_decoy_differs() {
        let mut rng = Rng::new(9);
        let s = niah_hard(&mut rng, 256);
        assert!(!s.answer.is_empty());
    }
}
