//! Needle-in-a-Haystack grid (Table 4 / Fig. 8): one needle at a
//! controlled depth × context length; score = retrieval accuracy averaged
//! over the grid.

use super::{kv_recall, Sample};
use crate::util::rng::Rng;

/// The evaluation grid: depths × lengths (paper: 10 depths × 16K..128K;
/// here scaled to the trained context window).
pub fn grid(lengths: &[usize], depths: usize) -> Vec<(usize, f64)> {
    let mut cells = Vec::new();
    for &len in lengths {
        for d in 0..depths {
            let depth = if depths == 1 {
                0.5
            } else {
                d as f64 / (depths - 1) as f64
            };
            cells.push((len, depth));
        }
    }
    cells
}

pub fn sample(rng: &mut Rng, len: usize, depth: f64) -> Sample {
    let mut s = kv_recall(rng, len, Some(depth), 0);
    s.task = "niah";
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_covers_all_cells() {
        let g = grid(&[128, 256], 5);
        assert_eq!(g.len(), 10);
        assert!(g.iter().any(|&(l, d)| l == 128 && d == 0.0));
        assert!(g.iter().any(|&(l, d)| l == 256 && d == 1.0));
    }

    #[test]
    fn samples_generate() {
        let mut rng = Rng::new(1);
        for (len, depth) in grid(&[128, 256], 3) {
            let s = sample(&mut rng, len, depth);
            assert_eq!(s.prompt.len(), len);
        }
    }
}
