//! LongBench-analog suite: 6 categories × subtasks (Table 2 / 6 / 7 of the
//! paper). Each subtask is a synthetic task family the substrate model was
//! trained on; see DESIGN.md for the category mapping.

use super::{
    assemble, filler, kv_recall, mark, pair, place, query_for, query_hop2,
    word, Sample,
};
use crate::tokenizer::{DOC_SEP, KV_SEP, KEY_START, MARK, QUERY};
use crate::util::rng::Rng;

pub const CATEGORIES: &[(&str, &[&str])] = &[
    ("single_doc_qa", &["narrative_kv", "field_kv"]),
    ("multi_doc_qa", &["hotpot_hop2", "multikey"]),
    ("summarization", &["marked_copy"]),
    ("few_shot", &["echo_upper"]),
    ("synthetic", &["passage_count", "passage_retrieval"]),
    ("code", &["fn_return"]),
];

/// Generate one sample of the named subtask at prompt length `len`.
pub fn sample(rng: &mut Rng, subtask: &str, len: usize) -> Sample {
    match subtask {
        "narrative_kv" => {
            let mut s = kv_recall(rng, len, None, 0);
            s.task = "narrative_kv";
            s
        }
        "field_kv" => {
            let mut s = kv_recall(rng, len, None, 1);
            s.task = "field_kv";
            s
        }
        "multikey" => {
            let mut s = kv_recall(rng, len, None, 3);
            s.task = "multikey";
            s
        }
        "hotpot_hop2" => hop2(rng, len),
        "marked_copy" => marked_copy(rng, len),
        "echo_upper" => echo_upper(rng, len),
        "passage_count" => passage_count(rng, len),
        "passage_retrieval" => {
            let depth = rng.f64();
            let mut s = kv_recall(rng, len, Some(depth), 2);
            s.task = "passage_retrieval";
            s
        }
        "fn_return" => fn_return(rng, len),
        other => panic!("unknown subtask {other}"),
    }
}

pub fn hop2(rng: &mut Rng, len: usize) -> Sample {
    let k1 = word(rng, 3, 6);
    let k2 = word(rng, 3, 6);
    let v = word(rng, 3, 6);
    let mut docs = vec![pair(&k1, &k2), pair(&k2, &v)];
    if rng.chance(0.5) {
        docs.reverse();
    }
    // doc separators around the hops: multi-document flavor
    let mut inserts: Vec<Vec<u8>> = Vec::new();
    for d in docs {
        let mut block = vec![DOC_SEP];
        block.extend(d);
        block.push(DOC_SEP);
        inserts.push(block);
    }
    let body = filler(rng, len.saturating_sub(96));
    let ctx = place(rng, &body, &inserts, None);
    Sample {
        prompt: assemble(rng, ctx, &query_hop2(&k1), len),
        answer: v,
        task: "hotpot_hop2",
    }
}

pub fn marked_copy(rng: &mut Rng, len: usize) -> Sample {
    let words: Vec<Vec<u8>> = (0..3).map(|_| word(rng, 3, 6)).collect();
    let inserts: Vec<Vec<u8>> = words.iter().map(|w| mark(w)).collect();
    let body = filler(rng, len.saturating_sub(64));
    let ctx = place(rng, &body, &inserts, None);
    let mut answer = Vec::new();
    for (i, w) in words.iter().enumerate() {
        if i > 0 {
            answer.push(b' ');
        }
        answer.extend_from_slice(w);
    }
    Sample {
        prompt: assemble(rng, ctx, &[QUERY, MARK], len),
        answer,
        task: "marked_copy",
    }
}

pub fn echo_upper(rng: &mut Rng, len: usize) -> Sample {
    let demo_words: Vec<Vec<u8>> = (0..3).map(|_| word(rng, 3, 6)).collect();
    let qword = word(rng, 3, 6);
    let inserts: Vec<Vec<u8>> = demo_words
        .iter()
        .map(|w| {
            let upper: Vec<u8> = w.iter().map(|b| b - 32).collect();
            pair(w, &upper)
        })
        .collect();
    let body = filler(rng, len.saturating_sub(96));
    let ctx = place(rng, &body, &inserts, None);
    let answer: Vec<u8> = qword.iter().map(|b| b - 32).collect();
    Sample {
        prompt: assemble(rng, ctx, &query_for(&qword), len),
        answer,
        task: "echo_upper",
    }
}

pub fn passage_count(rng: &mut Rng, len: usize) -> Sample {
    let n = rng.range(1, 9);
    let inserts: Vec<Vec<u8>> =
        (0..n).map(|_| mark(&word(rng, 3, 6))).collect();
    let body = filler(rng, len.saturating_sub(72));
    let ctx = place(rng, &body, &inserts, None);
    Sample {
        prompt: assemble(rng, ctx, &[QUERY, QUERY, MARK], len),
        answer: vec![b'0' + n as u8],
        task: "passage_count",
    }
}

/// Code-completion analog: `def NAME ... return VALUE`, query `NAME`.
/// Uses the same KV wire format under a code-looking surface so the
/// trained retrieval circuit transfers.
pub fn fn_return(rng: &mut Rng, len: usize) -> Sample {
    let name = word(rng, 4, 7);
    let value = word(rng, 3, 6);
    // surface text around the marker pair
    let mut block = b"def ".to_vec();
    block.extend(pair(&name, &value));
    let body = filler(rng, len.saturating_sub(72));
    let n_decoys = rng.range(1, 3);
    let mut inserts = vec![block];
    for _ in 0..n_decoys {
        let mut d = b"def ".to_vec();
        d.extend(pair(&word(rng, 4, 7), &word(rng, 3, 6)));
        inserts.push(d);
    }
    rng.shuffle(&mut inserts);
    let ctx = place(rng, &body, &inserts, None);
    Sample {
        prompt: assemble(rng, ctx, &query_for(&name), len),
        answer: value,
        task: "fn_return",
    }
}

/// Sanity helper used by tests: the queried key of a prompt.
pub fn queried_key(prompt: &[u8]) -> Option<Vec<u8>> {
    let q = prompt
        .windows(2)
        .rposition(|w| w == [QUERY, KEY_START])?;
    let rest = &prompt[q + 2..];
    let end = rest.iter().position(|&b| b == KV_SEP)?;
    Some(rest[..end].to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_subtasks_generate() {
        let mut rng = Rng::new(11);
        for (_, subs) in CATEGORIES {
            for s in *subs {
                let smp = sample(&mut rng, s, 256);
                assert_eq!(smp.prompt.len(), 256, "{s}");
                assert!(!smp.answer.is_empty(), "{s}");
            }
        }
    }

    #[test]
    fn hop2_answer_reachable() {
        for seed in 0..10 {
            let mut rng = Rng::new(seed);
            let s = hop2(&mut rng, 384);
            // k1 -> k2 and k2 -> answer must both be present
            let key1 = {
                let q = s
                    .prompt
                    .windows(3)
                    .rposition(|w| w[0] == QUERY && w[1] == QUERY)
                    .unwrap();
                let rest = &s.prompt[q + 3..];
                let end =
                    rest.iter().position(|&b| b == KV_SEP).unwrap();
                rest[..end].to_vec()
            };
            let mut n1 = vec![KEY_START];
            n1.extend_from_slice(&key1);
            n1.push(KV_SEP);
            assert!(s.prompt.windows(n1.len()).any(|w| w == &n1[..]));
        }
    }

    #[test]
    fn echo_upper_answer_is_uppercase_of_query() {
        let mut rng = Rng::new(5);
        let s = echo_upper(&mut rng, 256);
        let key = queried_key(&s.prompt).unwrap();
        let upper: Vec<u8> = key.iter().map(|b| b - 32).collect();
        assert_eq!(s.answer, upper);
    }

    #[test]
    fn passage_count_matches_marks() {
        for seed in 0..10 {
            let mut rng = Rng::new(seed);
            let s = passage_count(&mut rng, 256);
            let qpos = s
                .prompt
                .windows(3)
                .rposition(|w| w == [QUERY, QUERY, MARK])
                .unwrap();
            let marks = s.prompt[..qpos]
                .iter()
                .filter(|&&b| b == MARK)
                .count();
            assert_eq!(s.answer, vec![b'0' + marks as u8]);
        }
    }

    #[test]
    fn category_table_is_consistent() {
        let mut seen = std::collections::BTreeSet::new();
        for (cat, subs) in CATEGORIES {
            assert!(!subs.is_empty(), "{cat}");
            for s in *subs {
                assert!(seen.insert(*s), "duplicate subtask {s}");
            }
        }
    }
}
