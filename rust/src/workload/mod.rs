//! Synthetic benchmark workloads — Rust mirrors of `python/compile/data.py`
//! (same byte wire format the model was trained on), organized into the
//! paper's three evaluation suites:
//!
//!  * `longbench` — 6-category analog of LongBench (Table 2 / 6 / 7)
//!  * `ruler`     — retrieval / aggregation / multi-hop analog (Table 3)
//!  * `niah`      — needle-in-a-haystack grid (Table 4 / Fig. 8)

pub mod longbench;
pub mod niah;
pub mod ruler;
pub mod traces;

use crate::tokenizer::{END, KEY_START, KV_SEP, MARK, QUERY};
use crate::util::rng::Rng;

/// One evaluation sample: prompt bytes and the expected answer bytes.
#[derive(Debug, Clone)]
pub struct Sample {
    pub prompt: Vec<u8>,
    pub answer: Vec<u8>,
    /// Task label (subtask name in reports).
    pub task: &'static str,
}

pub fn word(rng: &mut Rng, lo: usize, hi: usize) -> Vec<u8> {
    let n = rng.range(lo, hi);
    (0..n).map(|_| b'a' + rng.below(26) as u8).collect()
}

pub fn filler(rng: &mut Rng, n: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(n);
    while out.len() < n {
        let w = word(rng, 2, 7);
        let take = w.len().min(n - out.len());
        out.extend_from_slice(&w[..take]);
        if out.len() < n {
            out.push(b' ');
        }
    }
    out
}

pub fn pair(key: &[u8], value: &[u8]) -> Vec<u8> {
    let mut p = vec![KEY_START];
    p.extend_from_slice(key);
    p.push(KV_SEP);
    p.extend_from_slice(value);
    p.push(END);
    p
}

pub fn mark(wordb: &[u8]) -> Vec<u8> {
    let mut p = vec![MARK];
    p.extend_from_slice(wordb);
    p.push(END);
    p
}

/// Scatter `inserts` into `body` at sorted random cut points. `depth_hint`
/// in [0,1] biases all inserts toward that relative depth when given
/// (needle-depth control for NIAH).
pub fn place(
    rng: &mut Rng,
    body: &[u8],
    inserts: &[Vec<u8>],
    depth_hint: Option<f64>,
) -> Vec<u8> {
    if inserts.is_empty() {
        return body.to_vec();
    }
    let mut cuts: Vec<usize> = match depth_hint {
        Some(d) => {
            let base = ((body.len() as f64) * d) as usize;
            inserts
                .iter()
                .map(|_| {
                    let jitter = rng.below(body.len() / 8 + 1);
                    (base + jitter).min(body.len())
                })
                .collect()
        }
        None => (0..inserts.len()).map(|_| rng.below(body.len() + 1)).collect(),
    };
    cuts.sort_unstable();
    let mut out = Vec::with_capacity(
        body.len() + inserts.iter().map(Vec::len).sum::<usize>(),
    );
    let mut prev = 0;
    for (c, ins) in cuts.iter().zip(inserts) {
        out.extend_from_slice(&body[prev..*c]);
        out.extend_from_slice(ins);
        prev = *c;
    }
    out.extend_from_slice(&body[prev..]);
    out
}

/// Assemble a prompt of exactly `target_len` bytes: context (truncated or
/// filler-extended) followed by `query`.
pub fn assemble(
    rng: &mut Rng,
    ctx: Vec<u8>,
    query: &[u8],
    target_len: usize,
) -> Vec<u8> {
    let room = target_len.saturating_sub(query.len());
    let mut out = if ctx.len() >= room {
        ctx[..room].to_vec()
    } else {
        let mut c = ctx;
        let pad = filler(rng, room - c.len());
        c.extend_from_slice(&pad);
        c
    };
    out.extend_from_slice(query);
    out
}

pub fn query_for(key: &[u8]) -> Vec<u8> {
    let mut q = vec![QUERY, KEY_START];
    q.extend_from_slice(key);
    q.push(KV_SEP);
    q
}

pub fn query_hop2(key: &[u8]) -> Vec<u8> {
    let mut q = vec![QUERY, QUERY, KEY_START];
    q.extend_from_slice(key);
    q.push(KV_SEP);
    q
}

/// Single-needle KV recall at a controlled depth.
pub fn kv_recall(
    rng: &mut Rng,
    len: usize,
    depth: Option<f64>,
    n_distractors: usize,
) -> Sample {
    let key = word(rng, 3, 6);
    let value = word(rng, 3, 6);
    let mut inserts = vec![pair(&key, &value)];
    for _ in 0..n_distractors {
        let k2 = word(rng, 3, 6);
        let v2 = word(rng, 3, 6);
        inserts.push(pair(&k2, &v2));
    }
    rng.shuffle(&mut inserts);
    let body = filler(rng, len.saturating_sub(64));
    let ctx = place(rng, &body, &inserts, depth);
    let q = query_for(&key);
    Sample {
        prompt: assemble(rng, ctx, &q, len),
        answer: value,
        task: "kv_recall",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_recall_contains_needle_before_query() {
        let mut rng = Rng::new(7);
        for seed in 0..20 {
            let mut rng2 = Rng::new(seed);
            let s = kv_recall(&mut rng2, 256, None, 2);
            assert_eq!(s.prompt.len(), 256);
            // find query
            let qpos = s
                .prompt
                .windows(2)
                .rposition(|w| w == [QUERY, KEY_START])
                .unwrap();
            // needle = KEY_START key KV_SEP value
            let key_end = s.prompt[qpos + 2..]
                .iter()
                .position(|&b| b == KV_SEP)
                .unwrap();
            let key = &s.prompt[qpos + 2..qpos + 2 + key_end];
            let mut needle = vec![KEY_START];
            needle.extend_from_slice(key);
            needle.push(KV_SEP);
            needle.extend_from_slice(&s.answer);
            let hay = &s.prompt[..qpos];
            assert!(
                hay.windows(needle.len()).any(|w| w == &needle[..]),
                "needle must appear in context (seed {seed})"
            );
            let _ = &mut rng;
        }
    }

    #[test]
    fn depth_hint_places_needle_early_vs_late() {
        let mut r1 = Rng::new(3);
        let s_early = kv_recall(&mut r1, 512, Some(0.05), 0);
        let mut r2 = Rng::new(3);
        let s_late = kv_recall(&mut r2, 512, Some(0.9), 0);
        let pos = |s: &Sample| {
            s.prompt.iter().position(|&b| b == KEY_START).unwrap()
        };
        assert!(pos(&s_early) < pos(&s_late));
    }

    #[test]
    fn assemble_exact_length() {
        let mut rng = Rng::new(1);
        let s = assemble(&mut rng, vec![b'x'; 10], b"??", 128);
        assert_eq!(s.len(), 128);
        let s = assemble(&mut rng, vec![b'x'; 500], b"??", 128);
        assert_eq!(s.len(), 128);
        assert!(s.ends_with(b"??"));
    }
}
