//! `fastkv` CLI — leader entrypoint.
//!
//! Subcommands (each regenerates a paper exhibit; see DESIGN.md index):
//!   run      — generate from a prompt with a chosen policy
//!   eval     — longbench | ruler | niah accuracy suites (Tables 2/3/4),
//!              plus `budgets`: the decode-budget accuracy differential
//!   analyze  — fig1a | fig1b | fig3 mechanism analyses
//!   ablate   — tsp-rate | tsp-layer | grid | layer-grid (Fig 5, Tab 9/10)
//!   bench    — latency breakdown across context lengths (Fig 4/9)
//!   overhead — token-importance estimation overhead (Table 8)
//!   info     — manifest / artifact inventory

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use fastkv::analysis;
use fastkv::coordinator::engine::generate;
use fastkv::coordinator::policies::{
    make_policy, Exec, PolicyCfg, ALL_POLICIES,
};
use fastkv::eval::report::{self, method_label, table};
use fastkv::eval::runner::{self, EvalConfig};
use fastkv::manifest::Manifest;
use fastkv::runtime::outputs::{PrefillFullOut, SweepOut};
use fastkv::runtime::{In, Runtime};
use fastkv::tensor::HostTensorI32;
use fastkv::tokenizer::Tokenizer;
use fastkv::util::cli::Args;
use fastkv::util::rng::Rng;
use fastkv::workload;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let res = match cmd {
        "run" => cmd_run(&args),
        "eval" => cmd_eval(&args),
        "analyze" => cmd_analyze(&args),
        "ablate" => cmd_ablate(&args),
        "bench" => cmd_bench(&args),
        "serve" => cmd_serve(&args),
        "overhead" => cmd_overhead(&args),
        "info" => cmd_info(&args),
        "help" | _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = res {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "fastkv — FastKV reproduction CLI\n\
         \n\
         USAGE: fastkv <cmd> [--flags]\n\
         \n\
         cmds:\n\
         \x20 run      --policy fastkv --len 256 [--kv-rate 0.1] [--tsp-rate 0.2]\n\
         \x20 eval     longbench|ruler|niah [--methods a,b] [--samples N] [--len N]\n\
         \x20 eval     budgets [--budgets 16,32,64] [--tolerance 5.0]  (decode-budget accuracy\n\
         \x20          differential: budgeted vs unbudgeted NIAH/RULER -> BENCH_eval_budgets.json)\n\
         \x20 analyze  fig1a|fig1b|fig3 [--len N] [--topk K]\n\
         \x20 ablate   tsp-rate|tsp-layer|grid|layer-grid [--samples N]\n\
         \x20 bench    [--lens 256,512,1024] [--methods ...] [--gen 64]\n\
         \x20 serve    [--policy fastkv] [--requests 16] [--rate 4] [--trace poisson|bursty]\n\
         \x20          [--flat] [--pool-blocks N] [--block-tokens 16] [--no-prefix-cache]\n\
         \x20          [--dense-staging]  (fallback: staged decode bridge instead of block tables)\n\
         \x20          [--swap-mb M]  (host swap budget for preempted lanes; 0 = recompute-resume)\n\
         \x20          [--swap-half]  (legacy alias: pool-wide f16 tier for *swapped lanes only*;\n\
         \x20           the resident slab stays at --precision. Prefer --precision / per-tenant tiers)\n\
         \x20          [--precision f32|f16|int8]  (KV codec for the resident slab AND the default\n\
         \x20           swap tier; int8 = per-row scaled blocks, ~4x lane capacity)\n\
         \x20          [--tenant-precision T:f32|f16|int8,...]  (per-tenant precision tier overrides)\n\
         \x20          [--shards S]  (KV-head-shard the slab into S per-shard pinned slabs;\n\
         \x20           S must divide the model's kv_heads; 1 = single-slab path)\n\
         \x20          [--tenants T] [--quota-blocks R]  (T tenants round-robin by request id,\n\
         \x20           each with a reserved floor of R pool blocks; 0 = single-tenant)\n\
         \x20          [--trace-out F.json]  (dump request lifecycles as Chrome trace JSON)\n\
         \x20          [--trace-events N]  (ring capacity; default 65536 when --trace-out set)\n\
         \x20          [--metrics-out F.json]  (JSON metrics snapshot + F.prom Prometheus text)\n\
         \x20          [--metrics-every N]  (re-export every N serve-loop iterations)\n\
         \x20 overhead [--lens 256,512,1024]\n\
         \x20 info\n\
         \n\
         common flags: --artifacts DIR (default ./artifacts), --seed N\n\
         policy flags: [--prefill-budget N]  (cap on FastKV-selected prefill KV rows; 0 = rate-derived)\n\
         \x20             [--decode-budget N]  (per-lane rows of generated KV kept live; 0 = unbudgeted)\n\
         \x20             [--decode-window N]  (sliding tail of recent tokens always retained)\n\
         \x20             [--prefill-chunk N]  (chunked prefill: stage-1 chunk size in tokens;\n\
         \x20              0 = monolithic; clamped to the manifest's chunk bucket capacity)\n\
         \x20             [--prefill-decode-ratio R]  (decode rounds interleaved between chunks; default 1)"
    );
}

fn open_runtime(args: &Args) -> Result<Runtime> {
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(Manifest::default_dir);
    Runtime::new(&dir)
}

fn policy_cfg(args: &Args, man: &Manifest) -> PolicyCfg {
    let mut cfg = PolicyCfg::default_for(man);
    cfg.kv_rate = args.f64("kv-rate", cfg.kv_rate);
    cfg.tsp_rate = args.f64("tsp-rate", cfg.tsp_rate);
    cfg.sinks = args.usize("sinks", cfg.sinks);
    cfg.filter_layer = args.usize("filter-layer", cfg.filter_layer);
    cfg.use_pallas = args.has("pallas");
    cfg.prefill_budget = args.usize("prefill-budget", cfg.prefill_budget);
    cfg.decode_budget = args.usize("decode-budget", cfg.decode_budget);
    cfg.decode_window = args.usize("decode-window", cfg.decode_window);
    cfg.prefill_chunk = args.usize("prefill-chunk", cfg.prefill_chunk);
    cfg.prefill_decode_ratio =
        args.usize("prefill-decode-ratio", cfg.prefill_decode_ratio);
    cfg
}

// ---------------------------------------------------------------- run

fn cmd_run(args: &Args) -> Result<()> {
    let rt = open_runtime(args)?;
    let man = rt.manifest.clone();
    let cfg = policy_cfg(args, &man);
    let policy = make_policy(args.str_or("policy", "fastkv"))?;
    let len = args.usize("len", 256);
    let max_new = args.usize("gen", 24);
    let tok = Tokenizer;

    let mut rng = Rng::new(args.usize("seed", 0) as u64);
    let sample = workload::kv_recall(&mut rng, len, None, 1);
    let ids = tok.encode(&sample.prompt);
    let out = generate(&rt, &man, policy.as_ref(), &cfg, &ids, max_new)?;
    let pred = tok.decode_answer(&out.tokens);

    println!("policy        : {}", policy.name());
    println!("prompt tokens : {}", len);
    println!("expected      : {}", tok.render(&sample.answer));
    println!("generated     : {}", tok.render(&pred));
    println!(
        "prefill       : {:.1} ms  (compute rate {})",
        out.stats.prefill_secs * 1e3,
        report::pct(
            out.stats.compute_tokens as f64
                / (man.model.n_layers * len) as f64
        )
    );
    println!(
        "decode        : {:.1} ms over {} steps ({:.1} ms/tok)",
        out.stats.decode_secs * 1e3,
        out.stats.decode_steps,
        out.stats.decode_secs * 1e3 / out.stats.decode_steps.max(1) as f64
    );
    println!(
        "kv cache      : {} f32 elems (cap bucket {})",
        out.stats.cache_elems, out.stats.decode_cap
    );
    if out.stats.truncated_by_capacity {
        println!("note          : generation truncated by KV capacity");
    }
    if args.has("stats") {
        let s = rt.stats();
        println!(
            "\nruntime: {} compiles ({:.2}s), {} execs ({:.2}s)",
            s.compiles, s.compile_secs, s.executions, s.execute_secs
        );
        if s.pinned_uploads + s.pinned_hits > 0 {
            println!(
                "pinned slabs: {} uploads, {} reuses, {} bytes resident",
                s.pinned_uploads, s.pinned_hits, s.pinned_bytes
            );
        }
        for (name, (n, secs)) in &s.per_artifact {
            println!(
                "  {name:24} n={n:4}  total {:8.1} ms  mean {:7.2} ms",
                secs * 1e3,
                secs * 1e3 / *n as f64
            );
        }
    }
    Ok(())
}

// ---------------------------------------------------------------- eval

fn methods_from(args: &Args) -> Vec<String> {
    args.str_list("methods", ALL_POLICIES)
}

fn cmd_eval(args: &Args) -> Result<()> {
    let which = args
        .positional
        .get(1)
        .map(String::as_str)
        .unwrap_or("longbench");
    let rt = open_runtime(args)?;
    let man = rt.manifest.clone();
    let ec = EvalConfig {
        policy_cfg: policy_cfg(args, &man),
        samples_per_task: args.usize("samples", 10),
        max_new: args.usize("gen", 16),
        seed: args.usize("seed", 0) as u64,
    };
    let methods = methods_from(args);
    match which {
        "longbench" => {
            let len = args.usize("len", 512);
            let mut rows = Vec::new();
            for m in &methods {
                let cells = runner::run_longbench(&rt, &man, m, &ec, len)?;
                let mut row = vec![method_label(m).to_string()];
                let pr = cells
                    .values()
                    .map(|c| c.prefill_rate())
                    .sum::<f64>()
                    / cells.len() as f64;
                let kv = cells.values().map(|c| c.kv_rate()).sum::<f64>()
                    / cells.len() as f64;
                row.push(report::pct(pr));
                row.push(report::pct(kv));
                let mut avg = 0.0;
                for (cat, _) in workload::longbench::CATEGORIES {
                    let c = &cells[*cat];
                    row.push(report::f1(c.score()));
                    avg += c.score();
                }
                row.push(report::f1(
                    avg / workload::longbench::CATEGORIES.len() as f64,
                ));
                rows.push(row);
                eprintln!("  {m} done");
            }
            let mut headers =
                vec!["Method", "Prefill", "KV"];
            for (cat, _) in workload::longbench::CATEGORIES {
                headers.push(cat);
            }
            headers.push("Avg");
            println!("\n# LongBench-analog (len {len}, {} samples/task, kv_rate {})\n",
                     ec.samples_per_task, ec.policy_cfg.kv_rate);
            println!("{}", table(&headers, &rows));
        }
        "ruler" => {
            let lens = args.usize_list("lens", &[128, 256, 512]);
            let mut rows = Vec::new();
            for m in &methods {
                let cells = runner::run_ruler(&rt, &man, m, &ec, &lens)?;
                let mut row = vec![method_label(m).to_string()];
                let mut avg = 0.0;
                for l in &lens {
                    let c = &cells[l];
                    row.push(report::f1(c.score()));
                    avg += c.score();
                }
                row.push(report::f1(avg / lens.len() as f64));
                rows.push(row);
                eprintln!("  {m} done");
            }
            let mut headers: Vec<String> = vec!["Method".into()];
            headers.extend(lens.iter().map(|l| l.to_string()));
            headers.push("Avg".into());
            let h: Vec<&str> = headers.iter().map(String::as_str).collect();
            println!("\n# RULER-analog (kv_rate {})\n", ec.policy_cfg.kv_rate);
            println!("{}", table(&h, &rows));
        }
        "niah" => {
            let lens = args.usize_list("lens", &[128, 256, 512]);
            let depths = args.usize("depths", 5);
            let mut rows = Vec::new();
            for m in &methods {
                let (total, grid) =
                    runner::run_niah(&rt, &man, m, &ec, &lens, depths)?;
                rows.push(vec![
                    method_label(m).to_string(),
                    report::f1(total.score()),
                ]);
                if args.has("grid") {
                    println!("\n## {m} grid (len, depth, score)");
                    for (l, d, s) in grid {
                        println!("{l:6} {d:4.2} {s:6.1}");
                    }
                }
                eprintln!("  {m} done");
            }
            println!("\n# Needle-in-a-Haystack (kv_rate {})\n",
                     ec.policy_cfg.kv_rate);
            println!("{}", table(&["Method", "Score"], &rows));
        }
        "budgets" => {
            // Decode-budget accuracy differential (SCOPE-style): one
            // policy, NIAH + RULER, budgeted vs unbudgeted at a few
            // decode budgets, deltas bounded by --tolerance. Writes the
            // sweep as BENCH_eval_budgets.json next to the other bench
            // artifacts.
            let lens = args.usize_list("lens", &[128, 256]);
            let depths = args.usize("depths", 3);
            let budgets = args.usize_list("budgets", &[16, 32, 64]);
            let tol = args.f64("tolerance", 5.0);
            let method = args.str_list("methods", &["fastkv"]);
            let policy = method.first().map(String::as_str).unwrap_or("fastkv");
            let points = runner::run_budget_sweep(
                &rt, &man, policy, &ec, &budgets, &lens, depths,
            )?;
            let rows: Vec<Vec<String>> = points
                .iter()
                .map(|p| {
                    vec![
                        if p.decode_budget == 0 {
                            "unbudgeted".to_string()
                        } else {
                            p.decode_budget.to_string()
                        },
                        report::f1(p.niah),
                        report::f1(p.ruler),
                        format!("{:+.1}", p.niah_delta),
                        format!("{:+.1}", p.ruler_delta),
                    ]
                })
                .collect();
            println!(
                "\n# Decode-budget accuracy differential ({policy}, window {}, {} samples/task)\n",
                ec.policy_cfg.decode_window, ec.samples_per_task
            );
            println!(
                "{}",
                table(
                    &["decode budget", "NIAH", "RULER", "dNIAH", "dRULER"],
                    &rows
                )
            );
            let json = format!(
                "{{\n  \"policy\": \"{policy}\",\n  \
                 \"decode_window\": {},\n  \"tolerance\": {tol},\n  \
                 \"points\": [\n{}\n  ]\n}}\n",
                ec.policy_cfg.decode_window,
                points
                    .iter()
                    .map(|p| format!(
                        "    {{\"decode_budget\": {}, \"niah\": {:.2}, \
                         \"ruler\": {:.2}, \"niah_delta\": {:.2}, \
                         \"ruler_delta\": {:.2}}}",
                        p.decode_budget,
                        p.niah,
                        p.ruler,
                        p.niah_delta,
                        p.ruler_delta
                    ))
                    .collect::<Vec<_>>()
                    .join(",\n"),
            );
            std::fs::write("BENCH_eval_budgets.json", &json)
                .context("write BENCH_eval_budgets.json")?;
            println!("wrote BENCH_eval_budgets.json");
            for p in points.iter().skip(1) {
                if p.niah_delta.abs() > tol || p.ruler_delta.abs() > tol {
                    bail!(
                        "decode budget {} drifted beyond tolerance {tol}: \
                         dNIAH {:+.1}, dRULER {:+.1}",
                        p.decode_budget,
                        p.niah_delta,
                        p.ruler_delta
                    );
                }
            }
        }
        other => bail!("unknown eval suite `{other}`"),
    }
    Ok(())
}

// ---------------------------------------------------------------- analyze

fn prefill_full_probe(
    rt: &Runtime,
    man: &Manifest,
    len: usize,
    seed: u64,
) -> Result<(PrefillFullOut, Vec<i32>)> {
    let mut rng = Rng::new(seed);
    let s = workload::kv_recall(&mut rng, len, None, 2);
    let tok = Tokenizer;
    let ids = tok.encode(&s.prompt);
    let b = fastkv::util::bucket_for(len, &man.buckets.prefill_ns)
        .context("len exceeds buckets")?;
    let mut padded = ids.clone();
    padded.resize(b, 0);
    let out = Exec::run(
        rt,
        &format!("prefill_full_{b}"),
        vec![
            HostTensorI32::new(vec![b], padded).into(),
            In::scalar_i32(len as i32),
        ],
    )?;
    Ok((PrefillFullOut::from_vec(out), ids))
}

fn cmd_analyze(args: &Args) -> Result<()> {
    let which = args.positional.get(1).map(String::as_str).unwrap_or("fig1a");
    let rt = open_runtime(args)?;
    let man = rt.manifest.clone();
    let len = args.usize("len", 512);
    let seed = args.usize("seed", 0) as u64;
    match which {
        "fig1a" => {
            // paper: top-512 of 128K (0.4%); here scale top-k to ~12.5%
            let topk = args.usize("topk", len / 8);
            let reps = args.usize("reps", 4);
            let split = man.model.tsp_layer;
            // (early_sum, early_n, late_sum, late_n) per distance
            let mut agg: BTreeMap<usize, (f64, usize, f64, usize)> =
                BTreeMap::new();
            for r in 0..reps {
                let (out, _) = prefill_full_probe(&rt, &man, len, seed + r as u64)?;
                let sets = analysis::critical_sets(&out.acc, len, topk);
                for (d, em, lm) in
                    analysis::overlap_by_distance(&sets, split)
                {
                    let e = agg.entry(d).or_insert((0.0, 0, 0.0, 0));
                    if !em.is_nan() {
                        e.0 += em;
                        e.1 += 1;
                    }
                    if !lm.is_nan() {
                        e.2 += lm;
                        e.3 += 1;
                    }
                }
            }
            println!("\n# Fig 1(a): critical-token overlap vs layer distance (top-{topk}, len {len})\n");
            let rows: Vec<Vec<String>> = agg
                .iter()
                .map(|(d, (es, en, ls, ln))| {
                    let fmt = |sum: f64, n: usize| {
                        if n == 0 {
                            "-".to_string()
                        } else {
                            report::f2(sum / n as f64)
                        }
                    };
                    vec![d.to_string(), fmt(*es, *en), fmt(*ls, *ln)]
                })
                .collect();
            println!(
                "{}",
                table(
                    &[
                        "layer distance",
                        &format!("early layers (<{split})"),
                        &format!("late layers (>={split})"),
                    ],
                    &rows
                )
            );
        }
        "fig1b" => {
            let reps = args.usize("reps", 4);
            let ks = args.usize_list("ks", &[4, 16, 64, len / 8]);
            let mut rows = Vec::new();
            let mut recalls: BTreeMap<usize, Vec<f64>> = BTreeMap::new();
            for r in 0..reps {
                let (out, _) =
                    prefill_full_probe(&rt, &man, len, seed + r as u64)?;
                for &k in &ks {
                    let rec = analysis::topk_recall(&out.acc, len, k);
                    recalls.entry(k).or_default().extend(rec);
                }
            }
            for (k, v) in &recalls {
                let per_layer = v.len() / man.model.n_layers.max(1);
                let _ = per_layer;
                let (m, _) = fastkv::util::mean_std(v);
                rows.push(vec![
                    k.to_string(),
                    format!("{:.1}%", 100.0 * m),
                ]);
            }
            println!("\n# Fig 1(b): top-K attention recall (len {len}, mean over layers x {reps} prompts)\n");
            println!("{}", table(&["K", "recall"], &rows));
        }
        "fig3" => {
            let n = man.buckets.sweep_n;
            let nt = man.buckets.sweep_nt;
            let reps = args.usize("reps", 4);
            let mut rows = Vec::new();
            let mut tsp_d: BTreeMap<usize, Vec<f64>> = BTreeMap::new();
            let mut gem_d: BTreeMap<usize, Vec<f64>> = BTreeMap::new();
            for r in 0..reps {
                let (full, ids) =
                    prefill_full_probe(&rt, &man, n, seed + r as u64)?;
                for t in 1..man.model.n_layers {
                    // TSP at layer t (in-HLO artifact)
                    let mut padded = ids.clone();
                    padded.resize(n, 0);
                    let sw = SweepOut::from_vec(Exec::run(
                        &rt,
                        &format!("sweep_tsp_l{t}_{n}"),
                        vec![
                            HostTensorI32::new(vec![n], padded).into(),
                            In::scalar_i32(n as i32),
                        ],
                    )?);
                    tsp_d.entry(t).or_default().push(
                        analysis::hidden_distance(
                            &full.final_h.data,
                            &sw.final_h.data,
                        ),
                    );
                    // GemFilter-like: select top-nt at layer t, re-prefill
                    let keep = fastkv::coordinator::selection::select_salient(
                        full.win.row(t.saturating_sub(1)),
                        man.model.n_heads,
                        full.win.shape[2],
                        n,
                        nt,
                        man.model.window,
                        man.model.pool_kernel,
                    );
                    let sel: Vec<i32> =
                        keep.iter().map(|&i| ids[i]).collect();
                    let b2 = fastkv::util::bucket_for(
                        sel.len(),
                        &man.buckets.prefill_ns,
                    )
                    .unwrap();
                    let mut p2 = sel.clone();
                    p2.resize(b2, 0);
                    let gf = PrefillFullOut::from_vec(Exec::run(
                        &rt,
                        &format!("prefill_full_{b2}"),
                        vec![
                            HostTensorI32::new(vec![b2], p2).into(),
                            In::scalar_i32(sel.len() as i32),
                        ],
                    )?);
                    gem_d.entry(t).or_default().push(
                        analysis::hidden_distance(
                            &full.final_h.data,
                            &gf.final_h.data,
                        ),
                    );
                }
            }
            for t in 1..man.model.n_layers {
                rows.push(vec![
                    t.to_string(),
                    report::f2(fastkv::util::mean_std(&tsp_d[&t]).0),
                    report::f2(fastkv::util::mean_std(&gem_d[&t]).0),
                ]);
            }
            println!("\n# Fig 3: normalized L2 distance of final hidden state vs full-context (len {n}, keep {nt})\n");
            println!(
                "{}",
                table(&["TSP/filter layer", "TSP", "GemFilter-like"], &rows)
            );
        }
        other => bail!("unknown analysis `{other}`"),
    }
    Ok(())
}

// ---------------------------------------------------------------- ablate

fn cmd_ablate(args: &Args) -> Result<()> {
    let which = args
        .positional
        .get(1)
        .map(String::as_str)
        .unwrap_or("tsp-rate");
    let rt = open_runtime(args)?;
    let man = rt.manifest.clone();
    let base = EvalConfig {
        policy_cfg: policy_cfg(args, &man),
        samples_per_task: args.usize("samples", 6),
        max_new: args.usize("gen", 16),
        seed: args.usize("seed", 0) as u64,
    };
    let len = args.usize("len", 512);

    // Score = mean over the longbench categories (matches Fig 5 y-axis).
    let score_with = |cfg: &PolicyCfg, policy: &str| -> Result<(f64, f64)> {
        let ec = EvalConfig {
            policy_cfg: cfg.clone(),
            samples_per_task: base.samples_per_task,
            max_new: base.max_new,
            seed: base.seed,
        };
        let cells = runner::run_longbench(&rt, &man, policy, &ec, len)?;
        let avg = cells.values().map(|c| c.score()).sum::<f64>()
            / cells.len() as f64;
        let prefill: f64 = cells.values().map(|c| c.prefill_secs).sum();
        let n: usize = cells.values().map(|c| c.n).sum();
        Ok((avg, prefill / n as f64))
    };

    match which {
        "tsp-rate" => {
            let rates = [0.05, 0.1, 0.2, 0.3, 0.5];
            let mut rows = Vec::new();
            for r in rates {
                let mut cfg = base.policy_cfg.clone();
                cfg.tsp_rate = r;
                let (score, pf) = score_with(&cfg, "fastkv")?;
                rows.push(vec![
                    format!("{r}"),
                    report::f1(score),
                    report::ms(pf),
                ]);
                eprintln!("  tsp_rate {r} done");
            }
            println!("\n# Fig 5(a): TSP rate ablation (kv_rate {}, len {len})\n", base.policy_cfg.kv_rate);
            println!(
                "{}",
                table(&["TSP rate", "LongBench avg", "prefill ms"], &rows)
            );
        }
        "tsp-layer" => {
            // Uses the in-HLO sweep artifacts for prefill-latency and the
            // logit-path quality proxy; full generate quality via fastkv
            // needs per-layer stage artifacts, so this ablation reports
            // the Fig 5(b) latency curve + the Fig 3 distance curve.
            bail!("use `analyze fig3` (distance curve) and `ablate layer-grid` (accuracy grid)");
        }
        "grid" => {
            // Table 9: TSP rate x KV retention.
            let tsps = args_f64_list(args, "tsp-rates", &[0.1, 0.2, 0.3]);
            let kvs = args_f64_list(args, "kv-rates", &[0.1, 0.2, 0.3]);
            let mut rows = Vec::new();
            for t in &tsps {
                let mut row = vec![format!("{t}")];
                for k in &kvs {
                    if k > t {
                        row.push("-".into());
                        continue;
                    }
                    let mut cfg = base.policy_cfg.clone();
                    cfg.tsp_rate = *t;
                    cfg.kv_rate = *k;
                    let (score, _) = score_with(&cfg, "fastkv")?;
                    row.push(report::f1(score));
                }
                rows.push(row);
                eprintln!("  tsp {t} done");
            }
            let mut headers = vec!["TSP \\ KV".to_string()];
            headers.extend(kvs.iter().map(|k| k.to_string()));
            let h: Vec<&str> = headers.iter().map(String::as_str).collect();
            println!("\n# Table 9: TSP rate x KV retention (len {len})\n");
            println!("{}", table(&h, &rows));
        }
        "layer-grid" => {
            // Table 10 analog via the sweep artifacts: teacher-forced
            // first-token agreement with full-context across layers/rates
            // is produced by analyze fig3; here we report fastkv accuracy
            // with the compiled TSP layer but varying rates (the compiled
            // stage boundary is fixed at build time).
            let tsps = args_f64_list(
                args,
                "tsp-rates",
                &[0.1, 0.2, 0.3, 0.5],
            );
            let mut rows = Vec::new();
            for t in &tsps {
                let mut cfg = base.policy_cfg.clone();
                cfg.tsp_rate = *t;
                let (score, pf) = score_with(&cfg, "fastkv")?;
                rows.push(vec![
                    format!("{t}"),
                    report::f1(score),
                    report::ms(pf),
                ]);
                eprintln!("  tsp {t} done");
            }
            println!("\n# Table 10 (rate axis at compiled TSP layer {}; layer axis => analyze fig3)\n", man.model.tsp_layer);
            println!(
                "{}",
                table(&["TSP rate", "LongBench avg", "prefill ms"], &rows)
            );
        }
        other => bail!("unknown ablation `{other}`"),
    }
    Ok(())
}

fn args_f64_list(args: &Args, key: &str, default: &[f64]) -> Vec<f64> {
    match args.get(key) {
        None => default.to_vec(),
        Some(v) => v
            .split(',')
            .map(|s| s.trim().parse().expect("bad float list"))
            .collect(),
    }
}

// ---------------------------------------------------------------- bench

fn cmd_bench(args: &Args) -> Result<()> {
    let rt = open_runtime(args)?;
    let man = rt.manifest.clone();
    let cfg = policy_cfg(args, &man);
    let lens = args.usize_list("lens", &[256, 512, 1024]);
    let methods = methods_from(args);
    let gen = args.usize("gen", 32);
    let reps = args.usize("reps", 3);
    let tok = Tokenizer;

    println!("\n# Fig 4/9: end-to-end latency breakdown (gen {gen} tokens, {reps} reps)\n");
    let mut rows = Vec::new();
    for &len in &lens {
        for m in &methods {
            let policy = match make_policy(m) {
                Ok(p) => p,
                Err(_) => continue,
            };
            let mut pf = Vec::new();
            let mut dc = Vec::new();
            let mut steps = 0usize;
            let mut ok = true;
            // untimed warmup: compiles all artifacts this config touches
            {
                let mut rng = Rng::new(999);
                let s = workload::kv_recall(&mut rng, len, None, 1);
                let ids = tok.encode(&s.prompt);
                if let Err(e) =
                    generate(&rt, &man, policy.as_ref(), &cfg, &ids, gen)
                {
                    eprintln!("  {m}@{len}: {e}");
                    ok = false;
                }
            }
            for r in 0..reps {
                if !ok {
                    break;
                }
                let mut rng = Rng::new(r as u64);
                let s = workload::kv_recall(&mut rng, len, None, 1);
                let ids = tok.encode(&s.prompt);
                match generate(&rt, &man, policy.as_ref(), &cfg, &ids, gen)
                {
                    Ok(out) => {
                        pf.push(out.stats.prefill_secs);
                        dc.push(out.stats.decode_secs);
                        steps += out.stats.decode_steps;
                    }
                    Err(e) => {
                        eprintln!("  {m}@{len}: {e}");
                        ok = false;
                        break;
                    }
                }
            }
            if !ok || pf.is_empty() {
                rows.push(vec![
                    len.to_string(),
                    method_label(m).to_string(),
                    "OOM/unsupported".into(),
                    "-".into(),
                    "-".into(),
                ]);
                continue;
            }
            let (pm, _) = fastkv::util::mean_std(&pf);
            let (dm, _) = fastkv::util::mean_std(&dc);
            let per_tok = dc.iter().sum::<f64>() / steps.max(1) as f64;
            rows.push(vec![
                len.to_string(),
                method_label(m).to_string(),
                report::ms(pm),
                report::ms(per_tok),
                report::ms(pm + dm),
            ]);
            eprintln!("  {m}@{len} done");
        }
    }
    println!(
        "{}",
        table(
            &[
                "ctx len",
                "Method",
                "prefill ms",
                "decode ms/tok",
                "total ms",
            ],
            &rows
        )
    );
    Ok(())
}

// ---------------------------------------------------------------- serve

fn cmd_serve(args: &Args) -> Result<()> {
    use fastkv::coordinator::scheduler::AdmitOrder;
    use fastkv::coordinator::server::{Server, ServerConfig};
    use fastkv::workload::traces::{self, ArrivalKind};

    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(Manifest::default_dir);
    let man = Manifest::load(&dir)?;
    let mut policy_cfg = policy_cfg(args, &man);
    policy_cfg.use_pallas = false;
    let len = args.usize("len", 256);
    let n = args.usize("requests", 16);
    let rate = args.f64("rate", 4.0);
    let kind = match args.str_or("trace", "poisson") {
        "bursty" => ArrivalKind::Bursty,
        _ => ArrivalKind::Poisson,
    };
    let order = match args.str_or("order", "fcfs") {
        "shortest" => AdmitOrder::ShortestFirst,
        _ => AdmitOrder::Fcfs,
    };
    // KV backend: paged by default; --flat selects the seed BatchArena.
    // --pool-blocks N under-provisions the pool to exercise memory-aware
    // admission and preemption; --block-tokens sets the block size (it
    // must match the compiled decode_paged artifacts for block-table
    // decode; a mismatch falls back to the staged bridge, as does
    // --dense-staging explicitly).
    let paging = if args.has("flat") {
        None
    } else {
        let mut pc = fastkv::PagingConfig::default();
        let default_bt = if man.buckets.block_tokens > 0 {
            man.buckets.block_tokens
        } else {
            pc.block_tokens
        };
        pc.block_tokens = args.usize("block-tokens", default_bt);
        if let Some(nb) = args.get("pool-blocks") {
            pc.num_blocks = Some(nb.parse().expect("--pool-blocks: not a number"));
        }
        pc.prefix_cache = !args.has("no-prefix-cache");
        pc.dense_staging = args.has("dense-staging");
        // --shards S: split the KV slab head-wise into S per-shard pinned
        // slabs (needs the decode_paged_shard artifacts for the sharded
        // decode path; 1 = today's single-slab path, bit-identical).
        pc.shards = args.usize("shards", 1);
        if let Err(e) =
            fastkv::ShardSpec::new(pc.shards.max(1), man.model.n_kv_heads, man.model.head_dim)
        {
            bail!("--shards: {e}");
        }
        // --swap-mb M: host swap budget for preempted lanes (0 disables
        // swap-to-host; preemption then recompute-resumes).
        pc.swap_bytes = args.usize("swap-mb", pc.swap_bytes >> 20) << 20;
        // --swap-half: legacy alias for a pool-wide f16 tier on *swapped
        // lanes only* (the resident slab stays at --precision). Subsumed
        // by --precision / per-tenant tiers; kept for compatibility.
        pc.swap_half = args.has("swap-half");
        // --precision: KV codec for the resident slab and the default
        // swap tier (int8 = per-row scaled blocks, ~4x lane capacity;
        // lossless restores only at f32).
        if let Some(p) = args.get("precision") {
            pc.precision = fastkv::KvCodec::parse(p)
                .map_err(|e| anyhow::anyhow!("--precision: {e}"))?;
        }
        // --tenants T + --quota-blocks R: every tenant gets a reserved
        // floor of R blocks (burst above it allowed while the pool has
        // slack); requests are assigned tenants round-robin below.
        let tenants = args.usize("tenants", 1);
        let quota = args.usize("quota-blocks", 0);
        if tenants > 1 && quota > 0 {
            pc.tenant_quotas = (0..tenants as u32)
                .map(|t| {
                    (fastkv::TenantId(t), fastkv::TenantQuota::reserved(quota))
                })
                .collect();
        }
        // --tenant-precision T:f16,U:int8,...: per-tenant precision tier
        // overrides (swap-encode tier for that tenant's preempted lanes;
        // untiered tenants inherit the pool default).
        if let Some(spec) = args.get("tenant-precision") {
            for part in spec.split(',').filter(|p| !p.is_empty()) {
                let (t, codec) = part.split_once(':').ok_or_else(|| {
                    anyhow::anyhow!(
                        "--tenant-precision: expected T:f32|f16|int8, got {part:?}"
                    )
                })?;
                let t: u32 = t.parse().map_err(|_| {
                    anyhow::anyhow!("--tenant-precision: bad tenant id {t:?}")
                })?;
                let codec = fastkv::KvCodec::parse(codec)
                    .map_err(|e| anyhow::anyhow!("--tenant-precision: {e}"))?;
                let id = fastkv::TenantId(t);
                let q = pc
                    .tenant_quotas
                    .iter_mut()
                    .find(|(tid, _)| *tid == id);
                match q {
                    Some((_, q)) => q.precision = Some(codec),
                    None => pc.tenant_quotas.push((
                        id,
                        fastkv::TenantQuota::default().with_precision(codec),
                    )),
                }
            }
        }
        Some(pc)
    };
    let tenants = args.usize("tenants", 1).max(1);
    // Observability: --trace-out implies tracing on (--trace-events
    // overrides the ring size); --metrics-out writes the JSON snapshot
    // plus a `.prom` Prometheus sibling, re-exported every
    // --metrics-every loop iterations and on shutdown.
    let trace_out = args.get("trace-out").map(std::path::PathBuf::from);
    let default_events = if trace_out.is_some() { 65536 } else { 0 };
    let obs = fastkv::ObsConfig {
        trace_events: args.usize("trace-events", default_events),
        trace_out,
        metrics_out: args.get("metrics-out").map(std::path::PathBuf::from),
        export_every: args.usize("metrics-every", 0),
    };
    let obs_paths: Vec<std::path::PathBuf> = obs
        .metrics_out
        .iter()
        .flat_map(|p| [p.clone(), p.with_extension("prom")])
        .chain(obs.trace_out.iter().cloned())
        .collect();
    let cfg = ServerConfig {
        artifact_dir: dir,
        policy: args.str_or("policy", "fastkv").to_string(),
        policy_cfg,
        decode_batch: args.usize("batch", 4),
        max_new: args.usize("gen", 16),
        max_prompt: len,
        order,
        paging,
        obs,
    };
    println!(
        "serving trace: {n} reqs, {rate} req/s ({:?}), policy {}, batch {}, kv backend {}",
        kind,
        cfg.policy,
        cfg.decode_batch,
        if cfg.paging.is_some() { "paged" } else { "flat" }
    );
    let server = Server::spawn(cfg)?;
    let handle = server.handle();
    let trace = traces::generate(
        args.usize("seed", 0) as u64,
        n,
        rate,
        &[len],
        args.usize("gen", 16),
        kind,
    );
    let tok = Tokenizer;
    let t0 = std::time::Instant::now();
    let mut rxs = Vec::new();
    for ev in trace.iter() {
        let wait = ev.at - t0.elapsed().as_secs_f64();
        if wait > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(wait));
        }
        let ids = tok.encode(&ev.sample.prompt);
        // Round-robin tenant assignment keyed on the REQUEST ID (tenant 0
        // with --tenants 1): `i % tenants` depended on where the workload
        // loop happened to (re)start its counter, so two runs of the same
        // trace could charge requests to different tenants and the
        // multi-tenant bench numbers would not reproduce across machines.
        // `id % tenants` is stable per request by construction.
        let (_, _tenant, rx) =
            handle.submit_round_robin(ids, ev.max_new, tenants as u32)?;
        rxs.push(rx);
    }
    let mut tokens = 0usize;
    let mut errors = 0usize;
    for rx in rxs {
        let r = rx.recv()?;
        if r.error.is_some() {
            errors += 1;
        }
        tokens += r.tokens.len();
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "\ndone: {n} requests, {errors} errors, {:.1} tok/s out, {:.2}s wall",
        tokens as f64 / wall,
        wall
    );
    // Join the serving thread first so the shutdown export (metrics
    // snapshot, Chrome trace) has flushed before we report.
    drop(server);
    println!("\n{}", handle.metrics.report());
    let flights = fastkv::obs::flight_text(handle.metrics.tracer());
    if !flights.is_empty() {
        println!("flight recorder:\n{flights}");
    }
    for p in &obs_paths {
        if p.exists() {
            println!("wrote {}", p.display());
        }
    }
    Ok(())
}

// ---------------------------------------------------------------- overhead

fn cmd_overhead(args: &Args) -> Result<()> {
    // Table 8: the saliency summaries are fused into the attention kernel,
    // so the "estimation" cost is the coordinator-side selection (head
    // mean + pool + top-k). We time prefill vs selection explicitly.
    let rt = open_runtime(args)?;
    let man = rt.manifest.clone();
    let lens = args.usize_list("lens", &[256, 512, 1024]);
    let reps = args.usize("reps", 5);
    let mut rows = Vec::new();
    for &len in &lens {
        let mut prefill = Vec::new();
        let mut estimate = Vec::new();
        for r in 0..reps {
            let t0 = std::time::Instant::now();
            let (out, _) =
                prefill_full_probe(&rt, &man, len, r as u64)?;
            prefill.push(t0.elapsed().as_secs_f64());
            let t1 = std::time::Instant::now();
            let budget = (0.1 * len as f64).ceil() as usize;
            for l in 0..man.model.n_layers {
                let _ = fastkv::coordinator::selection::select_kv_groupwise(
                    out.win.row(l),
                    man.model.n_heads,
                    out.win.shape[2],
                    len,
                    man.model.n_kv_heads,
                    budget,
                    man.model.window,
                    man.model.pool_kernel,
                );
            }
            estimate.push(t1.elapsed().as_secs_f64());
        }
        let (pm, ps) = fastkv::util::mean_std(&prefill);
        let (em, es) = fastkv::util::mean_std(&estimate);
        rows.push(vec![
            len.to_string(),
            format!("{:.1} ± {:.1}", pm * 1e3, ps * 1e3),
            format!("{:.3} ± {:.3}", em * 1e3, es * 1e3),
            format!("{:.2}%", 100.0 * em / (pm + em)),
        ]);
    }
    println!("\n# Table 8: token-importance estimation overhead\n");
    println!(
        "{}",
        table(
            &["ctx len", "prefill ms", "estimation ms", "overhead"],
            &rows
        )
    );
    Ok(())
}

// ---------------------------------------------------------------- info

fn cmd_info(args: &Args) -> Result<()> {
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(Manifest::default_dir);
    let man = Manifest::load(&dir)?;
    println!("model: {:?}", man.model);
    println!("params: {}", man.n_params);
    println!("kernel: {}", man.kernel);
    println!("buckets: {:?}", man.buckets);
    println!("artifacts ({}):", man.artifacts.len());
    for (name, a) in &man.artifacts {
        println!(
            "  {name:28} kind={:14} n={:5} batch={} cap={}",
            a.kind, a.n, a.batch, a.cap
        );
    }
    Ok(())
}
