//! Serve-level differential oracle for chunked prefill.
//!
//! Chunked prefill (`Policy::begin_chunked` → `ChunkedPrefill::step`* →
//! `finish` → `Request::carry_prefill` → `admit`) must be externally
//! indistinguishable from the blocking monolithic path: identical token
//! streams and identical final KV rows per request, for every chunk
//! size, every decode-interleave ratio, and every park/resume schedule.
//! The sim harness's stand-in model makes that exact: both paths build
//! their outcome from the same pure function of the token sequence, so
//! any divergence here is the serve machinery's (carry, park/resume,
//! deferred admission) — not the model's. The Python side pins the real
//! numerics: `test_model.py::test_chunked_stage1_bit_identical` asserts
//! the chunked stage-1 artifact is bit-identical to the monolithic one.
//!
//! Also pinned here, per the roadmap's continuous-batching contract:
//!
//!  * the chunked path never calls the blocking `Policy::prefill`
//!    (`policy_calls == 0` — admission reuses the carried outcome);
//!  * a park/resume mid-chunking re-runs **zero** chunks and counts
//!    **zero** `prefill_recomputed` (resume is from the completed-chunk
//!    boundary, not recompute);
//!  * total chunk steps equal the `chunk_spans` plan exactly — no chunk
//!    runs twice, none is skipped.

#[path = "common/sim.rs"]
mod sim;

use fastkv::coordinator::paging::PagingConfig;
use fastkv::coordinator::policies::chunk_spans;
use fastkv::metrics::names;
use sim::{
    run_stack_chunked, run_stack_server, sim_meta, sim_server_cfg,
    ChunkPark, StackResult,
};

/// Deterministic xorshift token/length source — no rand dependency.
struct Lcg(u64);

impl Lcg {
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x
    }

    fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + (self.next() as usize) % (hi - lo + 1)
    }
}

fn random_prompts(seed: u64, count: usize) -> Vec<Vec<i32>> {
    let mut rng = Lcg(seed | 1);
    (0..count)
        .map(|_| {
            let len = rng.range(3, 24);
            (0..len).map(|_| rng.range(4, 200) as i32).collect()
        })
        .collect()
}

fn pool() -> PagingConfig {
    PagingConfig {
        block_tokens: 2,
        prefix_cache: false,
        swap_bytes: 0,
        ..Default::default()
    }
}

fn assert_same_outcome(chunked: &StackResult, mono: &StackResult) {
    assert_eq!(
        chunked.streams, mono.streams,
        "chunked token streams diverged from monolithic"
    );
    assert_eq!(
        chunked.final_rows, mono.final_rows,
        "chunked final KV rows diverged from monolithic"
    );
}

fn planned_chunks(prompts: &[Vec<i32>], chunk: usize) -> usize {
    let w = sim_meta().window;
    prompts.iter().map(|p| chunk_spans(p.len(), chunk, w).len()).sum()
}

/// The core oracle: randomized prompt sets, every chunk size from
/// degenerate (1 token) past the longest prompt (one chunk), both
/// interleave ratios. `preempt_at == usize::MAX` keeps the monolithic
/// baseline preemption-free so the two stacks see identical schedules.
#[test]
fn chunked_serve_matches_monolithic_across_chunk_sizes() {
    for seed in [3, 17, 99] {
        let prompts = random_prompts(seed, 4);
        let mono =
            run_stack_server(pool(), &prompts, usize::MAX, sim_server_cfg(32, 6));
        assert_eq!(mono.policy_calls, prompts.len());
        for chunk in [1, 2, 5, 8, 64] {
            for ratio in [1, 3] {
                let mut cfg = sim_server_cfg(32, 6);
                cfg.policy_cfg.prefill_chunk = chunk;
                cfg.policy_cfg.prefill_decode_ratio = ratio;
                let chunked =
                    run_stack_chunked(pool(), &prompts, None, cfg);
                assert_same_outcome(&chunked, &mono);
                // Admission reuses the carried outcome: the blocking
                // prefill never runs on the chunked path.
                assert_eq!(chunked.policy_calls, 0);
                assert_eq!(
                    chunked.chunk_steps,
                    planned_chunks(&prompts, chunk),
                    "chunk plan must run exactly once (chunk={chunk})"
                );
                assert_eq!(
                    chunked.metrics.counter(names::PREFILL_RECOMPUTED),
                    0
                );
            }
        }
    }
}

/// Park/resume at *every* chunk boundary of a multi-chunk admission:
/// the resumed driver continues from the parked boundary (asserted
/// inside the harness), re-runs zero chunks, counts zero recomputes,
/// and the outcome still matches the monolithic baseline even though
/// other lanes kept decoding while the chunking lane was parked.
#[test]
fn park_resume_mid_chunking_recomputes_zero_chunks() {
    let mut prompts = random_prompts(41, 3);
    prompts[0] = (0..20).map(|i| 4 + i as i32).collect(); // 5+ chunks at 4
    let mono =
        run_stack_server(pool(), &prompts, usize::MAX, sim_server_cfg(32, 6));
    let chunk = 4;
    let boundaries = chunk_spans(prompts[0].len(), chunk, sim_meta().window)
        .len();
    assert!(boundaries >= 3, "prompt 0 must span several chunks");
    for park_at in 0..boundaries {
        for decode_rounds in [1, 4] {
            let mut cfg = sim_server_cfg(32, 6);
            cfg.policy_cfg.prefill_chunk = chunk;
            let park = ChunkPark { after_chunks: park_at, decode_rounds };
            let chunked =
                run_stack_chunked(pool(), &prompts, Some(park), cfg);
            assert_same_outcome(&chunked, &mono);
            // Zero chunks re-run: the total step count is still exactly
            // the plan, and nothing was accounted as a recompute.
            assert_eq!(
                chunked.chunk_steps,
                planned_chunks(&prompts, chunk),
                "park at boundary {park_at} re-ran a chunk"
            );
            assert_eq!(
                chunked.metrics.counter(names::PREFILL_RECOMPUTED),
                0,
                "chunk-boundary resume must not count as recompute"
            );
            assert_eq!(chunked.policy_calls, 0);
        }
    }
}

/// Degenerate shapes stay exact: single-token prompts, prompt shorter
/// than the observation window, chunk size larger than every prompt
/// (one-chunk plan), and a ratio of 0 (chunks run back-to-back).
#[test]
fn chunked_serve_edge_shapes() {
    let prompts: Vec<Vec<i32>> =
        vec![vec![7], vec![9, 8], (0..24).map(|i| 30 + i).collect()];
    let mono =
        run_stack_server(pool(), &prompts, usize::MAX, sim_server_cfg(32, 5));
    for (chunk, ratio) in [(1, 0), (64, 1), (3, 0)] {
        let mut cfg = sim_server_cfg(32, 5);
        cfg.policy_cfg.prefill_chunk = chunk;
        cfg.policy_cfg.prefill_decode_ratio = ratio;
        let chunked = run_stack_chunked(pool(), &prompts, None, cfg);
        assert_same_outcome(&chunked, &mono);
        assert_eq!(chunked.chunk_steps, planned_chunks(&prompts, chunk));
    }
}

/// The chunked admission claims pool blocks only at final admission
/// (the carried-prefill path), so a pool sized for the steady state
/// admits a chunking request whose monolithic admission would have had
/// to wait: streams still match, and the chunked run never recomputes.
#[test]
fn chunked_admission_defers_pool_claims_to_finish() {
    let prompts: Vec<Vec<i32>> =
        vec![(0..20).map(|i| 5 + i).collect(), vec![11, 12, 13]];
    let mono =
        run_stack_server(pool(), &prompts, usize::MAX, sim_server_cfg(32, 4));
    let mut cfg = sim_server_cfg(32, 4);
    cfg.policy_cfg.prefill_chunk = 2;
    cfg.policy_cfg.prefill_decode_ratio = 1;
    let chunked = run_stack_chunked(pool(), &prompts, None, cfg);
    assert_same_outcome(&chunked, &mono);
    assert_eq!(chunked.metrics.counter(names::PREFILL_RECOMPUTED), 0);
}
