//! Observability integration tests — no artifacts / no PJRT needed.
//!
//! Drives full request lifecycles through the real server machinery
//! (`admit` / `preempt` / `try_resume` / `finish` / `reject`) with
//! lifecycle tracing enabled, then checks the three pillars the obs
//! subsystem promises:
//!
//!  1. the **lifecycle-ordering invariant** holds for every traced
//!     request (`validate_lifecycle`);
//!  2. the **JSON snapshot round-trips** through the crate's own parser
//!     with exact counter/gauge values;
//!  3. the **Chrome trace** parses and reconstructs the phase spans;
//!
//! plus the ring-buffer wrap contract and the flight-recorder / honest-
//! TTFT behavior on rejection.

use std::collections::HashMap;

use fastkv::coordinator::scheduler::{AdmitOrder, Scheduler};
use fastkv::coordinator::server::{
    admit, finish, preempt, reject, try_resume, Active, AdmitFail,
    Request, Resume,
};
use fastkv::metrics::{names, Metrics};
use fastkv::obs::trace::{
    validate_lifecycle, EventKind, IncidentKind, ResumeMode, NO_LANE,
};
use fastkv::util::json::Value;
use fastkv::{PagedArena, PagingConfig, TenantId, TraceRecorder};

// Serve-lifecycle sim harness shared with `tests/paging.rs`
// (deterministic stand-in model, `NoExec`, `SimPolicy`,
// `sim_decode_round`).
#[path = "common/sim.rs"]
mod sim;
use sim::*;

/// Drive `n` requests through admit → decode → preempt (swap) → resume →
/// finish on a lane-limited scheduler, tracing on. Returns the metrics
/// registry (owning the trace ring) and the request ids.
fn run_traced_stack(n: u64) -> (Metrics, Vec<u64>) {
    let m = sim_meta();
    let man = sim_manifest(64);
    let policy = SimPolicy::new();
    let metrics = Metrics::default();
    metrics.tracer().enable(1024);
    let max_new = 6;
    let cfg = sim_server_cfg(32, max_new);
    let lanes = 2;
    let pcfg = PagingConfig {
        block_tokens: 2,
        prefix_cache: false,
        swap_bytes: 1 << 20,
        ..Default::default()
    };
    let mut pa = PagedArena::new(&m, lanes, 64, pcfg);
    let mut sched: Scheduler<Request> =
        Scheduler::new(lanes, AdmitOrder::Fcfs);
    let mut prompts: HashMap<u64, Vec<i32>> = HashMap::new();
    let mut rxs = Vec::new();
    for i in 0..n {
        let p: Vec<i32> =
            (0..8u64).map(|j| 4 + ((i * 31 + j * 7) % 200) as i32).collect();
        metrics.tracer().record(
            i,
            TenantId::DEFAULT,
            NO_LANE,
            EventKind::Submit { prompt_tokens: p.len() as u32 },
        );
        let (req, rx) = Request::synthetic(i, p.clone(), max_new);
        prompts.insert(i, p);
        rxs.push(rx);
        sched.enqueue(req);
    }
    let mut active: Vec<Active> = Vec::new();
    let mut preempted_once = vec![false; n as usize];
    let mut done = 0;
    let mut guard = 0;
    while done < n {
        guard += 1;
        assert!(guard < 1000, "sim stack livelocked");
        while active.len() < lanes && sched.queue_len() > 0 {
            let req = sched.pop_next(|r| r.prompt.len()).unwrap();
            match try_resume(req, &mut pa, &metrics) {
                Resume::Restored(a) => active.push(a),
                Resume::Busy(_) => panic!("worst-case pool went busy"),
                Resume::Recompute(req) => match admit(
                    &NoExec, &man, &policy, &cfg, req, &mut pa, &metrics,
                ) {
                    Ok(a) => active.push(a),
                    Err(AdmitFail::Defer(_) | AdmitFail::Reject(..)) => {
                        panic!("worst-case pool refused admission")
                    }
                },
            }
        }
        sim_decode_round(&mut pa, &mut active, &prompts, &cfg, &metrics);
        let mut j = 0;
        while j < active.len() {
            if active[j].is_done() || active[j].tokens().len() >= max_new {
                let a = active.remove(j);
                finish(a, &mut pa, &metrics);
                done += 1;
            } else {
                j += 1;
            }
        }
        let mut j = 0;
        while j < active.len() {
            let id = active[j].request_id() as usize;
            if !preempted_once[id] && active[j].tokens().len() >= 2 {
                preempted_once[id] = true;
                preempt(&mut active, j, &mut pa, &mut sched, &metrics);
            } else {
                j += 1;
            }
        }
    }
    for rx in rxs {
        let resp = rx.recv().unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(resp.tokens.len(), max_new);
        assert!(resp.ttft_secs.is_some(), "completed request lost TTFT");
    }
    (metrics, (0..n).collect())
}

// ---------------------------------------------------------------- tests

#[test]
fn lifecycle_ordering_holds_across_preempt_swap_resume() {
    let (metrics, ids) = run_traced_stack(3);
    let tracer = metrics.tracer();
    for &id in &ids {
        let evs = tracer.events_for(id, usize::MAX);
        assert!(!evs.is_empty(), "request {id} left no trace");
        if let Err(e) = validate_lifecycle(&evs) {
            panic!("request {id} lifecycle violated: {e}\n{evs:#?}");
        }
    }
    // Every request was preempted once with swap on: the full grammar —
    // Preempt{Swap}, SwapOut, Resume{Swap} — must appear in its trace.
    for &id in &ids {
        let evs = tracer.events_for(id, usize::MAX);
        let has = |f: &dyn Fn(&EventKind) -> bool| {
            evs.iter().any(|e| f(&e.kind))
        };
        assert!(
            has(&|k| matches!(
                k,
                EventKind::Preempt { mode: ResumeMode::Swap, .. }
            )),
            "request {id}: no swap preempt event"
        );
        assert!(
            has(&|k| matches!(k, EventKind::SwapOut { .. })),
            "request {id}: no swap-out event"
        );
        assert!(
            has(&|k| matches!(
                k,
                EventKind::Resume { mode: ResumeMode::Swap }
            )),
            "request {id}: no swap resume event"
        );
        assert!(
            has(&|k| matches!(k, EventKind::Finish { .. })),
            "request {id}: no finish event"
        );
    }
    // Phase histograms fed by the real server functions are non-empty
    // and the TTFT series is honest: 3 measured, none unmeasured.
    assert_eq!(metrics.histogram(names::QUEUE_WAIT_SECS).count(), 3);
    assert_eq!(metrics.histogram(names::PREFILL_SECS).count(), 3);
    assert_eq!(metrics.histogram(names::TTFT_SECS).count(), 3);
    assert_eq!(metrics.counter(names::TTFT_UNMEASURED), 0);
    assert!(metrics.histogram(names::SWAP_OUT_SECS).count() >= 3);
    assert!(metrics.histogram(names::SWAP_IN_SECS).count() >= 3);
}

#[test]
fn trace_ring_wraps_oldest_first_and_counts_drops() {
    let rec = TraceRecorder::default();
    rec.enable(4);
    for i in 0..7u64 {
        rec.record(
            i,
            TenantId::DEFAULT,
            NO_LANE,
            EventKind::Submit { prompt_tokens: 1 },
        );
    }
    assert_eq!(rec.len(), 4);
    assert_eq!(rec.dropped(), 3);
    let evs = rec.snapshot();
    let reqs: Vec<u64> = evs.iter().map(|e| e.req).collect();
    assert_eq!(reqs, vec![3, 4, 5, 6], "oldest events overwritten first");
    assert!(
        evs.windows(2).all(|w| w[0].ts <= w[1].ts),
        "snapshot not in chronological order"
    );
}

#[test]
fn json_snapshot_round_trips_through_value_parse() {
    let m = Metrics::default();
    m.inc("alpha", 3);
    m.inc("beta", 41);
    m.set_gauge("depth", 2.5);
    for i in 1..=100 {
        m.observe("lat", i as f64 * 1e-4);
    }
    let s = fastkv::obs::json_snapshot(&m).to_string();
    let v = Value::parse(&s).unwrap_or_else(|e| panic!("bad JSON: {e}"));
    assert_eq!(v.req("counters").req("alpha").as_f64(), Some(3.0));
    assert_eq!(v.req("counters").req("beta").as_f64(), Some(41.0));
    assert_eq!(v.req("gauges").req("depth").as_f64(), Some(2.5));
    let lat = v.req("histograms").req("lat");
    assert_eq!(lat.req("count").as_f64(), Some(100.0));
    let sum = lat.req("sum").as_f64().unwrap();
    assert!((sum - 0.505).abs() < 1e-9, "sum drifted: {sum}");
    let buckets = lat.req("buckets").as_arr().unwrap();
    assert!(!buckets.is_empty(), "non-empty histogram lost its buckets");
    let n: f64 = buckets
        .iter()
        .map(|b| b.req("n").as_f64().unwrap())
        .sum();
    assert_eq!(n, 100.0, "bucket counts don't sum to the sample count");
    // tracing was never enabled on this registry
    assert_eq!(v.req("trace").req("enabled").as_bool(), Some(false));
    assert_eq!(v.req("trace").req("events").as_f64(), Some(0.0));
}

#[test]
fn chrome_trace_parses_and_reconstructs_phase_spans() {
    let (metrics, _) = run_traced_stack(3);
    let s = fastkv::obs::chrome_trace(metrics.tracer());
    let v = Value::parse(&s).unwrap_or_else(|e| panic!("bad JSON: {e}"));
    let evs = v.req("traceEvents").as_arr().unwrap();
    assert!(!evs.is_empty());
    let span_names: Vec<&str> = evs
        .iter()
        .filter(|e| e.req("ph").as_str() == Some("X"))
        .map(|e| e.req("name").as_str().unwrap())
        .collect();
    for phase in ["queued", "prefill", "decode", "preempted"] {
        assert!(
            span_names.contains(&phase),
            "no `{phase}` span in {span_names:?}"
        );
    }
    // spans carry non-negative durations and a lane-or-queue track id
    for e in evs.iter().filter(|e| e.req("ph").as_str() == Some("X")) {
        assert!(e.req("dur").as_f64().unwrap() >= 0.0);
        assert!(e.req("tid").as_f64().unwrap() >= 0.0);
    }
    // per-track thread_name metadata names the queue track
    let meta_names: Vec<&str> = evs
        .iter()
        .filter(|e| e.req("ph").as_str() == Some("M"))
        .map(|e| e.req("args").req("name").as_str().unwrap())
        .collect();
    assert!(meta_names.contains(&"queue/parked"), "{meta_names:?}");
    assert!(
        meta_names.iter().any(|n| n.starts_with("lane ")),
        "{meta_names:?}"
    );
}

#[test]
fn reject_files_flight_incident_and_keeps_ttft_honest() {
    let m = sim_meta();
    let man = sim_manifest(64);
    let policy = SimPolicy::new();
    let metrics = Metrics::default();
    metrics.tracer().enable(256);
    let cfg = sim_server_cfg(8, 4);
    let pcfg = PagingConfig {
        block_tokens: 2,
        prefix_cache: false,
        ..Default::default()
    };
    let mut pa = PagedArena::new(&m, 1, 64, pcfg);
    // oversized prompt: admit must reject it before any prefill
    let (req, rx) = Request::synthetic(7, vec![5; 9], 4);
    metrics.tracer().record(
        7,
        TenantId::DEFAULT,
        NO_LANE,
        EventKind::Submit { prompt_tokens: 9 },
    );
    match admit(&NoExec, &man, &policy, &cfg, req, &mut pa, &metrics) {
        Err(AdmitFail::Reject(req, e)) => {
            reject(req, &mut pa, &metrics, format!("{e:#}"));
        }
        Ok(_) | Err(AdmitFail::Defer(_)) => {
            panic!("oversized prompt was not rejected")
        }
    }
    let resp = rx.recv().unwrap();
    assert!(resp.error.is_some());
    assert!(resp.ttft_secs.is_none(), "reject invented a TTFT");
    assert_eq!(metrics.histogram(names::TTFT_SECS).count(), 0);
    assert_eq!(metrics.counter(names::TTFT_UNMEASURED), 1);
    let evs = metrics.tracer().events_for(7, usize::MAX);
    validate_lifecycle(&evs).unwrap();
    let incidents = metrics.tracer().incidents();
    let inc = incidents
        .iter()
        .find(|i| i.kind == IncidentKind::Reject && i.req == 7)
        .expect("reject filed no flight-recorder incident");
    assert!(
        inc.history
            .iter()
            .any(|e| matches!(e.kind, EventKind::Submit { .. })),
        "incident history lost the submit event"
    );
    assert!(
        !fastkv::obs::flight_text(metrics.tracer()).is_empty(),
        "flight report empty despite an incident"
    );
}
