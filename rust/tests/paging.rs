//! Property-style tests for the paged KV-cache subsystem (hand-rolled
//! generator loop, same style as `properties.rs`): allocator invariants,
//! prefix-cache reuse, copy-on-write forking, block-granular compaction,
//! and scheduler preemption under memory pressure. The strongest checks
//! are *differential*: a `PagedArena` driven through the `KvStore` trait
//! must stage byte-identical decode inputs to the flat `BatchArena` for
//! any admit/append/compact/release schedule, and block-table decode
//! (reading KV through `DecodeView`) must produce the same token streams
//! and KV contents as the dense staged path across admissions, appends,
//! compactions, and preemption/resume.

use fastkv::coordinator::kvcache::{BatchArena, RequestCache};
use fastkv::coordinator::paging::{
    AppendResult, KvStore, PagedArena, PagingConfig,
};
use fastkv::coordinator::scheduler::{Action, AdmitOrder, Scheduler};
use fastkv::manifest::ModelMeta;
use fastkv::tensor::HostTensor;
use fastkv::util::rng::Rng;

fn cases(n: usize) -> impl Iterator<Item = (u64, Rng)> {
    (0..n as u64).map(|seed| (seed, Rng::new(seed)))
}

fn meta(rng: &mut Rng) -> ModelMeta {
    ModelMeta {
        vocab_size: 256,
        d_model: 16,
        n_layers: rng.range(1, 3),
        n_heads: 2,
        n_kv_heads: rng.range(1, 2),
        head_dim: rng.range(2, 4),
        tsp_layer: 1,
        window: 4,
        pool_kernel: 3,
        max_train_len: 64,
    }
}

/// A request cache with per-layer random lens and value-tagged rows.
fn rand_cache(rng: &mut Rng, m: &ModelMeta, max_len: usize, tag: f64) -> RequestCache {
    let re = m.n_kv_heads * m.head_dim;
    let mut rc = RequestCache::new(m);
    for l in 0..m.n_layers {
        let len = rng.range(1, max_len);
        rc.k[l] = (0..len * re)
            .map(|i| (tag * 1e3 + (l * 131 + i) as f64) as f32)
            .collect();
        rc.v[l] = (0..len * re)
            .map(|i| -((tag * 1e3 + (l * 131 + i) as f64) as f32))
            .collect();
        rc.lens[l] = len;
    }
    rc
}

fn rand_step(rng: &mut Rng, m: &ModelMeta, b: usize) -> HostTensor {
    let n = m.n_layers * b * m.n_kv_heads * m.head_dim;
    HostTensor::new(
        vec![m.n_layers, b, m.n_kv_heads, m.head_dim],
        (0..n).map(|_| (rng.f64() * 10.0 - 5.0) as f32).collect(),
    )
}

fn assert_staged_equal(a: &dyn KvStore, b: &dyn KvStore, seed: u64, what: &str) {
    let sa = a.stage();
    let sb = b.stage();
    assert_eq!(sa.lens.data, sb.lens.data, "seed {seed}: lens after {what}");
    assert_eq!(sa.k.data, sb.k.data, "seed {seed}: staged K after {what}");
    assert_eq!(sa.v.data, sb.v.data, "seed {seed}: staged V after {what}");
}

// ------------------------------------------------------------- invariants

#[test]
fn prop_pool_accounting_invariants() {
    for (seed, mut rng) in cases(120) {
        let m = meta(&mut rng);
        let b = rng.range(1, 4);
        let c = rng.range(6, 24);
        let cfg = PagingConfig {
            block_tokens: rng.range(2, 6),
            num_blocks: None,
            prefix_cache: rng.chance(0.5),
            ..Default::default()
        };
        let mut pa = PagedArena::new(&m, b, c, cfg);
        let total = pa.pool_stats().blocks_total;
        let mut slots: Vec<usize> = Vec::new();
        for step in 0..rng.range(4, 20) {
            let ps = pa.pool_stats();
            assert_eq!(
                ps.blocks_in_use + ps.blocks_cached + ps.blocks_free,
                total,
                "seed {seed}: accounting"
            );
            if !slots.is_empty() && rng.chance(0.4) {
                let slot = slots.swap_remove(rng.below(slots.len()));
                assert!(pa.release(slot), "seed {seed}");
                assert!(!pa.release(slot), "seed {seed}: double release");
            } else {
                let rc =
                    rand_cache(&mut rng, &m, c, (seed * 100 + step as u64) as f64);
                if let Some(slot) = KvStore::admit(&mut pa, &rc) {
                    // staged lens must mirror the cache lens
                    assert_eq!(pa.layer_lens(slot), rc.lens, "seed {seed}");
                    slots.push(slot);
                }
            }
        }
        for slot in slots {
            pa.release(slot);
        }
        assert_eq!(pa.pool_stats().blocks_in_use, 0, "seed {seed}: leak");
    }
}

// ----------------------------------------------------------- differential

#[test]
fn prop_paged_stages_identically_to_flat() {
    // Any schedule of admits, appends, compactions, and releases must
    // stage the same dense decode inputs as the flat arena.
    for (seed, mut rng) in cases(80) {
        let m = meta(&mut rng);
        let b = rng.range(1, 3);
        let c = rng.range(6, 20);
        let cfg = PagingConfig {
            block_tokens: rng.range(2, 5),
            num_blocks: None, // worst-case pool: admission never fails
            prefix_cache: rng.chance(0.7),
            ..Default::default()
        };
        let mut paged = PagedArena::new(&m, b, c, cfg);
        let mut flat = BatchArena::new(&m, b, c);
        // a fixed cache admitted repeatedly, so prefix sharing + COW paths
        // really trigger on the paged side
        let shared_rc = rand_cache(&mut rng, &m, c.min(9), 777.0);
        let mut live: Vec<usize> = Vec::new();
        for step in 0..rng.range(5, 25) {
            match rng.below(4) {
                0 => {
                    let rc = if rng.chance(0.5) {
                        shared_rc.clone()
                    } else {
                        rand_cache(&mut rng, &m, c.min(9), step as f64)
                    };
                    let sp = KvStore::admit(&mut paged, &rc);
                    let sf = KvStore::admit(&mut flat, &rc);
                    assert_eq!(sp, sf, "seed {seed}: slot assignment");
                    if let Some(s) = sp {
                        live.push(s);
                    }
                }
                1 if !live.is_empty() => {
                    let step_kv = rand_step(&mut rng, &m, b);
                    let slot = live[rng.below(live.len())];
                    let rp = KvStore::append(&mut paged, slot, &step_kv, &step_kv);
                    let rf = KvStore::append(&mut flat, slot, &step_kv, &step_kv);
                    assert_eq!(rp, rf, "seed {seed}: append result");
                }
                2 if !live.is_empty() => {
                    let slot = live.swap_remove(rng.below(live.len()));
                    assert_eq!(
                        KvStore::release(&mut paged, slot),
                        KvStore::release(&mut flat, slot),
                        "seed {seed}: release"
                    );
                }
                3 if !live.is_empty() => {
                    let slot = live[rng.below(live.len())];
                    let lens = KvStore::layer_lens(&paged, slot);
                    assert_eq!(
                        lens,
                        KvStore::layer_lens(&flat, slot),
                        "seed {seed}"
                    );
                    let keep: Vec<Vec<usize>> = lens
                        .iter()
                        .map(|&n| {
                            let k = rng.range(1, n.max(1));
                            rng.distinct_sorted(k.min(n), n)
                        })
                        .collect();
                    KvStore::compact(&mut paged, slot, &keep);
                    KvStore::compact(&mut flat, slot, &keep);
                }
                _ => {}
            }
            assert_staged_equal(&paged, &flat, seed, "step");
        }
    }
}

// ---------------------------------------------------------- prefix reuse

#[test]
fn prop_shared_prompt_allocates_sublinearly() {
    // N requests with an identical compressed cache must share full
    // blocks: pool usage grows only by partial-tail blocks per extra
    // request, never by the full per-request footprint.
    for (seed, mut rng) in cases(60) {
        let m = meta(&mut rng);
        let bt = rng.range(2, 5);
        let lanes = rng.range(2, 4);
        let c = 4 * bt;
        let cfg = PagingConfig {
            block_tokens: bt,
            num_blocks: None,
            prefix_cache: true,
            ..Default::default()
        };
        let mut pa = PagedArena::new(&m, lanes, c, cfg);
        // full-block-aligned lens so the entire cache is shareable
        let mut rc = rand_cache(&mut rng, &m, c, seed as f64);
        let re = m.n_kv_heads * m.head_dim;
        for l in 0..m.n_layers {
            let len = rng.range(1, 3) * bt;
            rc.k[l].resize(len * re, 0.5);
            rc.v[l].resize(len * re, -0.5);
            rc.lens[l] = len;
        }
        let s0 = KvStore::admit(&mut pa, &rc).unwrap();
        let single = pa.pool_stats().blocks_in_use;
        for _ in 1..lanes {
            KvStore::admit(&mut pa, &rc).unwrap();
        }
        let ps = pa.pool_stats();
        assert_eq!(
            ps.blocks_in_use, single,
            "seed {seed}: shared prompt duplicated blocks"
        );
        assert!(ps.prefix_hits > 0, "seed {seed}");
        let _ = s0;
    }
}

#[test]
fn prop_cache_survives_release_and_rehits() {
    // Release a request, admit the same content again: the evictable
    // blocks are revived from the prefix cache with no new allocation.
    for (seed, mut rng) in cases(60) {
        let m = meta(&mut rng);
        let bt = rng.range(2, 4);
        let cfg = PagingConfig {
            block_tokens: bt,
            num_blocks: None,
            prefix_cache: true,
            ..Default::default()
        };
        let mut pa = PagedArena::new(&m, 1, 4 * bt, cfg);
        let mut rc = rand_cache(&mut rng, &m, 4 * bt, seed as f64 + 0.5);
        let re = m.n_kv_heads * m.head_dim;
        for l in 0..m.n_layers {
            let len = 2 * bt; // aligned: fully cacheable
            rc.k[l].resize(len * re, 1.5);
            rc.v[l].resize(len * re, -1.5);
            rc.lens[l] = len;
        }
        let s = KvStore::admit(&mut pa, &rc).unwrap();
        let first = pa.stage();
        pa.release(s);
        assert_eq!(pa.pool_stats().blocks_in_use, 0, "seed {seed}");
        let hits_before = pa.pool_stats().prefix_hits;
        let s2 = KvStore::admit(&mut pa, &rc).unwrap();
        let ps = pa.pool_stats();
        assert!(ps.prefix_hits > hits_before, "seed {seed}: no rehit");
        let again = pa.stage();
        assert_eq!(first.k.data, again.k.data, "seed {seed}");
        let _ = s2;
    }
}

// ------------------------------------------------------- COW via forking

#[test]
fn prop_fork_then_divergent_appends_match_independent_lanes() {
    // fork + divergent appends must behave exactly like two independent
    // flat lanes loaded with the same cache (COW isolation).
    for (seed, mut rng) in cases(60) {
        let m = meta(&mut rng);
        let c = rng.range(8, 16);
        let cfg = PagingConfig {
            block_tokens: rng.range(2, 5),
            num_blocks: None,
            prefix_cache: rng.chance(0.5),
            ..Default::default()
        };
        let mut paged = PagedArena::new(&m, 2, c, cfg);
        let mut flat = BatchArena::new(&m, 2, c);
        let rc = rand_cache(&mut rng, &m, c - 3, seed as f64 + 9.0);
        let s0 = KvStore::admit(&mut paged, &rc).unwrap();
        let s1 = paged.fork(s0).unwrap();
        let f0 = KvStore::admit(&mut flat, &rc).unwrap();
        let f1 = KvStore::admit(&mut flat, &rc).unwrap();
        assert_eq!((s0, s1), (f0, f1), "seed {seed}");
        for _ in 0..rng.range(1, 6) {
            let step_kv = rand_step(&mut rng, &m, 2);
            let slot = if rng.chance(0.5) { s0 } else { s1 };
            let rp = KvStore::append(&mut paged, slot, &step_kv, &step_kv);
            let rf = KvStore::append(&mut flat, slot, &step_kv, &step_kv);
            assert_eq!(rp, rf, "seed {seed}");
            assert_staged_equal(&paged, &flat, seed, "fork-append");
        }
    }
}

// ------------------------------------------------ preemption under pressure

#[derive(Debug)]
struct SimReq {
    id: usize,
    cache: RequestCache,
    want: usize,
    got: usize,
}

#[test]
fn prop_preemption_resumes_and_all_requests_finish() {
    // A deliberately under-provisioned pool: requests admit only when the
    // allocator covers their budget, preempt back to the queue on
    // exhaustion (releasing blocks), and every request still finishes.
    for (seed, mut rng) in cases(40) {
        let m = meta(&mut rng);
        let bt = 2;
        let lanes = 2;
        let c = 12;
        let per_layer = 4usize; // tokens per layer at admission
        let gen = rng.range(2, 6); // decode steps per request
        // pool covers roughly one active request + slack: forces churn
        let tight = m.n_layers * ((per_layer + gen) / bt + 2);
        let cfg = PagingConfig {
            block_tokens: bt,
            num_blocks: Some(tight),
            prefix_cache: false,
            ..Default::default()
        };
        let mut pa = PagedArena::new(&m, lanes, c, cfg);
        let mut sched: Scheduler<SimReq> = Scheduler::new(lanes, AdmitOrder::Fcfs);
        let total = rng.range(3, 7);
        for id in 0..total {
            let mut rc = rand_cache(&mut rng, &m, per_layer, id as f64);
            for l in 0..m.n_layers {
                let re = m.n_kv_heads * m.head_dim;
                rc.k[l].resize(per_layer * re, 0.25);
                rc.v[l].resize(per_layer * re, -0.25);
                rc.lens[l] = per_layer;
            }
            sched.enqueue(SimReq { id, cache: rc, want: gen, got: 0 });
        }
        let mut active: Vec<(usize, SimReq)> = Vec::new();
        let mut finished = vec![false; total];
        let mut preemptions = 0usize;
        let mut steps = 0usize;
        while finished.iter().any(|f| !f) {
            steps += 1;
            assert!(steps < 10_000, "seed {seed}: livelock");
            let admit_ok = sched
                .peek_next(|r| r.cache.max_len())
                .map(|r| {
                    KvStore::can_admit(&pa, r.cache.max_len(), r.want - r.got)
                })
                .unwrap_or(true);
            match sched.next_action_mem(active.len(), admit_ok) {
                Action::Prefill => {
                    let req = sched.pop_next(|r| r.cache.max_len()).unwrap();
                    match KvStore::admit(&mut pa, &req.cache) {
                        Some(slot) => active.push((slot, req)),
                        None => {
                            assert!(
                                !active.is_empty(),
                                "seed {seed}: admit failed with idle pool"
                            );
                            sched.requeue_front(req);
                        }
                    }
                }
                Action::DecodeStep => {
                    let step_kv = rand_step(&mut rng, &m, lanes);
                    let mut idx = 0;
                    while idx < active.len() {
                        let (slot, req) = &mut active[idx];
                        match KvStore::append(&mut pa, *slot, &step_kv, &step_kv)
                        {
                            AppendResult::Ok => {
                                req.got += 1;
                                idx += 1;
                            }
                            AppendResult::CapacityExhausted => {
                                req.got = req.want; // done early
                                idx += 1;
                            }
                            AppendResult::PoolExhausted => {
                                // preempt: release blocks, requeue, resume
                                let (slot, mut req) = active.swap_remove(idx);
                                assert!(pa.release(slot), "seed {seed}");
                                // resume = re-prefill prompt+generated:
                                // simulate by carrying progress along
                                req.want -= req.got;
                                req.got = 0;
                                preemptions += 1;
                                assert!(
                                    preemptions < 1000,
                                    "seed {seed}: preemption storm"
                                );
                                sched.requeue_front(req);
                            }
                        }
                    }
                    // retire
                    let mut i = 0;
                    while i < active.len() {
                        if active[i].1.got >= active[i].1.want {
                            let (slot, req) = active.swap_remove(i);
                            assert!(pa.release(slot), "seed {seed}");
                            finished[req.id] = true;
                        } else {
                            i += 1;
                        }
                    }
                }
                Action::Idle => {
                    // queue blocked on memory with nothing active would be
                    // a livelock; the sizing above never produces it
                    assert!(
                        sched.queue_len() == 0 || !active.is_empty() || admit_ok,
                        "seed {seed}: stuck"
                    );
                }
            }
            let ps = pa.pool_stats();
            assert!(
                ps.blocks_in_use <= ps.blocks_total,
                "seed {seed}: over-allocated"
            );
        }
        assert_eq!(pa.pool_stats().blocks_in_use, 0, "seed {seed}: leak");
    }
}

// ------------------------------------------------------------- compaction

#[test]
fn prop_compaction_frees_blocks_and_preserves_survivors() {
    for (seed, mut rng) in cases(60) {
        let m = meta(&mut rng);
        let bt = rng.range(2, 4);
        let c = 6 * bt;
        let cfg = PagingConfig {
            block_tokens: bt,
            num_blocks: None,
            prefix_cache: false,
            ..Default::default()
        };
        let mut pa = PagedArena::new(&m, 1, c, cfg);
        let rc = rand_cache(&mut rng, &m, c, seed as f64 + 3.0);
        let slot = KvStore::admit(&mut pa, &rc).unwrap();
        let before = pa.stage();
        let re = m.n_kv_heads * m.head_dim;
        let keep: Vec<Vec<usize>> = rc
            .lens
            .iter()
            .map(|&n| {
                let k = rng.range(1, n);
                rng.distinct_sorted(k, n)
            })
            .collect();
        let in_use_before = pa.pool_stats().blocks_in_use;
        let released = KvStore::compact(&mut pa, slot, &keep);
        let ps = pa.pool_stats();
        assert_eq!(
            in_use_before - ps.blocks_in_use,
            released,
            "seed {seed}: release accounting"
        );
        let after = pa.stage();
        for l in 0..m.n_layers {
            assert_eq!(pa.layer_lens(slot)[l], keep[l].len(), "seed {seed}");
            for (new_row, &old_row) in keep[l].iter().enumerate() {
                let nb = ((l * 1 + 0) * c + new_row) * re;
                let ob = ((l * 1 + 0) * c + old_row) * re;
                assert_eq!(
                    &after.k.data[nb..nb + re],
                    &before.k.data[ob..ob + re],
                    "seed {seed}: survivor moved wrong (layer {l})"
                );
            }
        }
    }
}

// ----------------------------------------------- block-table decode oracle

/// Deterministic KV summary of one lane, read through the block-table
/// view. Accumulation order is row-major, matching `sums_staged`, so equal
/// KV content yields bitwise-equal f64 sums.
fn sums_view(pa: &PagedArena, slot: usize, layers: usize) -> Vec<f64> {
    let v = pa.view();
    let re = v.row_elems();
    (0..layers)
        .map(|l| {
            let mut s = 0.0f64;
            for row in 0..v.len(l, slot) {
                let kr = v.k_row(l, slot, row);
                let vr = v.v_row(l, slot, row);
                for i in 0..re {
                    s += kr[i] as f64 * (1.0 + (i % 3) as f64);
                    s += 0.5 * vr[i] as f64;
                }
            }
            s
        })
        .collect()
}

/// The same summary read from the dense staged layout (the fallback
/// decode path's view of the world).
fn sums_staged(pa: &PagedArena, slot: usize, layers: usize) -> Vec<f64> {
    let st = KvStore::stage(pa);
    let b = st.k.shape[1];
    let c = st.k.shape[2];
    let re = st.k.shape[3] * st.k.shape[4];
    (0..layers)
        .map(|l| {
            let len = st.lens.data[l * b + slot] as usize;
            let mut s = 0.0f64;
            for row in 0..len {
                let base = ((l * b + slot) * c + row) * re;
                for i in 0..re {
                    s += st.k.data[base + i] as f64 * (1.0 + (i % 3) as f64);
                    s += 0.5 * st.v.data[base + i] as f64;
                }
            }
            s
        })
        .collect()
}

/// FNV-mix a lane's decode inputs into the "model" outputs: the sampled
/// token and the per-layer appended KV row are pure functions of (current
/// token, position, KV summaries), so a divergence between the two read
/// paths becomes a diverging token stream.
fn sim_decode(cur: i32, pos: usize, sums: &[f64]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    let mut mix = |v: u64, h: &mut u64| {
        *h ^= v;
        *h = h.wrapping_mul(0x100000001b3);
    };
    mix(cur as u64, &mut h);
    mix(pos as u64, &mut h);
    for &s in sums {
        mix(s.to_bits(), &mut h);
    }
    h
}

fn sim_row(h: u64, layer: usize, re: usize) -> Vec<f32> {
    (0..re)
        .map(|i| {
            let x = h
                .wrapping_mul(6364136223846793005)
                .wrapping_add((layer * 97 + i) as u64);
            ((x >> 32) as f64 / u32::MAX as f64) as f32 - 0.5
        })
        .collect()
}

#[test]
fn prop_block_table_decode_matches_staged_decode() {
    // Two identical arenas — one decoding through the block-table view
    // (the default), one through the dense staged bridge (the fallback) —
    // are driven through the same randomized serving schedule: admissions,
    // decode appends whose content DEPENDS on the KV read back, policy
    // compactions, and preemption/resume under a tight pool. Token
    // streams and staged KV must stay identical throughout.
    for (seed, mut rng) in cases(30) {
        let m = meta(&mut rng);
        let bt = rng.range(2, 4);
        let lanes = rng.range(1, 2);
        let c = rng.range(8, 16);
        let re = m.n_kv_heads * m.head_dim;
        // tight-ish pool on half the seeds: forces the pressure paths
        let pool = if rng.chance(0.5) {
            Some(m.n_layers * lanes * ((c / 2) / bt + 2))
        } else {
            None
        };
        let mk = |dense: bool| PagingConfig {
            block_tokens: bt,
            num_blocks: pool,
            prefix_cache: false,
            dense_staging: dense,
        };
        let mut via_view = PagedArena::new(&m, lanes, c, mk(false));
        let mut via_stage = PagedArena::new(&m, lanes, c, mk(true));

        // request id -> (cache, want); queue of pending ids
        let total = rng.range(2, 5);
        let caches: Vec<RequestCache> = (0..total)
            .map(|id| rand_cache(&mut rng, &m, c.min(6), (seed * 50 + id as u64) as f64))
            .collect();
        let wants: Vec<usize> = (0..total).map(|_| rng.range(2, 8)).collect();
        let mut queue: Vec<usize> = (0..total).collect();
        // active: (req id, slot, cur token, pos, got)
        let mut active: Vec<(usize, usize, i32, usize, usize)> = Vec::new();
        let mut streams: Vec<Vec<i32>> = vec![Vec::new(); total];
        let mut done = vec![false; total];
        let mut steps = 0usize;
        while done.iter().any(|d| !d) {
            steps += 1;
            assert!(steps < 5_000, "seed {seed}: livelock");
            // admit while a lane is free and the pool covers the head
            while !queue.is_empty()
                && KvStore::free_slots(&via_view) > 0
                && KvStore::can_admit(
                    &via_view,
                    caches[queue[0]].max_len(),
                    wants[queue[0]],
                )
            {
                let id = queue.remove(0);
                let sa = KvStore::admit(&mut via_view, &caches[id]);
                let sb = KvStore::admit(&mut via_stage, &caches[id]);
                assert_eq!(sa, sb, "seed {seed}: admission diverged");
                match sa {
                    Some(slot) => {
                        active.push((id, slot, (id as i32) + 1, 0, 0))
                    }
                    None => {
                        queue.insert(0, id);
                        break;
                    }
                }
            }
            if active.is_empty() {
                // nothing admitted and queue non-empty would be a sizing
                // bug in the test itself
                assert!(
                    !queue.is_empty(),
                    "seed {seed}: no work but requests unfinished"
                );
                // head request can never fit a drained pool: count it done
                let id = queue.remove(0);
                done[id] = true;
                continue;
            }
            // one lockstep decode step over the active lanes
            let mut k_new_a = HostTensor::zeros(vec![
                m.n_layers, lanes, m.n_kv_heads, m.head_dim,
            ]);
            let mut v_new_a = k_new_a.clone();
            let mut k_new_b = k_new_a.clone();
            let mut v_new_b = k_new_a.clone();
            let mut nexts: Vec<i32> = Vec::with_capacity(active.len());
            for &(_id, slot, cur, pos, _) in &active {
                let sa = sums_view(&via_view, slot, m.n_layers);
                let sb = sums_staged(&via_stage, slot, m.n_layers);
                assert_eq!(sa, sb, "seed {seed}: KV read paths diverged");
                let ha = sim_decode(cur, pos, &sa);
                let hb = sim_decode(cur, pos, &sb);
                assert_eq!(ha, hb, "seed {seed}");
                for l in 0..m.n_layers {
                    let row = sim_row(ha, l, re);
                    let neg: Vec<f32> = row.iter().map(|x| -x).collect();
                    let base_a = (l * lanes + slot) * re;
                    k_new_a.data[base_a..base_a + re].copy_from_slice(&row);
                    v_new_a.data[base_a..base_a + re].copy_from_slice(&neg);
                }
                nexts.push((ha % 251) as i32 + 1);
            }
            k_new_b.data.copy_from_slice(&k_new_a.data);
            v_new_b.data.copy_from_slice(&v_new_a.data);

            let mut i = 0;
            while i < active.len() {
                let (id, slot, _cur, pos, got) = active[i];
                let ra = KvStore::append(&mut via_view, slot, &k_new_a, &v_new_a);
                let rb = KvStore::append(&mut via_stage, slot, &k_new_b, &v_new_b);
                assert_eq!(ra, rb, "seed {seed}: append result diverged");
                match ra {
                    AppendResult::Ok => {
                        let next = nexts[i];
                        streams[id].push(next);
                        active[i] = (id, slot, next, pos + 1, got + 1);
                        if got + 1 >= wants[id] {
                            assert!(via_view.release(slot));
                            assert!(via_stage.release(slot));
                            done[id] = true;
                            active.remove(i);
                            nexts.remove(i);
                        } else {
                            i += 1;
                        }
                    }
                    AppendResult::CapacityExhausted => {
                        assert!(via_view.release(slot));
                        assert!(via_stage.release(slot));
                        done[id] = true;
                        active.remove(i);
                        nexts.remove(i);
                    }
                    AppendResult::PoolExhausted => {
                        // policy compaction first, preempt if it frees
                        // nothing (release + requeue + resume later)
                        let lens = KvStore::layer_lens(&via_view, slot);
                        assert_eq!(
                            lens,
                            KvStore::layer_lens(&via_stage, slot),
                            "seed {seed}"
                        );
                        let keep: Vec<Vec<usize>> = lens
                            .iter()
                            .map(|&n| (0..n / 2).collect())
                            .collect();
                        let fa = KvStore::compact(&mut via_view, slot, &keep);
                        let fb = KvStore::compact(&mut via_stage, slot, &keep);
                        assert_eq!(fa, fb, "seed {seed}: compact diverged");
                        if fa == 0 {
                            assert!(via_view.release(slot));
                            assert!(via_stage.release(slot));
                            queue.insert(0, id);
                            active.remove(i);
                            nexts.remove(i);
                        }
                        // if compaction freed blocks, retry this lane on
                        // the next iteration (i unchanged)
                    }
                }
                assert_staged_equal(&via_view, &via_stage, seed, "decode");
            }
        }
        // final oracle: both stores drained identically and the schedule
        // actually generated tokens
        assert_staged_equal(&via_view, &via_stage, seed, "final");
        assert_eq!(
            via_view.pool_stats().blocks_in_use,
            via_stage.pool_stats().blocks_in_use,
            "seed {seed}"
        );
        let produced: usize = streams.iter().map(|s| s.len()).sum();
        assert!(produced > 0, "seed {seed}: nothing generated");
    }
}
