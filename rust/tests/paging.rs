//! Property-style tests for the paged KV-cache subsystem (hand-rolled
//! generator loop, same style as `properties.rs`): allocator invariants,
//! prefix-cache reuse, copy-on-write forking, block-granular compaction,
//! and scheduler preemption under memory pressure. The strongest checks
//! are *differential*: a `PagedArena` driven through the `KvStore` trait
//! must stage byte-identical decode inputs to the flat `BatchArena` for
//! any admit/append/compact/release schedule, and block-table decode
//! (reading KV through `DecodeView`) must produce the same token streams
//! and KV contents as the dense staged path across admissions, appends,
//! compactions, and preemption/resume.

use fastkv::coordinator::kvcache::{BatchArena, RequestCache};
use fastkv::coordinator::paging::allocator::{BlockAllocator, Revive};
use fastkv::coordinator::paging::{
    AppendResult, KvCodec, KvStore, PagedArena, PagingConfig, SwapIn,
    TenantId, TenantQuota,
};
use fastkv::coordinator::scheduler::{
    pick_preemption_victim, Action, AdmitOrder, Scheduler,
};
use fastkv::manifest::ModelMeta;
use fastkv::tensor::HostTensor;
use fastkv::util::rng::Rng;

fn cases(n: usize) -> impl Iterator<Item = (u64, Rng)> {
    (0..n as u64).map(|seed| (seed, Rng::new(seed)))
}

fn meta(rng: &mut Rng) -> ModelMeta {
    ModelMeta {
        vocab_size: 256,
        d_model: 16,
        n_layers: rng.range(1, 3),
        n_heads: 2,
        n_kv_heads: rng.range(1, 2),
        head_dim: rng.range(2, 4),
        tsp_layer: 1,
        window: 4,
        pool_kernel: 3,
        max_train_len: 64,
    }
}

/// A request cache with per-layer random lens and value-tagged rows.
fn rand_cache(rng: &mut Rng, m: &ModelMeta, max_len: usize, tag: f64) -> RequestCache {
    let re = m.n_kv_heads * m.head_dim;
    let mut rc = RequestCache::new(m);
    for l in 0..m.n_layers {
        let len = rng.range(1, max_len);
        rc.k[l] = (0..len * re)
            .map(|i| (tag * 1e3 + (l * 131 + i) as f64) as f32)
            .collect();
        rc.v[l] = (0..len * re)
            .map(|i| -((tag * 1e3 + (l * 131 + i) as f64) as f32))
            .collect();
        rc.lens[l] = len;
    }
    rc
}

fn rand_step(rng: &mut Rng, m: &ModelMeta, b: usize) -> HostTensor {
    let n = m.n_layers * b * m.n_kv_heads * m.head_dim;
    HostTensor::new(
        vec![m.n_layers, b, m.n_kv_heads, m.head_dim],
        (0..n).map(|_| (rng.f64() * 10.0 - 5.0) as f32).collect(),
    )
}

fn assert_staged_equal(a: &dyn KvStore, b: &dyn KvStore, seed: u64, what: &str) {
    let sa = a.stage();
    let sb = b.stage();
    assert_eq!(sa.lens.data, sb.lens.data, "seed {seed}: lens after {what}");
    assert_eq!(sa.k.data, sb.k.data, "seed {seed}: staged K after {what}");
    assert_eq!(sa.v.data, sb.v.data, "seed {seed}: staged V after {what}");
}

// ------------------------------------------------------------- invariants

#[test]
fn prop_pool_accounting_invariants() {
    for (seed, mut rng) in cases(120) {
        let m = meta(&mut rng);
        let b = rng.range(1, 4);
        let c = rng.range(6, 24);
        let cfg = PagingConfig {
            block_tokens: rng.range(2, 6),
            num_blocks: None,
            prefix_cache: rng.chance(0.5),
            ..Default::default()
        };
        let mut pa = PagedArena::new(&m, b, c, cfg);
        let total = pa.pool_stats().blocks_total;
        let mut slots: Vec<usize> = Vec::new();
        for step in 0..rng.range(4, 20) {
            let ps = pa.pool_stats();
            assert_eq!(
                ps.blocks_in_use + ps.blocks_cached + ps.blocks_free,
                total,
                "seed {seed}: accounting"
            );
            if !slots.is_empty() && rng.chance(0.4) {
                let slot = slots.swap_remove(rng.below(slots.len()));
                assert!(pa.release(slot), "seed {seed}");
                assert!(!pa.release(slot), "seed {seed}: double release");
            } else {
                let rc =
                    rand_cache(&mut rng, &m, c, (seed * 100 + step as u64) as f64);
                if let Some(slot) = KvStore::admit(&mut pa, &rc) {
                    // staged lens must mirror the cache lens
                    assert_eq!(pa.layer_lens(slot), rc.lens, "seed {seed}");
                    slots.push(slot);
                }
            }
        }
        for slot in slots {
            pa.release(slot);
        }
        assert_eq!(pa.pool_stats().blocks_in_use, 0, "seed {seed}: leak");
    }
}

// ----------------------------------------------------------- differential

#[test]
fn prop_paged_stages_identically_to_flat() {
    // Any schedule of admits, appends, compactions, and releases must
    // stage the same dense decode inputs as the flat arena.
    for (seed, mut rng) in cases(80) {
        let m = meta(&mut rng);
        let b = rng.range(1, 3);
        let c = rng.range(6, 20);
        let cfg = PagingConfig {
            block_tokens: rng.range(2, 5),
            num_blocks: None, // worst-case pool: admission never fails
            prefix_cache: rng.chance(0.7),
            ..Default::default()
        };
        let mut paged = PagedArena::new(&m, b, c, cfg);
        let mut flat = BatchArena::new(&m, b, c);
        // a fixed cache admitted repeatedly, so prefix sharing + COW paths
        // really trigger on the paged side
        let shared_rc = rand_cache(&mut rng, &m, c.min(9), 777.0);
        let mut live: Vec<usize> = Vec::new();
        for step in 0..rng.range(5, 25) {
            match rng.below(4) {
                0 => {
                    let rc = if rng.chance(0.5) {
                        shared_rc.clone()
                    } else {
                        rand_cache(&mut rng, &m, c.min(9), step as f64)
                    };
                    let sp = KvStore::admit(&mut paged, &rc);
                    let sf = KvStore::admit(&mut flat, &rc);
                    assert_eq!(sp, sf, "seed {seed}: slot assignment");
                    if let Some(s) = sp {
                        live.push(s);
                    }
                }
                1 if !live.is_empty() => {
                    let step_kv = rand_step(&mut rng, &m, b);
                    let slot = live[rng.below(live.len())];
                    let rp = KvStore::append(&mut paged, slot, &step_kv, &step_kv);
                    let rf = KvStore::append(&mut flat, slot, &step_kv, &step_kv);
                    assert_eq!(rp, rf, "seed {seed}: append result");
                }
                2 if !live.is_empty() => {
                    let slot = live.swap_remove(rng.below(live.len()));
                    assert_eq!(
                        KvStore::release(&mut paged, slot),
                        KvStore::release(&mut flat, slot),
                        "seed {seed}: release"
                    );
                }
                3 if !live.is_empty() => {
                    let slot = live[rng.below(live.len())];
                    let lens = KvStore::layer_lens(&paged, slot);
                    assert_eq!(
                        lens,
                        KvStore::layer_lens(&flat, slot),
                        "seed {seed}"
                    );
                    let keep: Vec<Vec<usize>> = lens
                        .iter()
                        .map(|&n| {
                            let k = rng.range(1, n.max(1));
                            rng.distinct_sorted(k.min(n), n)
                        })
                        .collect();
                    KvStore::compact(&mut paged, slot, &keep);
                    KvStore::compact(&mut flat, slot, &keep);
                }
                _ => {}
            }
            assert_staged_equal(&paged, &flat, seed, "step");
        }
    }
}

// ---------------------------------------------------------- prefix reuse

#[test]
fn prop_shared_prompt_allocates_sublinearly() {
    // N requests with an identical compressed cache must share full
    // blocks: pool usage grows only by partial-tail blocks per extra
    // request, never by the full per-request footprint.
    for (seed, mut rng) in cases(60) {
        let m = meta(&mut rng);
        let bt = rng.range(2, 5);
        let lanes = rng.range(2, 4);
        let c = 4 * bt;
        let cfg = PagingConfig {
            block_tokens: bt,
            num_blocks: None,
            prefix_cache: true,
            ..Default::default()
        };
        let mut pa = PagedArena::new(&m, lanes, c, cfg);
        // full-block-aligned lens so the entire cache is shareable
        let mut rc = rand_cache(&mut rng, &m, c, seed as f64);
        let re = m.n_kv_heads * m.head_dim;
        for l in 0..m.n_layers {
            let len = rng.range(1, 3) * bt;
            rc.k[l].resize(len * re, 0.5);
            rc.v[l].resize(len * re, -0.5);
            rc.lens[l] = len;
        }
        let s0 = KvStore::admit(&mut pa, &rc).unwrap();
        let single = pa.pool_stats().blocks_in_use;
        for _ in 1..lanes {
            KvStore::admit(&mut pa, &rc).unwrap();
        }
        let ps = pa.pool_stats();
        assert_eq!(
            ps.blocks_in_use, single,
            "seed {seed}: shared prompt duplicated blocks"
        );
        assert!(ps.prefix_hits > 0, "seed {seed}");
        let _ = s0;
    }
}

#[test]
fn prop_cache_survives_release_and_rehits() {
    // Release a request, admit the same content again: the evictable
    // blocks are revived from the prefix cache with no new allocation.
    for (seed, mut rng) in cases(60) {
        let m = meta(&mut rng);
        let bt = rng.range(2, 4);
        let cfg = PagingConfig {
            block_tokens: bt,
            num_blocks: None,
            prefix_cache: true,
            ..Default::default()
        };
        let mut pa = PagedArena::new(&m, 1, 4 * bt, cfg);
        let mut rc = rand_cache(&mut rng, &m, 4 * bt, seed as f64 + 0.5);
        let re = m.n_kv_heads * m.head_dim;
        for l in 0..m.n_layers {
            let len = 2 * bt; // aligned: fully cacheable
            rc.k[l].resize(len * re, 1.5);
            rc.v[l].resize(len * re, -1.5);
            rc.lens[l] = len;
        }
        let s = KvStore::admit(&mut pa, &rc).unwrap();
        let first = pa.stage();
        pa.release(s);
        assert_eq!(pa.pool_stats().blocks_in_use, 0, "seed {seed}");
        let hits_before = pa.pool_stats().prefix_hits;
        let s2 = KvStore::admit(&mut pa, &rc).unwrap();
        let ps = pa.pool_stats();
        assert!(ps.prefix_hits > hits_before, "seed {seed}: no rehit");
        let again = pa.stage();
        assert_eq!(first.k.data, again.k.data, "seed {seed}");
        let _ = s2;
    }
}

// ------------------------------------------------------- COW via forking

#[test]
fn prop_fork_then_divergent_appends_match_independent_lanes() {
    // fork + divergent appends must behave exactly like two independent
    // flat lanes loaded with the same cache (COW isolation).
    for (seed, mut rng) in cases(60) {
        let m = meta(&mut rng);
        let c = rng.range(8, 16);
        let cfg = PagingConfig {
            block_tokens: rng.range(2, 5),
            num_blocks: None,
            prefix_cache: rng.chance(0.5),
            ..Default::default()
        };
        let mut paged = PagedArena::new(&m, 2, c, cfg);
        let mut flat = BatchArena::new(&m, 2, c);
        let rc = rand_cache(&mut rng, &m, c - 3, seed as f64 + 9.0);
        let s0 = KvStore::admit(&mut paged, &rc).unwrap();
        let s1 = paged.fork(s0).unwrap();
        let f0 = KvStore::admit(&mut flat, &rc).unwrap();
        let f1 = KvStore::admit(&mut flat, &rc).unwrap();
        assert_eq!((s0, s1), (f0, f1), "seed {seed}");
        for _ in 0..rng.range(1, 6) {
            let step_kv = rand_step(&mut rng, &m, 2);
            let slot = if rng.chance(0.5) { s0 } else { s1 };
            let rp = KvStore::append(&mut paged, slot, &step_kv, &step_kv);
            let rf = KvStore::append(&mut flat, slot, &step_kv, &step_kv);
            assert_eq!(rp, rf, "seed {seed}");
            assert_staged_equal(&paged, &flat, seed, "fork-append");
        }
    }
}

// ------------------------------------------------ preemption under pressure

#[derive(Debug)]
struct SimReq {
    id: usize,
    cache: RequestCache,
    want: usize,
    got: usize,
}

#[test]
fn prop_preemption_resumes_and_all_requests_finish() {
    // A deliberately under-provisioned pool: requests admit only when the
    // allocator covers their budget, preempt back to the queue on
    // exhaustion (releasing blocks), and every request still finishes.
    for (seed, mut rng) in cases(40) {
        let m = meta(&mut rng);
        let bt = 2;
        let lanes = 2;
        let c = 12;
        let per_layer = 4usize; // tokens per layer at admission
        let gen = rng.range(2, 6); // decode steps per request
        // pool covers roughly one active request + slack: forces churn
        let tight = m.n_layers * ((per_layer + gen) / bt + 2);
        let cfg = PagingConfig {
            block_tokens: bt,
            num_blocks: Some(tight),
            prefix_cache: false,
            ..Default::default()
        };
        let mut pa = PagedArena::new(&m, lanes, c, cfg);
        let mut sched: Scheduler<SimReq> = Scheduler::new(lanes, AdmitOrder::Fcfs);
        let total = rng.range(3, 7);
        for id in 0..total {
            let mut rc = rand_cache(&mut rng, &m, per_layer, id as f64);
            for l in 0..m.n_layers {
                let re = m.n_kv_heads * m.head_dim;
                rc.k[l].resize(per_layer * re, 0.25);
                rc.v[l].resize(per_layer * re, -0.25);
                rc.lens[l] = per_layer;
            }
            sched.enqueue(SimReq { id, cache: rc, want: gen, got: 0 });
        }
        let mut active: Vec<(usize, SimReq)> = Vec::new();
        let mut finished = vec![false; total];
        let mut preemptions = 0usize;
        let mut steps = 0usize;
        while finished.iter().any(|f| !f) {
            steps += 1;
            assert!(steps < 10_000, "seed {seed}: livelock");
            let admit_ok = sched
                .peek_next(|r| r.cache.max_len())
                .map(|r| {
                    KvStore::can_admit(&pa, r.cache.max_len(), r.want - r.got)
                })
                .unwrap_or(true);
            match sched.next_action_mem(active.len(), admit_ok) {
                Action::Prefill => {
                    let req = sched.pop_next(|r| r.cache.max_len()).unwrap();
                    match KvStore::admit(&mut pa, &req.cache) {
                        Some(slot) => active.push((slot, req)),
                        None => {
                            assert!(
                                !active.is_empty(),
                                "seed {seed}: admit failed with idle pool"
                            );
                            sched.requeue_front(req);
                        }
                    }
                }
                Action::DecodeStep => {
                    let step_kv = rand_step(&mut rng, &m, lanes);
                    let mut idx = 0;
                    while idx < active.len() {
                        let (slot, req) = &mut active[idx];
                        match KvStore::append(&mut pa, *slot, &step_kv, &step_kv)
                        {
                            AppendResult::Ok => {
                                req.got += 1;
                                idx += 1;
                            }
                            AppendResult::CapacityExhausted => {
                                req.got = req.want; // done early
                                idx += 1;
                            }
                            AppendResult::PoolExhausted => {
                                // preempt: release blocks, requeue, resume
                                let (slot, mut req) = active.swap_remove(idx);
                                assert!(pa.release(slot), "seed {seed}");
                                // resume = re-prefill prompt+generated:
                                // simulate by carrying progress along
                                req.want -= req.got;
                                req.got = 0;
                                preemptions += 1;
                                assert!(
                                    preemptions < 1000,
                                    "seed {seed}: preemption storm"
                                );
                                sched.requeue_front(req);
                            }
                        }
                    }
                    // retire
                    let mut i = 0;
                    while i < active.len() {
                        if active[i].1.got >= active[i].1.want {
                            let (slot, req) = active.swap_remove(i);
                            assert!(pa.release(slot), "seed {seed}");
                            finished[req.id] = true;
                        } else {
                            i += 1;
                        }
                    }
                }
                Action::Idle => {
                    // queue blocked on memory with nothing active would be
                    // a livelock; the sizing above never produces it
                    assert!(
                        sched.queue_len() == 0 || !active.is_empty() || admit_ok,
                        "seed {seed}: stuck"
                    );
                }
            }
            let ps = pa.pool_stats();
            assert!(
                ps.blocks_in_use <= ps.blocks_total,
                "seed {seed}: over-allocated"
            );
        }
        assert_eq!(pa.pool_stats().blocks_in_use, 0, "seed {seed}: leak");
    }
}

// ------------------------------------------------------------- compaction

#[test]
fn prop_compaction_frees_blocks_and_preserves_survivors() {
    for (seed, mut rng) in cases(60) {
        let m = meta(&mut rng);
        let bt = rng.range(2, 4);
        let c = 6 * bt;
        let cfg = PagingConfig {
            block_tokens: bt,
            num_blocks: None,
            prefix_cache: false,
            ..Default::default()
        };
        let mut pa = PagedArena::new(&m, 1, c, cfg);
        let rc = rand_cache(&mut rng, &m, c, seed as f64 + 3.0);
        let slot = KvStore::admit(&mut pa, &rc).unwrap();
        let before = pa.stage();
        let re = m.n_kv_heads * m.head_dim;
        let keep: Vec<Vec<usize>> = rc
            .lens
            .iter()
            .map(|&n| {
                let k = rng.range(1, n);
                rng.distinct_sorted(k, n)
            })
            .collect();
        let in_use_before = pa.pool_stats().blocks_in_use;
        let released = KvStore::compact(&mut pa, slot, &keep);
        let ps = pa.pool_stats();
        assert_eq!(
            in_use_before - ps.blocks_in_use,
            released,
            "seed {seed}: release accounting"
        );
        let after = pa.stage();
        for l in 0..m.n_layers {
            assert_eq!(pa.layer_lens(slot)[l], keep[l].len(), "seed {seed}");
            for (new_row, &old_row) in keep[l].iter().enumerate() {
                let nb = ((l * 1 + 0) * c + new_row) * re;
                let ob = ((l * 1 + 0) * c + old_row) * re;
                assert_eq!(
                    &after.k.data[nb..nb + re],
                    &before.k.data[ob..ob + re],
                    "seed {seed}: survivor moved wrong (layer {l})"
                );
            }
        }
    }
}

// ----------------------------------------------- block-table decode oracle

/// Deterministic KV summary of one lane, read through the block-table
/// view. Accumulation order is row-major, matching `sums_staged`, so equal
/// KV content yields bitwise-equal f64 sums.
fn sums_view(pa: &PagedArena, slot: usize, layers: usize) -> Vec<f64> {
    let v = pa.view();
    let re = v.row_elems();
    (0..layers)
        .map(|l| {
            let mut s = 0.0f64;
            for row in 0..v.len(l, slot) {
                let kr = v.k_row(l, slot, row);
                let vr = v.v_row(l, slot, row);
                for i in 0..re {
                    s += kr[i] as f64 * (1.0 + (i % 3) as f64);
                    s += 0.5 * vr[i] as f64;
                }
            }
            s
        })
        .collect()
}

/// The same summary read from the dense staged layout (the fallback
/// decode path's view of the world).
fn sums_staged(pa: &PagedArena, slot: usize, layers: usize) -> Vec<f64> {
    let st = KvStore::stage(pa);
    let b = st.k.shape[1];
    let c = st.k.shape[2];
    let re = st.k.shape[3] * st.k.shape[4];
    (0..layers)
        .map(|l| {
            let len = st.lens.data[l * b + slot] as usize;
            let mut s = 0.0f64;
            for row in 0..len {
                let base = ((l * b + slot) * c + row) * re;
                for i in 0..re {
                    s += st.k.data[base + i] as f64 * (1.0 + (i % 3) as f64);
                    s += 0.5 * st.v.data[base + i] as f64;
                }
            }
            s
        })
        .collect()
}

/// FNV-mix a lane's decode inputs into the "model" outputs: the sampled
/// token and the per-layer appended KV row are pure functions of (current
/// token, position, KV summaries), so a divergence between the two read
/// paths becomes a diverging token stream.
fn sim_decode(cur: i32, pos: usize, sums: &[f64]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    let mut mix = |v: u64, h: &mut u64| {
        *h ^= v;
        *h = h.wrapping_mul(0x100000001b3);
    };
    mix(cur as u64, &mut h);
    mix(pos as u64, &mut h);
    for &s in sums {
        mix(s.to_bits(), &mut h);
    }
    h
}

fn sim_row(h: u64, layer: usize, re: usize) -> Vec<f32> {
    (0..re)
        .map(|i| {
            let x = h
                .wrapping_mul(6364136223846793005)
                .wrapping_add((layer * 97 + i) as u64);
            ((x >> 32) as f64 / u32::MAX as f64) as f32 - 0.5
        })
        .collect()
}

#[test]
fn prop_block_table_decode_matches_staged_decode() {
    // Two identical arenas — one decoding through the block-table view
    // (the default), one through the dense staged bridge (the fallback) —
    // are driven through the same randomized serving schedule: admissions,
    // decode appends whose content DEPENDS on the KV read back, policy
    // compactions, and preemption/resume under a tight pool. Token
    // streams and staged KV must stay identical throughout.
    for (seed, mut rng) in cases(30) {
        let m = meta(&mut rng);
        let bt = rng.range(2, 4);
        let lanes = rng.range(1, 2);
        let c = rng.range(8, 16);
        let re = m.n_kv_heads * m.head_dim;
        // tight-ish pool on half the seeds: forces the pressure paths
        let pool = if rng.chance(0.5) {
            Some(m.n_layers * lanes * ((c / 2) / bt + 2))
        } else {
            None
        };
        let mk = |dense: bool| PagingConfig {
            block_tokens: bt,
            num_blocks: pool,
            prefix_cache: false,
            dense_staging: dense,
            ..Default::default()
        };
        let mut via_view = PagedArena::new(&m, lanes, c, mk(false));
        let mut via_stage = PagedArena::new(&m, lanes, c, mk(true));

        // request id -> (cache, want); queue of pending ids
        let total = rng.range(2, 5);
        let caches: Vec<RequestCache> = (0..total)
            .map(|id| rand_cache(&mut rng, &m, c.min(6), (seed * 50 + id as u64) as f64))
            .collect();
        let wants: Vec<usize> = (0..total).map(|_| rng.range(2, 8)).collect();
        let mut queue: Vec<usize> = (0..total).collect();
        // active: (req id, slot, cur token, pos, got)
        let mut active: Vec<(usize, usize, i32, usize, usize)> = Vec::new();
        let mut streams: Vec<Vec<i32>> = vec![Vec::new(); total];
        let mut done = vec![false; total];
        let mut steps = 0usize;
        while done.iter().any(|d| !d) {
            steps += 1;
            assert!(steps < 5_000, "seed {seed}: livelock");
            // admit while a lane is free and the pool covers the head
            while !queue.is_empty()
                && KvStore::free_slots(&via_view) > 0
                && KvStore::can_admit(
                    &via_view,
                    caches[queue[0]].max_len(),
                    wants[queue[0]],
                )
            {
                let id = queue.remove(0);
                let sa = KvStore::admit(&mut via_view, &caches[id]);
                let sb = KvStore::admit(&mut via_stage, &caches[id]);
                assert_eq!(sa, sb, "seed {seed}: admission diverged");
                match sa {
                    Some(slot) => {
                        active.push((id, slot, (id as i32) + 1, 0, 0))
                    }
                    None => {
                        queue.insert(0, id);
                        break;
                    }
                }
            }
            if active.is_empty() {
                // nothing admitted and queue non-empty would be a sizing
                // bug in the test itself
                assert!(
                    !queue.is_empty(),
                    "seed {seed}: no work but requests unfinished"
                );
                // head request can never fit a drained pool: count it done
                let id = queue.remove(0);
                done[id] = true;
                continue;
            }
            // one lockstep decode step over the active lanes
            let mut k_new_a = HostTensor::zeros(vec![
                m.n_layers, lanes, m.n_kv_heads, m.head_dim,
            ]);
            let mut v_new_a = k_new_a.clone();
            let mut k_new_b = k_new_a.clone();
            let mut v_new_b = k_new_a.clone();
            let mut nexts: Vec<i32> = Vec::with_capacity(active.len());
            for &(_id, slot, cur, pos, _) in &active {
                let sa = sums_view(&via_view, slot, m.n_layers);
                let sb = sums_staged(&via_stage, slot, m.n_layers);
                assert_eq!(sa, sb, "seed {seed}: KV read paths diverged");
                let ha = sim_decode(cur, pos, &sa);
                let hb = sim_decode(cur, pos, &sb);
                assert_eq!(ha, hb, "seed {seed}");
                for l in 0..m.n_layers {
                    let row = sim_row(ha, l, re);
                    let neg: Vec<f32> = row.iter().map(|x| -x).collect();
                    let base_a = (l * lanes + slot) * re;
                    k_new_a.data[base_a..base_a + re].copy_from_slice(&row);
                    v_new_a.data[base_a..base_a + re].copy_from_slice(&neg);
                }
                nexts.push((ha % 251) as i32 + 1);
            }
            k_new_b.data.copy_from_slice(&k_new_a.data);
            v_new_b.data.copy_from_slice(&v_new_a.data);

            let mut i = 0;
            while i < active.len() {
                let (id, slot, _cur, pos, got) = active[i];
                let ra = KvStore::append(&mut via_view, slot, &k_new_a, &v_new_a);
                let rb = KvStore::append(&mut via_stage, slot, &k_new_b, &v_new_b);
                assert_eq!(ra, rb, "seed {seed}: append result diverged");
                match ra {
                    AppendResult::Ok => {
                        let next = nexts[i];
                        streams[id].push(next);
                        active[i] = (id, slot, next, pos + 1, got + 1);
                        if got + 1 >= wants[id] {
                            assert!(via_view.release(slot));
                            assert!(via_stage.release(slot));
                            done[id] = true;
                            active.remove(i);
                            nexts.remove(i);
                        } else {
                            i += 1;
                        }
                    }
                    AppendResult::CapacityExhausted => {
                        assert!(via_view.release(slot));
                        assert!(via_stage.release(slot));
                        done[id] = true;
                        active.remove(i);
                        nexts.remove(i);
                    }
                    AppendResult::PoolExhausted => {
                        // policy compaction first, preempt if it frees
                        // nothing (release + requeue + resume later)
                        let lens = KvStore::layer_lens(&via_view, slot);
                        assert_eq!(
                            lens,
                            KvStore::layer_lens(&via_stage, slot),
                            "seed {seed}"
                        );
                        let keep: Vec<Vec<usize>> = lens
                            .iter()
                            .map(|&n| (0..n / 2).collect())
                            .collect();
                        let fa = KvStore::compact(&mut via_view, slot, &keep);
                        let fb = KvStore::compact(&mut via_stage, slot, &keep);
                        assert_eq!(fa, fb, "seed {seed}: compact diverged");
                        if fa == 0 {
                            assert!(via_view.release(slot));
                            assert!(via_stage.release(slot));
                            queue.insert(0, id);
                            active.remove(i);
                            nexts.remove(i);
                        }
                        // if compaction freed blocks, retry this lane on
                        // the next iteration (i unchanged)
                    }
                }
                assert_staged_equal(&via_view, &via_stage, seed, "decode");
            }
        }
        // final oracle: both stores drained identically and the schedule
        // actually generated tokens
        assert_staged_equal(&via_view, &via_stage, seed, "final");
        assert_eq!(
            via_view.pool_stats().blocks_in_use,
            via_stage.pool_stats().blocks_in_use,
            "seed {seed}"
        );
        let produced: usize = streams.iter().map(|s| s.len()).sum();
        assert!(produced > 0, "seed {seed}: nothing generated");
    }
}

// ------------------------------------------------------------ swap-to-host

use std::collections::HashMap;

use fastkv::coordinator::server::{
    admit, can_resume_parts, preempt, resume_admit_state, try_resume,
    AdmitFail, Request, Resume,
};
use fastkv::metrics::{names, Metrics};
use fastkv::tokenizer::END;

// Serve-lifecycle sim harness shared with `tests/obs.rs` (deterministic
// stand-in model, `run_stack*` differential drivers, `lane_rows`).
#[path = "common/sim.rs"]
mod sim;
use sim::*;

#[test]
fn prop_swap_roundtrip_preserves_selected_kv_across_churn() {
    // The tentpole invariant: a swapped-out lane — including decode
    // appends and FastKV compactions that recompute-resume could never
    // reproduce — restores bit-identically after arbitrary churn on the
    // rest of the pool (appends, admissions, releases, compactions).
    for (seed, mut rng) in cases(60) {
        let m = meta(&mut rng);
        let bt = rng.range(2, 4);
        let lanes = 3;
        let c = rng.range(8, 16);
        let cfg = PagingConfig {
            block_tokens: bt,
            num_blocks: None,
            prefix_cache: rng.chance(0.5),
            swap_bytes: 64 << 20,
            ..Default::default()
        };
        let mut pa = PagedArena::new(&m, lanes, c, cfg);
        let rc = rand_cache(&mut rng, &m, c.min(8), seed as f64 + 0.25);
        let victim = KvStore::admit(&mut pa, &rc).unwrap();
        let mut others: Vec<usize> = Vec::new();
        if rng.chance(0.7) {
            let orc = rand_cache(&mut rng, &m, c.min(6), seed as f64 + 0.5);
            others.push(KvStore::admit(&mut pa, &orc).unwrap());
        }
        for _ in 0..rng.range(0, 4) {
            let step = rand_step(&mut rng, &m, lanes);
            let _ = KvStore::append(&mut pa, victim, &step, &step);
        }
        if rng.chance(0.5) {
            // Compact the victim first: the swapped entry must preserve
            // the *compacted* selection — exactly the state a re-run
            // policy prefill would not reproduce.
            let lens = KvStore::layer_lens(&pa, victim);
            let keep: Vec<Vec<usize>> = lens
                .iter()
                .map(|&n| {
                    let k = rng.range(1, n.max(1));
                    rng.distinct_sorted(k.min(n), n)
                })
                .collect();
            KvStore::compact(&mut pa, victim, &keep);
        }
        let expect_lens = KvStore::layer_lens(&pa, victim);
        let expect = lane_rows(&pa, victim, m.n_layers);
        let total = pa.pool_stats().blocks_total;

        let h = pa.swap_out(victim).expect("budget covers one lane");

        for step_i in 0..rng.range(0, 8) {
            match rng.below(3) {
                0 => {
                    let step = rand_step(&mut rng, &m, lanes);
                    for &s in &others {
                        let _ = KvStore::append(&mut pa, s, &step, &step);
                    }
                }
                1 => {
                    let rc2 = rand_cache(
                        &mut rng,
                        &m,
                        c.min(6),
                        seed as f64 + 10.0 + step_i as f64,
                    );
                    if let Some(s) = KvStore::admit(&mut pa, &rc2) {
                        if rng.chance(0.6) {
                            pa.release(s);
                        } else {
                            others.push(s);
                        }
                    }
                }
                _ => {
                    if let Some(&s) = others.first() {
                        let lens = KvStore::layer_lens(&pa, s);
                        let keep: Vec<Vec<usize>> = lens
                            .iter()
                            .map(|&n| (0..(n + 1) / 2).collect())
                            .collect();
                        KvStore::compact(&mut pa, s, &keep);
                    }
                }
            }
            let ps = pa.pool_stats();
            assert_eq!(
                ps.blocks_in_use + ps.blocks_cached + ps.blocks_free,
                total,
                "seed {seed}: accounting while lane parked"
            );
        }

        let mut res = pa.swap_in(h);
        while res == SwapIn::Busy {
            // churn filled every lane: free one and retry (the serving
            // loop would wait for decode to retire one instead)
            let s = others.pop().unwrap_or_else(|| {
                panic!("seed {seed}: swap-in busy with no lane to free")
            });
            pa.release(s);
            res = pa.swap_in(h);
        }
        let slot = match res {
            SwapIn::Restored(s) => s,
            other => panic!("seed {seed}: expected restore, got {other:?}"),
        };
        assert_eq!(
            KvStore::layer_lens(&pa, slot),
            expect_lens,
            "seed {seed}: restored lens"
        );
        assert_eq!(
            lane_rows(&pa, slot, m.n_layers),
            expect,
            "seed {seed}: swapped-in KV differs from the pre-preemption \
             selection"
        );
        let ps = pa.pool_stats();
        assert_eq!(
            ps.blocks_in_use + ps.blocks_cached + ps.blocks_free,
            total,
            "seed {seed}: accounting after restore"
        );
        assert_eq!(
            pa.swap_stats().used_bytes,
            0,
            "seed {seed}: entry bytes freed on restore"
        );
    }
}

#[test]
fn swap_budget_drop_oldest_forces_recompute_fallback() {
    // Budget fits one swapped lane (plus slack): the second swap-out
    // drops the first entry, whose owner must then recompute-resume.
    let m = sim_meta();
    let re = m.n_kv_heads * m.head_dim;
    let len = 4usize;
    let bytes_one = m.n_layers * len * re * 2 * std::mem::size_of::<f32>();
    let cfg = PagingConfig {
        block_tokens: 2,
        prefix_cache: false,
        swap_bytes: bytes_one + bytes_one / 2,
        ..Default::default()
    };
    let mut pa = PagedArena::new(&m, 2, 16, cfg);
    let mk_cache = |tag: f32| {
        let mut rc = RequestCache::new(&m);
        for l in 0..m.n_layers {
            rc.k[l] = (0..len * re).map(|i| tag + i as f32).collect();
            rc.v[l] = (0..len * re).map(|i| -(tag + i as f32)).collect();
            rc.lens[l] = len;
        }
        rc
    };
    let s0 = KvStore::admit(&mut pa, &mk_cache(100.0)).unwrap();
    let s1 = KvStore::admit(&mut pa, &mk_cache(200.0)).unwrap();
    let h0 = pa.swap_out(s0).unwrap();
    let h1 = pa.swap_out(s1).unwrap();
    assert!(!pa.swap_contains(h0), "oldest entry dropped under pressure");
    assert!(pa.swap_contains(h1));
    assert_eq!(pa.swap_stats().dropped, 1);
    assert_eq!(pa.swap_in(h0), SwapIn::Gone, "dropped handle is gone");
    match pa.swap_in(h1) {
        SwapIn::Restored(s) => assert_eq!(KvStore::layer_lens(&pa, s), vec![len; m.n_layers]),
        other => panic!("expected restore, got {other:?}"),
    }
}

// ------------------------------------------- server-level swap machinery

#[test]
fn swapped_resume_matches_recompute_resume_end_to_end() {
    // The differential oracle of the acceptance criteria: the swap stack
    // and the recompute stack must produce identical token streams and
    // identical final KV per request — while the swap stack performs
    // ZERO policy prefill calls on resume.
    let prompts: Vec<Vec<i32>> =
        vec![vec![10, 11, 12], vec![20, 21, 22, 23], vec![30, 31]];
    let max_new = 5;
    let n = prompts.len();
    let swapped = run_stack(128 << 20, &prompts, max_new, 2);
    let recompute = run_stack(0, &prompts, max_new, 2);
    for id in 0..n as u64 {
        assert_eq!(
            swapped.streams[&id], recompute.streams[&id],
            "token stream diverged for request {id}"
        );
        assert_eq!(swapped.streams[&id].len(), max_new);
        assert_eq!(
            swapped.final_rows[&id], recompute.final_rows[&id],
            "final KV diverged for request {id}"
        );
    }
    // prefill accounting: swap resumes are free, recompute pays again
    assert_eq!(
        swapped.policy_calls, n,
        "swap path must not prefill on resume"
    );
    assert_eq!(
        recompute.policy_calls,
        2 * n,
        "recompute path re-prefills every preempted request"
    );
    assert_eq!(swapped.metrics.counter(names::PREFILL_RECOMPUTED), 0);
    assert_eq!(
        recompute.metrics.counter(names::PREFILL_RECOMPUTED),
        n as u64
    );
    assert_eq!(swapped.metrics.counter(names::SWAP_OUTS), n as u64);
    assert_eq!(swapped.metrics.counter(names::SWAP_INS), n as u64);
    assert_eq!(swapped.metrics.counter("preempted"), n as u64);
    assert_eq!(recompute.metrics.counter(names::SWAP_REFUSED), n as u64);
}

#[test]
fn deferred_admission_carries_prefill_and_never_recomputes() {
    let m = sim_meta();
    let man = sim_manifest(64);
    let policy = SimPolicy::new();
    let metrics = Metrics::default();
    let cfg = sim_server_cfg(32, 4);
    let pcfg = PagingConfig {
        block_tokens: 2,
        prefix_cache: false,
        swap_bytes: 0,
        ..Default::default()
    };
    // a single lane, so the second admission must defer
    let mut pa = PagedArena::new(&m, 1, 32, pcfg);
    let (r0, _rx0) = Request::synthetic(0, vec![5, 6, 7], 4);
    let a0 = match admit(&NoExec, &man, &policy, &cfg, r0, &mut pa, &metrics) {
        Ok(a) => a,
        Err(_) => panic!("first admission must succeed"),
    };
    assert_eq!(policy.calls(), 1);
    let (r1, _rx1) = Request::synthetic(1, vec![8, 9], 4);
    let deferred =
        match admit(&NoExec, &man, &policy, &cfg, r1, &mut pa, &metrics) {
            Err(AdmitFail::Defer(r)) => r,
            _ => panic!("expected deferral with no free lane"),
        };
    assert_eq!(policy.calls(), 2, "deferral happens after the prefill");
    // a retry while the pool is still full must re-attempt admission
    // only, not the prefill
    let deferred =
        match admit(&NoExec, &man, &policy, &cfg, deferred, &mut pa, &metrics) {
            Err(AdmitFail::Defer(r)) => r,
            _ => panic!("still no free lane"),
        };
    assert_eq!(policy.calls(), 2, "deferral retry re-ran the prefill");
    // the lane frees; the carried prefill admits without policy work
    pa.release(a0.slot());
    let a1 =
        match admit(&NoExec, &man, &policy, &cfg, deferred, &mut pa, &metrics) {
            Ok(a) => a,
            _ => panic!("admission must succeed with a free lane"),
        };
    assert_eq!(policy.calls(), 2, "carried prefill was recomputed");
    assert_eq!(
        metrics.counter(names::PREFILL_RECOMPUTED),
        0,
        "double-prefill-per-deferral regression"
    );
    assert_eq!(a1.tokens().len(), 1);
}

#[test]
fn resume_admit_edge_cases() {
    // END as the first token of a resumed request: finished, END recorded
    let (toks, done) = resume_admit_state(&[7, 8], END as i32, 10);
    assert!(done);
    assert_eq!(toks, vec![7, 8, END as i32]);
    // resume landing exactly at max_new: no extra token may be emitted
    let (toks, done) = resume_admit_state(&[4, 5, 6], 9, 3);
    assert!(done);
    assert_eq!(toks, vec![4, 5, 6], "resumed request emitted past max_new");
    // max_new == 0: nothing generated (and no cache growth implied, which
    // is what lets `can_admit` reserve zero headroom for it)
    let (toks, done) = resume_admit_state(&[], 9, 0);
    assert!(done);
    assert!(toks.is_empty());
    // normal continuation
    let (toks, done) = resume_admit_state(&[4], 9, 3);
    assert!(!done);
    assert_eq!(toks, vec![4, 9]);
}

#[test]
fn preempting_fully_generated_lane_finishes_without_extra_token() {
    let m = sim_meta();
    let man = sim_manifest(64);
    let policy = SimPolicy::new();
    let metrics = Metrics::default();
    let max_new = 3;
    let cfg = sim_server_cfg(32, max_new);
    let pcfg = PagingConfig { block_tokens: 2, ..Default::default() };
    let mut pa = PagedArena::new(&m, 1, 32, pcfg);
    let prompts: HashMap<u64, Vec<i32>> =
        [(0u64, vec![5, 6, 7])].into_iter().collect();
    let (req, rx) = Request::synthetic(0, vec![5, 6, 7], max_new);
    let a = match admit(&NoExec, &man, &policy, &cfg, req, &mut pa, &metrics) {
        Ok(a) => a,
        Err(_) => panic!("admit"),
    };
    let mut active = vec![a];
    // decode until the token budget is spent but the lane has not been
    // retired yet (the window where the old code double-charged)
    while active[0].tokens().len() < max_new {
        sim_decode_round(&mut pa, &mut active, &prompts, &cfg, &metrics);
    }
    let mut sched: Scheduler<Request> = Scheduler::new(1, AdmitOrder::Fcfs);
    preempt(&mut active, 0, &mut pa, &mut sched, &metrics);
    assert!(active.is_empty());
    assert_eq!(
        sched.queue_len(),
        0,
        "fully generated lane must not be parked for resume"
    );
    let resp = rx.try_recv().expect("finished response");
    assert!(resp.error.is_none());
    assert_eq!(
        resp.tokens.len(),
        max_new,
        "extra token emitted past max_new"
    );
    assert_eq!(pa.pool_stats().blocks_in_use, 0, "lane released");
    assert_eq!(metrics.counter("preempted"), 0, "finish, not preemption");
    assert_eq!(policy.calls(), 1, "no resume prefill for a finished lane");
}

#[test]
fn end_as_first_resumed_token_finishes_at_admission() {
    let m = sim_meta();
    let man = sim_manifest(64);
    // emit END once the re-prefilled sequence reaches 5 tokens
    let policy = SimPolicy::ending_after(5);
    let metrics = Metrics::default();
    let cfg = sim_server_cfg(32, 8);
    let pcfg = PagingConfig {
        block_tokens: 2,
        prefix_cache: false,
        swap_bytes: 0, // force the recompute-resume path
        ..Default::default()
    };
    let mut pa = PagedArena::new(&m, 1, 32, pcfg);
    let prompts: HashMap<u64, Vec<i32>> =
        [(0u64, vec![5, 6, 7])].into_iter().collect();
    let (req, _rx) = Request::synthetic(0, vec![5, 6, 7], 8);
    let a = match admit(&NoExec, &man, &policy, &cfg, req, &mut pa, &metrics) {
        Ok(a) => a,
        Err(_) => panic!("admit"),
    };
    let mut active = vec![a];
    sim_decode_round(&mut pa, &mut active, &prompts, &cfg, &metrics); // 2 tokens now
    let mut sched: Scheduler<Request> = Scheduler::new(1, AdmitOrder::Fcfs);
    preempt(&mut active, 0, &mut pa, &mut sched, &metrics);
    assert_eq!(metrics.counter(names::SWAP_REFUSED), 1, "swap disabled");
    let req = sched.pop_next(|r| r.prompt.len()).unwrap();
    let req = match try_resume(req, &mut pa, &metrics) {
        Resume::Recompute(r) => r,
        _ => panic!("no swap entry to restore"),
    };
    // re-prefill sees 3 prompt + 2 generated = 5 tokens -> END
    let a = match admit(&NoExec, &man, &policy, &cfg, req, &mut pa, &metrics) {
        Ok(a) => a,
        Err(_) => panic!("resume admission"),
    };
    assert!(a.is_done(), "END on resume must finish at admission");
    assert_eq!(*a.tokens().last().unwrap(), END as i32);
    assert_eq!(a.tokens().len(), 3, "2 resumed tokens + END");
    assert_eq!(metrics.counter(names::PREFILL_RECOMPUTED), 1);
}

#[test]
fn can_resume_skips_lanes_beyond_prefill_limit_or_pool() {
    let m = sim_meta();
    let pcfg = PagingConfig {
        block_tokens: 2,
        num_blocks: Some(8),
        ..Default::default()
    };
    let mut pa = PagedArena::new(&m, 1, 8, pcfg);
    let t = TenantId::DEFAULT;
    // within the prefill bucket and pool: a valid victim
    assert!(can_resume_parts(10, 16, 4, t, &pa));
    // re-prefill would exceed the prefill bucket: never preempt this lane
    assert!(!can_resume_parts(17, 16, 4, t, &pa));
    // per-layer budget beyond lane capacity: could never re-admit
    assert!(!can_resume_parts(10, 16, 9, t, &pa));
    // budget that fits the lane but not the whole pool even when drained
    assert!(!can_resume_parts(10, 16, 7, t, &pa));
    // a ceiling below the pool shrinks what the tenant could ever retake
    let capped = TenantId(5);
    pa.set_tenant_quota(capped, TenantQuota::bounded(0, 4));
    assert!(!can_resume_parts(10, 16, 4, capped, &pa), "ceiling-aware");
    assert!(can_resume_parts(10, 16, 4, t, &pa), "others unaffected");
}

#[test]
fn evictable_queue_bounded_under_prefix_churn() {
    // Regression for the unbounded-stale-entries bug: a churny
    // prefix-hit workload (park + revive over and over) must keep the
    // allocator's evictable queue at or below one entry per block.
    let mut a = BlockAllocator::new(8, 4, 2);
    let t = TenantId::DEFAULT;
    let ids: Vec<_> = (0..4)
        .map(|i| {
            let b = a.alloc(t).unwrap().id;
            a.seal(b, 100 + i);
            b
        })
        .collect();
    for round in 0..200 {
        for &b in &ids {
            a.decref(b);
        }
        for &b in &ids {
            assert_eq!(a.revive(b, t), Revive::Revived, "round {round}");
        }
        assert!(
            a.evictable_len() <= a.blocks_total(),
            "round {round}: queue grew to {} entries for {} blocks",
            a.evictable_len(),
            a.blocks_total()
        );
    }
    // the sweep drops the (now all stale) survivors outright
    a.sweep_stale();
    assert_eq!(a.evictable_len(), 0);
    // and normal park/evict still works afterwards
    for &b in &ids {
        a.decref(b);
    }
    assert_eq!(a.evictable_len(), 4);
    assert_eq!(a.blocks_cached(), 4);
}

// --------------------------------------------------------- multi-tenant

const HEAVY: TenantId = TenantId(0);
const LIGHT: TenantId = TenantId(1);

/// Fixed-length cache with per-tenant-distinct content (so cross-tenant
/// admissions share blocks only when the content really matches).
fn tenant_cache(m: &ModelMeta, len: usize, tag: f32) -> RequestCache {
    let re = m.n_kv_heads * m.head_dim;
    let mut rc = RequestCache::new(m);
    for l in 0..m.n_layers {
        rc.k[l] = (0..len * re).map(|i| tag + (l * 977 + i) as f32).collect();
        rc.v[l] = rc.k[l].iter().map(|x| -x).collect();
        rc.lens[l] = len;
    }
    rc
}

/// Σ per-tenant charges must equal the pool's in-use gauge — published
/// exactly as the server does (TenantStats rows → `tenant_{id}_*`
/// gauges) and then read back against `BlockAllocator` accounting.
fn assert_tenant_gauges_reconcile(pa: &PagedArena, metrics: &Metrics) {
    let ps = pa.pool_stats();
    let ts = pa.tenant_stats();
    for row in &ts {
        metrics.set_gauge(
            &names::tenant_blocks_held(row.tenant),
            row.held_blocks as f64,
        );
    }
    metrics.set_gauge("pool_blocks_in_use", ps.blocks_in_use as f64);
    let held_sum: f64 = ts
        .iter()
        .map(|row| metrics.gauge(&names::tenant_blocks_held(row.tenant)))
        .sum();
    assert_eq!(
        held_sum, ps.blocks_in_use as f64,
        "per-tenant gauges vs pool accounting"
    );
}

#[test]
fn over_quota_admission_deferred_while_under_quota_admits() {
    // The heavy tenant saturates everything outside the light tenant's
    // reserved floor; its next admission is deferred (admit -> None)
    // while the light tenant's request, arriving LATER in the queue,
    // still admits — the fair-admission scan plus the floor at work.
    let m = sim_meta();
    let pcfg = PagingConfig {
        block_tokens: 2,
        num_blocks: Some(10),
        prefix_cache: false,
        swap_bytes: 0,
        tenant_quotas: vec![(LIGHT, TenantQuota::reserved(4))],
        ..Default::default()
    };
    let mut pa = PagedArena::new(&m, 4, 16, pcfg);
    // heavy request: 2 layers x ceil(4/2) = 4 blocks (+ l growth headroom
    // at the gate); light request: 2 blocks (+ headroom)
    let heavy_rc = tenant_cache(&m, 4, 1000.0);
    let light_rc = tenant_cache(&m, 2, 2000.0);
    assert!(pa.can_admit_for(4, 4, HEAVY));
    let h1 = pa.admit_for(&heavy_rc, HEAVY).unwrap();
    // heavy again: would need 4 + 2 headroom = 6 of available_to(HEAVY)
    // = (10 - 4 held) - 4 floor = 2 -> gated out AND the load itself
    // rolls back
    assert!(!pa.can_admit_for(4, 4, HEAVY), "floor gates the gate");
    assert!(pa.admit_for(&heavy_rc, HEAVY).is_none(), "load rolls back");
    assert!(pa.pool_stats().quota_denials > 0);
    // the light tenant still fits inside its floor
    assert!(pa.can_admit_for(2, 4, LIGHT));
    let l1 = pa.admit_for(&light_rc, LIGHT).unwrap();
    // fair admission: with [heavy, light] queued, the scheduler skips the
    // quota-blocked heavy head and hands back the light request
    let mut sched: Scheduler<(TenantId, usize)> =
        Scheduler::new(4, AdmitOrder::Fcfs);
    sched.enqueue((HEAVY, 4));
    sched.enqueue((LIGHT, 2));
    let popped = sched.pop_admissible(
        |&(_, n)| n,
        |&(t, n)| pa.can_admit_for(n, 4, t),
    );
    assert_eq!(popped, Some((LIGHT, 2)));
    assert_eq!(sched.queue_len(), 1, "heavy stays queued, not dropped");
    pa.release(h1);
    pa.release(l1);
}

#[test]
fn cross_tenant_shared_prefix_charges_once_and_never_double_frees() {
    // Two tenants admit the same content: full blocks are shared, the
    // charge stays with the first toucher, and releasing both lanes (in
    // either order) plus evicting the cached blocks afterwards must keep
    // pool accounting exact — no double-free, no leaked charge.
    for heavy_first in [true, false] {
        let m = sim_meta();
        let pcfg = PagingConfig {
            block_tokens: 2,
            num_blocks: Some(8),
            swap_bytes: 0,
            tenant_quotas: vec![
                (HEAVY, TenantQuota::reserved(2)),
                (LIGHT, TenantQuota::reserved(2)),
            ],
            ..Default::default()
        };
        let mut pa = PagedArena::new(&m, 2, 8, pcfg);
        let rc = tenant_cache(&m, 4, 3000.0);
        let s0 = pa.admit_for(&rc, HEAVY).unwrap();
        let in_use_one = pa.pool_stats().blocks_in_use;
        let s1 = pa.admit_for(&rc, LIGHT).unwrap();
        let ps = pa.pool_stats();
        assert_eq!(
            ps.blocks_in_use, in_use_one,
            "identical content: the second tenant allocates nothing"
        );
        assert!(ps.prefix_hits >= 4, "hits {}", ps.prefix_hits);
        // first-toucher: the sharer is not charged
        let ts = pa.tenant_stats();
        let held = |t: TenantId| {
            ts.iter().find(|r| r.tenant == t).map_or(0, |r| r.held_blocks)
        };
        assert_eq!(held(HEAVY), in_use_one);
        assert_eq!(held(LIGHT), 0, "prefix sharer rides free");
        assert_tenant_gauges_reconcile(&pa, &Metrics::default());
        // release in both orders; blocks must come back exactly once
        let (first, second) = if heavy_first { (s0, s1) } else { (s1, s0) };
        assert!(pa.release(first));
        assert_tenant_gauges_reconcile(&pa, &Metrics::default());
        assert!(pa.release(second));
        let ps = pa.pool_stats();
        assert_eq!(ps.blocks_in_use, 0, "all shared blocks released once");
        assert_eq!(
            ps.blocks_cached + ps.blocks_free,
            ps.blocks_total,
            "heavy_first={heavy_first}: accounting intact after teardown"
        );
        assert_tenant_gauges_reconcile(&pa, &Metrics::default());
        // drain everything HEAVY may take (pool minus LIGHT's floor) so
        // cached shared blocks get evicted — a double-parked block would
        // surface as a duplicate eviction here
        let filler = tenant_cache(&m, 6, 4000.0);
        let f = pa.admit_for(&filler, HEAVY).unwrap();
        let ps = pa.pool_stats();
        assert_eq!(ps.blocks_in_use, 6);
        assert!(ps.evictions >= 2, "sealed shared blocks evicted once each");
        pa.release(f);
    }
}

#[test]
fn quota_preferred_victim_over_least_progress() {
    // The server's victim key is (tenant_over_quota, progress, held):
    // a lane of a tenant bursting past its floor is preempted before a
    // least-progress lane of a tenant inside its floor.
    let m = sim_meta();
    let pcfg = PagingConfig {
        block_tokens: 2,
        num_blocks: Some(12),
        prefix_cache: false,
        swap_bytes: 0,
        tenant_quotas: vec![
            (HEAVY, TenantQuota::reserved(4)),
            (LIGHT, TenantQuota::reserved(4)),
        ],
        ..Default::default()
    };
    let mut pa = PagedArena::new(&m, 2, 16, pcfg);
    // heavy holds 6 > floor 4 (bursting); light holds 4 = floor
    let hs = pa.admit_for(&tenant_cache(&m, 6, 5000.0), HEAVY).unwrap();
    let ls = pa.admit_for(&tenant_cache(&m, 4, 6000.0), LIGHT).unwrap();
    assert!(pa.tenant_over_quota(HEAVY));
    assert!(!pa.tenant_over_quota(LIGHT));
    // heavy has MORE progress (10 tokens vs 1) — pre-quota ordering would
    // pick the light lane; quota-aware ordering picks the burster
    let keys = vec![
        (
            pa.tenant_over_quota(pa.tenant_of(hs)),
            10,
            KvStore::held_blocks(&pa, hs),
        ),
        (
            pa.tenant_over_quota(pa.tenant_of(ls)),
            1,
            KvStore::held_blocks(&pa, ls),
        ),
    ];
    assert_eq!(pick_preemption_victim(&keys), Some(0));
    // without quotas the same shapes fall back to least-progress
    assert_eq!(
        pick_preemption_victim(&[(false, 10, 6), (false, 1, 4)]),
        Some(1)
    );
}

#[test]
fn per_tenant_swap_refusal_falls_back_to_recompute_for_that_tenant_only() {
    // HEAVY's quota pins its swap bytes to 0: preempting its lane refuses
    // the swap-out (lane intact, recompute path) while LIGHT's lane still
    // swaps under the arena-wide budget.
    let m = sim_meta();
    let pcfg = PagingConfig {
        block_tokens: 2,
        prefix_cache: false,
        swap_bytes: 1 << 20,
        tenant_quotas: vec![(
            HEAVY,
            TenantQuota { swap_bytes: Some(0), ..TenantQuota::default() },
        )],
        ..Default::default()
    };
    let mut pa = PagedArena::new(&m, 2, 16, pcfg);
    let hs = pa.admit_for(&tenant_cache(&m, 4, 7000.0), HEAVY).unwrap();
    let ls = pa.admit_for(&tenant_cache(&m, 4, 8000.0), LIGHT).unwrap();
    assert!(pa.swap_out(hs).is_none(), "tenant swap budget 0 refuses");
    assert_eq!(pa.layer_lens(hs), vec![4, 4], "refused lane left intact");
    assert_eq!(pa.swap_stats().refused, 1);
    let h = pa.swap_out(ls).expect("other tenant swaps normally");
    assert!(pa.swap_contains(h));
    match pa.swap_in(h) {
        SwapIn::Restored(s) => {
            assert_eq!(pa.layer_lens(s), vec![4, 4]);
            // free the lane again so the server-path check below has room
            assert!(pa.release(s));
        }
        other => panic!("expected restore, got {other:?}"),
    }
    // and through the server's preempt ladder: HEAVY's request parks
    // without a swap ticket (recompute-resume), counted as refused
    let metrics = Metrics::default();
    let mut sched: Scheduler<Request> = Scheduler::new(2, AdmitOrder::Fcfs);
    let (req, _rx) = Request::synthetic_for(9, vec![5, 6, 7], 8, HEAVY);
    let man = sim_manifest(64);
    let cfg = sim_server_cfg(32, 8);
    let policy = SimPolicy::new();
    let a = match admit(&NoExec, &man, &policy, &cfg, req, &mut pa, &metrics)
    {
        Ok(a) => a,
        Err(_) => panic!("admission must succeed"),
    };
    assert_eq!(a.tenant(), HEAVY);
    let mut active = vec![a];
    preempt(&mut active, 0, &mut pa, &mut sched, &metrics);
    assert_eq!(metrics.counter(names::SWAP_REFUSED), 1);
    assert_eq!(metrics.counter(&names::tenant_preempted(HEAVY)), 1);
    let parked = sched.pop_next(|r| r.prompt.len()).unwrap();
    assert!(
        parked.swap_resume().is_none(),
        "no swap ticket: recompute-resume for this tenant only"
    );
}

/// One sim "round" outcome for the starvation differential below.
struct TenantRunOutcome {
    light_admit_rounds: Vec<usize>,
    light_completed: usize,
    light_deferred_rounds: usize,
    heavy_completed: usize,
}

/// Drive a serve-shaped admission loop (fair scheduler scan + tenant
/// admission gate + real `server::admit`) over a contended pool. Heavy
/// offers 6 requests of 4 tokens (held for 4 rounds each); light offers
/// 2 requests of 2 tokens (held for 1 round). Returns when everything
/// completed.
fn run_tenant_contention(light_floor: usize) -> TenantRunOutcome {
    let m = sim_meta();
    let man = sim_manifest(64);
    let policy = SimPolicy::new();
    let metrics = Metrics::default();
    let cfg = sim_server_cfg(32, 8);
    let mut pcfg = PagingConfig {
        block_tokens: 2,
        num_blocks: Some(10),
        prefix_cache: false,
        swap_bytes: 0,
        ..Default::default()
    };
    if light_floor > 0 {
        pcfg.tenant_quotas = vec![(LIGHT, TenantQuota::reserved(light_floor))];
    }
    let mut pa = PagedArena::new(&m, 4, 16, pcfg);
    let mut sched: Scheduler<Request> = Scheduler::new(4, AdmitOrder::Fcfs);
    let mut rxs = Vec::new();
    // heavy requests first in the queue (worst case for the light tenant)
    for i in 0..6u64 {
        let (req, rx) =
            Request::synthetic_for(i, vec![10 + i as i32; 4], 8, HEAVY);
        rxs.push(rx);
        sched.enqueue(req);
    }
    for i in 6..8u64 {
        let (req, rx) =
            Request::synthetic_for(i, vec![60 + i as i32; 2], 8, LIGHT);
        rxs.push(rx);
        sched.enqueue(req);
    }
    // (request id, slot, rounds left to hold the lane)
    let mut active: Vec<(u64, usize, usize, TenantId)> = Vec::new();
    let mut out = TenantRunOutcome {
        light_admit_rounds: Vec::new(),
        light_completed: 0,
        light_deferred_rounds: 0,
        heavy_completed: 0,
    };
    let gauges = Metrics::default();
    let mut round = 0usize;
    while sched.queue_len() > 0 || !active.is_empty() {
        assert!(round < 100, "contention loop livelocked");
        // admission phase: fair scan with the tenant-aware gate
        loop {
            let popped = sched.pop_admissible(
                |r| r.prompt.len(),
                |r| {
                    active.len() < 4
                        && pa.can_admit_for(r.prompt.len(), r.max_new, r.tenant)
                },
            );
            let Some(req) = popped else { break };
            let tenant = req.tenant;
            let a = match admit(
                &NoExec, &man, &policy, &cfg, req, &mut pa, &metrics,
            ) {
                Ok(a) => a,
                Err(_) => panic!("gated admission must not fail"),
            };
            if tenant == LIGHT {
                out.light_admit_rounds.push(round);
            }
            let hold = if tenant == HEAVY { 4 } else { 1 };
            active.push((a.request_id(), a.slot(), hold, tenant));
        }
        // a queued light request that could not admit this round is a
        // deferral (the starvation signal under heavy contention)
        if out.light_admit_rounds.len() < 2
            && sched.queue_len() > 0
            && !active.iter().any(|&(_, _, _, t)| t == LIGHT)
        {
            out.light_deferred_rounds += 1;
        }
        // the per-tenant gauges must reconcile with the pool at EVERY
        // step of the run, contended or not
        assert_tenant_gauges_reconcile(&pa, &gauges);
        // decode-round stand-in: age the active lanes, retire expired ones
        let mut i = 0;
        while i < active.len() {
            active[i].2 -= 1;
            if active[i].2 == 0 {
                let (_, slot, _, tenant) = active.swap_remove(i);
                assert!(pa.release(slot));
                if tenant == LIGHT {
                    out.light_completed += 1;
                } else {
                    out.heavy_completed += 1;
                }
            } else {
                i += 1;
            }
        }
        round += 1;
    }
    assert_eq!(pa.pool_stats().blocks_in_use, 0, "no leaked blocks");
    out
}

#[test]
fn two_tenant_differential_quotas_stop_heavy_starving_light() {
    // Acceptance differential. Quotas OFF: the heavy tenant's queue
    // saturates the pool and the light tenant's admissions are deferred
    // round after round. Quotas ON (reserved floor for the light
    // tenant): the light tenant admits immediately and completes inside
    // its floor, while the heavy tenant still finishes everything.
    let starved = run_tenant_contention(0);
    let fair = run_tenant_contention(4);

    // both runs eventually complete everything (quotas are not a DoS)
    assert_eq!(starved.heavy_completed, 6);
    assert_eq!(fair.heavy_completed, 6);
    assert_eq!(starved.light_completed, 2);
    assert_eq!(fair.light_completed, 2);

    // without quotas the light tenant waits behind the heavy queue...
    assert!(
        starved.light_deferred_rounds >= 4,
        "expected sustained deferral, got {}",
        starved.light_deferred_rounds
    );
    let starved_first = *starved.light_admit_rounds.first().unwrap();
    // ...with quotas its floor admits it in the very first round
    let fair_first = *fair.light_admit_rounds.first().unwrap();
    assert_eq!(fair_first, 0, "light tenant admits inside its floor");
    assert!(
        starved_first >= 4,
        "quotas-off run admitted light at round {starved_first}, \
         expected starvation past round 4"
    );
    assert!(
        fair.light_admit_rounds.last().unwrap() + 1 < starved_first,
        "every light admission under quotas beats the first one without"
    );
    assert_eq!(fair.light_deferred_rounds, 0, "no deferrals under quotas");
}

// ------------------------------------------------------------- sharding

use fastkv::coordinator::decode::{shard_pin_keys, stale_shards};
use fastkv::ShardSpec;

/// Meta with 4 KV heads so S ∈ {1, 2, 4} are all valid shard counts.
fn shard_meta() -> ModelMeta {
    ModelMeta {
        vocab_size: 256,
        d_model: 16,
        n_layers: 2,
        n_heads: 4,
        n_kv_heads: 4,
        head_dim: 2,
        tsp_layer: 1,
        window: 2,
        pool_kernel: 3,
        max_train_len: 64,
    }
}

#[test]
fn shard_count_that_does_not_divide_kv_heads_is_rejected_at_config_time() {
    // The config-time gate with the user-facing message…
    let err = ShardSpec::new(3, 4, 2).unwrap_err();
    assert!(err.contains("does not divide"), "{err}");
    assert!(err.contains("kv_heads 4"), "{err}");
    assert!(ShardSpec::new(0, 4, 2).is_err());
    for ok in [1usize, 2, 4] {
        assert!(ShardSpec::new(ok, 4, 2).is_ok(), "S={ok} divides 4");
    }
    // …and PagedArena::new enforces it for PagingConfig::shards.
    let m = shard_meta();
    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        PagedArena::new(
            &m,
            1,
            8,
            PagingConfig { shards: 3, ..Default::default() },
        )
    }));
    let msg = *res
        .expect_err("S=3 with 4 KV heads must be rejected")
        .downcast::<String>()
        .expect("panic carries the config error string");
    assert!(msg.contains("invalid PagingConfig::shards"), "{msg}");
    assert!(msg.contains("does not divide"), "{msg}");
}

/// Apply one identical mutation schedule to every arena and assert the
/// sharded stores never drift from the unsharded baseline: same staged
/// bytes, same pool accounting, and every shard projection reassembles
/// bit-identically to the canonical dense slab.
#[test]
fn prop_sharded_store_is_bit_identical_to_unsharded() {
    for (seed, mut rng) in cases(40) {
        let m = shard_meta();
        let b = rng.range(1, 3);
        let c = rng.range(8, 20);
        let bt = rng.range(2, 5);
        let prefix = rng.chance(0.5);
        let mk = |s: usize| PagingConfig {
            block_tokens: bt,
            prefix_cache: prefix,
            shards: s,
            ..Default::default()
        };
        let shard_counts = [1usize, 2, 4];
        let mut arenas: Vec<PagedArena> = shard_counts
            .iter()
            .map(|&s| PagedArena::new(&m, b, c, mk(s)))
            .collect();
        let mut slots: Vec<usize> = Vec::new();
        for step in 0..rng.range(6, 20) {
            let op = rng.below(5);
            match op {
                0 | 1 => {
                    let rc = rand_cache(
                        &mut rng,
                        &m,
                        c.min(12),
                        (seed * 1000 + step as u64) as f64,
                    );
                    let got: Vec<Option<usize>> = arenas
                        .iter_mut()
                        .map(|a| KvStore::admit(a, &rc))
                        .collect();
                    assert!(
                        got.iter().all(|g| *g == got[0]),
                        "seed {seed}: admit outcomes diverged {got:?}"
                    );
                    if let Some(slot) = got[0] {
                        slots.push(slot);
                    }
                }
                2 if !slots.is_empty() => {
                    let slot = slots[rng.below(slots.len())];
                    let stepk = rand_step(&mut rng, &m, b);
                    let stepv = rand_step(&mut rng, &m, b);
                    let got: Vec<AppendResult> = arenas
                        .iter_mut()
                        .map(|a| KvStore::append(a, slot, &stepk, &stepv))
                        .collect();
                    assert!(
                        got.iter().all(|g| *g == got[0]),
                        "seed {seed}: append outcomes diverged"
                    );
                }
                3 if !slots.is_empty() => {
                    // block-granular compaction with a shared keep-set
                    let slot = slots[rng.below(slots.len())];
                    let lens = arenas[0].layer_lens(slot);
                    let keep: Vec<Vec<usize>> = lens
                        .iter()
                        .map(|&n| {
                            (0..n).filter(|_| rng.chance(0.6)).collect()
                        })
                        .collect();
                    let got: Vec<usize> = arenas
                        .iter_mut()
                        .map(|a| KvStore::compact(a, slot, &keep))
                        .collect();
                    assert!(
                        got.iter().all(|g| *g == got[0]),
                        "seed {seed}: compact released diverged {got:?}"
                    );
                }
                4 if !slots.is_empty() => {
                    // preempt-resume roundtrip through the swap arena
                    // (the restore picks the lowest free lane, which may
                    // differ from the preempted one — track it, and pin
                    // that every arena picks the same lane)
                    let idx = rng.below(slots.len());
                    let slot = slots[idx];
                    let handles: Vec<_> = arenas
                        .iter_mut()
                        .map(|a| a.swap_out(slot).expect("default budget"))
                        .collect();
                    let mut restored_to: Option<usize> = None;
                    for (a, h) in arenas.iter_mut().zip(handles) {
                        match a.swap_in(h) {
                            SwapIn::Restored(s) => {
                                if let Some(prev) = restored_to {
                                    assert_eq!(
                                        s, prev,
                                        "seed {seed}: lane choice diverged"
                                    );
                                }
                                restored_to = Some(s);
                            }
                            other => {
                                panic!("seed {seed}: swap-in {other:?}")
                            }
                        }
                    }
                    slots[idx] = restored_to.expect("restored above");
                }
                _ if !slots.is_empty() && rng.chance(0.3) => {
                    let slot = slots.swap_remove(rng.below(slots.len()));
                    for a in arenas.iter_mut() {
                        assert!(a.release(slot), "seed {seed}");
                    }
                }
                _ => {}
            }

            // Differential: staged bytes + pool accounting match the
            // unsharded baseline after every step…
            let base = arenas[0].stage();
            let base_ps = arenas[0].pool_stats();
            for (i, a) in arenas.iter().enumerate().skip(1) {
                let st = a.stage();
                assert_eq!(st.lens.data, base.lens.data, "seed {seed}");
                assert_eq!(st.k.data, base.k.data, "seed {seed} S={}", shard_counts[i]);
                assert_eq!(st.v.data, base.v.data, "seed {seed} S={}", shard_counts[i]);
                let ps = a.pool_stats();
                assert_eq!(
                    (ps.blocks_in_use, ps.blocks_cached, ps.blocks_free),
                    (
                        base_ps.blocks_in_use,
                        base_ps.blocks_cached,
                        base_ps.blocks_free
                    ),
                    "seed {seed}: pool accounting S={}",
                    shard_counts[i]
                );
            }
            // …and every arena's shard projections reassemble to its own
            // canonical dense slab bit-identically.
            let (base_k, base_v) = {
                let v = arenas[0].view();
                let (k, vv) = v.slab_tensors(v.num_blocks);
                (k.data, vv.data)
            };
            for (i, a) in arenas.iter().enumerate() {
                let view = a.view();
                assert_eq!(view.shards, shard_counts[i]);
                assert_eq!(view.shard_versions.len(), shard_counts[i]);
                let (rk, rv) = view.reassembled_slab();
                let (dk, dv) = view.slab_tensors(view.num_blocks);
                assert_eq!(rk, dk.data, "seed {seed}: K reassembly S={}", shard_counts[i]);
                assert_eq!(rv, dv.data, "seed {seed}: V reassembly S={}", shard_counts[i]);
                assert_eq!(dk.data, base_k, "seed {seed}: slab vs baseline");
                assert_eq!(dv.data, base_v, "seed {seed}: slab vs baseline");
            }
        }
    }
}

#[test]
fn sharded_stack_matches_unsharded_token_streams_and_final_kv() {
    // Acceptance differential: identical token streams and bit-identical
    // final KV through the full serve lifecycle (admit, decode, preempt,
    // swap-resume, retire) for every valid shard count of the sim model
    // (kv_heads = 2 -> S ∈ {1, 2}).
    let prompts: Vec<Vec<i32>> =
        vec![vec![10, 11, 12], vec![20, 21, 22, 23], vec![30, 31]];
    let max_new = 5;
    let base = run_stack_sharded(128 << 20, &prompts, max_new, 2, 1);
    let sharded = run_stack_sharded(128 << 20, &prompts, max_new, 2, 2);
    for id in 0..prompts.len() as u64 {
        assert_eq!(
            base.streams[&id], sharded.streams[&id],
            "token stream diverged for request {id} under S=2"
        );
        assert_eq!(
            base.final_rows[&id], sharded.final_rows[&id],
            "final KV diverged for request {id} under S=2"
        );
    }
    assert_eq!(base.policy_calls, sharded.policy_calls);
}

#[test]
fn single_shard_mutation_marks_only_that_shard_stale() {
    // The upload-amplification acceptance property at the store level: a
    // whole-row append dirties every shard; a head-local mutation marks
    // exactly one shard for re-upload (the decode planner and the bench
    // judge staleness through the same `stale_shards` helper).
    let m = shard_meta();
    let cfg = PagingConfig {
        block_tokens: 2,
        prefix_cache: false,
        shards: 4,
        ..Default::default()
    };
    let mut pa = PagedArena::new(&m, 1, 8, cfg);
    let rc = rand_cache(&mut Rng::new(7), &m, 6, 3.0);
    let slot = KvStore::admit(&mut pa, &rc).unwrap();
    let mut mirror: HashMap<String, u64> = HashMap::new();
    let mut sync = |pa: &PagedArena, mirror: &mut HashMap<String, u64>| {
        let view = pa.view();
        let keys = shard_pin_keys(&view);
        let stale =
            stale_shards(&view, &keys, &|k, v| mirror.get(k).copied() == Some(v));
        for &s in &stale {
            mirror.insert(keys[s].0.clone(), view.shard_versions[s]);
            mirror.insert(keys[s].1.clone(), view.shard_versions[s]);
        }
        stale
    };
    assert_eq!(sync(&pa, &mut mirror), vec![0, 1, 2, 3], "cold start");
    assert_eq!(sync(&pa, &mut mirror), Vec::<usize>::new(), "all current");

    // whole-row append: every shard re-uploads
    let step = rand_step(&mut Rng::new(8), &m, 1);
    assert_eq!(KvStore::append(&mut pa, slot, &step, &step), AppendResult::Ok);
    assert_eq!(sync(&pa, &mut mirror), vec![0, 1, 2, 3], "append dirties all");

    // head-local mutation: exactly one shard re-uploads
    let srw = pa.shard_spec().shard_row_elems();
    assert!(pa.mutate_shard_row(slot, 0, 0, 2, &vec![9.5; srw], &vec![-9.5; srw]));
    assert_eq!(sync(&pa, &mut mirror), vec![2], "locality: only shard 2");

    // the mutation landed in the canonical slab too: row 0 of layer 0 of
    // the (only) lane sits at the start of the staged K plane
    let st = pa.stage();
    let re = pa.row_elems();
    let row0 = &st.k.data[..re];
    assert_eq!(&row0[2 * srw..3 * srw], &vec![9.5; srw][..]);
}

#[test]
fn swap_half_roundtrip_within_tolerance_and_halves_budget_pressure() {
    let m = shard_meta();
    let mk = |half: bool| PagingConfig {
        block_tokens: 2,
        prefix_cache: false,
        swap_half: half,
        ..Default::default()
    };
    // Baseline lane: the exact f32 path for byte comparison.
    let rc = rand_cache(&mut Rng::new(42), &m, 10, 5.0);
    let elems: usize = rc.lens.iter().sum::<usize>() * rc.row_elems() * 2;

    let mut full = PagedArena::new(&m, 1, 12, mk(false));
    let slot = KvStore::admit(&mut full, &rc).unwrap();
    let before = lane_rows(&full, slot, m.n_layers);
    let h = full.swap_out(slot).unwrap();
    assert_eq!(full.swap_stats().used_bytes, elems * 4, "f32 bytes");
    assert!(matches!(full.swap_in(h), SwapIn::Restored(_)));
    assert_eq!(
        lane_rows(&full, slot, m.n_layers),
        before,
        "f32 swap stays bit-identical"
    );

    let mut half = PagedArena::new(&m, 1, 12, mk(true));
    let slot = KvStore::admit(&mut half, &rc).unwrap();
    let before = lane_rows(&half, slot, m.n_layers);
    let h = half.swap_out(slot).unwrap();
    // swap_bytes_used reflects the ENCODED size: half the f32 payload.
    assert_eq!(half.swap_stats().used_bytes, elems * 2, "f16 bytes");
    assert!(matches!(half.swap_in(h), SwapIn::Restored(_)));
    let after = lane_rows(&half, slot, m.n_layers);
    let mut max_rel = 0f32;
    for (b_l, a_l) in before.iter().zip(&after) {
        assert_eq!(b_l.len(), a_l.len());
        for (b, a) in b_l.iter().zip(a_l) {
            let tol = b.abs() * (2.0f32).powi(-11) + 1e-6;
            assert!(
                (a - b).abs() <= tol,
                "f16 restore error {} > tol {tol} ({b} -> {a})",
                (a - b).abs()
            );
            if b.abs() > 1e-3 {
                max_rel = max_rel.max((a - b).abs() / b.abs());
            }
        }
    }
    assert!(max_rel > 0.0, "rows large enough that f16 actually rounds");

    // A tiny budget that fits the f16 lane but not the f32 lane: the
    // codec is what makes the swap admissible at all.
    let tiny = PagingConfig {
        block_tokens: 2,
        prefix_cache: false,
        swap_bytes: elems * 2 + 16,
        swap_half: true,
        ..Default::default()
    };
    let mut pa = PagedArena::new(&m, 1, 12, tiny);
    let slot = KvStore::admit(&mut pa, &rc).unwrap();
    assert!(pa.swap_out(slot).is_some(), "encoded lane fits the budget");
}

#[test]
fn lossy_swap_never_reregisters_preserved_hashes() {
    // An f16 restore writes *approximations* of the serialized rows: the
    // preserved chain hashes must not be re-registered for those fresh
    // blocks, or the prefix cache would alias lossy content to the exact
    // chain and hand it to future admissions.
    let m = shard_meta();
    // pool of exactly 12 blocks: rc takes 4, the filler takes all 12
    // (evicting rc's parked blocks and unregistering their hashes).
    let cfg = PagingConfig {
        block_tokens: 2,
        num_blocks: Some(12),
        prefix_cache: true,
        swap_half: true,
        ..Default::default()
    };
    let mut pa = PagedArena::new(&m, 2, 12, cfg);
    let re = m.n_kv_heads * m.head_dim;
    let mut rc = RequestCache::new(&m);
    for l in 0..m.n_layers {
        // 1/3 is NOT f16-representable: any lossy re-share would be
        // detectable as bit drift on a later exact admission.
        rc.k[l] = (0..4 * re).map(|i| (i as f32 + 1.0) / 3.0).collect();
        rc.v[l] = (0..4 * re).map(|i| -(i as f32 + 1.0) / 3.0).collect();
        rc.lens[l] = 4;
    }
    let slot = KvStore::admit(&mut pa, &rc).unwrap();
    let h = pa.swap_out(slot).unwrap();
    assert_eq!(pa.pool_stats().blocks_cached, 4, "rc parked for reuse");
    // Fill the whole pool with distinct content so every one of rc's
    // cached blocks is evicted: the restore can only write fresh
    // (lossy) blocks.
    let mut filler = RequestCache::new(&m);
    for l in 0..m.n_layers {
        filler.k[l] = (0..12 * re).map(|i| 500.0 + (l * 977 + i) as f32).collect();
        filler.v[l] = (0..12 * re).map(|i| -(500.0 + (l * 977 + i) as f32)).collect();
        filler.lens[l] = 12;
    }
    let fs = KvStore::admit(&mut pa, &filler).expect("filler fills the pool");
    assert_eq!(pa.pool_stats().blocks_cached, 0, "rc's blocks evicted");
    assert!(pa.release(fs));
    let restored = match pa.swap_in(h) {
        SwapIn::Restored(s) => s,
        other => panic!("expected restore, got {other:?}"),
    };
    // the restored lane's rows are the f16 approximations…
    let lossy = lane_rows(&pa, restored, m.n_layers);
    assert_ne!(&lossy[0][..re], &rc.k[0][..re], "restore really is lossy");
    // …and a fresh exact admission of the same content must NOT share
    // those blocks — bit-exact rows prove the hashes stayed unregistered.
    let s2 = KvStore::admit(&mut pa, &rc).expect("pool has headroom");
    let rows = lane_rows(&pa, s2, m.n_layers);
    for (l, row) in rows.iter().enumerate() {
        let mut expect = rc.k[l].clone();
        expect.extend(rc.v[l].iter().copied());
        assert_eq!(row, &expect, "layer {l}: exact admission stayed exact");
    }
}

// --------------------------------------------------- in-slab quantization

/// Per-row int8 tolerance for `rewrites` lossy rewrites of a row whose
/// exact content is `row`: each re-quantization contributes at most half
/// the quantization step (`scale = max|row| / 127`), with headroom for
/// the slight scale drift that re-encoding already-dequantized content
/// introduces.
fn int8_row_tol(row: &[f32], rewrites: usize) -> f32 {
    let max = row.iter().fold(0f32, |a, x| a.max(x.abs()));
    0.75 * (max / 127.0) * rewrites.max(1) as f32 + 1e-4
}

/// Compare two [`lane_rows`] captures row by row (both are `K ++ V` per
/// layer, so every `re`-sized chunk is one logical row) against the
/// accumulated int8 bound.
fn assert_rows_within_int8_bound(
    exact: &[Vec<f32>],
    quant: &[Vec<f32>],
    re: usize,
    rewrites: usize,
    ctx: &str,
) {
    assert_eq!(exact.len(), quant.len(), "{ctx}: layer count");
    for (l, (el, ql)) in exact.iter().zip(quant).enumerate() {
        assert_eq!(el.len(), ql.len(), "{ctx}: layer {l} row bytes");
        for (r, (erow, qrow)) in el.chunks(re).zip(ql.chunks(re)).enumerate()
        {
            let tol = int8_row_tol(erow, rewrites);
            for (i, (e, q)) in erow.iter().zip(qrow).enumerate() {
                assert!(
                    (e - q).abs() <= tol,
                    "{ctx}: layer {l} row {r} elem {i}: |{e} - {q}| = {} \
                     > tol {tol} ({rewrites} rewrites)",
                    (e - q).abs()
                );
            }
        }
    }
}

#[test]
fn prop_quantized_store_matches_f32_within_bound() {
    // The lossy differential oracle of the acceptance criteria: an
    // int8-precision pool driven in lockstep with an f32 pool through
    // admits, appends, compactions, swap roundtrips, and releases keeps
    // identical lens/slots/results everywhere and never drifts from the
    // exact store by more than the accumulated per-row quantization
    // bound. Shard counts ride along so the sharded quantized mirror is
    // exercised under the same schedules.
    for (seed, mut rng) in cases(60) {
        let m = meta(&mut rng);
        let re = m.n_kv_heads * m.head_dim;
        let lanes = rng.range(1, 3);
        let c = rng.range(6, 16);
        let bt = rng.range(2, 4);
        let shards = if rng.chance(0.3) { m.n_kv_heads } else { 1 };
        let mk = |precision| PagingConfig {
            block_tokens: bt,
            num_blocks: None, // worst-case pool: admission never fails
            prefix_cache: false,
            swap_bytes: 64 << 20,
            shards,
            precision,
            ..Default::default()
        };
        let mut exact = PagedArena::new(&m, lanes, c, mk(KvCodec::F32));
        let mut quant =
            PagedArena::new(&m, lanes, c, mk(KvCodec::Int8PerRow));
        // (slot, lossy-rewrite upper bound for every row of the lane)
        let mut live: Vec<(usize, usize)> = Vec::new();
        for step in 0..rng.range(5, 18) {
            match rng.below(5) {
                0 => {
                    let rc = rand_cache(
                        &mut rng,
                        &m,
                        c.min(8),
                        (seed * 100 + step as u64) as f64,
                    );
                    let se = KvStore::admit(&mut exact, &rc);
                    let sq = KvStore::admit(&mut quant, &rc);
                    assert_eq!(se, sq, "seed {seed}: slot assignment");
                    if let Some(s) = se {
                        live.push((s, 1));
                    }
                }
                1 if !live.is_empty() => {
                    let kv = rand_step(&mut rng, &m, lanes);
                    let (slot, _) = live[rng.below(live.len())];
                    let re_ap = KvStore::append(&mut exact, slot, &kv, &kv);
                    let rq_ap = KvStore::append(&mut quant, slot, &kv, &kv);
                    assert_eq!(re_ap, rq_ap, "seed {seed}: append result");
                }
                2 if !live.is_empty() => {
                    let i = rng.below(live.len());
                    let slot = live[i].0;
                    let lens = KvStore::layer_lens(&exact, slot);
                    assert_eq!(
                        lens,
                        KvStore::layer_lens(&quant, slot),
                        "seed {seed}: lens before compact"
                    );
                    let keep: Vec<Vec<usize>> = lens
                        .iter()
                        .map(|&n| {
                            let k = rng.range(1, n.max(1));
                            rng.distinct_sorted(k.min(n), n)
                        })
                        .collect();
                    KvStore::compact(&mut exact, slot, &keep);
                    KvStore::compact(&mut quant, slot, &keep);
                    // compaction re-quantizes every kept row once
                    live[i].1 += 1;
                }
                3 if !live.is_empty() => {
                    // swap roundtrip: the exact pool stays bit-identical,
                    // the int8 lane re-encodes on park and re-quantizes
                    // on restore (two lossy rewrites)
                    let i = rng.below(live.len());
                    let slot = live[i].0;
                    let he = exact.swap_out(slot).unwrap();
                    let hq = quant.swap_out(slot).unwrap();
                    let se = match exact.swap_in(he) {
                        SwapIn::Restored(s) => s,
                        other => panic!("seed {seed}: exact {other:?}"),
                    };
                    let sq = match quant.swap_in(hq) {
                        SwapIn::Restored(s) => s,
                        other => panic!("seed {seed}: quant {other:?}"),
                    };
                    assert_eq!(se, sq, "seed {seed}: restored lane");
                    let rw = live[i].1 + 2;
                    live[i] = (se, rw);
                }
                4 if !live.is_empty() => {
                    let (slot, _) = live.swap_remove(rng.below(live.len()));
                    assert_eq!(
                        exact.release(slot),
                        quant.release(slot),
                        "seed {seed}: release"
                    );
                }
                _ => {}
            }
            for &(slot, rw) in &live {
                assert_eq!(
                    KvStore::layer_lens(&exact, slot),
                    KvStore::layer_lens(&quant, slot),
                    "seed {seed}: lens drift"
                );
                assert_rows_within_int8_bound(
                    &lane_rows(&exact, slot, m.n_layers),
                    &lane_rows(&quant, slot, m.n_layers),
                    re,
                    rw,
                    &format!("seed {seed} step {step} slot {slot}"),
                );
            }
        }
    }
}

#[test]
fn mixed_precision_pool_gauges_reconcile() {
    // Satellite regression for the hardcoded-`* 4` sweep: every byte
    // gauge must come from `KvCodec::bytes_per_row`, so pools of every
    // tier reconcile exactly — whole-slab vs per-shard, at every valid
    // shard count — and tiered tenants group into the right lane gauges.
    let m = shard_meta(); // 4 KV heads, head_dim 2 -> re = 8
    let re = m.n_kv_heads * m.head_dim;
    let bt = 2usize;
    let blocks = 12usize;
    let mk = |precision, shards| PagingConfig {
        block_tokens: bt,
        num_blocks: Some(blocks),
        prefix_cache: false,
        shards,
        precision,
        ..Default::default()
    };
    let mut slab_bytes_by_codec = Vec::new();
    for codec in KvCodec::ALL {
        for shards in [1usize, 2, 4] {
            let pa = PagedArena::new(&m, 2, 8, mk(codec, shards));
            let ps = pa.pool_stats();
            assert_eq!(ps.codec, codec);
            assert_eq!(
                ps.slab_bytes,
                2 * blocks * bt * codec.bytes_per_row(re),
                "{} slab bytes",
                codec.name()
            );
            let shard_bytes = pa.shard_slab_bytes();
            assert_eq!(shard_bytes.len(), shards);
            let per = 2 * blocks * bt * codec.bytes_per_row(re / shards);
            assert!(
                shard_bytes.iter().all(|&b| b == per),
                "{} S={shards}: uniform shard bytes",
                codec.name()
            );
            // Σ shard bytes equals the whole-slab gauge, except that the
            // int8 per-row scale planes (4 bytes per row per plane) ride
            // along once per shard.
            let scale_planes = (shards - 1) * 2 * blocks * bt * 4;
            let expect = match codec {
                KvCodec::Int8PerRow => ps.slab_bytes + scale_planes,
                _ => ps.slab_bytes,
            };
            assert_eq!(
                shard_bytes.iter().sum::<usize>(),
                expect,
                "{} S={shards}: shard gauges vs slab gauge",
                codec.name()
            );
        }
        slab_bytes_by_codec
            .push(PagedArena::new(&m, 2, 8, mk(codec, 1)).pool_stats().slab_bytes);
    }
    // strict resident-byte ordering at equal block count: int8 < f16 < f32
    let (f32b, f16b, q8b) = (
        slab_bytes_by_codec[0],
        slab_bytes_by_codec[1],
        slab_bytes_by_codec[2],
    );
    assert!(q8b < f16b && f16b < f32b, "tier ordering: {q8b} {f16b} {f32b}");

    // per-tenant tiers: lanes group by *effective* codec and the tenant
    // block gauges still reconcile on a mixed-precision pool
    let pcfg = PagingConfig {
        block_tokens: bt,
        num_blocks: Some(32),
        prefix_cache: false,
        swap_bytes: 1 << 20,
        tenant_quotas: vec![(
            HEAVY,
            TenantQuota::default().with_precision(KvCodec::Int8PerRow),
        )],
        ..Default::default() // pool default stays f32
    };
    let mut pa = PagedArena::new(&m, 3, 8, pcfg);
    let _h = pa.admit_for(&tenant_cache(&m, 4, 10.0), HEAVY).unwrap();
    let _l = pa.admit_for(&tenant_cache(&m, 4, 20.0), LIGHT).unwrap();
    let tiers: HashMap<KvCodec, usize> = pa.lanes_by_tier().into_iter().collect();
    assert_eq!(tiers[&KvCodec::F32], 1, "LIGHT rides the pool default");
    assert_eq!(tiers[&KvCodec::Int8PerRow], 1, "HEAVY's configured tier");
    assert_eq!(tiers[&KvCodec::F16], 0, "empty tiers still reported");
    assert_eq!(tiers.values().sum::<usize>(), 2, "tier gauges cover lanes");
    let metrics = Metrics::default();
    assert_tenant_gauges_reconcile(&pa, &metrics);

    // codec activity counters move only where the codec is lossy
    let mut q = PagedArena::new(&m, 1, 8, mk(KvCodec::Int8PerRow, 1));
    let slot = KvStore::admit(&mut q, &tenant_cache(&m, 4, 30.0)).unwrap();
    let before = q.pool_stats();
    assert!(before.quant_rows > 0, "admission quantizes rows");
    let _ = lane_rows(&q, slot, m.n_layers);
    assert!(
        q.pool_stats().dequant_rows > before.dequant_rows,
        "view reads dequantize"
    );
    let f = PagedArena::new(&m, 1, 8, mk(KvCodec::F32, 1));
    assert_eq!(f.pool_stats().quant_rows, 0, "f32 pool never quantizes");
}

#[test]
fn tenant_precision_tier_prices_swap_at_quantized_bytes() {
    // `would_refuse` consults the *tenant's* tier, not the pool flag: an
    // int8-tier lane is priced and parked at `rows * 2 * (re + 4)` bytes
    // while a default-tier lane in the same f32 pool pays full f32
    // freight — so a budget sized for the quantized lane admits one and
    // refuses the other.
    let m = shard_meta();
    let re = m.n_kv_heads * m.head_dim;
    let rc = rand_cache(&mut Rng::new(11), &m, 10, 7.0);
    let rows: usize = rc.lens.iter().sum();
    let q8_bytes = rows * 2 * KvCodec::Int8PerRow.bytes_per_row(re);
    let f32_bytes = rows * 2 * KvCodec::F32.bytes_per_row(re);
    assert!(q8_bytes * 2 < f32_bytes, "int8 lane well under half of f32");
    let mk = || PagingConfig {
        block_tokens: 2,
        prefix_cache: false,
        swap_bytes: q8_bytes + 8, // fits the int8 lane, nowhere near f32
        tenant_quotas: vec![(
            HEAVY,
            TenantQuota::default().with_precision(KvCodec::Int8PerRow),
        )],
        ..Default::default()
    };
    let mut pa = PagedArena::new(&m, 2, 12, mk());
    let h = pa.admit_for(&rc, HEAVY).unwrap();
    let before = lane_rows(&pa, h, m.n_layers);
    let handle = pa.swap_out(h).expect("int8-tier lane fits the budget");
    assert_eq!(pa.swap_stats().used_bytes, q8_bytes, "encoded size charged");
    let heavy_row = pa
        .tenant_stats()
        .into_iter()
        .find(|t| t.tenant == HEAVY)
        .expect("HEAVY has a tenant row");
    assert_eq!(heavy_row.swap_bytes_used, q8_bytes, "charged to HEAVY");
    let restored = match pa.swap_in(handle) {
        SwapIn::Restored(s) => s,
        other => panic!("expected restore, got {other:?}"),
    };
    // one lossy rewrite: int8 encode on park, decoded back into the f32
    // slab on restore
    assert_rows_within_int8_bound(
        &before,
        &lane_rows(&pa, restored, m.n_layers),
        re,
        1,
        "int8-tier restore",
    );

    // the same budget refuses the default-tier lane, leaving it intact
    let mut pa2 = PagedArena::new(&m, 2, 12, mk());
    let s2 = pa2.admit_for(&rc, LIGHT).unwrap();
    assert!(pa2.swap_out(s2).is_none(), "f32-priced lane over budget");
    assert_eq!(pa2.swap_stats().used_bytes, 0, "refusal charges nothing");
    assert_eq!(
        lane_rows(&pa2, s2, m.n_layers),
        before,
        "refused lane left fully intact"
    );
}

#[test]
fn quantized_stack_matches_f32_token_streams_with_bounded_kv() {
    // The end-to-end oracle of the acceptance criteria: an
    // int8-precision pool pushed through the full serve lifecycle —
    // admit, decode, preempt, swap-resume, retire — emits the IDENTICAL
    // token streams as the f32 stack, its swap resumes stay free of
    // policy re-prefills, and every request's final KV lands inside the
    // accumulated per-row quantization bound.
    let m = sim_meta();
    let re = m.n_kv_heads * m.head_dim;
    let prompts: Vec<Vec<i32>> =
        vec![vec![10, 11, 12], vec![20, 21, 22, 23], vec![30, 31]];
    let max_new = 5;
    let mk = |precision| PagingConfig {
        block_tokens: 2,
        prefix_cache: false,
        swap_bytes: 128 << 20,
        precision,
        ..Default::default()
    };
    let exact = run_stack_cfg(mk(KvCodec::F32), &prompts, max_new, 2);
    let quant = run_stack_cfg(mk(KvCodec::Int8PerRow), &prompts, max_new, 2);
    for id in 0..prompts.len() as u64 {
        assert_eq!(
            exact.streams[&id], quant.streams[&id],
            "token stream diverged for request {id} under int8"
        );
        assert_eq!(quant.streams[&id].len(), max_new);
        // admit quantizes once, the preemption swap re-encodes and
        // restores (two more rewrites); decode appends stay under that
        assert_rows_within_int8_bound(
            &exact.final_rows[&id],
            &quant.final_rows[&id],
            re,
            3,
            &format!("request {id} final KV"),
        );
        assert_ne!(
            exact.final_rows[&id], quant.final_rows[&id],
            "rows large enough that int8 actually rounds (request {id})"
        );
    }
    // the quantized stack still swap-resumes every preempted request —
    // no recompute, no extra prefills
    assert_eq!(exact.policy_calls, quant.policy_calls);
    assert_eq!(quant.metrics.counter(names::PREFILL_RECOMPUTED), 0);
    assert_eq!(quant.metrics.counter(names::SWAP_OUTS), prompts.len() as u64);
    assert_eq!(quant.metrics.counter(names::SWAP_INS), prompts.len() as u64);
}

#[test]
fn f16_slab_roundtrips_representable_values_and_default_stays_lossless() {
    // Lossless pin: the default pool precision is f32 (the flat-vs-paged
    // differentials above enforce bit-identity for it), and the codec
    // taxonomy agrees.
    assert_eq!(PagingConfig::default().precision, KvCodec::F32);
    assert!(KvCodec::F32.is_lossless());
    assert!(!KvCodec::F16.is_lossless());
    assert!(!KvCodec::Int8PerRow.is_lossless());
    // An f16 slab stores exactly-representable content bit-identically
    // while halving resident bytes.
    let m = shard_meta();
    let re = m.n_kv_heads * m.head_dim;
    let mk = |precision| PagingConfig {
        block_tokens: 2,
        num_blocks: Some(8),
        prefix_cache: false,
        precision,
        ..Default::default()
    };
    let mut rc = RequestCache::new(&m);
    for l in 0..m.n_layers {
        // quarter-integers: exact in f16, so any slab rounding shows up
        rc.k[l] = (0..4 * re).map(|i| (i as f32) * 0.25 - 3.0).collect();
        rc.v[l] = rc.k[l].iter().map(|x| -x).collect();
        rc.lens[l] = 4;
    }
    let mut half = PagedArena::new(&m, 1, 8, mk(KvCodec::F16));
    let slot = KvStore::admit(&mut half, &rc).unwrap();
    for (l, row) in lane_rows(&half, slot, m.n_layers).iter().enumerate() {
        let mut expect = rc.k[l].clone();
        expect.extend(rc.v[l].iter().copied());
        assert_eq!(row, &expect, "layer {l}: f16 slab exact on representables");
    }
    assert_eq!(
        half.pool_stats().slab_bytes * 2,
        PagedArena::new(&m, 1, 8, mk(KvCodec::F32)).pool_stats().slab_bytes,
        "f16 slab is half the f32 slab"
    );
}
